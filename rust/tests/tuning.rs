//! Integration tests for the measured-cost autotuning planner: tuning-cache
//! persistence, analytic fallback on corrupted artifacts, tuning-generation
//! staleness (both `CompiledPlan::verify()` and `PlanCache` keying),
//! measured-vs-optimal bit-identity across ConvKinds × backends, per-geometry
//! GEMM-tuning bit-invariance, and the pinned-fixture CI smoke test.
//!
//! Every test serializes on one mutex: the tuning cache, its generation
//! counter, the dispatcher's tuned-geometry registry, and `force_variant`
//! are all process-global, and these tests mutate them.

use conv_einsum::autodiff::CkptPolicy;
use conv_einsum::cost::tuning::{
    self, CalibKey, GemmTuning, Measurement, TuningCache, TUNING_CACHE_ENV,
};
use conv_einsum::einsum::{parse, ConvKind, SizedSpec};
use conv_einsum::kernels::dispatch::{self, Variant, PACK_MIN_FLOPS};
use conv_einsum::tune::{
    calibrate_expr, calibrate_gemm_blocking, CalibrationSpec, GEMM_KC_CANDIDATES,
};
use conv_einsum::util::rng::Rng;
use conv_einsum::{
    compile_expr, Backend, PlanCache, PlanOptions, Strategy, Tensor, TrainWorkspace, VerifyError,
    Workspace,
};
use std::sync::{Mutex, MutexGuard};

static GLOBAL_STATE: Mutex<()> = Mutex::new(());

fn lock_global() -> MutexGuard<'static, ()> {
    // A panicking test must not wedge the rest of the binary.
    GLOBAL_STATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// Restores the forced kernel variant (and clears the global tuning cache)
/// when dropped, so a panicking test cannot leak process-global state into
/// the next one.
struct StateGuard;

impl Drop for StateGuard {
    fn drop(&mut self) {
        dispatch::force_variant(None);
        tuning::global().clear();
    }
}

fn bits(t: &Tensor) -> Vec<u32> {
    t.data().iter().map(|x| x.to_bits()).collect()
}

fn tmp_path(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("conv_einsum_{}_{}.json", name, std::process::id()))
}

fn measurement(fwd: f64, cost: f64) -> Measurement {
    Measurement {
        fwd_secs: fwd,
        train_secs: None,
        cost,
    }
}

#[test]
fn tuning_cache_json_file_round_trip() {
    let _g = lock_global();
    let path = tmp_path("roundtrip");
    let cache = TuningCache::new();
    cache.record("ctx-a", "sig-1", measurement(1.5e-3, 1000.0));
    cache.record(
        "ctx-a",
        "sig-2",
        Measurement {
            fwd_secs: 2.5e-3,
            train_secs: Some(7.5e-3),
            cost: 2000.0,
        },
    );
    cache.record("ctx-b", "sig-1", measurement(9e-4, 500.0));
    cache.set_gemm_tuning(GemmTuning {
        m: 16,
        n: 64,
        k: 32,
        kc: 8,
        min_flops: 1 << 12,
    });
    cache.save_to(path.to_str().unwrap()).unwrap();

    let back = TuningCache::new();
    let loaded = back.load_path(path.to_str().unwrap()).unwrap();
    assert_eq!(loaded, 3);
    assert_eq!(back.context_count(), 2);
    assert_eq!(
        back.lookup("ctx-a", "sig-2"),
        cache.lookup("ctx-a", "sig-2"),
        "train_secs must survive the round trip"
    );
    assert_eq!(back.lookup("ctx-b", "sig-1"), cache.lookup("ctx-b", "sig-1"));
    assert_eq!(back.gemm_tunings(), cache.gemm_tunings());
    std::fs::remove_file(&path).ok();
}

#[test]
fn corrupted_cache_falls_back_to_analytic_without_panicking() {
    let _g = lock_global();
    let _restore = StateGuard;
    for garbage in [
        "",
        "{",
        "not json at all",
        "[1, 2, 3]",
        "{\"kind\": \"something_else\"}",
        // Truncated mid-object.
        "{\"kind\": \"conv_einsum_tuning_cache\", \"contexts\": {\"c\": {\"s\": {\"fwd_",
    ] {
        let path = tmp_path("corrupt");
        std::fs::write(&path, garbage).unwrap();
        let cache = TuningCache::new();
        assert!(
            cache.load_path(path.to_str().unwrap()).is_err(),
            "garbage {garbage:?} must be rejected, not half-loaded"
        );
        assert!(cache.is_empty());
        std::fs::remove_file(&path).ok();
    }
    // With nothing measured for this context, a measured plan reproduces
    // the analytic choice exactly — planning never panics on cache misses.
    let dims = vec![vec![3, 17], vec![17, 29], vec![29, 5]];
    let optimal = compile_expr("ab,bc,cd->ad", &dims, &PlanOptions::default()).unwrap();
    let measured = compile_expr(
        "ab,bc,cd->ad",
        &dims,
        &PlanOptions {
            strategy: Strategy::Measured { top_k: 4 },
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(measured.plan().cost, optimal.plan().cost);
}

#[test]
fn stale_generation_stamp_is_rejected_by_verify() {
    let _g = lock_global();
    let _restore = StateGuard;
    let dims = vec![vec![4, 6], vec![6, 8]];
    let opts = PlanOptions {
        strategy: Strategy::Measured { top_k: 2 },
        ..Default::default()
    };
    let compiled = compile_expr("ij,jk->ik", &dims, &opts).unwrap();
    compiled.verify().expect("fresh measured plan verifies");
    let stamped = compiled.plan().tuning_generation.expect("stamped");

    // Any recording into the global cache moves the generation on.
    tuning::global().record("some-context", "some-sig", measurement(1e-3, 10.0));

    match compiled.verify() {
        Err(VerifyError::TuningGenerationMismatch { plan, current }) => {
            assert_eq!(plan, stamped);
            assert!(current > stamped);
        }
        other => panic!("expected TuningGenerationMismatch, got {other:?}"),
    }
    // Replanning picks up the new generation and verifies again.
    let fresh = compile_expr("ij,jk->ik", &dims, &opts).unwrap();
    fresh.verify().expect("recompiled measured plan verifies");
    // Analytic plans never carry a stamp and are untouched by calibration.
    let optimal = compile_expr("ij,jk->ik", &dims, &PlanOptions::default()).unwrap();
    assert_eq!(optimal.plan().tuning_generation, None);
    optimal.verify().unwrap();
}

#[test]
fn plan_cache_key_rotates_with_tuning_generation() {
    let _g = lock_global();
    let _restore = StateGuard;
    let cache = PlanCache::new();
    let dims = vec![vec![4, 6], vec![6, 8]];
    let opts = PlanOptions {
        strategy: Strategy::Measured { top_k: 2 },
        ..Default::default()
    };
    cache.get_or_compile("ij,jk->ik", &dims, &opts).unwrap();
    cache.get_or_compile("ij,jk->ik", &dims, &opts).unwrap();
    assert_eq!((cache.misses(), cache.hits()), (1, 1));

    // Calibration data landed: the measured key rotates, so the stale
    // compiled plan is never served again.
    tuning::global().record("some-context", "some-sig", measurement(1e-3, 10.0));
    cache.get_or_compile("ij,jk->ik", &dims, &opts).unwrap();
    assert_eq!((cache.misses(), cache.hits()), (2, 1));

    // Analytic strategies key with generation 0 and keep hitting.
    let analytic = PlanOptions::default();
    cache.get_or_compile("ij,jk->ik", &dims, &analytic).unwrap();
    tuning::global().record("other-context", "sig", measurement(1e-3, 10.0));
    cache.get_or_compile("ij,jk->ik", &dims, &analytic).unwrap();
    assert_eq!((cache.misses(), cache.hits()), (3, 2));
}

/// Run forward + one train step, returning (output bits, grad bits).
fn run_both(
    compiled: &conv_einsum::CompiledPlan,
    inputs: &[&Tensor],
) -> (Vec<u32>, Vec<Vec<u32>>) {
    let mut ws = Workspace::new();
    let out = compiled.run(inputs, &mut ws).unwrap();

    let layout = compiled.train_layout(CkptPolicy::StoreAll);
    let mut tws = TrainWorkspace::new();
    let mut dout = Tensor::zeros(compiled.out_shape());
    for (i, v) in dout.data_mut().iter_mut().enumerate() {
        *v = ((i % 13) as f32) * 0.25 - 1.0;
    }
    let mut tout = Tensor::zeros(compiled.out_shape());
    let mut grads: Vec<Tensor> = compiled
        .in_dims()
        .iter()
        .map(|d| Tensor::zeros(d))
        .collect();
    compiled
        .train_step(&layout, inputs, &dout, &mut tws, &mut tout, &mut grads)
        .unwrap();
    assert_eq!(bits(&out), bits(&tout), "taped forward matches inference");
    (bits(&out), grads.iter().map(bits).collect())
}

#[test]
fn measured_plans_bit_identical_to_optimal_across_kinds_and_backends() {
    let _g = lock_global();
    let _restore = StateGuard;
    // Pin the portable kernels: mirror eligibility and accumulation order
    // become machine-independent, so this grid behaves identically on
    // AVX2, NEON, and fallback hosts.
    dispatch::force_variant(Some(Variant::Portable));

    const KINDS: [ConvKind; 4] = [
        ConvKind::Same,
        ConvKind::Valid,
        ConvKind::Full,
        ConvKind::Circular,
    ];
    let backends = [Backend::Scalar, Backend::Parallel { threads: 2 }];

    // A conv expression (2-input conv mode, so every kind is legal) and a
    // pure contraction; both 2-input, so the measured tournament contains
    // exactly the analytic tree (conv steps are never mirrored) or the
    // tree plus its orientation mirror.
    let conv_case = ("bsx,tsx->btx|x", vec![vec![2, 3, 9], vec![4, 3, 9]]);
    let mm_case = ("ij,jk->ik", vec![vec![6, 24], vec![24, 10]]);

    let mut rng = Rng::new(20260808);
    for backend in backends {
        for kind in KINDS {
            let opts = |strategy| PlanOptions {
                strategy,
                conv_kinds: Some(vec![kind]),
                backend,
                ..Default::default()
            };
            let (expr, dims) = conv_case.clone();
            let probes: Vec<Tensor> = dims
                .iter()
                .map(|d| Tensor::rand(d, -1.0, 1.0, &mut rng))
                .collect();
            let inputs: Vec<&Tensor> = probes.iter().collect();
            let optimal = compile_expr(expr, &dims, &opts(Strategy::Optimal)).unwrap();
            let measured =
                compile_expr(expr, &dims, &opts(Strategy::Measured { top_k: 3 })).unwrap();
            assert_eq!(
                run_both(&optimal, &inputs),
                run_both(&measured, &inputs),
                "{expr} kind={kind:?} backend={backend:?}"
            );
        }

        // Contraction case: seed the cache so the measured planner picks
        // the orientation *mirror* — the selection wall-clock can prefer —
        // and prove outputs and gradients still match the analytic plan
        // bit for bit.
        let (expr, dims) = mm_case.clone();
        let sized = SizedSpec::new(parse(expr).unwrap(), dims.clone()).unwrap();
        let base = PlanOptions {
            backend,
            ..Default::default()
        };
        let cands = conv_einsum::candidate_plans(&sized, &base, 1).unwrap();
        assert_eq!(
            cands.len(),
            2,
            "2-input contraction must offer canonical + mirror"
        );
        let ctx = CalibKey::current(&cands[0].expr, &dims, backend, false).context_id();
        // Canonical "slow", mirror "fast": measured choice flips.
        tuning::global().record(&ctx, &cands[0].signature(), measurement(5e-3, cands[0].cost));
        tuning::global().record(&ctx, &cands[1].signature(), measurement(1e-3, cands[1].cost));

        let probes: Vec<Tensor> = dims
            .iter()
            .map(|d| Tensor::rand(d, -1.0, 1.0, &mut rng))
            .collect();
        let inputs: Vec<&Tensor> = probes.iter().collect();
        let optimal = compile_expr(expr, &dims, &base).unwrap();
        let measured = compile_expr(
            expr,
            &dims,
            &PlanOptions {
                strategy: Strategy::Measured { top_k: 1 },
                backend,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(
            measured.plan().signature(),
            cands[1].signature(),
            "seeded measurements must flip selection to the mirror"
        );
        assert_ne!(measured.plan().signature(), optimal.plan().signature());
        assert_eq!(
            run_both(&optimal, &inputs),
            run_both(&measured, &inputs),
            "mirrored measured plan must stay bit-identical ({backend:?})"
        );
        tuning::global().clear();
    }
}

#[test]
fn gemm_kc_tuning_is_bit_invariant() {
    let _g = lock_global();
    let _restore = StateGuard;
    // Native variant: on SIMD hosts the packed GEMM engages for this
    // geometry and the tuned kc actually changes the blocking; on
    // portable hosts resolved_gemm is None both ways and the test
    // degenerates to a (still valid) equality check.
    let dims = vec![vec![16, 32], vec![32, 64]];
    let mut rng = Rng::new(7);
    let probes: Vec<Tensor> = dims
        .iter()
        .map(|d| Tensor::rand(d, -1.0, 1.0, &mut rng))
        .collect();
    let inputs: Vec<&Tensor> = probes.iter().collect();
    let opts = PlanOptions::default();

    let untuned = compile_expr("ij,jk->ik", &dims, &opts).unwrap();
    let mut ws = Workspace::new();
    let before = bits(&untuned.run(&inputs, &mut ws).unwrap());

    // Tune the forward geometry (m, n, k) = (16, 64, 32) to a much
    // smaller cache block; keep the engagement threshold at the default
    // so only the (bit-invariant) blocking changes.
    tuning::global().set_gemm_tuning(GemmTuning {
        m: 16,
        n: 64,
        k: 32,
        kc: 8,
        min_flops: PACK_MIN_FLOPS,
    });
    if let Some(g) = dispatch::resolved_gemm(dispatch::selected(), 16, 64, 32) {
        assert_eq!(g.kc, 8, "tuned kc must be resolved for the geometry");
    }

    let tuned = compile_expr("ij,jk->ik", &dims, &opts).unwrap();
    let after = bits(&tuned.run(&inputs, &mut ws).unwrap());
    assert_eq!(
        before, after,
        "kc-only GEMM tuning must not change result bits"
    );
}

#[test]
fn gemm_blocking_sweep_learns_and_installs_per_geometry_tuning() {
    let _g = lock_global();
    let _restore = StateGuard;
    let spec = CalibrationSpec {
        top_k: 1,
        warmup: 0,
        iters: 1,
        persist: false,
        seed: 11,
    };
    let (m, n, k) = (12, 40, 96);
    let before_gen = tuning::generation();
    let reports = calibrate_gemm_blocking(&[(m, n, k)], &spec).unwrap();
    assert_eq!(reports.len(), 1);
    let r = &reports[0];
    assert_eq!((r.m, r.n, r.k), (m, n, k));
    // The winning depth is one of the swept candidates, clamped to k.
    assert!(r.kc <= k && r.kc >= 1);
    assert!(GEMM_KC_CANDIDATES.iter().any(|&c| c.min(k).max(1) == r.kc));
    // Every distinct clamped depth was timed, plus the unpacked baseline.
    assert!(!r.kc_secs.is_empty());
    assert!(r.kc_secs.iter().all(|&(_, s)| s >= 0.0));
    assert!(r.unpacked_secs >= 0.0);
    // The learned blocking landed in the persistent cache (generation
    // bumped, so stale measured plans re-verify)...
    assert!(tuning::generation() > before_gen);
    let learned = tuning::global().gemm_tunings();
    assert!(
        learned
            .iter()
            .any(|t| (t.m, t.n, t.k, t.kc, t.min_flops) == (m, n, k, r.kc, r.min_flops)),
        "sweep result must be recorded in the tuning cache"
    );
    // ...and in the dispatcher, where the next compile resolves it.
    if let Some(g) = dispatch::resolved_gemm(dispatch::selected(), m, n, k) {
        assert_eq!(g.kc, r.kc, "dispatcher must resolve the learned kc");
    }
    // The JSON row used by the bench artifact carries the sweep.
    let row = r.to_json();
    assert!(row.get("kc").is_some() && row.get("unpacked_secs").is_some());
}

#[test]
fn pinned_fixture_calibration_smoke() {
    // CI runs this with CONV_EINSUM_TUNING_CACHE pointing at the pinned
    // fixture in tests/fixtures/; without the variable the test is a no-op
    // so ordinary `cargo test` stays hermetic.
    let Ok(path) = std::env::var(TUNING_CACHE_ENV) else {
        return;
    };
    let _g = lock_global();
    let _restore = StateGuard;

    // The pinned artifact parses and carries both measurement contexts
    // and a GEMM tuning.
    let local = TuningCache::new();
    let loaded = local.load_path(&path).expect("pinned fixture must parse");
    assert!(loaded >= 1, "fixture carries measurements");
    assert!(
        !local.gemm_tunings().is_empty(),
        "fixture carries a GEMM tuning"
    );

    // Deterministic end-to-end calibration: pinned backend geometry and
    // kernel variant, fixed probe seed, no persistence (the checked-in
    // fixture must never be overwritten by a test run).
    dispatch::force_variant(Some(Variant::Portable));
    let dims = vec![vec![3, 48], vec![48, 32]];
    let opts = PlanOptions {
        strategy: Strategy::Measured { top_k: 2 },
        backend: Backend::Parallel { threads: 2 },
        ..Default::default()
    };
    let spec = CalibrationSpec {
        top_k: 2,
        warmup: 1,
        iters: 3,
        persist: false,
        seed: 7,
    };
    let report = calibrate_expr("ij,jk->ik", &dims, &opts, &spec).unwrap();
    assert!(
        report.candidates.len() >= 2,
        "tournament includes the orientation mirror"
    );
    assert!(report.saved.is_none(), "persist=false never writes");
    assert!(report.best < report.candidates.len());

    // The calibrated context now drives measured planning: the compile
    // succeeds, verifies, and selects the measured wall-clock winner.
    let compiled = compile_expr("ij,jk->ik", &dims, &opts).unwrap();
    compiled.verify().expect("measured plan verifies");
    assert_eq!(
        compiled.plan().signature(),
        report.candidates[report.best].signature,
        "measured planning selects the calibration winner"
    );
}
