//! Chaos suite: deterministic fault schedules against the coordinator.
//!
//! Each test installs a seeded [`FaultPlan`] (cargo feature
//! `fault-injection`) and drives a mixed inference + training workload
//! through [`EvalService`], then checks the service's liveness and
//! correctness contract:
//!
//! - **exactly-once**: every submitted request observes exactly one
//!   terminal outcome — a result or a structured [`ServiceError`] — never
//!   a hung or dropped receiver;
//! - **bit-identity**: any request that *does* succeed under faults
//!   returns bits identical to a fault-free run of the same workload
//!   (scalar backend, `max_batch: 1`, so no batching variance);
//! - **conservation**: after drain, `completed + errors == submitted`;
//! - **clean drain**: `shutdown()` returns and answers all stragglers.
//!
//! Fault plans mutate process-global state, so every test holds
//! [`faults::test_serial`]; the CI chaos job additionally runs the suite
//! with `--test-threads=1`.

#![cfg(feature = "fault-injection")]

use conv_einsum::autodiff::CkptPolicy;
use conv_einsum::coordinator::{EvalService, InferResult, ServiceConfig, ServiceError, TrainResult};
use conv_einsum::exec::conv_einsum;
use conv_einsum::faults::{self, FaultAction, FaultPlan, Schedule};
use conv_einsum::tnn::{build_layer, Decomp};
use conv_einsum::util::rng::Rng;
use conv_einsum::{Backend, Tensor};
use std::sync::mpsc::Receiver;
use std::time::Duration;

const RECV_TIMEOUT: Duration = Duration::from_secs(30);

/// One generated request. Inputs are built deterministically from the
/// seed so the fault-free and faulted runs see identical payloads.
enum Op {
    Eval(Tensor),
    Adhoc(Vec<Tensor>),
    Train(Vec<Tensor>, Tensor),
}

enum Rx {
    Infer(Receiver<InferResult>),
    Train(Receiver<TrainResult>),
}

/// Terminal outcome flattened to comparable bits (`None` = error).
type Outcome = Result<Vec<u32>, ServiceError>;

fn bits(t: &Tensor) -> Vec<u32> {
    t.data().iter().map(|x| x.to_bits()).collect()
}

fn layer() -> (String, Vec<Tensor>, Vec<usize>) {
    let spec = build_layer(Decomp::Cp, 1, 4, 3, 3, 3, 1.0).unwrap();
    let factors = spec.init_factors(&mut Rng::new(9));
    // Output shape for the canonical eval input, used to size `dout`.
    let x = Tensor::zeros(&[1, 3, 6, 6]);
    let mut inputs = vec![&x];
    inputs.extend(factors.iter());
    let y = conv_einsum(&spec.expr, &inputs).unwrap();
    (spec.expr.clone(), factors, y.shape().to_vec())
}

fn build_ops(seed: u64, factors: &[Tensor], dout_shape: &[usize]) -> Vec<Op> {
    let mut rng = Rng::new(seed.wrapping_mul(0x9e37_79b9).wrapping_add(1));
    (0..24)
        .map(|_| match rng.below(4) {
            0 | 1 => Op::Eval(Tensor::rand(&[1, 3, 6, 6], -1.0, 1.0, &mut rng)),
            2 => Op::Adhoc(vec![
                Tensor::rand(&[3, 4], -1.0, 1.0, &mut rng),
                Tensor::rand(&[4, 5], -1.0, 1.0, &mut rng),
            ]),
            _ => {
                let mut tensors = vec![Tensor::rand(&[1, 3, 6, 6], -1.0, 1.0, &mut rng)];
                tensors.extend(factors.iter().cloned());
                let dout = Tensor::rand(dout_shape, -1.0, 1.0, &mut rng);
                Op::Train(tensors, dout)
            }
        })
        .collect()
}

fn chaos_config() -> ServiceConfig {
    ServiceConfig {
        workers: 2,
        // One request per batch + scalar backend: successful faulted
        // results must be bit-identical to the fault-free run.
        max_batch: 1,
        batch_timeout: Duration::from_millis(1),
        backend: Backend::Scalar,
        max_retries: 2,
        request_deadline: Some(Duration::from_secs(5)),
        ..Default::default()
    }
}

/// Submit every op, wait for every terminal outcome, shut down, and check
/// the conservation law. Panics (fails the test) if any receiver hangs.
fn run_workload(expr: &str, factors: &[Tensor], ops: &[Op]) -> Vec<Outcome> {
    let service = EvalService::start(
        chaos_config(),
        vec![("cp".to_string(), expr.to_string(), factors.to_vec())],
    )
    .unwrap();
    let h = service.handle();
    let rxs: Vec<Rx> = ops
        .iter()
        .map(|op| match op {
            Op::Eval(x) => Rx::Infer(h.submit("cp", x.clone()).unwrap()),
            Op::Adhoc(ts) => Rx::Infer(h.submit_adhoc("ij,jk->ik", ts.clone()).unwrap()),
            Op::Train(ts, dout) => Rx::Train(
                h.submit_train(expr, ts.clone(), dout.clone(), CkptPolicy::StoreAll).unwrap(),
            ),
        })
        .collect();
    let outcomes: Vec<Outcome> = rxs
        .into_iter()
        .enumerate()
        .map(|(i, rx)| match rx {
            Rx::Infer(rx) => match rx.recv_timeout(RECV_TIMEOUT) {
                Ok(Ok(y)) => Ok(bits(&y)),
                Ok(Err(e)) => Err(e),
                Err(_) => panic!("request {i} never reached a terminal outcome"),
            },
            Rx::Train(rx) => match rx.recv_timeout(RECV_TIMEOUT) {
                Ok(Ok((y, grads))) => {
                    let mut all = bits(&y);
                    for g in &grads {
                        all.extend(bits(g));
                    }
                    Ok(all)
                }
                Ok(Err(e)) => Err(e),
                Err(_) => panic!("train request {i} never reached a terminal outcome"),
            },
        })
        .collect();
    let m = h.metrics();
    assert_eq!(m.completed + m.errors, m.submitted, "unaccounted terminal outcomes");
    service.shutdown();
    outcomes
}

fn assert_fault_err_is_structured(i: usize, e: &ServiceError) {
    let allowed = matches!(e, ServiceError::WorkerCrashed(_))
        || matches!(e, ServiceError::DeadlineExceeded)
        || matches!(e, ServiceError::Engine(m) if m.contains("injected fault"));
    assert!(allowed, "request {i}: unexpected error under faults: {e}");
}

/// The tentpole chaos property: across a grid of fixed seeds, random
/// panic/delay/error schedules never lose a request, and every success is
/// bit-identical to the fault-free run.
#[test]
fn seeded_fault_schedules_never_lose_a_request() {
    let _g = faults::test_serial();
    let (expr, factors, dout_shape) = layer();
    for seed in [1u64, 7, 23, 101] {
        let ops = build_ops(seed, &factors, &dout_shape);

        // Reference: identical workload, no faults — everything succeeds.
        faults::clear();
        let reference = run_workload(&expr, &factors, &ops);
        let reference: Vec<Vec<u32>> = reference
            .into_iter()
            .enumerate()
            .map(|(i, r)| r.unwrap_or_else(|e| panic!("fault-free request {i} failed: {e}")))
            .collect();

        // Faulted: same workload under a seeded schedule of panics,
        // stalls, and forced errors on every worker site.
        let train_action = if seed % 2 == 0 {
            FaultAction::Error
        } else {
            FaultAction::Panic
        };
        faults::install(
            FaultPlan::new(seed)
                .rule("worker.eval.pre", Schedule::Prob(0.25), FaultAction::Panic)
                .rule(
                    "worker.adhoc.pre",
                    Schedule::Prob(0.25),
                    FaultAction::Delay(Duration::from_millis(3)),
                )
                .rule("worker.train.pre", Schedule::Prob(0.25), train_action),
        );
        let faulted = run_workload(&expr, &factors, &ops);
        faults::clear();

        for (i, (got, want)) in faulted.iter().zip(&reference).enumerate() {
            match got {
                Ok(b) => assert_eq!(b, want, "seed {seed} req {i}: bits differ vs clean run"),
                Err(e) => assert_fault_err_is_structured(i, e),
            }
        }
    }
}

/// Shutdown racing in-flight faulted work still answers every receiver:
/// flushed-and-served, or a structured `Shutdown` error. Nothing dangles.
#[test]
fn shutdown_mid_flight_under_faults_answers_everything() {
    let _g = faults::test_serial();
    faults::install(
        FaultPlan::new(5)
            .rule(
                "worker.eval.pre",
                Schedule::Every(2),
                FaultAction::Delay(Duration::from_millis(10)),
            )
            .rule(
                "worker.train.pre",
                Schedule::Every(3),
                FaultAction::Delay(Duration::from_millis(10)),
            ),
    );
    let (expr, factors, dout_shape) = layer();
    let service = EvalService::start(
        ServiceConfig {
            workers: 2,
            max_batch: 4,
            batch_timeout: Duration::from_millis(20),
            backend: Backend::Scalar,
            ..Default::default()
        },
        vec![("cp".to_string(), expr.clone(), factors.clone())],
    )
    .unwrap();
    let h = service.handle();
    let mut rng = Rng::new(77);
    let mut rxs = Vec::new();
    for _ in 0..16 {
        let x = Tensor::rand(&[1, 3, 6, 6], -1.0, 1.0, &mut rng);
        rxs.push(Rx::Infer(h.submit("cp", x).unwrap()));
    }
    for _ in 0..4 {
        let mut tensors = vec![Tensor::rand(&[1, 3, 6, 6], -1.0, 1.0, &mut rng)];
        tensors.extend(factors.iter().cloned());
        let dout = Tensor::rand(&dout_shape, -1.0, 1.0, &mut rng);
        rxs.push(Rx::Train(h.submit_train(&expr, tensors, dout, CkptPolicy::StoreAll).unwrap()));
    }
    service.shutdown();
    for (i, rx) in rxs.into_iter().enumerate() {
        let terminal_err = |e: ServiceError| {
            assert_eq!(e, ServiceError::Shutdown, "request {i}: drain failure taxonomy");
        };
        match rx {
            Rx::Infer(rx) => match rx.recv_timeout(RECV_TIMEOUT) {
                Ok(Ok(_)) => {}
                Ok(Err(e)) => terminal_err(e),
                Err(_) => panic!("request {i} left dangling across shutdown"),
            },
            Rx::Train(rx) => match rx.recv_timeout(RECV_TIMEOUT) {
                Ok(Ok(_)) => {}
                Ok(Err(e)) => terminal_err(e),
                Err(_) => panic!("train request {i} left dangling across shutdown"),
            },
        }
    }
    let m = h.metrics();
    assert_eq!(m.completed + m.errors, m.submitted);
    faults::clear();
}

/// A deterministic stall longer than the deadline sheds every request
/// with `DeadlineExceeded` — counted once each, retried never.
#[test]
fn deadline_storm_sheds_every_request() {
    let _g = faults::test_serial();
    faults::install(FaultPlan::new(3).rule(
        "worker.eval.pre",
        Schedule::Every(1),
        FaultAction::Delay(Duration::from_millis(30)),
    ));
    let (expr, factors, _) = layer();
    let service = EvalService::start(
        ServiceConfig {
            workers: 1,
            request_deadline: Some(Duration::from_millis(5)),
            backend: Backend::Scalar,
            ..Default::default()
        },
        vec![("cp".to_string(), expr, factors)],
    )
    .unwrap();
    let h = service.handle();
    let mut rng = Rng::new(13);
    let rxs: Vec<_> = (0..6)
        .map(|_| {
            let x = Tensor::rand(&[1, 3, 6, 6], -1.0, 1.0, &mut rng);
            h.submit("cp", x).unwrap()
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx
            .recv_timeout(RECV_TIMEOUT)
            .unwrap_or_else(|_| panic!("request {i} never answered"));
        let shed = matches!(r, Err(ServiceError::DeadlineExceeded));
        assert!(shed, "request {i}: expected a deadline shed");
    }
    assert_eq!(h.metrics().deadline_expired, 6);
    faults::clear();
    service.shutdown();
}
