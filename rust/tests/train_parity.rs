//! Property tests for the workspace-backed training tape: the
//! arena-scheduled `forward_with_tape` + `backward` must be
//! **bit-identical** to the heap tape it replaced — across all four
//! convolution varieties × {StoreAll, Sqrt, None} checkpoint policies ×
//! scalar/parallel backends × 100 re-runs against one workspace — and its
//! gradients must agree with central finite differences. The heap
//! reference (`testsupport/heap_tape.rs`, shared with `bench_hotpath`)
//! replays the pre-refactor algorithm step by step over the same compiled
//! plan through the public atom API.

use conv_einsum::autodiff::{CkptPolicy, MemoryMeter, PathAutodiff};
use conv_einsum::einsum::ConvKind;
use conv_einsum::util::rng::Rng;
use conv_einsum::{compile_expr, Backend, PlanOptions, Tensor, TrainWorkspace, Workspace};
use std::sync::Arc;

#[path = "../testsupport/heap_tape.rs"]
mod heap_tape;
use heap_tape::heap_forward_backward;

const KINDS: [ConvKind; 4] = [
    ConvKind::Same,
    ConvKind::Valid,
    ConvKind::Full,
    ConvKind::Circular,
];

const POLICIES: [CkptPolicy; 3] = [CkptPolicy::StoreAll, CkptPolicy::Sqrt, CkptPolicy::None];

/// A 4-input expression whose conv mode `x` is 2-input (so every
/// [`ConvKind`] is legal) with a contraction tail — 3 pairwise steps, so
/// Sqrt/None genuinely checkpoint and recompute.
fn grid_case() -> (&'static str, Vec<Vec<usize>>) {
    (
        "bsx,tsx,tu,uv->bvx|x",
        vec![vec![2, 3, 9], vec![4, 3, 3], vec![4, 5], vec![5, 3]],
    )
}

fn bits(t: &Tensor) -> Vec<u32> {
    t.data().iter().map(|x| x.to_bits()).collect()
}

fn opts_for(kind: ConvKind, backend: Backend) -> PlanOptions {
    PlanOptions {
        training: true,
        conv_kinds: Some(vec![kind]),
        backend,
        ..Default::default()
    }
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[test]
fn workspace_tape_bit_identical_to_heap_tape_full_grid_100_reruns() {
    // All four ConvKinds × three checkpoint policies × scalar/parallel
    // backends × 100 re-runs against one long-lived workspace: output and
    // every gradient must reproduce the heap tape bit-for-bit, every time.
    let (expr, dims) = grid_case();
    for kind in KINDS {
        for backend in [Backend::Scalar, Backend::Parallel { threads: 2 }] {
            let compiled =
                Arc::new(compile_expr(expr, &dims, &opts_for(kind, backend)).unwrap());
            let mut rng = Rng::new(81);
            let ins: Vec<Tensor> = dims.iter().map(|d| Tensor::rand(d, -1.0, 1.0, &mut rng)).collect();
            let refs: Vec<&Tensor> = ins.iter().collect();
            let dout = Tensor::rand(compiled.out_shape(), -1.0, 1.0, &mut rng);
            let ad = PathAutodiff::from_compiled(Arc::clone(&compiled));
            let mut ws = TrainWorkspace::new();
            let meter = MemoryMeter::new();
            for policy in POLICIES {
                let (want_y, want_g) = heap_forward_backward(&compiled, &refs, &dout, policy);
                for rerun in 0..100 {
                    let d = dout.clone();
                    let (y, g) = ad
                        .forward_backward(&refs, |_| d.clone(), policy, &mut ws, &meter)
                        .unwrap();
                    assert_eq!(
                        bits(&y),
                        bits(&want_y),
                        "{kind:?} {backend:?} {policy:?} rerun {rerun}: output diverged"
                    );
                    for (i, (gi, wi)) in g.iter().zip(want_g.iter()).enumerate() {
                        assert_eq!(
                            bits(gi),
                            bits(wi),
                            "{kind:?} {backend:?} {policy:?} rerun {rerun}: grad {i} diverged"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn workspace_tape_gradients_match_finite_differences_full_grid() {
    // Central finite differences on L = Σ out ⊙ dout for a few probe
    // coordinates per input, across every kind × policy × backend.
    let (expr, dims) = grid_case();
    for kind in KINDS {
        for backend in [Backend::Scalar, Backend::Parallel { threads: 2 }] {
            let opts = opts_for(kind, backend);
            let compiled = Arc::new(compile_expr(expr, &dims, &opts).unwrap());
            let mut rng = Rng::new(82);
            let ins: Vec<Tensor> = dims.iter().map(|d| Tensor::rand(d, -1.0, 1.0, &mut rng)).collect();
            let dout = Tensor::rand(compiled.out_shape(), -1.0, 1.0, &mut rng);
            let ad = PathAutodiff::from_compiled(Arc::clone(&compiled));
            let mut ws = TrainWorkspace::new();
            let meter = MemoryMeter::new();

            let loss = |ins: &[Tensor]| -> f32 {
                let refs: Vec<&Tensor> = ins.iter().collect();
                let mut fws = Workspace::new();
                let o = compiled.run(&refs, &mut fws).unwrap();
                o.data().iter().zip(dout.data()).map(|(a, b)| a * b).sum()
            };

            for policy in POLICIES {
                let refs: Vec<&Tensor> = ins.iter().collect();
                let d = dout.clone();
                let (_y, grads) = ad
                    .forward_backward(&refs, |_| d.clone(), policy, &mut ws, &meter)
                    .unwrap();
                let eps = 1e-2f32;
                for input_idx in 0..ins.len() {
                    let len = ins[input_idx].len();
                    for k in [0usize, len / 2, len - 1] {
                        let mut p = ins.clone();
                        p[input_idx].data_mut()[k] += eps;
                        let mut m = ins.clone();
                        m[input_idx].data_mut()[k] -= eps;
                        let fd = (loss(&p) - loss(&m)) / (2.0 * eps);
                        let an = grads[input_idx].data()[k];
                        assert!(
                            (fd - an).abs() < 3e-2 * (1.0 + an.abs()),
                            "{kind:?} {backend:?} {policy:?} input {input_idx} coord {k}: \
                             fd={fd} analytic={an}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn multiway_circular_conv_path_matches_heap_tape() {
    // A CP-style expression with a multi-way circular conv mode: pairwise
    // steps carry explicit wrap moduli, which the arena replay must honour
    // exactly like the heap tape.
    let expr = "bsh,rt,rs,rh->bth|h";
    let dims = vec![vec![2, 2, 6], vec![3, 2], vec![3, 2], vec![3, 3]];
    let opts = PlanOptions {
        training: true,
        ..Default::default()
    };
    let compiled = Arc::new(compile_expr(expr, &dims, &opts).unwrap());
    let mut rng = Rng::new(83);
    let ins: Vec<Tensor> = dims.iter().map(|d| Tensor::rand(d, -1.0, 1.0, &mut rng)).collect();
    let refs: Vec<&Tensor> = ins.iter().collect();
    let dout = Tensor::rand(compiled.out_shape(), -1.0, 1.0, &mut rng);
    let ad = PathAutodiff::from_compiled(Arc::clone(&compiled));
    let mut ws = TrainWorkspace::new();
    let meter = MemoryMeter::new();
    for policy in POLICIES {
        let (want_y, want_g) = heap_forward_backward(&compiled, &refs, &dout, policy);
        let d = dout.clone();
        let (y, g) = ad
            .forward_backward(&refs, |_| d.clone(), policy, &mut ws, &meter)
            .unwrap();
        assert_eq!(bits(&y), bits(&want_y), "{policy:?}: output diverged");
        for (i, (gi, wi)) in g.iter().zip(want_g.iter()).enumerate() {
            assert_eq!(bits(gi), bits(wi), "{policy:?}: grad {i} diverged");
        }
    }
}

#[test]
fn one_workspace_serves_alternating_plans() {
    // Alternate two different plans (different arena layouts, different
    // scratch sizes) against one TrainWorkspace: results must stay
    // bit-identical to each plan's heap reference — the arena only grows
    // and carries no state between steps.
    let (expr_a, dims_a) = grid_case();
    let expr_b = "bsh,rt,rs,rh->bth|h";
    let dims_b = vec![vec![2, 2, 6], vec![3, 2], vec![3, 2], vec![3, 3]];
    let opts = PlanOptions {
        training: true,
        ..Default::default()
    };
    let ca = Arc::new(
        compile_expr(
            expr_a,
            &dims_a,
            &PlanOptions {
                conv_kinds: Some(vec![ConvKind::Same]),
                ..opts.clone()
            },
        )
        .unwrap(),
    );
    let cb = Arc::new(compile_expr(expr_b, &dims_b, &opts).unwrap());
    let mut rng = Rng::new(84);
    let ins_a: Vec<Tensor> = dims_a.iter().map(|d| Tensor::rand(d, -1.0, 1.0, &mut rng)).collect();
    let ins_b: Vec<Tensor> = dims_b.iter().map(|d| Tensor::rand(d, -1.0, 1.0, &mut rng)).collect();
    let refs_a: Vec<&Tensor> = ins_a.iter().collect();
    let refs_b: Vec<&Tensor> = ins_b.iter().collect();
    let dout_a = Tensor::rand(ca.out_shape(), -1.0, 1.0, &mut rng);
    let dout_b = Tensor::rand(cb.out_shape(), -1.0, 1.0, &mut rng);
    let (want_ya, want_ga) = heap_forward_backward(&ca, &refs_a, &dout_a, CkptPolicy::Sqrt);
    let (want_yb, want_gb) = heap_forward_backward(&cb, &refs_b, &dout_b, CkptPolicy::Sqrt);

    let ad_a = PathAutodiff::from_compiled(Arc::clone(&ca));
    let ad_b = PathAutodiff::from_compiled(Arc::clone(&cb));
    let mut ws = TrainWorkspace::new();
    let meter = MemoryMeter::new();
    for _ in 0..10 {
        let d = dout_a.clone();
        let (y, g) = ad_a
            .forward_backward(&refs_a, |_| d.clone(), CkptPolicy::Sqrt, &mut ws, &meter)
            .unwrap();
        assert_eq!(bits(&y), bits(&want_ya));
        for (gi, wi) in g.iter().zip(want_ga.iter()) {
            assert_eq!(bits(gi), bits(wi));
        }
        let d = dout_b.clone();
        let (y, g) = ad_b
            .forward_backward(&refs_b, |_| d.clone(), CkptPolicy::Sqrt, &mut ws, &meter)
            .unwrap();
        assert_eq!(bits(&y), bits(&want_yb));
        for (gi, wi) in g.iter().zip(want_gb.iter()) {
            assert_eq!(bits(gi), bits(wi));
        }
    }
}

#[test]
fn into_variants_match_allocating_variants() {
    // The allocation-free `_into` entry points must produce the same bits
    // as the convenience wrappers.
    let (expr, dims) = grid_case();
    let opts = opts_for(ConvKind::Same, Backend::Scalar);
    let compiled = Arc::new(compile_expr(expr, &dims, &opts).unwrap());
    let mut rng = Rng::new(85);
    let ins: Vec<Tensor> = dims.iter().map(|d| Tensor::rand(d, -1.0, 1.0, &mut rng)).collect();
    let refs: Vec<&Tensor> = ins.iter().collect();
    let dout = Tensor::rand(compiled.out_shape(), -1.0, 1.0, &mut rng);
    let ad = PathAutodiff::from_compiled(Arc::clone(&compiled));
    let meter = MemoryMeter::new();

    let mut ws = TrainWorkspace::new();
    let d = dout.clone();
    let (want_y, want_g) = ad
        .forward_backward(&refs, |_| d.clone(), CkptPolicy::Sqrt, &mut ws, &meter)
        .unwrap();

    let mut out = Tensor::zeros(compiled.out_shape());
    let mut grads: Vec<Tensor> = dims.iter().map(|d| Tensor::zeros(d)).collect();
    for _ in 0..5 {
        let token = ad
            .forward_with_tape_into(&refs, CkptPolicy::Sqrt, &mut ws, &mut out, &meter)
            .unwrap();
        ad.backward_into(&token, &dout, &mut ws, &mut grads, &meter)
            .unwrap();
        assert_eq!(bits(&out), bits(&want_y));
        for (gi, wi) in grads.iter().zip(want_g.iter()) {
            assert_eq!(bits(gi), bits(wi));
        }
    }
}
