//! Property tests for the persistent-pool parallel backend and the SIMD
//! microkernels: every parallel path must be **bit-identical** to the
//! scalar backend — across all four convolution varieties, worker counts,
//! 100 re-runs against one workspace, and under concurrent use from
//! multiple threads — and the microkernels must reproduce their documented
//! accumulation order exactly on ragged (non-multiple-of-8) lengths.

use conv_einsum::einsum::{parse, ConvKind, SizedSpec};
use conv_einsum::exec::{pairwise_vjp_with, pairwise_with};
use conv_einsum::kernels::{add8, axpy8, dot8, LANES};
use conv_einsum::util::rng::Rng;
use conv_einsum::{compile_expr, Backend, ExecOptions, PlanOptions, Tensor, Workspace};

const KINDS: [ConvKind; 4] = [
    ConvKind::Same,
    ConvKind::Valid,
    ConvKind::Full,
    ConvKind::Circular,
];

fn conv_spec(kind: ConvKind) -> SizedSpec {
    SizedSpec::with_kinds(
        parse("bsx,tsx->btx|x").unwrap(),
        vec![vec![2, 3, 11], vec![4, 3, 3]],
        vec![kind],
    )
    .unwrap()
}

#[test]
fn persistent_pool_bit_identical_all_kinds_workers_and_reruns() {
    // All four ConvKinds × 1/2/4 workers × 100 re-runs: the persistent
    // pool must reproduce the scalar backend bit-for-bit every time (same
    // microkernels, same per-row order, chunk results independent of which
    // worker claims them).
    for kind in KINDS {
        let s = conv_spec(kind);
        let mut rng = Rng::new(71);
        let a = Tensor::rand(&s.dims[0], -1.0, 1.0, &mut rng);
        let b = Tensor::rand(&s.dims[1], -1.0, 1.0, &mut rng);
        let scalar = pairwise_with(&s, &a, &b, &[], &ExecOptions::scalar());
        for workers in [1usize, 2, 4] {
            let opts = ExecOptions::parallel(workers);
            for rerun in 0..100 {
                let par = pairwise_with(&s, &a, &b, &[], &opts);
                assert_eq!(
                    par.data(),
                    scalar.data(),
                    "{kind:?} workers={workers} rerun={rerun}"
                );
            }
        }
    }
}

#[test]
fn contraction_forward_bit_identical_scalar_vs_pool() {
    // Pure contraction with a ragged contraction length (s = 13, not a
    // multiple of 8): scalar matmul and the pool's per-row dot8 now share
    // the normative blocked order, so even the matmul path is bit-exact.
    let s = SizedSpec::new(
        parse("gts,gns->gtn").unwrap(),
        vec![vec![3, 5, 13], vec![3, 7, 13]],
    )
    .unwrap();
    let mut rng = Rng::new(72);
    let a = Tensor::rand(&[3, 5, 13], -1.0, 1.0, &mut rng);
    let b = Tensor::rand(&[3, 7, 13], -1.0, 1.0, &mut rng);
    let scalar = pairwise_with(&s, &a, &b, &[], &ExecOptions::scalar());
    for workers in [1usize, 2, 4] {
        let par = pairwise_with(&s, &a, &b, &[], &ExecOptions::parallel(workers));
        assert_eq!(par.data(), scalar.data(), "workers={workers}");
    }
}

#[test]
fn vjp_bit_identical_scalar_vs_pool_all_kinds() {
    // Training path: the VJP replayed through the pool must match the
    // scalar backward bit-for-bit for every convolution variety.
    for kind in KINDS {
        let s = conv_spec(kind);
        let mut rng = Rng::new(73);
        let a = Tensor::rand(&s.dims[0], -1.0, 1.0, &mut rng);
        let b = Tensor::rand(&s.dims[1], -1.0, 1.0, &mut rng);
        let out = pairwise_with(&s, &a, &b, &[], &ExecOptions::scalar());
        let dout = Tensor::rand(out.shape(), -1.0, 1.0, &mut rng);
        let (da_s, db_s) = pairwise_vjp_with(&s, &a, &b, &dout, &[], &ExecOptions::scalar());
        for workers in [1usize, 2, 4] {
            let (da_p, db_p) =
                pairwise_vjp_with(&s, &a, &b, &dout, &[], &ExecOptions::parallel(workers));
            assert_eq!(da_p.data(), da_s.data(), "{kind:?} da workers={workers}");
            assert_eq!(db_p.data(), db_s.data(), "{kind:?} db workers={workers}");
        }
    }
}

#[test]
fn compiled_replay_bit_identical_under_concurrent_use() {
    // One compiled plan shared by four threads, each replaying 25 times
    // against its own workspace while all contend for the same persistent
    // pool (the busy flag serializes fan-out): every result must equal the
    // scalar reference bit-for-bit.
    let expr = "bshw,tshw->bthw|hw";
    let dims = vec![vec![2, 3, 10, 10], vec![4, 3, 3, 3]];
    let mut rng = Rng::new(74);
    let x = Tensor::rand(&dims[0], -1.0, 1.0, &mut rng);
    let w = Tensor::rand(&dims[1], -1.0, 1.0, &mut rng);

    let scalar_opts = PlanOptions {
        backend: Backend::Scalar,
        ..Default::default()
    };
    let scalar_plan = compile_expr(expr, &dims, &scalar_opts).unwrap();
    let mut ws = Workspace::new();
    let want = scalar_plan.run(&[&x, &w], &mut ws).unwrap();

    let par_opts = PlanOptions {
        backend: Backend::Parallel { threads: 2 },
        ..Default::default()
    };
    let par_plan = compile_expr(expr, &dims, &par_opts).unwrap();

    std::thread::scope(|scope| {
        for t in 0..4 {
            let plan = &par_plan;
            let (x, w, want) = (&x, &w, &want);
            scope.spawn(move || {
                let mut ws = Workspace::new();
                for rerun in 0..25 {
                    let got = plan.run(&[x, w], &mut ws).unwrap();
                    assert_eq!(
                        got.data(),
                        want.data(),
                        "thread {t} rerun {rerun} diverged from scalar"
                    );
                }
            });
        }
    });
}

// ---------------------------------------------------------------------------
// Microkernel contracts on ragged lengths
// ---------------------------------------------------------------------------

/// Scalar emulation of `dot8`'s documented order: 8 lane accumulators over
/// full blocks, pairwise lane combine, sequential tail.
fn dot8_reference(a: &[f32], b: &[f32]) -> f32 {
    let blocks = a.len() / LANES;
    let mut acc = [0.0f32; LANES];
    for k in 0..blocks {
        for l in 0..LANES {
            acc[l] += a[k * LANES + l] * b[k * LANES + l];
        }
    }
    let mut total =
        ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for i in blocks * LANES..a.len() {
        total += a[i] * b[i];
    }
    total
}

#[test]
fn microkernels_bit_identical_to_reference_on_ragged_lengths() {
    let mut rng = Rng::new(75);
    for len in 0..=41usize {
        let a: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let b: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let init: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();

        // axpy8: per-element, no reassociation — equals the naive loop.
        let mut got = init.clone();
        axpy8(0.75, &a, &mut got);
        let mut want = init.clone();
        for (o, s) in want.iter_mut().zip(&a) {
            *o += 0.75 * s;
        }
        assert_eq!(bits(&got), bits(&want), "axpy8 len {len}");

        // add8: same property.
        let mut got = init.clone();
        add8(&mut got, &a);
        let mut want = init.clone();
        for (o, s) in want.iter_mut().zip(&a) {
            *o += s;
        }
        assert_eq!(bits(&got), bits(&want), "add8 len {len}");

        // dot8: matches its documented blocked order exactly.
        assert_eq!(
            dot8(&a, &b).to_bits(),
            dot8_reference(&a, &b).to_bits(),
            "dot8 len {len}"
        );
    }
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}
