//! Property tests for coalesced training batches: a batch of independent
//! training requests replayed through one `TrainLayout` against one
//! workspace ([`PathAutodiff::train_step_batch_into`]) must produce outputs,
//! input gradients (the batch-mode slices) and **per-segment weight
//! gradients** bit-identical to submitting every request individually —
//! across all four convolution varieties × scalar/parallel backends ×
//! batch sizes {1, 2, 4, 7} × {StoreAll, Sqrt} checkpoint policies — and
//! tape tokens must be invalidated across batch epochs.

use conv_einsum::autodiff::{CkptPolicy, MemoryMeter, PathAutodiff, TrainSegment};
use conv_einsum::einsum::ConvKind;
use conv_einsum::util::rng::Rng;
use conv_einsum::{compile_expr, Backend, PlanOptions, Tensor, TrainWorkspace};
use std::sync::Arc;

const KINDS: [ConvKind; 4] = [
    ConvKind::Same,
    ConvKind::Valid,
    ConvKind::Full,
    ConvKind::Circular,
];

const BATCH_SIZES: [usize; 4] = [1, 2, 4, 7];

const POLICIES: [CkptPolicy; 2] = [CkptPolicy::StoreAll, CkptPolicy::Sqrt];

/// A 4-input expression whose conv mode `x` is 2-input (so every
/// [`ConvKind`] is legal) with a contraction tail — 3 pairwise steps, so
/// checkpointing policies genuinely recompute. Input 0 carries the batch
/// mode `b`; inputs 1–3 are the "weights" whose per-segment gradients the
/// batched replay must keep separate.
fn grid_case() -> (&'static str, Vec<Vec<usize>>) {
    (
        "bsx,tsx,tu,uv->bvx|x",
        vec![vec![2, 3, 9], vec![4, 3, 3], vec![4, 5], vec![5, 3]],
    )
}

fn bits(t: &Tensor) -> Vec<u32> {
    t.data().iter().map(|x| x.to_bits()).collect()
}

fn opts_for(kind: ConvKind, backend: Backend) -> PlanOptions {
    PlanOptions {
        training: true,
        conv_kinds: Some(vec![kind]),
        backend,
        ..Default::default()
    }
}

#[test]
fn batched_train_steps_bit_identical_to_individual_submission_full_grid() {
    let (expr, dims) = grid_case();
    for kind in KINDS {
        for backend in [Backend::Scalar, Backend::Parallel { threads: 2 }] {
            let compiled =
                Arc::new(compile_expr(expr, &dims, &opts_for(kind, backend)).unwrap());
            let ad = PathAutodiff::from_compiled(Arc::clone(&compiled));
            let mut rng = Rng::new(91);
            for &k in &BATCH_SIZES {
                // k independent requests: distinct inputs, distinct weights,
                // distinct cotangents.
                let reqs: Vec<(Vec<Tensor>, Tensor)> = (0..k)
                    .map(|_| {
                        let ins: Vec<Tensor> = dims
                            .iter()
                            .map(|d| Tensor::rand(d, -1.0, 1.0, &mut rng))
                            .collect();
                        let dout = Tensor::rand(compiled.out_shape(), -1.0, 1.0, &mut rng);
                        (ins, dout)
                    })
                    .collect();
                for policy in POLICIES {
                    // Individual submission: each request alone, the way the
                    // pre-batching coordinator served the stream.
                    let mut ws_ref = TrainWorkspace::new();
                    let meter = MemoryMeter::new();
                    let mut want: Vec<(Tensor, Vec<Tensor>)> = Vec::new();
                    for (ins, dout) in &reqs {
                        let refs: Vec<&Tensor> = ins.iter().collect();
                        let d = dout.clone();
                        let yg = ad
                            .forward_backward(&refs, |_| d.clone(), policy, &mut ws_ref, &meter)
                            .unwrap();
                        want.push(yg);
                    }
                    // Coalesced batch: one layout, one workspace, segments
                    // in submission order.
                    let refs: Vec<Vec<&Tensor>> =
                        reqs.iter().map(|(ins, _)| ins.iter().collect()).collect();
                    let mut outs: Vec<Tensor> =
                        (0..k).map(|_| Tensor::zeros(compiled.out_shape())).collect();
                    let mut grads: Vec<Vec<Tensor>> = (0..k)
                        .map(|_| dims.iter().map(|d| Tensor::zeros(d)).collect())
                        .collect();
                    let mut ws = TrainWorkspace::new();
                    let mut segs: Vec<TrainSegment> = refs
                        .iter()
                        .zip(reqs.iter())
                        .zip(outs.iter_mut())
                        .zip(grads.iter_mut())
                        .map(|(((r, req), o), g)| TrainSegment {
                            inputs: r.as_slice(),
                            dout: &req.1,
                            out: o,
                            grads: g.as_mut_slice(),
                        })
                        .collect();
                    ad.train_step_batch_into(&mut segs, policy, &mut ws, &meter)
                        .unwrap();
                    drop(segs);
                    for i in 0..k {
                        assert_eq!(
                            bits(&outs[i]),
                            bits(&want[i].0),
                            "{kind:?} {backend:?} {policy:?} k={k} segment {i}: output diverged"
                        );
                        for (j, (gi, wi)) in
                            grads[i].iter().zip(want[i].1.iter()).enumerate()
                        {
                            assert_eq!(
                                bits(gi),
                                bits(wi),
                                "{kind:?} {backend:?} {policy:?} k={k} segment {i}: \
                                 grad {j} diverged (weight grads must accumulate per segment)"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn stale_tape_rejected_across_batch_epochs() {
    let (expr, dims) = grid_case();
    let compiled = Arc::new(
        compile_expr(expr, &dims, &opts_for(ConvKind::Same, Backend::Scalar)).unwrap(),
    );
    let ad = PathAutodiff::from_compiled(Arc::clone(&compiled));
    let mut rng = Rng::new(92);
    let ins: Vec<Tensor> = dims.iter().map(|d| Tensor::rand(d, -1.0, 1.0, &mut rng)).collect();
    let refs: Vec<&Tensor> = ins.iter().collect();
    let dout = Tensor::rand(compiled.out_shape(), -1.0, 1.0, &mut rng);
    let meter = MemoryMeter::new();
    let mut ws = TrainWorkspace::new();

    // Take a tape token, then run a coalesced batch over the same
    // workspace: the batch advances the epoch per segment, so the old
    // token's backward must be rejected, not silently replay segment state.
    let mut out = Tensor::zeros(compiled.out_shape());
    let token = ad
        .forward_with_tape_into(&refs, CkptPolicy::StoreAll, &mut ws, &mut out, &meter)
        .unwrap();

    let reqs: Vec<(Vec<Tensor>, Tensor)> = (0..2)
        .map(|_| {
            let ins: Vec<Tensor> = dims
                .iter()
                .map(|d| Tensor::rand(d, -1.0, 1.0, &mut rng))
                .collect();
            (ins, Tensor::rand(compiled.out_shape(), -1.0, 1.0, &mut rng))
        })
        .collect();
    let seg_refs: Vec<Vec<&Tensor>> = reqs.iter().map(|(i, _)| i.iter().collect()).collect();
    let mut outs: Vec<Tensor> = (0..2).map(|_| Tensor::zeros(compiled.out_shape())).collect();
    let mut grads: Vec<Vec<Tensor>> = (0..2)
        .map(|_| dims.iter().map(|d| Tensor::zeros(d)).collect())
        .collect();
    let mut segs: Vec<TrainSegment> = seg_refs
        .iter()
        .zip(reqs.iter())
        .zip(outs.iter_mut())
        .zip(grads.iter_mut())
        .map(|(((r, req), o), g)| TrainSegment {
            inputs: r.as_slice(),
            dout: &req.1,
            out: o,
            grads: g.as_mut_slice(),
        })
        .collect();
    ad.train_step_batch_into(&mut segs, CkptPolicy::StoreAll, &mut ws, &meter)
        .unwrap();
    drop(segs);

    let mut stale_grads: Vec<Tensor> = dims.iter().map(|d| Tensor::zeros(d)).collect();
    let err = ad
        .backward_into(&token, &dout, &mut ws, &mut stale_grads, &meter)
        .expect_err("token from before the batch must be invalid after it");
    assert!(
        err.to_string().contains("invalidated"),
        "stale-tape error should say so: {err}"
    );
}
