//! Integration property tests for the paper's Theorems 1–2 at larger scale
//! than the unit suite, plus cross-strategy numeric agreement on full layer
//! expressions from the zoo.
use conv_einsum::exec::{conv_einsum, conv_einsum_ltr};
use conv_einsum::planner::{contract_path, PlanOptions};
use conv_einsum::tnn::{build_layer, Decomp};
use conv_einsum::util::prop;
use conv_einsum::util::rng::Rng;
use conv_einsum::Tensor;

#[test]
fn theorem1_holds_on_resnet_shapes() {
    // Every RCP(M=3) tensorialization of a ResNet-34 3x3 conv site admits a
    // cheaper-than-naive path (Theorem 1 hypotheses hold: H' >> H, R >= S).
    for site in conv_einsum::tnn::arch::resnet34_cifar10() {
        if site.s < 8 {
            continue; // conv1 has S=3; R >= S trivially but skip the stem
        }
        let layer = build_layer(Decomp::Cp, 3, site.t, site.s, site.h, site.w, 1.0).unwrap();
        let dims = layer.expr_dims(16, site.hp, site.wp);
        let plan = contract_path(&layer.expr, &dims, &PlanOptions::default()).unwrap();
        assert!(
            plan.cost < plan.naive_cost,
            "{}: {} !< {}",
            site.stage,
            plan.cost,
            plan.naive_cost
        );
    }
}

#[test]
fn theorem2_holds_on_resnet_shapes() {
    for site in conv_einsum::tnn::arch::resnet34_cifar10() {
        if site.s < 8 {
            continue;
        }
        let layer = build_layer(Decomp::Tucker, 3, site.t, site.s, site.h, site.w, 1.0).unwrap();
        let dims = layer.expr_dims(16, site.hp, site.wp);
        let plan = contract_path(&layer.expr, &dims, &PlanOptions::default()).unwrap();
        assert!(
            plan.cost < plan.naive_cost,
            "{}: {} !< {}",
            site.stage,
            plan.cost,
            plan.naive_cost
        );
    }
}

#[test]
fn property_zoo_path_agreement() {
    // For random zoo layers, optimal and naive paths agree numerically.
    prop::check("zoo-path-agreement", 10, |g| {
        let decomp = *g.pick(&[Decomp::Cp, Decomp::Tucker, Decomp::TensorTrain, Decomp::TensorRing]);
        let m = g.usize_in(1, 2);
        let t = 2 * g.usize_in(1, 2);
        let s = 2 * g.usize_in(1, 2);
        let layer = build_layer(decomp, m, t, s, 3, 3, 1.0).unwrap();
        let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
        let factors = layer.init_factors(&mut rng);
        let x = Tensor::rand(&layer.input_shape(1, 6, 6), -1.0, 1.0, &mut rng);
        let mut inputs: Vec<&Tensor> = vec![&x];
        inputs.extend(factors.iter());
        let a = conv_einsum(&layer.expr, &inputs).unwrap();
        let b = conv_einsum_ltr(&layer.expr, &inputs).unwrap();
        a.assert_close(&b, 1e-3);
    });
}
