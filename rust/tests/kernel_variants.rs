//! Per-variant parity suite for the runtime-dispatched microkernels.
//!
//! The v3 accumulation contract is stated *per variant*: for every kernel
//! variant the host can run (plus the always-present portable fallback),
//! scalar and parallel backends must produce bit-identical outputs and
//! gradients — across all four convolution varieties, the tiny-K /
//! packed-GEMM / unblocked contraction routings, the packed conv-atom
//! weight-panel path (forced on, forced off, and auto-engaged), and the
//! training engine under {StoreAll, Sqrt} checkpoint policies. Packing a
//! conv atom's weights into a zero-padded panel is a pure data-layout
//! change, so packed and unpacked runs of the *same* variant must also be
//! bit-identical to each other. The suite also pins the verifier's
//! rejection of stale compiled artifacts (wrong accumulation-order
//! version, wrong pinned variant) and the tiny-geometry short-circuit
//! that keeps small conv atoms on the plain run loop.
//!
//! Forcing a variant is process-global, so everything runs inside ONE
//! `#[test]` (this integration binary contains nothing else) and the
//! force is cleared at the end.

use conv_einsum::autodiff::{CkptPolicy, MemoryMeter, PathAutodiff};
use conv_einsum::einsum::{parse, ConvKind, SizedSpec};
use conv_einsum::exec::{canonicalize, force_conv_pack, pairwise_vjp_with, pairwise_with};
use conv_einsum::kernels::dispatch::{self, Variant};
use conv_einsum::kernels::{ACCUM_ORDER_VERSION, LANES};
use conv_einsum::util::rng::Rng;
use conv_einsum::{
    compile_expr, Backend, ExecOptions, PlanOptions, Tensor, TrainWorkspace, VerifyError,
};
use std::sync::Arc;

const KINDS: [ConvKind; 4] = [
    ConvKind::Same,
    ConvKind::Valid,
    ConvKind::Full,
    ConvKind::Circular,
];

fn bits(t: &Tensor) -> Vec<u32> {
    t.data().iter().map(|x| x.to_bits()).collect()
}

fn conv_spec(kind: ConvKind) -> SizedSpec {
    SizedSpec::with_kinds(
        parse("bsx,tsx->btx|x").unwrap(),
        vec![vec![2, 3, 11], vec![4, 3, 3]],
        vec![kind],
    )
    .unwrap()
}

/// A conv geometry big enough to auto-engage the packed weight panel
/// (flop estimate 1·4·6·3·64·5 = 23040 ≥ `CONV_PACK_MIN_FLOPS`, t ≥ 2).
fn big_conv_spec(kind: ConvKind) -> SizedSpec {
    SizedSpec::with_kinds(
        parse("bsx,tsx->btx|x").unwrap(),
        vec![vec![4, 3, 64], vec![6, 3, 5]],
        vec![kind],
    )
    .unwrap()
}

fn contraction_spec(g: usize, t: usize, n: usize, s: usize) -> SizedSpec {
    SizedSpec::new(
        parse("gts,gns->gtn").unwrap(),
        vec![vec![g, t, s], vec![g, n, s]],
    )
    .unwrap()
}

/// Plain unfused row-major oracle for `out[g,t,n] = Σ_s a·b` — the exact
/// order the tiny-K short-circuit promises on every variant (and the v1
/// `dot8` order for `s < LANES`, whose lane blocks are empty there).
fn tiny_k_oracle(a: &Tensor, b: &Tensor, g: usize, t: usize, n: usize, s: usize) -> Vec<u32> {
    let av = a.data();
    let bv = b.data();
    let mut out = vec![0.0f32; g * t * n];
    for gi in 0..g {
        for ti in 0..t {
            for ni in 0..n {
                let mut acc = 0.0f32;
                for si in 0..s {
                    acc += av[(gi * t + ti) * s + si] * bv[(gi * n + ni) * s + si];
                }
                out[(gi * t + ti) * n + ni] = acc;
            }
        }
    }
    out.iter().map(|x| x.to_bits()).collect()
}

/// Convolution forward + VJP: scalar vs pool, all four kinds.
fn conv_parity(variant: Variant) {
    for kind in KINDS {
        let s = conv_spec(kind);
        let mut rng = Rng::new(311);
        let a = Tensor::rand(&s.dims[0], -1.0, 1.0, &mut rng);
        let b = Tensor::rand(&s.dims[1], -1.0, 1.0, &mut rng);
        let want = pairwise_with(&s, &a, &b, &[], &ExecOptions::scalar());
        let dout = Tensor::rand(want.shape(), -1.0, 1.0, &mut rng);
        let (da_s, db_s) = pairwise_vjp_with(&s, &a, &b, &dout, &[], &ExecOptions::scalar());
        for workers in [1usize, 2, 4] {
            let opts = ExecOptions::parallel(workers);
            let got = pairwise_with(&s, &a, &b, &[], &opts);
            assert_eq!(
                bits(&got),
                bits(&want),
                "{} {kind:?} forward workers={workers}",
                variant.name()
            );
            let (da_p, db_p) = pairwise_vjp_with(&s, &a, &b, &dout, &[], &opts);
            assert_eq!(
                bits(&da_p),
                bits(&da_s),
                "{} {kind:?} da workers={workers}",
                variant.name()
            );
            assert_eq!(
                bits(&db_p),
                bits(&db_s),
                "{} {kind:?} db workers={workers}",
                variant.name()
            );
        }
    }
}

/// Packed conv-atom weight panels: for a fixed variant, forcing the panel
/// on and off must produce bit-identical outputs and gradients (packing
/// is a pure data-layout change — the packed loop consumes the same
/// weights in the same order, pad lanes are zero weights the existing
/// `w == 0` fast path skips), on scalar and parallel backends, across all
/// four kinds. Also pins the engagement oracle: the big geometry
/// auto-engages, the tiny one short-circuits to the plain run loop
/// (`CONV_PACK_MIN_FLOPS` floor).
fn conv_pack_parity(variant: Variant) {
    for kind in KINDS {
        // Engagement oracle under auto routing.
        let tiny = canonicalize(&conv_spec(kind), &[]);
        let tiny_kernel = tiny.kernel();
        assert_eq!(
            tiny.pack_lens(&tiny_kernel),
            (0, 0),
            "{} {kind:?}: tiny conv atom must stay on the plain run loop",
            variant.name()
        );
        let s = big_conv_spec(kind);
        let big = canonicalize(&s, &[]);
        let big_kernel = big.kernel();
        assert!(
            big.pack_lens(&big_kernel).1 > 0,
            "{} {kind:?}: big conv atom must auto-engage the weight panel",
            variant.name()
        );

        let mut rng = Rng::new(331);
        let a = Tensor::rand(&s.dims[0], -1.0, 1.0, &mut rng);
        let b = Tensor::rand(&s.dims[1], -1.0, 1.0, &mut rng);

        // Unpacked scalar baseline.
        force_conv_pack(Some(false));
        let want = pairwise_with(&s, &a, &b, &[], &ExecOptions::scalar());
        let dout = Tensor::rand(want.shape(), -1.0, 1.0, &mut rng);
        let (da_u, db_u) = pairwise_vjp_with(&s, &a, &b, &dout, &[], &ExecOptions::scalar());

        // Packed (forced) and auto-engaged runs, scalar and pooled, must
        // all reproduce the unpacked bits exactly.
        for force in [Some(true), None] {
            force_conv_pack(force);
            for opts in [
                ExecOptions::scalar(),
                ExecOptions::parallel(1),
                ExecOptions::parallel(2),
                ExecOptions::parallel(4),
            ] {
                let got = pairwise_with(&s, &a, &b, &[], &opts);
                assert_eq!(
                    bits(&got),
                    bits(&want),
                    "{} {kind:?} packed={force:?} forward {:?}",
                    variant.name(),
                    opts.backend
                );
                let (da_p, db_p) = pairwise_vjp_with(&s, &a, &b, &dout, &[], &opts);
                assert_eq!(
                    bits(&da_p),
                    bits(&da_u),
                    "{} {kind:?} packed={force:?} da {:?}",
                    variant.name(),
                    opts.backend
                );
                assert_eq!(
                    bits(&db_p),
                    bits(&db_u),
                    "{} {kind:?} packed={force:?} db {:?}",
                    variant.name(),
                    opts.backend
                );
            }
        }
        force_conv_pack(None);
    }
}

/// Training engine over the packed conv-panel path: plans compiled with
/// the panel forced off vs forced on must train bit-identically under
/// {StoreAll, Sqrt} (the pack decision is captured per compiled kernel,
/// so each plan pins one routing for its whole lifetime).
fn conv_pack_training_parity(variant: Variant) {
    let expr = "bsx,tsx->btx|x";
    let dims = vec![vec![4, 3, 64], vec![6, 3, 5]];
    for kind in KINDS {
        let opts = PlanOptions {
            training: true,
            conv_kinds: Some(vec![kind]),
            ..Default::default()
        };
        force_conv_pack(Some(false));
        let unpacked = Arc::new(compile_expr(expr, &dims, &opts).unwrap());
        force_conv_pack(Some(true));
        let packed = Arc::new(compile_expr(expr, &dims, &opts).unwrap());
        force_conv_pack(None);
        let mut rng = Rng::new(337);
        let ins: Vec<Tensor> = dims.iter().map(|d| Tensor::rand(d, -1.0, 1.0, &mut rng)).collect();
        let refs: Vec<&Tensor> = ins.iter().collect();
        let dout = Tensor::rand(unpacked.out_shape(), -1.0, 1.0, &mut rng);
        let ad_u = PathAutodiff::from_compiled(Arc::clone(&unpacked));
        let ad_p = PathAutodiff::from_compiled(Arc::clone(&packed));
        let mut ws = TrainWorkspace::new();
        let meter = MemoryMeter::new();
        for policy in [CkptPolicy::StoreAll, CkptPolicy::Sqrt] {
            let d = dout.clone();
            let (y_u, g_u) = ad_u
                .forward_backward(&refs, |_| d.clone(), policy, &mut ws, &meter)
                .unwrap();
            let d = dout.clone();
            let (y_p, g_p) = ad_p
                .forward_backward(&refs, |_| d.clone(), policy, &mut ws, &meter)
                .unwrap();
            assert_eq!(
                bits(&y_p),
                bits(&y_u),
                "{} {kind:?} {policy:?}: packed training output diverged",
                variant.name()
            );
            for (i, (gp, gu)) in g_p.iter().zip(g_u.iter()).enumerate() {
                assert_eq!(
                    bits(gp),
                    bits(gu),
                    "{} {kind:?} {policy:?}: packed training grad {i} diverged",
                    variant.name()
                );
            }
        }
    }
}

/// Pure contractions across all three routings (tiny-K short-circuit,
/// packed cache-blocked GEMM, unblocked per-row fallback): scalar vs pool
/// bit-identical, and the tiny-K path equal to the unfused oracle on
/// every variant.
fn contraction_parity(variant: Variant) {
    // (g, t, n, s): tiny-K (s < LANES); GEMM-sized with ragged m/n/k
    // (engages every packed orientation on AVX2 and NEON); small fallback
    // (deep enough to vectorize, too narrow/small to pack).
    let shapes = [(2usize, 5usize, 6usize, 5usize), (4, 48, 40, 33), (2, 8, 5, 16)];
    for (g, t, n, s) in shapes {
        let spec = contraction_spec(g, t, n, s);
        let mut rng = Rng::new(313);
        let a = Tensor::rand(&[g, t, s], -1.0, 1.0, &mut rng);
        let b = Tensor::rand(&[g, n, s], -1.0, 1.0, &mut rng);
        let want = pairwise_with(&spec, &a, &b, &[], &ExecOptions::scalar());
        if s < LANES {
            assert_eq!(
                bits(&want),
                tiny_k_oracle(&a, &b, g, t, n, s),
                "{} tiny-K path must be the plain unfused loop",
                variant.name()
            );
        }
        let dout = Tensor::rand(want.shape(), -1.0, 1.0, &mut rng);
        let (da_s, db_s) = pairwise_vjp_with(&spec, &a, &b, &dout, &[], &ExecOptions::scalar());
        for workers in [1usize, 2, 4] {
            let opts = ExecOptions::parallel(workers);
            let got = pairwise_with(&spec, &a, &b, &[], &opts);
            assert_eq!(
                bits(&got),
                bits(&want),
                "{} gts({g},{t},{n},{s}) forward workers={workers}",
                variant.name()
            );
            let (da_p, db_p) = pairwise_vjp_with(&spec, &a, &b, &dout, &[], &opts);
            assert_eq!(
                bits(&da_p),
                bits(&da_s),
                "{} gts({g},{t},{n},{s}) da workers={workers}",
                variant.name()
            );
            assert_eq!(
                bits(&db_p),
                bits(&db_s),
                "{} gts({g},{t},{n},{s}) db workers={workers}",
                variant.name()
            );
        }
    }
}

/// Training engine: all four kinds × {StoreAll, Sqrt}, scalar vs parallel
/// plans — outputs and every gradient bit-identical.
fn training_parity(variant: Variant) {
    let expr = "bsx,tsx,tu,uv->bvx|x";
    let dims = vec![vec![2, 3, 9], vec![4, 3, 3], vec![4, 5], vec![5, 3]];
    for kind in KINDS {
        let opts_for = |backend| PlanOptions {
            training: true,
            conv_kinds: Some(vec![kind]),
            backend,
            ..Default::default()
        };
        let scalar = Arc::new(compile_expr(expr, &dims, &opts_for(Backend::Scalar)).unwrap());
        let parallel = Arc::new(
            compile_expr(expr, &dims, &opts_for(Backend::Parallel { threads: 2 })).unwrap(),
        );
        let mut rng = Rng::new(317);
        let ins: Vec<Tensor> = dims.iter().map(|d| Tensor::rand(d, -1.0, 1.0, &mut rng)).collect();
        let refs: Vec<&Tensor> = ins.iter().collect();
        let dout = Tensor::rand(scalar.out_shape(), -1.0, 1.0, &mut rng);
        let ad_s = PathAutodiff::from_compiled(Arc::clone(&scalar));
        let ad_p = PathAutodiff::from_compiled(Arc::clone(&parallel));
        let mut ws = TrainWorkspace::new();
        let meter = MemoryMeter::new();
        for policy in [CkptPolicy::StoreAll, CkptPolicy::Sqrt] {
            let d = dout.clone();
            let (y_s, g_s) = ad_s
                .forward_backward(&refs, |_| d.clone(), policy, &mut ws, &meter)
                .unwrap();
            let d = dout.clone();
            let (y_p, g_p) = ad_p
                .forward_backward(&refs, |_| d.clone(), policy, &mut ws, &meter)
                .unwrap();
            assert_eq!(
                bits(&y_p),
                bits(&y_s),
                "{} {kind:?} {policy:?}: training output diverged",
                variant.name()
            );
            for (i, (gp, gs)) in g_p.iter().zip(g_s.iter()).enumerate() {
                assert_eq!(
                    bits(gp),
                    bits(gs),
                    "{} {kind:?} {policy:?}: grad {i} diverged",
                    variant.name()
                );
            }
        }
    }
}

/// Verifier rejection: a stale accumulation-order version and a
/// cross-variant replay must both fail `CompiledPlan::verify`.
fn verify_rejects_stale_artifacts() {
    let opts = PlanOptions::default();
    let dims = vec![vec![2, 24, 16], vec![2, 24, 16]];

    dispatch::force_variant(Some(Variant::Portable));
    let mut plan = compile_expr("gts,gns->gtn", &dims, &opts).unwrap();
    plan.verify().unwrap();
    plan.poison_kernel_order_version_for_tests(0, ACCUM_ORDER_VERSION - 1);
    match plan.verify() {
        Err(VerifyError::KernelOrderVersion { step, found, expected }) => {
            assert_eq!(step, 0);
            assert_eq!(found, ACCUM_ORDER_VERSION - 1);
            assert_eq!(expected, ACCUM_ORDER_VERSION);
        }
        other => panic!("expected KernelOrderVersion rejection, got {other:?}"),
    }

    // A plan pinned to portable replayed under a different process
    // selection must be rejected (only exercisable on hosts with a SIMD
    // variant; portable-only hosts re-select portable and stay valid).
    let plan = compile_expr("gts,gns->gtn", &dims, &opts).unwrap();
    dispatch::force_variant(None);
    if dispatch::selected().variant != Variant::Portable {
        match plan.verify() {
            Err(VerifyError::KernelVariantMismatch { step, found, selected }) => {
                assert_eq!(step, 0);
                assert_eq!(found, "portable");
                assert_eq!(selected, dispatch::selected().variant.name());
            }
            other => panic!("expected KernelVariantMismatch rejection, got {other:?}"),
        }
    } else {
        plan.verify().unwrap();
    }
}

#[test]
fn per_variant_bit_identity_and_verifier_pinning() {
    // `available()` lists the host's preferred variant first and always
    // ends with Portable, so the loop covers every runnable variant plus
    // the forced-portable (v1-order) configuration.
    for variant in dispatch::available() {
        dispatch::force_variant(Some(variant));
        assert_eq!(dispatch::selected().variant, variant, "force must stick");
        conv_parity(variant);
        conv_pack_parity(variant);
        conv_pack_training_parity(variant);
        contraction_parity(variant);
        training_parity(variant);
    }
    verify_rejects_stale_artifacts();
    dispatch::force_variant(None);
}
