//! Per-variant parity suite for the runtime-dispatched microkernels.
//!
//! The v2 accumulation contract is stated *per variant*: for every kernel
//! variant the host can run (plus the always-present portable fallback),
//! scalar and parallel backends must produce bit-identical outputs and
//! gradients — across all four convolution varieties, the tiny-K /
//! packed-GEMM / unblocked contraction routings, and the training engine
//! under {StoreAll, Sqrt} checkpoint policies. The suite also pins the
//! verifier's rejection of stale compiled artifacts (wrong
//! accumulation-order version, wrong pinned variant).
//!
//! Forcing a variant is process-global, so everything runs inside ONE
//! `#[test]` (this integration binary contains nothing else) and the
//! force is cleared at the end.

use conv_einsum::autodiff::{CkptPolicy, MemoryMeter, PathAutodiff};
use conv_einsum::einsum::{parse, ConvKind, SizedSpec};
use conv_einsum::exec::{pairwise_vjp_with, pairwise_with};
use conv_einsum::kernels::dispatch::{self, Variant};
use conv_einsum::kernels::{ACCUM_ORDER_VERSION, LANES};
use conv_einsum::util::rng::Rng;
use conv_einsum::{
    compile_expr, Backend, ExecOptions, PlanOptions, Tensor, TrainWorkspace, VerifyError,
};
use std::sync::Arc;

const KINDS: [ConvKind; 4] = [
    ConvKind::Same,
    ConvKind::Valid,
    ConvKind::Full,
    ConvKind::Circular,
];

fn bits(t: &Tensor) -> Vec<u32> {
    t.data().iter().map(|x| x.to_bits()).collect()
}

fn conv_spec(kind: ConvKind) -> SizedSpec {
    SizedSpec::with_kinds(
        parse("bsx,tsx->btx|x").unwrap(),
        vec![vec![2, 3, 11], vec![4, 3, 3]],
        vec![kind],
    )
    .unwrap()
}

fn contraction_spec(g: usize, t: usize, n: usize, s: usize) -> SizedSpec {
    SizedSpec::new(
        parse("gts,gns->gtn").unwrap(),
        vec![vec![g, t, s], vec![g, n, s]],
    )
    .unwrap()
}

/// Plain unfused row-major oracle for `out[g,t,n] = Σ_s a·b` — the exact
/// order the tiny-K short-circuit promises on every variant (and the v1
/// `dot8` order for `s < LANES`, whose lane blocks are empty there).
fn tiny_k_oracle(a: &Tensor, b: &Tensor, g: usize, t: usize, n: usize, s: usize) -> Vec<u32> {
    let av = a.data();
    let bv = b.data();
    let mut out = vec![0.0f32; g * t * n];
    for gi in 0..g {
        for ti in 0..t {
            for ni in 0..n {
                let mut acc = 0.0f32;
                for si in 0..s {
                    acc += av[(gi * t + ti) * s + si] * bv[(gi * n + ni) * s + si];
                }
                out[(gi * t + ti) * n + ni] = acc;
            }
        }
    }
    out.iter().map(|x| x.to_bits()).collect()
}

/// Convolution forward + VJP: scalar vs pool, all four kinds.
fn conv_parity(variant: Variant) {
    for kind in KINDS {
        let s = conv_spec(kind);
        let mut rng = Rng::new(311);
        let a = Tensor::rand(&s.dims[0], -1.0, 1.0, &mut rng);
        let b = Tensor::rand(&s.dims[1], -1.0, 1.0, &mut rng);
        let want = pairwise_with(&s, &a, &b, &[], &ExecOptions::scalar());
        let dout = Tensor::rand(want.shape(), -1.0, 1.0, &mut rng);
        let (da_s, db_s) = pairwise_vjp_with(&s, &a, &b, &dout, &[], &ExecOptions::scalar());
        for workers in [1usize, 2, 4] {
            let opts = ExecOptions::parallel(workers);
            let got = pairwise_with(&s, &a, &b, &[], &opts);
            assert_eq!(
                bits(&got),
                bits(&want),
                "{} {kind:?} forward workers={workers}",
                variant.name()
            );
            let (da_p, db_p) = pairwise_vjp_with(&s, &a, &b, &dout, &[], &opts);
            assert_eq!(
                bits(&da_p),
                bits(&da_s),
                "{} {kind:?} da workers={workers}",
                variant.name()
            );
            assert_eq!(
                bits(&db_p),
                bits(&db_s),
                "{} {kind:?} db workers={workers}",
                variant.name()
            );
        }
    }
}

/// Pure contractions across all three routings (tiny-K short-circuit,
/// packed cache-blocked GEMM, unblocked per-row fallback): scalar vs pool
/// bit-identical, and the tiny-K path equal to the unfused oracle on
/// every variant.
fn contraction_parity(variant: Variant) {
    // (g, t, n, s): tiny-K (s < LANES); GEMM-sized with ragged m/n/k
    // (engages every packed orientation on AVX2 and NEON); small fallback
    // (deep enough to vectorize, too narrow/small to pack).
    let shapes = [(2usize, 5usize, 6usize, 5usize), (4, 48, 40, 33), (2, 8, 5, 16)];
    for (g, t, n, s) in shapes {
        let spec = contraction_spec(g, t, n, s);
        let mut rng = Rng::new(313);
        let a = Tensor::rand(&[g, t, s], -1.0, 1.0, &mut rng);
        let b = Tensor::rand(&[g, n, s], -1.0, 1.0, &mut rng);
        let want = pairwise_with(&spec, &a, &b, &[], &ExecOptions::scalar());
        if s < LANES {
            assert_eq!(
                bits(&want),
                tiny_k_oracle(&a, &b, g, t, n, s),
                "{} tiny-K path must be the plain unfused loop",
                variant.name()
            );
        }
        let dout = Tensor::rand(want.shape(), -1.0, 1.0, &mut rng);
        let (da_s, db_s) = pairwise_vjp_with(&spec, &a, &b, &dout, &[], &ExecOptions::scalar());
        for workers in [1usize, 2, 4] {
            let opts = ExecOptions::parallel(workers);
            let got = pairwise_with(&spec, &a, &b, &[], &opts);
            assert_eq!(
                bits(&got),
                bits(&want),
                "{} gts({g},{t},{n},{s}) forward workers={workers}",
                variant.name()
            );
            let (da_p, db_p) = pairwise_vjp_with(&spec, &a, &b, &dout, &[], &opts);
            assert_eq!(
                bits(&da_p),
                bits(&da_s),
                "{} gts({g},{t},{n},{s}) da workers={workers}",
                variant.name()
            );
            assert_eq!(
                bits(&db_p),
                bits(&db_s),
                "{} gts({g},{t},{n},{s}) db workers={workers}",
                variant.name()
            );
        }
    }
}

/// Training engine: all four kinds × {StoreAll, Sqrt}, scalar vs parallel
/// plans — outputs and every gradient bit-identical.
fn training_parity(variant: Variant) {
    let expr = "bsx,tsx,tu,uv->bvx|x";
    let dims = vec![vec![2, 3, 9], vec![4, 3, 3], vec![4, 5], vec![5, 3]];
    for kind in KINDS {
        let opts_for = |backend| PlanOptions {
            training: true,
            conv_kinds: Some(vec![kind]),
            backend,
            ..Default::default()
        };
        let scalar = Arc::new(compile_expr(expr, &dims, &opts_for(Backend::Scalar)).unwrap());
        let parallel = Arc::new(
            compile_expr(expr, &dims, &opts_for(Backend::Parallel { threads: 2 })).unwrap(),
        );
        let mut rng = Rng::new(317);
        let ins: Vec<Tensor> = dims.iter().map(|d| Tensor::rand(d, -1.0, 1.0, &mut rng)).collect();
        let refs: Vec<&Tensor> = ins.iter().collect();
        let dout = Tensor::rand(scalar.out_shape(), -1.0, 1.0, &mut rng);
        let ad_s = PathAutodiff::from_compiled(Arc::clone(&scalar));
        let ad_p = PathAutodiff::from_compiled(Arc::clone(&parallel));
        let mut ws = TrainWorkspace::new();
        let meter = MemoryMeter::new();
        for policy in [CkptPolicy::StoreAll, CkptPolicy::Sqrt] {
            let d = dout.clone();
            let (y_s, g_s) = ad_s
                .forward_backward(&refs, |_| d.clone(), policy, &mut ws, &meter)
                .unwrap();
            let d = dout.clone();
            let (y_p, g_p) = ad_p
                .forward_backward(&refs, |_| d.clone(), policy, &mut ws, &meter)
                .unwrap();
            assert_eq!(
                bits(&y_p),
                bits(&y_s),
                "{} {kind:?} {policy:?}: training output diverged",
                variant.name()
            );
            for (i, (gp, gs)) in g_p.iter().zip(g_s.iter()).enumerate() {
                assert_eq!(
                    bits(gp),
                    bits(gs),
                    "{} {kind:?} {policy:?}: grad {i} diverged",
                    variant.name()
                );
            }
        }
    }
}

/// Verifier rejection: a stale accumulation-order version and a
/// cross-variant replay must both fail `CompiledPlan::verify`.
fn verify_rejects_stale_artifacts() {
    let opts = PlanOptions::default();
    let dims = vec![vec![2, 24, 16], vec![2, 24, 16]];

    dispatch::force_variant(Some(Variant::Portable));
    let mut plan = compile_expr("gts,gns->gtn", &dims, &opts).unwrap();
    plan.verify().unwrap();
    plan.poison_kernel_order_version_for_tests(0, ACCUM_ORDER_VERSION - 1);
    match plan.verify() {
        Err(VerifyError::KernelOrderVersion { step, found, expected }) => {
            assert_eq!(step, 0);
            assert_eq!(found, ACCUM_ORDER_VERSION - 1);
            assert_eq!(expected, ACCUM_ORDER_VERSION);
        }
        other => panic!("expected KernelOrderVersion rejection, got {other:?}"),
    }

    // A plan pinned to portable replayed under a different process
    // selection must be rejected (only exercisable on hosts with a SIMD
    // variant; portable-only hosts re-select portable and stay valid).
    let plan = compile_expr("gts,gns->gtn", &dims, &opts).unwrap();
    dispatch::force_variant(None);
    if dispatch::selected().variant != Variant::Portable {
        match plan.verify() {
            Err(VerifyError::KernelVariantMismatch { step, found, selected }) => {
                assert_eq!(step, 0);
                assert_eq!(found, "portable");
                assert_eq!(selected, dispatch::selected().variant.name());
            }
            other => panic!("expected KernelVariantMismatch rejection, got {other:?}"),
        }
    } else {
        plan.verify().unwrap();
    }
}

#[test]
fn per_variant_bit_identity_and_verifier_pinning() {
    // `available()` lists the host's preferred variant first and always
    // ends with Portable, so the loop covers every runnable variant plus
    // the forced-portable (v1-order) configuration.
    for variant in dispatch::available() {
        dispatch::force_variant(Some(variant));
        assert_eq!(dispatch::selected().variant, variant, "force must stick");
        conv_parity(variant);
        contraction_parity(variant);
        training_parity(variant);
    }
    verify_rejects_stale_artifacts();
    dispatch::force_variant(None);
}
