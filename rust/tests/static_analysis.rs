//! The static-analysis gate, runnable as an ordinary test target (CI runs
//! the same three checks as a dedicated job):
//!
//! 1. the hot-path allocation / unsafe-hygiene lint over `src/exec`,
//!    `src/kernels`, `src/parallel`, `src/tensor` (`tools/hotpath_lint.rs`);
//! 2. the exhaustive pool-protocol model checker
//!    ([`conv_einsum::verify::pool_model`]);
//! 3. the static plan verifier ([`CompiledPlan::verify`]) over a corpus of
//!    compiled plans spanning strategies, conv varieties and training
//!    modes — plus the overflow-hardening regression for degenerate dims.

use conv_einsum::einsum::ConvKind;
use conv_einsum::verify::pool_model;
use conv_einsum::{compile_expr, PlanOptions, Strategy};
use std::process::Command;

#[test]
fn hotpath_lint_is_clean() {
    let out = Command::new(env!("CARGO_BIN_EXE_hotpath-lint"))
        .arg(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("hotpath-lint binary must run");
    assert!(
        out.status.success(),
        "hotpath-lint found violations:\n{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr),
    );
}

#[test]
fn pool_protocol_model_is_exhaustively_safe() {
    let stats = pool_model::check_standard_configs()
        .unwrap_or_else(|v| panic!("pool protocol violation: {v:?}"));
    assert!(
        stats.states > 10_000,
        "state space suspiciously small: {}",
        stats.states
    );
}

#[test]
fn plan_corpus_verifies() {
    // (expression, dims, multiway) corpus spanning the operation classes
    // the engine compiles: matmul chains, batch modes, grouped conv atoms,
    // multi-way conv paths, and transposed outputs. `multiway` marks
    // expressions whose conv modes occur in more than two inputs — those
    // are circular-only (paper Appendix B), so variety overrides are
    // skipped for them.
    let corpus: &[(&str, &[&[usize]], bool)] = &[
        ("ij,jk->ik", &[&[7, 3], &[3, 5]], false),
        ("ij,jk->ki", &[&[4, 6], &[6, 2]], false),
        ("ij,jk,kl,lm->im", &[&[2, 5], &[5, 4], &[4, 4], &[4, 3]], false),
        ("bi,bi->b", &[&[5, 3], &[5, 3]], false),
        ("bsxy,tsxy->btxy|xy", &[&[2, 3, 6, 5], &[4, 3, 3, 3]], false),
        ("isx,stx,tjx->ijx|x", &[&[2, 3, 5], &[3, 4, 5], &[4, 2, 5]], true),
        ("ix,jx->ijx|x", &[&[3, 8], &[2, 3]], false),
    ];
    let kinds = [
        None,
        Some(ConvKind::Circular),
        Some(ConvKind::Same),
        Some(ConvKind::Valid),
        Some(ConvKind::Full),
    ];
    let mut verified = 0usize;
    for &(expr, dims, multiway) in corpus {
        let dims: Vec<Vec<usize>> = dims.iter().map(|d| d.to_vec()).collect();
        // one ConvKind per conv mode (parallel to the pipe list)
        let n_conv_modes = expr.split('|').nth(1).map_or(0, str::len);
        for kind in kinds {
            // conv-kind overrides only make sense for conv expressions, and
            // multi-way conv modes admit only circular padding
            if kind.is_some() && n_conv_modes == 0 {
                continue;
            }
            if multiway && !matches!(kind, None | Some(ConvKind::Circular)) {
                continue;
            }
            for strategy in [Strategy::Optimal, Strategy::Greedy, Strategy::LeftToRight] {
                for training in [false, true] {
                    let opts = PlanOptions {
                        strategy,
                        training,
                        conv_kinds: kind.map(|k| vec![k; n_conv_modes]),
                        ..PlanOptions::default()
                    };
                    let cp = match compile_expr(expr, &dims, &opts) {
                        Ok(cp) => cp,
                        Err(e) => panic!("{expr} ({kind:?}, {strategy:?}) must compile: {e}"),
                    };
                    cp.verify().unwrap_or_else(|e| {
                        panic!(
                            "{expr} ({kind:?}, {strategy:?}, training={training}) \
                             failed verification: {e}"
                        )
                    });
                    verified += 1;
                }
            }
        }
    }
    assert!(verified >= 60, "corpus too small: {verified} plans");
}

#[test]
fn degenerate_huge_dims_are_rejected_not_wrapped() {
    // Element counts that overflow usize must surface as structured compile
    // errors (checked shape arithmetic), never wrap into a bogus layout.
    let huge = usize::MAX / 2;
    let err = compile_expr(
        "ij,jk->ik",
        &[vec![huge, huge], vec![huge, huge]],
        &PlanOptions::default(),
    )
    .expect_err("overflowing dims must not compile");
    let msg = format!("{err:#}").to_ascii_lowercase();
    assert!(
        msg.contains("overflow"),
        "error should name the overflow: {msg}"
    );

    // The tensor-level checked helpers agree.
    assert!(conv_einsum::tensor::checked_elems(&[huge, huge]).is_err());
    assert!(conv_einsum::tensor::checked_elems(&[4, 4]).is_ok());
}
