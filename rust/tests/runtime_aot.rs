//! End-to-end three-layer integration: loads the AOT artifacts produced by
//! `make artifacts` (L2 JAX calling L1 Pallas kernels, lowered to HLO
//! text) and checks their numerics against the native L3 engine.
//! Skips when artifacts/ has not been built.
use conv_einsum::exec::{conv_einsum, conv_einsum_ltr};
use conv_einsum::runtime::ArtifactRegistry;
use conv_einsum::util::rng::Rng;
use conv_einsum::Tensor;

fn registry() -> Option<ArtifactRegistry> {
    ArtifactRegistry::open("artifacts").ok()
}

#[test]
fn cp_layer_artifact_matches_native_engine() {
    let Some(mut reg) = registry() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut rng = Rng::new(21);
    for name in ["cp_layer_fwd_optimal", "cp_layer_fwd_ltr"] {
        let meta = reg.meta(name).expect("artifact in manifest").clone();
        let inputs: Vec<Tensor> = meta
            .input_shapes
            .iter()
            .map(|s| Tensor::rand(s, -0.5, 0.5, &mut rng))
            .collect();
        let refs: Vec<&Tensor> = inputs.iter().collect();
        let out = reg.execute(name, &refs).unwrap();
        assert_eq!(out.len(), 1);
        // Native engine on the same expression + tensors.
        let expr = "bshw,rt,rs,rh,rw->bthw|hw";
        let native = if name.ends_with("optimal") {
            conv_einsum(expr, &refs).unwrap()
        } else {
            conv_einsum_ltr(expr, &refs).unwrap()
        };
        assert_eq!(out[0].shape(), native.shape());
        let rel = out[0].rel_l2(&native);
        assert!(rel < 1e-3, "{name}: PJRT vs native rel-l2 {rel}");
    }
}

#[test]
fn rcp_artifact_executes() {
    let Some(mut reg) = registry() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let meta = reg.meta("rcp_layer_fwd_optimal").unwrap().clone();
    let mut rng = Rng::new(22);
    let inputs: Vec<Tensor> = meta
        .input_shapes
        .iter()
        .map(|s| Tensor::rand(s, -0.5, 0.5, &mut rng))
        .collect();
    let refs: Vec<&Tensor> = inputs.iter().collect();
    let out = reg.execute("rcp_layer_fwd_optimal", &refs).unwrap();
    assert_eq!(out[0].shape(), &meta.output_shape[..]);
    // native comparison
    let expr = "b(s1)(s2)hw,r(t1)(s1),r(t2)(s2),rhw->b(t1)(t2)hw|hw";
    let native = conv_einsum(expr, &refs).unwrap();
    assert!(out[0].rel_l2(&native) < 1e-3);
}

#[test]
fn train_step_artifact_reduces_loss() {
    let Some(mut reg) = registry() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let meta = reg.meta("tnn_train_step").unwrap().clone();
    let mut rng = Rng::new(23);
    // inputs: x, onehot labels, factors..., w, b
    let mut tensors: Vec<Tensor> = meta
        .input_shapes
        .iter()
        .map(|s| Tensor::rand(s, -0.3, 0.3, &mut rng))
        .collect();
    // proper one-hot labels
    let n_classes = meta.input_shapes[1][1];
    let bsz = meta.input_shapes[1][0];
    let mut onehot = Tensor::zeros(&[bsz, n_classes]);
    for i in 0..bsz {
        onehot.set(&[i, rng.below(n_classes)], 1.0);
    }
    tensors[1] = onehot;
    let mut losses = Vec::new();
    for _ in 0..6 {
        let refs: Vec<&Tensor> = tensors.iter().collect();
        let out = reg.execute("tnn_train_step", &refs).unwrap();
        // out = (loss, new_params...)
        losses.push(out[0].data()[0]);
        for (k, p) in out[1..].iter().enumerate() {
            tensors[2 + k] = p.clone();
        }
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss did not decrease across AOT train steps: {losses:?}"
    );
}
