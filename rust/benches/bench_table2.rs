//! Paper Table 2: FLOPs per CP convolutional layer in ResNet-34 (analytic,
//! CR=100%, batch 128) — exact-mechanism reproduction.
use conv_einsum::experiments::table2;

fn main() {
    let table = table2::run(128);
    println!("{}", table.render());
    table.save("table2").expect("save experiments/table2.json");
    // Headline checks mirroring the paper's shape:
    let rows = table2::rows(128);
    for r in &rows {
        assert!(r.ltr > r.opt, "{} must win", r.stage);
    }
    println!(
        "speedups grow with depth: conv2_x {:.1}x -> conv5_x {:.1}x (paper: 4.5x -> 90x)",
        rows[1].ltr / rows[1].opt,
        rows[4].ltr / rows[4].opt
    );
}
