//! Paper Table 1: run-time per epoch, RCP(M=3) ResNet-34-proxy on the
//! ImageNet-like synthetic task, conv_einsum vs naive w/ ckpt across CRs.
//! Scaled to laptop size; the paper's *shape* (conv_einsum faster at every
//! CR, both growing with CR) is the reproduction target.
use conv_einsum::experiments::runtime_sweep::{render, sweep, Workload};
use conv_einsum::tnn::Decomp;

fn main() {
    let full = std::env::var("FULL").is_ok();
    let crs = if full {
        vec![0.05, 0.1, 0.2, 0.5, 1.0]
    } else {
        vec![0.05, 0.2, 1.0]
    };
    let cells = sweep(
        &Workload::ImageClassification { size: if full { 24 } else { 12 }, channels: 3 },
        Decomp::Cp,
        3,
        &crs,
        8,
        if full { 64 } else { 16 },
        2,
        16,
    );
    let table = render(
        "Table 1 (scaled): s/epoch, RCP(M=3) ResNet-34-proxy, ImageNet-like",
        &cells,
    );
    println!("{}", table.render());
    table.save("table1").unwrap();
    // shape check: conv_einsum no slower than naive w/ ckpt at each CR
    for cr in &crs {
        let ce = cells.iter().find(|c| c.cr == *cr && c.mode == "conv_einsum").unwrap();
        let nc = cells.iter().find(|c| c.cr == *cr && c.mode == "naive w/ ckpt").unwrap();
        println!(
            "CR {:>4.0}%: conv_einsum {:.2}s vs naive-ckpt {:.2}s ({:.2}x)",
            cr * 100.0, ce.train_secs, nc.train_secs, nc.train_secs / ce.train_secs
        );
    }
}
