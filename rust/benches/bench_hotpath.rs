//! §Perf hot-path microbenchmarks (EXPERIMENTS.md §Perf): per-variant
//! microkernel throughput (GEMM-shaped contraction + conv atom GFLOP/s at
//! small/medium/large geometries for every runtime-dispatchable kernel
//! variant, dumped to `BENCH_kernels.json` with the dispatched-vs-portable
//! large-GEMM speedup, a tiny-K non-regression assertion, a packed-vs-
//! unpacked conv-atom weight-panel sweep across all four ConvKinds with a
//! tiny-geometry short-circuit assertion, and the self-learning per-
//! geometry GEMM-blocking sweep), the
//! measured-vs-FLOPs planner sweep (skewed GEMM geometries on the parallel
//! backend, calibrated through the plan tournament, dumped to
//! `BENCH_planner.json`; all candidates are asserted bit-identical and the
//! measured planner must pick the tournament winner), executor
//! throughput on the two atoms (contraction GFLOP/s, conv atom GFLOP/s),
//! scalar-vs-parallel backend scaling across 1/2/4/8-thread pools, CP/TT
//! layer steps under both backends, compiled-vs-uncompiled training steps
//! (with heap-allocation counts and workspace bytes, dumped to
//! `BENCH_compiled.json`), workspace-tape vs heap-tape training steps with
//! per-step allocation counts (dumped to `BENCH_train.json`; zero
//! steady-state allocations are *asserted* for StoreAll and Sqrt on both
//! backends), persistent-pool dispatch latency, small-atom and
//! fine-grained-region throughput vs a scoped-spawn baseline plus
//! allocations-per-replay on both backends (dumped to `BENCH_pool.json`),
//! and coordinator request throughput — infer / train / mixed traffic at
//! 1/2/4 workers, adaptive batching vs the unbatched (`max_batch = 1`)
//! baseline (dumped to `BENCH_coordinator.json`).
//!
//! With `CONV_EINSUM_BENCH_ASSERT_ONLY=1` only the zero-allocation
//! assertions run (fast; used by the CI release-test job) — inference,
//! single training steps, coalesced training batches, and measured-plan
//! replays. With `CONV_EINSUM_BENCH_KERNELS_ONLY=1` only the per-variant
//! kernel section runs and writes `BENCH_kernels.json` (used by the CI
//! forced-variant matrix job to publish the packed-vs-unpacked sweep).
use conv_einsum::autodiff::{CkptPolicy, MemoryMeter, PathAutodiff, TrainSegment};
use conv_einsum::coordinator::{EvalService, ServiceConfig};
use conv_einsum::cost::tuning;
use conv_einsum::einsum::{parse, ConvKind, SizedSpec};
use conv_einsum::exec::{force_conv_pack, pairwise, pairwise_with};
use conv_einsum::kernels::{axpy8, dispatch};
use conv_einsum::parallel::{default_threads, Pool};
use conv_einsum::planner::{candidate_plans, contract_path, PlanOptions, Strategy};
use conv_einsum::tnn::{build_layer, Decomp};
use conv_einsum::tune::{calibrate_expr, calibrate_gemm_blocking, CalibrationSpec};
use conv_einsum::util::json::Json;
use conv_einsum::util::rng::Rng;
use conv_einsum::util::timing::bench;
use conv_einsum::{
    compile_expr, conv_einsum_with, Backend, CompiledPlan, ExecOptions, Tensor, TrainWorkspace,
    Workspace,
};
use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Heap-tape reference (shared with `tests/train_parity.rs`): the
/// pre-workspace training algorithm, the baseline the workspace tape is
/// measured — and bit-parity-asserted — against.
#[path = "../testsupport/heap_tape.rs"]
mod heap_tape;
use heap_tape::heap_forward_backward;

/// Counting allocator: makes the compiled engine's zero-alloc steady state
/// measurable rather than asserted.
struct CountingAlloc;

static ALLOC_COUNT: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL_ALLOC: CountingAlloc = CountingAlloc;

fn allocs() -> usize {
    ALLOC_COUNT.load(Ordering::Relaxed)
}

fn gflops(mults: f64, secs: f64) -> f64 {
    2.0 * mults / secs / 1e9
}

/// The pre-persistent-pool dispatcher, kept as the benchmark baseline:
/// spawn scoped threads per region with round-robin chunk assignment —
/// this is what every parallel region used to pay.
fn scoped_run_chunks<F: Fn(usize, &mut [f32]) + Sync>(
    threads: usize,
    out: &mut [f32],
    chunk: usize,
    f: F,
) {
    let n_chunks = (out.len() + chunk - 1) / chunk;
    let nt = threads.min(n_chunks).max(1);
    if nt <= 1 {
        for (i, c) in out.chunks_mut(chunk).enumerate() {
            f(i, c);
        }
        return;
    }
    let mut buckets: Vec<Vec<(usize, &mut [f32])>> = (0..nt).map(|_| Vec::new()).collect();
    for (i, c) in out.chunks_mut(chunk).enumerate() {
        buckets[i % nt].push((i, c));
    }
    let fref = &f;
    std::thread::scope(|s| {
        let mut buckets = buckets.into_iter();
        let first = buckets.next().expect("nt >= 2 buckets");
        for bucket in buckets {
            s.spawn(move || {
                for (i, c) in bucket {
                    fref(i, c);
                }
            });
        }
        for (i, c) in first {
            fref(i, c);
        }
    });
}

/// Inference zero-allocation assertions: 50 compiled replays on each
/// backend must not allocate after warm-up.
fn inference_zero_alloc_assertions() {
    let mut rng = Rng::new(3);
    let layer = build_layer(Decomp::Cp, 1, 16, 16, 3, 3, 0.5).unwrap();
    let factors = layer.init_factors(&mut rng);
    let xin = Tensor::rand(&layer.input_shape(8, 32, 32), -1.0, 1.0, &mut rng);
    let mut inputs: Vec<&Tensor> = vec![&xin];
    inputs.extend(factors.iter());
    let dims: Vec<Vec<usize>> = inputs.iter().map(|t| t.shape().to_vec()).collect();
    for backend in [Backend::Scalar, Backend::Parallel { threads: 2 }] {
        let opts = PlanOptions {
            backend,
            ..Default::default()
        };
        let compiled = compile_expr(&layer.expr, &dims, &opts).unwrap();
        let mut ws = Workspace::new();
        let mut out = Tensor::zeros(compiled.out_shape());
        for _ in 0..3 {
            compiled.run_into(&inputs, &mut ws, &mut out).unwrap();
        }
        let a0 = allocs();
        for _ in 0..50 {
            compiled.run_into(&inputs, &mut ws, &mut out).unwrap();
        }
        let steady = allocs() - a0;
        assert_eq!(
            steady, 0,
            "inference steady state must not allocate ({backend:?}: {steady} across 50 replays)"
        );
        println!("inference zero-alloc OK: {backend:?}");
    }
}

/// Training zero-allocation assertions: a repeated forward-with-tape +
/// backward step (the `_into` entry points against a held workspace) must
/// not allocate after warm-up — StoreAll and Sqrt, scalar and parallel.
fn train_zero_alloc_assertions() {
    let mut rng = Rng::new(7);
    let layer = build_layer(Decomp::Cp, 1, 16, 16, 3, 3, 0.5).unwrap();
    let factors = layer.init_factors(&mut rng);
    let xin = Tensor::rand(&layer.input_shape(4, 16, 16), -1.0, 1.0, &mut rng);
    let mut inputs: Vec<&Tensor> = vec![&xin];
    inputs.extend(factors.iter());
    let dims: Vec<Vec<usize>> = inputs.iter().map(|t| t.shape().to_vec()).collect();
    for backend in [Backend::Scalar, Backend::Parallel { threads: 2 }] {
        let opts = PlanOptions {
            training: true,
            backend,
            ..Default::default()
        };
        let compiled = Arc::new(compile_expr(&layer.expr, &dims, &opts).unwrap());
        let ad = PathAutodiff::from_compiled(Arc::clone(&compiled));
        let dout = Tensor::full(compiled.out_shape(), 1.0);
        let meter = MemoryMeter::new();
        let mut ws = TrainWorkspace::new();
        let mut out = Tensor::zeros(compiled.out_shape());
        let mut grads: Vec<Tensor> = dims.iter().map(|d| Tensor::zeros(d)).collect();
        for policy in [CkptPolicy::StoreAll, CkptPolicy::Sqrt] {
            for _ in 0..3 {
                let token = ad
                    .forward_with_tape_into(&inputs, policy, &mut ws, &mut out, &meter)
                    .unwrap();
                ad.backward_into(&token, &dout, &mut ws, &mut grads, &meter)
                    .unwrap();
            }
            let a0 = allocs();
            for _ in 0..20 {
                let token = ad
                    .forward_with_tape_into(&inputs, policy, &mut ws, &mut out, &meter)
                    .unwrap();
                ad.backward_into(&token, &dout, &mut ws, &mut grads, &meter)
                    .unwrap();
            }
            let steady = allocs() - a0;
            assert_eq!(
                steady, 0,
                "train steady state must not allocate \
                 ({backend:?} {policy:?}: {steady} allocs across 20 steps)"
            );
            println!("train zero-alloc OK: {backend:?} {policy:?}");
        }
    }
}

/// Coalesced-training zero-allocation assertions: a repeated **batched**
/// train step (several segments replayed through one layout against one
/// workspace — the coordinator's unified-scheduler hot path) must not
/// allocate after warm-up, StoreAll and Sqrt, scalar and parallel.
fn train_batch_zero_alloc_assertions() {
    let mut rng = Rng::new(11);
    let layer = build_layer(Decomp::Cp, 1, 16, 16, 3, 3, 0.5).unwrap();
    let factors = layer.init_factors(&mut rng);
    let n_seg = 4usize;
    for backend in [Backend::Scalar, Backend::Parallel { threads: 2 }] {
        let opts = PlanOptions {
            training: true,
            backend,
            ..Default::default()
        };
        let xs: Vec<Tensor> = (0..n_seg)
            .map(|_| Tensor::rand(&layer.input_shape(2, 12, 12), -1.0, 1.0, &mut rng))
            .collect();
        let dims: Vec<Vec<usize>> = std::iter::once(xs[0].shape().to_vec())
            .chain(factors.iter().map(|f| f.shape().to_vec()))
            .collect();
        let compiled = Arc::new(compile_expr(&layer.expr, &dims, &opts).unwrap());
        let ad = PathAutodiff::from_compiled(Arc::clone(&compiled));
        let douts: Vec<Tensor> = (0..n_seg)
            .map(|_| Tensor::full(compiled.out_shape(), 1.0))
            .collect();
        let in_refs: Vec<Vec<&Tensor>> = xs
            .iter()
            .map(|x| {
                let mut v: Vec<&Tensor> = vec![x];
                v.extend(factors.iter());
                v
            })
            .collect();
        let mut outs: Vec<Tensor> = (0..n_seg)
            .map(|_| Tensor::zeros(compiled.out_shape()))
            .collect();
        let mut grads: Vec<Vec<Tensor>> = (0..n_seg)
            .map(|_| dims.iter().map(|d| Tensor::zeros(d)).collect())
            .collect();
        let meter = MemoryMeter::new();
        let mut ws = TrainWorkspace::new();
        for policy in [CkptPolicy::StoreAll, CkptPolicy::Sqrt] {
            let mut segs: Vec<TrainSegment> = in_refs
                .iter()
                .zip(douts.iter())
                .zip(outs.iter_mut())
                .zip(grads.iter_mut())
                .map(|(((r, d), o), g)| TrainSegment {
                    inputs: r.as_slice(),
                    dout: d,
                    out: o,
                    grads: g.as_mut_slice(),
                })
                .collect();
            for _ in 0..3 {
                ad.train_step_batch_into(&mut segs, policy, &mut ws, &meter)
                    .unwrap();
            }
            let a0 = allocs();
            for _ in 0..20 {
                ad.train_step_batch_into(&mut segs, policy, &mut ws, &meter)
                    .unwrap();
            }
            let steady = allocs() - a0;
            assert_eq!(
                steady, 0,
                "batched train steady state must not allocate \
                 ({backend:?} {policy:?}: {steady} allocs across 20 batched steps)"
            );
            println!("batched-train zero-alloc OK: {backend:?} {policy:?} ({n_seg} segments)");
        }
    }
}

/// Measured-plan zero-allocation assertions: a plan ranked by
/// `Strategy::Measured` replays through the same compiled engine as an
/// analytic plan, so its steady state must be just as allocation-free.
/// A tiny in-process calibration pass seeds real measurements first, so
/// the compiled plan is genuinely measurement-ranked (and carries a
/// tuning-generation stamp), not an analytic-fallback plan in disguise.
fn measured_zero_alloc_assertions() {
    let mut rng = Rng::new(13);
    let layer = build_layer(Decomp::Cp, 1, 16, 16, 3, 3, 0.5).unwrap();
    let factors = layer.init_factors(&mut rng);
    let xin = Tensor::rand(&layer.input_shape(4, 16, 16), -1.0, 1.0, &mut rng);
    let mut inputs: Vec<&Tensor> = vec![&xin];
    inputs.extend(factors.iter());
    let dims: Vec<Vec<usize>> = inputs.iter().map(|t| t.shape().to_vec()).collect();
    let spec = CalibrationSpec {
        top_k: 2,
        warmup: 1,
        iters: 2,
        persist: false,
        seed: 5,
    };
    for backend in [Backend::Scalar, Backend::Parallel { threads: 2 }] {
        let calib_opts = PlanOptions {
            backend,
            ..Default::default()
        };
        calibrate_expr(&layer.expr, &dims, &calib_opts, &spec).unwrap();
        let opts = PlanOptions {
            strategy: Strategy::Measured { top_k: 2 },
            backend,
            ..Default::default()
        };
        let compiled = compile_expr(&layer.expr, &dims, &opts).unwrap();
        assert!(
            compiled.plan().tuning_generation.is_some(),
            "measured plan must carry a tuning-generation stamp"
        );
        let mut ws = Workspace::new();
        let mut out = Tensor::zeros(compiled.out_shape());
        for _ in 0..3 {
            compiled.run_into(&inputs, &mut ws, &mut out).unwrap();
        }
        let a0 = allocs();
        for _ in 0..50 {
            compiled.run_into(&inputs, &mut ws, &mut out).unwrap();
        }
        let steady = allocs() - a0;
        assert_eq!(
            steady, 0,
            "measured-plan steady state must not allocate \
             ({backend:?}: {steady} across 50 replays)"
        );
        println!("measured-plan zero-alloc OK: {backend:?}");
    }
    tuning::global().clear();
}

/// Per-variant microkernel throughput: the GEMM-shaped contraction and the
/// conv atom at small/medium/large geometries, once for every kernel
/// variant the host can run (portable always included), dumped to
/// `BENCH_kernels.json` together with the dispatched-vs-portable speedup
/// on the large GEMM — the number the dispatch layer is measured by.
///
/// The tiny-K (`s < LANES`) contraction is also timed per variant and
/// *asserted* not to regress under SIMD dispatch: every variant
/// short-circuits that shape to the same straight scalar loop, so its
/// throughput must stay within noise of the portable baseline.
fn kernel_variant_benches(rng: &mut Rng) {
    println!("== kernel variants: per-variant GEMM / conv-atom throughput ==");
    let dispatched = dispatch::selected().variant;
    println!("dispatched variant: {}", dispatched.name());
    let mut report = BTreeMap::new();
    report.insert("bench".to_string(), Json::str("kernel_variants"));
    report.insert("dispatched".to_string(), Json::str(dispatched.name()));

    // GEMM-shaped contraction "gts,gns->gtn": all three geometries are
    // large enough to engage a variant's packed path where it has one; the
    // tiny-K shape (s < LANES) exercises the short-circuit instead.
    let gemm_shapes = [
        ("small", 1usize, 32usize, 32usize, 32usize),
        ("medium", 2, 128, 128, 128),
        ("large", 4, 256, 256, 256),
    ];
    let tiny_shape = (2usize, 64usize, 64usize, 5usize);
    // Conv atom "bshw,tshw->bthw|hw" (standard conv layer).
    let conv_shapes = [
        ("small", 1usize, 4usize, 8usize, 12usize, 3usize),
        ("medium", 2, 8, 8, 24, 3),
        ("large", 4, 16, 16, 32, 3),
    ];
    let scalar_opts = ExecOptions::scalar();
    let mut gemm_large: BTreeMap<&'static str, f64> = BTreeMap::new();
    let mut tiny_gflops: BTreeMap<&'static str, f64> = BTreeMap::new();
    for variant in dispatch::available() {
        dispatch::force_variant(Some(variant));
        let name = variant.name();
        for (size, g, t, n, s) in gemm_shapes {
            let spec = SizedSpec::new(
                parse("gts,gns->gtn").unwrap(),
                vec![vec![g, t, s], vec![g, n, s]],
            )
            .unwrap();
            let a = Tensor::rand(&[g, t, s], -1.0, 1.0, rng);
            let b = Tensor::rand(&[g, n, s], -1.0, 1.0, rng);
            let smp = bench(&format!("gemm {size} {g}x{t}x{n}x{s} [{name}]"), 2, 10, || {
                let _ = pairwise_with(&spec, &a, &b, &[], &scalar_opts);
            });
            let gf = gflops((g * t * n * s) as f64, smp.median_secs());
            println!("{}\n  -> {gf:.2} GFLOP/s", smp.report());
            report.insert(format!("gemm_{size}_{name}_gflops"), Json::num(gf));
            if size == "large" {
                gemm_large.insert(name, gf);
            }
        }
        {
            let (g, t, n, s) = tiny_shape;
            let spec = SizedSpec::new(
                parse("gts,gns->gtn").unwrap(),
                vec![vec![g, t, s], vec![g, n, s]],
            )
            .unwrap();
            let a = Tensor::rand(&[g, t, s], -1.0, 1.0, rng);
            let b = Tensor::rand(&[g, n, s], -1.0, 1.0, rng);
            let smp = bench(&format!("gemm tiny-K {g}x{t}x{n}x{s} [{name}]"), 20, 100, || {
                let _ = pairwise_with(&spec, &a, &b, &[], &scalar_opts);
            });
            let gf = gflops((g * t * n * s) as f64, smp.median_secs());
            println!("{}\n  -> {gf:.2} GFLOP/s", smp.report());
            report.insert(format!("gemm_tiny_k_{name}_gflops"), Json::num(gf));
            tiny_gflops.insert(name, gf);
        }
        for (size, bb, ss, tt, hh, kk) in conv_shapes {
            let spec = SizedSpec::new(
                parse("bshw,tshw->bthw|hw").unwrap(),
                vec![vec![bb, ss, hh, hh], vec![tt, ss, kk, kk]],
            )
            .unwrap();
            let x = Tensor::rand(&[bb, ss, hh, hh], -1.0, 1.0, rng);
            let w = Tensor::rand(&[tt, ss, kk, kk], -1.0, 1.0, rng);
            let label = format!("conv {size} b{bb} s{ss} t{tt} {hh}^2 k{kk} [{name}]");
            let smp = bench(&label, 2, 10, || {
                let _ = pairwise_with(&spec, &x, &w, &[], &scalar_opts);
            });
            let mults = (bb * ss * tt * hh * hh * kk * kk) as f64;
            let gf = gflops(mults, smp.median_secs());
            println!("{}\n  -> {gf:.2} GFLOP/s", smp.report());
            report.insert(format!("conv_{size}_{name}_gflops"), Json::num(gf));
        }
    }
    dispatch::force_variant(None);

    let portable_large = gemm_large["portable"];
    let dispatched_large = gemm_large[dispatched.name()];
    let speedup = dispatched_large / portable_large;
    println!(
        "  -> large GEMM: dispatched ({}) {dispatched_large:.2} GFLOP/s, \
         portable {portable_large:.2} GFLOP/s, speedup {speedup:.2}x",
        dispatched.name()
    );
    report.insert("gemm_large_portable_gflops".to_string(), Json::num(portable_large));
    report.insert("gemm_large_dispatched_gflops".to_string(), Json::num(dispatched_large));
    report.insert("gemm_large_speedup_vs_portable".to_string(), Json::num(speedup));

    // Tiny-K non-regression pin: the short-circuit is the same unfused
    // scalar loop on every variant, so SIMD dispatch must not slow the
    // small-atom path down (0.5x floor absorbs timer noise).
    let tiny_portable = tiny_gflops["portable"];
    for (name, gf) in &tiny_gflops {
        assert!(
            *gf >= 0.5 * tiny_portable,
            "tiny-K path regressed under variant dispatch: \
             {name} {gf:.2} GFLOP/s vs portable {tiny_portable:.2} GFLOP/s"
        );
    }
    println!("  -> tiny-K short-circuit holds across variants (no dispatch regression)");

    // ---- packed conv-atom weight panels: packed vs unpacked per kind ------
    // One realistic 1-D conv layer geometry per convolution variety on the
    // dispatched variant: the run-structured loop with the weights gathered
    // into a zero-padded consumption-ordered panel vs the same loop reading
    // weights through the strided `boff` gather. Packing is a pure data-
    // layout change, so the speedup is the panel's cache story alone.
    println!("== packed conv-atom panels: packed vs unpacked per ConvKind ==");
    let pack_kinds = [
        (ConvKind::Same, "same"),
        (ConvKind::Valid, "valid"),
        (ConvKind::Full, "full"),
        (ConvKind::Circular, "circular"),
    ];
    for (kind, kname) in pack_kinds {
        let spec = SizedSpec::with_kinds(
            parse("bsx,tsx->btx|x").unwrap(),
            vec![vec![8, 16, 128], vec![32, 16, 5]],
            vec![kind],
        )
        .unwrap();
        let x = Tensor::rand(&[8, 16, 128], -1.0, 1.0, rng);
        let w = Tensor::rand(&[32, 16, 5], -1.0, 1.0, rng);
        let mults = (8usize * 16 * 32 * 128 * 5) as f64;
        force_conv_pack(Some(false));
        let unp = bench(&format!("conv-pack {kname} unpacked"), 3, 15, || {
            let _ = pairwise_with(&spec, &x, &w, &[], &scalar_opts);
        });
        force_conv_pack(Some(true));
        let pck = bench(&format!("conv-pack {kname} packed  "), 3, 15, || {
            let _ = pairwise_with(&spec, &x, &w, &[], &scalar_opts);
        });
        force_conv_pack(None);
        let speedup = unp.median_secs() / pck.median_secs();
        println!(
            "{}\n{}\n  -> {kname}: unpacked {:.2} GFLOP/s, packed {:.2} GFLOP/s, \
             speedup {speedup:.2}x",
            unp.report(),
            pck.report(),
            gflops(mults, unp.median_secs()),
            gflops(mults, pck.median_secs())
        );
        report.insert(
            format!("conv_pack_{kname}_unpacked_median_s"),
            Json::num(unp.median_secs()),
        );
        report.insert(
            format!("conv_pack_{kname}_packed_median_s"),
            Json::num(pck.median_secs()),
        );
        report.insert(format!("conv_pack_{kname}_speedup"), Json::num(speedup));
    }

    // Tiny-geometry non-regression pin: a conv atom below the
    // `CONV_PACK_MIN_FLOPS` floor short-circuits packing to the plain run
    // loop, so auto routing must stay within noise of the forced-unpacked
    // loop (0.5x floor absorbs timer noise).
    let tiny_spec = SizedSpec::with_kinds(
        parse("bsx,tsx->btx|x").unwrap(),
        vec![vec![2, 3, 11], vec![4, 3, 3]],
        vec![ConvKind::Same],
    )
    .unwrap();
    let tx = Tensor::rand(&[2, 3, 11], -1.0, 1.0, rng);
    let tw = Tensor::rand(&[4, 3, 3], -1.0, 1.0, rng);
    force_conv_pack(None);
    let tiny_auto = bench("conv-pack tiny auto    ", 20, 100, || {
        let _ = pairwise_with(&tiny_spec, &tx, &tw, &[], &scalar_opts);
    });
    force_conv_pack(Some(false));
    let tiny_plain = bench("conv-pack tiny unpacked", 20, 100, || {
        let _ = pairwise_with(&tiny_spec, &tx, &tw, &[], &scalar_opts);
    });
    force_conv_pack(None);
    println!("{}\n{}", tiny_auto.report(), tiny_plain.report());
    assert!(
        tiny_auto.median_secs() <= 2.0 * tiny_plain.median_secs(),
        "tiny conv atom regressed under auto pack routing: auto {:.3e}s vs plain {:.3e}s \
         (the CONV_PACK_MIN_FLOPS short-circuit must keep small atoms on the plain loop)",
        tiny_auto.median_secs(),
        tiny_plain.median_secs()
    );
    println!("  -> tiny conv short-circuit holds (auto routing within noise of plain loop)");
    report.insert(
        "conv_pack_tiny_auto_median_s".to_string(),
        Json::num(tiny_auto.median_secs()),
    );
    report.insert(
        "conv_pack_tiny_unpacked_median_s".to_string(),
        Json::num(tiny_plain.median_secs()),
    );

    // ---- self-learning GEMM blocking: measured KC / engagement sweep ------
    // The calibration sweep times each KC candidate (and the unpacked
    // loop) per geometry and installs the winner in the dispatcher via the
    // persistent tuning cache; the learned rows land in the report.
    println!("== self-learning GEMM blocking: per-geometry KC sweep ==");
    let blk_spec = CalibrationSpec {
        top_k: 1,
        warmup: 1,
        iters: 5,
        persist: false,
        seed: 23,
    };
    match calibrate_gemm_blocking(&[(96, 96, 192), (48, 256, 512)], &blk_spec) {
        Ok(rows) => {
            for r in &rows {
                println!(
                    "  gemm {}x{}x{}: learned kc={} min_flops={} packed {:.3e}s \
                     unpacked {:.3e}s (packs: {})",
                    r.m, r.n, r.k, r.kc, r.min_flops, r.packed_secs, r.unpacked_secs,
                    r.packs()
                );
            }
            report.insert(
                "gemm_blocking_sweep".to_string(),
                Json::arr(rows.iter().map(|r| r.to_json())),
            );
        }
        Err(e) => println!("  (gemm blocking sweep skipped: {e})"),
    }
    tuning::global().clear();

    std::fs::write("BENCH_kernels.json", Json::Obj(report).encode_pretty()).ok();
    println!("wrote BENCH_kernels.json\n");
}

/// Measured-vs-FLOPs planner sweep (`BENCH_planner.json`): skewed GEMMs
/// where the analytic cost model ties a contraction tree with its mirror
/// but the parallel backend does not — the canonical orientation splits
/// the output into `t` parallel row-chunks, so `t` below the pool width
/// leaves workers idle, while the mirror's `n`-row split stays balanced.
/// The tournament times both orientations, the measured planner must pick
/// the tournament winner, and all candidates are *asserted* bit-identical
/// first (portable kernels are forced, making the mirror exact), so the
/// wall-clock choice can never change results. Wins are counted and
/// reported, not asserted: timing noise on a loaded host must not fail
/// the bench.
fn planner_measured_benches() {
    println!("== measured planner: FLOPs-optimal vs measured-cost plans ==");
    // Portable kernels: every candidate orientation is bit-identical, so
    // the tournament is a pure scheduling comparison.
    dispatch::force_variant(Some(dispatch::Variant::Portable));
    tuning::global().clear();
    let threads = 4usize;
    let backend = Backend::Parallel { threads };
    let geometries: &[(&str, &[&[usize]])] = &[
        ("ij,jk->ik", &[&[3, 1024], &[1024, 1024]]),
        ("ij,jk->ik", &[&[2, 1536], &[1536, 768]]),
        ("ij,jk->ik", &[&[6, 896], &[896, 896]]),
    ];
    let spec = CalibrationSpec {
        top_k: 1,
        warmup: 2,
        iters: 9,
        persist: false,
        seed: 17,
    };
    let mut rows = Vec::new();
    let mut wins = 0usize;
    for (expr, dim_slices) in geometries {
        let dims: Vec<Vec<usize>> = dim_slices.iter().map(|d| d.to_vec()).collect();
        let opts = PlanOptions {
            backend,
            ..Default::default()
        };
        // Bit-identity gate: every tournament candidate must agree on the
        // output exactly before wall-clock is allowed to choose.
        let sized = SizedSpec::new(parse(expr).unwrap(), dims.clone()).unwrap();
        let cands = candidate_plans(&sized, &opts, 1).unwrap();
        assert_eq!(
            cands.len(),
            2,
            "skewed GEMM should offer a canonical tree plus its mirror"
        );
        let compiled: Vec<CompiledPlan> = cands
            .iter()
            .map(|p| CompiledPlan::compile_arc(Arc::new(p.clone())).unwrap())
            .collect();
        let mut rng = Rng::new(17);
        let probes: Vec<Tensor> = dims
            .iter()
            .map(|d| Tensor::rand(d, -1.0, 1.0, &mut rng))
            .collect();
        let inputs: Vec<&Tensor> = probes.iter().collect();
        let mut ws = Workspace::new();
        let mut ref_out = Tensor::zeros(compiled[0].out_shape());
        compiled[0].run_into(&inputs, &mut ws, &mut ref_out).unwrap();
        for cp in &compiled[1..] {
            let mut out = Tensor::zeros(cp.out_shape());
            cp.run_into(&inputs, &mut ws, &mut out).unwrap();
            let identical = ref_out
                .data()
                .iter()
                .zip(out.data())
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(
                identical,
                "tournament candidates must be bit-identical ({expr} {dims:?})"
            );
        }
        // Tournament: time both orientations, record the measurements,
        // then let the measured planner rank from the live cache.
        let rep = calibrate_expr(expr, &dims, &opts, &spec).unwrap();
        let mopts = PlanOptions {
            strategy: Strategy::Measured { top_k: 1 },
            backend,
            ..Default::default()
        };
        let measured = compile_expr(expr, &dims, &mopts).unwrap();
        assert_eq!(
            measured.plan().signature(),
            rep.candidates[rep.best].signature,
            "measured planner must pick the tournament winner"
        );
        let flops_secs = rep.candidates[0].fwd_secs;
        let measured_secs = rep.candidates[rep.best].fwd_secs;
        let speedup = flops_secs / measured_secs;
        if rep.best != 0 {
            wins += 1;
        }
        println!(
            "  {expr} {dims:?}: flops-best {flops_secs:.3e}s, measured \
             {measured_secs:.3e}s ({speedup:.2}x, winner #{})",
            rep.best
        );
        rows.push(Json::obj(vec![
            ("expr", Json::str(*expr)),
            ("dims", Json::str(format!("{dims:?}"))),
            ("flops_best_secs", Json::num(flops_secs)),
            ("measured_secs", Json::num(measured_secs)),
            ("speedup", Json::num(speedup)),
            ("winner", Json::num(rep.best as f64)),
            (
                "winner_signature",
                Json::str(&rep.candidates[rep.best].signature),
            ),
            ("bit_identical", Json::Bool(true)),
        ]));
    }
    let total = geometries.len();
    println!("  -> measured plan beat the FLOPs-optimal plan on {wins}/{total} geometries");
    let out_report = Json::obj(vec![
        ("bench", Json::str("planner_measured")),
        ("backend", Json::str(format!("parallel-{threads}"))),
        ("kernel_variant", Json::str("portable")),
        ("measured_wins", Json::num(wins as f64)),
        ("geometries_total", Json::num(total as f64)),
        ("geometries", Json::arr(rows)),
    ]);
    std::fs::write("BENCH_planner.json", out_report.encode_pretty()).ok();
    println!("wrote BENCH_planner.json\n");
    tuning::global().clear();
    dispatch::force_variant(None);
}

fn main() {
    // CI fast path: only the zero-allocation assertions (inference +
    // training + coalesced training batches), then exit — used by the
    // release-test job.
    if std::env::var("CONV_EINSUM_BENCH_ASSERT_ONLY").is_ok() {
        inference_zero_alloc_assertions();
        train_zero_alloc_assertions();
        train_batch_zero_alloc_assertions();
        measured_zero_alloc_assertions();
        println!(
            "zero-allocation assertions passed \
             (inference + training + batched training + measured plans)"
        );
        return;
    }

    // CI artifact path: only the per-variant kernel section — which also
    // runs the packed-vs-unpacked conv-atom sweep, the tiny-geometry
    // short-circuit assert, and the learned GEMM-blocking sweep — and its
    // `BENCH_kernels.json` dump (used by the forced-variant matrix job).
    if std::env::var("CONV_EINSUM_BENCH_KERNELS_ONLY").is_ok() {
        let mut rng = Rng::new(3);
        kernel_variant_benches(&mut rng);
        return;
    }

    let mut rng = Rng::new(3);

    // Per-variant microkernel section first: it forces variants globally
    // and restores auto-detection before any other section compiles plans.
    kernel_variant_benches(&mut rng);

    // Measured-planner tournament sweep: forces the portable variant and
    // seeds (then clears) the global tuning cache, restoring both before
    // the sections below compile plans.
    planner_measured_benches();
    measured_zero_alloc_assertions();

    // contraction atom: batched matmul via "gts,gns->gtn"
    let (g, t, n, s) = (4usize, 96usize, 96usize, 96usize);
    let spec = SizedSpec::new(
        parse("gts,gns->gtn").unwrap(),
        vec![vec![g, t, s], vec![g, n, s]],
    )
    .unwrap();
    let a = Tensor::rand(&[g, t, s], -1.0, 1.0, &mut rng);
    let b = Tensor::rand(&[g, n, s], -1.0, 1.0, &mut rng);
    let sample = bench("matmul-atom 4x96^3", 2, 10, || {
        let _ = pairwise(&spec, &a, &b);
    });
    println!("{}", sample.report());
    println!(
        "  -> {:.2} GFLOP/s",
        gflops((g * t * n * s) as f64, sample.median_secs())
    );

    // conv atom: standard conv layer "bshw,tshw->bthw|hw"
    let (bb, ss, tt, hh, kk) = (4usize, 16usize, 16usize, 32usize, 3usize);
    let spec = SizedSpec::new(
        parse("bshw,tshw->bthw|hw").unwrap(),
        vec![vec![bb, ss, hh, hh], vec![tt, ss, kk, kk]],
    )
    .unwrap();
    let x = Tensor::rand(&[bb, ss, hh, hh], -1.0, 1.0, &mut rng);
    let w = Tensor::rand(&[tt, ss, kk, kk], -1.0, 1.0, &mut rng);
    let sample = bench("conv-atom 4x16x16 32^2 k3", 2, 10, || {
        let _ = pairwise(&spec, &x, &w);
    });
    println!("{}", sample.report());
    let mults = (bb * ss * tt * hh * hh * kk * kk) as f64;
    println!("  -> {:.2} GFLOP/s", gflops(mults, sample.median_secs()));

    // ---- scalar vs parallel backend scaling -------------------------------
    println!("\n== backend scaling: scalar vs parallel (conv atom) ==");
    let scalar_opts = ExecOptions::scalar();
    let base = bench("conv-atom scalar", 2, 10, || {
        let _ = pairwise_with(&spec, &x, &w, &[], &scalar_opts);
    });
    println!(
        "{}\n  -> {:.2} GFLOP/s",
        base.report(),
        gflops(mults, base.median_secs())
    );
    for threads in [1usize, 2, 4, 8] {
        let opts = ExecOptions::parallel(threads);
        let smp = bench(&format!("conv-atom parallel t={threads}"), 2, 10, || {
            let _ = pairwise_with(&spec, &x, &w, &[], &opts);
        });
        println!(
            "{}\n  -> {:.2} GFLOP/s  speedup {:.2}x vs scalar",
            smp.report(),
            gflops(mults, smp.median_secs()),
            base.median_secs() / smp.median_secs()
        );
    }

    println!("\n== backend scaling: scalar vs parallel (matmul atom) ==");
    let mspec = SizedSpec::new(
        parse("gts,gns->gtn").unwrap(),
        vec![vec![g, t, s], vec![g, n, s]],
    )
    .unwrap();
    let mbase = bench("matmul-atom scalar", 2, 10, || {
        let _ = pairwise_with(&mspec, &a, &b, &[], &scalar_opts);
    });
    println!("{}", mbase.report());
    for threads in [1usize, 2, 4, 8] {
        let opts = ExecOptions::parallel(threads);
        let smp = bench(&format!("matmul-atom parallel t={threads}"), 2, 10, || {
            let _ = pairwise_with(&mspec, &a, &b, &[], &opts);
        });
        println!(
            "{}\n  -> speedup {:.2}x vs scalar",
            smp.report(),
            mbase.median_secs() / smp.median_secs()
        );
    }

    // ---- representative CP / TT layer steps under both backends -----------
    for (decomp, label) in [(Decomp::Cp, "CP"), (Decomp::TensorTrain, "TT")] {
        println!("\n== backend scaling: {label} layer (batch 4, 32x32) ==");
        let layer = match build_layer(decomp, 1, 16, 16, 3, 3, 0.5) {
            Ok(l) => l,
            Err(e) => {
                println!("  (skipped: {e})");
                continue;
            }
        };
        let factors = layer.init_factors(&mut rng);
        let xin = Tensor::rand(&layer.input_shape(4, 32, 32), -1.0, 1.0, &mut rng);
        let mut inputs: Vec<&Tensor> = vec![&xin];
        inputs.extend(factors.iter());
        let sbase = bench(&format!("{label}-layer scalar"), 1, 5, || {
            let _ = conv_einsum_with(
                &layer.expr,
                &inputs,
                &PlanOptions {
                    backend: Backend::Scalar,
                    ..Default::default()
                },
            )
            .unwrap();
        });
        println!("{}", sbase.report());
        for threads in [2usize, 4] {
            let smp = bench(&format!("{label}-layer parallel t={threads}"), 1, 5, || {
                let _ = conv_einsum_with(
                    &layer.expr,
                    &inputs,
                    &PlanOptions {
                        backend: Backend::Parallel { threads },
                        ..Default::default()
                    },
                )
                .unwrap();
            });
            println!(
                "{}\n  -> speedup {:.2}x vs scalar",
                smp.report(),
                sbase.median_secs() / smp.median_secs()
            );
        }
    }

    // ---- compiled plan: compile once, run many ----------------------------
    println!("\n== compiled plan: cached CompiledPlan vs per-call conv_einsum ==");
    let layer = build_layer(Decomp::Cp, 1, 16, 16, 3, 3, 0.5).unwrap();
    let factors = layer.init_factors(&mut rng);
    let xin = Tensor::rand(&layer.input_shape(8, 32, 32), -1.0, 1.0, &mut rng);
    let mut inputs: Vec<&Tensor> = vec![&xin];
    inputs.extend(factors.iter());
    let dims: Vec<Vec<usize>> = inputs.iter().map(|t| t.shape().to_vec()).collect();
    let popts = PlanOptions::default();

    let uncompiled = bench("fwd per-call conv_einsum (parse+plan+compile+run)", 2, 10, || {
        let _ = conv_einsum_with(&layer.expr, &inputs, &popts).unwrap();
    });
    println!("{}", uncompiled.report());
    let compiled = compile_expr(&layer.expr, &dims, &popts).unwrap();
    let mut ws = Workspace::new();
    let mut out = Tensor::zeros(compiled.out_shape());
    compiled.run_into(&inputs, &mut ws, &mut out).unwrap(); // warm-up
    let compiled_s = bench("fwd compiled run (cached plan + workspace)", 2, 10, || {
        compiled.run_into(&inputs, &mut ws, &mut out).unwrap();
    });
    println!(
        "{}\n  -> speedup {:.2}x vs per-call",
        compiled_s.report(),
        uncompiled.median_secs() / compiled_s.median_secs()
    );
    // Bit-identical to a fresh call (same kernels, same order).
    let fresh = conv_einsum_with(&layer.expr, &inputs, &popts).unwrap();
    assert_eq!(out.data(), fresh.data(), "compiled output must be bit-identical");

    // Steady-state heap allocations (scalar backend: the parallel backend's
    // scoped thread spawns allocate by design — see ROADMAP "persistent
    // worker threads").
    let sopts = PlanOptions {
        backend: Backend::Scalar,
        ..Default::default()
    };
    let scompiled = compile_expr(&layer.expr, &dims, &sopts).unwrap();
    let mut sws = Workspace::new();
    let mut sout = Tensor::zeros(scompiled.out_shape());
    scompiled.run_into(&inputs, &mut sws, &mut sout).unwrap(); // warm-up
    let a0 = allocs();
    for _ in 0..50 {
        scompiled.run_into(&inputs, &mut sws, &mut sout).unwrap();
    }
    let steady_allocs = allocs() - a0;
    // The engine's headline guarantee — keep it enforced, not just printed,
    // so a reintroduced per-run allocation fails the next bench run.
    assert_eq!(
        steady_allocs, 0,
        "compiled scalar steady state must not allocate (got {steady_allocs} across 50 runs)"
    );
    let a1 = allocs();
    let _ = conv_einsum_with(&layer.expr, &inputs, &sopts).unwrap();
    let percall_allocs = allocs() - a1;
    println!(
        "steady-state heap allocations: {} across 50 compiled runs \
         (vs {} for a single per-call conv_einsum); workspace {} bytes",
        steady_allocs,
        percall_allocs,
        sws.bytes()
    );

    // Training step (forward tape + backward): cached compiled plan vs
    // re-planning and re-lowering every step.
    let meter = MemoryMeter::new();
    let mut tws = TrainWorkspace::new();
    let compiled_arc = Arc::new(compile_expr(&layer.expr, &dims, &popts).unwrap());
    let t_uncompiled = bench("train step, plan+compile per call", 1, 5, || {
        let plan = contract_path(&layer.expr, &dims, &popts).unwrap();
        let ad = PathAutodiff::new(&plan).unwrap();
        let _ = ad
            .forward_backward(
                &inputs,
                |o| Tensor::full(o.shape(), 1.0),
                CkptPolicy::Sqrt,
                &mut tws,
                &meter,
            )
            .unwrap();
    });
    println!("{}", t_uncompiled.report());
    let t_compiled = bench("train step, cached CompiledPlan", 1, 5, || {
        let ad = PathAutodiff::from_compiled(Arc::clone(&compiled_arc));
        let _ = ad
            .forward_backward(
                &inputs,
                |o| Tensor::full(o.shape(), 1.0),
                CkptPolicy::Sqrt,
                &mut tws,
                &meter,
            )
            .unwrap();
    });
    println!(
        "{}\n  -> speedup {:.2}x vs per-call",
        t_compiled.report(),
        t_uncompiled.median_secs() / t_compiled.median_secs()
    );

    // ---- training: workspace tape vs heap tape ----------------------------
    println!("\n== training: workspace tape vs heap tape ==");
    let t_dout = Tensor::full(compiled_arc.out_shape(), 1.0);
    let heap_s = bench("train step, heap tape (per-value allocs)", 1, 5, || {
        let _ = heap_forward_backward(&compiled_arc, &inputs, &t_dout, CkptPolicy::Sqrt);
    });
    println!("{}", heap_s.report());
    let t_ad = PathAutodiff::from_compiled(Arc::clone(&compiled_arc));
    let mut t_out = Tensor::zeros(compiled_arc.out_shape());
    let mut t_grads: Vec<Tensor> = dims.iter().map(|d| Tensor::zeros(d)).collect();
    // Warm-up: grow the arena, build kernel tables and the train layout.
    for _ in 0..2 {
        let token = t_ad
            .forward_with_tape_into(&inputs, CkptPolicy::Sqrt, &mut tws, &mut t_out, &meter)
            .unwrap();
        t_ad.backward_into(&token, &t_dout, &mut tws, &mut t_grads, &meter)
            .unwrap();
    }
    let ws_s = bench("train step, workspace tape (arena)", 2, 10, || {
        let token = t_ad
            .forward_with_tape_into(&inputs, CkptPolicy::Sqrt, &mut tws, &mut t_out, &meter)
            .unwrap();
        t_ad.backward_into(&token, &t_dout, &mut tws, &mut t_grads, &meter)
            .unwrap();
    });
    println!(
        "{}\n  -> speedup {:.2}x vs heap tape",
        ws_s.report(),
        heap_s.median_secs() / ws_s.median_secs()
    );
    // Bit parity with the heap tape (same kernels, same schedule).
    let (heap_y, heap_g) = heap_forward_backward(&compiled_arc, &inputs, &t_dout, CkptPolicy::Sqrt);
    assert_eq!(
        t_out.data(),
        heap_y.data(),
        "workspace tape output must be bit-identical to the heap tape"
    );
    for (g, w) in t_grads.iter().zip(heap_g.iter()) {
        assert_eq!(
            g.data(),
            w.data(),
            "workspace tape gradients must be bit-identical to the heap tape"
        );
    }
    // Allocations per step: the heap tape pays per value/cotangent, the
    // workspace tape pays nothing (asserted — the headline guarantee).
    let h0 = allocs();
    let _ = heap_forward_backward(&compiled_arc, &inputs, &t_dout, CkptPolicy::Sqrt);
    let heap_allocs = allocs() - h0;
    let w0 = allocs();
    for _ in 0..20 {
        let token = t_ad
            .forward_with_tape_into(&inputs, CkptPolicy::Sqrt, &mut tws, &mut t_out, &meter)
            .unwrap();
        t_ad.backward_into(&token, &t_dout, &mut tws, &mut t_grads, &meter)
            .unwrap();
    }
    let ws_allocs = allocs() - w0;
    assert_eq!(
        ws_allocs, 0,
        "workspace train steady state must not allocate (got {ws_allocs} across 20 steps)"
    );
    println!(
        "train-step heap allocations: heap tape {heap_allocs} per step, \
         workspace tape {ws_allocs} across 20 steps"
    );
    // Full assertion grid: StoreAll and Sqrt on both backends, single and
    // coalesced-batch steps.
    train_zero_alloc_assertions();
    train_batch_zero_alloc_assertions();

    let train_report = Json::obj(vec![
        ("bench", Json::str("train_workspace")),
        ("expr", Json::str(&layer.expr)),
        ("batch", Json::num(8.0)),
        ("policy", Json::str("sqrt")),
        ("train_heap_median_s", Json::num(heap_s.median_secs())),
        ("train_workspace_median_s", Json::num(ws_s.median_secs())),
        (
            "train_speedup_vs_heap",
            Json::num(heap_s.median_secs() / ws_s.median_secs()),
        ),
        ("allocs_heap_one_step", Json::num(heap_allocs as f64)),
        ("allocs_workspace_20_steps", Json::num(ws_allocs as f64)),
        (
            "train_arena_bytes_sqrt",
            Json::num(compiled_arc.train_layout(CkptPolicy::Sqrt).arena_bytes() as f64),
        ),
        (
            "train_arena_bytes_storeall",
            Json::num(compiled_arc.train_layout(CkptPolicy::StoreAll).arena_bytes() as f64),
        ),
        (
            "train_arena_bytes_none",
            Json::num(compiled_arc.train_layout(CkptPolicy::None).arena_bytes() as f64),
        ),
    ]);
    std::fs::write("BENCH_train.json", train_report.encode_pretty()).ok();
    println!("wrote BENCH_train.json");

    let report = Json::obj(vec![
        ("bench", Json::str("compiled_plan")),
        ("expr", Json::str(&layer.expr)),
        ("batch", Json::num(8.0)),
        ("fwd_uncompiled_median_s", Json::num(uncompiled.median_secs())),
        ("fwd_compiled_median_s", Json::num(compiled_s.median_secs())),
        (
            "fwd_speedup",
            Json::num(uncompiled.median_secs() / compiled_s.median_secs()),
        ),
        ("train_uncompiled_median_s", Json::num(t_uncompiled.median_secs())),
        ("train_compiled_median_s", Json::num(t_compiled.median_secs())),
        (
            "train_speedup",
            Json::num(t_uncompiled.median_secs() / t_compiled.median_secs()),
        ),
        ("steady_state_allocs_50_runs", Json::num(steady_allocs as f64)),
        ("allocs_one_uncompiled_call", Json::num(percall_allocs as f64)),
        ("workspace_bytes", Json::num(sws.bytes() as f64)),
        (
            "plan_workspace_bytes",
            Json::num(scompiled.workspace_bytes() as f64),
        ),
    ]);
    std::fs::write("BENCH_compiled.json", report.encode_pretty()).ok();
    println!("wrote BENCH_compiled.json");

    // ---- persistent pool vs scoped spawn ----------------------------------
    println!("\n== persistent pool vs scoped spawn ==");

    // (a) Pure dispatch latency: 8 near-empty chunks isolate the cost of
    // fanning a region out and joining it again.
    let mut tiny = vec![0.0f32; 8 * 32];
    let pool4 = Pool::sized(4);
    let disp_persist = bench("dispatch persistent t=4 (8 tiny chunks)", 50, 200, || {
        pool4.run_chunks(&mut tiny, 32, |i, c| c[0] = i as f32);
    });
    println!("{}", disp_persist.report());
    let disp_scoped = bench("dispatch scoped    t=4 (8 tiny chunks)", 5, 50, || {
        scoped_run_chunks(4, &mut tiny, 32, |i, c| c[0] = i as f32);
    });
    println!(
        "{}\n  -> persistent dispatch {:.1}x faster",
        disp_scoped.report(),
        disp_scoped.median_secs() / disp_persist.median_secs()
    );

    // (b) Small-atom-sized parallel step (32 rows × 64 elems, 8 axpy passes
    // per row ≈ a sub-100µs conv atom) under both dispatchers: at this
    // scale dispatch overhead decides the outcome.
    let mut small = vec![0.0f32; 32 * 64];
    let srcrow = vec![0.5f32; 64];
    let small_step = |_i: usize, c: &mut [f32]| {
        for _ in 0..8 {
            axpy8(1.0001, &srcrow, c);
        }
    };
    let small_scoped = bench("small-atom step scoped     t=4", 5, 50, || {
        scoped_run_chunks(4, &mut small, 64, small_step);
    });
    println!("{}", small_scoped.report());
    let thread_list = [1usize, 2, 4, 8];
    let mut small_persist = [0.0f64; 4];
    let mut small_t4 = 0.0f64;
    for (k, &threads) in thread_list.iter().enumerate() {
        let p = Pool::sized(threads);
        let smp = bench(&format!("small-atom step persistent t={threads}"), 50, 200, || {
            p.run_chunks(&mut small, 64, small_step);
        });
        println!("{}", smp.report());
        small_persist[k] = smp.median_secs();
        if threads == 4 {
            small_t4 = smp.median_secs();
        }
    }
    let small_speedup_t4 = small_scoped.median_secs() / small_t4;
    println!("  -> small-atom step at t=4: persistent {small_speedup_t4:.1}x faster than scoped");

    // (c) A real small conv atom end-to-end through the executor on the
    // persistent pool (explicit counts force the parallel path).
    let small_spec = SizedSpec::new(
        parse("bshw,tshw->bthw|hw").unwrap(),
        vec![vec![1, 4, 12, 12], vec![4, 4, 3, 3]],
    )
    .unwrap();
    let sx = Tensor::rand(&[1, 4, 12, 12], -1.0, 1.0, &mut rng);
    let sw = Tensor::rand(&[4, 4, 3, 3], -1.0, 1.0, &mut rng);
    let mut pairwise_small = [0.0f64; 3];
    for (k, threads) in [1usize, 2, 4].into_iter().enumerate() {
        let opts = ExecOptions::parallel(threads);
        let smp = bench(&format!("small conv atom pairwise t={threads}"), 10, 50, || {
            let _ = pairwise_with(&small_spec, &sx, &sw, &[], &opts);
        });
        println!("{}", smp.report());
        pairwise_small[k] = smp.median_secs();
    }

    // (d) Allocations per compiled replay on the parallel backend: with the
    // persistent pool the parallel steady state must be as allocation-free
    // as the scalar one (asserted, like the scalar case above).
    let p2opts = PlanOptions {
        backend: Backend::Parallel { threads: 2 },
        ..Default::default()
    };
    let pcompiled = compile_expr(&layer.expr, &dims, &p2opts).unwrap();
    let mut pws = Workspace::new();
    let mut pout = Tensor::zeros(pcompiled.out_shape());
    for _ in 0..3 {
        // Warm-up: spawn pool workers, build kernel tables, grow buffers.
        pcompiled.run_into(&inputs, &mut pws, &mut pout).unwrap();
    }
    let pa0 = allocs();
    for _ in 0..50 {
        pcompiled.run_into(&inputs, &mut pws, &mut pout).unwrap();
    }
    let par_steady_allocs = allocs() - pa0;
    assert_eq!(
        par_steady_allocs, 0,
        "parallel compiled steady state must not allocate (got {par_steady_allocs} across 50 runs)"
    );
    println!(
        "steady-state heap allocations: scalar {steady_allocs}, parallel {par_steady_allocs} \
         (50 compiled replays each)"
    );

    // (e) Fine-grained claim contention: 512 tiny chunks on 4 threads. The
    // atomic cursor hands out batches of indices per fetch, so per-chunk
    // claim overhead — the old mutex round-trip per chunk — is what this
    // isolates (the scoped baseline is unchanged for reference).
    let mut fine = vec![0.0f32; 512 * 16];
    let fine_work = |_i: usize, c: &mut [f32]| {
        for v in c.iter_mut() {
            *v += 1.0;
        }
    };
    let fine_persist = bench("fine-grain 512x16 persistent t=4", 20, 100, || {
        pool4.run_chunks(&mut fine, 16, fine_work);
    });
    println!("{}", fine_persist.report());
    let fine_scoped = bench("fine-grain 512x16 scoped     t=4", 5, 20, || {
        scoped_run_chunks(4, &mut fine, 16, fine_work);
    });
    println!(
        "{}\n  -> persistent {:.1}x faster on fine-grained regions",
        fine_scoped.report(),
        fine_scoped.median_secs() / fine_persist.median_secs()
    );

    let disp_sc = disp_scoped.median_secs();
    let disp_ps = disp_persist.median_secs();
    let small_sc = small_scoped.median_secs();
    let allocs_sc = steady_allocs as f64;
    let allocs_par = par_steady_allocs as f64;
    let pool_report = Json::obj(vec![
        ("bench", Json::str("persistent_pool")),
        ("default_threads", Json::num(default_threads() as f64)),
        ("dispatch_scoped_t4_median_s", Json::num(disp_sc)),
        ("dispatch_persistent_t4_median_s", Json::num(disp_ps)),
        ("dispatch_speedup_t4", Json::num(disp_sc / disp_ps)),
        ("small_atom_scoped_t4_median_s", Json::num(small_sc)),
        ("small_atom_persistent_t1_median_s", Json::num(small_persist[0])),
        ("small_atom_persistent_t2_median_s", Json::num(small_persist[1])),
        ("small_atom_persistent_t4_median_s", Json::num(small_persist[2])),
        ("small_atom_persistent_t8_median_s", Json::num(small_persist[3])),
        ("small_atom_speedup_t4", Json::num(small_speedup_t4)),
        ("pairwise_small_atom_t1_median_s", Json::num(pairwise_small[0])),
        ("pairwise_small_atom_t2_median_s", Json::num(pairwise_small[1])),
        ("pairwise_small_atom_t4_median_s", Json::num(pairwise_small[2])),
        (
            "fine_grain_512x16_persistent_t4_median_s",
            Json::num(fine_persist.median_secs()),
        ),
        (
            "fine_grain_512x16_scoped_t4_median_s",
            Json::num(fine_scoped.median_secs()),
        ),
        ("allocs_scalar_50_replays", Json::num(allocs_sc)),
        ("allocs_parallel_50_replays", Json::num(allocs_par)),
    ]);
    std::fs::write("BENCH_pool.json", pool_report.encode_pretty()).ok();
    println!("wrote BENCH_pool.json");

    // ---- coordinator throughput: batched vs unbatched, infer/train mixes --
    println!("\n== coordinator throughput: unified batching scheduler ==");
    let clayer = build_layer(Decomp::Cp, 1, 16, 8, 3, 3, 0.5).unwrap();
    let cfactors = clayer.init_factors(&mut rng);
    let x_shape = clayer.input_shape(1, 16, 16);
    let cdims: Vec<Vec<usize>> = std::iter::once(x_shape.clone())
        .chain(cfactors.iter().map(|f| f.shape().to_vec()))
        .collect();
    let train_out_shape = compile_expr(
        &clayer.expr,
        &cdims,
        &PlanOptions {
            training: true,
            ..Default::default()
        },
    )
    .unwrap()
    .out_shape()
    .to_vec();
    let mut coord = BTreeMap::new();
    coord.insert("bench".to_string(), Json::str("coordinator_batching"));
    coord.insert("n_requests".to_string(), Json::num(48.0));
    for workers in [1usize, 2, 4] {
        for mode in ["infer", "train", "mixed"] {
            let batched = coordinator_rps(
                workers,
                8,
                mode,
                &clayer.expr,
                &cfactors,
                &x_shape,
                &train_out_shape,
                &mut rng,
            );
            let unbatched = coordinator_rps(
                workers,
                1,
                mode,
                &clayer.expr,
                &cfactors,
                &x_shape,
                &train_out_shape,
                &mut rng,
            );
            println!("  -> {mode} w={workers}: batched {:.2}x vs unbatched", batched / unbatched);
            coord.insert(format!("{mode}_w{workers}_batched_rps"), Json::num(batched));
            coord.insert(format!("{mode}_w{workers}_unbatched_rps"), Json::num(unbatched));
            coord.insert(format!("{mode}_w{workers}_speedup"), Json::num(batched / unbatched));
        }
    }
    std::fs::write("BENCH_coordinator.json", Json::Obj(coord).encode_pretty()).ok();
    println!("wrote BENCH_coordinator.json");
}

/// Drive one coordinator configuration with a burst of `infer` / `train` /
/// `mixed` traffic and return requests per second. `max_batch = 1` is the
/// unbatched baseline (the adaptive controller is bounded to singles);
/// `max_batch = 8` lets the pool-aware controller coalesce under load.
#[allow(clippy::too_many_arguments)]
fn coordinator_rps(
    workers: usize,
    max_batch: usize,
    mode: &str,
    layer_expr: &str,
    factors: &[Tensor],
    x_shape: &[usize],
    train_out_shape: &[usize],
    rng: &mut Rng,
) -> f64 {
    let service = EvalService::start(
        ServiceConfig {
            workers,
            max_batch,
            batch_timeout: Duration::from_millis(2),
            ..Default::default()
        },
        vec![("cp".into(), layer_expr.to_string(), factors.to_vec())],
    )
    .unwrap();
    let h = service.handle();
    let n_req = 48usize;
    let xs: Vec<Tensor> = (0..n_req)
        .map(|_| Tensor::rand(x_shape, -1.0, 1.0, rng))
        .collect();
    let dout = Tensor::full(train_out_shape, 1.0);
    let burst = || {
        let mut eval_rx = Vec::new();
        let mut train_rx = Vec::new();
        for (i, x) in xs.iter().enumerate() {
            let train = match mode {
                "train" => true,
                "infer" => false,
                _ => i % 2 == 1,
            };
            if train {
                let mut tensors = vec![x.clone()];
                tensors.extend(factors.iter().cloned());
                train_rx.push(
                    h.submit_train(layer_expr, tensors, dout.clone(), CkptPolicy::StoreAll)
                        .unwrap(),
                );
            } else {
                eval_rx.push(h.submit("cp", x.clone()).unwrap());
            }
        }
        for rx in eval_rx {
            rx.recv().unwrap().unwrap();
        }
        for rx in train_rx {
            rx.recv().unwrap().unwrap();
        }
    };
    // Untimed warm-up burst: populate the per-geometry layer plan caches and
    // the shared training plan cache, so the timed window measures steady-
    // state serving, not first-time planning+compilation (which the batched
    // config would otherwise pay once per coalesced batch geometry while the
    // unbatched baseline pays it only for batch size 1).
    burst();
    let t0 = std::time::Instant::now();
    burst();
    let dt = t0.elapsed();
    let rps = n_req as f64 / dt.as_secs_f64();
    println!(
        "coordinator {mode:>5} w={workers} max_batch={max_batch}: {n_req} req in {dt:?} \
         ({rps:.0} req/s) | {}",
        h.metrics().report()
    );
    service.shutdown();
    rps
}
