//! §Perf hot-path microbenchmarks (EXPERIMENTS.md §Perf): executor
//! throughput on the two atoms (contraction GFLOP/s, conv atom GFLOP/s),
//! scalar-vs-parallel backend scaling across 1/2/4/8-thread pools, CP/TT
//! layer steps under both backends, pairwise overhead, and coordinator
//! request throughput with batching on vs off.
use conv_einsum::coordinator::{EvalService, ServiceConfig};
use conv_einsum::einsum::{parse, SizedSpec};
use conv_einsum::exec::{pairwise, pairwise_with};
use conv_einsum::planner::PlanOptions;
use conv_einsum::tnn::{build_layer, Decomp};
use conv_einsum::util::rng::Rng;
use conv_einsum::util::timing::bench;
use conv_einsum::{conv_einsum_with, Backend, ExecOptions, Tensor};

fn gflops(mults: f64, secs: f64) -> f64 {
    2.0 * mults / secs / 1e9
}

fn main() {
    let mut rng = Rng::new(3);

    // contraction atom: batched matmul via "gts,gns->gtn"
    let (g, t, n, s) = (4usize, 96usize, 96usize, 96usize);
    let spec = SizedSpec::new(
        parse("gts,gns->gtn").unwrap(),
        vec![vec![g, t, s], vec![g, n, s]],
    )
    .unwrap();
    let a = Tensor::rand(&[g, t, s], -1.0, 1.0, &mut rng);
    let b = Tensor::rand(&[g, n, s], -1.0, 1.0, &mut rng);
    let sample = bench("matmul-atom 4x96^3", 2, 10, || {
        let _ = pairwise(&spec, &a, &b);
    });
    println!("{}", sample.report());
    println!(
        "  -> {:.2} GFLOP/s",
        gflops((g * t * n * s) as f64, sample.median_secs())
    );

    // conv atom: standard conv layer "bshw,tshw->bthw|hw"
    let (bb, ss, tt, hh, kk) = (4usize, 16usize, 16usize, 32usize, 3usize);
    let spec = SizedSpec::new(
        parse("bshw,tshw->bthw|hw").unwrap(),
        vec![vec![bb, ss, hh, hh], vec![tt, ss, kk, kk]],
    )
    .unwrap();
    let x = Tensor::rand(&[bb, ss, hh, hh], -1.0, 1.0, &mut rng);
    let w = Tensor::rand(&[tt, ss, kk, kk], -1.0, 1.0, &mut rng);
    let sample = bench("conv-atom 4x16x16 32^2 k3", 2, 10, || {
        let _ = pairwise(&spec, &x, &w);
    });
    println!("{}", sample.report());
    let mults = (bb * ss * tt * hh * hh * kk * kk) as f64;
    println!("  -> {:.2} GFLOP/s", gflops(mults, sample.median_secs()));

    // ---- scalar vs parallel backend scaling -------------------------------
    println!("\n== backend scaling: scalar vs parallel (conv atom) ==");
    let scalar_opts = ExecOptions::scalar();
    let base = bench("conv-atom scalar", 2, 10, || {
        let _ = pairwise_with(&spec, &x, &w, &[], &scalar_opts);
    });
    println!(
        "{}\n  -> {:.2} GFLOP/s",
        base.report(),
        gflops(mults, base.median_secs())
    );
    for threads in [1usize, 2, 4, 8] {
        let opts = ExecOptions::parallel(threads);
        let smp = bench(&format!("conv-atom parallel t={threads}"), 2, 10, || {
            let _ = pairwise_with(&spec, &x, &w, &[], &opts);
        });
        println!(
            "{}\n  -> {:.2} GFLOP/s  speedup {:.2}x vs scalar",
            smp.report(),
            gflops(mults, smp.median_secs()),
            base.median_secs() / smp.median_secs()
        );
    }

    println!("\n== backend scaling: scalar vs parallel (matmul atom) ==");
    let mspec = SizedSpec::new(
        parse("gts,gns->gtn").unwrap(),
        vec![vec![g, t, s], vec![g, n, s]],
    )
    .unwrap();
    let mbase = bench("matmul-atom scalar", 2, 10, || {
        let _ = pairwise_with(&mspec, &a, &b, &[], &scalar_opts);
    });
    println!("{}", mbase.report());
    for threads in [1usize, 2, 4, 8] {
        let opts = ExecOptions::parallel(threads);
        let smp = bench(&format!("matmul-atom parallel t={threads}"), 2, 10, || {
            let _ = pairwise_with(&mspec, &a, &b, &[], &opts);
        });
        println!(
            "{}\n  -> speedup {:.2}x vs scalar",
            smp.report(),
            mbase.median_secs() / smp.median_secs()
        );
    }

    // ---- representative CP / TT layer steps under both backends -----------
    for (decomp, label) in [(Decomp::Cp, "CP"), (Decomp::TensorTrain, "TT")] {
        println!("\n== backend scaling: {label} layer (batch 4, 32x32) ==");
        let layer = match build_layer(decomp, 1, 16, 16, 3, 3, 0.5) {
            Ok(l) => l,
            Err(e) => {
                println!("  (skipped: {e})");
                continue;
            }
        };
        let factors = layer.init_factors(&mut rng);
        let xin = Tensor::rand(&layer.input_shape(4, 32, 32), -1.0, 1.0, &mut rng);
        let mut inputs: Vec<&Tensor> = vec![&xin];
        inputs.extend(factors.iter());
        let sbase = bench(&format!("{label}-layer scalar"), 1, 5, || {
            let _ = conv_einsum_with(
                &layer.expr,
                &inputs,
                &PlanOptions {
                    backend: Backend::Scalar,
                    ..Default::default()
                },
            )
            .unwrap();
        });
        println!("{}", sbase.report());
        for threads in [2usize, 4] {
            let smp = bench(&format!("{label}-layer parallel t={threads}"), 1, 5, || {
                let _ = conv_einsum_with(
                    &layer.expr,
                    &inputs,
                    &PlanOptions {
                        backend: Backend::Parallel { threads },
                        ..Default::default()
                    },
                )
                .unwrap();
            });
            println!(
                "{}\n  -> speedup {:.2}x vs scalar",
                smp.report(),
                sbase.median_secs() / smp.median_secs()
            );
        }
    }

    // coordinator throughput, batching on vs off
    println!();
    for max_batch in [1usize, 8] {
        let layer = build_layer(Decomp::Cp, 1, 16, 8, 3, 3, 0.5).unwrap();
        let factors = layer.init_factors(&mut rng);
        let service = EvalService::start(
            ServiceConfig { max_batch, workers: 2, ..Default::default() },
            vec![("cp".into(), layer.expr.clone(), factors)],
        )
        .unwrap();
        let h = service.handle();
        let n_req = 64;
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = (0..n_req)
            .map(|_| {
                let x = Tensor::rand(&[1, 8, 16, 16], -1.0, 1.0, &mut rng);
                h.submit("cp", x).unwrap()
            })
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let dt = t0.elapsed();
        println!(
            "coordinator max_batch={max_batch}: {n_req} req in {dt:?} ({:.0} req/s) | {}",
            n_req as f64 / dt.as_secs_f64(),
            h.metrics().report()
        );
        service.shutdown();
    }
}
