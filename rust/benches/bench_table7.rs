//! Paper Table 7: accuracy vs compression rate (the trend reproduction:
//! accuracy degrades monotonically-ish as CR shrinks). Trains the small
//! RCP net per CR on the synthetic IC task.
use conv_einsum::experiments::Table;
use conv_einsum::nn::{small_tnn_cnn, EvalConfig, Sgd, SyntheticImages, Trainer, TrainerConfig};
use conv_einsum::tnn::Decomp;
use conv_einsum::util::rng::Rng;

fn main() {
    let full = std::env::var("FULL").is_ok();
    let crs = if full {
        vec![1.0, 0.5, 0.2, 0.1, 0.05, 0.02]
    } else {
        vec![1.0, 0.1, 0.02]
    };
    let epochs = if full { 8 } else { 4 };
    let mut rows = Vec::new();
    let mut accs = Vec::new();
    for &cr in &crs {
        let mut rng = Rng::new(0x7AB1E7);
        let mut model = small_tnn_cnn(
            Decomp::Cp, 2, cr, 1, 12, 2, 3, 4, EvalConfig::conv_einsum(), &mut rng,
        )
        .unwrap();
        let train = SyntheticImages::sized(1, 12, 12, 4, 96, 31);
        let eval = SyntheticImages::sized(1, 12, 12, 4, 48, 32);
        let mut trainer = Trainer::new(
            TrainerConfig { batch_size: 16, epochs, ..Default::default() },
            Sgd::new(0.05, 0.9, 5e-4),
        );
        let stats = trainer.fit(&mut model, &train, &eval);
        let acc = stats.last().unwrap().eval_acc;
        accs.push(acc);
        rows.push(vec![
            format!("{:.0}%", cr * 100.0),
            format!("{}", model.param_count()),
            format!("{:.3}", acc),
        ]);
        println!("CR {:>4.0}%: {} params, eval acc {:.3}", cr * 100.0, model.param_count(), acc);
    }
    let table = Table {
        title: "Table 7 (scaled): accuracy vs compression rate (RCP, synthetic IC)".into(),
        header: vec!["CR".into(), "params".into(), "eval acc".into()],
        rows,
    };
    println!("{}", table.render());
    table.save("table7").unwrap();
    // trend: highest CR should not be the worst model
    let max_acc = accs.iter().cloned().fold(0.0f32, f32::max);
    assert!(accs[0] >= max_acc - 0.15, "full-rank model unexpectedly weak: {accs:?}");
}
