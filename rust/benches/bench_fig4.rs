//! Paper Figure 4: video classification, two-stream RCP(M=3) network at
//! the maximum allowable batch size per mode — runtime + the max-batch
//! interplay (memory-bounded workload). Spatial stream: RGB; temporal
//! stream: stacked-flow channels.
use conv_einsum::experiments::memory::max_batch;
use conv_einsum::experiments::runtime_sweep::{render, sweep, Workload};
use conv_einsum::nn::EvalConfig;
use conv_einsum::tnn::{build_layer, Decomp};

fn main() {
    let full = std::env::var("FULL").is_ok();
    let crs = if full { vec![0.01, 0.05, 0.1, 0.2, 0.5, 1.0] } else { vec![0.05, 0.5] };
    let budget = 8 * 1024 * 1024; // scaled "GPU memory"
    println!("max allowable batch (budget {} bytes), VC-layer 16x20x3x3 @ 14x14:", budget);
    println!("{:>6} {:>14} {:>16} {:>16}", "CR", "conv_einsum", "naive w/ ckpt", "naive w/o ckpt");
    for &cr in &crs {
        // temporal-stream-like layer: 20 input channels (stacked flow)
        let spec = build_layer(Decomp::Cp, 3, 16, 20, 3, 3, cr).unwrap();
        let ce = max_batch(&spec, EvalConfig::conv_einsum(), 14, 14, budget, 128);
        let nc = max_batch(&spec, EvalConfig::naive_ckpt(), 14, 14, budget, 128);
        let nn = max_batch(&spec, EvalConfig::naive_no_ckpt(), 14, 14, budget, 128);
        println!("{:>5.0}% {:>14} {:>16} {:>16}", cr * 100.0, ce, nc, nn);
    }
    // runtime at a fixed feasible batch for both "streams"
    let cells = sweep(
        &Workload::ImageClassification { size: 14, channels: 3 },
        Decomp::Cp, 3, &crs, 4, if full { 32 } else { 8 }, 2, 16,
    );
    let t = render("Figure 4 (VC spatial stream, scaled): s/epoch", &cells);
    println!("{}", t.render());
    t.save("fig4_vc").unwrap();
}
