//! Paper Table 3: maximal batch size under a memory budget — ASR-like and
//! VC-like tensorial layers across CRs and the three execution modes.
//! conv_einsum must permit the largest batches (paper's headline).
use conv_einsum::experiments::memory::table3;
use conv_einsum::tnn::Decomp;

fn main() {
    let budget = 8 * 1024 * 1024; // scaled stand-in for the 2080Ti's 11 GB
    let full = std::env::var("FULL").is_ok();
    let crs = if full {
        vec![0.01, 0.02, 0.05, 0.1, 0.2, 0.5, 1.0]
    } else {
        vec![0.05, 0.2, 1.0]
    };
    // ASR-like: CP over 1-D frames (represented as H'=48, W'=1)
    let asr = table3(
        "Table 3 (ASR, scaled): max batch under memory budget",
        Decomp::Cp, 1, 16, 16, 3, 48, 1, &crs, budget,
    );
    println!("{}", asr.render());
    asr.save("table3_asr").unwrap();

    // VC-like: RCP(M=3), temporal stream channels
    let vc = table3(
        "Table 3 (VC temporal, scaled): max batch under memory budget",
        Decomp::Cp, 3, 16, 20, 3, 14, 14, &crs, budget,
    );
    println!("{}", vc.render());
    vc.save("table3_vc").unwrap();
}
