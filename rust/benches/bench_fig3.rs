//! Paper Figure 3: runtime vs CR for image classification (RCP ResNet-34 /
//! CIFAR-10-like) and ASR (CP Conformer-conv / LibriSpeech-like), all three
//! execution modes. Scaled-down measured epochs.
use conv_einsum::experiments::runtime_sweep::{render, sweep, Workload};
use conv_einsum::tnn::Decomp;

fn main() {
    let full = std::env::var("FULL").is_ok();
    let crs = if full {
        vec![0.01, 0.05, 0.1, 0.2, 0.5, 1.0]
    } else {
        vec![0.05, 0.5]
    };
    // IC: RCP (M=3) on image batches
    let ic = sweep(
        &Workload::ImageClassification { size: 12, channels: 3 },
        Decomp::Cp,
        3,
        &crs,
        8,
        if full { 48 } else { 16 },
        2,
        16,
    );
    let t = render("Figure 3 (IC, scaled): s/epoch, RCP(M=3), CIFAR-10-like", &ic);
    println!("{}", t.render());
    t.save("fig3_ic").unwrap();

    // ASR: flat CP on sequence batches (W'=1)
    let asr = sweep(
        &Workload::SpeechRecognition { channels: 8, frames: 32 },
        Decomp::Cp,
        1,
        &crs,
        8,
        if full { 48 } else { 16 },
        2,
        16,
    );
    let t = render("Figure 3 (ASR, scaled): s/epoch, CP, LibriSpeech-like", &asr);
    println!("{}", t.render());
    t.save("fig3_asr").unwrap();
}
