//! Paper Table 6: low-resource (4-core CPU) s/epoch, RCP vs TK across CRs.
//! Our engine is single-threaded per request, so the 4-core cap is the
//! natural habitat; this bench compares the two decompositions' scaling.
use conv_einsum::experiments::runtime_sweep::{sweep, Workload};
use conv_einsum::experiments::Table;
use conv_einsum::tnn::Decomp;

fn main() {
    let full = std::env::var("FULL").is_ok();
    let crs = if full { vec![0.05, 0.1, 0.2, 0.5, 1.0] } else { vec![0.05, 0.5] };
    let mut rows = Vec::new();
    for &cr in &crs {
        let mut row = vec![format!("{:.0}%", cr * 100.0)];
        for decomp in [Decomp::Cp, Decomp::Tucker] {
            let cells = sweep(
                &Workload::ImageClassification { size: 12, channels: 3 },
                decomp, 3, &[cr], 8, if full { 32 } else { 12 }, 2, 16,
            );
            let ce = cells.iter().find(|c| c.mode == "conv_einsum").unwrap();
            row.push(format!("{:.2}", ce.train_secs));
            row.push(format!("{:.2}", ce.test_secs));
        }
        rows.push(row);
    }
    let table = Table {
        title: "Table 6 (scaled, CPU): conv_einsum s/epoch, RCP vs RTK across CRs".into(),
        header: vec![
            "CR".into(),
            "RCP train".into(), "RCP test".into(),
            "RTK train".into(), "RTK test".into(),
        ],
        rows,
    };
    println!("{}", table.render());
    table.save("table6").unwrap();
}
