//! Paper Table 5: s/epoch on CIFAR-10-like IC for the reshaped
//! decomposition zoo (RCP / RTR / RTT / RTK, M=3), three execution modes.
use conv_einsum::experiments::runtime_sweep::{render, sweep, Workload};
use conv_einsum::experiments::Table;
use conv_einsum::tnn::Decomp;

fn main() {
    let full = std::env::var("FULL").is_ok();
    let mut rows = Vec::new();
    for (name, decomp) in [
        ("RCP", Decomp::Cp),
        ("RTR", Decomp::TensorRing),
        ("RTT", Decomp::TensorTrain),
        ("RTK", Decomp::Tucker),
    ] {
        let cells = sweep(
            &Workload::ImageClassification { size: 12, channels: 3 },
            decomp,
            3,
            &[0.5],
            8,
            if full { 48 } else { 16 },
            2,
            16,
        );
        let mut row = vec![name.to_string()];
        for mode in ["conv_einsum", "naive w/ ckpt", "naive w/o ckpt"] {
            let c = cells.iter().find(|c| c.mode == mode).unwrap();
            row.push(format!("{:.2}", c.train_secs));
            row.push(format!("{:.2}", c.test_secs));
        }
        rows.push(row);
        let t = render(&format!("Table 5 detail: {name}"), &cells);
        println!("{}", t.render());
    }
    let table = Table {
        title: "Table 5 (scaled): s/epoch by decomposition form (M=3, CR 50%)".into(),
        header: vec![
            "form".into(),
            "conv_einsum train".into(), "conv_einsum test".into(),
            "naive ckpt train".into(), "naive ckpt test".into(),
            "naive no-ckpt train".into(), "naive no-ckpt test".into(),
        ],
        rows,
    };
    println!("{}", table.render());
    table.save("table5").unwrap();
}
