//! Figures 1b & 2: the optimal sequencer — path report on the Fig. 1a
//! string, cost-capped planning (Fig. 2's orange path), and planner latency
//! across network sizes.
use conv_einsum::planner::{contract_path, PlanOptions, Strategy};
use conv_einsum::util::timing::bench;

fn main() {
    // Figure 1b
    let dims = vec![vec![4, 7, 9], vec![10, 5], vec![5, 4, 2], vec![6, 8, 9, 2]];
    let expr = "ijk,jl,lmq,njpq->ijknp|j";
    let plan = contract_path(expr, &dims, &PlanOptions::default()).unwrap();
    println!("{}", plan.report());

    // Figure 2: cap per-node cost; the planner returns the best tree whose
    // every step satisfies the cap (or errors when infeasible).
    let max_step = plan.steps.iter().map(|s| s.cost).fold(0.0, f64::max);
    for cap in [max_step, max_step / 2.0, 1.0] {
        match contract_path(expr, &dims, &PlanOptions { cost_cap: Some(cap), ..Default::default() }) {
            Ok(p) => println!("cap {:>12.0}: feasible, total cost {:.0}", cap, p.cost),
            Err(e) => println!("cap {:>12.0}: {e}", cap),
        }
    }
    println!();

    // Planner latency: exact DP across input counts (CP chains).
    for n in [4usize, 6, 8, 10, 12] {
        let mut parts = vec!["bsh".to_string()];
        let mut d = vec![vec![4, 8, 32]];
        for i in 1..n {
            parts.push(format!("r(t{i})"));
            d.push(vec![6, 4]);
        }
        let e = format!("{}->b{}h|h", parts.join(","), (1..n).map(|i| format!("(t{i})")).collect::<String>());
        // make it contract: tie r across factors and s onto first factor
        let e = e.replace("r(t1)", "rs(t1)");
        let mut d2 = d.clone();
        d2[1] = vec![6, 8, 4];
        let s = bench(&format!("plan n={n}"), 1, 5, || {
            let _ = contract_path(&e, &d2, &PlanOptions::default()).unwrap();
        });
        println!("{}", s.report());
    }

    // Strategy comparison on the RCP(M=3) layer string.
    let expr = "b(s1)(s2)(s3)hw,r(t1)(s1),r(t2)(s2),r(t3)(s3),rhw->b(t1)(t2)(t3)hw|hw";
    let dims = vec![
        vec![32, 4, 4, 4, 32, 32],
        vec![64, 4, 4],
        vec![64, 4, 4],
        vec![64, 4, 4],
        vec![64, 3, 3],
    ];
    for strat in [Strategy::Optimal, Strategy::Greedy, Strategy::LeftToRight] {
        let p = contract_path(expr, &dims, &PlanOptions { strategy: strat, ..Default::default() }).unwrap();
        println!("{:>14}: cost {:>14.3e}  largest intermediate {:>12.3e}", format!("{strat}"), p.cost, p.largest_intermediate);
    }
}
