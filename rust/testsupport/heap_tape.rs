//! Heap-tape reference: the pre-workspace training algorithm (every tape
//! value, recompute and cotangent individually heap-allocated), replayed
//! over the same compiled plan through the public atom API. Shared —
//! via `#[path]` inclusion — by `tests/train_parity.rs` (bit-parity
//! property suite) and `benches/bench_hotpath.rs` (timing baseline +
//! parity assertion), so there is exactly one definition of what "the old
//! algorithm" is.

use conv_einsum::autodiff::CkptPolicy;
use conv_einsum::exec::CompiledPlan;
use conv_einsum::Tensor;

fn run_step(compiled: &CompiledPlan, k: usize, vals: &mut [Option<Tensor>]) {
    let n = compiled.n_inputs();
    let st = compiled.step(k);
    let (l, r) = st.nodes();
    let a = vals[l].as_ref().expect("lhs value live");
    let b = vals[r].as_ref().expect("rhs value live");
    let out = st
        .atom()
        .execute_with_kernel(st.kernel_tables(), a, b, compiled.exec_options());
    vals[n + k] = Some(out);
}

fn needed_after(compiled: &CompiledPlan, node: usize, after: usize) -> bool {
    (after..compiled.n_steps()).any(|k| {
        let (l, r) = compiled.step(k).nodes();
        l == node || r == node
    })
}

fn recompute(compiled: &CompiledPlan, node: usize, vals: &mut Vec<Option<Tensor>>) {
    let n = compiled.n_inputs();
    let k = node - n;
    let (l, r) = compiled.step(k).nodes();
    for dep in [l, r] {
        if vals[dep].is_none() {
            recompute(compiled, dep, vals);
        }
    }
    run_step(compiled, k, vals);
}

fn invert(perm: &[usize]) -> Vec<usize> {
    let mut inv = vec![0usize; perm.len()];
    for (i, &p) in perm.iter().enumerate() {
        inv[p] = i;
    }
    inv
}

/// The pre-refactor heap tape, step by step: stored forward under the
/// policy's keep-set, then the backward with checkpoint-segment
/// recomputes. The workspace tape must reproduce this bit-for-bit.
pub fn heap_forward_backward(
    compiled: &CompiledPlan,
    inputs: &[&Tensor],
    dout: &Tensor,
    policy: CkptPolicy,
) -> (Tensor, Vec<Tensor>) {
    let n = compiled.n_inputs();
    let ksteps = compiled.n_steps();
    let root = n + ksteps - 1;
    let keep: Vec<bool> = match policy {
        CkptPolicy::StoreAll => vec![true; ksteps],
        CkptPolicy::None => vec![false; ksteps],
        CkptPolicy::Sqrt => {
            let seg = (ksteps as f64).sqrt().ceil() as usize;
            (0..ksteps).map(|k| seg != 0 && k % seg == seg - 1).collect()
        }
    };
    // Stored forward.
    let mut vals: Vec<Option<Tensor>> = vec![None; n + ksteps];
    for (i, t) in inputs.iter().enumerate() {
        vals[i] = Some((*t).clone());
    }
    for k in 0..ksteps {
        run_step(compiled, k, &mut vals);
        let (l, r) = compiled.step(k).nodes();
        for node in [l, r] {
            let is_input = node < n;
            let is_kept = !is_input && keep[node - n];
            if !is_input && !is_kept && !needed_after(compiled, node, k + 1) {
                vals[node] = None;
            }
        }
    }
    for k in 0..ksteps {
        let node = n + k;
        if node != root && !keep[k] && vals[node].is_some() {
            vals[node] = None;
        }
    }
    let root_val = vals[root].clone().expect("root");
    let output = match &compiled.plan().final_perm {
        Some(p) => root_val.permute(p),
        None => root_val.clone(),
    };
    // Backward with segment recomputes.
    let droot = match &compiled.plan().final_perm {
        Some(p) => dout.permute(&invert(p)),
        None => dout.clone(),
    };
    let mut grads: Vec<Option<Tensor>> = vec![None; n + ksteps];
    grads[root] = Some(droot);
    for k in (0..ksteps).rev() {
        let (l, r) = compiled.step(k).nodes();
        for node in [l, r] {
            if vals[node].is_none() {
                recompute(compiled, node, &mut vals);
            }
        }
        let st = compiled.step(k);
        let dnode = grads[n + k].take().expect("cotangent for step output");
        let a = vals[l].as_ref().unwrap();
        let b = vals[r].as_ref().unwrap();
        let (da, db) =
            st.atom()
                .vjp_with_kernel(st.kernel_tables(), a, b, &dnode, compiled.exec_options());
        match &mut grads[l] {
            Some(existing) => existing.add_assign(&da),
            slot @ None => *slot = Some(da),
        }
        match &mut grads[r] {
            Some(existing) => existing.add_assign(&db),
            slot @ None => *slot = Some(db),
        }
        vals[n + k] = None;
    }
    let input_grads: Vec<Tensor> = (0..n)
        .map(|i| grads[i].take().expect("every input gets a gradient"))
        .collect();
    (output, input_grads)
}
