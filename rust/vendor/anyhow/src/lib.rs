//! Offline, API-compatible subset of the `anyhow` crate.
//!
//! The build environment for this repository has no network access, so the
//! real crates.io `anyhow` cannot be fetched. This vendored shim provides the
//! slice of its API the workspace actually uses — a message-carrying opaque
//! [`Error`], the [`anyhow!`] macro, a [`Result`] alias, [`Error::msg`], and
//! the [`Context`] extension trait — with the same semantics:
//!
//! * `Error` deliberately does **not** implement `std::error::Error`, which
//!   is what lets the blanket `From<E: std::error::Error>` impl coexist with
//!   the reflexive `From<Error>` used by the `?` operator.
//! * `anyhow!("literal {captures}")`, `anyhow!("fmt {}", args)` and
//!   `anyhow!(expr)` all work.
//!
//! No backtraces, no downcasting, no error chains — none of which the
//! workspace relies on.

use std::fmt;

/// An opaque, message-carrying error.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
        }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: format!("{}: {}", context, self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {}", context, e)))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {}", f(), e)))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built as by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return Err($crate::anyhow!($($tt)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_debug_carry_message() {
        let e = anyhow!("bad {} thing", 3);
        assert_eq!(format!("{e}"), "bad 3 thing");
        assert_eq!(format!("{e:?}"), "bad 3 thing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<i32> {
            let n: i32 = "7".parse()?;
            Ok(n)
        }
        assert_eq!(inner().unwrap(), 7);
        fn failing() -> Result<i32> {
            let n: i32 = "x".parse()?;
            Ok(n)
        }
        assert!(failing().is_err());
    }

    #[test]
    fn context_wraps_messages() {
        let r: std::result::Result<(), String> = Err("inner".to_string());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer: inner");
        let o: Option<i32> = None;
        assert!(o.with_context(|| "missing").is_err());
    }

    #[test]
    fn error_msg_accepts_strings() {
        let e = Error::msg("plain".to_string());
        assert_eq!(format!("{e}"), "plain");
    }
}
