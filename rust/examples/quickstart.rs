//! Quickstart: the paper's Figure 1 walkthrough.
//!
//! Builds the four tensors of Fig. 1a, plans the conv_einsum string
//! `"ijk,jl,lmq,njpq->ijknp|j"`, prints the Fig. 1b-style path report
//! (naive vs optimized FLOPs, largest intermediate, step list), then
//! executes both paths and checks they agree numerically.
//!
//! Run: `cargo run --release --example quickstart`

use conv_einsum::planner::{contract_path, PlanOptions, Strategy};
use conv_einsum::util::rng::Rng;
use conv_einsum::{conv_einsum, conv_einsum_with, Tensor};

fn main() -> anyhow::Result<()> {
    // Figure 1a: A(4,7,9), B(10,5), C(5,4,2), D(6,8,9,2)
    let mut rng = Rng::new(0);
    let a = Tensor::rand(&[4, 7, 9], -1.0, 1.0, &mut rng);
    let b = Tensor::rand(&[10, 5], -1.0, 1.0, &mut rng);
    let c = Tensor::rand(&[5, 4, 2], -1.0, 1.0, &mut rng);
    let d = Tensor::rand(&[6, 8, 9, 2], -1.0, 1.0, &mut rng);
    let expr = "ijk,jl,lmq,njpq->ijknp|j";

    println!("conv_einsum quickstart — paper Figure 1 reproduction\n");
    let dims: Vec<Vec<usize>> = [&a, &b, &c, &d].iter().map(|t| t.shape().to_vec()).collect();
    let plan = contract_path(expr, &dims, &PlanOptions::default()).map_err(anyhow::Error::msg)?;
    println!("{}", plan.report());
    println!(
        "speedup over left-to-right: {:.2}x\n",
        plan.speedup_vs_naive()
    );

    // Execute optimal and naive paths; identical numerics, different cost.
    let inputs = [&a, &b, &c, &d];
    let optimal = conv_einsum(expr, &inputs)?;
    let naive = conv_einsum_with(
        expr,
        &inputs,
        &PlanOptions {
            strategy: Strategy::LeftToRight,
            ..Default::default()
        },
    )?;
    println!("output shape: {:?}", optimal.shape());
    println!("max |optimal - naive| = {:.2e}", optimal.max_abs_diff(&naive));
    assert!(optimal.max_abs_diff(&naive) < 1e-3);

    // A standard conv layer and its CP factorization (paper §2.3).
    println!("\n--- CP convolutional layer (paper §2.3) ---");
    let layer = conv_einsum::tnn::build_layer(conv_einsum::tnn::Decomp::Cp, 1, 16, 8, 3, 3, 0.5)
        .map_err(anyhow::Error::msg)?;
    println!("layer string:   {}", layer.expr);
    println!(
        "parameters:     {} ({:.1}% of the dense kernel)",
        layer.params,
        100.0 * layer.achieved_cr()
    );
    let ldims = layer.expr_dims(8, 32, 32);
    let lplan =
        contract_path(&layer.expr, &ldims, &PlanOptions::default()).map_err(anyhow::Error::msg)?;
    println!(
        "planned FLOPs:  {} optimal vs {} naive ({:.1}x)",
        conv_einsum::util::sci(lplan.cost),
        conv_einsum::util::sci(lplan.naive_cost),
        lplan.speedup_vs_naive()
    );
    println!("\nquickstart OK");
    Ok(())
}
