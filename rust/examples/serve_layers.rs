//! Serving example: the L3 coordinator dispatching batched tensorial-layer
//! evaluations, with latency/throughput reporting — and, when `make
//! artifacts` has been run, the same layer executed through the AOT
//! JAX/Pallas artifact on the PJRT runtime (proving all three layers
//! compose: rust coordinator → PJRT → HLO lowered from JAX+Pallas).
//!
//! Run: `cargo run --release --example serve_layers`

use conv_einsum::coordinator::{EvalService, ServiceConfig};
use conv_einsum::runtime::ArtifactRegistry;
use conv_einsum::tnn::{build_layer, Decomp};
use conv_einsum::util::rng::Rng;
use conv_einsum::Tensor;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(5);

    // Register two tensorial layers with the service.
    let cp = build_layer(Decomp::Cp, 1, 16, 8, 3, 3, 0.5).map_err(anyhow::Error::msg)?;
    let tk = build_layer(Decomp::Tucker, 1, 16, 8, 3, 3, 0.5).map_err(anyhow::Error::msg)?;
    let cp_factors = cp.init_factors(&mut rng);
    let tk_factors = tk.init_factors(&mut rng);
    let service = EvalService::start(
        ServiceConfig {
            workers: 2,
            max_batch: 8,
            ..Default::default()
        },
        vec![
            ("cp".into(), cp.expr.clone(), cp_factors),
            ("tk".into(), tk.expr.clone(), tk_factors),
        ],
    )?;
    let handle = service.handle();

    // Fire a mixed request stream.
    let n = 96;
    println!("serving {n} single-example layer evaluations (batched)...");
    let t0 = Instant::now();
    let receivers: Vec<_> = (0..n)
        .map(|i| {
            let layer = if i % 3 == 0 { "tk" } else { "cp" };
            let x = Tensor::rand(&[1, 8, 16, 16], -1.0, 1.0, &mut rng);
            handle.submit(layer, x).unwrap()
        })
        .collect();
    for rx in receivers {
        rx.recv().unwrap()?;
    }
    let dt = t0.elapsed();
    println!(
        "done in {dt:?} → {:.1} req/s",
        n as f64 / dt.as_secs_f64()
    );
    println!("{}\n", handle.metrics().report());
    service.shutdown();

    // PJRT path: run the AOT'd CP layer artifact if it has been built.
    match ArtifactRegistry::open("artifacts") {
        Ok(mut registry) => {
            println!("AOT artifacts found (platform: {}):", registry.platform());
            let names: Vec<String> =
                registry.names().iter().map(|s| s.to_string()).collect();
            for name in names.iter().filter(|n| n.contains("fwd")) {
                let meta = registry.meta(name).unwrap().clone();
                let inputs: Vec<Tensor> = meta
                    .input_shapes
                    .iter()
                    .map(|s| Tensor::rand(s, -0.5, 0.5, &mut rng))
                    .collect();
                let refs: Vec<&Tensor> = inputs.iter().collect();
                // warm (compile) + timed run
                let _ = registry.execute(name, &refs)?;
                let t0 = Instant::now();
                let out = registry.execute(name, &refs)?;
                println!(
                    "  {name}: out {:?} in {:?} (jax+pallas → HLO → PJRT)",
                    out[0].shape(),
                    t0.elapsed()
                );
            }
        }
        Err(_) => {
            println!(
                "no artifacts/ directory — run `make artifacts` to exercise \
                 the PJRT path (jax+pallas AOT)."
            );
        }
    }
    Ok(())
}
