//! End-to-end training driver (DESIGN.md deliverable (b)/E2E validation):
//! trains an RCP (M=3) tensorial CNN on the synthetic CIFAR-like task for
//! several hundred steps under all three execution modes, logging the loss
//! curve, per-epoch wall time and peak tape memory. Records the run in
//! `experiments/train_tnn.json` (referenced by EXPERIMENTS.md).
//!
//! Run: `cargo run --release --example train_tnn [-- --epochs 4 --steps 100]`

use conv_einsum::nn::{
    small_tnn_cnn, EvalConfig, Sgd, SyntheticImages, Trainer, TrainerConfig,
};
use conv_einsum::tnn::Decomp;
use conv_einsum::util::json::Json;
use conv_einsum::util::rng::Rng;

fn arg(name: &str, default: usize) -> usize {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let epochs = arg("--epochs", 4);
    let epoch_examples = arg("--steps", 96); // examples per epoch
    let batch = arg("--batch", 16);
    println!(
        "train_tnn: RCP(M=3) tensorial CNN, {} epochs x {} examples, batch {}\n",
        epochs, epoch_examples, batch
    );

    let mut results = Vec::new();
    for eval in [
        EvalConfig::conv_einsum(),
        EvalConfig::naive_ckpt(),
        EvalConfig::naive_no_ckpt(),
    ] {
        // Same seed everywhere: identical math, different time/memory.
        let mut rng = Rng::new(0xE2E);
        let mut model = small_tnn_cnn(
            Decomp::Cp,
            3,      // M=3 reshaping, as in the paper's RCP experiments
            0.5,    // CR 50%
            3,      // RGB input
            16,     // width
            3,      // depth
            3,      // 3x3 kernels
            10,     // classes
            eval,
            &mut rng,
        )
        .map_err(anyhow::Error::msg)?;
        let train = SyntheticImages::sized(3, 16, 16, 10, epoch_examples, 11);
        let evalds = SyntheticImages::sized(3, 16, 16, 10, epoch_examples / 2, 12);
        let mut trainer = Trainer::new(
            TrainerConfig {
                batch_size: batch,
                epochs,
                ..Default::default()
            },
            Sgd::paper_defaults(),
        );
        println!("--- mode: {} ({} params) ---", eval.label(), model.param_count());
        let stats = trainer.fit(&mut model, &train, &evalds);
        for s in &stats {
            println!(
                "  epoch {}: loss {:.4} acc {:.3} | eval acc {:.3} | train {:.2}s test {:.2}s | peak tape {}",
                s.epoch,
                s.train_loss,
                s.train_acc,
                s.eval_acc,
                s.train_time.as_secs_f64(),
                s.eval_time.as_secs_f64(),
                conv_einsum::util::human_bytes(s.peak_tape_bytes)
            );
        }
        let last = stats.last().unwrap();
        results.push(Json::obj(vec![
            ("mode", Json::str(eval.label())),
            (
                "loss_curve",
                Json::arr(stats.iter().map(|s| Json::num(s.train_loss as f64))),
            ),
            ("final_eval_acc", Json::num(last.eval_acc as f64)),
            (
                "train_secs_per_epoch",
                Json::arr(stats.iter().map(|s| Json::num(s.train_time.as_secs_f64()))),
            ),
            ("peak_tape_bytes", Json::num(last.peak_tape_bytes as f64)),
        ]));
        println!();
    }

    std::fs::create_dir_all("experiments")?;
    std::fs::write(
        "experiments/train_tnn.json",
        Json::obj(vec![
            ("workload", Json::str("RCP(M=3) CNN, synthetic CIFAR-like")),
            ("epochs", Json::num(epochs as f64)),
            ("examples_per_epoch", Json::num(epoch_examples as f64)),
            ("runs", Json::Arr(results)),
        ])
        .encode_pretty(),
    )?;
    println!("wrote experiments/train_tnn.json");
    Ok(())
}
