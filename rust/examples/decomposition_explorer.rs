//! Decomposition explorer: sweeps the TNN zoo (CP/TK/TT/TR/BT/HT, flat and
//! reshaped) across compression rates, reporting parameters, planned FLOPs
//! for optimal vs left-to-right evaluation, and the speedup — a practical
//! guide to which factorization benefits most from the optimal sequencer.
//!
//! Run: `cargo run --release --example decomposition_explorer`

use conv_einsum::experiments::Table;
use conv_einsum::planner::{contract_path, PlanOptions};
use conv_einsum::tnn::{build_layer, Decomp};
use conv_einsum::util::sci;

fn main() -> anyhow::Result<()> {
    let (t, s, k) = (64, 64, 3);
    let (batch, hp) = (32, 32);
    println!(
        "exploring tensorial factorizations of a {t}x{s}x{k}x{k} kernel on \
         {hp}x{hp} features (batch {batch})\n"
    );

    let mut rows = Vec::new();
    for decomp in Decomp::all() {
        for m in [1usize, 3] {
            if decomp == Decomp::HierarchicalTucker && m == 1 {
                continue;
            }
            for cr in [0.1, 0.5, 1.0] {
                let layer = match build_layer(decomp, m, t, s, k, k, cr) {
                    Ok(l) => l,
                    Err(e) => {
                        eprintln!("skip {} m={m} cr={cr}: {e}", decomp.name());
                        continue;
                    }
                };
                let dims = layer.expr_dims(batch, hp, hp);
                let plan = contract_path(&layer.expr, &dims, &PlanOptions::default())
                    .map_err(anyhow::Error::msg)?;
                rows.push(vec![
                    format!("{}{}", if m > 1 { "R" } else { "" }, decomp.name()),
                    format!("{m}"),
                    format!("{:.0}%", cr * 100.0),
                    format!("{}", layer.params),
                    sci(plan.cost),
                    sci(plan.naive_cost),
                    format!("{:.2}x", plan.speedup_vs_naive()),
                ]);
            }
        }
    }
    let table = Table {
        title: "TNN zoo: planned FLOPs, optimal vs left-to-right".into(),
        header: vec![
            "form".into(),
            "M".into(),
            "CR".into(),
            "params".into(),
            "optimal".into(),
            "naive".into(),
            "speedup".into(),
        ],
        rows,
    };
    println!("{}", table.render());
    table.save("decomposition_explorer").ok();
    Ok(())
}
