//! Experiment harnesses reproducing the paper's tables and figures
//! (DESIGN.md §4 experiment index). Each submodule produces the rows of one
//! artefact; the `rust/benches/` binaries print them and dump JSON under
//! `experiments/`.

pub mod memory;
pub mod runtime_sweep;
pub mod table2;

use crate::util::json::Json;

/// A printable experiment table.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(cell.len());
                }
            }
        }
        let mut out = format!("== {} ==\n", self.title);
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8) + 2))
                .collect::<String>()
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().map(|w| w + 2).sum::<usize>()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::str(&self.title)),
            (
                "header",
                Json::arr(self.header.iter().map(|h| Json::str(h.clone()))),
            ),
            (
                "rows",
                Json::arr(self.rows.iter().map(|r| {
                    Json::arr(r.iter().map(|c| Json::str(c.clone())))
                })),
            ),
        ])
    }

    /// Write the table's JSON to `experiments/<name>.json`.
    pub fn save(&self, name: &str) -> std::io::Result<()> {
        std::fs::create_dir_all("experiments")?;
        std::fs::write(
            format!("experiments/{name}.json"),
            self.to_json().encode_pretty(),
        )
    }
}
