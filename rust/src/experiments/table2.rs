//! Paper Table 2: FLOPs per CP convolutional layer in ResNet-34
//! (CR = 100%, batch 128) — left-to-right vs conv_einsum and the speedup.
//! Purely analytic (the tnn-cost model), so this reproduction is exact in
//! mechanism; absolute counts differ from the paper only through the rank
//! chosen by the CR solver.

use super::Table;
use crate::einsum::{parse, SizedSpec};
use crate::planner::{plan_with, PlanOptions, Strategy};
use crate::tnn::arch::{resnet34_imagenet, stages};
use crate::tnn::{build_layer, Decomp};
use crate::util::sci;

pub struct StageRow {
    pub stage: &'static str,
    pub ltr: f64,
    pub opt: f64,
}

pub fn rows(batch: usize) -> Vec<StageRow> {
    let sites = resnet34_imagenet();
    let mut out: Vec<StageRow> = Vec::new();
    for stage in stages(&sites) {
        let mut ltr = 0.0;
        let mut opt = 0.0;
        for site in sites.iter().filter(|s| s.stage == stage) {
            let layer = build_layer(Decomp::Cp, 1, site.t, site.s, site.h, site.w, 1.0)
                .expect("CP layer builds");
            let spec = parse(&layer.expr).unwrap();
            let mut dims = vec![vec![batch, site.s, site.hp, site.wp]];
            dims.extend(layer.factor_shapes.iter().cloned());
            let sized = SizedSpec::new(spec, dims).unwrap();
            let plan = plan_with(&sized, &PlanOptions::default()).unwrap();
            ltr += plan.naive_cost * site.count as f64;
            opt += plan.cost * site.count as f64;
        }
        out.push(StageRow { stage, ltr, opt });
    }
    out
}

pub fn run(batch: usize) -> Table {
    let rows = rows(batch);
    Table {
        title: format!(
            "Table 2: FLOPs per CP convolutional layer in ResNet-34 (CR=100%, batch {batch})"
        ),
        header: vec![
            "Layer".into(),
            "Left-to-Right".into(),
            "conv_einsum".into(),
            "Speedup x".into(),
        ],
        rows: rows
            .iter()
            .map(|r| {
                vec![
                    r.stage.to_string(),
                    sci(r.ltr),
                    sci(r.opt),
                    format!("{:.2}", r.ltr / r.opt),
                ]
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedups_positive_and_increase_with_depth() {
        // The paper's headline shape: conv_einsum wins at every stage and
        // the win grows toward the deep stages (3.9x ... 90x in Table 2),
        // because channel counts grow while feature maps shrink.
        let rows = rows(128);
        assert_eq!(rows.len(), 5);
        for r in &rows {
            assert!(r.ltr > r.opt, "{}: no win", r.stage);
        }
        let first = rows[1].ltr / rows[1].opt; // conv2_x
        let last = rows[4].ltr / rows[4].opt; // conv5_x
        assert!(
            last > first,
            "speedup should grow with depth: conv2_x {first:.1}x vs conv5_x {last:.1}x"
        );
    }

    #[test]
    fn table_renders() {
        let t = run(8);
        let s = t.render();
        assert!(s.contains("conv1"));
        assert!(s.contains("conv5_x"));
    }
}
