//! Measured-runtime sweeps: train/test seconds per epoch across
//! compression rates × the three execution modes, on scaled-down versions
//! of the paper's workloads. Drives Table 1, Figures 3–4 and Tables 5–6.

use super::Table;
use crate::nn::{
    Dataset, EvalConfig, Sequential, Sgd, SyntheticImages, SyntheticSequences, Trainer,
    TrainerConfig,
};
use crate::tnn::Decomp;
use crate::util::rng::Rng;

/// One measured cell of a runtime table.
#[derive(Debug, Clone)]
pub struct RuntimeCell {
    pub cr: f64,
    pub mode: &'static str,
    pub train_secs: f64,
    pub test_secs: f64,
    pub peak_tape_bytes: usize,
    pub eval_acc: f32,
}

/// The three execution modes compared throughout §5.
pub fn modes() -> [EvalConfig; 3] {
    [
        EvalConfig::conv_einsum(),
        EvalConfig::naive_ckpt(),
        EvalConfig::naive_no_ckpt(),
    ]
}

/// Which synthetic task a sweep runs on.
pub enum Workload {
    /// IC: CIFAR-like images through a small tensorial CNN.
    ImageClassification { size: usize, channels: usize },
    /// ASR: 1-D sequences through a Conformer-conv-like tensorial stack.
    SpeechRecognition { channels: usize, frames: usize },
}

/// Train one epoch per (CR, mode) and measure.
#[allow(clippy::too_many_arguments)]
pub fn sweep(
    workload: &Workload,
    decomp: Decomp,
    m: usize,
    crs: &[f64],
    batch: usize,
    epoch_examples: usize,
    depth: usize,
    width: usize,
) -> Vec<RuntimeCell> {
    let mut cells = Vec::new();
    for &cr in crs {
        for eval in modes() {
            let mut rng = Rng::new(0xC0DE ^ (cr * 1000.0) as u64);
            let (mut model, train_ds, eval_ds): (Sequential, Box<dyn Dataset>, Box<dyn Dataset>) =
                match workload {
                    Workload::ImageClassification { size, channels } => {
                        let model = crate::nn::model::small_tnn_cnn(
                            decomp, m, cr, *channels, width, depth, 3, 10, eval, &mut rng,
                        )
                        .expect("model builds");
                        (
                            model,
                            Box::new(SyntheticImages::sized(
                                *channels,
                                *size,
                                *size,
                                10,
                                epoch_examples,
                                1,
                            )),
                            Box::new(SyntheticImages::sized(
                                *channels,
                                *size,
                                *size,
                                10,
                                epoch_examples / 2,
                                2,
                            )),
                        )
                    }
                    Workload::SpeechRecognition { channels, frames } => {
                        // 1-D temporal convolution: kernel 3x1 over [B,C,T,1]
                        let model = crate::nn::model::small_tnn_cnn_hw(
                            decomp, m, cr, *channels, width, depth, 3, 1, 10, eval, &mut rng,
                        )
                        .expect("model builds");
                        (
                            model,
                            Box::new(SyntheticSequences::librispeech_like(
                                *channels,
                                *frames,
                                epoch_examples,
                                3,
                            )),
                            Box::new(SyntheticSequences::librispeech_like(
                                *channels,
                                *frames,
                                epoch_examples / 2,
                                4,
                            )),
                        )
                    }
                };
            let mut trainer = Trainer::new(
                TrainerConfig {
                    batch_size: batch,
                    epochs: 1,
                    ..Default::default()
                },
                Sgd::paper_defaults(),
            );
            let (loss, _acc, train_time, peak) = trainer.train_epoch(&mut model, &*train_ds, 0);
            let (_eloss, eacc, eval_time) = trainer.eval_epoch(&mut model, &*eval_ds);
            let _ = loss;
            cells.push(RuntimeCell {
                cr,
                mode: eval.label(),
                train_secs: train_time.as_secs_f64(),
                test_secs: eval_time.as_secs_f64(),
                peak_tape_bytes: peak,
                eval_acc: eacc,
            });
        }
    }
    cells
}

/// Render cells as a paper-style table (rows = CR, column groups = modes).
pub fn render(title: &str, cells: &[RuntimeCell]) -> Table {
    let mut crs: Vec<f64> = cells.iter().map(|c| c.cr).collect();
    crs.dedup();
    let mode_names: Vec<&str> = {
        let mut v = Vec::new();
        for c in cells {
            if !v.contains(&c.mode) {
                v.push(c.mode);
            }
        }
        v
    };
    let mut header = vec!["CR".to_string()];
    for m in &mode_names {
        header.push(format!("{m} train(s)"));
        header.push(format!("{m} test(s)"));
    }
    let mut rows = Vec::new();
    for &cr in &crs {
        let mut row = vec![format!("{:.0}%", cr * 100.0)];
        for m in &mode_names {
            if let Some(c) = cells.iter().find(|c| c.cr == cr && &c.mode == m) {
                row.push(format!("{:.2}", c.train_secs));
                row.push(format!("{:.2}", c.test_secs));
            } else {
                row.push("-".into());
                row.push("-".into());
            }
        }
        rows.push(row);
    }
    Table {
        title: title.to_string(),
        header,
        rows,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_sweep_produces_cells() {
        let cells = sweep(
            &Workload::ImageClassification {
                size: 8,
                channels: 1,
            },
            Decomp::Cp,
            1,
            &[0.5],
            4,
            8,
            1,
            4,
        );
        assert_eq!(cells.len(), 3); // one per mode
        assert!(cells.iter().all(|c| c.train_secs > 0.0));
        let t = render("test", &cells);
        assert!(t.render().contains("conv_einsum"));
    }
}
