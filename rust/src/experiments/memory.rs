//! Paper Table 3: maximal batch size under a memory budget, per execution
//! mode and compression rate. The peak live bytes of a training step
//! (forward tape + cotangents) are measured with the [`MemoryMeter`];
//! the max batch is found by doubling + binary search against the budget.

use super::Table;
use crate::autodiff::{MemoryMeter, PathAutodiff};
use crate::einsum::{parse, SizedSpec};
use crate::exec::TrainWorkspace;
use crate::nn::EvalConfig;
use crate::planner::{plan_with, PlanOptions};
use crate::tensor::Tensor;
use crate::tnn::{build_layer, Decomp, TnnLayerSpec};
use crate::util::rng::Rng;

/// Peak training-step bytes for one tensorial layer at batch `b`.
pub fn peak_bytes(spec: &TnnLayerSpec, eval: EvalConfig, b: usize, hp: usize, wp: usize) -> usize {
    let mut rng = Rng::new(17);
    let factors = spec.init_factors(&mut rng);
    let x = Tensor::rand(&spec.input_shape(b, hp, wp), -1.0, 1.0, &mut rng);
    let parsed = parse(&spec.expr).unwrap();
    let mut dims = vec![x.shape().to_vec()];
    dims.extend(factors.iter().map(|f| f.shape().to_vec()));
    let sized = SizedSpec::new(parsed, dims).unwrap();
    let plan = plan_with(
        &sized,
        &PlanOptions {
            strategy: eval.strategy,
            training: eval.training_cost_model,
            ..Default::default()
        },
    )
    .unwrap();
    let ad = PathAutodiff::new(&plan).unwrap();
    let meter = MemoryMeter::new();
    let mut ws = TrainWorkspace::new();
    let mut inputs: Vec<&Tensor> = vec![&x];
    inputs.extend(factors.iter());
    let _ = ad
        .forward_backward(
            &inputs,
            |o| Tensor::full(o.shape(), 1.0),
            eval.ckpt,
            &mut ws,
            &meter,
        )
        .unwrap();
    meter.peak_bytes()
}

/// Largest batch size whose peak stays within `budget_bytes` (0 if none).
pub fn max_batch(
    spec: &TnnLayerSpec,
    eval: EvalConfig,
    hp: usize,
    wp: usize,
    budget_bytes: usize,
    cap: usize,
) -> usize {
    if peak_bytes(spec, eval, 1, hp, wp) > budget_bytes {
        return 0;
    }
    let mut lo = 1usize;
    let mut hi = 2usize;
    while hi <= cap && peak_bytes(spec, eval, hi, hp, wp) <= budget_bytes {
        lo = hi;
        hi *= 2;
    }
    hi = hi.min(cap + 1);
    while lo + 1 < hi {
        let mid = (lo + hi) / 2;
        if peak_bytes(spec, eval, mid, hp, wp) <= budget_bytes {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Build the Table-3-style report for a layer family across CRs and modes.
pub fn table3(
    title: &str,
    decomp: Decomp,
    m: usize,
    t: usize,
    s: usize,
    k: usize,
    hp: usize,
    wp: usize,
    crs: &[f64],
    budget_bytes: usize,
) -> Table {
    let modes = [
        ("conv_einsum", EvalConfig::conv_einsum()),
        ("naive w/ ckpt", EvalConfig::naive_ckpt()),
        ("naive w/o ckpt", EvalConfig::naive_no_ckpt()),
    ];
    let mut rows = Vec::new();
    for &cr in crs {
        let spec = build_layer(decomp, m, t, s, k, k, cr).expect("layer builds");
        let mut row = vec![format!("{:.0}%", cr * 100.0)];
        for (_, eval) in &modes {
            row.push(max_batch(&spec, *eval, hp, wp, budget_bytes, 512).to_string());
        }
        rows.push(row);
    }
    Table {
        title: title.to_string(),
        header: vec![
            "CR".into(),
            "conv_einsum".into(),
            "naive w/ ckpt".into(),
            "naive w/o ckpt".into(),
        ],
        rows,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_grows_with_batch() {
        let spec = build_layer(Decomp::Cp, 2, 8, 8, 3, 3, 1.0).unwrap();
        let p1 = peak_bytes(&spec, EvalConfig::conv_einsum(), 1, 8, 8);
        let p4 = peak_bytes(&spec, EvalConfig::conv_einsum(), 4, 8, 8);
        assert!(p4 > p1);
    }

    #[test]
    fn conv_einsum_allows_largest_batch() {
        // The paper's Table 3 shape: conv_einsum ≥ naive w/ ckpt ≥ naive w/o.
        let spec = build_layer(Decomp::Cp, 3, 16, 16, 3, 3, 1.0).unwrap();
        let budget = 4 * 1024 * 1024;
        let ce = max_batch(&spec, EvalConfig::conv_einsum(), 12, 12, budget, 256);
        let nc = max_batch(&spec, EvalConfig::naive_ckpt(), 12, 12, budget, 256);
        let nn = max_batch(&spec, EvalConfig::naive_no_ckpt(), 12, 12, budget, 256);
        assert!(ce >= nc, "conv_einsum {ce} < naive ckpt {nc}");
        assert!(nc >= nn, "naive ckpt {nc} < naive no-ckpt {nn}");
        assert!(ce > nn, "no separation at all: {ce} vs {nn}");
    }

    #[test]
    fn zero_when_budget_too_small() {
        let spec = build_layer(Decomp::Cp, 1, 8, 8, 3, 3, 1.0).unwrap();
        assert_eq!(
            max_batch(&spec, EvalConfig::naive_no_ckpt(), 16, 16, 1024, 64),
            0
        );
    }
}
