//! Unit tests for the conv_einsum grammar: parsing, classification,
//! validation, rendering, and sized-spec semantics. Strings are taken
//! directly from the paper (§2.1–§2.3, Appendix A.3).

use super::*;

fn ids(spec: &EinsumSpec, names: &[&str]) -> Vec<ModeId> {
    names.iter().map(|n| spec.modes.get(n).unwrap()).collect()
}

#[test]
fn parse_simple_contraction() {
    // Paper §2.1: T = einsum("bci,bcj->bij", T1, T2)
    let s = parse("bci,bcj->bij").unwrap();
    assert_eq!(s.n_inputs(), 2);
    assert!(s.conv.is_empty());
    let c = s.modes.get("c").unwrap();
    let b = s.modes.get("b").unwrap();
    let i = s.modes.get("i").unwrap();
    assert_eq!(s.kind(c), ModeKind::Contraction);
    assert_eq!(s.kind(b), ModeKind::Batch);
    assert_eq!(s.kind(i), ModeKind::Free);
}

#[test]
fn parse_conv_mode() {
    // Paper §2.2: conv_einsum("xbc,ade->xbcde|x", T1, T2)
    // (the paper writes the conv mode as `x` on both inputs)
    let s = parse("xbc,xde->xbcde|x").unwrap();
    let x = s.modes.get("x").unwrap();
    assert_eq!(s.kind(x), ModeKind::Convolution);
    assert_eq!(s.conv, vec![x]);
}

#[test]
fn parse_interleaved_group_conv() {
    // Paper Eq. (2): conv_einsum("bfshw,fghw,sthw->bgthw|hw", X, K1, K2)
    let s = parse("bfshw,fghw,sthw->bgthw|hw").unwrap();
    assert_eq!(s.n_inputs(), 3);
    let h = s.modes.get("h").unwrap();
    let w = s.modes.get("w").unwrap();
    assert_eq!(s.conv, vec![h, w]);
    assert_eq!(s.occurrences(h), 3); // multi-way convolution
    let f = s.modes.get("f").unwrap();
    assert_eq!(s.kind(f), ModeKind::Contraction);
}

#[test]
fn parse_pipe_comma_form() {
    // §3.1 writes conv2d as "...->bgthw|h,w" — comma-separated conv list.
    let a = parse("gtshw,bgshw->bgthw|h,w").unwrap();
    let b = parse("gtshw,bgshw->bgthw|hw").unwrap();
    assert_eq!(a.conv.len(), 2);
    assert_eq!(a.conv, b.conv);
}

#[test]
fn parse_multichar_modes() {
    // Paper §2.3 RCP layer string.
    let s =
        parse("b(s1)(s2)(s3)hw,r(t1)(s1),r(t2)(s2),r(t3)(s3),rhw->b(t1)(t2)(t3)hw|hw").unwrap();
    assert_eq!(s.n_inputs(), 5);
    let t1 = s.modes.get("t1").unwrap();
    assert_eq!(s.kind(t1), ModeKind::Free);
    let s1 = s.modes.get("s1").unwrap();
    assert_eq!(s.kind(s1), ModeKind::Contraction);
    let r = s.modes.get("r").unwrap();
    assert_eq!(s.kind(r), ModeKind::Contraction);
    // Round-trip rendering.
    assert_eq!(
        s.render(),
        "b(s1)(s2)(s3)hw,r(t1)(s1),r(t2)(s2),r(t3)(s3),rhw->b(t1)(t2)(t3)hw|hw"
    );
}

#[test]
fn parse_whitespace_insensitive() {
    let a = parse(" b s h w , t s h w -> b t h w | h w ").unwrap();
    let b = parse("bshw,tshw->bthw|hw").unwrap();
    assert_eq!(a.render(), b.render());
}

#[test]
fn self_sum_mode_classified() {
    // 'k' appears only in input 0 and not the output: case (5) of §3.1.
    let s = parse("ak,ab->b").unwrap();
    let k = s.modes.get("k").unwrap();
    assert_eq!(s.kind(k), ModeKind::SelfSum);
    let a = s.modes.get("a").unwrap();
    assert_eq!(s.kind(a), ModeKind::Contraction);
}

#[test]
fn reject_missing_arrow() {
    assert!(parse("ab,bc").is_err());
}

#[test]
fn reject_unknown_output_mode() {
    assert!(parse("ab,bc->az").is_err());
}

#[test]
fn reject_conv_mode_not_in_output() {
    // conv mode must appear in the output (it produces an axis).
    assert!(parse("ah,bh->ab|h").is_err());
}

#[test]
fn reject_duplicate_mode_within_tensor() {
    assert!(parse("aa->a").is_err());
}

#[test]
fn reject_bad_characters() {
    assert!(parse("a$b,bc->ac").is_err());
    assert!(parse("a(b,bc->ac").is_err());
    assert!(parse("a()b->ab").is_err());
}

#[test]
fn fig1_string_parses() {
    // Figure 1a: conv_einsum.contract_path("ijk,jl,lmq,njpq->ijknp|j", A,B,C,D)
    let s = parse("ijk,jl,lmq,njpq->ijknp|j").unwrap();
    assert_eq!(s.n_inputs(), 4);
    let j = s.modes.get("j").unwrap();
    assert_eq!(s.kind(j), ModeKind::Convolution);
    assert_eq!(s.occurrences(j), 3);
}

#[test]
fn sized_spec_standard_conv_layer() {
    // §2.3: Y = conv_einsum("bshw,tshw->bthw|hw", X, W)
    let spec = parse("bshw,tshw->bthw|hw").unwrap();
    let sized = SizedSpec::new(spec, vec![vec![8, 3, 32, 32], vec![16, 3, 5, 5]]).unwrap();
    // Default variety for 2-input conv is Same → output spatial = feature.
    assert_eq!(sized.output_shape(), vec![8, 16, 32, 32]);
    let h = sized.spec.modes.get("h").unwrap();
    assert_eq!(sized.conv_feature_size(h), 32);
    assert_eq!(sized.occurrence_sizes(h), vec![32, 5]);
}

#[test]
fn sized_spec_full_conv_matches_eq1() {
    // Eq. (1): standard convolution yields X' = X + L − 1.
    let spec = parse("xbc,xde->xbcde|x").unwrap();
    let sized = SizedSpec::with_kinds(
        spec,
        vec![vec![10, 2, 3], vec![4, 5, 6]],
        vec![ConvKind::Full],
    )
    .unwrap();
    assert_eq!(sized.output_shape(), vec![13, 2, 3, 5, 6]); // 10+4-1
}

#[test]
fn sized_spec_rejects_inconsistent_contraction() {
    let spec = parse("ab,bc->ac").unwrap();
    assert!(SizedSpec::new(spec, vec![vec![2, 3], vec![4, 5]]).is_err());
}

#[test]
fn sized_spec_rejects_wrong_arity() {
    let spec = parse("ab,bc->ac").unwrap();
    assert!(SizedSpec::new(spec.clone(), vec![vec![2, 3]]).is_err());
    assert!(SizedSpec::new(spec, vec![vec![2], vec![3, 4]]).is_err());
}

#[test]
fn sized_spec_conv_modes_may_differ_in_size() {
    // "the same letter x is used for different modes, even if their
    //  dimension sizes may differ" (§2.2).
    let spec = parse("xa,xb->xab|x").unwrap();
    let sized = SizedSpec::new(spec, vec![vec![32, 2], vec![5, 3]]).unwrap();
    assert_eq!(sized.output_shape(), vec![32, 2, 3]);
}

#[test]
fn sized_spec_multiway_requires_circular() {
    let spec = parse("bfshw,fghw,sthw->bgthw|hw").unwrap();
    let dims = vec![
        vec![2, 3, 4, 16, 16],
        vec![3, 5, 3, 3],
        vec![4, 6, 3, 3],
    ];
    // Default (multi-way → circular) is accepted.
    let ok = SizedSpec::new(spec.clone(), dims.clone()).unwrap();
    let h = ok.spec.modes.get("h").unwrap();
    assert_eq!(ok.conv_kind(h), ConvKind::Circular);
    // Forcing Same on a 3-way conv mode is rejected.
    assert!(SizedSpec::with_kinds(spec, dims, vec![ConvKind::Same, ConvKind::Same]).is_err());
}

#[test]
fn conv_kind_out_dims() {
    assert_eq!(ConvKind::Full.out_dim(10, 4), 13);
    assert_eq!(ConvKind::Valid.out_dim(10, 4), 7);
    assert_eq!(ConvKind::Same.out_dim(10, 4), 10);
    assert_eq!(ConvKind::Circular.out_dim(10, 4), 10);
    // Symmetric in argument order (feature = max).
    assert_eq!(ConvKind::Full.out_dim(4, 10), 13);
}

#[test]
fn all_modes_enumeration() {
    let s = parse("ab,bc->ac").unwrap();
    let all = s.all_modes();
    assert_eq!(all.len(), 3);
    assert_eq!(ids(&s, &["a", "b", "c"]), all);
}

#[test]
fn render_multichar_parenthesizes() {
    let s = parse("(r1)t,(r2)s,(r1)(r2)hw->tshw").unwrap();
    assert_eq!(s.render(), "(r1)t,(r2)s,(r1)(r2)hw->tshw");
}
