//! The conv_einsum grammar (paper §2): einsum strings extended with
//! multi-character modes `(t1)` and a pipe-delimited convolution mode list,
//! e.g. `"b(s1)(s2)hw,r(t1)(s1),r(t2)(s2),rhw->b(t1)(t2)hw|hw"`.
//!
//! [`EinsumSpec`] is the parsed, size-free form; [`SizedSpec`] binds concrete
//! dimension sizes to every mode occurrence (convolution modes may carry
//! *different* sizes per occurrence — feature vs filter).

mod parse;
mod spec;

pub use parse::{parse, ParseError};
pub use spec::{ConvKind, EinsumSpec, ModeId, ModeKind, ModeTable, SizedSpec};

#[cfg(test)]
mod tests;
