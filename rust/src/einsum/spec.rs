//! Core spec types for conv_einsum expressions.

use std::collections::HashMap;
use std::fmt;

/// Identifier of a mode (an index into the expression's [`ModeTable`]).
pub type ModeId = u32;

/// Interned mode names for one expression. Single-letter modes (`b`) and
/// parenthesized multi-character modes (`(t1)`) share this table.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ModeTable {
    names: Vec<String>,
    map: HashMap<String, ModeId>,
}

impl ModeTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Intern `name`, returning its id.
    pub fn intern(&mut self, name: &str) -> ModeId {
        if let Some(&id) = self.map.get(name) {
            return id;
        }
        let id = self.names.len() as ModeId;
        self.names.push(name.to_string());
        self.map.insert(name.to_string(), id);
        id
    }

    /// Look up an existing mode by name.
    pub fn get(&self, name: &str) -> Option<ModeId> {
        self.map.get(name).copied()
    }

    /// Name of mode `id`.
    pub fn name(&self, id: ModeId) -> &str {
        &self.names[id as usize]
    }

    /// Number of distinct modes.
    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// Render a mode list back to subscript syntax: multi-char modes get
    /// parens, single chars don't.
    pub fn render(&self, modes: &[ModeId]) -> String {
        let mut s = String::new();
        for &m in modes {
            let name = self.name(m);
            if name.chars().count() == 1 {
                s.push_str(name);
            } else {
                s.push('(');
                s.push_str(name);
                s.push(')');
            }
        }
        s
    }
}

/// The role a mode plays in a (sub)expression, following the paper's §2.1 /
/// §3.1 taxonomy.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModeKind {
    /// Appears in ≥2 inputs, not in the output: summed out.
    Contraction,
    /// Appears in ≥2 inputs and in the output ("filter group" in conv1d).
    Batch,
    /// Appears in exactly one input and in the output.
    Free,
    /// Appears in exactly one input and not in the output: pre-summed
    /// (paper §3.1 case 5, "self-contraction").
    SelfSum,
    /// Listed after the pipe: convolved across its occurrences.
    Convolution,
}

/// Boundary handling for a convolution mode. The paper's framework supports
/// several "convolution varieties" (Appendix B): multi-way convolutions are
/// restricted to circular padding; 2-input convolutions may be any variety.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ConvKind {
    /// Circular (periodic) convolution modulo the feature length. The only
    /// variety that is commutative/associative, hence the only one allowed
    /// for multi-way (>2 occurrence) convolution modes.
    Circular,
    /// Zero-padded, output length = feature length (the standard NN
    /// "same" convolution; paper's default for layers).
    Same,
    /// No padding: output length = feature − filter + 1.
    Valid,
    /// Full convolution: output length = feature + filter − 1
    /// (the paper's `X' = X + L − 1` standard convolution, Eq. 1).
    Full,
}

impl ConvKind {
    /// Output dimension for a pairwise convolution of lengths `a`, `b`
    /// (feature = max, filter = min).
    pub fn out_dim(self, a: usize, b: usize) -> usize {
        let feat = a.max(b);
        let filt = a.min(b);
        match self {
            ConvKind::Circular | ConvKind::Same => feat,
            ConvKind::Valid => feat - filt + 1,
            ConvKind::Full => feat + filt - 1,
        }
    }
}

impl fmt::Display for ConvKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            ConvKind::Circular => "circular",
            ConvKind::Same => "same",
            ConvKind::Valid => "valid",
            ConvKind::Full => "full",
        };
        f.write_str(s)
    }
}

/// A parsed conv_einsum expression (shape-free).
#[derive(Clone, Debug, PartialEq)]
pub struct EinsumSpec {
    /// Interned mode names.
    pub modes: ModeTable,
    /// Ordered mode list per input tensor.
    pub inputs: Vec<Vec<ModeId>>,
    /// Ordered mode list of the output tensor.
    pub output: Vec<ModeId>,
    /// Modes listed after the pipe (convolution modes), in pipe order.
    pub conv: Vec<ModeId>,
}

impl EinsumSpec {
    /// Number of input tensors.
    pub fn n_inputs(&self) -> usize {
        self.inputs.len()
    }

    /// Is `m` a convolution mode?
    pub fn is_conv(&self, m: ModeId) -> bool {
        self.conv.contains(&m)
    }

    /// Number of inputs in which mode `m` occurs.
    pub fn occurrences(&self, m: ModeId) -> usize {
        self.inputs
            .iter()
            .filter(|modes| modes.contains(&m))
            .count()
    }

    /// Classify a mode per the paper's taxonomy (see [`ModeKind`]).
    pub fn kind(&self, m: ModeId) -> ModeKind {
        if self.is_conv(m) {
            return ModeKind::Convolution;
        }
        let occ = self.occurrences(m);
        let in_out = self.output.contains(&m);
        match (occ, in_out) {
            (0 | 1, false) => ModeKind::SelfSum,
            (0 | 1, true) => ModeKind::Free,
            (_, true) => ModeKind::Batch,
            (_, false) => ModeKind::Contraction,
        }
    }

    /// All distinct modes used anywhere in the expression.
    pub fn all_modes(&self) -> Vec<ModeId> {
        let mut seen = vec![false; self.modes.len()];
        let mut out = Vec::new();
        for modes in self.inputs.iter().chain(std::iter::once(&self.output)) {
            for &m in modes {
                if !seen[m as usize] {
                    seen[m as usize] = true;
                    out.push(m);
                }
            }
        }
        out
    }

    /// Render the expression back to conv_einsum string syntax.
    pub fn render(&self) -> String {
        let mut s = String::new();
        for (i, input) in self.inputs.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&self.modes.render(input));
        }
        s.push_str("->");
        s.push_str(&self.modes.render(&self.output));
        if !self.conv.is_empty() {
            s.push('|');
            s.push_str(&self.modes.render(&self.conv));
        }
        s
    }

    /// Structural validation that does not need sizes: conv modes must
    /// appear in the output and in at least one input; output modes must
    /// come from some input; no duplicate modes within a single tensor.
    pub fn validate(&self) -> Result<(), String> {
        for (i, modes) in self.inputs.iter().enumerate() {
            let mut seen = std::collections::HashSet::new();
            for &m in modes {
                if !seen.insert(m) {
                    return Err(format!(
                        "input {} repeats mode '{}' (diagonals are unsupported)",
                        i,
                        self.modes.name(m)
                    ));
                }
            }
        }
        {
            let mut seen = std::collections::HashSet::new();
            for &m in &self.output {
                if !seen.insert(m) {
                    return Err(format!(
                        "output repeats mode '{}'",
                        self.modes.name(m)
                    ));
                }
            }
        }
        for &m in &self.output {
            if self.occurrences(m) == 0 {
                return Err(format!(
                    "output mode '{}' does not appear in any input",
                    self.modes.name(m)
                ));
            }
        }
        for &m in &self.conv {
            if !self.output.contains(&m) {
                return Err(format!(
                    "convolution mode '{}' must appear in the output",
                    self.modes.name(m)
                ));
            }
            if self.occurrences(m) == 0 {
                return Err(format!(
                    "convolution mode '{}' does not appear in any input",
                    self.modes.name(m)
                ));
            }
        }
        Ok(())
    }
}

impl fmt::Display for EinsumSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.render())
    }
}

/// An [`EinsumSpec`] with concrete dimension sizes bound to every input
/// mode occurrence, plus the convolution variety per conv mode.
#[derive(Clone, Debug, PartialEq)]
pub struct SizedSpec {
    pub spec: EinsumSpec,
    /// dims[i][j] = size of the j-th mode of input i.
    pub dims: Vec<Vec<usize>>,
    /// Convolution variety per entry of `spec.conv` (parallel array).
    pub conv_kinds: Vec<ConvKind>,
}

impl SizedSpec {
    /// Bind sizes with default convolution varieties: `Same` for conv modes
    /// occurring in exactly two inputs, `Circular` for multi-way.
    pub fn new(spec: EinsumSpec, dims: Vec<Vec<usize>>) -> Result<SizedSpec, String> {
        let conv_kinds = spec
            .conv
            .iter()
            .map(|&m| {
                if spec.occurrences(m) > 2 {
                    ConvKind::Circular
                } else {
                    ConvKind::Same
                }
            })
            .collect();
        Self::with_kinds(spec, dims, conv_kinds)
    }

    /// Bind sizes with explicit convolution varieties.
    pub fn with_kinds(
        spec: EinsumSpec,
        dims: Vec<Vec<usize>>,
        conv_kinds: Vec<ConvKind>,
    ) -> Result<SizedSpec, String> {
        spec.validate()?;
        if dims.len() != spec.inputs.len() {
            return Err(format!(
                "expected {} dim lists, got {}",
                spec.inputs.len(),
                dims.len()
            ));
        }
        for (i, (modes, sizes)) in spec.inputs.iter().zip(dims.iter()).enumerate() {
            if modes.len() != sizes.len() {
                return Err(format!(
                    "input {}: {} modes but {} dims",
                    i,
                    modes.len(),
                    sizes.len()
                ));
            }
            if sizes.iter().any(|&d| d == 0) {
                return Err(format!("input {}: zero-sized dimension", i));
            }
        }
        if conv_kinds.len() != spec.conv.len() {
            return Err(format!(
                "expected {} conv kinds, got {}",
                spec.conv.len(),
                conv_kinds.len()
            ));
        }
        let sized = SizedSpec {
            spec,
            dims,
            conv_kinds,
        };
        // Non-conv shared modes must agree in size everywhere.
        for &m in &sized.spec.all_modes() {
            if sized.spec.is_conv(m) {
                // Multi-way circular conv additionally requires that the
                // "feature" (max) size is consistent; filters just need to
                // be no larger than the feature. Nothing to check here.
                continue;
            }
            let sizes = sized.occurrence_sizes(m);
            if sizes.windows(2).any(|w| w[0] != w[1]) {
                return Err(format!(
                    "mode '{}' has inconsistent sizes {:?}",
                    sized.spec.modes.name(m),
                    sizes
                ));
            }
        }
        // Valid convolution requires feature ≥ filter (guaranteed) and a
        // positive output dim (guaranteed by out_dim formula); Full/Valid
        // only make sense for 2-occurrence modes.
        for (idx, &m) in sized.spec.conv.iter().enumerate() {
            let occ = sized.spec.occurrences(m);
            if occ > 2 && sized.conv_kinds[idx] != ConvKind::Circular {
                return Err(format!(
                    "multi-way convolution mode '{}' requires circular padding \
                     (paper Appendix B, Convolution Varieties)",
                    sized.spec.modes.name(m)
                ));
            }
        }
        Ok(sized)
    }

    /// Sizes of mode `m` across inputs that contain it (in input order).
    pub fn occurrence_sizes(&self, m: ModeId) -> Vec<usize> {
        let mut out = Vec::new();
        for (modes, sizes) in self.spec.inputs.iter().zip(self.dims.iter()) {
            if let Some(pos) = modes.iter().position(|&x| x == m) {
                out.push(sizes[pos]);
            }
        }
        out
    }

    /// Size of non-conv mode `m` (consistent across occurrences).
    pub fn mode_size(&self, m: ModeId) -> usize {
        self.occurrence_sizes(m)[0]
    }

    /// For a conv mode, the "feature" size: the max across occurrences.
    /// This is the size that circular convolution wraps modulo, and the
    /// output size for Same/Circular varieties.
    pub fn conv_feature_size(&self, m: ModeId) -> usize {
        self.occurrence_sizes(m).into_iter().max().unwrap()
    }

    /// Variety of conv mode `m`.
    pub fn conv_kind(&self, m: ModeId) -> ConvKind {
        let idx = self.spec.conv.iter().position(|&x| x == m).unwrap();
        self.conv_kinds[idx]
    }

    /// The output shape implied by the sizes and conv varieties. For a conv
    /// mode with >2 occurrences the output is the feature size (circular);
    /// for 2 occurrences it follows the variety's `out_dim`; for 1
    /// occurrence the mode passes through unchanged.
    pub fn output_shape(&self) -> Vec<usize> {
        self.spec
            .output
            .iter()
            .map(|&m| {
                if self.spec.is_conv(m) {
                    let sizes = self.occurrence_sizes(m);
                    match sizes.len() {
                        1 => sizes[0],
                        2 => self.conv_kind(m).out_dim(sizes[0], sizes[1]),
                        _ => self.conv_feature_size(m),
                    }
                } else {
                    self.mode_size(m)
                }
            })
            .collect()
    }

    /// Number of elements of input `i`.
    pub fn input_elems(&self, i: usize) -> usize {
        self.dims[i].iter().product()
    }

    /// Number of elements of the output.
    pub fn output_elems(&self) -> usize {
        self.output_shape().iter().product()
    }
}

impl fmt::Display for SizedSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} dims={:?}", self.spec.render(), self.dims)
    }
}
