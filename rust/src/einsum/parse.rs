//! Parser for the conv_einsum string grammar.
//!
//! ```text
//! expr      := subscripts ("," subscripts)* "->" subscripts conv?
//! conv      := "|" mode (","? mode)*
//! subscripts:= mode*
//! mode      := LETTER | "(" NAME ")"
//! ```
//!
//! Whitespace is ignored everywhere. Mode names are case-sensitive; `(t1)`
//! and `t` are distinct modes. The convolution list accepts both the
//! paper's juxtaposed form `|hw` and comma form `|h,w`.

use super::spec::{EinsumSpec, ModeTable};
use std::fmt;

/// Error produced while parsing a conv_einsum string.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "conv_einsum parse error at char {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a conv_einsum string such as `"bshw,rt,rs,rh,rw->bthw|hw"`.
pub fn parse(input: &str) -> Result<EinsumSpec, ParseError> {
    let chars: Vec<char> = input.chars().collect();
    let mut modes = ModeTable::new();

    // Split at "->" first (required — implicit-output einsum is not part of
    // the paper's grammar and is rejected explicitly).
    let arrow = find_arrow(&chars).ok_or_else(|| ParseError {
        pos: input.len(),
        msg: "missing '->' (conv_einsum requires an explicit output)".to_string(),
    })?;

    let lhs = &chars[..arrow];
    let rhs = &chars[arrow + 2..];

    // rhs = output [ '|' convlist ]
    let pipe = rhs.iter().position(|&c| c == '|');
    let (out_part, conv_part) = match pipe {
        Some(p) => (&rhs[..p], Some(&rhs[p + 1..])),
        None => (rhs, None),
    };

    let mut inputs = Vec::new();
    for segment in split_commas(lhs) {
        let (seg, offset) = segment;
        let parsed = parse_subscripts(seg, offset, &mut modes)?;
        inputs.push(parsed);
    }
    if inputs.is_empty() || inputs.iter().any(|v| v.is_empty()) {
        // An empty subscript list is legal einsum (a scalar) but every layer
        // expression in the paper has non-scalar inputs; still allow scalars
        // only when explicitly written as "->...": reject empty inputs that
        // came from stray commas.
        if inputs.is_empty() {
            return Err(ParseError {
                pos: 0,
                msg: "no input subscripts".to_string(),
            });
        }
    }

    let output = parse_subscripts(out_part, arrow + 2, &mut modes)?;

    let mut conv = Vec::new();
    if let Some(cp) = conv_part {
        let base = arrow + 2 + out_part.len() + 1;
        for (seg, offset) in split_commas(cp) {
            let ms = parse_subscripts(seg, base + offset, &mut modes)?;
            conv.extend(ms);
        }
        if conv.is_empty() {
            return Err(ParseError {
                pos: base,
                msg: "empty convolution list after '|'".to_string(),
            });
        }
        let mut dedup = std::collections::HashSet::new();
        for &m in &conv {
            if !dedup.insert(m) {
                return Err(ParseError {
                    pos: base,
                    msg: format!("duplicate convolution mode '{}'", modes.name(m)),
                });
            }
        }
    }

    let spec = EinsumSpec {
        modes,
        inputs,
        output,
        conv,
    };
    spec.validate().map_err(|msg| ParseError { pos: 0, msg })?;
    Ok(spec)
}

/// Find the index of the `->` token.
fn find_arrow(chars: &[char]) -> Option<usize> {
    chars
        .windows(2)
        .position(|w| w[0] == '-' && w[1] == '>')
}

/// Split a char slice at top-level commas, yielding (segment, start offset).
fn split_commas(chars: &[char]) -> Vec<(&[char], usize)> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut depth = 0usize;
    for (i, &c) in chars.iter().enumerate() {
        match c {
            '(' => depth += 1,
            ')' => depth = depth.saturating_sub(1),
            ',' if depth == 0 => {
                out.push((&chars[start..i], start));
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push((&chars[start..], start));
    out
}

/// Parse one subscript group (`b(s1)(s2)hw`) into mode ids.
fn parse_subscripts(
    chars: &[char],
    base: usize,
    modes: &mut ModeTable,
) -> Result<Vec<super::spec::ModeId>, ParseError> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        if c == '(' {
            let close = chars[i + 1..]
                .iter()
                .position(|&c| c == ')')
                .ok_or_else(|| ParseError {
                    pos: base + i,
                    msg: "unclosed '('".to_string(),
                })?;
            let name: String = chars[i + 1..i + 1 + close]
                .iter()
                .filter(|c| !c.is_whitespace())
                .collect();
            if name.is_empty() {
                return Err(ParseError {
                    pos: base + i,
                    msg: "empty mode name '()'".to_string(),
                });
            }
            if !name.chars().all(|c| c.is_alphanumeric() || c == '_') {
                return Err(ParseError {
                    pos: base + i,
                    msg: format!("invalid mode name '({})'", name),
                });
            }
            out.push(modes.intern(&name));
            i += close + 2;
        } else if c.is_alphabetic() {
            out.push(modes.intern(&c.to_string()));
            i += 1;
        } else {
            return Err(ParseError {
                pos: base + i,
                msg: format!("unexpected character '{}'", c),
            });
        }
    }
    Ok(out)
}
