//! Explicit AVX-512F microkernels (`x86_64` only, behind the `avx512`
//! cargo feature), selected at runtime by [`crate::kernels::dispatch`]
//! after `is_x86_feature_detected!` confirms `avx512f`.
//!
//! The module is additionally feature-gated at compile time because the
//! AVX-512 intrinsics and `#[target_feature(enable = "avx512f")]` only
//! stabilized in rustc 1.89; the default build keeps the crate's baseline
//! MSRV and simply never compiles this file (the `Avx512` dispatch variant
//! then degrades to `Portable`).
//!
//! # Accumulation order (normative for the `Avx512` variant)
//!
//! * [`dot`] — 32 fused logical lanes: 16-lane chunks are consumed in
//!   index order, even-numbered chunks fusing into accumulator `acc0` and
//!   odd-numbered chunks into `acc1` (`acc[l] = fma(a, b, acc[l])`); the
//!   final ragged chunk is handled with a masked FMA that leaves dead
//!   lanes untouched. The accumulators combine element-wise as
//!   `acc = acc0 + acc1`, then reduce by the pairwise tree
//!   `(((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))) + (((l8+l9)+(l10+l11)) +
//!   ((l12+l13)+(l14+l15)))`. There is no scalar tail: raggedness is
//!   absorbed by the masked chunk.
//! * [`axpy`] — each element updated exactly once with a single fused
//!   multiply-add (`out[i] = fma(w, a[i], out[i])`), the ragged tail via
//!   masked load/FMA/store. Per-element this is the same operation as the
//!   AVX2/NEON axpy, so all fused variants agree bitwise on axpy.
//! * [`add`] — plain addition, each element exactly once (masked tail):
//!   bit-identical to every other variant's `add`.
//! * [`panel`] — the 8×32 GEMM microtile: every output element is loaded
//!   from C, updated by one pure FMA chain over `k` ascending, and stored
//!   back — the same per-element contract as the AVX2/NEON panels, so the
//!   result per element is independent of tiling, `KC` blocking, and row
//!   partitioning across workers.
//!
//! Scalar edges elsewhere in the GEMM driver use [`f32::mul_add`], which
//! is bit-identical to the hardware FMA used here.
//!
//! Every intrinsic call sits in an explicit `unsafe` block (the crate
//! denies `unsafe_op_in_unsafe_fn`) with its obligation discharged in a
//! `SAFETY:` comment; `tools/hotpath_lint.rs` additionally checks that
//! every `#[target_feature]` function here is declared `unsafe fn`.

// Arch intrinsics are callable without `unsafe` inside a matching
// `#[target_feature]` context on newer toolchains, which would flag the
// explicit blocks below as unused; keep them for the SAFETY discipline.
#![allow(unused_unsafe)]

use core::arch::x86_64::{
    _mm512_add_ps, _mm512_fmadd_ps, _mm512_loadu_ps, _mm512_mask3_fmadd_ps,
    _mm512_mask_storeu_ps, _mm512_maskz_loadu_ps, _mm512_set1_ps, _mm512_setzero_ps,
    _mm512_storeu_ps, __mmask16,
};

/// Vector length of one 512-bit register of `f32`.
pub const VL: usize = 16;
/// Microtile rows of the packed GEMM kernel (16 of 32 zmm registers hold
/// accumulators: 8 rows × 2 halves of 32 columns).
pub const MR: usize = 8;
/// Microtile columns (two 16-lane registers wide).
pub const NR: usize = 32;

/// The lane mask selecting the first `live` of 16 lanes (`live <= 16`).
#[inline]
fn tail_mask(live: usize) -> __mmask16 {
    debug_assert!(live <= VL);
    if live >= VL {
        !0
    } else {
        ((1u32 << live) - 1) as __mmask16
    }
}

/// Safe entry installed in the `Avx512` [`crate::kernels::dispatch::KernelTable`].
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: this function is only reachable through the table returned by
    // `dispatch::table_for(Variant::Avx512)`, which is handed out only
    // after `is_x86_feature_detected!` confirmed "avx512f".
    unsafe { dot_avx512(a, b) }
}

/// Safe entry installed in the `Avx512` [`crate::kernels::dispatch::KernelTable`].
pub fn axpy(w: f32, a: &[f32], out: &mut [f32]) {
    // SAFETY: reachable only via the detection-gated Avx512 table (see
    // `dot` above).
    unsafe { axpy_avx512(w, a, out) }
}

/// Safe entry installed in the `Avx512` [`crate::kernels::dispatch::KernelTable`].
pub fn add(out: &mut [f32], a: &[f32]) {
    // SAFETY: reachable only via the detection-gated Avx512 table (see
    // `dot` above).
    unsafe { add_avx512(out, a) }
}

/// Safe entry installed in the `Avx512` [`crate::kernels::dispatch::GemmParams`].
pub fn panel(pa: &[f32], pb: &[f32], c: &mut [f32], cs: usize, rows: usize, kc: usize) {
    // SAFETY: reachable only via the detection-gated Avx512 table (see
    // `dot` above).
    unsafe { panel_avx512(pa, pb, c, cs, rows, kc) }
}

/// # Safety
///
/// Requires AVX-512F; the caller must have verified CPU support (the safe
/// wrappers above are only installed after feature detection).
#[target_feature(enable = "avx512f")]
unsafe fn dot_avx512(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / VL;
    let tail = a.len() % VL;
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    // SAFETY: no memory preconditions; AVX-512F is enabled on this function.
    let (mut acc0, mut acc1) = unsafe { (_mm512_setzero_ps(), _mm512_setzero_ps()) };
    for k in 0..chunks {
        // SAFETY: `k * VL + VL <= chunks * VL <= len` for both slices, so
        // the unaligned 16-float loads stay in bounds.
        unsafe {
            let x = _mm512_loadu_ps(ap.add(k * VL));
            let y = _mm512_loadu_ps(bp.add(k * VL));
            if k % 2 == 0 {
                acc0 = _mm512_fmadd_ps(x, y, acc0);
            } else {
                acc1 = _mm512_fmadd_ps(x, y, acc1);
            }
        }
    }
    if tail > 0 {
        let m = tail_mask(tail);
        // SAFETY: masked loads access only the `tail` live lanes, all of
        // which are within the slices (`chunks * VL + tail == len`); the
        // architecture suppresses faults on masked-out lanes. The masked
        // FMA leaves dead accumulator lanes bit-untouched.
        unsafe {
            let x = _mm512_maskz_loadu_ps(m, ap.add(chunks * VL));
            let y = _mm512_maskz_loadu_ps(m, bp.add(chunks * VL));
            if chunks % 2 == 0 {
                acc0 = _mm512_mask3_fmadd_ps(x, y, acc0, m);
            } else {
                acc1 = _mm512_mask3_fmadd_ps(x, y, acc1, m);
            }
        }
    }
    // SAFETY: no memory preconditions for the element-wise combine.
    let acc = unsafe { _mm512_add_ps(acc0, acc1) };
    let mut lanes = [0.0f32; VL];
    // SAFETY: `lanes` holds exactly 16 f32s; unaligned store is permitted.
    unsafe { _mm512_storeu_ps(lanes.as_mut_ptr(), acc) };
    let q0 = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
        + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]));
    let q1 = ((lanes[8] + lanes[9]) + (lanes[10] + lanes[11]))
        + ((lanes[12] + lanes[13]) + (lanes[14] + lanes[15]));
    q0 + q1
}

/// # Safety
///
/// Requires AVX-512F; the caller must have verified CPU support.
#[target_feature(enable = "avx512f")]
unsafe fn axpy_avx512(w: f32, a: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), out.len());
    let chunks = out.len() / VL;
    let tail = out.len() % VL;
    let ap = a.as_ptr();
    let op = out.as_mut_ptr();
    // SAFETY: no memory preconditions for the broadcast.
    let wv = unsafe { _mm512_set1_ps(w) };
    for k in 0..chunks {
        // SAFETY: `k * VL + VL <= chunks * VL <= len` keeps loads and the
        // store in bounds; `a` and `out` are distinct slices (&/&mut), so
        // the accesses never alias.
        unsafe {
            let x = _mm512_loadu_ps(ap.add(k * VL));
            let o = _mm512_loadu_ps(op.add(k * VL));
            _mm512_storeu_ps(op.add(k * VL), _mm512_fmadd_ps(wv, x, o));
        }
    }
    if tail > 0 {
        let m = tail_mask(tail);
        // SAFETY: masked load/FMA/store touch only the `tail` live lanes,
        // all in bounds (`chunks * VL + tail == len`); masked-out lanes are
        // neither read nor written.
        unsafe {
            let x = _mm512_maskz_loadu_ps(m, ap.add(chunks * VL));
            let o = _mm512_maskz_loadu_ps(m, op.add(chunks * VL));
            _mm512_mask_storeu_ps(op.add(chunks * VL), m, _mm512_fmadd_ps(wv, x, o));
        }
    }
}

/// # Safety
///
/// Requires AVX-512F; the caller must have verified CPU support.
#[target_feature(enable = "avx512f")]
unsafe fn add_avx512(out: &mut [f32], a: &[f32]) {
    debug_assert_eq!(a.len(), out.len());
    let chunks = out.len() / VL;
    let tail = out.len() % VL;
    let ap = a.as_ptr();
    let op = out.as_mut_ptr();
    for k in 0..chunks {
        // SAFETY: in-bounds as in `axpy_avx512`; distinct slices, no
        // aliasing.
        unsafe {
            let x = _mm512_loadu_ps(ap.add(k * VL));
            let o = _mm512_loadu_ps(op.add(k * VL));
            _mm512_storeu_ps(op.add(k * VL), _mm512_add_ps(o, x));
        }
    }
    if tail > 0 {
        let m = tail_mask(tail);
        // SAFETY: masked load/add/store touch only the `tail` live lanes,
        // all in bounds; masked-out lanes are neither read nor written.
        unsafe {
            let x = _mm512_maskz_loadu_ps(m, ap.add(chunks * VL));
            let o = _mm512_maskz_loadu_ps(m, op.add(chunks * VL));
            _mm512_mask_storeu_ps(op.add(chunks * VL), m, _mm512_add_ps(o, x));
        }
    }
}

/// The 8×32 FMA microtile over packed panels: `C[r][j]` is loaded, updated
/// by `kc` fused multiply-adds in `k`-ascending order, and stored back.
/// Rows `rows..MR` read the A panel's zero padding into never-stored
/// accumulators.
///
/// # Safety
///
/// Requires AVX-512F; the caller must have verified CPU support, and must
/// pass panels with `pa.len() >= kc * MR`, `pb.len() >= kc * NR`,
/// `1 <= rows <= MR`, `cs >= NR` and `c.len() >= (rows - 1) * cs + NR`
/// (all debug-asserted).
#[target_feature(enable = "avx512f")]
unsafe fn panel_avx512(pa: &[f32], pb: &[f32], c: &mut [f32], cs: usize, rows: usize, kc: usize) {
    debug_assert!(rows >= 1 && rows <= MR);
    debug_assert!(cs >= NR);
    debug_assert!(pa.len() >= kc * MR);
    debug_assert!(pb.len() >= kc * NR);
    debug_assert!(c.len() >= (rows - 1) * cs + NR);
    // SAFETY: no memory preconditions.
    let zero = unsafe { _mm512_setzero_ps() };
    let mut acc = [[zero; 2]; MR];
    let cp = c.as_mut_ptr();
    for (r, accr) in acc.iter_mut().enumerate().take(rows) {
        // SAFETY: `r < rows`, so `r * cs + NR <= c.len()` (asserted above).
        unsafe {
            accr[0] = _mm512_loadu_ps(cp.add(r * cs));
            accr[1] = _mm512_loadu_ps(cp.add(r * cs + VL));
        }
    }
    let pap = pa.as_ptr();
    let pbp = pb.as_ptr();
    for k in 0..kc {
        // SAFETY: `k < kc` and the panel-length asserts above keep every
        // load in bounds (`k * NR + NR <= kc * NR`, `k * MR + MR <= kc * MR`).
        unsafe {
            let b0 = _mm512_loadu_ps(pbp.add(k * NR));
            let b1 = _mm512_loadu_ps(pbp.add(k * NR + VL));
            for (r, accr) in acc.iter_mut().enumerate() {
                let av = _mm512_set1_ps(*pap.add(k * MR + r));
                accr[0] = _mm512_fmadd_ps(av, b0, accr[0]);
                accr[1] = _mm512_fmadd_ps(av, b1, accr[1]);
            }
        }
    }
    for (r, accr) in acc.iter().enumerate().take(rows) {
        // SAFETY: `r < rows`, bounds as for the loads above; rows are
        // `cs >= NR` apart, so the two stores per row never overlap another
        // row's.
        unsafe {
            _mm512_storeu_ps(cp.add(r * cs), accr[0]);
            _mm512_storeu_ps(cp.add(r * cs + VL), accr[1]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn supported() -> bool {
        std::arch::is_x86_feature_detected!("avx512f")
    }

    /// Scalar emulation of the AVX-512 dot order: 16-lane chunks fused
    /// into two alternating accumulators (masked ragged chunk included),
    /// element-wise combine, pairwise tree reduction.
    fn dot_reference(a: &[f32], b: &[f32]) -> f32 {
        let mut acc = [[0.0f32; VL]; 2];
        let mut i = 0;
        let mut chunk = 0;
        while i < a.len() {
            let live = VL.min(a.len() - i);
            let dst = &mut acc[chunk % 2];
            for l in 0..live {
                dst[l] = a[i + l].mul_add(b[i + l], dst[l]);
            }
            i += live;
            chunk += 1;
        }
        let lanes: Vec<f32> = (0..VL).map(|l| acc[0][l] + acc[1][l]).collect();
        let q0 = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
            + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]));
        let q1 = ((lanes[8] + lanes[9]) + (lanes[10] + lanes[11]))
            + ((lanes[12] + lanes[13]) + (lanes[14] + lanes[15]));
        q0 + q1
    }

    #[test]
    fn dot_matches_scalar_fma_emulation_on_ragged_lengths() {
        if !supported() {
            return;
        }
        let mut rng = Rng::new(401);
        for len in 0..=71 {
            let a: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            assert_eq!(
                dot(&a, &b).to_bits(),
                dot_reference(&a, &b).to_bits(),
                "len {len}"
            );
        }
    }

    #[test]
    fn axpy_matches_scalar_fma_on_ragged_lengths() {
        if !supported() {
            return;
        }
        let mut rng = Rng::new(402);
        for len in 0..=71 {
            let a: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let init: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let w = rng.normal_f32(0.0, 2.0);
            let mut got = init.clone();
            axpy(w, &a, &mut got);
            for (i, g) in got.iter().enumerate() {
                let want = w.mul_add(a[i], init[i]);
                assert_eq!(g.to_bits(), want.to_bits(), "len {len} idx {i}");
            }
        }
    }

    #[test]
    fn add_bit_identical_to_portable() {
        if !supported() {
            return;
        }
        let mut rng = Rng::new(403);
        for len in 0..=71 {
            let a: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let init: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut got = init.clone();
            add(&mut got, &a);
            let mut want = init;
            crate::kernels::portable::add8(&mut want, &a);
            for (g, w_) in got.iter().zip(&want) {
                assert_eq!(g.to_bits(), w_.to_bits(), "len {len}");
            }
        }
    }

    #[test]
    fn panel_matches_pure_fma_chain() {
        if !supported() {
            return;
        }
        let mut rng = Rng::new(404);
        for rows in 1..=MR {
            let kc = 7;
            let pa: Vec<f32> = (0..kc * MR).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let pb: Vec<f32> = (0..kc * NR).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let c0: Vec<f32> = (0..rows * NR).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut c = c0.clone();
            panel(&pa, &pb, &mut c, NR, rows, kc);
            for r in 0..rows {
                for j in 0..NR {
                    let mut want = c0[r * NR + j];
                    for k in 0..kc {
                        want = pa[k * MR + r].mul_add(pb[k * NR + j], want);
                    }
                    assert_eq!(
                        c[r * NR + j].to_bits(),
                        want.to_bits(),
                        "rows {rows} r {r} j {j}"
                    );
                }
            }
        }
    }
}
