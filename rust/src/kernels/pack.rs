//! Cache-blocked panel packing for the register-blocked GEMM microkernels.
//!
//! The packed matmul path (see [`crate::kernels::dispatch::GemmParams`])
//! copies the operands of one `KC`-deep slice of the contraction into
//! contiguous, microkernel-friendly panels before the FMA microtile loop
//! runs over them:
//!
//! * the A panel interleaves `mr` rows per tile —
//!   `dst[tile·mr·kc + k·mr + r] = A[tile·mr + r][k0 + k]` — so the
//!   microkernel broadcasts one element per row with a unit-stride walk;
//!   rows past `m` in the last tile are **zero-filled** (the microkernel
//!   computes them into never-stored accumulators, and `0 · b + 0 = 0`
//!   raises no signal);
//! * the B panel interleaves `nr` columns per tile —
//!   `dst[jt·nr·kc + k·nr + j] = B[k0 + k][jt·nr + j]` — so each microtile
//!   step loads `nr` consecutive floats. Only *full* column tiles are
//!   packed; the ragged `n % nr` column edge is computed by the scalar-FMA
//!   edge loop in the driver straight from the strided source.
//!
//! Both functions take generic `(row, col)` strides, which is what lets the
//! three matmul orientations (`NT`, `NN`, `TN`) share one packing routine:
//! an operand is "transposed" by swapping the strides, never by copying
//! twice. Every element of the destination prefix in use is overwritten on
//! every call (including the zero padding), so pack buffers need no
//! clearing between replays.
//!
//! # Conv-atom weight panels
//!
//! [`pack_conv_weights`] serves the conv atoms' run-structured loops the
//! same way: for every `(group · bfree, s)` weight row it gathers the
//! weights in the exact `(head, run)` order the inner loops consume them,
//! into rows of a fixed padded width. The pad entries are **zero**, which
//! the conv loops already skip (the `w == 0` fast path), so padding never
//! changes which operations run. Like the GEMM panels, the full
//! destination prefix is overwritten every call.

/// Pack the `kc`-deep slice (columns `k0..k0 + kc` of the logical
/// `m × k` operand `A`, where `A[i][k] = src[i * rs + k * cs]`) into
/// row-interleaved tiles of `mr` rows. `dst` must hold at least
/// `ceil(m / mr) * mr * kc` elements.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn pack_a(
    src: &[f32],
    rs: usize,
    cs: usize,
    m: usize,
    k0: usize,
    kc: usize,
    mr: usize,
    dst: &mut [f32],
) {
    let m_tiles = (m + mr - 1) / mr;
    debug_assert!(dst.len() >= m_tiles * mr * kc);
    for tile in 0..m_tiles {
        let i0 = tile * mr;
        let rows = mr.min(m - i0);
        let d = &mut dst[tile * mr * kc..(tile + 1) * mr * kc];
        for kk in 0..kc {
            let col = (k0 + kk) * cs;
            let (live, pad) = d[kk * mr..(kk + 1) * mr].split_at_mut(rows);
            for (r, slot) in live.iter_mut().enumerate() {
                *slot = src[(i0 + r) * rs + col];
            }
            for slot in pad.iter_mut() {
                *slot = 0.0;
            }
        }
    }
}

/// Pack the `kc`-deep slice (rows `k0..k0 + kc` of the logical `k × n`
/// operand `B`, where `B[k][j] = src[k * rs + j * cs]`) into
/// column-interleaved tiles of `nr` columns, full tiles only
/// (`n_full % nr == 0`). `dst` must hold at least `n_full * kc` elements.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn pack_b(
    src: &[f32],
    rs: usize,
    cs: usize,
    n_full: usize,
    k0: usize,
    kc: usize,
    nr: usize,
    dst: &mut [f32],
) {
    debug_assert_eq!(n_full % nr, 0);
    debug_assert!(dst.len() >= n_full * kc);
    for jt in 0..n_full / nr {
        let j0 = jt * nr;
        let d = &mut dst[jt * nr * kc..(jt + 1) * nr * kc];
        for kk in 0..kc {
            let row = (k0 + kk) * rs;
            let drow = &mut d[kk * nr..(kk + 1) * nr];
            for (jj, slot) in drow.iter_mut().enumerate() {
                *slot = src[row + (j0 + jj) * cs];
            }
        }
    }
}

/// Pack conv-atom weights into a consumption-ordered panel: for each of
/// the `rows` logical weight rows (one per `(group·bfree, s)` pair, each
/// `pb` elements of `src` apart), gather the `boffs` entries — the
/// flattened `(head, run)` weight offsets — into a padded row of `ne`
/// elements (`ne >= boffs.len()`; the pad is zero-filled). `dst` must hold
/// at least `rows * ne` elements.
#[inline]
pub fn pack_conv_weights(
    src: &[f32],
    rows: usize,
    pb: usize,
    boffs: &[u32],
    ne: usize,
    dst: &mut [f32],
) {
    debug_assert!(ne >= boffs.len());
    debug_assert!(dst.len() >= rows * ne);
    debug_assert!(src.len() >= rows * pb);
    for row in 0..rows {
        let s = &src[row * pb..(row + 1) * pb];
        let d = &mut dst[row * ne..row * ne + ne];
        let (live, pad) = d.split_at_mut(boffs.len());
        for (slot, &bo) in live.iter_mut().zip(boffs) {
            *slot = s[bo as usize];
        }
        for slot in pad.iter_mut() {
            *slot = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_a_interleaves_and_zero_pads() {
        // 5×4 row-major A, mr = 2: three tiles, last padded with one row.
        let m = 5;
        let k = 4;
        let src: Vec<f32> = (0..m * k).map(|v| v as f32).collect();
        let mr = 2;
        let mut dst = vec![-1.0f32; 3 * mr * k];
        pack_a(&src, k, 1, m, 0, k, mr, &mut dst);
        for tile in 0..3 {
            for kk in 0..k {
                for r in 0..mr {
                    let i = tile * mr + r;
                    let want = if i < m { src[i * k + kk] } else { 0.0 };
                    assert_eq!(dst[tile * mr * k + kk * mr + r], want);
                }
            }
        }
    }

    #[test]
    fn pack_b_interleaves_full_tiles() {
        // 3×8 row-major B, nr = 4: two full tiles.
        let k = 3;
        let n = 8;
        let src: Vec<f32> = (0..k * n).map(|v| (v as f32) * 0.5).collect();
        let nr = 4;
        let mut dst = vec![-1.0f32; n * k];
        pack_b(&src, n, 1, n, 0, k, nr, &mut dst);
        for jt in 0..2 {
            for kk in 0..k {
                for jj in 0..nr {
                    let j = jt * nr + jj;
                    assert_eq!(dst[jt * nr * k + kk * nr + jj], src[kk * n + j]);
                }
            }
        }
    }

    #[test]
    fn pack_conv_weights_gathers_in_consumption_order_and_zero_pads() {
        // Three weight rows of pb = 4, gather order [3, 0, 2], padded to 5.
        let src: Vec<f32> = (0..12).map(|v| v as f32).collect();
        let boffs = [3u32, 0, 2];
        let ne = 5;
        let mut dst = vec![-1.0f32; 3 * ne];
        pack_conv_weights(&src, 3, 4, &boffs, ne, &mut dst);
        for row in 0..3 {
            for (e, &bo) in boffs.iter().enumerate() {
                assert_eq!(dst[row * ne + e], src[row * 4 + bo as usize]);
            }
            for e in boffs.len()..ne {
                assert_eq!(dst[row * ne + e], 0.0);
            }
        }
    }

    #[test]
    fn pack_handles_strided_transposed_views() {
        // A_std[i][k] = src[k * 3 + i] (a 4×3 matrix read as its transpose).
        let src: Vec<f32> = (0..12).map(|v| v as f32).collect();
        let (m, k) = (3, 4);
        let mr = 4;
        let mut dst = vec![0.0f32; mr * k];
        pack_a(&src, 1, 3, m, 0, k, mr, &mut dst);
        for kk in 0..k {
            for r in 0..m {
                assert_eq!(dst[kk * mr + r], src[kk * 3 + r]);
            }
            assert_eq!(dst[kk * mr + 3], 0.0);
        }
    }
}
