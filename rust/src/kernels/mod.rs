//! Microkernels for the executor's inner loops, with one-time runtime
//! variant dispatch.
//!
//! The planner minimizes FLOPs, but the paper's wall-clock claims only
//! materialize if each atom executes near hardware peak. The crate ships
//! four kernel *variants* (see [`dispatch::Variant`]): the portable
//! hand-unrolled 8-lane code that leans on the autovectorizer, and
//! explicit AVX2+FMA / AVX-512F / NEON implementations that add fused
//! multiply-adds and a register-blocked, cache-blocked packed GEMM for
//! the matmul-shaped atom loops. [`dispatch::selected`] resolves one
//! variant per process (feature detection, overridable via the
//! `CONV_EINSUM_KERNEL_VARIANT` env var), and every kernel table built
//! afterwards uses it.
//!
//! # Accumulation order v3 (normative, per variant)
//!
//! Floating-point addition is not associative, so every kernel fixes its
//! accumulation order *as part of its contract*. Since v2 the contract is
//! stated **per variant**: the scalar and parallel backends, and the
//! compiled-plan replay, all draw their kernels from the same
//! process-selected [`dispatch::KernelTable`], so results are bit-identical
//! across backends *for a fixed variant* — not across variants or ISAs
//! (the fused variants round once where the portable code rounds twice,
//! and the AVX-512 dot uses a 32-lane order where the others use 8).
//!
//! Orders common to all variants:
//!
//! * **axpy / add** touch each output element exactly once
//!   (`out[i] += w * a[i]`, fused to `out[i] = fma(w, a[i], out[i])` on
//!   FMA variants); no reassociation ever. `add` performs no
//!   multiplication, so it is bit-identical across *all* variants.
//! * **dot** on the 8-lane variants accumulates 8 logical lanes per block
//!   (`acc[l] ⊕= a[8k + l] · b[8k + l]`, where `⊕` is fused on FMA
//!   variants), combines lanes pairwise as
//!   `((acc0+acc1)+(acc2+acc3)) + ((acc4+acc5)+(acc6+acc7))`, then folds
//!   the ragged tail sequentially in index order. The AVX-512 variant's
//!   dot is 32 logical lanes (two 16-lane accumulators fed by alternating
//!   chunks, a masked ragged chunk, element-wise combine, then a pairwise
//!   tree over 16 lanes — see `kernels/avx512.rs`).
//! * **packed GEMM** (SIMD variants only; engages per
//!   [`dispatch::GemmParams::engages`]): each output element is one pure
//!   FMA chain over the contracted index in ascending order, with the
//!   accumulator loaded from and stored back to C at cache-block
//!   boundaries. Loads and stores are exact, so the result per element is
//!   independent of the microtile size, the `KC` blocking, and how rows
//!   are partitioned across worker threads — which is what keeps the
//!   scalar-vs-parallel contract intact on the packed path. Scalar edge
//!   loops use [`f32::mul_add`] (IEEE single rounding, bit-identical to
//!   the vector FMA). The packed path does **not** skip zero operands the
//!   way the portable axpy fallbacks do, so on non-finite data
//!   (`0 · ∞`, NaN payloads) the variants may differ; the contract
//!   quantifies over finite inputs.
//! * **conv atoms** (new in v3): the forward keeps its v2 per-element
//!   order (head entries in table order, last-axis runs in order, zero
//!   weights skipped) whether or not the packed weight-panel path engages
//!   — packing is a pure data-layout change, so packed and unpacked
//!   results are bit-identical for a fixed variant. The conv *backward*
//!   is now run-structured on every path: dA accumulates via the
//!   variant's axpy over last-axis runs and dB via [`dot_run`] over the
//!   same runs, each da/db element receiving its contributions in
//!   `(n or t, s, head, run)` order. This changes backward bits relative
//!   to the v2 element-wise order, which is why the contract version is
//!   bumped — stale compiled artifacts fail verification instead of
//!   silently mixing orders.
//!
//! The portable variant's dot/axpy/add orders are byte-for-byte those of
//! accumulation order v1 ([`dot8`], [`axpy8`], [`add8`] remain exported
//! under their v1 names).
//!
//! # Per-step selection
//!
//! [`StepKernel`] names the microkernel family a compiled step uses;
//! [`crate::exec::Atom::select_kernel`] chooses it when the step's
//! [`crate::exec::AtomKernel`] table holder is built (pure contractions →
//! [`StepKernel::MatmulDot8`]; convolutions with last-axis runs long enough
//! to fill 8-lane blocks → [`StepKernel::ConvRunsWide`], otherwise
//! [`StepKernel::ConvRunsNarrow`]). Wide and narrow axpy variants are
//! bit-identical within a variant — the choice only avoids block-setup
//! overhead on runs that can never fill a lane block. The kernel table
//! holder also pins the *variant* selected at build time, and
//! [`crate::exec::CompiledPlan::verify`] rejects replaying a plan under a
//! different selection.

mod portable;

#[cfg(target_arch = "x86_64")]
mod avx2;
#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
mod avx512;
#[cfg(target_arch = "aarch64")]
mod neon;

pub(crate) mod pack;

pub mod dispatch;

pub use portable::{add8, axpy8, dot8};

use dispatch::KernelTable;

/// Lane width of the 8-lane blocked kernels (one 256-bit register of
/// `f32`, or two NEON registers).
pub const LANES: usize = 8;

/// Version tag of the normative accumulation order documented above.
///
/// Every compiled step's kernel-table holder
/// ([`crate::exec::AtomKernel`]) records the version current at lowering
/// time, and [`crate::exec::CompiledPlan::verify`] rejects plans whose
/// steps carry a stale tag. **Bump this constant whenever the documented
/// accumulation order changes** — stale compiled artifacts then fail
/// verification instead of silently breaking cross-backend bit-identity.
///
/// History: **v1** — single portable variant (unfused 8-lane orders).
/// **v2** — per-variant contract: runtime-dispatched AVX2+FMA/NEON
/// variants with fused contractions and a packed cache-blocked GEMM;
/// bit-identity quantifies over (variant, input), not ISA.
/// **v3** — AVX-512 variant (32-lane dot order, masked ragged edges) and
/// run-structured conv backward (dA via axpy runs, dB via [`dot_run`],
/// replacing the v2 element-wise triple loops); conv forward order
/// unchanged, packed conv panels bit-identical to unpacked by
/// construction.
pub const ACCUM_ORDER_VERSION: u32 = 3;

/// Which microkernel family a compiled step's inner loops use. Chosen once
/// per step at compile/lowering time (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepKernel {
    /// Pure contraction: per-group matmul over the variant's dot rows,
    /// upgraded to the packed cache-blocked GEMM when the selected variant
    /// has one and the shape warrants it.
    MatmulDot8,
    /// Convolution whose last-axis runs can fill 8-lane blocks: the
    /// variant's axpy kernel.
    ConvRunsWide,
    /// Convolution with short (ragged) runs: plain element axpy — the same
    /// per-element order as the variant's axpy, minus the block prologue.
    ConvRunsNarrow,
}

/// Dot product using the process-selected variant (see [`dispatch`]).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    (dispatch::selected().dot)(a, b)
}

/// `out[i] += w * a[i]` using the process-selected variant.
#[inline]
pub fn axpy(w: f32, a: &[f32], out: &mut [f32]) {
    (dispatch::selected().axpy)(w, a, out)
}

/// `out[i] += a[i]` using the process-selected variant (bit-identical
/// across all variants).
#[inline]
pub fn add(out: &mut [f32], a: &[f32]) {
    (dispatch::selected().add)(out, a)
}

/// Axpy dispatched by the step's selected kernel, drawing from `table`.
/// Both arms compute the same per-element result bit-for-bit within a
/// variant; narrow runs skip the block setup, and the element loop fuses
/// exactly when the table's vector kernels do.
#[inline]
pub fn axpy_run(table: &KernelTable, kind: StepKernel, w: f32, a: &[f32], out: &mut [f32]) {
    match kind {
        StepKernel::ConvRunsNarrow => {
            if table.fused {
                for (o, s) in out.iter_mut().zip(a) {
                    *o = w.mul_add(*s, *o);
                }
            } else {
                for (o, s) in out.iter_mut().zip(a) {
                    *o += w * s;
                }
            }
        }
        _ => (table.axpy)(w, a, out),
    }
}

/// Dot product over one conv run, dispatched by the step's selected
/// kernel (the dB mirror of [`axpy_run`]): wide runs use the table's
/// blocked dot, narrow runs a sequential loop that fuses exactly when the
/// table's vector kernels do. Part of the v3 conv-backward order.
#[inline]
pub fn dot_run(table: &KernelTable, kind: StepKernel, a: &[f32], b: &[f32]) -> f32 {
    match kind {
        StepKernel::ConvRunsNarrow => {
            let mut total = 0.0f32;
            if table.fused {
                for (x, y) in a.iter().zip(b) {
                    total = x.mul_add(*y, total);
                }
            } else {
                for (x, y) in a.iter().zip(b) {
                    total += x * y;
                }
            }
            total
        }
        _ => (table.dot)(a, b),
    }
}

#[cfg(test)]
mod tests {
    use super::dispatch::{table_for, Variant};
    use super::*;
    use crate::util::rng::Rng;

    /// Scalar emulation of `dot8`'s documented accumulation order.
    fn dot8_reference(a: &[f32], b: &[f32]) -> f32 {
        let blocks = a.len() / LANES;
        let mut acc = [0.0f32; LANES];
        for k in 0..blocks {
            for l in 0..LANES {
                acc[l] += a[k * LANES + l] * b[k * LANES + l];
            }
        }
        let mut total =
            ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
        for i in blocks * LANES..a.len() {
            total += a[i] * b[i];
        }
        total
    }

    #[test]
    fn axpy8_bit_identical_to_naive_on_ragged_lengths() {
        let mut rng = Rng::new(101);
        for len in 0..=41 {
            let a: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let init: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let w = rng.normal_f32(0.0, 2.0);
            let mut got = init.clone();
            axpy8(w, &a, &mut got);
            let mut want = init.clone();
            for (o, s) in want.iter_mut().zip(&a) {
                *o += w * s;
            }
            for (g, w_) in got.iter().zip(&want) {
                assert_eq!(g.to_bits(), w_.to_bits(), "len {len}");
            }
        }
    }

    #[test]
    fn add8_bit_identical_to_naive_on_ragged_lengths() {
        let mut rng = Rng::new(102);
        for len in 0..=41 {
            let a: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let init: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut got = init.clone();
            add8(&mut got, &a);
            let mut want = init.clone();
            for (o, s) in want.iter_mut().zip(&a) {
                *o += s;
            }
            for (g, w_) in got.iter().zip(&want) {
                assert_eq!(g.to_bits(), w_.to_bits(), "len {len}");
            }
        }
    }

    #[test]
    fn dot8_matches_documented_order_on_ragged_lengths() {
        let mut rng = Rng::new(103);
        for len in 0..=41 {
            let a: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let got = dot8(&a, &b);
            let want = dot8_reference(&a, &b);
            assert_eq!(got.to_bits(), want.to_bits(), "len {len}");
        }
    }

    #[test]
    fn axpy_run_variants_agree_bitwise_per_table() {
        let mut rng = Rng::new(104);
        let tables = [table_for(Variant::Portable), dispatch::selected()];
        for table in tables {
            for len in [0usize, 1, 3, 7, 8, 9, 23] {
                let a: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let init: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let mut wide = init.clone();
                let mut narrow = init.clone();
                axpy_run(table, StepKernel::ConvRunsWide, 1.5, &a, &mut wide);
                axpy_run(table, StepKernel::ConvRunsNarrow, 1.5, &a, &mut narrow);
                for (x, y) in wide.iter().zip(&narrow) {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "variant {} len {len}",
                        table.variant.name()
                    );
                }
            }
        }
    }

    #[test]
    fn add_is_bit_identical_across_variants() {
        let mut rng = Rng::new(105);
        for len in [0usize, 1, 7, 8, 9, 33] {
            let a: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let init: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut portable_out = init.clone();
            add8(&mut portable_out, &a);
            for v in dispatch::available() {
                let mut got = init.clone();
                (table_for(v).add)(&mut got, &a);
                for (g, w_) in got.iter().zip(&portable_out) {
                    assert_eq!(g.to_bits(), w_.to_bits(), "variant {} len {len}", v.name());
                }
            }
        }
    }

    #[test]
    fn dispatched_wrappers_use_selected_table() {
        let mut rng = Rng::new(106);
        let a: Vec<f32> = (0..19).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let b: Vec<f32> = (0..19).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let table = dispatch::selected();
        assert_eq!(dot(&a, &b).to_bits(), (table.dot)(&a, &b).to_bits());
        let mut x = b.clone();
        let mut y = b.clone();
        axpy(0.75, &a, &mut x);
        (table.axpy)(0.75, &a, &mut y);
        assert_eq!(x, y);
        add(&mut x, &a);
        (table.add)(&mut y, &a);
        assert_eq!(x, y);
    }
}
