//! Explicit 8-lane `f32` microkernels for the executor's inner loops.
//!
//! The planner minimizes FLOPs, but the paper's wall-clock claims only
//! materialize if each atom executes near hardware peak. These kernels
//! replace reliance on autovectorization with hand-unrolled 8-wide blocks
//! (one AVX/NEON-register-width of `f32`s) written so the backend compiles
//! them to packed SIMD: fixed-size `chunks_exact` bodies with no bounds
//! checks and independent accumulator lanes.
//!
//! # Accumulation order (normative)
//!
//! Floating-point addition is not associative, so every kernel fixes its
//! accumulation order *as part of its contract* — the scalar and parallel
//! backends, and the compiled-plan replay, all call these same kernels, so
//! results are bit-identical across backends by construction:
//!
//! * [`axpy8`] / [`add8`] touch each output element exactly once
//!   (`out[i] += w * a[i]`), so unrolling performs no reassociation at all —
//!   they are bit-identical to the naive element loop.
//! * [`dot8`] accumulates block `k` lane-wise into 8 independent lanes
//!   (`acc[l] += a[8k + l] * b[8k + l]`), then combines lanes pairwise as
//!   `((acc0+acc1)+(acc2+acc3)) + ((acc4+acc5)+(acc6+acc7))`, then folds the
//!   ragged tail sequentially onto that total in index order. Any scalar
//!   emulation of this order reproduces the result bit-for-bit (the
//!   property suite checks ragged lengths 0..=41).
//!
//! # Per-step selection
//!
//! [`StepKernel`] names the microkernel family a compiled step uses;
//! [`crate::exec::Atom::select_kernel`] chooses it when the step's
//! [`crate::exec::AtomKernel`] table holder is built (pure contractions →
//! [`StepKernel::MatmulDot8`]; convolutions with last-axis runs long enough
//! to fill 8-lane blocks → [`StepKernel::ConvRunsWide`], otherwise
//! [`StepKernel::ConvRunsNarrow`]). Wide and narrow axpy variants are
//! bit-identical — the choice only avoids block-setup overhead on runs that
//! can never fill a lane block.

/// Lane width of the hand-unrolled kernels (one 256-bit register of `f32`).
pub const LANES: usize = 8;

/// Version tag of the normative accumulation order documented above.
///
/// Every compiled step's kernel-table holder
/// ([`crate::exec::AtomKernel`]) records the version current at lowering
/// time, and [`crate::exec::CompiledPlan::verify`] rejects plans whose
/// steps carry a stale tag. **Bump this constant whenever the documented
/// accumulation order changes** (e.g. a future explicit-SIMD variant that
/// reassociates differently) — stale compiled artifacts then fail
/// verification instead of silently breaking cross-backend bit-identity.
pub const ACCUM_ORDER_VERSION: u32 = 1;

/// Which microkernel family a compiled step's inner loops use. Chosen once
/// per step at compile/lowering time (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepKernel {
    /// Pure contraction: per-group matmul over [`dot8`] rows.
    MatmulDot8,
    /// Convolution whose last-axis runs can fill 8-lane blocks: [`axpy8`].
    ConvRunsWide,
    /// Convolution with short (ragged) runs: plain element axpy — the same
    /// per-element order as [`axpy8`], minus the block prologue.
    ConvRunsNarrow,
}

/// `out[i] += w * a[i]` over 8-lane blocks plus a sequential tail.
/// Bit-identical to the naive element loop (each element is touched once).
#[inline]
pub fn axpy8(w: f32, a: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), out.len());
    let blocks = out.len() / LANES;
    let split = blocks * LANES;
    let (a_main, a_tail) = a.split_at(split);
    let (o_main, o_tail) = out.split_at_mut(split);
    for (o, s) in o_main.chunks_exact_mut(LANES).zip(a_main.chunks_exact(LANES)) {
        o[0] += w * s[0];
        o[1] += w * s[1];
        o[2] += w * s[2];
        o[3] += w * s[3];
        o[4] += w * s[4];
        o[5] += w * s[5];
        o[6] += w * s[6];
        o[7] += w * s[7];
    }
    for (o, s) in o_tail.iter_mut().zip(a_tail) {
        *o += w * s;
    }
}

/// `out[i] += a[i]` over 8-lane blocks plus a sequential tail.
/// Bit-identical to the naive element loop.
#[inline]
pub fn add8(out: &mut [f32], a: &[f32]) {
    debug_assert_eq!(a.len(), out.len());
    let blocks = out.len() / LANES;
    let split = blocks * LANES;
    let (a_main, a_tail) = a.split_at(split);
    let (o_main, o_tail) = out.split_at_mut(split);
    for (o, s) in o_main.chunks_exact_mut(LANES).zip(a_main.chunks_exact(LANES)) {
        o[0] += s[0];
        o[1] += s[1];
        o[2] += s[2];
        o[3] += s[3];
        o[4] += s[4];
        o[5] += s[5];
        o[6] += s[6];
        o[7] += s[7];
    }
    for (o, s) in o_tail.iter_mut().zip(a_tail) {
        *o += s;
    }
}

/// Dot product in the normative 8-lane blocked order (see module docs):
/// lane-parallel block accumulation, pairwise lane combine, sequential
/// ragged tail.
#[inline]
pub fn dot8(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let blocks = a.len() / LANES;
    let split = blocks * LANES;
    let (a_main, a_tail) = a.split_at(split);
    let (b_main, b_tail) = b.split_at(split);
    let mut acc = [0.0f32; LANES];
    for (x, y) in a_main.chunks_exact(LANES).zip(b_main.chunks_exact(LANES)) {
        acc[0] += x[0] * y[0];
        acc[1] += x[1] * y[1];
        acc[2] += x[2] * y[2];
        acc[3] += x[3] * y[3];
        acc[4] += x[4] * y[4];
        acc[5] += x[5] * y[5];
        acc[6] += x[6] * y[6];
        acc[7] += x[7] * y[7];
    }
    let mut total =
        ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for (x, y) in a_tail.iter().zip(b_tail) {
        total += x * y;
    }
    total
}

/// Axpy dispatched by the step's selected kernel. Both arms compute the
/// same per-element result bit-for-bit; narrow runs skip the block setup.
#[inline]
pub fn axpy_run(kind: StepKernel, w: f32, a: &[f32], out: &mut [f32]) {
    match kind {
        StepKernel::ConvRunsNarrow => {
            for (o, s) in out.iter_mut().zip(a) {
                *o += w * s;
            }
        }
        _ => axpy8(w, a, out),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Scalar emulation of `dot8`'s documented accumulation order.
    fn dot8_reference(a: &[f32], b: &[f32]) -> f32 {
        let blocks = a.len() / LANES;
        let mut acc = [0.0f32; LANES];
        for k in 0..blocks {
            for l in 0..LANES {
                acc[l] += a[k * LANES + l] * b[k * LANES + l];
            }
        }
        let mut total =
            ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
        for i in blocks * LANES..a.len() {
            total += a[i] * b[i];
        }
        total
    }

    #[test]
    fn axpy8_bit_identical_to_naive_on_ragged_lengths() {
        let mut rng = Rng::new(101);
        for len in 0..=41 {
            let a: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let init: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let w = rng.normal_f32(0.0, 2.0);
            let mut got = init.clone();
            axpy8(w, &a, &mut got);
            let mut want = init.clone();
            for (o, s) in want.iter_mut().zip(&a) {
                *o += w * s;
            }
            for (g, w_) in got.iter().zip(&want) {
                assert_eq!(g.to_bits(), w_.to_bits(), "len {len}");
            }
        }
    }

    #[test]
    fn add8_bit_identical_to_naive_on_ragged_lengths() {
        let mut rng = Rng::new(102);
        for len in 0..=41 {
            let a: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let init: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut got = init.clone();
            add8(&mut got, &a);
            let mut want = init.clone();
            for (o, s) in want.iter_mut().zip(&a) {
                *o += s;
            }
            for (g, w_) in got.iter().zip(&want) {
                assert_eq!(g.to_bits(), w_.to_bits(), "len {len}");
            }
        }
    }

    #[test]
    fn dot8_matches_documented_order_on_ragged_lengths() {
        let mut rng = Rng::new(103);
        for len in 0..=41 {
            let a: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let got = dot8(&a, &b);
            let want = dot8_reference(&a, &b);
            assert_eq!(got.to_bits(), want.to_bits(), "len {len}");
        }
    }

    #[test]
    fn axpy_run_variants_agree_bitwise() {
        let mut rng = Rng::new(104);
        for len in [0usize, 1, 3, 7, 8, 9, 23] {
            let a: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let init: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut wide = init.clone();
            let mut narrow = init.clone();
            axpy_run(StepKernel::ConvRunsWide, 1.5, &a, &mut wide);
            axpy_run(StepKernel::ConvRunsNarrow, 1.5, &a, &mut narrow);
            for (x, y) in wide.iter().zip(&narrow) {
                assert_eq!(x.to_bits(), y.to_bits(), "len {len}");
            }
        }
    }
}
