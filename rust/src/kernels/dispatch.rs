//! One-time runtime selection of the microkernel variant.
//!
//! The crate ships four implementations of its hot inner loops (see the
//! [`crate::kernels`] module docs for the accumulation-order contract):
//!
//! * **`Portable`** — the original hand-unrolled 8-lane kernels
//!   ([`crate::kernels::dot8`] and friends), compiled for any target and
//!   carrying accumulation order v1 semantics. No packed GEMM
//!   ([`KernelTable::gemm`] is `None`).
//! * **`Avx2Fma`** — explicit AVX2 + FMA intrinsics with a 6×16
//!   register-blocked GEMM microtile over cache-blocked packed panels
//!   (`x86_64` only, gated on `is_x86_feature_detected!("avx2")` and
//!   `"fma"`).
//! * **`Avx512`** — explicit AVX-512F intrinsics with 32 fused logical
//!   lanes, masked ragged edges, and an 8×32 microtile (`x86_64` with the
//!   `avx512` cargo feature, gated on
//!   `is_x86_feature_detected!("avx512f")`; without the feature the
//!   variant degrades to `Portable` at table-construction time).
//! * **`Neon`** — explicit NEON intrinsics with an 8×8 microtile
//!   (`aarch64` only, where NEON is a baseline feature).
//!
//! # Selection rules
//!
//! [`selected`] resolves the process-wide variant exactly once:
//!
//! 1. a test/bench override installed via [`force_variant`] (hidden API,
//!    single-process use only) wins;
//! 2. else the `CONV_EINSUM_KERNEL_VARIANT` environment variable
//!    ([`VARIANT_ENV`]) is honoured — `portable`/`scalar`, `avx2` (or
//!    `avx2fma`/`avx2+fma`), `avx512` (or `avx512f`), `neon`; any other
//!    value falls through to auto-detection;
//! 3. else CPU features are detected: `Avx512` when AVX-512F is present
//!    (and compiled in), else `Avx2Fma` when AVX2 and FMA are both
//!    present, `Neon` on `aarch64`, `Portable` otherwise.
//!
//! The result is cached in a `OnceLock`, so every `AtomKernel` built in
//! the process — on both the scalar and the parallel backend — uses the
//! same table; that is what lets the bit-identical scalar-vs-parallel
//! contract be stated *per variant*. Requesting a variant the host cannot
//! run (e.g. `avx2` on a non-AVX2 CPU) silently degrades to `Portable`
//! through [`table_for`] — the table constructors are the only way to
//! reach the `target_feature` entry points, which keeps the unsafe
//! feature-gated calls sound by construction.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{OnceLock, RwLock};

#[cfg(target_arch = "x86_64")]
use super::avx2;
#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
use super::avx512;
#[cfg(target_arch = "aarch64")]
use super::neon;
use super::{portable, LANES};

/// Environment variable consulted (once, at first kernel build) to pin the
/// kernel variant: `portable` / `scalar`, `avx2` / `avx2fma` / `avx2+fma`,
/// `avx512` / `avx512f`, or `neon`. Unknown values fall back to
/// auto-detection.
pub const VARIANT_ENV: &str = "CONV_EINSUM_KERNEL_VARIANT";

/// Depth of one cache-blocked GEMM slice: panels cover `KC` values of the
/// contracted index at a time, sized so an A panel (`mr · KC` floats) and
/// the B tile row it streams against stay L1/L2-resident.
pub const KC: usize = 256;

/// Minimum `m · n · k` multiply count before the packed GEMM path engages;
/// below this the packing traffic costs more than the microtile saves and
/// the unblocked per-row loops win.
pub const PACK_MIN_FLOPS: usize = 1 << 14;

/// Signature of the dot-product kernel (`a · b`).
pub type DotFn = fn(&[f32], &[f32]) -> f32;
/// Signature of the axpy kernel (`out[i] += w * a[i]`).
pub type AxpyFn = fn(f32, &[f32], &mut [f32]);
/// Signature of the accumulate kernel (`out[i] += a[i]`).
pub type AddFn = fn(&mut [f32], &[f32]);
/// Signature of the GEMM microtile: `panel(pa, pb, c, cs, rows, kc)`
/// updates the `rows × nr` tile of C (row stride `cs`) from `mr`-row /
/// `nr`-column packed panels, one pure FMA chain per element.
pub type PanelFn = fn(&[f32], &[f32], &mut [f32], usize, usize, usize);

/// The four microkernel implementations. `Ord` on preference is not
/// defined — use [`selected`]/[`table_for`] to resolve one.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// Hand-unrolled autovectorizer-friendly kernels; runs anywhere.
    Portable,
    /// Explicit AVX2 + FMA intrinsics (`x86_64` with both features).
    Avx2Fma,
    /// Explicit AVX-512F intrinsics (`x86_64` with the feature detected
    /// and the `avx512` cargo feature compiled in).
    Avx512,
    /// Explicit NEON intrinsics (`aarch64`).
    Neon,
}

impl Variant {
    /// Stable lowercase name (used in logs, benches, and verify errors).
    pub fn name(self) -> &'static str {
        match self {
            Variant::Portable => "portable",
            Variant::Avx2Fma => "avx2fma",
            Variant::Avx512 => "avx512",
            Variant::Neon => "neon",
        }
    }
}

/// Parameters of a variant's packed-GEMM path.
#[derive(Debug, Clone, Copy)]
pub struct GemmParams {
    /// Microtile rows (register-block height).
    pub mr: usize,
    /// Microtile columns (register-block width).
    pub nr: usize,
    /// Cache-block depth along the contracted index. [`KC`] by default;
    /// per-geometry tunings from the tuning cache may override it (a pure
    /// blocking change — the accumulation order, and therefore the result
    /// bits, are invariant under `kc`).
    pub kc: usize,
    /// Engagement threshold in `m·n·k` multiplies ([`PACK_MIN_FLOPS`] by
    /// default, per-geometry tunable). Unlike `kc`, changing this flips
    /// which kernel path runs, so tuned plans are generation-stamped.
    pub min_flops: usize,
    /// The register-blocked microtile kernel.
    pub panel: PanelFn,
}

impl GemmParams {
    /// Whether the packed path should run a matmul of logical shape
    /// `m × k · k × n`: the contraction must be deep enough to vectorize
    /// (`k >= LANES`), wide enough for at least one full column tile, and
    /// large enough overall to amortize the packing copies.
    pub fn engages(&self, m: usize, n: usize, k: usize) -> bool {
        k >= LANES && n >= self.nr && m.saturating_mul(n).saturating_mul(k) >= self.min_flops
    }
}

/// Minimum atom FLOP estimate before the packed conv-atom panel path
/// engages; below this (the tiny-geometry mirror of the tiny-K GEMM rule)
/// the panel packing traffic costs more than the streamed weight reads it
/// replaces, and the plain run loop wins.
pub const CONV_PACK_MIN_FLOPS: usize = 1 << 14;

/// Ceiling on the conv weight-panel footprint in `f32` elements (16 MiB).
/// The panel duplicates each weight once per head entry that reads it, so
/// degenerate geometries could otherwise blow the workspace up; past this
/// bound the unpacked path is used.
pub const CONV_PACK_MAX_PANEL: usize = 1 << 22;

/// Parameters of the packed conv-atom panel path (the conv-geometry
/// analogue of [`GemmParams`]): weights are re-laid-out into a
/// consumption-ordered, zero-padded panel in the workspace pack buffers so
/// the run-structured inner loops stream them sequentially.
#[derive(Debug, Clone, Copy)]
pub struct ConvPackParams {
    /// Engagement threshold on the atom's FLOP estimate
    /// ([`CONV_PACK_MIN_FLOPS`] by default).
    pub min_flops: usize,
    /// Maximum panel footprint in `f32` elements ([`CONV_PACK_MAX_PANEL`]).
    pub max_panel: usize,
}

impl ConvPackParams {
    /// Whether the packed panel path should run for a conv atom with this
    /// FLOP estimate, `t` reuse rows (the panel is packed once per replay
    /// and re-read for every `t` output row), and `panel_elems` panel
    /// footprint. Packing is a pure data-layout change — engaging or not
    /// never changes result bits for a fixed variant.
    pub fn engages(&self, flops: usize, t: usize, panel_elems: usize) -> bool {
        t >= 2 && panel_elems > 0 && panel_elems <= self.max_panel && flops >= self.min_flops
    }
}

/// The conv-pack parameters for a kernel table (currently
/// variant-independent: the panel layout feeds the same run loops on every
/// variant; routed through the table so per-variant tuning can slot in).
pub fn conv_pack_params(_table: &KernelTable) -> ConvPackParams {
    ConvPackParams {
        min_flops: CONV_PACK_MIN_FLOPS,
        max_panel: CONV_PACK_MAX_PANEL,
    }
}

/// A resolved set of kernel entry points. Tables are `'static`: the safe
/// wrappers inside only ever reach `target_feature` code after the
/// constructors here have verified CPU support.
#[derive(Debug, Clone, Copy)]
pub struct KernelTable {
    /// Which implementation this table carries.
    pub variant: Variant,
    /// Whether `dot`/`axpy` (and the GEMM path) contract with fused
    /// multiply-adds. Scalar edge loops in callers must match: fused
    /// variants use `f32::mul_add`, unfused use `a * b + c`.
    pub fused: bool,
    /// Dot product in this variant's normative order.
    pub dot: DotFn,
    /// `out += w * a` in this variant's normative order.
    pub axpy: AxpyFn,
    /// `out += a` (bit-identical across all variants).
    pub add: AddFn,
    /// Packed cache-blocked GEMM, when the variant has one.
    pub gemm: Option<GemmParams>,
}

/// The always-available fallback; byte-for-byte the accumulation orders of
/// kernel version v1.
static PORTABLE: KernelTable = KernelTable {
    variant: Variant::Portable,
    fused: false,
    dot: portable::dot8,
    axpy: portable::axpy8,
    add: portable::add8,
    gemm: None,
};

#[cfg(target_arch = "x86_64")]
static AVX2_FMA: KernelTable = KernelTable {
    variant: Variant::Avx2Fma,
    fused: true,
    dot: avx2::dot,
    axpy: avx2::axpy,
    add: avx2::add,
    gemm: Some(GemmParams {
        mr: avx2::MR,
        nr: avx2::NR,
        kc: KC,
        min_flops: PACK_MIN_FLOPS,
        panel: avx2::panel,
    }),
};

#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
static AVX512: KernelTable = KernelTable {
    variant: Variant::Avx512,
    fused: true,
    dot: avx512::dot,
    axpy: avx512::axpy,
    add: avx512::add,
    gemm: Some(GemmParams {
        mr: avx512::MR,
        nr: avx512::NR,
        kc: KC,
        min_flops: PACK_MIN_FLOPS,
        panel: avx512::panel,
    }),
};

#[cfg(target_arch = "aarch64")]
static NEON: KernelTable = KernelTable {
    variant: Variant::Neon,
    fused: true,
    dot: neon::dot,
    axpy: neon::axpy,
    add: neon::add,
    gemm: Some(GemmParams {
        mr: neon::MR,
        nr: neon::NR,
        kc: KC,
        min_flops: PACK_MIN_FLOPS,
        panel: neon::panel,
    }),
};

/// Test/bench override: 0 = none, 1 = portable, 2 = avx2fma, 3 = neon,
/// 4 = avx512.
static FORCED: AtomicU8 = AtomicU8::new(0);

/// The process-wide default, resolved once from env + detection.
static DEFAULT: OnceLock<&'static KernelTable> = OnceLock::new();

/// The table for `v`, degraded to `Portable` when the host cannot run it.
/// This is the only constructor of non-portable tables, which makes the
/// `target_feature` entry points inside them sound: a table exists only if
/// detection succeeded.
pub fn table_for(v: Variant) -> &'static KernelTable {
    match v {
        Variant::Portable => &PORTABLE,
        Variant::Avx2Fma => avx2_table(),
        Variant::Avx512 => avx512_table(),
        Variant::Neon => neon_table(),
    }
}

fn avx512_table() -> &'static KernelTable {
    #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
    {
        if std::arch::is_x86_feature_detected!("avx512f") {
            return &AVX512;
        }
    }
    &PORTABLE
}

fn avx2_table() -> &'static KernelTable {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            return &AVX2_FMA;
        }
    }
    &PORTABLE
}

fn neon_table() -> &'static KernelTable {
    #[cfg(target_arch = "aarch64")]
    {
        return &NEON;
    }
    #[cfg(not(target_arch = "aarch64"))]
    &PORTABLE
}

/// The variant every kernel built in this process uses (see the module
/// docs for the resolution order). Cheap after the first call: one relaxed
/// atomic load plus a `OnceLock` read.
pub fn selected() -> &'static KernelTable {
    match FORCED.load(Ordering::Relaxed) {
        1 => return table_for(Variant::Portable),
        2 => return table_for(Variant::Avx2Fma),
        3 => return table_for(Variant::Neon),
        4 => return table_for(Variant::Avx512),
        _ => {}
    }
    DEFAULT.get_or_init(|| match env_choice() {
        Some(v) => table_for(v),
        None => detect(),
    })
}

/// Pin the process to a variant (`None` restores env/auto selection).
///
/// Test/bench plumbing only: plans compiled while a force is active embed
/// the forced table, and `CompiledPlan::verify` rejects replaying them
/// after the selection changes — so only force in single-process contexts
/// (the per-variant parity suite, the kernel bench section) and restore
/// before touching anything else.
#[doc(hidden)]
pub fn force_variant(v: Option<Variant>) {
    let code = match v {
        None => 0,
        Some(Variant::Portable) => 1,
        Some(Variant::Avx2Fma) => 2,
        Some(Variant::Neon) => 3,
        Some(Variant::Avx512) => 4,
    };
    FORCED.store(code, Ordering::Relaxed);
}

/// Variants this host can actually run, preferred first (`Portable` is
/// always last and always present).
// alloc-ok(fn): cold introspection helper for tests and benches; never
// called on the execution hot path.
pub fn available() -> Vec<Variant> {
    let mut v = Vec::new();
    #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
    {
        if std::arch::is_x86_feature_detected!("avx512f") {
            v.push(Variant::Avx512);
        }
    }
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            v.push(Variant::Avx2Fma);
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        v.push(Variant::Neon);
    }
    v.push(Variant::Portable);
    v
}

/// A tuned per-geometry blocking override (mirror of
/// `cost::tuning::GemmTuning`'s payload, kept dependency-free here).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TunedGemm {
    /// Tuned cache-block depth (clamped to ≥ 1).
    pub kc: usize,
    /// Tuned packed-path engagement threshold (`m·n·k` multiplies).
    pub min_flops: usize,
}

/// Per-geometry blocking overrides installed from the tuning cache, keyed
/// by the forward contraction geometry `(m, n, k)`. Read once per compiled
/// step when its kernel is resolved — never on the replay hot path.
static TUNED: OnceLock<RwLock<HashMap<(usize, usize, usize), TunedGemm>>> = OnceLock::new();

fn tuned_map() -> &'static RwLock<HashMap<(usize, usize, usize), TunedGemm>> {
    TUNED.get_or_init(|| RwLock::new(HashMap::new()))
}

/// Install per-geometry GEMM tunings (cold path: called when the tuning
/// cache loads or records a tuning, not during execution).
pub fn set_gemm_tunings(entries: &[((usize, usize, usize), TunedGemm)]) {
    let mut map = tuned_map().write().unwrap();
    for &(geom, t) in entries {
        map.insert(geom, t);
    }
}

/// Drop all per-geometry tunings (tests and cache clears).
pub fn clear_gemm_tunings() {
    tuned_map().write().unwrap().clear();
}

/// The GEMM parameters a compiled step of forward geometry `m × k · k × n`
/// should embed under `table`: the table's static defaults with any tuned
/// per-geometry `kc` / engagement threshold applied. `None` when the
/// variant has no packed path (portable). Resolved once per compiled
/// step; the embedded copy keeps replays lock-free.
pub fn resolved_gemm(table: &KernelTable, m: usize, n: usize, k: usize) -> Option<GemmParams> {
    let base = table.gemm?;
    let tuned = tuned_map().read().unwrap().get(&(m, n, k)).copied();
    match tuned {
        Some(t) => Some(GemmParams {
            kc: t.kc.max(1),
            min_flops: t.min_flops,
            ..base
        }),
        None => Some(base),
    }
}

fn env_choice() -> Option<Variant> {
    let raw = std::env::var(VARIANT_ENV).ok()?;
    match raw.trim().to_ascii_lowercase().as_str() {
        "portable" | "scalar" => Some(Variant::Portable),
        "avx2" | "avx2fma" | "avx2+fma" => Some(Variant::Avx2Fma),
        "avx512" | "avx512f" => Some(Variant::Avx512),
        "neon" => Some(Variant::Neon),
        _ => None,
    }
}

fn detect() -> &'static KernelTable {
    #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
    {
        if std::arch::is_x86_feature_detected!("avx512f") {
            return &AVX512;
        }
    }
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            return &AVX2_FMA;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return &NEON;
    }
    #[cfg(not(target_arch = "aarch64"))]
    &PORTABLE
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn portable_table_is_always_available() {
        let t = table_for(Variant::Portable);
        assert_eq!(t.variant, Variant::Portable);
        assert!(!t.fused);
        assert!(t.gemm.is_none());
    }

    #[test]
    fn table_for_degrades_to_portable_when_unavailable() {
        // Whichever of the SIMD variants the host lacks must degrade; the
        // one it has must come back as itself with a packed GEMM.
        let avail = available();
        for v in [Variant::Avx2Fma, Variant::Avx512, Variant::Neon] {
            let t = table_for(v);
            if avail.contains(&v) {
                assert_eq!(t.variant, v);
                assert!(t.fused);
                let gp = t.gemm.expect("SIMD variants carry a packed GEMM");
                assert!(gp.mr >= 1 && gp.nr >= LANES && gp.kc == KC);
            } else {
                assert_eq!(t.variant, Variant::Portable);
            }
        }
    }

    #[test]
    fn available_ends_with_portable() {
        let avail = available();
        assert_eq!(*avail.last().unwrap(), Variant::Portable);
        assert!(avail.len() <= 3);
    }

    #[test]
    fn conv_pack_engages_requires_reuse_volume_and_bounded_panel() {
        let cp = conv_pack_params(&PORTABLE);
        // Too few reuse rows to amortize the pack.
        assert!(!cp.engages(1 << 20, 1, 1 << 10));
        // Too small overall (the tiny-geometry short-circuit).
        assert!(!cp.engages(CONV_PACK_MIN_FLOPS - 1, 8, 1 << 10));
        // Degenerate: an empty panel never engages.
        assert!(!cp.engages(1 << 20, 8, 0));
        // Panel footprint past the workspace ceiling.
        assert!(!cp.engages(1 << 20, 8, CONV_PACK_MAX_PANEL + 1));
        // Realistic conv geometry.
        assert!(cp.engages(1 << 20, 8, 1 << 16));
    }

    #[test]
    fn engages_requires_depth_width_and_volume() {
        let gp = GemmParams {
            mr: 6,
            nr: 16,
            kc: KC,
            min_flops: PACK_MIN_FLOPS,
            panel: |_, _, _, _, _, _| {},
        };
        // Too shallow: k < LANES.
        assert!(!gp.engages(1000, 1000, LANES - 1));
        // Too narrow: n < nr.
        assert!(!gp.engages(1000, 15, 1000));
        // Too small overall.
        assert!(!gp.engages(4, 16, 8));
        // Large and GEMM-shaped.
        assert!(gp.engages(96, 96, 96));
        // Saturating volume never wraps around.
        assert!(gp.engages(usize::MAX, usize::MAX, usize::MAX));
    }

    #[test]
    fn tuned_geometry_overrides_resolved_gemm() {
        // A geometry no other test compiles: tuning it cannot perturb
        // concurrently running plan tests.
        let geom = (7777usize, 13usize, 9999usize);
        let table = KernelTable {
            variant: Variant::Portable,
            fused: false,
            dot: portable::dot8,
            axpy: portable::axpy8,
            add: portable::add8,
            gemm: Some(GemmParams {
                mr: 6,
                nr: 16,
                kc: KC,
                min_flops: PACK_MIN_FLOPS,
                panel: |_, _, _, _, _, _| {},
            }),
        };
        // Untuned: static defaults come back.
        let base = resolved_gemm(&table, geom.0, geom.1, geom.2).unwrap();
        assert_eq!(base.kc, KC);
        assert_eq!(base.min_flops, PACK_MIN_FLOPS);
        // Tuned: kc and min_flops override, microtile shape untouched.
        set_gemm_tunings(&[(
            geom,
            TunedGemm {
                kc: 64,
                min_flops: 1 << 10,
            },
        )]);
        let tuned = resolved_gemm(&table, geom.0, geom.1, geom.2).unwrap();
        assert_eq!(tuned.kc, 64);
        assert_eq!(tuned.min_flops, 1 << 10);
        assert_eq!(tuned.mr, base.mr);
        assert_eq!(tuned.nr, base.nr);
        // Other geometries are untouched; a gemm-less table stays None.
        let other = resolved_gemm(&table, 1, 2, 3).unwrap();
        assert_eq!(other.kc, KC);
        assert!(resolved_gemm(&PORTABLE, geom.0, geom.1, geom.2).is_none());
        // A zero kc is clamped rather than dividing the blocking by zero.
        set_gemm_tunings(&[(
            geom,
            TunedGemm {
                kc: 0,
                min_flops: 1,
            },
        )]);
        assert_eq!(resolved_gemm(&table, geom.0, geom.1, geom.2).unwrap().kc, 1);
        clear_gemm_tunings();
        assert_eq!(
            resolved_gemm(&table, geom.0, geom.1, geom.2).unwrap().kc,
            KC
        );
    }

    #[test]
    fn forced_variant_overrides_and_restores() {
        force_variant(Some(Variant::Portable));
        assert_eq!(selected().variant, Variant::Portable);
        force_variant(None);
        // Back to env/auto: whatever it is, it must be host-available.
        assert!(available().contains(&selected().variant));
    }
}
