//! Explicit NEON microkernels (`aarch64` only). NEON is a baseline feature
//! of every `aarch64` target the crate supports, so
//! [`crate::kernels::dispatch`] selects this variant unconditionally there
//! (the `CONV_EINSUM_KERNEL_VARIANT=portable` override still forces the
//! fallback).
//!
//! # Accumulation order (normative for the `Neon` variant)
//!
//! The orders mirror the AVX2+FMA variant with 4-lane registers:
//!
//! * [`dot`] — two 4-lane fused accumulators form the same 8 logical lanes
//!   as the portable kernel (`acc[l] = fma(a, b, acc[l])` per 8-element
//!   block), combined pairwise, then a fused sequential ragged tail.
//! * [`axpy`] — each element updated exactly once with a single fused
//!   multiply-add, vector body and scalar tail alike.
//! * [`add`] — plain addition, no reassociation: bit-identical to the
//!   portable [`crate::kernels::portable::add8`].
//! * [`panel`] — the 8×8 GEMM microtile: per output element one pure FMA
//!   chain over `k` ascending with the accumulator loaded from and stored
//!   back to C, invariant under tiling, `KC` blocking, and row
//!   partitioning.
//!
//! Scalar edges use [`f32::mul_add`], bit-identical to the hardware
//! `vfmaq_f32` the vector body performs on the same operands.
//!
//! Every intrinsic call sits in an explicit `unsafe` block (the crate
//! denies `unsafe_op_in_unsafe_fn`) with a `SAFETY:` comment;
//! `tools/hotpath_lint.rs` additionally checks that every
//! `#[target_feature]` function here is declared `unsafe fn`.

// On newer toolchains arch intrinsics are safe to call inside a matching
// `#[target_feature]` context, which would flag the explicit blocks below
// as unused; older toolchains (through the crate's 1.70 MSRV) require them.
#![allow(unused_unsafe)]

use super::LANES;
use core::arch::aarch64::{vaddq_f32, vdupq_n_f32, vfmaq_f32, vld1q_f32, vst1q_f32};

/// NEON register width in `f32` lanes.
const VL: usize = 4;

/// Microtile rows of the packed GEMM kernel (16 of 32 q registers hold
/// accumulators: 8 rows × 2 halves of 8 columns).
pub const MR: usize = 8;
/// Microtile columns (two 4-lane registers wide).
pub const NR: usize = 8;

/// Safe entry installed in the `Neon` [`crate::kernels::dispatch::KernelTable`].
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: NEON is a baseline feature of the `aarch64` targets this
    // module is compiled for; `dispatch::table_for` re-checks availability
    // before handing out this table.
    unsafe { dot_neon(a, b) }
}

/// Safe entry installed in the `Neon` [`crate::kernels::dispatch::KernelTable`].
pub fn axpy(w: f32, a: &[f32], out: &mut [f32]) {
    // SAFETY: NEON is baseline on `aarch64` (see `dot` above).
    unsafe { axpy_neon(w, a, out) }
}

/// Safe entry installed in the `Neon` [`crate::kernels::dispatch::KernelTable`].
pub fn add(out: &mut [f32], a: &[f32]) {
    // SAFETY: NEON is baseline on `aarch64` (see `dot` above).
    unsafe { add_neon(out, a) }
}

/// Safe entry installed in the `Neon` [`crate::kernels::dispatch::GemmParams`].
pub fn panel(pa: &[f32], pb: &[f32], c: &mut [f32], cs: usize, rows: usize, kc: usize) {
    // SAFETY: NEON is baseline on `aarch64` (see `dot` above).
    unsafe { panel_neon(pa, pb, c, cs, rows, kc) }
}

/// # Safety
///
/// Requires NEON (baseline on `aarch64`; enabled via `target_feature`).
#[target_feature(enable = "neon")]
unsafe fn dot_neon(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let blocks = a.len() / LANES;
    let split = blocks * LANES;
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    // SAFETY: the broadcast has no memory preconditions.
    let mut acc_lo = unsafe { vdupq_n_f32(0.0) };
    let mut acc_hi = acc_lo;
    for k in 0..blocks {
        // SAFETY: `k * LANES + LANES <= split <= len` for both slices, so
        // the two 4-float loads per operand stay in bounds.
        unsafe {
            let x0 = vld1q_f32(ap.add(k * LANES));
            let x1 = vld1q_f32(ap.add(k * LANES + VL));
            let y0 = vld1q_f32(bp.add(k * LANES));
            let y1 = vld1q_f32(bp.add(k * LANES + VL));
            acc_lo = vfmaq_f32(acc_lo, x0, y0);
            acc_hi = vfmaq_f32(acc_hi, x1, y1);
        }
    }
    let mut lanes = [0.0f32; LANES];
    // SAFETY: `lanes` holds exactly 8 f32s, split into two 4-float stores.
    unsafe {
        vst1q_f32(lanes.as_mut_ptr(), acc_lo);
        vst1q_f32(lanes.as_mut_ptr().add(VL), acc_hi);
    }
    let mut total = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
        + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]));
    for i in split..a.len() {
        total = a[i].mul_add(b[i], total);
    }
    total
}

/// # Safety
///
/// Requires NEON (baseline on `aarch64`).
#[target_feature(enable = "neon")]
unsafe fn axpy_neon(w: f32, a: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), out.len());
    let blocks = out.len() / VL;
    let split = blocks * VL;
    let ap = a.as_ptr();
    let op = out.as_mut_ptr();
    // SAFETY: the broadcast has no memory preconditions.
    let wv = unsafe { vdupq_n_f32(w) };
    for k in 0..blocks {
        // SAFETY: `k * VL + VL <= split <= len` keeps the load and store in
        // bounds; `a` and `out` are distinct slices (&/&mut), no aliasing.
        unsafe {
            let x = vld1q_f32(ap.add(k * VL));
            let o = vld1q_f32(op.add(k * VL));
            vst1q_f32(op.add(k * VL), vfmaq_f32(o, wv, x));
        }
    }
    for i in split..out.len() {
        out[i] = w.mul_add(a[i], out[i]);
    }
}

/// # Safety
///
/// Requires NEON (baseline on `aarch64`).
#[target_feature(enable = "neon")]
unsafe fn add_neon(out: &mut [f32], a: &[f32]) {
    debug_assert_eq!(a.len(), out.len());
    let blocks = out.len() / VL;
    let split = blocks * VL;
    let ap = a.as_ptr();
    let op = out.as_mut_ptr();
    for k in 0..blocks {
        // SAFETY: in-bounds as in `axpy_neon`; distinct slices, no aliasing.
        unsafe {
            let x = vld1q_f32(ap.add(k * VL));
            let o = vld1q_f32(op.add(k * VL));
            vst1q_f32(op.add(k * VL), vaddq_f32(o, x));
        }
    }
    for i in split..out.len() {
        out[i] += a[i];
    }
}

/// The 8×8 FMA microtile over packed panels: `C[r][j]` is loaded, updated
/// by `kc` fused multiply-adds in `k`-ascending order, and stored back.
/// Rows `rows..MR` read the A panel's zero padding into never-stored
/// accumulators.
///
/// # Safety
///
/// Requires NEON (baseline on `aarch64`); the caller must pass panels with
/// `pa.len() >= kc * MR`, `pb.len() >= kc * NR`, `1 <= rows <= MR`,
/// `cs >= NR` and `c.len() >= (rows - 1) * cs + NR` (all debug-asserted).
#[target_feature(enable = "neon")]
unsafe fn panel_neon(pa: &[f32], pb: &[f32], c: &mut [f32], cs: usize, rows: usize, kc: usize) {
    debug_assert!(rows >= 1 && rows <= MR);
    debug_assert!(cs >= NR);
    debug_assert!(pa.len() >= kc * MR);
    debug_assert!(pb.len() >= kc * NR);
    debug_assert!(c.len() >= (rows - 1) * cs + NR);
    // SAFETY: the broadcast has no memory preconditions.
    let zero = unsafe { vdupq_n_f32(0.0) };
    let mut acc = [[zero; 2]; MR];
    let cp = c.as_mut_ptr();
    for (r, accr) in acc.iter_mut().enumerate().take(rows) {
        // SAFETY: `r < rows`, so `r * cs + NR <= c.len()` (asserted above).
        unsafe {
            accr[0] = vld1q_f32(cp.add(r * cs));
            accr[1] = vld1q_f32(cp.add(r * cs + VL));
        }
    }
    let pap = pa.as_ptr();
    let pbp = pb.as_ptr();
    for k in 0..kc {
        // SAFETY: `k < kc` and the panel-length asserts above keep every
        // load in bounds (`k * NR + NR <= kc * NR`, `k * MR + MR <= kc * MR`).
        unsafe {
            let b0 = vld1q_f32(pbp.add(k * NR));
            let b1 = vld1q_f32(pbp.add(k * NR + VL));
            for (r, accr) in acc.iter_mut().enumerate() {
                let av = vdupq_n_f32(*pap.add(k * MR + r));
                accr[0] = vfmaq_f32(accr[0], av, b0);
                accr[1] = vfmaq_f32(accr[1], av, b1);
            }
        }
    }
    for (r, accr) in acc.iter().enumerate().take(rows) {
        // SAFETY: `r < rows`, bounds as for the loads above; rows are
        // `cs >= NR` apart, so stores to different rows never overlap.
        unsafe {
            vst1q_f32(cp.add(r * cs), accr[0]);
            vst1q_f32(cp.add(r * cs + VL), accr[1]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Scalar emulation of the NEON dot order (identical lane structure to
    /// the AVX2 variant): fused lanes, pairwise combine, fused tail.
    fn dot_reference(a: &[f32], b: &[f32]) -> f32 {
        let blocks = a.len() / LANES;
        let mut acc = [0.0f32; LANES];
        for k in 0..blocks {
            for (l, accl) in acc.iter_mut().enumerate() {
                *accl = a[k * LANES + l].mul_add(b[k * LANES + l], *accl);
            }
        }
        let mut total = ((acc[0] + acc[1]) + (acc[2] + acc[3]))
            + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
        for i in blocks * LANES..a.len() {
            total = a[i].mul_add(b[i], total);
        }
        total
    }

    #[test]
    fn dot_matches_scalar_fma_emulation_on_ragged_lengths() {
        let mut rng = Rng::new(311);
        for len in 0..=41 {
            let a: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            assert_eq!(
                dot(&a, &b).to_bits(),
                dot_reference(&a, &b).to_bits(),
                "len {len}"
            );
        }
    }

    #[test]
    fn axpy_matches_scalar_fma_on_ragged_lengths() {
        let mut rng = Rng::new(312);
        for len in 0..=41 {
            let a: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let init: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let w = rng.normal_f32(0.0, 2.0);
            let mut got = init.clone();
            axpy(w, &a, &mut got);
            for (i, g) in got.iter().enumerate() {
                let want = w.mul_add(a[i], init[i]);
                assert_eq!(g.to_bits(), want.to_bits(), "len {len} idx {i}");
            }
        }
    }

    #[test]
    fn add_bit_identical_to_portable() {
        let mut rng = Rng::new(313);
        for len in 0..=41 {
            let a: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let init: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut got = init.clone();
            add(&mut got, &a);
            let mut want = init;
            crate::kernels::portable::add8(&mut want, &a);
            for (g, w_) in got.iter().zip(&want) {
                assert_eq!(g.to_bits(), w_.to_bits(), "len {len}");
            }
        }
    }

    #[test]
    fn panel_matches_pure_fma_chain() {
        let mut rng = Rng::new(314);
        for rows in 1..=MR {
            let kc = 7;
            let pa: Vec<f32> = (0..kc * MR).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let pb: Vec<f32> = (0..kc * NR).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let c0: Vec<f32> = (0..rows * NR).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut c = c0.clone();
            panel(&pa, &pb, &mut c, NR, rows, kc);
            for r in 0..rows {
                for j in 0..NR {
                    let mut want = c0[r * NR + j];
                    for k in 0..kc {
                        want = pa[k * MR + r].mul_add(pb[k * NR + j], want);
                    }
                    assert_eq!(
                        c[r * NR + j].to_bits(),
                        want.to_bits(),
                        "rows {rows} r {r} j {j}"
                    );
                }
            }
        }
    }
}
