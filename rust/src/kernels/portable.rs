//! Portable 8-lane `f32` microkernels — the fallback [`crate::kernels::dispatch::Variant`]
//! and the baseline every explicit-SIMD variant is benchmarked against.
//!
//! These are the original hand-unrolled kernels: fixed-size `chunks_exact`
//! bodies with no bounds checks and independent accumulator lanes, written
//! so the autovectorizer compiles them to packed SIMD on any target. Their
//! accumulation orders are *normative for the portable variant* (see the
//! [`crate::kernels`] module docs for the v2 per-variant contract):
//!
//! * [`axpy8`] / [`add8`] touch each output element exactly once
//!   (`out[i] += w * a[i]`), so unrolling performs no reassociation at all —
//!   they are bit-identical to the naive element loop.
//! * [`dot8`] accumulates block `k` lane-wise into 8 independent lanes
//!   (`acc[l] += a[8k + l] * b[8k + l]`), then combines lanes pairwise as
//!   `((acc0+acc1)+(acc2+acc3)) + ((acc4+acc5)+(acc6+acc7))`, then folds the
//!   ragged tail sequentially onto that total in index order. Any scalar
//!   emulation of this order reproduces the result bit-for-bit (the
//!   property suite checks ragged lengths 0..=41).
//!
//! The portable variant carries no packed-GEMM microkernel
//! ([`crate::kernels::dispatch::KernelTable::gemm`] is `None`): the matmul
//! paths in [`crate::exec::atom`] fall back to the unblocked
//! [`dot8`]-per-row / [`axpy8`]-per-row loops, exactly as in accumulation
//! order v1.

use super::LANES;

/// `out[i] += w * a[i]` over 8-lane blocks plus a sequential tail.
/// Bit-identical to the naive element loop (each element is touched once).
#[inline]
pub fn axpy8(w: f32, a: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), out.len());
    let blocks = out.len() / LANES;
    let split = blocks * LANES;
    let (a_main, a_tail) = a.split_at(split);
    let (o_main, o_tail) = out.split_at_mut(split);
    for (o, s) in o_main.chunks_exact_mut(LANES).zip(a_main.chunks_exact(LANES)) {
        o[0] += w * s[0];
        o[1] += w * s[1];
        o[2] += w * s[2];
        o[3] += w * s[3];
        o[4] += w * s[4];
        o[5] += w * s[5];
        o[6] += w * s[6];
        o[7] += w * s[7];
    }
    for (o, s) in o_tail.iter_mut().zip(a_tail) {
        *o += w * s;
    }
}

/// `out[i] += a[i]` over 8-lane blocks plus a sequential tail.
/// Bit-identical to the naive element loop.
#[inline]
pub fn add8(out: &mut [f32], a: &[f32]) {
    debug_assert_eq!(a.len(), out.len());
    let blocks = out.len() / LANES;
    let split = blocks * LANES;
    let (a_main, a_tail) = a.split_at(split);
    let (o_main, o_tail) = out.split_at_mut(split);
    for (o, s) in o_main.chunks_exact_mut(LANES).zip(a_main.chunks_exact(LANES)) {
        o[0] += s[0];
        o[1] += s[1];
        o[2] += s[2];
        o[3] += s[3];
        o[4] += s[4];
        o[5] += s[5];
        o[6] += s[6];
        o[7] += s[7];
    }
    for (o, s) in o_tail.iter_mut().zip(a_tail) {
        *o += s;
    }
}

/// Dot product in the portable variant's 8-lane blocked order (see module
/// docs): lane-parallel block accumulation, pairwise lane combine,
/// sequential ragged tail.
#[inline]
pub fn dot8(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let blocks = a.len() / LANES;
    let split = blocks * LANES;
    let (a_main, a_tail) = a.split_at(split);
    let (b_main, b_tail) = b.split_at(split);
    let mut acc = [0.0f32; LANES];
    for (x, y) in a_main.chunks_exact(LANES).zip(b_main.chunks_exact(LANES)) {
        acc[0] += x[0] * y[0];
        acc[1] += x[1] * y[1];
        acc[2] += x[2] * y[2];
        acc[3] += x[3] * y[3];
        acc[4] += x[4] * y[4];
        acc[5] += x[5] * y[5];
        acc[6] += x[6] * y[6];
        acc[7] += x[7] * y[7];
    }
    let mut total =
        ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for (x, y) in a_tail.iter().zip(b_tail) {
        total += x * y;
    }
    total
}
