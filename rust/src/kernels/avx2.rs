//! Explicit AVX2 + FMA microkernels (`x86_64` only), selected at runtime by
//! [`crate::kernels::dispatch`] after `is_x86_feature_detected!` confirms
//! both features.
//!
//! # Accumulation order (normative for the `Avx2Fma` variant)
//!
//! * [`dot`] — 8 fused lanes per block (`acc[l] = fma(a, b, acc[l])`), the
//!   same pairwise lane combine as the portable variant, then a fused
//!   sequential ragged tail (`total = fma(a[i], b[i], total)`).
//! * [`axpy`] — each element updated exactly once with a single fused
//!   multiply-add (`out[i] = fma(w, a[i], out[i])`), vector body and scalar
//!   tail alike.
//! * [`add`] — plain addition, no reassociation: bit-identical to the
//!   portable [`crate::kernels::portable::add8`].
//! * [`panel`] — the 6×16 GEMM microtile: for every output element the
//!   accumulator is *loaded from C* and updated by one pure FMA chain over
//!   `k` ascending, so the result per element is independent of the
//!   `MR`/`NR` tiling, the `KC` blocking (C is stored and reloaded
//!   exactly), and any row partitioning across worker threads.
//!
//! Scalar edges use [`f32::mul_add`], which the IEEE contract makes
//! bit-identical to the hardware FMA the vector body performs — the scalar
//! column-edge loop in the GEMM driver therefore extends the exact same
//! per-element chains.
//!
//! Every intrinsic call sits in an explicit `unsafe` block (the crate
//! denies `unsafe_op_in_unsafe_fn`) with its obligation discharged in a
//! `SAFETY:` comment; `tools/hotpath_lint.rs` additionally checks that
//! every `#[target_feature]` function here is declared `unsafe fn`.

// On newer toolchains arch intrinsics are safe to call inside a matching
// `#[target_feature]` context, which would flag the explicit blocks below
// as unused; older toolchains (through the crate's 1.70 MSRV) require them.
#![allow(unused_unsafe)]

use super::LANES;
use core::arch::x86_64::{
    _mm256_add_ps, _mm256_fmadd_ps, _mm256_loadu_ps, _mm256_set1_ps, _mm256_setzero_ps,
    _mm256_storeu_ps,
};

/// Microtile rows of the packed GEMM kernel (12 of 16 ymm registers hold
/// accumulators: 6 rows × 2 halves of 16 columns).
pub const MR: usize = 6;
/// Microtile columns (two 8-lane registers wide).
pub const NR: usize = 16;

/// Safe entry installed in the `Avx2Fma` [`crate::kernels::dispatch::KernelTable`].
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: this function is only reachable through the table returned by
    // `dispatch::table_for(Variant::Avx2Fma)`, which is handed out only
    // after `is_x86_feature_detected!` confirmed "avx2" and "fma".
    unsafe { dot_avx2(a, b) }
}

/// Safe entry installed in the `Avx2Fma` [`crate::kernels::dispatch::KernelTable`].
pub fn axpy(w: f32, a: &[f32], out: &mut [f32]) {
    // SAFETY: reachable only via the detection-gated Avx2Fma table (see
    // `dot` above).
    unsafe { axpy_avx2(w, a, out) }
}

/// Safe entry installed in the `Avx2Fma` [`crate::kernels::dispatch::KernelTable`].
pub fn add(out: &mut [f32], a: &[f32]) {
    // SAFETY: reachable only via the detection-gated Avx2Fma table (see
    // `dot` above).
    unsafe { add_avx2(out, a) }
}

/// Safe entry installed in the `Avx2Fma` [`crate::kernels::dispatch::GemmParams`].
pub fn panel(pa: &[f32], pb: &[f32], c: &mut [f32], cs: usize, rows: usize, kc: usize) {
    // SAFETY: reachable only via the detection-gated Avx2Fma table (see
    // `dot` above).
    unsafe { panel_avx2(pa, pb, c, cs, rows, kc) }
}

/// # Safety
///
/// Requires AVX2 and FMA; the caller must have verified CPU support (the
/// safe wrappers above are only installed after feature detection).
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let blocks = a.len() / LANES;
    let split = blocks * LANES;
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    // SAFETY: no memory preconditions; AVX2 is enabled on this function.
    let mut acc = unsafe { _mm256_setzero_ps() };
    for k in 0..blocks {
        // SAFETY: `k * LANES + LANES <= split <= len` for both slices, so
        // the unaligned 8-float loads stay in bounds.
        unsafe {
            let x = _mm256_loadu_ps(ap.add(k * LANES));
            let y = _mm256_loadu_ps(bp.add(k * LANES));
            acc = _mm256_fmadd_ps(x, y, acc);
        }
    }
    let mut lanes = [0.0f32; LANES];
    // SAFETY: `lanes` holds exactly 8 f32s; unaligned store is permitted.
    unsafe { _mm256_storeu_ps(lanes.as_mut_ptr(), acc) };
    let mut total = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
        + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]));
    for i in split..a.len() {
        total = a[i].mul_add(b[i], total);
    }
    total
}

/// # Safety
///
/// Requires AVX2 and FMA; the caller must have verified CPU support.
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn axpy_avx2(w: f32, a: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), out.len());
    let blocks = out.len() / LANES;
    let split = blocks * LANES;
    let ap = a.as_ptr();
    let op = out.as_mut_ptr();
    // SAFETY: no memory preconditions for the broadcast.
    let wv = unsafe { _mm256_set1_ps(w) };
    for k in 0..blocks {
        // SAFETY: `k * LANES + LANES <= split <= len` keeps loads and the
        // store in bounds; `a` and `out` are distinct slices (&/&mut), so
        // the accesses never alias.
        unsafe {
            let x = _mm256_loadu_ps(ap.add(k * LANES));
            let o = _mm256_loadu_ps(op.add(k * LANES));
            _mm256_storeu_ps(op.add(k * LANES), _mm256_fmadd_ps(wv, x, o));
        }
    }
    for i in split..out.len() {
        out[i] = w.mul_add(a[i], out[i]);
    }
}

/// # Safety
///
/// Requires AVX2; the caller must have verified CPU support.
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn add_avx2(out: &mut [f32], a: &[f32]) {
    debug_assert_eq!(a.len(), out.len());
    let blocks = out.len() / LANES;
    let split = blocks * LANES;
    let ap = a.as_ptr();
    let op = out.as_mut_ptr();
    for k in 0..blocks {
        // SAFETY: in-bounds as in `axpy_avx2`; distinct slices, no aliasing.
        unsafe {
            let x = _mm256_loadu_ps(ap.add(k * LANES));
            let o = _mm256_loadu_ps(op.add(k * LANES));
            _mm256_storeu_ps(op.add(k * LANES), _mm256_add_ps(o, x));
        }
    }
    for i in split..out.len() {
        out[i] += a[i];
    }
}

/// The 6×16 FMA microtile over packed panels: `C[r][j]` is loaded, updated
/// by `kc` fused multiply-adds in `k`-ascending order, and stored back.
/// Rows `rows..MR` read the A panel's zero padding into never-stored
/// accumulators.
///
/// # Safety
///
/// Requires AVX2 and FMA; the caller must have verified CPU support, and
/// must pass panels with `pa.len() >= kc * MR`, `pb.len() >= kc * NR`,
/// `1 <= rows <= MR`, `cs >= NR` and `c.len() >= (rows - 1) * cs + NR`
/// (all debug-asserted).
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn panel_avx2(pa: &[f32], pb: &[f32], c: &mut [f32], cs: usize, rows: usize, kc: usize) {
    debug_assert!(rows >= 1 && rows <= MR);
    debug_assert!(cs >= NR);
    debug_assert!(pa.len() >= kc * MR);
    debug_assert!(pb.len() >= kc * NR);
    debug_assert!(c.len() >= (rows - 1) * cs + NR);
    // SAFETY: no memory preconditions.
    let zero = unsafe { _mm256_setzero_ps() };
    let mut acc = [[zero; 2]; MR];
    let cp = c.as_mut_ptr();
    for (r, accr) in acc.iter_mut().enumerate().take(rows) {
        // SAFETY: `r < rows`, so `r * cs + NR <= c.len()` (asserted above).
        unsafe {
            accr[0] = _mm256_loadu_ps(cp.add(r * cs));
            accr[1] = _mm256_loadu_ps(cp.add(r * cs + LANES));
        }
    }
    let pap = pa.as_ptr();
    let pbp = pb.as_ptr();
    for k in 0..kc {
        // SAFETY: `k < kc` and the panel-length asserts above keep every
        // load in bounds (`k * NR + NR <= kc * NR`, `k * MR + MR <= kc * MR`).
        unsafe {
            let b0 = _mm256_loadu_ps(pbp.add(k * NR));
            let b1 = _mm256_loadu_ps(pbp.add(k * NR + LANES));
            for (r, accr) in acc.iter_mut().enumerate() {
                let av = _mm256_set1_ps(*pap.add(k * MR + r));
                accr[0] = _mm256_fmadd_ps(av, b0, accr[0]);
                accr[1] = _mm256_fmadd_ps(av, b1, accr[1]);
            }
        }
    }
    for (r, accr) in acc.iter().enumerate().take(rows) {
        // SAFETY: `r < rows`, bounds as for the loads above; rows are
        // `cs >= NR` apart, so the two stores per row never overlap another
        // row's.
        unsafe {
            _mm256_storeu_ps(cp.add(r * cs), accr[0]);
            _mm256_storeu_ps(cp.add(r * cs + LANES), accr[1]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn supported() -> bool {
        std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
    }

    /// Scalar emulation of the AVX2 dot order: fused lanes, pairwise
    /// combine, fused sequential tail.
    fn dot_reference(a: &[f32], b: &[f32]) -> f32 {
        let blocks = a.len() / LANES;
        let mut acc = [0.0f32; LANES];
        for k in 0..blocks {
            for (l, accl) in acc.iter_mut().enumerate() {
                *accl = a[k * LANES + l].mul_add(b[k * LANES + l], *accl);
            }
        }
        let mut total = ((acc[0] + acc[1]) + (acc[2] + acc[3]))
            + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
        for i in blocks * LANES..a.len() {
            total = a[i].mul_add(b[i], total);
        }
        total
    }

    #[test]
    fn dot_matches_scalar_fma_emulation_on_ragged_lengths() {
        if !supported() {
            return;
        }
        let mut rng = Rng::new(301);
        for len in 0..=41 {
            let a: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            assert_eq!(
                dot(&a, &b).to_bits(),
                dot_reference(&a, &b).to_bits(),
                "len {len}"
            );
        }
    }

    #[test]
    fn axpy_matches_scalar_fma_on_ragged_lengths() {
        if !supported() {
            return;
        }
        let mut rng = Rng::new(302);
        for len in 0..=41 {
            let a: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let init: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let w = rng.normal_f32(0.0, 2.0);
            let mut got = init.clone();
            axpy(w, &a, &mut got);
            for (i, g) in got.iter().enumerate() {
                let want = w.mul_add(a[i], init[i]);
                assert_eq!(g.to_bits(), want.to_bits(), "len {len} idx {i}");
            }
        }
    }

    #[test]
    fn add_bit_identical_to_portable() {
        if !supported() {
            return;
        }
        let mut rng = Rng::new(303);
        for len in 0..=41 {
            let a: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let init: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut got = init.clone();
            add(&mut got, &a);
            let mut want = init;
            crate::kernels::portable::add8(&mut want, &a);
            for (g, w_) in got.iter().zip(&want) {
                assert_eq!(g.to_bits(), w_.to_bits(), "len {len}");
            }
        }
    }

    #[test]
    fn panel_matches_pure_fma_chain() {
        if !supported() {
            return;
        }
        let mut rng = Rng::new(304);
        for rows in 1..=MR {
            let kc = 7;
            let pa: Vec<f32> = (0..kc * MR).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let pb: Vec<f32> = (0..kc * NR).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let c0: Vec<f32> = (0..rows * NR).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut c = c0.clone();
            panel(&pa, &pb, &mut c, NR, rows, kc);
            for r in 0..rows {
                for j in 0..NR {
                    let mut want = c0[r * NR + j];
                    for k in 0..kc {
                        want = pa[k * MR + r].mul_add(pb[k * NR + j], want);
                    }
                    assert_eq!(
                        c[r * NR + j].to_bits(),
                        want.to_bits(),
                        "rows {rows} r {r} j {j}"
                    );
                }
            }
        }
    }
}
