//! Shared scoped worker pool for intra-operator parallelism (std-only).
//!
//! The atomic grouped convolution (paper §3.1) decomposes into independent
//! per-`(group, output-row)` blocks, so the executor's parallel backend
//! splits its output buffer into disjoint row chunks and fans them out over
//! scoped threads. A [`Pool`] is a *concurrency budget* plus an arbitration
//! flag rather than a set of long-lived threads: each [`Pool::run_chunks`]
//! call spawns scoped workers (so borrowed tensor data crosses thread
//! boundaries safely with zero `unsafe`), and a `busy` flag guarantees that
//! concurrent users of the same pool — e.g. several coordinator workers
//! executing batches at once, or a nested parallel region — degrade to
//! serial execution on their own thread instead of oversubscribing the
//! machine with `workers × threads` runnables.
//!
//! The process-wide pool ([`Pool::global`]) sizes itself from the
//! `CONV_EINSUM_THREADS` environment variable when set, falling back to
//! [`std::thread::available_parallelism`]. The coordinator's worker loop and
//! the executor's default [`crate::exec::Backend::Parallel`] backend share
//! this single pool.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

/// A reusable concurrency budget for scoped data-parallel loops.
#[derive(Debug)]
pub struct Pool {
    threads: usize,
    busy: AtomicBool,
}

/// Clears the busy flag even if a worker panics mid-region (the panic is
/// propagated by `thread::scope` after joining, unwinding through this).
struct BusyGuard<'a>(&'a AtomicBool);

impl Drop for BusyGuard<'_> {
    fn drop(&mut self) {
        self.0.store(false, Ordering::Release);
    }
}

impl Pool {
    /// A pool with an explicit thread budget (clamped to ≥ 1).
    pub fn new(threads: usize) -> Pool {
        Pool {
            threads: threads.max(1),
            busy: AtomicBool::new(false),
        }
    }

    /// The process-wide shared pool.
    pub fn global() -> &'static Pool {
        static GLOBAL: OnceLock<Pool> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            let threads = std::env::var("CONV_EINSUM_THREADS")
                .ok()
                .and_then(|v| v.trim().parse::<usize>().ok())
                .filter(|&t| t > 0)
                .unwrap_or_else(|| {
                    std::thread::available_parallelism()
                        .map(|n| n.get())
                        .unwrap_or(4)
                });
            Pool::new(threads)
        })
    }

    /// This pool's thread budget.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Split `out` into contiguous chunks of `chunk` elements (the last may
    /// be shorter) and invoke `f(chunk_index, chunk)` on every chunk, fanned
    /// out across up to `self.threads` scoped worker threads.
    ///
    /// Chunks are assigned round-robin, so uniform per-chunk work balances
    /// well. Falls back to serial execution on the calling thread when the
    /// budget is 1, there is only one chunk, or the pool is already busy
    /// (nested or concurrent use) — never blocks waiting for the pool.
    pub fn run_chunks<F>(&self, out: &mut [f32], chunk: usize, f: F)
    where
        F: Fn(usize, &mut [f32]) + Sync,
    {
        assert!(chunk > 0, "chunk size must be positive");
        let n_chunks = (out.len() + chunk - 1) / chunk;
        let nt = self.threads.min(n_chunks);
        if nt <= 1 || self.busy.swap(true, Ordering::Acquire) {
            for (i, c) in out.chunks_mut(chunk).enumerate() {
                f(i, c);
            }
            return;
        }
        let _guard = BusyGuard(&self.busy);
        let mut buckets: Vec<Vec<(usize, &mut [f32])>> =
            (0..nt).map(|_| Vec::new()).collect();
        for (i, c) in out.chunks_mut(chunk).enumerate() {
            buckets[i % nt].push((i, c));
        }
        let fref = &f;
        std::thread::scope(|s| {
            let mut buckets = buckets.into_iter();
            let first = buckets.next().expect("nt >= 2 buckets");
            for bucket in buckets {
                s.spawn(move || {
                    for (i, c) in bucket {
                        fref(i, c);
                    }
                });
            }
            for (i, c) in first {
                fref(i, c);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn every_chunk_visited_exactly_once() {
        let pool = Pool::new(4);
        let mut data = vec![0.0f32; 100];
        pool.run_chunks(&mut data, 7, |i, c| {
            for v in c.iter_mut() {
                *v += 1.0 + i as f32;
            }
        });
        for (k, &v) in data.iter().enumerate() {
            assert_eq!(v, 1.0 + (k / 7) as f32, "element {k}");
        }
    }

    #[test]
    fn uneven_tail_chunk_has_right_length() {
        let pool = Pool::new(3);
        let mut data = vec![0.0f32; 10];
        let lens = std::sync::Mutex::new(vec![0usize; 4]);
        pool.run_chunks(&mut data, 3, |i, c| {
            lens.lock().unwrap()[i] = c.len();
        });
        assert_eq!(*lens.lock().unwrap(), vec![3, 3, 3, 1]);
    }

    #[test]
    fn single_thread_budget_runs_serially() {
        let pool = Pool::new(1);
        let mut data = vec![0.0f32; 16];
        let count = AtomicUsize::new(0);
        pool.run_chunks(&mut data, 4, |_, c| {
            count.fetch_add(1, Ordering::SeqCst);
            c[0] = 1.0;
        });
        assert_eq!(count.load(Ordering::SeqCst), 4);
        assert!(!pool.busy.load(Ordering::SeqCst));
    }

    #[test]
    fn nested_use_degrades_to_serial_without_deadlock() {
        let pool = Pool::new(4);
        let mut outer = vec![0.0f32; 8];
        pool.run_chunks(&mut outer, 2, |i, c| {
            // Nested region on the same pool: must complete serially.
            let mut inner = vec![0.0f32; 4];
            pool.run_chunks(&mut inner, 1, |j, ic| {
                ic[0] = (i * 10 + j) as f32;
            });
            c[0] = inner.iter().sum();
        });
        for (k, chunk) in outer.chunks(2).enumerate() {
            // Σ_j (10k + j) for j in 0..4 = 40k + 6
            assert_eq!(chunk[0], (40 * k + 6) as f32);
        }
        assert!(!pool.busy.load(Ordering::SeqCst));
    }

    #[test]
    fn busy_flag_clears_after_parallel_run() {
        let pool = Pool::new(2);
        let mut data = vec![0.0f32; 64];
        pool.run_chunks(&mut data, 8, |_, c| c.iter_mut().for_each(|v| *v = 2.0));
        assert!(!pool.busy.load(Ordering::SeqCst));
        assert!(data.iter().all(|&v| v == 2.0));
        // The pool is immediately reusable.
        pool.run_chunks(&mut data, 8, |_, c| c.iter_mut().for_each(|v| *v += 1.0));
        assert!(data.iter().all(|&v| v == 3.0));
    }

    #[test]
    fn global_pool_is_a_singleton_with_positive_budget() {
        let a = Pool::global() as *const Pool;
        let b = Pool::global() as *const Pool;
        assert_eq!(a, b);
        assert!(Pool::global().threads() >= 1);
    }
}
