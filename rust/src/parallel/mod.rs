//! Persistent worker pool for intra-operator parallelism (std-only).
//!
//! The atomic grouped convolution (paper §3.1) decomposes into independent
//! per-`(group, output-row)` blocks, so the executor's parallel backend
//! splits its output buffer into disjoint row chunks and fans them out over
//! the pool. A [`Pool`] owns a set of **long-lived worker threads** parked
//! on a condvar: dispatching a parallel region costs a mutex hand-off and a
//! wake-up (nanoseconds to a few microseconds) instead of the tens of
//! microseconds per-region scoped spawning used to cost — and, crucially,
//! the steady state performs **zero heap allocations**, so a compiled-plan
//! replay on the parallel backend is as allocation-free as the scalar one
//! (`bench_hotpath` asserts both).
//!
//! # Execution model
//!
//! [`Pool::run_chunks`] splits the output into fixed-size chunks and
//! publishes one *job* (an erased pointer to the caller's closure) to the
//! pool's job slot. Workers and the calling thread then claim chunk indices
//! from a shared **lock-free atomic cursor** — each fetch grabs a *batch*
//! of up to `claim` consecutive indices (sized so every participant still
//! gets several fetches per job), so fine-grained regions with hundreds of
//! tiny chunks pay one atomic add per batch instead of a mutex round-trip
//! per chunk. The caller blocks until every chunk has finished executing
//! (an atomic `remaining` counter; the last finisher signals completion).
//! Chunks are claimed dynamically, so load balances even when per-chunk
//! work is uneven, and every chunk is a deterministic function of its
//! index — results are bit-identical regardless of which thread runs which
//! chunk, and identical to serial execution.
//!
//! Workers are started lazily on the first parallel region and live until
//! the pool is dropped ([`Pool::global`] and the [`Pool::sized`] registry
//! entries live for the process). Because the threads persist, everything
//! thread-local to a worker — its stack, lazily-built kernel state —
//! survives across jobs; the coordinator's workers likewise keep their
//! per-thread [`crate::exec::Workspace`]s across requests.
//!
//! A `busy` flag guarantees that concurrent users of the same pool — e.g.
//! several coordinator workers executing batches at once, or a nested
//! parallel region — degrade to serial execution on their own thread
//! instead of oversubscribing the machine with `workers × threads`
//! runnables.
//!
//! The process-wide pool ([`Pool::global`]) sizes itself from
//! [`default_threads`]: the `CONV_EINSUM_THREADS` environment variable when
//! set, falling back to [`std::thread::available_parallelism`]. Explicit
//! `Backend::Parallel { threads: k }` counts resolve through [`Pool::sized`]
//! to persistent per-size pools, so benchmarking at a fixed width also pays
//! spawn cost only once.
//!
//! # Safety
//!
//! The job slot stores a type-erased raw pointer to a closure living on the
//! caller's stack. This is sound because `run_chunks` does not return until
//! every chunk has executed (`remaining == 0`) **and** every worker that
//! joined the job has left its claim loop (`participants == 0`) — i.e.
//! until no thread can still dereference the pointer. Workers only join a
//! job (and bump `participants`) under the slot mutex while the job is
//! still published, so a stale worker can never reach the atomic cursor of
//! a later job with an old `JobRef`. Distinct chunk indices map to disjoint
//! sub-slices of the output, so no two threads ever alias the same
//! `&mut [f32]`.

use std::any::Any;
use std::collections::HashMap;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// Default worker-thread budget: the `CONV_EINSUM_THREADS` environment
/// variable when set to a positive integer, otherwise the machine's
/// [`std::thread::available_parallelism`] (falling back to 4 when that is
/// unavailable). [`Pool::global`] and the coordinator's default worker
/// count both derive from this, replacing the old fixed config constant.
pub fn default_threads() -> usize {
    std::env::var("CONV_EINSUM_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&t| t > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        })
}

/// Human-readable identity of a caught panic payload: the `&str` or
/// `String` message when present (the common cases — `panic!` with a
/// literal or a formatted message), a fixed fallback otherwise. The pool
/// preserves panic identity by re-raising the original payload
/// (`resume_unwind`); callers that must *report* a panic instead of
/// re-raising it — the coordinator's worker supervisor building structured
/// `WorkerCrashed` errors — extract the message with this.
// alloc-ok(fn): cold path — runs only after a caught panic.
pub fn describe_panic(payload: &(dyn Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic with non-string payload".to_string()
    }
}

/// Type-erased reference to the in-flight job: a data pointer to the
/// caller's [`ChunkJob`] plus a monomorphized shim that executes one chunk.
#[derive(Clone, Copy)]
struct JobRef {
    data: *const (),
    // SAFETY contract of `call`: `data` must point at the live `ChunkJob`
    // the shim was monomorphized for (upheld by `run_chunks`, which blocks
    // until every participant is done with the pointee).
    call: unsafe fn(*const (), usize),
}

// SAFETY: the pointee outlives every dereference (see module docs: the
// publishing call blocks until all participants have finished), and the
// closure it points at is `Sync`, so shared access from workers is sound.
unsafe impl Send for JobRef {}

/// The caller-stack job descriptor `JobRef::data` points at.
struct ChunkJob<F> {
    f: *const F,
    base: *mut f32,
    len: usize,
    chunk: usize,
}

/// Execute chunk `i` of the job at `data`: reconstruct the disjoint output
/// sub-slice for that index and invoke the user closure on it.
///
/// SAFETY (caller): `data` must point at a live `ChunkJob<F>` whose `base`/
/// `len` describe a valid `f32` buffer, and no other thread may hold chunk
/// index `i` (guaranteed by the claim cursor).
unsafe fn call_chunk<F: Fn(usize, &mut [f32]) + Sync>(data: *const (), i: usize) {
    // SAFETY: the caller contract guarantees `data` points at a live
    // `ChunkJob<F>` (the publishing `run_chunks` frame blocks until every
    // participant is done, so the pointee outlives this call).
    let job = unsafe { &*(data as *const ChunkJob<F>) };
    let start = i * job.chunk;
    let end = (start + job.chunk).min(job.len);
    // SAFETY: `base`/`len` describe a valid `f32` buffer (they come from a
    // live `&mut [f32]` held by the publisher), `start <= end <= len` by
    // construction, and the claim cursor hands index `i` to exactly one
    // thread, so this `&mut` sub-slice is never aliased.
    let slice = unsafe { std::slice::from_raw_parts_mut(job.base.add(start), end - start) };
    // SAFETY: `job.f` points at the publisher's live closure; `F: Sync`
    // makes shared calls from multiple worker threads sound.
    unsafe { (*job.f)(i, slice) };
}

/// Mutex-protected dispatch state shared between the caller and workers.
/// The lock is taken only at job boundaries — publish, join, leave, panic —
/// never per chunk: claiming runs on the lock-free cursor in [`Shared`].
struct JobSlot {
    /// Monotone job counter; workers remember the last epoch they joined so
    /// a stale wake-up never re-enters a finished job.
    epoch: u64,
    job: Option<JobRef>,
    n_chunks: usize,
    /// Chunk indices grabbed per cursor fetch (≥ 1): sized at publish so
    /// every participant still gets several fetches (dynamic balancing)
    /// while fine-grained regions amortize the claim traffic.
    claim: usize,
    /// Workers currently inside the claim loop for this epoch (the
    /// publishing caller is tracked separately — it waits for this to reach
    /// zero before invalidating the job pointer).
    participants: usize,
    /// First panic payload from a chunk closure; the publishing caller
    /// re-raises it via `resume_unwind`, preserving the original message.
    panic: Option<Box<dyn Any + Send>>,
    shutdown: bool,
}

struct Shared {
    slot: Mutex<JobSlot>,
    /// Workers park here waiting for a new epoch.
    work_cv: Condvar,
    /// The publishing caller parks here waiting for completion.
    done_cv: Condvar,
    /// Next unclaimed chunk index. Claims are plain `fetch_add`s of the
    /// job's `claim` batch size — no lock. Reset under the slot mutex at
    /// publish time; only threads that joined the current epoch (under the
    /// mutex) ever touch it, so overshooting past `n_chunks` is the only
    /// steady-state artifact and is harmless.
    cursor: AtomicUsize,
    /// Chunks not yet fully executed. The thread that finishes the last
    /// chunk takes the slot lock and signals `done_cv`.
    remaining: AtomicUsize,
    /// Threads currently inside a claim loop (workers that joined the job
    /// plus the publishing caller) — the instantaneous activity level read
    /// by [`Pool::utilization`]. Relaxed: it is a monitoring signal, not a
    /// synchronization edge.
    active: AtomicUsize,
}

impl Shared {
    fn new() -> Shared {
        Shared {
            slot: Mutex::new(JobSlot {
                epoch: 0,
                job: None,
                n_chunks: 0,
                claim: 1,
                participants: 0,
                panic: None,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            cursor: AtomicUsize::new(0),
            remaining: AtomicUsize::new(0),
            active: AtomicUsize::new(0),
        }
    }
}

/// Claim and execute chunk batches of the published job until the cursor is
/// exhausted. Run by the caller and by every joined worker. Lock-free on
/// the claim path; the slot mutex is touched only to record a panic or to
/// signal completion of the final chunk.
fn execute_chunks(shared: &Shared, job: JobRef, n_chunks: usize, claim: usize) {
    loop {
        let start = shared.cursor.fetch_add(claim, Ordering::AcqRel);
        if start >= n_chunks {
            return;
        }
        let end = (start + claim).min(n_chunks);
        let mut first_panic: Option<Box<dyn Any + Send>> = None;
        for i in start..end {
            // SAFETY: `job.data` outlives this call (the publisher blocks
            // until `remaining == 0 && participants == 0`), and `i` was
            // claimed from the cursor by this thread alone, satisfying
            // `call_chunk`'s contract.
            let result = catch_unwind(AssertUnwindSafe(|| unsafe { (job.call)(job.data, i) }));
            if let Err(payload) = result {
                // Keep the first payload; the publishing caller re-raises it.
                first_panic.get_or_insert(payload);
            }
        }
        if let Some(payload) = first_panic {
            shared.slot.lock().unwrap().panic.get_or_insert(payload);
        }
        let done = end - start;
        if shared.remaining.fetch_sub(done, Ordering::AcqRel) == done {
            // Last chunk finished. Taking the lock before notifying pairs
            // with the publisher's predicate re-check, so the wake-up can
            // never be lost.
            let _guard = shared.slot.lock().unwrap();
            shared.done_cv.notify_all();
        }
    }
}

/// Worker body: park on the condvar until a new job epoch appears (or
/// shutdown), join it (under the mutex — this is what makes the raw job
/// pointer sound), help drain its chunk batches, then leave.
fn worker_loop(shared: Arc<Shared>) {
    let mut seen = 0u64;
    loop {
        let (job, epoch, n_chunks, claim) = {
            let mut slot = shared.slot.lock().unwrap();
            loop {
                if slot.shutdown {
                    return;
                }
                if let Some(job) = slot.job {
                    if slot.epoch != seen && shared.cursor.load(Ordering::Relaxed) < slot.n_chunks
                    {
                        slot.participants += 1;
                        break (job, slot.epoch, slot.n_chunks, slot.claim);
                    }
                }
                slot = shared.work_cv.wait(slot).unwrap();
            }
        };
        seen = epoch;
        shared.active.fetch_add(1, Ordering::Relaxed);
        execute_chunks(&shared, job, n_chunks, claim);
        shared.active.fetch_sub(1, Ordering::Relaxed);
        let mut slot = shared.slot.lock().unwrap();
        slot.participants -= 1;
        if slot.participants == 0 {
            shared.done_cv.notify_all();
        }
    }
}

/// Target number of cursor fetches per participant and job: high enough
/// that dynamic balancing still works when per-chunk work is uneven, low
/// enough that a fine-grained region (hundreds of tiny chunks) claims many
/// indices per atomic fetch.
const CLAIM_FETCHES_PER_THREAD: usize = 4;

/// A persistent worker pool: `threads - 1` parked worker threads (started
/// lazily; the calling thread is the remaining participant) plus a `busy`
/// arbitration flag. See the module docs for the execution model.
#[derive(Debug)]
pub struct Pool {
    threads: usize,
    busy: AtomicBool,
    shared: OnceLock<Arc<Shared>>,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl std::fmt::Debug for Shared {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("Shared{..}")
    }
}

/// Clears the busy flag even if a chunk closure panics (the panic is
/// re-raised by `run_chunks` after completion, unwinding through this).
struct BusyGuard<'a>(&'a AtomicBool);

impl Drop for BusyGuard<'_> {
    fn drop(&mut self) {
        self.0.store(false, Ordering::Release);
    }
}

impl Pool {
    /// A pool with an explicit thread budget (clamped to ≥ 1). Workers are
    /// spawned on first use and joined when the pool is dropped. For a
    /// shared persistent pool of a given width, prefer [`Pool::sized`].
    pub fn new(threads: usize) -> Pool {
        Pool {
            threads: threads.max(1),
            busy: AtomicBool::new(false),
            shared: OnceLock::new(),
            handles: Mutex::new(Vec::new()), // alloc-ok: one-time pool construction
        }
    }

    /// The process-wide shared pool, sized by [`default_threads`].
    pub fn global() -> &'static Pool {
        static GLOBAL: OnceLock<Pool> = OnceLock::new();
        GLOBAL.get_or_init(|| Pool::new(default_threads()))
    }

    /// The process-wide persistent pool of exactly `threads` workers
    /// (clamped to ≥ 1). Pools are created once per distinct size and live
    /// for the process, so repeated `Backend::Parallel { threads: k }`
    /// executions pay thread-spawn cost once and dispatch allocation-free
    /// afterwards. Common widths (≤ 16) resolve through a lock-free
    /// `OnceLock` table — this lookup sits on the per-step dispatch path of
    /// compiled replays with explicit thread counts, so it must not
    /// serialize concurrent callers on a registry mutex.
    pub fn sized(threads: usize) -> Arc<Pool> {
        const FAST_WIDTHS: usize = 16;
        #[allow(clippy::declare_interior_mutable_const)]
        const EMPTY: OnceLock<Arc<Pool>> = OnceLock::new();
        static FAST: [OnceLock<Arc<Pool>>; FAST_WIDTHS + 1] = [EMPTY; FAST_WIDTHS + 1];
        static REGISTRY: OnceLock<Mutex<HashMap<usize, Arc<Pool>>>> = OnceLock::new();
        let threads = threads.max(1);
        if threads <= FAST_WIDTHS {
            return Arc::clone(
                FAST[threads].get_or_init(|| Arc::new(Pool::new(threads))),
            );
        }
        let reg = REGISTRY.get_or_init(|| Mutex::new(HashMap::new()));
        let mut map = reg.lock().unwrap();
        Arc::clone(
            map.entry(threads)
                .or_insert_with(|| Arc::new(Pool::new(threads))),
        )
    }

    /// This pool's thread budget (including the calling thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Instantaneous fraction of the thread budget currently executing a
    /// parallel region, in `[0.0, 1.0]`. Best-effort monitoring probe (a
    /// pair of relaxed atomic loads — callable at any frequency from any
    /// thread): the coordinator's adaptive batching controller reads it to
    /// decide whether to flush small batches early (idle pool) or hold for
    /// larger ones (saturated pool). A serial fallback caused by the pool
    /// being busy still reads non-zero through the `busy` flag; regions
    /// that bypass the pool machinery entirely (single-thread budgets,
    /// single-chunk jobs) are invisible to the probe — callers wanting a
    /// complete picture combine it with their own in-flight accounting, as
    /// the coordinator does.
    pub fn utilization(&self) -> f64 {
        let busy = usize::from(self.busy.load(Ordering::Relaxed));
        let active = self
            .shared
            .get()
            .map(|s| s.active.load(Ordering::Relaxed))
            .unwrap_or(0);
        (active.max(busy) as f64 / self.threads as f64).min(1.0)
    }

    /// Lazily start the worker threads (budget − 1 of them; the caller is
    /// the last participant). Spawn failures degrade the pool silently —
    /// the dynamic chunk cursor means the caller alone still completes
    /// every job.
    fn shared(&self) -> &Arc<Shared> {
        self.shared.get_or_init(|| {
            let shared = Arc::new(Shared::new());
            let mut handles = self.handles.lock().unwrap();
            for i in 0..self.threads - 1 {
                let s = Arc::clone(&shared);
                if let Ok(h) = std::thread::Builder::new()
                    // alloc-ok: one-time lazy worker spawn, not steady state
                    .name(format!("conv-einsum-pool-{i}"))
                    .spawn(move || worker_loop(s))
                {
                    handles.push(h);
                }
            }
            shared
        })
    }

    /// Split `out` into contiguous chunks of `chunk` elements (the last may
    /// be shorter) and invoke `f(chunk_index, chunk)` on every chunk, fanned
    /// out across the persistent workers plus the calling thread.
    ///
    /// Chunks are claimed dynamically from a shared lock-free cursor in
    /// batches of up to `n_chunks / (threads ·` a small constant `)` indices
    /// per fetch, so uneven per-chunk work still balances while fine-grained
    /// regions do not pay per-chunk synchronization. Falls back to serial
    /// execution on the calling thread when the budget is 1, there is only
    /// one chunk, or the pool is already busy (nested or concurrent use) —
    /// never blocks waiting for the pool. Steady-state dispatch performs no
    /// heap allocation.
    ///
    /// If `f` panics on any chunk, the remaining chunks still complete (or
    /// drain) and the panic is re-raised on the calling thread.
    pub fn run_chunks<F>(&self, out: &mut [f32], chunk: usize, f: F)
    where
        F: Fn(usize, &mut [f32]) + Sync,
    {
        assert!(chunk > 0, "chunk size must be positive");
        // Chaos-test fault site (compiles to a constant `false` without the
        // `fault-injection` feature): an injected panic here unwinds out of
        // the publisher before any job state is touched, exercising
        // panic-identity propagation through the callers' containment.
        let _ = crate::faults::point("parallel.run_chunks.pre");
        let n_chunks = (out.len() + chunk - 1) / chunk;
        if self.threads <= 1 || n_chunks <= 1 || self.busy.swap(true, Ordering::Acquire) {
            for (i, c) in out.chunks_mut(chunk).enumerate() {
                f(i, c);
            }
            return;
        }
        let guard = BusyGuard(&self.busy);
        let shared = self.shared();
        let ctx = ChunkJob {
            f: &f as *const F,
            base: out.as_mut_ptr(),
            len: out.len(),
            chunk,
        };
        let job = JobRef {
            data: &ctx as *const ChunkJob<F> as *const (),
            call: call_chunk::<F>,
        };
        let claim = (n_chunks / (self.threads * CLAIM_FETCHES_PER_THREAD)).max(1);
        {
            let mut slot = shared.slot.lock().unwrap();
            slot.epoch = slot.epoch.wrapping_add(1);
            slot.job = Some(job);
            slot.n_chunks = n_chunks;
            slot.claim = claim;
            slot.panic = None;
            shared.cursor.store(0, Ordering::Release);
            shared.remaining.store(n_chunks, Ordering::Release);
        }
        // Wake only as many workers as the job can use (the caller takes
        // chunks too): a small region on a wide pool must not thundering-
        // herd every parked worker. A worker that misses its wake-up (e.g.
        // still draining the previous job) re-checks the slot condition
        // before sleeping, so under-notification never strands chunks —
        // the caller drains whatever workers do not claim.
        let wake = (n_chunks - 1).min(self.threads - 1);
        for _ in 0..wake {
            shared.work_cv.notify_one();
        }
        // The caller is a full participant: even if every worker is slow to
        // wake (or failed to spawn), the job completes.
        shared.active.fetch_add(1, Ordering::Relaxed);
        execute_chunks(shared, job, n_chunks, claim);
        shared.active.fetch_sub(1, Ordering::Relaxed);
        let panic = {
            let mut slot = shared.slot.lock().unwrap();
            // Wait until every chunk has executed AND every joined worker
            // has left its claim loop — only then is the stack-held job
            // safe to invalidate (no thread can still hold the pointer).
            while shared.remaining.load(Ordering::Acquire) > 0 || slot.participants > 0 {
                slot = shared.done_cv.wait(slot).unwrap();
            }
            slot.job = None;
            slot.panic.take()
        };
        drop(guard);
        if let Some(payload) = panic {
            resume_unwind(payload);
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        if let Some(shared) = self.shared.get() {
            shared.slot.lock().unwrap().shutdown = true;
            shared.work_cv.notify_all();
            if let Ok(handles) = self.handles.get_mut() {
                for h in handles.drain(..) {
                    let _ = h.join();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn every_chunk_visited_exactly_once() {
        let pool = Pool::new(4);
        let mut data = vec![0.0f32; 100];
        pool.run_chunks(&mut data, 7, |i, c| {
            for v in c.iter_mut() {
                *v += 1.0 + i as f32;
            }
        });
        for (k, &v) in data.iter().enumerate() {
            assert_eq!(v, 1.0 + (k / 7) as f32, "element {k}");
        }
    }

    #[test]
    fn uneven_tail_chunk_has_right_length() {
        let pool = Pool::new(3);
        let mut data = vec![0.0f32; 10];
        let lens = std::sync::Mutex::new(vec![0usize; 4]);
        pool.run_chunks(&mut data, 3, |i, c| {
            lens.lock().unwrap()[i] = c.len();
        });
        assert_eq!(*lens.lock().unwrap(), vec![3, 3, 3, 1]);
    }

    #[test]
    fn single_thread_budget_runs_serially() {
        let pool = Pool::new(1);
        let mut data = vec![0.0f32; 16];
        let count = AtomicUsize::new(0);
        pool.run_chunks(&mut data, 4, |_, c| {
            count.fetch_add(1, Ordering::SeqCst);
            c[0] = 1.0;
        });
        assert_eq!(count.load(Ordering::SeqCst), 4);
        assert!(!pool.busy.load(Ordering::SeqCst));
    }

    #[test]
    fn nested_use_degrades_to_serial_without_deadlock() {
        let pool = Pool::new(4);
        let mut outer = vec![0.0f32; 8];
        pool.run_chunks(&mut outer, 2, |i, c| {
            // Nested region on the same pool: must complete serially.
            let mut inner = vec![0.0f32; 4];
            pool.run_chunks(&mut inner, 1, |j, ic| {
                ic[0] = (i * 10 + j) as f32;
            });
            c[0] = inner.iter().sum();
        });
        for (k, chunk) in outer.chunks(2).enumerate() {
            // Σ_j (10k + j) for j in 0..4 = 40k + 6
            assert_eq!(chunk[0], (40 * k + 6) as f32);
        }
        assert!(!pool.busy.load(Ordering::SeqCst));
    }

    #[test]
    fn busy_flag_clears_after_parallel_run() {
        let pool = Pool::new(2);
        let mut data = vec![0.0f32; 64];
        pool.run_chunks(&mut data, 8, |_, c| c.iter_mut().for_each(|v| *v = 2.0));
        assert!(!pool.busy.load(Ordering::SeqCst));
        assert!(data.iter().all(|&v| v == 2.0));
        // The pool is immediately reusable.
        pool.run_chunks(&mut data, 8, |_, c| c.iter_mut().for_each(|v| *v += 1.0));
        assert!(data.iter().all(|&v| v == 3.0));
    }

    #[test]
    fn workers_persist_across_many_jobs() {
        // Hundreds of back-to-back dispatches on one pool: exercises the
        // epoch protocol (publish → drain → clear) repeatedly and checks
        // every job's result.
        let pool = Pool::new(4);
        let mut data = vec![0.0f32; 256];
        for round in 0..300 {
            pool.run_chunks(&mut data, 16, |i, c| {
                for (k, v) in c.iter_mut().enumerate() {
                    *v = (round * 10_000 + i * 100 + k) as f32;
                }
            });
            for (k, &v) in data.iter().enumerate() {
                assert_eq!(v, (round * 10_000 + (k / 16) * 100 + (k % 16)) as f32);
            }
        }
        assert!(!pool.busy.load(Ordering::SeqCst));
    }

    #[test]
    fn fine_grained_many_chunks_use_batched_claims_correctly() {
        // 1024 tiny chunks on 4 threads → the cursor hands out batches of
        // 64 indices per fetch; every chunk must still run exactly once.
        let pool = Pool::new(4);
        let mut data = vec![0.0f32; 1024 * 3];
        for round in 0..20usize {
            pool.run_chunks(&mut data, 3, |i, c| {
                for (k, v) in c.iter_mut().enumerate() {
                    *v = (round * 100_000 + i * 10 + k) as f32;
                }
            });
            for (k, &v) in data.iter().enumerate() {
                assert_eq!(v, (round * 100_000 + (k / 3) * 10 + (k % 3)) as f32);
            }
        }
        assert!(!pool.busy.load(Ordering::SeqCst));
    }

    #[test]
    fn dropping_a_pool_joins_its_workers() {
        let pool = Pool::new(3);
        let mut data = vec![0.0f32; 32];
        pool.run_chunks(&mut data, 4, |_, c| c.iter_mut().for_each(|v| *v = 1.0));
        drop(pool); // must not hang
        assert!(data.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn panic_in_chunk_propagates_and_pool_stays_usable() {
        let pool = Pool::new(2);
        let mut data = vec![0.0f32; 64];
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run_chunks(&mut data, 8, |i, _| {
                if i == 3 {
                    panic!("chunk 3 exploded");
                }
            });
        }));
        let payload = r.expect_err("panic must propagate to the caller");
        // The original payload is preserved (resume_unwind, not a new panic).
        assert_eq!(payload.downcast_ref::<&str>(), Some(&"chunk 3 exploded"));
        assert!(!pool.busy.load(Ordering::SeqCst), "busy flag must clear");
        // Subsequent jobs still run.
        pool.run_chunks(&mut data, 8, |_, c| c.iter_mut().for_each(|v| *v = 5.0));
        assert!(data.iter().all(|&v| v == 5.0));
    }

    #[test]
    fn concurrent_callers_all_complete() {
        // Several threads race run_chunks on one pool: exactly one fans
        // out, the rest run serially (busy flag), but all finish correctly.
        let pool = Pool::new(4);
        std::thread::scope(|s| {
            for t in 0..4 {
                let pool = &pool;
                s.spawn(move || {
                    for _ in 0..50 {
                        let mut data = vec![0.0f32; 64];
                        pool.run_chunks(&mut data, 8, |i, c| {
                            for v in c.iter_mut() {
                                *v = (t * 1000 + i) as f32;
                            }
                        });
                        for (k, &v) in data.iter().enumerate() {
                            assert_eq!(v, (t * 1000 + k / 8) as f32);
                        }
                    }
                });
            }
        });
        assert!(!pool.busy.load(Ordering::SeqCst));
    }

    #[test]
    fn global_pool_is_a_singleton_with_positive_budget() {
        let a = Pool::global() as *const Pool;
        let b = Pool::global() as *const Pool;
        assert_eq!(a, b);
        assert!(Pool::global().threads() >= 1);
    }

    #[test]
    fn sized_registry_returns_one_pool_per_width() {
        let a = Pool::sized(3);
        let b = Pool::sized(3);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(a.threads(), 3);
        let c = Pool::sized(0); // clamped
        assert_eq!(c.threads(), 1);
        let mut data = vec![0.0f32; 30];
        a.run_chunks(&mut data, 5, |i, c| c.iter_mut().for_each(|v| *v = i as f32));
        for (k, &v) in data.iter().enumerate() {
            assert_eq!(v, (k / 5) as f32);
        }
    }

    #[test]
    fn default_threads_is_positive() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn utilization_probe_reflects_activity() {
        let pool = Pool::new(4);
        assert_eq!(pool.utilization(), 0.0, "idle pool before first job");
        let peak = Mutex::new(0.0f64);
        let mut data = vec![0.0f32; 1 << 14];
        pool.run_chunks(&mut data, 1 << 11, |_, c| {
            // Probed from inside a chunk: at least this thread is active
            // (and the caller's busy flag is set), so the reading is > 0.
            let u = pool.utilization();
            let mut m = peak.lock().unwrap();
            if u > *m {
                *m = u;
            }
            for v in c.iter_mut() {
                *v = 1.0;
            }
        });
        let seen = *peak.lock().unwrap();
        assert!(seen > 0.0, "utilization must be positive mid-job (saw {seen})");
        assert!(seen <= 1.0);
        assert_eq!(pool.utilization(), 0.0, "idle again after the job drains");
    }
}
