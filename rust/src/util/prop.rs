//! A miniature property-testing harness (substitute for `proptest`, which is
//! unavailable offline). Supports seeded case generation and greedy input
//! shrinking on failure.
//!
//! Usage:
//! ```
//! use conv_einsum::util::prop::{check, Gen};
//! check("sum is commutative", 200, |g: &mut Gen| {
//!     let a = g.usize_in(0, 100);
//!     let b = g.usize_in(0, 100);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Rng;

/// Generation context handed to a property closure. Records the *choices*
/// made so failing cases can be shrunk by replaying with smaller choices.
pub struct Gen {
    rng: Rng,
    /// When replaying a shrunk case, choices are served from here.
    replay: Option<Vec<u64>>,
    replay_pos: usize,
    /// Choices made during this run (each paired with its modulus).
    pub trace: Vec<(u64, u64)>,
}

impl Gen {
    fn fresh(seed: u64) -> Gen {
        Gen {
            rng: Rng::new(seed),
            replay: None,
            replay_pos: 0,
            trace: Vec::new(),
        }
    }

    fn replaying(choices: Vec<u64>) -> Gen {
        Gen {
            rng: Rng::new(0),
            replay: Some(choices),
            replay_pos: 0,
            trace: Vec::new(),
        }
    }

    /// Core choice primitive: uniform u64 in [0, modulus).
    fn choice(&mut self, modulus: u64) -> u64 {
        debug_assert!(modulus > 0);
        let v = match &self.replay {
            Some(tape) => {
                let raw = tape.get(self.replay_pos).copied().unwrap_or(0);
                self.replay_pos += 1;
                raw % modulus
            }
            None => self.rng.next_u64() % modulus,
        };
        self.trace.push((v, modulus));
        v
    }

    /// usize uniform in [lo, hi] inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.choice((hi - lo + 1) as u64) as usize
    }

    /// f32 uniform in [lo, hi), quantized to 2^20 steps so shrinking is
    /// meaningful.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        let q = self.choice(1 << 20) as f32 / (1u64 << 20) as f32;
        lo + (hi - lo) * q
    }

    /// Bernoulli(1/2).
    pub fn bool(&mut self) -> bool {
        self.choice(2) == 1
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }

    /// Vec of f32 samples in [lo, hi).
    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// A tensor shape: `rank` dims each in [1, max_dim].
    pub fn shape(&mut self, rank: usize, max_dim: usize) -> Vec<usize> {
        (0..rank).map(|_| self.usize_in(1, max_dim)).collect()
    }
}

/// Result of a property check.
pub struct PropResult {
    pub cases: usize,
    pub shrinks: usize,
}

/// Run `cases` random cases of `prop`. On a panic inside `prop`, greedily
/// shrink the choice tape (halving each choice toward 0) and re-panic with
/// the minimal failing seed/tape information.
pub fn check<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    name: &str,
    cases: usize,
    prop: F,
) -> PropResult {
    check_seeded(name, 0xC0FFEE ^ fxhash(name), cases, prop)
}

fn fxhash(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// As [`check`] but with an explicit base seed.
pub fn check_seeded<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    name: &str,
    seed: u64,
    cases: usize,
    prop: F,
) -> PropResult {
    for case in 0..cases {
        let case_seed = seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen::fresh(case_seed);
        let outcome = run_one(&prop, &mut g);
        if let Err(panic_msg) = outcome {
            // Shrink: repeatedly try halving each choice.
            let mut tape: Vec<u64> = g.trace.iter().map(|&(v, _)| v).collect();
            let mut shrinks = 0;
            let mut improved = true;
            while improved && shrinks < 2000 {
                improved = false;
                for i in 0..tape.len() {
                    if tape[i] == 0 {
                        continue;
                    }
                    for candidate in [0, tape[i] / 2, tape[i].saturating_sub(1)] {
                        if candidate >= tape[i] {
                            continue;
                        }
                        let mut t2 = tape.clone();
                        t2[i] = candidate;
                        let mut g2 = Gen::replaying(t2.clone());
                        if run_one(&prop, &mut g2).is_err() {
                            tape = t2;
                            shrinks += 1;
                            improved = true;
                            break;
                        }
                    }
                }
            }
            // Reproduce the minimal case to extract its message.
            let mut gmin = Gen::replaying(tape.clone());
            let min_msg = run_one(&prop, &mut gmin)
                .err()
                .unwrap_or_else(|| panic_msg.clone());
            panic!(
                "property '{}' failed (case {} of {}, seed {:#x}, {} shrinks)\nminimal tape: {:?}\nfailure: {}",
                name, case, cases, case_seed, shrinks, tape, min_msg
            );
        }
    }
    PropResult { cases, shrinks: 0 }
}

fn run_one<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    prop: &F,
    g: &mut Gen,
) -> Result<(), String> {
    // Silence the default panic hook while probing.
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(g)));
    std::panic::set_hook(prev);
    match res {
        Ok(()) => Ok(()),
        Err(e) => {
            let msg = if let Some(s) = e.downcast_ref::<&str>() {
                s.to_string()
            } else if let Some(s) = e.downcast_ref::<String>() {
                s.clone()
            } else {
                "<non-string panic>".to_string()
            };
            Err(msg)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let r = check("add-commutes", 50, |g| {
            let a = g.usize_in(0, 1000);
            let b = g.usize_in(0, 1000);
            assert_eq!(a + b, b + a);
        });
        assert_eq!(r.cases, 50);
    }

    #[test]
    fn failing_property_shrinks() {
        let result = std::panic::catch_unwind(|| {
            check_seeded("find-42", 99, 500, |g| {
                let x = g.usize_in(0, 100);
                assert!(x < 42, "x too big: {x}");
            });
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(_) => panic!("property should have failed"),
        };
        // Greedy shrink should land exactly on the boundary, 42.
        assert!(msg.contains("x too big: 42"), "got: {msg}");
    }
}
