//! Deterministic pseudo-random number generation (SplitMix64 seeding a
//! xoshiro256**). Substitute for the `rand` crate, which is unavailable in
//! this offline environment. Every stochastic component of the library
//! (weight init, synthetic data, property tests) threads an explicit
//! [`Rng`] so runs are reproducible from a single seed.

/// xoshiro256** PRNG seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit value (xoshiro256**).
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform f32 in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform usize in [0, n). `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Rejection-free: n is tiny relative to 2^64 in all our uses, the
        // modulo bias is ~n/2^64 and irrelevant for test-data generation.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn between(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal sample (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE); // avoid ln(0)
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal f32 with given mean and std.
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fill a vector with `n` uniform f32 in [lo, hi).
    pub fn fill_uniform(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.range_f32(lo, hi)).collect()
    }

    /// Bernoulli with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fork a child generator with an independent stream.
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly-random element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let k = r.between(3, 9);
            assert!((3..=9).contains(&k));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(42);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
