//! Offline substitutes for common ecosystem crates (see DESIGN.md §5):
//! a mini JSON encoder/parser ([`json`]), a deterministic RNG ([`rng`]),
//! a small property-testing harness ([`prop`]), timing helpers
//! ([`timing`]) and a tiny bounded LRU map ([`lru`]).

pub mod json;
pub mod lru;
pub mod prop;
pub mod rng;
pub mod timing;

/// Product of a slice of dimension sizes, as f64 (cost-model friendly —
/// matches opt-einsum which also reports FLOP counts as floats).
pub fn prod_f64(dims: &[usize]) -> f64 {
    dims.iter().map(|&d| d as f64).product()
}

/// Product of a slice of dimension sizes, as usize (element counts).
pub fn prod(dims: &[usize]) -> usize {
    dims.iter().product()
}

/// Human-readable engineering formatting for FLOP counts: `4.212e+05` style,
/// mirroring opt-einsum's `contract_path` report (paper Fig. 1b).
pub fn sci(x: f64) -> String {
    if x == 0.0 {
        return "0.000e+00".to_string();
    }
    let exp = x.abs().log10().floor() as i32;
    let mant = x / 10f64.powi(exp);
    let sign = if exp < 0 { '-' } else { '+' };
    format!("{:.3}e{}{:02}", mant, sign, exp.abs())
}

/// Format a byte count as a human-readable string.
pub fn human_bytes(b: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", b, UNITS[0])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prod_basics() {
        assert_eq!(prod(&[2, 3, 4]), 24);
        assert_eq!(prod(&[]), 1);
        assert_eq!(prod_f64(&[10, 10]), 100.0);
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(12), "12 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn sci_format() {
        assert_eq!(sci(421200.0), "4.212e+05");
        assert_eq!(sci(0.0), "0.000e+00");
        assert_eq!(sci(0.00321), "3.210e-03");
    }
}
