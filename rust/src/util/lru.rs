//! A tiny least-recently-used map for small, bounded caches.
//!
//! Linear-scan over a `Vec` — the per-layer compiled-plan caches this backs
//! ([`crate::coordinator`] layer entries, [`crate::nn::TensorialConv2d`])
//! hold at most a handful of entries, where a scan beats a hash map and the
//! code stays dependency-free. For the larger shared cache see
//! [`crate::exec::PlanCache`].

/// A bounded map evicting the least-recently-used entry on overflow.
/// `get` and `insert` both count as a use.
#[derive(Debug, Clone)]
pub struct LruCache<K, V> {
    capacity: usize,
    tick: u64,
    entries: Vec<(K, V, u64)>,
}

impl<K: PartialEq, V> LruCache<K, V> {
    /// A cache holding at most `capacity` entries (clamped to ≥ 1).
    pub fn new(capacity: usize) -> LruCache<K, V> {
        let capacity = capacity.max(1);
        LruCache {
            capacity,
            tick: 0,
            entries: Vec::with_capacity(capacity),
        }
    }

    /// Look up `key`, marking the entry as most recently used.
    pub fn get(&mut self, key: &K) -> Option<&V> {
        let idx = self.entries.iter().position(|(k, _, _)| k == key)?;
        self.tick += 1;
        self.entries[idx].2 = self.tick;
        Some(&self.entries[idx].1)
    }

    /// Insert (or replace) `key`, evicting the least-recently-used entry
    /// if the cache is full. Returns the evicted `(key, value)` if any.
    pub fn insert(&mut self, key: K, value: V) -> Option<(K, V)> {
        self.tick += 1;
        if let Some(idx) = self.entries.iter().position(|(k, _, _)| k == &key) {
            self.entries[idx] = (key, value, self.tick);
            return None;
        }
        let evicted = if self.entries.len() >= self.capacity {
            let oldest = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, (_, _, t))| *t)
                .map(|(i, _)| i)
                .expect("full cache has an oldest entry");
            let (k, v, _) = self.entries.swap_remove(oldest);
            Some((k, v))
        } else {
            None
        };
        self.entries.push((key, value, self.tick));
        evicted
    }

    /// Whether `key` is resident (does not touch recency).
    pub fn contains(&self, key: &K) -> bool {
        self.entries.iter().any(|(k, _, _)| k == key)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Maximum number of entries retained.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn clear(&mut self) {
        self.entries.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_and_get_roundtrip() {
        let mut c: LruCache<u32, &str> = LruCache::new(2);
        assert!(c.is_empty());
        assert!(c.insert(1, "one").is_none());
        assert!(c.insert(2, "two").is_none());
        assert_eq!(c.get(&1), Some(&"one"));
        assert_eq!(c.get(&3), None);
        assert_eq!(c.len(), 2);
        assert_eq!(c.capacity(), 2);
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c: LruCache<u32, u32> = LruCache::new(2);
        c.insert(1, 10);
        c.insert(2, 20);
        // Touch 1 so 2 is the LRU entry.
        assert_eq!(c.get(&1), Some(&10));
        let evicted = c.insert(3, 30);
        assert_eq!(evicted, Some((2, 20)));
        assert!(c.contains(&1) && c.contains(&3) && !c.contains(&2));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn reinsert_replaces_without_eviction() {
        let mut c: LruCache<u32, u32> = LruCache::new(2);
        c.insert(1, 10);
        c.insert(2, 20);
        assert!(c.insert(1, 11).is_none());
        assert_eq!(c.get(&1), Some(&11));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn capacity_clamped_to_one() {
        let mut c: LruCache<u32, u32> = LruCache::new(0);
        assert_eq!(c.capacity(), 1);
        c.insert(1, 10);
        let evicted = c.insert(2, 20);
        assert_eq!(evicted, Some((1, 10)));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn insert_counts_as_use() {
        let mut c: LruCache<u32, u32> = LruCache::new(2);
        c.insert(1, 10);
        c.insert(2, 20);
        // Re-inserting 1 makes 2 the LRU.
        c.insert(1, 11);
        let evicted = c.insert(3, 30);
        assert_eq!(evicted, Some((2, 20)));
    }

    #[test]
    fn clear_empties() {
        let mut c: LruCache<u32, u32> = LruCache::new(4);
        c.insert(1, 10);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.get(&1), None);
    }
}
