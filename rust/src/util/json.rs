//! Minimal JSON value model with encoder and recursive-descent parser.
//! Substitute for serde_json (unavailable offline). Used for execution
//! plans, artifact manifests, experiment records and coordinator metrics.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so encoded output is
/// deterministically ordered (useful for golden-file tests against the
/// python planner mirror).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    pub fn usize_arr(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Encode to a compact JSON string.
    pub fn encode(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Encode with 2-space indentation.
    pub fn encode_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(*x, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent + 1);
        let pad_close = "  ".repeat(indent);
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    item.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&pad_close);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    write_str(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&pad_close);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn write_num(x: f64, out: &mut String) {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{}", x));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// JSON parse error with byte position.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", word)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 character
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let end = (start + len).min(self.bytes.len());
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let v = Json::obj(vec![
            ("name", Json::str("conv_einsum")),
            ("flops", Json::num(4.212e5)),
            ("path", Json::usize_arr(&[0, 2, 1])),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
        ]);
        let enc = v.encode();
        let back = parse(&enc).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::arr(vec![Json::num(1), Json::obj(vec![("a", Json::arr(vec![]))])]);
        let back = parse(&v.encode_pretty()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parse_numbers() {
        assert_eq!(parse("-3.25e2").unwrap(), Json::Num(-325.0));
        assert_eq!(parse("0").unwrap(), Json::Num(0.0));
    }

    #[test]
    fn parse_strings_with_escapes() {
        assert_eq!(
            parse(r#""a\nb\t\"c\" é""#).unwrap(),
            Json::Str("a\nb\t\"c\" é".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": false}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::str("héllo ⊛ wörld");
        assert_eq!(parse(&v.encode()).unwrap(), v);
    }
}
