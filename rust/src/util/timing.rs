//! Self-timing bench helpers (substitute for criterion, unavailable
//! offline): warmup + median-of-K measurement, simple stats, and a tiny
//! wall-clock stopwatch used by the coordinator metrics.

use std::time::{Duration, Instant};

/// Measurement summary for one benchmark case.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Sample {
    pub fn report(&self) -> String {
        format!(
            "{:<48} median {:>12?}  mean {:>12?}  min {:>12?}  max {:>12?}  (n={})",
            self.name, self.median, self.mean, self.min, self.max, self.iters
        )
    }

    pub fn median_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }
}

/// Run `f` with `warmup` unmeasured iterations then `iters` measured ones;
/// return median/mean/min/max. `f` should do one unit of work per call.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Sample {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    times.sort_unstable();
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<Duration>() / iters as u32;
    Sample {
        name: name.to_string(),
        iters,
        median,
        mean,
        min: times[0],
        max: times[times.len() - 1],
    }
}

/// Time a single invocation (for long-running "epoch"-scale workloads where
/// repeated measurement is impractical).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// A lightweight online histogram for latency metrics: power-of-two bucket
/// boundaries in microseconds.
#[derive(Debug, Clone, Default)]
pub struct LatencyHisto {
    buckets: [u64; 24], // 1us .. ~8.3s
    count: u64,
    total_us: u64,
    max_us: u64,
}

impl LatencyHisto {
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = (64 - us.max(1).leading_zeros() as usize).min(self.buckets.len()) - 1;
        self.buckets[idx] += 1;
        self.count += 1;
        self.total_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total_us as f64 / self.count as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Approximate percentile from bucket midpoints, p in [0,100].
    pub fn percentile_us(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                // midpoint of bucket [2^i, 2^(i+1))
                return (1u64 << i) as f64 * 1.5;
            }
        }
        self.max_us as f64
    }

    pub fn merge(&mut self, other: &LatencyHisto) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.total_us += other.total_us;
        self.max_us = self.max_us.max(other.max_us);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut n = 0usize;
        let s = bench("noop", 2, 10, || n += 1);
        assert_eq!(n, 12);
        assert_eq!(s.iters, 10);
        assert!(s.min <= s.median && s.median <= s.max);
    }

    #[test]
    fn histo_percentiles_monotone() {
        let mut h = LatencyHisto::default();
        for us in [1u64, 10, 100, 1000, 10_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert!(h.percentile_us(50.0) <= h.percentile_us(99.0));
        assert!(h.mean_us() > 0.0);
    }

    #[test]
    fn histo_merge_adds_counts() {
        let mut a = LatencyHisto::default();
        let mut b = LatencyHisto::default();
        a.record(Duration::from_micros(5));
        b.record(Duration::from_micros(500));
        a.merge(&b);
        assert_eq!(a.count(), 2);
    }
}
