//! Dense row-major f32 tensors — the substrate every other module executes
//! on. (ndarray is unavailable offline; this is a purpose-built minimal
//! replacement with exactly the layout operations conv_einsum needs:
//! reshape, permute, mode merge/split, pad, slice, and fast accessors.)
//!
//! Storage is shared copy-on-write (`Arc<Vec<f32>>`): `clone()`, identity
//! `permute`, and `reshape` are O(1) metadata operations; mutation through
//! [`Tensor::data_mut`] copies only when the payload is actually shared.
//!
//! Besides the allocating `Tensor` methods, this module exposes the
//! workspace kernels [`permute_into`], [`sum_axis_into`] and
//! [`gather_into`] that write into caller-provided buffers (and optionally
//! fan out over a [`crate::parallel::Pool`]) — the allocation-free
//! canonicalization pre-pass used by the compiled execution engine
//! ([`crate::exec::CompiledPlan`]) — plus axis-0 batch-formation
//! primitives in allocating and allocation-free pairs:
//! [`Tensor::concat_axis0`] / [`concat_into`] and [`Tensor::split_axis0`]
//! / [`split_axis0_into`]. The coordinator coalesces requests with
//! [`concat_into`] (into a reusable staging tensor) and hands each request
//! its slice of the batched result with [`Tensor::split_axis0`].

use crate::kernels::dispatch;
use crate::parallel::Pool;
use crate::util::rng::Rng;
use std::fmt;
use std::sync::Arc;

/// A dense, contiguous, row-major tensor of f32 values.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Arc<Vec<f32>>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.data.len() <= 16 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

/// Row-major strides for `shape`.
// alloc-ok(fn): returns a fresh stride table; hot paths precompute it.
pub fn strides_for(shape: &[usize]) -> Vec<usize> {
    let mut strides = vec![1usize; shape.len()];
    for i in (0..shape.len().saturating_sub(1)).rev() {
        strides[i] = strides[i + 1] * shape[i + 1];
    }
    strides
}

/// A shape whose element count (or a stride) overflows `usize` — a
/// degenerate or corrupted shape, never a representable tensor. Surfaced as
/// a structured error so layout computations ([`checked_elems`],
/// [`checked_strides_for`], `CompiledPlan` lowering) reject such shapes
/// instead of wrapping silently in release builds.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShapeOverflow {
    pub shape: Vec<usize>,
}

impl fmt::Display for ShapeOverflow {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "shape {:?} has an element count that overflows usize",
            self.shape
        )
    }
}

impl std::error::Error for ShapeOverflow {}

/// Element count of `shape`, or [`ShapeOverflow`] when the product does not
/// fit a `usize` (in release builds the unchecked product would wrap and
/// silently size a buffer wrong).
// alloc-ok(fn): allocates only on the error path.
pub fn checked_elems(shape: &[usize]) -> Result<usize, ShapeOverflow> {
    shape
        .iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .ok_or_else(|| ShapeOverflow {
            shape: shape.to_vec(),
        })
}

/// As [`strides_for`], rejecting shapes whose strides overflow `usize`.
// alloc-ok(fn): returns a fresh stride table; hot paths precompute it.
pub fn checked_strides_for(shape: &[usize]) -> Result<Vec<usize>, ShapeOverflow> {
    let mut strides = vec![1usize; shape.len()];
    for i in (0..shape.len().saturating_sub(1)).rev() {
        strides[i] = strides[i + 1]
            .checked_mul(shape[i + 1])
            .ok_or_else(|| ShapeOverflow {
                shape: shape.to_vec(),
            })?;
    }
    Ok(strides)
}

/// Panicking wrapper over [`checked_elems`] for the allocating
/// constructors: a clear shape-overflow message beats a wrapped size.
fn elems_or_panic(shape: &[usize]) -> usize {
    checked_elems(shape).unwrap_or_else(|e| panic!("{e}"))
}

impl Tensor {
    /// All-zero tensor.
    // alloc-ok(fn): allocating constructor.
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n = elems_or_panic(shape);
        Tensor {
            shape: shape.to_vec(),
            data: Arc::new(vec![0.0; n]),
        }
    }

    /// Tensor filled with `v`.
    // alloc-ok(fn): allocating constructor.
    pub fn full(shape: &[usize], v: f32) -> Tensor {
        let n = elems_or_panic(shape);
        Tensor {
            shape: shape.to_vec(),
            data: Arc::new(vec![v; n]),
        }
    }

    /// Build from data; length must match the shape product.
    // alloc-ok(fn): allocating constructor.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            elems_or_panic(shape),
            data.len(),
            "shape {:?} incompatible with {} elements",
            shape,
            data.len()
        );
        Tensor {
            shape: shape.to_vec(),
            data: Arc::new(data),
        }
    }

    /// Scalar (rank-0) tensor.
    // alloc-ok(fn): allocating constructor.
    pub fn scalar(v: f32) -> Tensor {
        Tensor {
            shape: vec![],
            data: Arc::new(vec![v]),
        }
    }

    /// Uniform random in [lo, hi).
    // alloc-ok(fn): allocating constructor.
    pub fn rand(shape: &[usize], lo: f32, hi: f32, rng: &mut Rng) -> Tensor {
        let n = elems_or_panic(shape);
        Tensor {
            shape: shape.to_vec(),
            data: Arc::new(rng.fill_uniform(n, lo, hi)),
        }
    }

    /// Normal(mean, std) random.
    // alloc-ok(fn): allocating constructor.
    pub fn randn(shape: &[usize], mean: f32, std: f32, rng: &mut Rng) -> Tensor {
        let n = elems_or_panic(shape);
        Tensor {
            shape: shape.to_vec(),
            data: Arc::new((0..n).map(|_| rng.normal_f32(mean, std)).collect()),
        }
    }

    /// Values 0,1,2,... (testing helper).
    // alloc-ok(fn): allocating constructor.
    pub fn iota(shape: &[usize]) -> Tensor {
        let n = elems_or_panic(shape);
        Tensor {
            shape: shape.to_vec(),
            data: Arc::new((0..n).map(|i| i as f32).collect()),
        }
    }

    // ---- accessors -------------------------------------------------------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable view of the payload; copies the data first if it is shared
    /// with another tensor (copy-on-write).
    pub fn data_mut(&mut self) -> &mut [f32] {
        Arc::make_mut(&mut self.data).as_mut_slice()
    }

    pub fn into_vec(self) -> Vec<f32> {
        Arc::try_unwrap(self.data).unwrap_or_else(|shared| (*shared).clone())
    }

    /// Size in bytes of the payload.
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    /// Multi-index read (slow; for tests and reference paths).
    pub fn at(&self, idx: &[usize]) -> f32 {
        debug_assert_eq!(idx.len(), self.shape.len());
        let strides = strides_for(&self.shape);
        let off: usize = idx.iter().zip(strides.iter()).map(|(&i, &s)| i * s).sum();
        self.data[off]
    }

    /// Multi-index write (slow; for tests and reference paths).
    pub fn set(&mut self, idx: &[usize], v: f32) {
        let strides = strides_for(&self.shape);
        let off: usize = idx.iter().zip(strides.iter()).map(|(&i, &s)| i * s).sum();
        Arc::make_mut(&mut self.data)[off] = v;
    }

    // ---- layout ops ------------------------------------------------------

    /// Reinterpret with a new shape of equal element count. O(1).
    // alloc-ok(fn): clones only the shape metadata, never the payload.
    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(
            elems_or_panic(shape),
            self.data.len(),
            "reshape {:?} -> {:?} changes element count",
            self.shape,
            shape
        );
        self.shape = shape.to_vec();
        self
    }

    /// Materializing axis permutation: output axis `i` is input axis
    /// `perm[i]`. Identity permutations (and rank ≤ 1) return a copy-free
    /// clone — O(1) layout-metadata sharing, no element gather.
    // alloc-ok(fn): materializing layout op; hot paths use `permute_into`.
    pub fn permute(&self, perm: &[usize]) -> Tensor {
        assert_eq!(perm.len(), self.shape.len());
        let rank = perm.len();
        if rank <= 1 || perm.iter().enumerate().all(|(i, &p)| i == p) {
            return self.clone();
        }
        let new_shape: Vec<usize> = perm.iter().map(|&p| self.shape[p]).collect();
        let mut out = vec![0.0f32; self.data.len()];
        permute_into(&self.data, &self.shape, perm, &mut out, None);
        Tensor {
            shape: new_shape,
            data: Arc::new(out),
        }
    }

    /// Sum over one axis.
    // alloc-ok(fn): materializing reduction; hot paths use `sum_axis_into`.
    pub fn sum_axis(&self, axis: usize) -> Tensor {
        assert!(axis < self.shape.len());
        let outer: usize = self.shape[..axis].iter().product();
        let inner: usize = self.shape[axis + 1..].iter().product();
        let mut out = vec![0.0f32; outer * inner];
        sum_axis_into(&self.data, &self.shape, axis, &mut out, None);
        let mut shape = self.shape.clone();
        shape.remove(axis);
        Tensor {
            shape,
            data: Arc::new(out),
        }
    }

    /// Insert a broadcast axis of size `size` at `axis` (repeats data).
    // alloc-ok(fn): materializing layout op, not on the compiled hot path.
    pub fn broadcast_axis(&self, axis: usize, size: usize) -> Tensor {
        assert!(axis <= self.shape.len());
        let outer: usize = self.shape[..axis].iter().product();
        let inner: usize = self.shape[axis..].iter().product();
        let mut out = Vec::with_capacity(outer * size * inner);
        for o in 0..outer {
            let chunk = &self.data[o * inner..(o + 1) * inner];
            for _ in 0..size {
                out.extend_from_slice(chunk);
            }
        }
        let mut shape = self.shape.clone();
        shape.insert(axis, size);
        Tensor {
            shape,
            data: Arc::new(out),
        }
    }

    /// Slice `axis` to the half-open range [start, stop).
    // alloc-ok(fn): materializing layout op, not on the compiled hot path.
    pub fn slice_axis(&self, axis: usize, start: usize, stop: usize) -> Tensor {
        assert!(axis < self.shape.len() && start <= stop && stop <= self.shape[axis]);
        let outer: usize = self.shape[..axis].iter().product();
        let mid = self.shape[axis];
        let inner: usize = self.shape[axis + 1..].iter().product();
        let new_mid = stop - start;
        let mut out = Vec::with_capacity(outer * new_mid * inner);
        for o in 0..outer {
            let base = (o * mid + start) * inner;
            out.extend_from_slice(&self.data[base..base + new_mid * inner]);
        }
        let mut shape = self.shape.clone();
        shape[axis] = new_mid;
        Tensor {
            shape,
            data: Arc::new(out),
        }
    }

    /// Concatenate `parts` along axis 0 (the batch mode of layer
    /// expressions). All parts must share the trailing shape; the result's
    /// leading extent is the sum of the parts'. This is the coordinator's
    /// batch-formation primitive — see [`concat_into`] for the
    /// allocation-free variant against a caller-held destination.
    // alloc-ok(fn): allocating batch formation; the coordinator's steady
    // state uses `concat_into` against a reused staging tensor.
    pub fn concat_axis0(parts: &[&Tensor]) -> Tensor {
        assert!(!parts.is_empty(), "concat_axis0 needs at least one part");
        let mut shape = parts[0].shape().to_vec();
        assert!(!shape.is_empty(), "concat_axis0 needs rank >= 1");
        shape[0] = parts.iter().map(|p| p.shape()[0]).sum();
        let mut out = Tensor::zeros(&shape);
        concat_into(parts, &mut out);
        out
    }

    /// Split along axis 0 into consecutive chunks of the given leading
    /// extents (which must sum to this tensor's leading extent) — the
    /// inverse of [`Tensor::concat_axis0`], used to hand each request of a
    /// coalesced batch its slice of the batched result.
    // alloc-ok(fn): allocating split; the steady state uses
    // `split_axis0_into` against caller-held destinations.
    pub fn split_axis0(&self, sizes: &[usize]) -> Vec<Tensor> {
        assert!(!self.shape.is_empty(), "split_axis0 needs rank >= 1");
        assert_eq!(
            sizes.iter().sum::<usize>(),
            self.shape[0],
            "split sizes must sum to the leading extent"
        );
        let mut off = 0usize;
        sizes
            .iter()
            .map(|&b| {
                let t = self.slice_axis(0, off, off + b);
                off += b;
                t
            })
            .collect()
    }

    /// Zero-pad `axis` with `before` zeros in front and `after` behind.
    // alloc-ok(fn): materializing layout op, not on the compiled hot path.
    pub fn pad_axis(&self, axis: usize, before: usize, after: usize) -> Tensor {
        if before == 0 && after == 0 {
            return self.clone();
        }
        let outer: usize = self.shape[..axis].iter().product();
        let mid = self.shape[axis];
        let inner: usize = self.shape[axis + 1..].iter().product();
        let new_mid = mid + before + after;
        let mut out = vec![0.0f32; outer * new_mid * inner];
        for o in 0..outer {
            let src = o * mid * inner;
            let dst = (o * new_mid + before) * inner;
            out[dst..dst + mid * inner].copy_from_slice(&self.data[src..src + mid * inner]);
        }
        let mut shape = self.shape.clone();
        shape[axis] = new_mid;
        Tensor {
            shape,
            data: Arc::new(out),
        }
    }

    // ---- elementwise -----------------------------------------------------

    /// Elementwise map.
    // alloc-ok(fn): materializing elementwise op for tests and setup code.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: Arc::new(self.data.iter().map(|&x| f(x)).collect()),
        }
    }

    /// In-place `self += other` (shapes must match).
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        let d = Arc::make_mut(&mut self.data);
        (dispatch::selected().add)(d, &other.data);
    }

    /// In-place `self *= s`.
    pub fn scale(&mut self, s: f32) {
        let d = Arc::make_mut(&mut self.data);
        for a in d.iter_mut() {
            *a *= s;
        }
    }

    /// In-place axpy: `self += alpha * other` (8-lane microkernel; same
    /// per-element result as the naive loop).
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        let d = Arc::make_mut(&mut self.data);
        (dispatch::selected().axpy)(alpha, &other.data, d);
    }

    /// Sum of all elements.
    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    /// Maximum absolute element difference to `other`.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape, "shape mismatch in comparison");
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative L2 distance ‖a−b‖/(‖b‖+ε).
    pub fn rel_l2(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        let num: f32 = self
            .data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt();
        let den: f32 = other.data.iter().map(|b| b * b).sum::<f32>().sqrt();
        num / (den + 1e-12)
    }

    /// Assert elementwise closeness (for tests).
    pub fn assert_close(&self, other: &Tensor, tol: f32) {
        assert_eq!(self.shape, other.shape, "shape mismatch");
        let d = self.max_abs_diff(other);
        assert!(
            d <= tol,
            "tensors differ: max |Δ| = {} > tol {} (shape {:?})",
            d,
            tol,
            self.shape
        );
    }
}

/// Iterate all multi-indices of `shape` in row-major order, calling `f`.
// alloc-ok(fn): odometer buffer; used by tests and reference paths only.
pub fn for_each_index(shape: &[usize], mut f: impl FnMut(&[usize])) {
    if shape.iter().any(|&d| d == 0) {
        return;
    }
    let mut idx = vec![0usize; shape.len()];
    loop {
        f(&idx);
        // odometer increment
        let mut ax = shape.len();
        loop {
            if ax == 0 {
                return;
            }
            ax -= 1;
            idx[ax] += 1;
            if idx[ax] < shape[ax] {
                break;
            }
            idx[ax] = 0;
        }
    }
}

// ---------------------------------------------------------------------------
// Workspace kernels: canonicalization pre-passes that write into
// caller-provided buffers (no allocation) and optionally fan out over the
// worker pool. Accumulation order per output element matches the allocating
// `Tensor` methods exactly, so results are bit-identical.
// ---------------------------------------------------------------------------

/// Below this many destination elements the `_into` kernels stay serial even
/// when handed a pool: scoped-thread spawn costs tens of µs, which dwarfs
/// small gathers.
const PAR_CANON_MIN_ELEMS: usize = 1 << 14;

/// Ranks up to this use stack-allocated index/stride buffers in
/// [`permute_into`]; larger ranks (never seen in practice) fall back to heap
/// buffers.
const MAX_STACK_RANK: usize = 32;

/// Permute `src` (row-major, `shape`) into `out`: output axis `i` is input
/// axis `perm[i]`. `out.len()` must equal `src.len()`. With `pool`, the
/// output is split into per-thread chunks gathered independently (the gather
/// is order-independent, so the parallel path is bit-identical).
pub fn permute_into(
    src: &[f32],
    shape: &[usize],
    perm: &[usize],
    out: &mut [f32],
    pool: Option<&Pool>,
) {
    let rank = shape.len();
    assert_eq!(perm.len(), rank, "permutation rank mismatch");
    assert_eq!(
        src.len(),
        shape.iter().product::<usize>(),
        "src length does not match shape"
    );
    assert_eq!(out.len(), src.len(), "out length does not match src");
    if rank <= 1 || perm.iter().enumerate().all(|(i, &p)| i == p) {
        out.copy_from_slice(src);
        return;
    }
    // Output shape and, per output axis, its stride in the input.
    let mut shape_buf = [0usize; MAX_STACK_RANK];
    let mut stride_buf = [0usize; MAX_STACK_RANK];
    let shape_vec: Vec<usize>;
    let stride_vec: Vec<usize>;
    let (new_shape, strides): (&[usize], &[usize]) = if rank <= MAX_STACK_RANK {
        let mut in_stride_buf = [0usize; MAX_STACK_RANK];
        let mut s = 1usize;
        for ax in (0..rank).rev() {
            in_stride_buf[ax] = s;
            s *= shape[ax];
        }
        for (i, &p) in perm.iter().enumerate() {
            shape_buf[i] = shape[p];
            stride_buf[i] = in_stride_buf[p];
        }
        (&shape_buf[..rank], &stride_buf[..rank])
    } else {
        let in_strides = strides_for(shape);
        shape_vec = perm.iter().map(|&p| shape[p]).collect(); // alloc-ok: rank > MAX_STACK_RANK fallback
        stride_vec = perm.iter().map(|&p| in_strides[p]).collect(); // alloc-ok: rank > MAX_STACK_RANK fallback
        (&shape_vec, &stride_vec)
    };

    let parallel = match pool {
        Some(p) => p.threads() > 1 && out.len() >= PAR_CANON_MIN_ELEMS,
        None => false,
    };
    if parallel {
        let p = pool.expect("parallel implies pool");
        let chunk = (out.len() + p.threads() - 1) / p.threads();
        p.run_chunks(out, chunk, |ci, c| {
            if rank <= MAX_STACK_RANK {
                let mut idx = [0usize; MAX_STACK_RANK];
                permute_gather(src, c, ci * chunk, new_shape, strides, &mut idx[..rank]);
            } else {
                let mut idx = vec![0usize; rank]; // alloc-ok: rank > MAX_STACK_RANK fallback
                permute_gather(src, c, ci * chunk, new_shape, strides, &mut idx);
            }
        });
    } else if rank <= MAX_STACK_RANK {
        let mut idx = [0usize; MAX_STACK_RANK];
        permute_gather(src, out, 0, new_shape, strides, &mut idx[..rank]);
    } else {
        let mut idx = vec![0usize; rank]; // alloc-ok: rank > MAX_STACK_RANK fallback
        permute_gather(src, out, 0, new_shape, strides, &mut idx);
    }
}

/// Gather `out.len()` permuted elements starting at linear output index
/// `start`, tracking the input offset incrementally (odometer) — O(n) with
/// no per-element multiply.
fn permute_gather(
    src: &[f32],
    out: &mut [f32],
    start: usize,
    new_shape: &[usize],
    strides: &[usize],
    idx: &mut [usize],
) {
    let rank = new_shape.len();
    let mut rem = start;
    let mut in_off = 0usize;
    for ax in (0..rank).rev() {
        let d = new_shape[ax];
        idx[ax] = rem % d;
        rem /= d;
        in_off += idx[ax] * strides[ax];
    }
    for slot in out.iter_mut() {
        *slot = src[in_off];
        for ax in (0..rank).rev() {
            idx[ax] += 1;
            in_off += strides[ax];
            if idx[ax] < new_shape[ax] {
                break;
            }
            in_off -= strides[ax] * new_shape[ax];
            idx[ax] = 0;
        }
    }
}

/// Strided gather into `out` (row-major over `out_shape`): output element
/// `i` reads `src` at the offset `Σ_ax idx_ax · strides[ax]`, where a
/// stride may be **0** — this is how the training engine fuses the VJP
/// un-canonicalization (inverse permute + re-broadcast of pre-summed axes)
/// into one pass with no intermediate tensors. With `accumulate`, values
/// are added (`out[i] += …`) instead of stored — the gradient-accumulation
/// case, elementwise identical to materializing the gather and running
/// [`Tensor::add_assign`]. Each output element is touched exactly once, so
/// the pooled path is bit-identical to the serial one.
pub fn gather_into(
    src: &[f32],
    out_shape: &[usize],
    strides: &[usize],
    out: &mut [f32],
    accumulate: bool,
    pool: Option<&Pool>,
) {
    let rank = out_shape.len();
    assert_eq!(strides.len(), rank, "stride rank mismatch");
    assert_eq!(
        out.len(),
        out_shape.iter().product::<usize>(),
        "out length does not match out_shape"
    );
    if out.is_empty() {
        return;
    }
    let parallel = match pool {
        Some(p) => p.threads() > 1 && out.len() >= PAR_CANON_MIN_ELEMS,
        None => false,
    };
    if parallel {
        let p = pool.expect("parallel implies pool");
        let chunk = (out.len() + p.threads() - 1) / p.threads();
        p.run_chunks(out, chunk, |ci, c| {
            if rank <= MAX_STACK_RANK {
                let mut idx = [0usize; MAX_STACK_RANK];
                gather_span(src, c, ci * chunk, out_shape, strides, accumulate, &mut idx[..rank]);
            } else {
                let mut idx = vec![0usize; rank]; // alloc-ok: rank > MAX_STACK_RANK fallback
                gather_span(src, c, ci * chunk, out_shape, strides, accumulate, &mut idx);
            }
        });
    } else if rank <= MAX_STACK_RANK {
        let mut idx = [0usize; MAX_STACK_RANK];
        gather_span(src, out, 0, out_shape, strides, accumulate, &mut idx[..rank]);
    } else {
        let mut idx = vec![0usize; rank]; // alloc-ok: rank > MAX_STACK_RANK fallback
        gather_span(src, out, 0, out_shape, strides, accumulate, &mut idx);
    }
}

/// Gather `out.len()` strided elements starting at linear output index
/// `start`, tracking the source offset incrementally (odometer; zero
/// strides simply never move it).
#[allow(clippy::too_many_arguments)]
fn gather_span(
    src: &[f32],
    out: &mut [f32],
    start: usize,
    shape: &[usize],
    strides: &[usize],
    accumulate: bool,
    idx: &mut [usize],
) {
    let rank = shape.len();
    let mut rem = start;
    let mut off = 0usize;
    for ax in (0..rank).rev() {
        let d = shape[ax];
        idx[ax] = rem % d;
        rem /= d;
        off += idx[ax] * strides[ax];
    }
    for slot in out.iter_mut() {
        if accumulate {
            *slot += src[off];
        } else {
            *slot = src[off];
        }
        for ax in (0..rank).rev() {
            idx[ax] += 1;
            off += strides[ax];
            if idx[ax] < shape[ax] {
                break;
            }
            off -= strides[ax] * shape[ax];
            idx[ax] = 0;
        }
    }
}

/// Concatenate `parts` along axis 0 into the caller-held `out`
/// (allocation-free; copy-on-write duplicates `out`'s payload once if it is
/// shared). All parts must share `out`'s trailing shape and their leading
/// extents must sum to `out`'s — axis-0 concatenation of row-major tensors
/// is a straight sequential copy, so the batched buffer holds each part's
/// rows contiguously in part order.
pub fn concat_into(parts: &[&Tensor], out: &mut Tensor) {
    assert!(!parts.is_empty(), "concat_into needs at least one part");
    let tail = &parts[0].shape()[1..];
    let mut total = 0usize;
    for p in parts {
        assert!(!p.shape().is_empty(), "concat_into needs rank >= 1");
        assert_eq!(&p.shape()[1..], tail, "concat_into parts must share trailing shape");
        total += p.shape()[0];
    }
    assert!(!out.shape().is_empty(), "concat_into needs rank >= 1");
    assert_eq!(out.shape()[0], total, "out leading extent must equal the sum of parts'");
    assert_eq!(&out.shape()[1..], tail, "out trailing shape must match the parts'");
    let dst = out.data_mut();
    let mut off = 0usize;
    for p in parts {
        dst[off..off + p.len()].copy_from_slice(p.data());
        off += p.len();
    }
}

/// Split `src` along axis 0 into the caller-held `outs` (allocation-free):
/// each destination receives the next `outs[i].shape()[0]` leading rows.
/// The inverse of [`concat_into`]; leading extents must sum to `src`'s and
/// trailing shapes must match.
pub fn split_axis0_into(src: &Tensor, outs: &mut [Tensor]) {
    assert!(!src.shape().is_empty(), "split_axis0_into needs rank >= 1");
    let tail = &src.shape()[1..];
    let total: usize = outs.iter().map(|o| o.shape()[0]).sum();
    assert_eq!(src.shape()[0], total, "split extents must sum to src's leading extent");
    let mut off = 0usize;
    for o in outs.iter_mut() {
        assert_eq!(&o.shape()[1..], tail, "split parts must share src's trailing shape");
        let n = o.len();
        o.data_mut().copy_from_slice(&src.data()[off..off + n]);
        off += n;
    }
}

/// Sum `src` (row-major, `shape`) over `axis` into `out`
/// (`out.len() == src.len() / shape[axis]`). `out` is zeroed first; per
/// output element the summation order over the axis matches
/// [`Tensor::sum_axis`] exactly, so the result is bit-identical (with or
/// without a pool — each output block is owned by one task).
pub fn sum_axis_into(
    src: &[f32],
    shape: &[usize],
    axis: usize,
    out: &mut [f32],
    pool: Option<&Pool>,
) {
    assert!(axis < shape.len(), "axis out of range");
    let outer: usize = shape[..axis].iter().product();
    let mid = shape[axis];
    let inner: usize = shape[axis + 1..].iter().product();
    assert_eq!(src.len(), outer * mid * inner, "src length mismatch");
    assert_eq!(out.len(), outer * inner, "out length mismatch");
    // Pure adds carry no fused-multiply ordering, so the dispatched `add`
    // is bit-identical to the portable kernel on every variant — the
    // sum_axis parity promise above holds regardless of selection.
    let add = dispatch::selected().add;
    let parallel = match pool {
        Some(p) => p.threads() > 1 && out.len() >= PAR_CANON_MIN_ELEMS && inner > 0,
        None => false,
    };
    if parallel && outer == 1 {
        // Leading-axis reduction: split the (single) output block across
        // threads — each task owns a disjoint slice of the output, keeping
        // the serial path's m-ascending accumulation order per element.
        let p = pool.expect("parallel implies pool");
        let chunk = (inner + p.threads() - 1) / p.threads();
        p.run_chunks(out, chunk, |ci, c| {
            let i0 = ci * chunk;
            let clen = c.len();
            for v in c.iter_mut() {
                *v = 0.0;
            }
            for m in 0..mid {
                let base = m * inner + i0;
                add(c, &src[base..base + clen]);
            }
        });
    } else if parallel {
        let p = pool.expect("parallel implies pool");
        // One task per group of whole outer blocks (so a near-last summed
        // axis with tiny `inner` still dispatches ~threads tasks, not one
        // per output element); each output element keeps the serial path's
        // m-ascending accumulation order.
        let blocks_per_task = (outer + p.threads() - 1) / p.threads();
        let chunk = blocks_per_task * inner;
        p.run_chunks(out, chunk, |ci, c| {
            let o0 = ci * blocks_per_task;
            for (bi, block) in c.chunks_mut(inner).enumerate() {
                let o = o0 + bi;
                for v in block.iter_mut() {
                    *v = 0.0;
                }
                for m in 0..mid {
                    let base = (o * mid + m) * inner;
                    add(block, &src[base..base + inner]);
                }
            }
        });
    } else {
        for v in out.iter_mut() {
            *v = 0.0;
        }
        for o in 0..outer {
            for m in 0..mid {
                let sbase = (o * mid + m) * inner;
                let dbase = o * inner;
                add(&mut out[dbase..dbase + inner], &src[sbase..sbase + inner]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_full() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(Tensor::full(&[2], 3.5).data(), &[3.5, 3.5]);
    }

    #[test]
    fn strides_row_major() {
        assert_eq!(strides_for(&[2, 3, 4]), vec![12, 4, 1]);
        assert_eq!(strides_for(&[]), Vec::<usize>::new());
    }

    #[test]
    fn checked_shape_arithmetic_rejects_overflow() {
        assert_eq!(checked_elems(&[2, 3, 4]), Ok(24));
        assert_eq!(checked_elems(&[]), Ok(1));
        assert_eq!(checked_strides_for(&[2, 3, 4]), Ok(vec![12, 4, 1]));
        let huge = [usize::MAX, 2];
        let err = checked_elems(&huge).unwrap_err();
        assert_eq!(err.shape, huge.to_vec());
        assert!(err.to_string().contains("overflows usize"));
        // Strides multiply trailing extents, so overflow needs two huge dims
        // behind the leading axis.
        assert!(checked_strides_for(&[2, usize::MAX, usize::MAX]).is_err());
    }

    #[test]
    #[should_panic(expected = "overflows usize")]
    fn zeros_rejects_overflowing_shape() {
        let _ = Tensor::zeros(&[usize::MAX, usize::MAX]);
    }

    #[test]
    fn index_read_write() {
        let mut t = Tensor::iota(&[2, 3]);
        assert_eq!(t.at(&[1, 2]), 5.0);
        t.set(&[0, 1], 9.0);
        assert_eq!(t.at(&[0, 1]), 9.0);
    }

    #[test]
    fn permute_matches_manual() {
        let t = Tensor::iota(&[2, 3, 4]);
        let p = t.permute(&[2, 0, 1]);
        assert_eq!(p.shape(), &[4, 2, 3]);
        for i in 0..2 {
            for j in 0..3 {
                for k in 0..4 {
                    assert_eq!(p.at(&[k, i, j]), t.at(&[i, j, k]));
                }
            }
        }
    }

    #[test]
    fn permute_identity_is_noop() {
        let t = Tensor::iota(&[3, 5]);
        assert_eq!(t.permute(&[0, 1]), t);
    }

    #[test]
    fn permute_roundtrip() {
        let t = Tensor::iota(&[2, 3, 4, 5]);
        let p = t.permute(&[3, 1, 0, 2]);
        // inverse of [3,1,0,2] is [2,1,3,0]
        let back = p.permute(&[2, 1, 3, 0]);
        assert_eq!(back, t);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::iota(&[2, 6]).reshape(&[3, 4]);
        assert_eq!(t.shape(), &[3, 4]);
        assert_eq!(t.at(&[2, 3]), 11.0);
    }

    #[test]
    #[should_panic]
    fn reshape_wrong_count_panics() {
        let _ = Tensor::iota(&[2, 3]).reshape(&[4]);
    }

    #[test]
    fn sum_axis_matches_manual() {
        let t = Tensor::iota(&[2, 3]);
        let s0 = t.sum_axis(0);
        assert_eq!(s0.shape(), &[3]);
        assert_eq!(s0.data(), &[3.0, 5.0, 7.0]);
        let s1 = t.sum_axis(1);
        assert_eq!(s1.data(), &[3.0, 12.0]);
    }

    #[test]
    fn broadcast_axis_repeats() {
        let t = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        let b = t.broadcast_axis(0, 3);
        assert_eq!(b.shape(), &[3, 2]);
        assert_eq!(b.data(), &[1.0, 2.0, 1.0, 2.0, 1.0, 2.0]);
        let b2 = t.broadcast_axis(1, 2);
        assert_eq!(b2.shape(), &[2, 2]);
        assert_eq!(b2.data(), &[1.0, 1.0, 2.0, 2.0]);
    }

    #[test]
    fn slice_and_pad() {
        let t = Tensor::iota(&[4, 2]);
        let s = t.slice_axis(0, 1, 3);
        assert_eq!(s.shape(), &[2, 2]);
        assert_eq!(s.data(), &[2.0, 3.0, 4.0, 5.0]);
        let p = s.pad_axis(0, 1, 2);
        assert_eq!(p.shape(), &[5, 2]);
        assert_eq!(p.at(&[0, 0]), 0.0);
        assert_eq!(p.at(&[1, 0]), 2.0);
        assert_eq!(p.at(&[4, 1]), 0.0);
    }

    #[test]
    fn elementwise_ops() {
        let mut a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::from_vec(&[3], vec![0.5, 0.5, 0.5]);
        a.add_assign(&b);
        assert_eq!(a.data(), &[1.5, 2.5, 3.5]);
        a.axpy(2.0, &b);
        assert_eq!(a.data(), &[2.5, 3.5, 4.5]);
        a.scale(2.0);
        assert_eq!(a.sum(), 21.0);
        assert!(a.map(|x| x * 0.0).sum() == 0.0);
    }

    #[test]
    fn comparison_helpers() {
        let a = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[2], vec![1.0, 2.001]);
        assert!(a.max_abs_diff(&b) < 0.01);
        assert!(a.rel_l2(&b) < 0.01);
        a.assert_close(&b, 0.01);
    }

    #[test]
    fn for_each_index_visits_all() {
        let mut count = 0;
        let mut last = vec![];
        for_each_index(&[2, 3], |idx| {
            count += 1;
            last = idx.to_vec();
        });
        assert_eq!(count, 6);
        assert_eq!(last, vec![1, 2]);
        // empty dims: no visits
        let mut n = 0;
        for_each_index(&[2, 0], |_| n += 1);
        assert_eq!(n, 0);
        // scalar: one visit
        let mut n = 0;
        for_each_index(&[], |_| n += 1);
        assert_eq!(n, 1);
    }

    #[test]
    fn permute_into_matches_permute() {
        let t = Tensor::iota(&[3, 4, 5]);
        let want = t.permute(&[2, 0, 1]);
        let mut out = vec![0.0f32; t.len()];
        permute_into(t.data(), t.shape(), &[2, 0, 1], &mut out, None);
        assert_eq!(out.as_slice(), want.data());
        // identity permutation is a plain copy
        let mut id = vec![0.0f32; t.len()];
        permute_into(t.data(), t.shape(), &[0, 1, 2], &mut id, None);
        assert_eq!(id.as_slice(), t.data());
    }

    #[test]
    fn parallel_permute_gather_matches_serial_on_large_tensor() {
        let mut rng = Rng::new(9);
        let t = Tensor::rand(&[32, 32, 32], -1.0, 1.0, &mut rng);
        let want = t.permute(&[1, 2, 0]);
        let pool = Pool::new(4);
        let mut out = vec![0.0f32; t.len()];
        permute_into(t.data(), t.shape(), &[1, 2, 0], &mut out, Some(&pool));
        assert_eq!(out.as_slice(), want.data());
    }

    #[test]
    fn sum_axis_into_matches_sum_axis() {
        let mut rng = Rng::new(10);
        let t = Tensor::rand(&[8, 5, 7], -1.0, 1.0, &mut rng);
        for axis in 0..3 {
            let want = t.sum_axis(axis);
            // dirty destination: the kernel must zero it first
            let mut out = vec![1.0f32; want.len()];
            sum_axis_into(t.data(), t.shape(), axis, &mut out, None);
            assert_eq!(out.as_slice(), want.data());
        }
        // large enough to take the parallel path; must stay bit-identical
        let big = Tensor::rand(&[64, 3, 512], -1.0, 1.0, &mut rng);
        let want = big.sum_axis(1);
        let pool = Pool::new(4);
        let mut out = vec![0.0f32; want.len()];
        sum_axis_into(big.data(), big.shape(), 1, &mut out, Some(&pool));
        assert_eq!(out.as_slice(), want.data());
        // leading-axis reduction (outer == 1) splits over the output slice
        let lead = Tensor::rand(&[3, 20_000], -1.0, 1.0, &mut rng);
        let want = lead.sum_axis(0);
        let mut out = vec![0.0f32; want.len()];
        sum_axis_into(lead.data(), lead.shape(), 0, &mut out, Some(&pool));
        assert_eq!(out.as_slice(), want.data());
    }

    #[test]
    fn gather_into_reproduces_inverse_permute_plus_broadcast() {
        // The VJP un-canonicalization shape: canon = post.permute(perm),
        // gathered back to post order with a broadcast axis re-inserted.
        let post = Tensor::iota(&[3, 4]);
        let canon = post.permute(&[1, 0]); // shape [4, 3]
        // want = canon.permute(inv).broadcast_axis(1, 5) → shape [3, 5, 4]
        let want = canon.permute(&[1, 0]).broadcast_axis(1, 5);
        // strides into canon's flat data: canon strides [3, 1]; axis 0 of
        // the output is canon axis 1 (stride 1), axis 1 broadcast (0),
        // axis 2 is canon axis 0 (stride 3).
        let mut out = vec![0.0f32; 3 * 5 * 4];
        gather_into(canon.data(), &[3, 5, 4], &[1, 0, 3], &mut out, false, None);
        assert_eq!(out.as_slice(), want.data());
        // accumulate adds elementwise on top of existing contents
        let mut acc = vec![1.0f32; 3 * 5 * 4];
        gather_into(canon.data(), &[3, 5, 4], &[1, 0, 3], &mut acc, true, None);
        for (a, w) in acc.iter().zip(want.data()) {
            assert_eq!(*a, 1.0 + w);
        }
        // scalar (rank-0) gather
        let mut s = vec![0.0f32];
        gather_into(&[7.5], &[], &[], &mut s, false, None);
        assert_eq!(s[0], 7.5);
    }

    #[test]
    fn parallel_gather_into_matches_serial() {
        let mut rng = Rng::new(11);
        let t = Tensor::rand(&[64, 512], -1.0, 1.0, &mut rng);
        // broadcast a middle axis of 3: out[i, j, k] = t[i, k]
        let shape = [64usize, 3, 512];
        let strides = [512usize, 0, 1];
        let mut serial = vec![0.0f32; 64 * 3 * 512];
        gather_into(t.data(), &shape, &strides, &mut serial, false, None);
        let pool = Pool::new(4);
        let mut par = vec![0.0f32; 64 * 3 * 512];
        gather_into(t.data(), &shape, &strides, &mut par, false, Some(&pool));
        assert_eq!(par, serial);
    }

    #[test]
    fn concat_and_split_axis0_roundtrip() {
        let a = Tensor::iota(&[2, 3]);
        let b = a.map(|x| x + 100.0).slice_axis(0, 0, 1); // shape [1, 3]
        let c = a.map(|x| x + 200.0); // shape [2, 3]
        let cat = Tensor::concat_axis0(&[&a, &b, &c]);
        assert_eq!(cat.shape(), &[5, 3]);
        assert_eq!(&cat.data()[..6], a.data());
        assert_eq!(&cat.data()[6..9], b.data());
        assert_eq!(&cat.data()[9..], c.data());
        // allocation-free variant into a held destination
        let mut out = Tensor::zeros(&[5, 3]);
        concat_into(&[&a, &b, &c], &mut out);
        assert_eq!(out.data(), cat.data());
        // split returns the original parts
        let parts = cat.split_axis0(&[2, 1, 2]);
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0].data(), a.data());
        assert_eq!(parts[1].data(), b.data());
        assert_eq!(parts[2].data(), c.data());
        // allocation-free split into held destinations
        let mut outs = vec![Tensor::zeros(&[2, 3]), Tensor::zeros(&[1, 3]), Tensor::zeros(&[2, 3])];
        split_axis0_into(&cat, &mut outs);
        assert_eq!(outs[0].data(), a.data());
        assert_eq!(outs[1].data(), b.data());
        assert_eq!(outs[2].data(), c.data());
    }

    #[test]
    #[should_panic]
    fn concat_axis0_rejects_mismatched_tails() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 4]);
        let _ = Tensor::concat_axis0(&[&a, &b]);
    }

    #[test]
    fn identity_permute_and_clone_are_copy_free() {
        let t = Tensor::iota(&[64, 64]);
        let p = t.permute(&[0, 1]);
        assert_eq!(t.data().as_ptr(), p.data().as_ptr(), "identity permute shares storage");
        // clones share storage until mutated (copy-on-write)
        let mut c = t.clone();
        assert_eq!(t.data().as_ptr(), c.data().as_ptr());
        c.data_mut()[0] = 42.0;
        assert_ne!(t.data().as_ptr(), c.data().as_ptr());
        assert_eq!(t.data()[0], 0.0);
        assert_eq!(c.data()[0], 42.0);
    }

    #[test]
    fn random_tensors_in_range() {
        let mut rng = Rng::new(3);
        let t = Tensor::rand(&[100], -1.0, 1.0, &mut rng);
        assert!(t.data().iter().all(|&x| (-1.0..1.0).contains(&x)));
        let n = Tensor::randn(&[100], 0.0, 1.0, &mut rng);
        assert!(n.data().iter().any(|&x| x.abs() > 0.5));
    }
}
