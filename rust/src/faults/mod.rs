//! Deterministic fault injection for robustness testing (std-only).
//!
//! A process-wide registry of **named fault sites**. Production code marks
//! the places where failure is interesting — e.g.
//! `faults::point("worker.eval.pre")` just before a coordinator worker
//! executes a batch — and a test installs a seeded [`FaultPlan`] describing
//! *when* each site fires and *what* it does:
//!
//! * [`FaultAction::Panic`] — panic with a recognizable message (exercises
//!   worker supervision and panic containment);
//! * [`FaultAction::Delay`] — sleep for a fixed duration (exercises request
//!   deadlines and the drain timeout);
//! * [`FaultAction::Error`] — `point` returns `true` and the caller turns
//!   that into a structured `Err` (exercises error routing and retry
//!   semantics).
//!
//! Schedules are **deterministic**: counted triggers ([`Schedule::Nth`],
//! [`Schedule::First`], [`Schedule::Every`]) fire on exact per-site hit
//! indices, and probabilistic triggers ([`Schedule::Prob`]) draw from a
//! per-rule [`crate::util::rng::Rng`] forked from the plan seed — the k-th
//! hit of a site makes the same decision in every run with that seed. (With
//! several worker threads the *assignment* of requests to hit indices can
//! vary with scheduling; the chaos suite's invariants — every request
//! terminates, successful results are bit-identical — hold for every
//! assignment, and fully deterministic replays pin `workers: 1`.)
//!
//! # Zero cost when disabled
//!
//! The whole registry is compiled only under the `fault-injection` cargo
//! feature. Without it, [`point`] is an `#[inline(always)]` constant
//! `false`, so every `if faults::point(..) { .. }` branch folds away and
//! the zero-allocation hot paths are untouched (the release CI job keeps
//! asserting them with the feature off).
//!
//! # Poisoning
//!
//! [`point`] never panics or sleeps while holding the registry lock, and
//! every lock acquisition shrugs off poisoning — an injected panic
//! unwinding through a caller can never wedge the registry for other
//! threads.

#[cfg(feature = "fault-injection")]
pub use enabled::{clear, hits, injected, install, point, test_serial};

#[cfg(not(feature = "fault-injection"))]
pub use disabled::{clear, hits, injected, install, point};

use std::time::Duration;

/// What a fault site does when its schedule fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Panic with message `"injected fault: panic at <site>"`.
    Panic,
    /// Sleep for the given duration, then report "no fault" (`false`).
    Delay(Duration),
    /// Report a forced failure: [`point`] returns `true` and the caller
    /// responds with a structured error.
    Error,
}

/// When a fault rule fires, in terms of the site's per-process hit count
/// (0-based: the first execution of a site is hit 0).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Schedule {
    /// Fire exactly once, on hit `n`.
    Nth(u64),
    /// Fire on hits `0..n`.
    First(u64),
    /// Fire on every `k`-th hit (`k >= 1`): hits `k-1, 2k-1, ...`.
    Every(u64),
    /// Fire independently on each hit with probability `p`, drawn from a
    /// per-rule deterministic RNG forked from the plan seed.
    Prob(f64),
}

/// A seeded set of fault rules, installed process-wide via [`install`].
#[derive(Debug, Clone)]
pub struct FaultPlan {
    seed: u64,
    rules: Vec<(String, Schedule, FaultAction)>,
}

impl FaultPlan {
    /// An empty plan; probabilistic rules fork their RNG streams from
    /// `seed` and the rule's site name.
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            rules: Vec::new(),
        }
    }

    /// Add a rule. Multiple rules may target the same site; on each hit
    /// they are evaluated in insertion order and the first that fires wins.
    pub fn rule(mut self, site: &str, schedule: Schedule, action: FaultAction) -> FaultPlan {
        self.rules.push((site.to_string(), schedule, action));
        self
    }

    /// The plan seed (used to fork per-rule RNG streams).
    pub fn seed(&self) -> u64 {
        self.seed
    }
}

#[cfg(feature = "fault-injection")]
mod enabled {
    use super::{FaultAction, FaultPlan, Schedule};
    use crate::util::rng::Rng;
    use std::collections::HashMap;
    use std::sync::{Mutex, MutexGuard, OnceLock, PoisonError};

    struct Rule {
        schedule: Schedule,
        action: FaultAction,
        rng: Rng,
    }

    #[derive(Default)]
    struct SiteState {
        rules: Vec<Rule>,
        hits: u64,
        injected: u64,
    }

    #[derive(Default)]
    struct Registry {
        sites: HashMap<String, SiteState>,
    }

    fn registry() -> MutexGuard<'static, Registry> {
        static REGISTRY: OnceLock<Mutex<Registry>> = OnceLock::new();
        REGISTRY
            .get_or_init(|| Mutex::new(Registry::default()))
            .lock()
            // An injected panic may unwind through arbitrary callers;
            // poisoning must never disable the registry for other threads.
            .unwrap_or_else(PoisonError::into_inner)
    }

    /// Derive a stable per-rule RNG stream from the plan seed and the
    /// site name (FNV-1a over the name, mixed into the seed).
    fn rule_rng(seed: u64, site: &str, index: usize) -> Rng {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in site.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        Rng::new(seed ^ h ^ ((index as u64) << 32))
    }

    /// Install `plan`, replacing any previous plan and resetting all hit /
    /// injection counters.
    pub fn install(plan: FaultPlan) {
        let mut reg = registry();
        reg.sites.clear();
        for (i, (site, schedule, action)) in plan.rules.iter().enumerate() {
            let state = reg.sites.entry(site.clone()).or_default();
            state.rules.push(Rule {
                schedule: *schedule,
                action: *action,
                rng: rule_rng(plan.seed, site, i),
            });
        }
    }

    /// Remove every rule and reset all counters.
    pub fn clear() {
        registry().sites.clear();
    }

    /// Times `site` has been executed since the last [`install`]/[`clear`]
    /// (counted even for sites with no rules).
    pub fn hits(site: &str) -> u64 {
        registry().sites.get(site).map(|s| s.hits).unwrap_or(0)
    }

    /// Times a fault actually fired at `site`.
    pub fn injected(site: &str) -> u64 {
        registry()
            .sites
            .get(site)
            .map(|s| s.injected)
            .unwrap_or(0)
    }

    /// Serialize tests that install process-wide fault plans: the registry
    /// is global, so concurrent test threads with different plans would
    /// interfere. Hold the returned guard for the duration of the test.
    pub fn test_serial() -> MutexGuard<'static, ()> {
        static GATE: Mutex<()> = Mutex::new(());
        GATE.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Execute fault site `site`: decide under the registry lock whether a
    /// rule fires, then act **outside** the lock — panic for
    /// [`FaultAction::Panic`], sleep for [`FaultAction::Delay`], and return
    /// `true` for [`FaultAction::Error`] (the caller produces the error).
    /// Returns `false` when nothing fires.
    pub fn point(site: &str) -> bool {
        let fired = {
            let mut reg = registry();
            let Some(state) = reg.sites.get_mut(site) else {
                return false;
            };
            let hit = state.hits;
            state.hits += 1;
            let mut fired = None;
            for rule in state.rules.iter_mut() {
                let fire = match rule.schedule {
                    Schedule::Nth(n) => hit == n,
                    Schedule::First(n) => hit < n,
                    Schedule::Every(k) => k >= 1 && (hit + 1) % k == 0,
                    Schedule::Prob(p) => rule.rng.bool(p),
                };
                if fire {
                    fired = Some(rule.action);
                    break;
                }
            }
            if fired.is_some() {
                state.injected += 1;
            }
            fired
        };
        match fired {
            None => false,
            Some(FaultAction::Error) => true,
            Some(FaultAction::Delay(d)) => {
                std::thread::sleep(d);
                false
            }
            Some(FaultAction::Panic) => {
                panic!("injected fault: panic at {site}");
            }
        }
    }
}

#[cfg(not(feature = "fault-injection"))]
mod disabled {
    use super::FaultPlan;

    /// No-op without the `fault-injection` feature: a constant `false` the
    /// optimizer folds away, keeping the hot path untouched.
    #[inline(always)]
    pub fn point(_site: &str) -> bool {
        false
    }

    /// No-op without the `fault-injection` feature.
    #[inline(always)]
    pub fn install(_plan: FaultPlan) {}

    /// No-op without the `fault-injection` feature.
    #[inline(always)]
    pub fn clear() {}

    /// Always 0 without the `fault-injection` feature.
    #[inline(always)]
    pub fn hits(_site: &str) -> u64 {
        0
    }

    /// Always 0 without the `fault-injection` feature.
    #[inline(always)]
    pub fn injected(_site: &str) -> u64 {
        0
    }
}

#[cfg(all(test, feature = "fault-injection"))]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::time::Instant;

    // The registry is process-global; tests touching it serialize on the
    // shared gate so parallel test threads never see each other's plans.
    fn gate() -> std::sync::MutexGuard<'static, ()> {
        test_serial()
    }

    #[test]
    fn unregistered_site_is_silent() {
        let _g = gate();
        clear();
        assert!(!point("no.such.site"));
        assert_eq!(hits("no.such.site"), 0);
    }

    #[test]
    fn nth_fires_exactly_once() {
        let _g = gate();
        install(FaultPlan::new(1).rule("s", Schedule::Nth(2), FaultAction::Error));
        let fired: Vec<bool> = (0..5).map(|_| point("s")).collect();
        assert_eq!(fired, vec![false, false, true, false, false]);
        assert_eq!(hits("s"), 5);
        assert_eq!(injected("s"), 1);
        clear();
    }

    #[test]
    fn every_fires_periodically_and_first_fires_prefix() {
        let _g = gate();
        install(
            FaultPlan::new(2)
                .rule("e", Schedule::Every(3), FaultAction::Error)
                .rule("f", Schedule::First(2), FaultAction::Error),
        );
        let e: Vec<bool> = (0..7).map(|_| point("e")).collect();
        assert_eq!(e, vec![false, false, true, false, false, true, false]);
        let f: Vec<bool> = (0..4).map(|_| point("f")).collect();
        assert_eq!(f, vec![true, true, false, false]);
        clear();
    }

    #[test]
    fn prob_stream_is_deterministic_per_seed() {
        let _g = gate();
        let run = |seed: u64| -> Vec<bool> {
            install(FaultPlan::new(seed).rule("p", Schedule::Prob(0.5), FaultAction::Error));
            (0..64).map(|_| point("p")).collect()
        };
        let a = run(7);
        let b = run(7);
        assert_eq!(a, b, "same seed, same decision stream");
        let c = run(8);
        assert_ne!(a, c, "different seed should diverge somewhere in 64 draws");
        assert!(a.iter().any(|&x| x) && a.iter().any(|&x| !x));
        clear();
    }

    #[test]
    fn panic_action_panics_with_site_name_and_registry_survives() {
        let _g = gate();
        install(FaultPlan::new(3).rule("boom", Schedule::Nth(0), FaultAction::Panic));
        let err = catch_unwind(AssertUnwindSafe(|| point("boom"))).unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("injected fault: panic at boom"), "got {msg:?}");
        // Registry still answers after the panic (no poisoning wedge).
        assert_eq!(injected("boom"), 1);
        assert!(!point("boom"), "Nth(0) already fired");
        clear();
    }

    #[test]
    fn delay_action_sleeps_then_reports_no_fault() {
        let _g = gate();
        install(FaultPlan::new(4).rule(
            "slow",
            Schedule::Nth(0),
            FaultAction::Delay(Duration::from_millis(20)),
        ));
        let t0 = Instant::now();
        assert!(!point("slow"), "delay is not a forced error");
        assert!(t0.elapsed() >= Duration::from_millis(20));
        clear();
    }

    #[test]
    fn first_matching_rule_wins() {
        let _g = gate();
        install(
            FaultPlan::new(5)
                .rule("s", Schedule::Nth(0), FaultAction::Error)
                .rule("s", Schedule::First(10), FaultAction::Panic),
        );
        // Hit 0: the Error rule fires first — no panic.
        assert!(point("s"));
        clear();
    }
}
