//! The unified batching scheduler: one queueing/grouping/flushing engine
//! for **both** request kinds the coordinator serves.
//!
//! Inference evals and training steps of the same expression are the same
//! einsum family differing only in the batch-carrying operand, so they
//! share one scheduler: pending requests are keyed by *compatibility
//! group* — `(layer, input shape)` for inference, `(expression, input
//! shapes, checkpoint policy)` for training — and each group batches
//! independently. Interleaved traffic of different shapes therefore never
//! starves batch formation (the pre-unification router flushed the whole
//! partial batch whenever an incompatible shape arrived, so an
//! alternating-shape stream degenerated to batch size 1).
//!
//! # Adaptive, pool-aware batch sizing
//!
//! How long to hold a partial batch is a latency/throughput trade the
//! right answer to which depends on whether anything else is running. The
//! [`AdaptiveController`] derives both limits from live utilization —
//! the fraction of coordinator workers busy
//! ([`ServiceMetrics::inflight`]) combined with the executor pool's
//! activity ([`crate::parallel::Pool::utilization`]):
//!
//! * **idle** (utilization ≈ 0): flush early and small — a lone request
//!   dispatches immediately, batch size 1, zero added latency;
//! * **saturated** (utilization ≈ 1): hold up to the configured timeout
//!   and coalesce up to the configured maximum — workers are busy anyway,
//!   so queued requests amortize plan lookup and dispatch.
//!
//! [`crate::coordinator::ServiceConfig::max_batch`] and
//! [`crate::coordinator::ServiceConfig::batch_timeout`] are **bounds** on
//! the controller, not fixed operating points.
//!
//! # Admission control and deadlines
//!
//! Pending work is bounded by an explicit budget
//! ([`crate::coordinator::ServiceConfig::max_pending`] requests and
//! [`crate::coordinator::ServiceConfig::max_pending_bytes`] of payload):
//! a push that would exceed either returns
//! [`PushOutcome::Rejected`], and the router answers the caller with a
//! structured `Overloaded` error after first trying to make room by
//! shedding expired work ([`Batcher::shed_expired`]) — under sustained
//! overload the oldest (already-expired) requests are dropped first.
//! Every request may carry an absolute deadline; [`dispatch`] sheds
//! expired requests with `DeadlineExceeded` instead of handing them to a
//! worker.
//!
//! # Flushing and dispatch
//!
//! A group flushes when it reaches the controller's current target size
//! (at push) or when its oldest request has waited the controller's
//! current hold time (at the router's deadline tick); flushed groups are
//! split into chunks of at most the configured `max_batch`. [`dispatch`]
//! turns a flushed group into worker messages: inference batches get their
//! compiled plan here (per-layer LRU plan cache, keyed by total batch ×
//! spatial size), training batches carry expression + policy and compile
//! through the workers' shared [`crate::exec::PlanCache`].

use super::{Inflight, ServiceConfig, ServiceError, ServiceMetrics, WorkItem, WorkMsg};
use crate::autodiff::CkptPolicy;
use crate::einsum::{parse, SizedSpec};
use crate::exec::{Backend, CompiledPlan};
use crate::planner::{plan_with, PlanOptions, Strategy};
use crate::tensor::Tensor;
use crate::util::lru::LruCache;
use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::mpsc::SyncSender;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Bound on each layer's per-geometry compiled-plan cache: enough for a
/// realistic batch/spatial mix per layer while keeping client-controlled
/// geometry churn from growing resident memory without limit (the shared
/// ad-hoc [`crate::exec::PlanCache`] is bounded separately).
pub const LAYER_PLAN_CACHE_CAPACITY: usize = 16;

/// A registered tensorial layer: expression + factor weights.
pub(crate) struct LayerEntry {
    pub(crate) expr: String,
    pub(crate) factors: Vec<Tensor>,
    /// Per-(batch, height, width) compiled-plan cache, LRU-bounded at
    /// [`LAYER_PLAN_CACHE_CAPACITY`]; each entry carries its hoisted
    /// `ExecOptions`, so every replay uses one consistent backend.
    pub(crate) plans: LruCache<(usize, usize, usize), Arc<CompiledPlan>>,
}

/// Payload bytes of a tensor (`f32` elements) — the unit of the pending
/// byte budget and the `pending_bytes` gauge.
pub(crate) fn tensor_bytes(t: &Tensor) -> usize {
    t.len() * std::mem::size_of::<f32>()
}

/// Common view of a pending request used by deadline and budget logic.
pub(crate) trait PendingRequest {
    /// Inflight-table id (the key to this request's responder).
    fn id(&self) -> u64;
    /// Absolute deadline, if the service configures one.
    fn deadline(&self) -> Option<Instant>;
    /// Payload bytes charged against the pending byte budget.
    fn bytes(&self) -> usize;
    /// Whether the deadline has passed at `now`.
    fn expired(&self, now: Instant) -> bool {
        self.deadline().is_some_and(|d| now >= d)
    }
}

/// One in-flight inference request, answered through the service's
/// [`Inflight`] table by id (the responder never travels with the work, so
/// shutdown can terminally answer every request no matter where it is).
pub(crate) struct Pending {
    pub(crate) x: Tensor,
    pub(crate) id: u64,
    pub(crate) enqueued: Instant,
    pub(crate) deadline: Option<Instant>,
    /// Crash-retry count so far (bounded by
    /// [`crate::coordinator::ServiceConfig::max_retries`]).
    pub(crate) retries: u32,
    /// Retry backoff: the router holds the request until this instant.
    pub(crate) not_before: Option<Instant>,
}

impl PendingRequest for Pending {
    fn id(&self) -> u64 {
        self.id
    }
    fn deadline(&self) -> Option<Instant> {
        self.deadline
    }
    fn bytes(&self) -> usize {
        tensor_bytes(&self.x)
    }
}

/// One in-flight training-step request. Training steps mutate workspace
/// state and are therefore **never retried** — no retry fields.
pub(crate) struct TrainPending {
    pub(crate) tensors: Vec<Tensor>,
    pub(crate) dout: Tensor,
    pub(crate) policy: CkptPolicy,
    pub(crate) id: u64,
    pub(crate) enqueued: Instant,
    pub(crate) deadline: Option<Instant>,
}

impl PendingRequest for TrainPending {
    fn id(&self) -> u64 {
        self.id
    }
    fn deadline(&self) -> Option<Instant> {
        self.deadline
    }
    fn bytes(&self) -> usize {
        self.tensors.iter().map(tensor_bytes).sum::<usize>() + tensor_bytes(&self.dout)
    }
}

/// Maps live utilization to batch-formation limits, bounded by the service
/// config (see the module docs for the policy).
#[derive(Debug, Clone)]
pub struct AdaptiveController {
    max_batch: usize,
    max_hold: Duration,
}

impl AdaptiveController {
    /// A controller bounded by `max_batch` requests per batch (clamped to
    /// ≥ 1) and `max_hold` of added queueing latency.
    pub fn new(max_batch: usize, max_hold: Duration) -> AdaptiveController {
        AdaptiveController {
            max_batch: max_batch.max(1),
            max_hold,
        }
    }

    /// Requests a group should accumulate before flushing, at the given
    /// utilization (clamped to `[0, 1]`): 1 when idle, rising linearly to
    /// the configured maximum when saturated.
    pub fn target_batch(&self, utilization: f64) -> usize {
        let u = utilization.clamp(0.0, 1.0);
        1 + ((self.max_batch - 1) as f64 * u).round() as usize
    }

    /// How long a partial group may hold its oldest request before a
    /// deadline flush, at the given utilization: zero when idle (flush
    /// immediately), rising linearly to the configured timeout.
    pub fn hold(&self, utilization: f64) -> Duration {
        self.max_hold.mul_f64(utilization.clamp(0.0, 1.0))
    }

    /// The hard per-batch bound (the config's `max_batch`).
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }
}

/// Shape-compatibility group key: requests in one group can execute as one
/// batched replay.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
enum GroupKey {
    Eval {
        layer: String,
        shape: Vec<usize>,
    },
    Train {
        expr: String,
        dims: Vec<Vec<usize>>,
        policy: CkptPolicy,
    },
}

enum GroupItems {
    Eval(Vec<Pending>),
    Train(Vec<TrainPending>),
}

impl GroupItems {
    fn len(&self) -> usize {
        match self {
            GroupItems::Eval(v) => v.len(),
            GroupItems::Train(v) => v.len(),
        }
    }

    /// Drop expired items, recording their ids in `shed`; returns the
    /// payload bytes freed.
    fn shed_expired(&mut self, now: Instant, shed: &mut Vec<u64>) -> usize {
        let mut freed = 0usize;
        match self {
            GroupItems::Eval(v) => v.retain(|p| {
                if p.expired(now) {
                    shed.push(p.id);
                    freed += p.bytes();
                    false
                } else {
                    true
                }
            }),
            GroupItems::Train(v) => v.retain(|p| {
                if p.expired(now) {
                    shed.push(p.id);
                    freed += p.bytes();
                    false
                } else {
                    true
                }
            }),
        }
        freed
    }

    fn oldest(&self) -> Option<Instant> {
        match self {
            GroupItems::Eval(v) => v.iter().map(|p| p.enqueued).min(),
            GroupItems::Train(v) => v.iter().map(|p| p.enqueued).min(),
        }
    }
}

struct PendingGroup {
    items: GroupItems,
    /// Enqueue time of the oldest pending request (deadline anchor).
    oldest: Instant,
    /// Payload bytes held by this group (budget accounting).
    bytes: usize,
}

/// A flushed, shape-compatible batch ready for dispatch.
pub(crate) enum ReadyBatch {
    Eval {
        layer: String,
        items: Vec<Pending>,
    },
    Train {
        expr: String,
        policy: CkptPolicy,
        items: Vec<TrainPending>,
    },
}

impl ReadyBatch {
    fn len(&self) -> usize {
        match self {
            ReadyBatch::Eval { items, .. } => items.len(),
            ReadyBatch::Train { items, .. } => items.len(),
        }
    }
}

/// What happened to a pushed request.
pub(crate) enum PushOutcome<T> {
    /// Its group reached the target size (or the service is idle) and
    /// flushed into this batch.
    Ready(ReadyBatch),
    /// Queued in its group; no batch formed yet.
    Queued,
    /// Admission control: the pending budget is exhausted. The request is
    /// handed back so the router can shed expired work and retry, or
    /// answer `Overloaded`.
    Rejected(T),
}

/// The scheduler state: per-group pending queues, the adaptive controller,
/// and the admission budget. Owned by the router thread; not shared.
pub(crate) struct Batcher {
    groups: HashMap<GroupKey, PendingGroup>,
    controller: AdaptiveController,
    /// Admission budget: maximum queued requests across all groups.
    max_pending: usize,
    /// Admission budget: maximum queued payload bytes across all groups.
    max_pending_bytes: usize,
    pending_reqs: usize,
    pending_bytes: usize,
}

impl Batcher {
    pub(crate) fn new(
        controller: AdaptiveController,
        max_pending: usize,
        max_pending_bytes: usize,
    ) -> Batcher {
        Batcher {
            groups: HashMap::new(),
            controller,
            max_pending,
            max_pending_bytes,
            pending_reqs: 0,
            pending_bytes: 0,
        }
    }

    fn over_budget(&self, extra_bytes: usize) -> bool {
        self.pending_reqs + 1 > self.max_pending
            || self.pending_bytes + extra_bytes > self.max_pending_bytes
    }

    /// Queue an inference request; returns a batch if its group reached the
    /// controller's current target size. One map access per request — the
    /// router serializes every request through this path.
    pub(crate) fn push_eval(
        &mut self,
        layer: &str,
        p: Pending,
        utilization: f64,
    ) -> PushOutcome<Pending> {
        let target = self.controller.target_batch(utilization);
        let key = GroupKey::Eval {
            layer: layer.to_string(),
            shape: p.x.shape().to_vec(),
        };
        let bytes = p.bytes();
        let over = self.over_budget(bytes);
        match self.groups.entry(key) {
            Entry::Vacant(slot) => {
                if target <= 1 {
                    // Idle service: flush the lone request without queueing
                    // it (immediate flushes never consume pending budget).
                    let GroupKey::Eval { layer, .. } = slot.into_key() else {
                        unreachable!("eval push built an eval key")
                    };
                    return PushOutcome::Ready(ReadyBatch::Eval {
                        layer,
                        items: vec![p],
                    });
                }
                if over {
                    return PushOutcome::Rejected(p);
                }
                let oldest = p.enqueued;
                self.pending_reqs += 1;
                self.pending_bytes += bytes;
                slot.insert(PendingGroup {
                    items: GroupItems::Eval(vec![p]),
                    oldest,
                    bytes,
                });
                PushOutcome::Queued
            }
            Entry::Occupied(mut e) => {
                if over {
                    return PushOutcome::Rejected(p);
                }
                self.pending_reqs += 1;
                self.pending_bytes += bytes;
                let group = e.get_mut();
                group.bytes += bytes;
                match &mut group.items {
                    GroupItems::Eval(v) => v.push(p),
                    GroupItems::Train(_) => unreachable!("eval key holds eval items"),
                }
                if e.get().items.len() >= target {
                    let (key, group) = e.remove_entry();
                    self.pending_reqs -= group.items.len();
                    self.pending_bytes -= group.bytes;
                    PushOutcome::Ready(ready(key, group.items))
                } else {
                    PushOutcome::Queued
                }
            }
        }
    }

    /// Queue a training-step request; returns a batch if its group reached
    /// the controller's current target size.
    pub(crate) fn push_train(
        &mut self,
        expr: &str,
        p: TrainPending,
        utilization: f64,
    ) -> PushOutcome<TrainPending> {
        let target = self.controller.target_batch(utilization);
        let key = GroupKey::Train {
            expr: expr.to_string(),
            dims: p.tensors.iter().map(|t| t.shape().to_vec()).collect(),
            policy: p.policy,
        };
        let bytes = p.bytes();
        let over = self.over_budget(bytes);
        match self.groups.entry(key) {
            Entry::Vacant(slot) => {
                if target <= 1 {
                    let GroupKey::Train { expr, policy, .. } = slot.into_key() else {
                        unreachable!("train push built a train key")
                    };
                    return PushOutcome::Ready(ReadyBatch::Train {
                        expr,
                        policy,
                        items: vec![p],
                    });
                }
                if over {
                    return PushOutcome::Rejected(p);
                }
                let oldest = p.enqueued;
                self.pending_reqs += 1;
                self.pending_bytes += bytes;
                slot.insert(PendingGroup {
                    items: GroupItems::Train(vec![p]),
                    oldest,
                    bytes,
                });
                PushOutcome::Queued
            }
            Entry::Occupied(mut e) => {
                if over {
                    return PushOutcome::Rejected(p);
                }
                self.pending_reqs += 1;
                self.pending_bytes += bytes;
                let group = e.get_mut();
                group.bytes += bytes;
                match &mut group.items {
                    GroupItems::Train(v) => v.push(p),
                    GroupItems::Eval(_) => unreachable!("train key holds train items"),
                }
                if e.get().items.len() >= target {
                    let (key, group) = e.remove_entry();
                    self.pending_reqs -= group.items.len();
                    self.pending_bytes -= group.bytes;
                    PushOutcome::Ready(ready(key, group.items))
                } else {
                    PushOutcome::Queued
                }
            }
        }
    }

    fn take(&mut self, key: &GroupKey) -> Option<ReadyBatch> {
        self.groups.remove_entry(key).map(|(k, g)| {
            self.pending_reqs -= g.items.len();
            self.pending_bytes -= g.bytes;
            ready(k, g.items)
        })
    }

    /// Flush every group whose oldest request has waited at least the
    /// controller's current hold time, split into chunks of at most the
    /// configured `max_batch`.
    pub(crate) fn due(&mut self, now: Instant, utilization: f64) -> Vec<ReadyBatch> {
        let hold = self.controller.hold(utilization);
        let due_keys: Vec<GroupKey> = self
            .groups
            .iter()
            .filter(|(_, g)| g.oldest + hold <= now)
            .map(|(k, _)| k.clone())
            .collect();
        let mut out = Vec::new();
        for key in due_keys {
            if let Some(batch) = self.take(&key) {
                split_ready(batch, self.controller.max_batch(), &mut out);
            }
        }
        out
    }

    /// Flush everything pending (shutdown drain), chunked by `max_batch`.
    pub(crate) fn drain(&mut self) -> Vec<ReadyBatch> {
        let keys: Vec<GroupKey> = self.groups.keys().cloned().collect();
        let mut out = Vec::new();
        for key in keys {
            if let Some(batch) = self.take(&key) {
                split_ready(batch, self.controller.max_batch(), &mut out);
            }
        }
        out
    }

    /// Shed every queued request whose deadline has passed — the
    /// lowest-priority work under overload — freeing its budget. Returns
    /// the shed ids for the router to answer with `DeadlineExceeded`.
    pub(crate) fn shed_expired(&mut self, now: Instant) -> Vec<u64> {
        let mut shed = Vec::new();
        let mut freed_reqs = 0usize;
        let mut freed_bytes = 0usize;
        self.groups.retain(|_, g| {
            let before = g.items.len();
            let bytes = g.items.shed_expired(now, &mut shed);
            g.bytes -= bytes;
            freed_reqs += before - g.items.len();
            freed_bytes += bytes;
            match g.items.oldest() {
                Some(o) => {
                    g.oldest = o;
                    true
                }
                None => false,
            }
        });
        self.pending_reqs -= freed_reqs;
        self.pending_bytes -= freed_bytes;
        shed
    }

    /// The earliest deadline across pending groups at the given
    /// utilization, or `None` when nothing is pending.
    pub(crate) fn next_deadline(&self, utilization: f64) -> Option<Instant> {
        let hold = self.controller.hold(utilization);
        self.groups.values().map(|g| g.oldest + hold).min()
    }

    /// Total requests currently pending across all groups.
    pub(crate) fn pending_len(&self) -> usize {
        self.pending_reqs
    }

    /// Total payload bytes currently pending across all groups (the
    /// `pending_bytes` gauge the router publishes each tick).
    pub(crate) fn pending_bytes(&self) -> usize {
        self.pending_bytes
    }
}

/// Rebuild a flushed group into a [`ReadyBatch`] from its (owned) key.
fn ready(key: GroupKey, items: GroupItems) -> ReadyBatch {
    match (key, items) {
        (GroupKey::Eval { layer, .. }, GroupItems::Eval(items)) => {
            ReadyBatch::Eval { layer, items }
        }
        (GroupKey::Train { expr, policy, .. }, GroupItems::Train(items)) => {
            ReadyBatch::Train {
                expr,
                policy,
                items,
            }
        }
        _ => unreachable!("group kind always matches its key kind"),
    }
}

/// Split `items` into consecutive chunks of at most `cap`, preserving
/// submission order (the documented segment order of batched training).
fn split_items<T>(mut items: Vec<T>, cap: usize) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    while items.len() > cap {
        let rest = items.split_off(cap);
        out.push(std::mem::replace(&mut items, rest));
    }
    out.push(items);
    out
}

/// Defensive only: the push path flushes a group the moment it reaches the
/// (≤ `cap`) target, so today's deadline/drain flushes never exceed `cap`
/// — but the cap is the config's hard contract, so enforce it here rather
/// than assume every future flush policy preserves the invariant.
fn split_ready(batch: ReadyBatch, cap: usize, out: &mut Vec<ReadyBatch>) {
    if batch.len() <= cap {
        out.push(batch);
        return;
    }
    match batch {
        ReadyBatch::Eval { layer, items } => {
            for chunk in split_items(items, cap) {
                out.push(ReadyBatch::Eval {
                    layer: layer.clone(),
                    items: chunk,
                });
            }
        }
        ReadyBatch::Train {
            expr,
            policy,
            items,
        } => {
            for chunk in split_items(items, cap) {
                out.push(ReadyBatch::Train {
                    expr: expr.clone(),
                    policy,
                    items: chunk,
                });
            }
        }
    }
}

/// Drop already-expired requests from a flushed batch, answering each with
/// `DeadlineExceeded` — a worker never receives dead work.
fn shed_batch<T: PendingRequest>(
    items: Vec<T>,
    now: Instant,
    metrics: &ServiceMetrics,
    inflight: &Inflight,
) -> Vec<T> {
    let mut kept = Vec::with_capacity(items.len());
    for p in items {
        if p.expired(now) {
            metrics.note_deadline_expired();
            inflight.fail(p.id(), ServiceError::DeadlineExceeded);
        } else {
            kept.push(p);
        }
    }
    kept
}

/// Turn a flushed batch into a worker message: shed expired requests, look
/// up (or compile) the layer plan for inference batches, record
/// batch/queue metrics, and send. Planning failures are routed back to
/// every requester as structured errors through the inflight table.
/// `send_deadline` bounds the worker-channel send during shutdown drain
/// (see [`super::send_work`]); `None` means block (backpressure).
pub(crate) fn dispatch(
    batch: ReadyBatch,
    registry: &mut HashMap<String, LayerEntry>,
    wtx: &SyncSender<WorkMsg>,
    metrics: &ServiceMetrics,
    config: &ServiceConfig,
    inflight: &Inflight,
    send_deadline: Option<Instant>,
) {
    let now = Instant::now();
    match batch {
        ReadyBatch::Eval { layer, items } => {
            let items = shed_batch(items, now, metrics, inflight);
            if items.is_empty() {
                return;
            }
            let entry = registry.get_mut(&layer).expect("layer exists");
            // All requests in a group share the single-example shape;
            // derive the batched plan for the combined batch size. Reject
            // inputs too low-rank to carry (batch, …, h, w) instead of
            // panicking the router thread on the key computation below.
            let bshape = items[0].x.shape().to_vec();
            if bshape.len() < 2 {
                for p in items {
                    inflight.fail(
                        p.id,
                        ServiceError::BadRequest(format!(
                            "layer input must have rank >= 2 (batch plus spatial modes), \
                             got shape {bshape:?}"
                        )),
                    );
                }
                return;
            }
            let total_b: usize = items.iter().map(|p| p.x.shape()[0]).sum();
            let key = (total_b, bshape[bshape.len() - 2], bshape[bshape.len() - 1]);
            let cached = entry.plans.get(&key).cloned();
            let plan = match cached {
                Some(p) => p,
                None => {
                    match plan_layer(entry, total_b, &bshape, config.strategy, config.backend) {
                        Ok(p) => {
                            let p = Arc::new(p);
                            // LRU-bounded: geometry churn past the capacity
                            // evicts the least-recently-served shape.
                            entry.plans.insert(key, Arc::clone(&p));
                            metrics.note_plan_miss();
                            p
                        }
                        Err(e) => {
                            let msg = format!("planning failed: {e}");
                            for p in items {
                                inflight.fail(p.id, ServiceError::Engine(msg.clone()));
                            }
                            return;
                        }
                    }
                }
            };
            metrics.note_batch(items.len());
            for p in &items {
                metrics.note_queue_wait(p.enqueued.elapsed());
            }
            super::send_work(
                wtx,
                WorkMsg::Batch(WorkItem {
                    layer,
                    plan,
                    factors: Arc::new(entry.factors.clone()),
                    requests: items,
                }),
                send_deadline,
                metrics,
                inflight,
            );
        }
        ReadyBatch::Train {
            expr,
            policy,
            items,
        } => {
            let items = shed_batch(items, now, metrics, inflight);
            if items.is_empty() {
                return;
            }
            metrics.note_train_batch(items.len());
            for p in &items {
                metrics.note_queue_wait(p.enqueued.elapsed());
            }
            super::send_work(
                wtx,
                WorkMsg::TrainBatch {
                    expr,
                    policy,
                    items,
                    strategy: config.strategy,
                    backend: config.backend,
                },
                send_deadline,
                metrics,
                inflight,
            );
        }
    }
}

pub(crate) fn plan_layer(
    entry: &LayerEntry,
    batch: usize,
    single_shape: &[usize],
    strategy: Strategy,
    backend: Backend,
) -> Result<CompiledPlan, String> {
    let spec = parse(&entry.expr).map_err(|e| e.to_string())?;
    let mut x_dims = single_shape.to_vec();
    x_dims[0] = batch;
    let mut dims = vec![x_dims];
    dims.extend(entry.factors.iter().map(|f| f.shape().to_vec()));
    let sized = SizedSpec::new(spec, dims)?;
    let plan = plan_with(
        &sized,
        &PlanOptions {
            strategy,
            backend,
            ..Default::default()
        },
    )?;
    CompiledPlan::compile_arc(Arc::new(plan)).map_err(|e| e.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn controller() -> AdaptiveController {
        AdaptiveController::new(8, Duration::from_millis(10))
    }

    fn batcher() -> Batcher {
        Batcher::new(controller(), 1024, 1 << 30)
    }

    fn eval_pending(id: u64, shape: &[usize]) -> Pending {
        Pending {
            x: Tensor::zeros(shape),
            id,
            enqueued: Instant::now(),
            deadline: None,
            retries: 0,
            not_before: None,
        }
    }

    fn train_pending(id: u64, dims: &[Vec<usize>]) -> TrainPending {
        TrainPending {
            tensors: dims.iter().map(|d| Tensor::zeros(d)).collect(),
            dout: Tensor::zeros(&[1]),
            policy: CkptPolicy::StoreAll,
            id,
            enqueued: Instant::now(),
            deadline: None,
        }
    }

    fn queued<T>(outcome: &PushOutcome<T>) -> bool {
        matches!(outcome, PushOutcome::Queued)
    }

    #[test]
    fn controller_is_monotone_and_bounded_by_config() {
        let c = controller();
        assert_eq!(c.target_batch(0.0), 1, "idle -> flush singles");
        assert_eq!(c.target_batch(1.0), 8, "saturated -> config bound");
        assert_eq!(c.target_batch(5.0), 8, "clamped above 1.0");
        assert_eq!(c.hold(0.0), Duration::ZERO, "idle -> no added latency");
        assert_eq!(c.hold(1.0), Duration::from_millis(10));
        let mut last_b = 0usize;
        let mut last_h = Duration::ZERO;
        for step in 0..=10 {
            let u = step as f64 / 10.0;
            let b = c.target_batch(u);
            let h = c.hold(u);
            assert!(b >= last_b && b >= 1 && b <= 8, "target monotone in [1, max]");
            assert!(h >= last_h && h <= Duration::from_millis(10), "hold monotone bounded");
            last_b = b;
            last_h = h;
        }
    }

    #[test]
    fn idle_utilization_flushes_immediately() {
        let mut b = batcher();
        let flushed = b.push_eval("l", eval_pending(0, &[1, 3, 4, 4]), 0.0);
        assert!(
            matches!(flushed, PushOutcome::Ready(_)),
            "idle service must not queue a lone request"
        );
        assert_eq!(b.pending_len(), 0);
        assert_eq!(b.pending_bytes(), 0);
    }

    #[test]
    fn saturated_utilization_holds_until_target() {
        let mut b = batcher();
        for i in 0..7 {
            assert!(
                queued(&b.push_eval("l", eval_pending(i, &[1, 3, 4, 4]), 1.0)),
                "request {i} must queue under saturation"
            );
        }
        let batch = b.push_eval("l", eval_pending(7, &[1, 3, 4, 4]), 1.0);
        match batch {
            PushOutcome::Ready(ReadyBatch::Eval { items, .. }) => assert_eq!(items.len(), 8),
            _ => panic!("8th request must flush a full batch"),
        }
        assert_eq!(b.pending_len(), 0);
        assert_eq!(b.pending_bytes(), 0, "flush releases the byte budget");
    }

    #[test]
    fn interleaved_shapes_batch_independently() {
        // The starvation fix: alternating shapes (and kinds) accumulate in
        // separate groups instead of flushing each other out.
        let mut b = batcher();
        for i in 0..3 {
            assert!(queued(&b.push_eval("l", eval_pending(i, &[1, 3, 4, 4]), 1.0)));
            assert!(queued(&b.push_eval(
                "l",
                eval_pending(10 + i, &[1, 3, 6, 6]),
                1.0
            )));
            assert!(queued(&b.push_train(
                "ij,jk->ik",
                train_pending(20 + i, &[vec![2, 3], vec![3, 4]]),
                1.0
            )));
        }
        assert_eq!(b.pending_len(), 9, "three independent groups of three");
        // Each group completes to its target independently.
        for i in 0..4 {
            assert!(queued(&b.push_eval(
                "l",
                eval_pending(30 + i, &[1, 3, 4, 4]),
                1.0
            )));
        }
        let batch = b.push_eval("l", eval_pending(40, &[1, 3, 4, 4]), 1.0);
        match batch {
            PushOutcome::Ready(ReadyBatch::Eval { items, .. }) => {
                assert_eq!(items.len(), 8);
                assert!(items.iter().all(|p| p.x.shape() == &[1, 3, 4, 4]));
            }
            _ => panic!("shape-[4,4] group must flush alone"),
        }
        assert_eq!(b.pending_len(), 6, "other groups untouched");
    }

    #[test]
    fn deadline_flush_respects_hold_and_caps_chunks() {
        // A hold long enough that scheduler pauses cannot make it elapse.
        let mut b = Batcher::new(
            AdaptiveController::new(4, Duration::from_secs(30)),
            1024,
            1 << 30,
        );
        for i in 0..10 {
            let _ = b.push_train(
                "ij,jk->ik",
                train_pending(i, &[vec![2, 3], vec![3, 4]]),
                1.0,
            );
        }
        // Group flushed once at 4+4; 2 remain pending.
        assert_eq!(b.pending_len(), 2);
        // Not yet due under full hold.
        assert!(b.due(Instant::now(), 1.0).is_empty());
        // Due once the hold elapses (or immediately at utilization 0).
        let batches = b.due(Instant::now(), 0.0);
        assert_eq!(batches.len(), 1);
        match &batches[0] {
            ReadyBatch::Train { items, policy, .. } => {
                assert_eq!(items.len(), 2);
                assert_eq!(*policy, CkptPolicy::StoreAll);
            }
            _ => panic!("train batch expected"),
        }
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn drain_chunks_by_config_bound() {
        let mut b = Batcher::new(
            AdaptiveController::new(4, Duration::from_millis(5)),
            1024,
            1 << 30,
        );
        for i in 0..9 {
            // Cap 4 is reached at pushes 4 and 8; one request remains for
            // the drain to pick up.
            let _ = b.push_eval("l", eval_pending(i, &[1, 3, 4, 4]), 1.0);
        }
        assert_eq!(b.pending_len(), 1);
        let drained = b.drain();
        assert_eq!(drained.len(), 1);
        assert_eq!(drained[0].len(), 1);
        assert_eq!(b.pending_len(), 0);
        assert_eq!(b.pending_bytes(), 0);
    }

    #[test]
    fn split_items_preserves_order() {
        let chunks = split_items((0..10).collect::<Vec<_>>(), 4);
        assert_eq!(chunks, vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7], vec![8, 9]]);
        let whole = split_items(vec![1, 2], 4);
        assert_eq!(whole, vec![vec![1, 2]]);
    }

    #[test]
    fn next_deadline_tracks_oldest_group() {
        let mut b = batcher();
        assert!(b.next_deadline(1.0).is_none());
        let _ = b.push_eval("l", eval_pending(0, &[1, 3, 4, 4]), 1.0);
        let d1 = b.next_deadline(1.0).expect("one group pending");
        std::thread::sleep(Duration::from_millis(2));
        let _ = b.push_eval("l", eval_pending(1, &[1, 3, 6, 6]), 1.0);
        let d2 = b.next_deadline(1.0).expect("two groups pending");
        assert_eq!(d1, d2, "deadline anchored to the oldest request");
    }

    #[test]
    fn count_budget_rejects_and_hands_back_the_request() {
        let mut b = Batcher::new(controller(), 2, 1 << 30);
        assert!(queued(&b.push_eval("l", eval_pending(0, &[1, 3, 4, 4]), 1.0)));
        assert!(queued(&b.push_eval("l", eval_pending(1, &[1, 3, 4, 4]), 1.0)));
        match b.push_eval("l", eval_pending(2, &[1, 3, 4, 4]), 1.0) {
            PushOutcome::Rejected(p) => assert_eq!(p.id, 2, "rejected request comes back"),
            _ => panic!("third request must exceed the count budget"),
        }
        assert_eq!(b.pending_len(), 2, "rejection leaves the queue untouched");
    }

    #[test]
    fn byte_budget_rejects_before_count_budget() {
        let one = tensor_bytes(&Tensor::zeros(&[1, 3, 4, 4]));
        let mut b = Batcher::new(controller(), 1024, 2 * one);
        assert!(queued(&b.push_eval("l", eval_pending(0, &[1, 3, 4, 4]), 1.0)));
        assert!(queued(&b.push_eval("l", eval_pending(1, &[1, 3, 4, 4]), 1.0)));
        assert_eq!(b.pending_bytes(), 2 * one);
        assert!(matches!(
            b.push_eval("l", eval_pending(2, &[1, 3, 4, 4]), 1.0),
            PushOutcome::Rejected(_)
        ));
        // Training payloads charge all inputs plus the cotangent.
        let tp = train_pending(3, &[vec![2, 3], vec![3, 4]]);
        assert_eq!(tp.bytes(), (6 + 12 + 1) * 4);
    }

    #[test]
    fn immediate_flush_bypasses_the_budget() {
        // A zero budget still serves an idle service: lone requests flush
        // without ever being queued.
        let mut b = Batcher::new(controller(), 0, 0);
        assert!(matches!(
            b.push_eval("l", eval_pending(0, &[1, 3, 4, 4]), 0.0),
            PushOutcome::Ready(_)
        ));
        // ...but queueing under saturation is rejected outright.
        assert!(matches!(
            b.push_eval("l", eval_pending(1, &[1, 3, 4, 4]), 1.0),
            PushOutcome::Rejected(_)
        ));
    }

    #[test]
    fn shed_expired_frees_budget_and_reports_ids() {
        let mut b = batcher();
        let now = Instant::now();
        let mut expired = eval_pending(7, &[1, 3, 4, 4]);
        expired.deadline = Some(now - Duration::from_millis(1));
        let mut live = eval_pending(8, &[1, 3, 6, 6]);
        live.deadline = Some(now + Duration::from_secs(60));
        assert!(queued(&b.push_eval("l", expired, 1.0)));
        assert!(queued(&b.push_eval("l", live, 1.0)));
        let before_bytes = b.pending_bytes();
        let shed = b.shed_expired(Instant::now());
        assert_eq!(shed, vec![7], "only the expired request is shed");
        assert_eq!(b.pending_len(), 1);
        assert!(b.pending_bytes() < before_bytes);
        // The emptied group is gone: its deadline no longer drives ticks.
        let d = b.next_deadline(1.0).expect("live group remains");
        assert!(d > now);
    }
}
