//! The unified batching scheduler: one queueing/grouping/flushing engine
//! for **both** request kinds the coordinator serves.
//!
//! Inference evals and training steps of the same expression are the same
//! einsum family differing only in the batch-carrying operand, so they
//! share one scheduler: pending requests are keyed by *compatibility
//! group* — `(layer, input shape)` for inference, `(expression, input
//! shapes, checkpoint policy)` for training — and each group batches
//! independently. Interleaved traffic of different shapes therefore never
//! starves batch formation (the pre-unification router flushed the whole
//! partial batch whenever an incompatible shape arrived, so an
//! alternating-shape stream degenerated to batch size 1).
//!
//! # Adaptive, pool-aware batch sizing
//!
//! How long to hold a partial batch is a latency/throughput trade the
//! right answer to which depends on whether anything else is running. The
//! [`AdaptiveController`] derives both limits from live utilization —
//! the fraction of coordinator workers busy
//! ([`ServiceMetrics::inflight`]) combined with the executor pool's
//! activity ([`crate::parallel::Pool::utilization`]):
//!
//! * **idle** (utilization ≈ 0): flush early and small — a lone request
//!   dispatches immediately, batch size 1, zero added latency;
//! * **saturated** (utilization ≈ 1): hold up to the configured timeout
//!   and coalesce up to the configured maximum — workers are busy anyway,
//!   so queued requests amortize plan lookup and dispatch.
//!
//! [`crate::coordinator::ServiceConfig::max_batch`] and
//! [`crate::coordinator::ServiceConfig::batch_timeout`] are **bounds** on
//! the controller, not fixed operating points.
//!
//! # Flushing and dispatch
//!
//! A group flushes when it reaches the controller's current target size
//! (at push) or when its oldest request has waited the controller's
//! current hold time (at the router's deadline tick); flushed groups are
//! split into chunks of at most the configured `max_batch`. [`dispatch`]
//! turns a flushed group into worker messages: inference batches get their
//! compiled plan here (per-layer LRU plan cache, keyed by total batch ×
//! spatial size), training batches carry expression + policy and compile
//! through the workers' shared [`crate::exec::PlanCache`].

use super::{ServiceConfig, ServiceMetrics, WorkItem, WorkMsg};
use crate::autodiff::CkptPolicy;
use crate::einsum::{parse, SizedSpec};
use crate::exec::{Backend, CompiledPlan};
use crate::planner::{plan_with, PlanOptions, Strategy};
use crate::tensor::Tensor;
use crate::util::lru::LruCache;
use anyhow::{anyhow, Result};
use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::mpsc::SyncSender;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Bound on each layer's per-geometry compiled-plan cache: enough for a
/// realistic batch/spatial mix per layer while keeping client-controlled
/// geometry churn from growing resident memory without limit (the shared
/// ad-hoc [`crate::exec::PlanCache`] is bounded separately).
pub const LAYER_PLAN_CACHE_CAPACITY: usize = 16;

/// A registered tensorial layer: expression + factor weights.
pub(crate) struct LayerEntry {
    pub(crate) expr: String,
    pub(crate) factors: Vec<Tensor>,
    /// Per-(batch, height, width) compiled-plan cache, LRU-bounded at
    /// [`LAYER_PLAN_CACHE_CAPACITY`]; each entry carries its hoisted
    /// `ExecOptions`, so every replay uses one consistent backend.
    pub(crate) plans: LruCache<(usize, usize, usize), Arc<CompiledPlan>>,
}

/// One in-flight inference request.
pub(crate) struct Pending {
    pub(crate) x: Tensor,
    pub(crate) respond: SyncSender<Result<Tensor>>,
    pub(crate) enqueued: Instant,
}

/// One in-flight training-step request.
pub(crate) struct TrainPending {
    pub(crate) tensors: Vec<Tensor>,
    pub(crate) dout: Tensor,
    pub(crate) policy: CkptPolicy,
    pub(crate) respond: SyncSender<Result<(Tensor, Vec<Tensor>)>>,
    pub(crate) enqueued: Instant,
}

/// Maps live utilization to batch-formation limits, bounded by the service
/// config (see the module docs for the policy).
#[derive(Debug, Clone)]
pub struct AdaptiveController {
    max_batch: usize,
    max_hold: Duration,
}

impl AdaptiveController {
    /// A controller bounded by `max_batch` requests per batch (clamped to
    /// ≥ 1) and `max_hold` of added queueing latency.
    pub fn new(max_batch: usize, max_hold: Duration) -> AdaptiveController {
        AdaptiveController {
            max_batch: max_batch.max(1),
            max_hold,
        }
    }

    /// Requests a group should accumulate before flushing, at the given
    /// utilization (clamped to `[0, 1]`): 1 when idle, rising linearly to
    /// the configured maximum when saturated.
    pub fn target_batch(&self, utilization: f64) -> usize {
        let u = utilization.clamp(0.0, 1.0);
        1 + ((self.max_batch - 1) as f64 * u).round() as usize
    }

    /// How long a partial group may hold its oldest request before a
    /// deadline flush, at the given utilization: zero when idle (flush
    /// immediately), rising linearly to the configured timeout.
    pub fn hold(&self, utilization: f64) -> Duration {
        self.max_hold.mul_f64(utilization.clamp(0.0, 1.0))
    }

    /// The hard per-batch bound (the config's `max_batch`).
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }
}

/// Shape-compatibility group key: requests in one group can execute as one
/// batched replay.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
enum GroupKey {
    Eval {
        layer: String,
        shape: Vec<usize>,
    },
    Train {
        expr: String,
        dims: Vec<Vec<usize>>,
        policy: CkptPolicy,
    },
}

enum GroupItems {
    Eval(Vec<Pending>),
    Train(Vec<TrainPending>),
}

impl GroupItems {
    fn len(&self) -> usize {
        match self {
            GroupItems::Eval(v) => v.len(),
            GroupItems::Train(v) => v.len(),
        }
    }
}

struct PendingGroup {
    items: GroupItems,
    /// Enqueue time of the oldest pending request (deadline anchor).
    oldest: Instant,
}

/// A flushed, shape-compatible batch ready for dispatch.
pub(crate) enum ReadyBatch {
    Eval {
        layer: String,
        items: Vec<Pending>,
    },
    Train {
        expr: String,
        policy: CkptPolicy,
        items: Vec<TrainPending>,
    },
}

impl ReadyBatch {
    fn len(&self) -> usize {
        match self {
            ReadyBatch::Eval { items, .. } => items.len(),
            ReadyBatch::Train { items, .. } => items.len(),
        }
    }
}

/// The scheduler state: per-group pending queues plus the adaptive
/// controller. Owned by the router thread; not shared.
pub(crate) struct Batcher {
    groups: HashMap<GroupKey, PendingGroup>,
    controller: AdaptiveController,
}

impl Batcher {
    pub(crate) fn new(controller: AdaptiveController) -> Batcher {
        Batcher {
            groups: HashMap::new(),
            controller,
        }
    }

    /// Queue an inference request; returns a batch if its group reached the
    /// controller's current target size. One map access per request — the
    /// router serializes every request through this path.
    pub(crate) fn push_eval(
        &mut self,
        layer: &str,
        p: Pending,
        utilization: f64,
    ) -> Option<ReadyBatch> {
        let target = self.controller.target_batch(utilization);
        let key = GroupKey::Eval {
            layer: layer.to_string(),
            shape: p.x.shape().to_vec(),
        };
        match self.groups.entry(key) {
            Entry::Vacant(slot) => {
                if target <= 1 {
                    // Idle service: flush the lone request without touching
                    // the map at all.
                    let GroupKey::Eval { layer, .. } = slot.into_key() else {
                        unreachable!("eval push built an eval key")
                    };
                    return Some(ReadyBatch::Eval {
                        layer,
                        items: vec![p],
                    });
                }
                let oldest = p.enqueued;
                slot.insert(PendingGroup {
                    items: GroupItems::Eval(vec![p]),
                    oldest,
                });
                None
            }
            Entry::Occupied(mut e) => {
                match &mut e.get_mut().items {
                    GroupItems::Eval(v) => v.push(p),
                    GroupItems::Train(_) => unreachable!("eval key holds eval items"),
                }
                if e.get().items.len() >= target {
                    let (key, group) = e.remove_entry();
                    Some(ready(key, group.items))
                } else {
                    None
                }
            }
        }
    }

    /// Queue a training-step request; returns a batch if its group reached
    /// the controller's current target size.
    pub(crate) fn push_train(
        &mut self,
        expr: &str,
        p: TrainPending,
        utilization: f64,
    ) -> Option<ReadyBatch> {
        let target = self.controller.target_batch(utilization);
        let key = GroupKey::Train {
            expr: expr.to_string(),
            dims: p.tensors.iter().map(|t| t.shape().to_vec()).collect(),
            policy: p.policy,
        };
        match self.groups.entry(key) {
            Entry::Vacant(slot) => {
                if target <= 1 {
                    let GroupKey::Train { expr, policy, .. } = slot.into_key() else {
                        unreachable!("train push built a train key")
                    };
                    return Some(ReadyBatch::Train {
                        expr,
                        policy,
                        items: vec![p],
                    });
                }
                let oldest = p.enqueued;
                slot.insert(PendingGroup {
                    items: GroupItems::Train(vec![p]),
                    oldest,
                });
                None
            }
            Entry::Occupied(mut e) => {
                match &mut e.get_mut().items {
                    GroupItems::Train(v) => v.push(p),
                    GroupItems::Eval(_) => unreachable!("train key holds train items"),
                }
                if e.get().items.len() >= target {
                    let (key, group) = e.remove_entry();
                    Some(ready(key, group.items))
                } else {
                    None
                }
            }
        }
    }

    fn take(&mut self, key: &GroupKey) -> Option<ReadyBatch> {
        self.groups
            .remove_entry(key)
            .map(|(k, g)| ready(k, g.items))
    }

    /// Flush every group whose oldest request has waited at least the
    /// controller's current hold time, split into chunks of at most the
    /// configured `max_batch`.
    pub(crate) fn due(&mut self, now: Instant, utilization: f64) -> Vec<ReadyBatch> {
        let hold = self.controller.hold(utilization);
        let due_keys: Vec<GroupKey> = self
            .groups
            .iter()
            .filter(|(_, g)| g.oldest + hold <= now)
            .map(|(k, _)| k.clone())
            .collect();
        let mut out = Vec::new();
        for key in due_keys {
            if let Some(batch) = self.take(&key) {
                split_ready(batch, self.controller.max_batch(), &mut out);
            }
        }
        out
    }

    /// Flush everything pending (shutdown drain), chunked by `max_batch`.
    pub(crate) fn drain(&mut self) -> Vec<ReadyBatch> {
        let keys: Vec<GroupKey> = self.groups.keys().cloned().collect();
        let mut out = Vec::new();
        for key in keys {
            if let Some(batch) = self.take(&key) {
                split_ready(batch, self.controller.max_batch(), &mut out);
            }
        }
        out
    }

    /// The earliest deadline across pending groups at the given
    /// utilization, or `None` when nothing is pending.
    pub(crate) fn next_deadline(&self, utilization: f64) -> Option<Instant> {
        let hold = self.controller.hold(utilization);
        self.groups.values().map(|g| g.oldest + hold).min()
    }

    /// Total requests currently pending across all groups.
    pub(crate) fn pending_len(&self) -> usize {
        self.groups.values().map(|g| g.items.len()).sum()
    }
}

/// Rebuild a flushed group into a [`ReadyBatch`] from its (owned) key.
fn ready(key: GroupKey, items: GroupItems) -> ReadyBatch {
    match (key, items) {
        (GroupKey::Eval { layer, .. }, GroupItems::Eval(items)) => {
            ReadyBatch::Eval { layer, items }
        }
        (GroupKey::Train { expr, policy, .. }, GroupItems::Train(items)) => {
            ReadyBatch::Train {
                expr,
                policy,
                items,
            }
        }
        _ => unreachable!("group kind always matches its key kind"),
    }
}

/// Split `items` into consecutive chunks of at most `cap`, preserving
/// submission order (the documented segment order of batched training).
fn split_items<T>(mut items: Vec<T>, cap: usize) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    while items.len() > cap {
        let rest = items.split_off(cap);
        out.push(std::mem::replace(&mut items, rest));
    }
    out.push(items);
    out
}

/// Defensive only: the push path flushes a group the moment it reaches the
/// (≤ `cap`) target, so today's deadline/drain flushes never exceed `cap`
/// — but the cap is the config's hard contract, so enforce it here rather
/// than assume every future flush policy preserves the invariant.
fn split_ready(batch: ReadyBatch, cap: usize, out: &mut Vec<ReadyBatch>) {
    if batch.len() <= cap {
        out.push(batch);
        return;
    }
    match batch {
        ReadyBatch::Eval { layer, items } => {
            for chunk in split_items(items, cap) {
                out.push(ReadyBatch::Eval {
                    layer: layer.clone(),
                    items: chunk,
                });
            }
        }
        ReadyBatch::Train {
            expr,
            policy,
            items,
        } => {
            for chunk in split_items(items, cap) {
                out.push(ReadyBatch::Train {
                    expr: expr.clone(),
                    policy,
                    items: chunk,
                });
            }
        }
    }
}

/// Turn a flushed batch into a worker message: look up (or compile) the
/// layer plan for inference batches, record batch/queue metrics, and send.
/// Planning failures are routed back to every requester as errors.
pub(crate) fn dispatch(
    batch: ReadyBatch,
    registry: &mut HashMap<String, LayerEntry>,
    wtx: &SyncSender<WorkMsg>,
    metrics: &ServiceMetrics,
    config: &ServiceConfig,
) {
    match batch {
        ReadyBatch::Eval { layer, items } => {
            if items.is_empty() {
                return;
            }
            let entry = registry.get_mut(&layer).expect("layer exists");
            // All requests in a group share the single-example shape;
            // derive the batched plan for the combined batch size. Reject
            // inputs too low-rank to carry (batch, …, h, w) instead of
            // panicking the router thread on the key computation below.
            let bshape = items[0].x.shape().to_vec();
            if bshape.len() < 2 {
                for p in items {
                    metrics.note_error();
                    let _ = p.respond.send(Err(anyhow!(
                        "layer input must have rank >= 2 (batch plus spatial modes), \
                         got shape {bshape:?}"
                    )));
                }
                return;
            }
            let total_b: usize = items.iter().map(|p| p.x.shape()[0]).sum();
            let key = (total_b, bshape[bshape.len() - 2], bshape[bshape.len() - 1]);
            let cached = entry.plans.get(&key).cloned();
            let plan = match cached {
                Some(p) => p,
                None => {
                    match plan_layer(entry, total_b, &bshape, config.strategy, config.backend) {
                        Ok(p) => {
                            let p = Arc::new(p);
                            // LRU-bounded: geometry churn past the capacity
                            // evicts the least-recently-served shape.
                            entry.plans.insert(key, Arc::clone(&p));
                            metrics.note_plan_miss();
                            p
                        }
                        Err(e) => {
                            let msg = format!("planning failed: {e}");
                            for p in items {
                                metrics.note_error();
                                let _ = p.respond.send(Err(anyhow!("{msg}")));
                            }
                            return;
                        }
                    }
                }
            };
            metrics.note_batch(items.len());
            for p in &items {
                metrics.note_queue_wait(p.enqueued.elapsed());
            }
            metrics.note_dispatched();
            let _ = wtx.send(WorkMsg::Batch(WorkItem {
                layer,
                plan,
                factors: Arc::new(entry.factors.clone()),
                requests: items,
            }));
        }
        ReadyBatch::Train {
            expr,
            policy,
            items,
        } => {
            if items.is_empty() {
                return;
            }
            metrics.note_train_batch(items.len());
            for p in &items {
                metrics.note_queue_wait(p.enqueued.elapsed());
            }
            metrics.note_dispatched();
            let _ = wtx.send(WorkMsg::TrainBatch {
                expr,
                policy,
                items,
                strategy: config.strategy,
                backend: config.backend,
            });
        }
    }
}

pub(crate) fn plan_layer(
    entry: &LayerEntry,
    batch: usize,
    single_shape: &[usize],
    strategy: Strategy,
    backend: Backend,
) -> Result<CompiledPlan, String> {
    let spec = parse(&entry.expr).map_err(|e| e.to_string())?;
    let mut x_dims = single_shape.to_vec();
    x_dims[0] = batch;
    let mut dims = vec![x_dims];
    dims.extend(entry.factors.iter().map(|f| f.shape().to_vec()));
    let sized = SizedSpec::new(spec, dims)?;
    let plan = plan_with(
        &sized,
        &PlanOptions {
            strategy,
            backend,
            ..Default::default()
        },
    )?;
    CompiledPlan::compile_arc(Arc::new(plan)).map_err(|e| e.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::sync_channel;

    fn controller() -> AdaptiveController {
        AdaptiveController::new(8, Duration::from_millis(10))
    }

    fn eval_pending(shape: &[usize]) -> Pending {
        let (tx, _rx) = sync_channel(1);
        // Keep the receiver alive is unnecessary here: scheduler tests never
        // send responses.
        Pending {
            x: Tensor::zeros(shape),
            respond: tx,
            enqueued: Instant::now(),
        }
    }

    fn train_pending(dims: &[Vec<usize>]) -> TrainPending {
        let (tx, _rx) = sync_channel(1);
        TrainPending {
            tensors: dims.iter().map(|d| Tensor::zeros(d)).collect(),
            dout: Tensor::zeros(&[1]),
            policy: CkptPolicy::StoreAll,
            respond: tx,
            enqueued: Instant::now(),
        }
    }

    #[test]
    fn controller_is_monotone_and_bounded_by_config() {
        let c = controller();
        assert_eq!(c.target_batch(0.0), 1, "idle -> flush singles");
        assert_eq!(c.target_batch(1.0), 8, "saturated -> config bound");
        assert_eq!(c.target_batch(5.0), 8, "clamped above 1.0");
        assert_eq!(c.hold(0.0), Duration::ZERO, "idle -> no added latency");
        assert_eq!(c.hold(1.0), Duration::from_millis(10));
        let mut last_b = 0usize;
        let mut last_h = Duration::ZERO;
        for step in 0..=10 {
            let u = step as f64 / 10.0;
            let b = c.target_batch(u);
            let h = c.hold(u);
            assert!(b >= last_b && b >= 1 && b <= 8, "target monotone in [1, max]");
            assert!(h >= last_h && h <= Duration::from_millis(10), "hold monotone bounded");
            last_b = b;
            last_h = h;
        }
    }

    #[test]
    fn idle_utilization_flushes_immediately() {
        let mut b = Batcher::new(controller());
        let flushed = b.push_eval("l", eval_pending(&[1, 3, 4, 4]), 0.0);
        assert!(flushed.is_some(), "idle service must not queue a lone request");
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn saturated_utilization_holds_until_target() {
        let mut b = Batcher::new(controller());
        for i in 0..7 {
            assert!(
                b.push_eval("l", eval_pending(&[1, 3, 4, 4]), 1.0).is_none(),
                "request {i} must queue under saturation"
            );
        }
        let batch = b.push_eval("l", eval_pending(&[1, 3, 4, 4]), 1.0);
        match batch {
            Some(ReadyBatch::Eval { items, .. }) => assert_eq!(items.len(), 8),
            _ => panic!("8th request must flush a full batch"),
        }
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn interleaved_shapes_batch_independently() {
        // The starvation fix: alternating shapes (and kinds) accumulate in
        // separate groups instead of flushing each other out.
        let mut b = Batcher::new(controller());
        for _ in 0..3 {
            assert!(b.push_eval("l", eval_pending(&[1, 3, 4, 4]), 1.0).is_none());
            assert!(b.push_eval("l", eval_pending(&[1, 3, 6, 6]), 1.0).is_none());
            assert!(b
                .push_train("ij,jk->ik", train_pending(&[vec![2, 3], vec![3, 4]]), 1.0)
                .is_none());
        }
        assert_eq!(b.pending_len(), 9, "three independent groups of three");
        // Each group completes to its target independently.
        for _ in 0..4 {
            assert!(b.push_eval("l", eval_pending(&[1, 3, 4, 4]), 1.0).is_none());
        }
        let batch = b.push_eval("l", eval_pending(&[1, 3, 4, 4]), 1.0);
        match batch {
            Some(ReadyBatch::Eval { items, .. }) => {
                assert_eq!(items.len(), 8);
                assert!(items.iter().all(|p| p.x.shape() == &[1, 3, 4, 4]));
            }
            _ => panic!("shape-[4,4] group must flush alone"),
        }
        assert_eq!(b.pending_len(), 6, "other groups untouched");
    }

    #[test]
    fn deadline_flush_respects_hold_and_caps_chunks() {
        // A hold long enough that scheduler pauses cannot make it elapse.
        let mut b = Batcher::new(AdaptiveController::new(4, Duration::from_secs(30)));
        for _ in 0..10 {
            let _ = b.push_train("ij,jk->ik", train_pending(&[vec![2, 3], vec![3, 4]]), 1.0);
        }
        // Group flushed once at 4+4; 2 remain pending.
        assert_eq!(b.pending_len(), 2);
        // Not yet due under full hold.
        assert!(b.due(Instant::now(), 1.0).is_empty());
        // Due once the hold elapses (or immediately at utilization 0).
        let batches = b.due(Instant::now(), 0.0);
        assert_eq!(batches.len(), 1);
        match &batches[0] {
            ReadyBatch::Train { items, policy, .. } => {
                assert_eq!(items.len(), 2);
                assert_eq!(*policy, CkptPolicy::StoreAll);
            }
            _ => panic!("train batch expected"),
        }
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn drain_chunks_by_config_bound() {
        let mut b = Batcher::new(AdaptiveController::new(4, Duration::from_millis(5)));
        for _ in 0..9 {
            // Utilization above 1 clamps; nothing flushes below 4... but the
            // 4th and 8th pushes do. Use a fresh group each time via shapes?
            // Simpler: push with utilization that never triggers (cap 4
            // reached at pushes 4 and 8), so drain sees the remainder plus
            // verify chunking on a long tail.
            let _ = b.push_eval("l", eval_pending(&[1, 3, 4, 4]), 1.0);
        }
        // pushes 4 and 8 flushed; one request remains.
        assert_eq!(b.pending_len(), 1);
        let drained = b.drain();
        assert_eq!(drained.len(), 1);
        assert_eq!(drained[0].len(), 1);
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn split_items_preserves_order() {
        let chunks = split_items((0..10).collect::<Vec<_>>(), 4);
        assert_eq!(chunks, vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7], vec![8, 9]]);
        let whole = split_items(vec![1, 2], 4);
        assert_eq!(whole, vec![vec![1, 2]]);
    }

    #[test]
    fn next_deadline_tracks_oldest_group() {
        let mut b = Batcher::new(controller());
        assert!(b.next_deadline(1.0).is_none());
        let _ = b.push_eval("l", eval_pending(&[1, 3, 4, 4]), 1.0);
        let d1 = b.next_deadline(1.0).expect("one group pending");
        std::thread::sleep(Duration::from_millis(2));
        let _ = b.push_eval("l", eval_pending(&[1, 3, 6, 6]), 1.0);
        let d2 = b.next_deadline(1.0).expect("two groups pending");
        assert_eq!(d1, d2, "deadline anchored to the oldest request");
    }
}
