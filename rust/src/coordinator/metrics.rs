//! Service metrics: atomic counters + latency histograms, with cheap
//! snapshots for reporting.

use crate::util::timing::LatencyHisto;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Shared metrics for the evaluation service.
#[derive(Default)]
pub struct ServiceMetrics {
    submitted: AtomicU64,
    completed: AtomicU64,
    errors: AtomicU64,
    batches: AtomicU64,
    batched_requests: AtomicU64,
    plan_misses: AtomicU64,
    queue_depth: AtomicUsize,
    latency: Mutex<LatencyHisto>,
    exec_time: Mutex<LatencyHisto>,
}

impl ServiceMetrics {
    pub fn note_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_done(&self, latency: Duration) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latency.lock().unwrap().record(latency);
    }

    pub fn note_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests
            .fetch_add(size as u64, Ordering::Relaxed);
    }

    pub fn note_plan_miss(&self) {
        self.plan_misses.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_exec_time(&self, d: Duration) {
        self.exec_time.lock().unwrap().record(d);
    }

    pub fn set_queue_depth(&self, depth: usize) {
        self.queue_depth.store(depth, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let latency = self.latency.lock().unwrap().clone();
        let exec = self.exec_time.lock().unwrap().clone();
        let batches = self.batches.load(Ordering::Relaxed);
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            batches,
            mean_batch_size: if batches == 0 {
                0.0
            } else {
                self.batched_requests.load(Ordering::Relaxed) as f64 / batches as f64
            },
            plan_misses: self.plan_misses.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            latency_p50_us: latency.percentile_us(50.0),
            latency_p99_us: latency.percentile_us(99.0),
            latency_mean_us: latency.mean_us(),
            exec_mean_us: exec.mean_us(),
        }
    }
}

/// A point-in-time copy of the service metrics.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub errors: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    pub plan_misses: u64,
    pub queue_depth: usize,
    pub latency_p50_us: f64,
    pub latency_p99_us: f64,
    pub latency_mean_us: f64,
    pub exec_mean_us: f64,
}

impl MetricsSnapshot {
    pub fn report(&self) -> String {
        format!(
            "requests: {} submitted, {} completed, {} errors | batches: {} (mean size {:.2}, {} plan misses) | latency: p50 {:.0}us p99 {:.0}us mean {:.0}us | exec mean {:.0}us",
            self.submitted,
            self.completed,
            self.errors,
            self.batches,
            self.mean_batch_size,
            self.plan_misses,
            self.latency_p50_us,
            self.latency_p99_us,
            self.latency_mean_us,
            self.exec_mean_us,
        )
    }
}
