//! Service metrics: atomic counters, a batch-size histogram, and latency
//! histograms (end-to-end, queue wait, execution), with cheap snapshots
//! for reporting. The in-flight gauge doubles as the utilization signal
//! the adaptive batching controller reads.

use crate::util::timing::LatencyHisto;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Batch-size histogram buckets: index = exact batch size for sizes
/// `1..BATCH_SIZE_BUCKETS-1`, with the last bucket counting everything at
/// or above it (index 0 is unused — batches have at least one request).
pub const BATCH_SIZE_BUCKETS: usize = 33;

/// Shared metrics for the evaluation service.
pub struct ServiceMetrics {
    submitted: AtomicU64,
    infer_submitted: AtomicU64,
    train_submitted: AtomicU64,
    completed: AtomicU64,
    errors: AtomicU64,
    batches: AtomicU64,
    batched_requests: AtomicU64,
    train_batches: AtomicU64,
    train_batched_requests: AtomicU64,
    plan_misses: AtomicU64,
    /// Worker threads resurrected after a panic (supervision).
    worker_restarts: AtomicU64,
    /// Requests shed because their absolute deadline passed before dispatch.
    deadline_expired: AtomicU64,
    /// Requests rejected at submit time by admission control.
    overload_rejected: AtomicU64,
    /// Inference requests re-queued after a worker died mid-batch.
    retries: AtomicU64,
    queue_depth: AtomicUsize,
    /// Bytes held by pending (undispatched) request payloads.
    pending_bytes: AtomicUsize,
    /// Work messages dispatched to workers and not yet finished — the
    /// coordinator half of the utilization signal driving adaptive batch
    /// sizing (the other half is [`crate::parallel::Pool::utilization`]).
    inflight: AtomicUsize,
    /// Sizes of every flushed batch (inference and training alike).
    batch_sizes: [AtomicU64; BATCH_SIZE_BUCKETS],
    latency: Mutex<LatencyHisto>,
    /// Router-queue residency per request: enqueue → dispatch to a worker.
    queue_wait: Mutex<LatencyHisto>,
    exec_time: Mutex<LatencyHisto>,
}

impl Default for ServiceMetrics {
    fn default() -> Self {
        ServiceMetrics {
            submitted: AtomicU64::new(0),
            infer_submitted: AtomicU64::new(0),
            train_submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            train_batches: AtomicU64::new(0),
            train_batched_requests: AtomicU64::new(0),
            plan_misses: AtomicU64::new(0),
            worker_restarts: AtomicU64::new(0),
            deadline_expired: AtomicU64::new(0),
            overload_rejected: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            queue_depth: AtomicUsize::new(0),
            pending_bytes: AtomicUsize::new(0),
            inflight: AtomicUsize::new(0),
            batch_sizes: std::array::from_fn(|_| AtomicU64::new(0)),
            latency: Mutex::new(LatencyHisto::default()),
            queue_wait: Mutex::new(LatencyHisto::default()),
            exec_time: Mutex::new(LatencyHisto::default()),
        }
    }
}

impl ServiceMetrics {
    /// An inference request (layer eval or ad-hoc expression) entered.
    pub fn note_infer_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
        self.infer_submitted.fetch_add(1, Ordering::Relaxed);
    }

    /// A training-step request entered.
    pub fn note_train_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
        self.train_submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_done(&self, latency: Duration) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latency.lock().unwrap().record(latency);
    }

    pub fn note_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    fn note_batch_size(&self, size: usize) {
        let bucket = size.min(BATCH_SIZE_BUCKETS - 1);
        self.batch_sizes[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// An inference batch of `size` requests was flushed to a worker.
    pub fn note_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests
            .fetch_add(size as u64, Ordering::Relaxed);
        self.note_batch_size(size);
    }

    /// A training batch of `size` requests was flushed to a worker.
    pub fn note_train_batch(&self, size: usize) {
        self.train_batches.fetch_add(1, Ordering::Relaxed);
        self.train_batched_requests
            .fetch_add(size as u64, Ordering::Relaxed);
        self.note_batch_size(size);
    }

    /// Record one request's router-queue residency (enqueue → dispatch).
    pub fn note_queue_wait(&self, d: Duration) {
        self.queue_wait.lock().unwrap().record(d);
    }

    pub fn note_plan_miss(&self) {
        self.plan_misses.fetch_add(1, Ordering::Relaxed);
    }

    /// A panicked worker thread was resurrected by the supervisor.
    pub fn note_worker_restart(&self) {
        self.worker_restarts.fetch_add(1, Ordering::Relaxed);
    }

    /// Worker restarts so far (regression tests assert capacity recovery).
    pub fn worker_restarts(&self) -> u64 {
        self.worker_restarts.load(Ordering::Relaxed)
    }

    /// A request was shed with `DeadlineExceeded` instead of dispatched.
    pub fn note_deadline_expired(&self) {
        self.deadline_expired.fetch_add(1, Ordering::Relaxed);
    }

    /// A request was rejected at submit time with `Overloaded`.
    pub fn note_overload_rejected(&self) {
        self.overload_rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// An inference request was re-queued after a worker crash.
    pub fn note_retry(&self) {
        self.retries.fetch_add(1, Ordering::Relaxed);
    }

    /// Update the pending-payload byte gauge (set by the router each tick).
    pub fn set_pending_bytes(&self, bytes: usize) {
        self.pending_bytes.store(bytes, Ordering::Relaxed);
    }

    pub fn note_exec_time(&self, d: Duration) {
        self.exec_time.lock().unwrap().record(d);
    }

    pub fn set_queue_depth(&self, depth: usize) {
        self.queue_depth.store(depth, Ordering::Relaxed);
    }

    /// Requests pending in the router (gauge, set by the router each tick);
    /// the submit-side admission check reads this to reject early.
    pub fn queue_depth(&self) -> usize {
        self.queue_depth.load(Ordering::Relaxed)
    }

    /// Bytes held by pending request payloads (gauge, set by the router
    /// each tick); the submit-side admission check reads this.
    pub fn pending_bytes(&self) -> usize {
        self.pending_bytes.load(Ordering::Relaxed)
    }

    /// A work message left the router for the worker channel.
    pub fn note_dispatched(&self) {
        self.inflight.fetch_add(1, Ordering::Relaxed);
    }

    /// A worker finished a work message (paired with
    /// [`ServiceMetrics::note_dispatched`]).
    pub fn note_work_done(&self) {
        self.inflight.fetch_sub(1, Ordering::Relaxed);
    }

    /// Work messages currently dispatched and unfinished.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let latency = self.latency.lock().unwrap().clone();
        let queue = self.queue_wait.lock().unwrap().clone();
        let exec = self.exec_time.lock().unwrap().clone();
        let batches = self.batches.load(Ordering::Relaxed);
        let train_batches = self.train_batches.load(Ordering::Relaxed);
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            infer_submitted: self.infer_submitted.load(Ordering::Relaxed),
            train_submitted: self.train_submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            batches,
            mean_batch_size: if batches == 0 {
                0.0
            } else {
                self.batched_requests.load(Ordering::Relaxed) as f64 / batches as f64
            },
            train_batches,
            mean_train_batch_size: if train_batches == 0 {
                0.0
            } else {
                self.train_batched_requests.load(Ordering::Relaxed) as f64 / train_batches as f64
            },
            batch_sizes: self
                .batch_sizes
                .iter()
                .map(|c| c.load(Ordering::Relaxed))
                .collect(),
            plan_misses: self.plan_misses.load(Ordering::Relaxed),
            worker_restarts: self.worker_restarts.load(Ordering::Relaxed),
            deadline_expired: self.deadline_expired.load(Ordering::Relaxed),
            overload_rejected: self.overload_rejected.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            pending_bytes: self.pending_bytes.load(Ordering::Relaxed),
            inflight: self.inflight.load(Ordering::Relaxed),
            latency_p50_us: latency.percentile_us(50.0),
            latency_p99_us: latency.percentile_us(99.0),
            latency_mean_us: latency.mean_us(),
            queue_p50_us: queue.percentile_us(50.0),
            queue_p99_us: queue.percentile_us(99.0),
            queue_mean_us: queue.mean_us(),
            exec_mean_us: exec.mean_us(),
        }
    }
}

/// A point-in-time copy of the service metrics.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    /// Inference submissions (layer evals + ad-hoc expressions).
    pub infer_submitted: u64,
    /// Training-step submissions.
    pub train_submitted: u64,
    pub completed: u64,
    pub errors: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    /// Coalesced training batches flushed.
    pub train_batches: u64,
    pub mean_train_batch_size: f64,
    /// Batch-size histogram over all flushed batches (inference and
    /// training): `batch_sizes[s]` counts batches of exactly `s` requests
    /// for `s < BATCH_SIZE_BUCKETS - 1`; the last entry counts larger ones.
    pub batch_sizes: Vec<u64>,
    pub plan_misses: u64,
    /// Worker threads resurrected after a panic.
    pub worker_restarts: u64,
    /// Requests shed with `DeadlineExceeded` before dispatch.
    pub deadline_expired: u64,
    /// Requests rejected with `Overloaded` at submit time.
    pub overload_rejected: u64,
    /// Inference requests re-queued after a worker crash.
    pub retries: u64,
    pub queue_depth: usize,
    /// Bytes held by pending (undispatched) request payloads.
    pub pending_bytes: usize,
    /// Work messages dispatched and unfinished at snapshot time.
    pub inflight: usize,
    pub latency_p50_us: f64,
    pub latency_p99_us: f64,
    pub latency_mean_us: f64,
    /// Router-queue residency (enqueue → dispatch) percentiles.
    pub queue_p50_us: f64,
    pub queue_p99_us: f64,
    pub queue_mean_us: f64,
    pub exec_mean_us: f64,
}

impl MetricsSnapshot {
    pub fn report(&self) -> String {
        format!(
            "requests: {} submitted ({} infer / {} train), {} completed, {} errors | \
             batches: {} infer (mean size {:.2}), {} train (mean size {:.2}), {} plan misses | \
             faults: {} restarts, {} deadline-expired, {} overload-rejected, {} retries | \
             pending {} bytes | \
             latency: p50 {:.0}us p99 {:.0}us mean {:.0}us | queue: p50 {:.0}us mean {:.0}us | \
             exec mean {:.0}us",
            self.submitted,
            self.infer_submitted,
            self.train_submitted,
            self.completed,
            self.errors,
            self.batches,
            self.mean_batch_size,
            self.train_batches,
            self.mean_train_batch_size,
            self.plan_misses,
            self.worker_restarts,
            self.deadline_expired,
            self.overload_rejected,
            self.retries,
            self.pending_bytes,
            self.latency_p50_us,
            self.latency_p99_us,
            self.latency_mean_us,
            self.queue_p50_us,
            self.queue_mean_us,
            self.exec_mean_us,
        )
    }
}
