//! Coordinator tests: correctness under batching, concurrency, error
//! routing, metrics accounting and shutdown.

use super::*;
use crate::exec::conv_einsum;
use crate::tnn::{build_layer, Decomp};
use crate::util::rng::Rng;

fn cp_layer(name: &str, rng: &mut Rng) -> (String, String, Vec<Tensor>, crate::tnn::TnnLayerSpec) {
    let spec = build_layer(Decomp::Cp, 1, 4, 3, 3, 3, 1.0).unwrap();
    let factors = spec.init_factors(rng);
    (name.to_string(), spec.expr.clone(), factors, spec)
}

#[test]
fn single_request_matches_direct_execution() {
    let mut rng = Rng::new(1);
    let (name, expr, factors, _spec) = cp_layer("cp", &mut rng);
    let service = EvalService::start(
        ServiceConfig::default(),
        vec![(name.clone(), expr.clone(), factors.clone())],
    )
    .unwrap();
    let h = service.handle();
    let x = Tensor::rand(&[1, 3, 8, 8], -1.0, 1.0, &mut rng);
    let y = h.eval("cp", x.clone()).unwrap();
    // direct evaluation
    let mut inputs = vec![&x];
    inputs.extend(factors.iter());
    let want = conv_einsum(&expr, &inputs).unwrap();
    y.assert_close(&want, 1e-4);
    service.shutdown();
}

#[test]
fn batched_requests_each_get_their_slice() {
    let mut rng = Rng::new(2);
    let (name, expr, factors, _spec) = cp_layer("cp", &mut rng);
    let service = EvalService::start(
        ServiceConfig {
            max_batch: 4,
            batch_timeout: std::time::Duration::from_millis(20),
            ..Default::default()
        },
        vec![(name, expr.clone(), factors.clone())],
    )
    .unwrap();
    let h = service.handle();
    let xs: Vec<Tensor> = (0..6)
        .map(|_| Tensor::rand(&[1, 3, 6, 6], -1.0, 1.0, &mut rng))
        .collect();
    let receivers: Vec<_> = xs
        .iter()
        .map(|x| h.submit("cp", x.clone()).unwrap())
        .collect();
    for (x, rx) in xs.iter().zip(receivers) {
        let y = rx.recv().unwrap().unwrap();
        let mut inputs = vec![x];
        inputs.extend(factors.iter());
        let want = conv_einsum(&expr, &inputs).unwrap();
        y.assert_close(&want, 1e-4);
    }
    let m = h.metrics();
    assert_eq!(m.completed, 6);
    assert!(m.batches >= 1);
    assert!(m.mean_batch_size >= 1.0);
    service.shutdown();
}

#[test]
fn batching_coalesces_under_load() {
    let mut rng = Rng::new(3);
    let (name, expr, factors, _spec) = cp_layer("cp", &mut rng);
    let service = EvalService::start(
        ServiceConfig {
            max_batch: 8,
            workers: 1,
            batch_timeout: std::time::Duration::from_millis(30),
            ..Default::default()
        },
        vec![(name, expr, factors)],
    )
    .unwrap();
    let h = service.handle();
    let receivers: Vec<_> = (0..16)
        .map(|_| {
            let x = Tensor::rand(&[1, 3, 6, 6], -1.0, 1.0, &mut rng);
            h.submit("cp", x).unwrap()
        })
        .collect();
    for rx in receivers {
        rx.recv().unwrap().unwrap();
    }
    let m = h.metrics();
    assert_eq!(m.completed, 16);
    assert!(
        m.batches < 16,
        "16 requests should coalesce into fewer batches (got {})",
        m.batches
    );
    assert!(m.mean_batch_size > 1.0);
    service.shutdown();
}

#[test]
fn concurrent_clients() {
    let mut rng = Rng::new(4);
    let (name, expr, factors, _spec) = cp_layer("cp", &mut rng);
    let service =
        EvalService::start(ServiceConfig::default(), vec![(name, expr.clone(), factors.clone())])
            .unwrap();
    let h = service.handle();
    let threads: Vec<_> = (0..4)
        .map(|tid| {
            let h = h.clone();
            let factors = factors.clone();
            let expr = expr.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(100 + tid);
                for _ in 0..5 {
                    let x = Tensor::rand(&[1, 3, 5, 5], -1.0, 1.0, &mut rng);
                    let y = h.eval("cp", x.clone()).unwrap();
                    let mut inputs = vec![&x];
                    inputs.extend(factors.iter());
                    let want = conv_einsum(&expr, &inputs).unwrap();
                    y.assert_close(&want, 1e-4);
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    assert_eq!(h.metrics().completed, 20);
    service.shutdown();
}

#[test]
fn unknown_layer_errors() {
    let service = EvalService::start(ServiceConfig::default(), vec![]).unwrap();
    let h = service.handle();
    let x = Tensor::zeros(&[1, 3, 4, 4]);
    let res = h.eval("nope", x);
    assert!(res.is_err());
    service.shutdown();
}

#[test]
fn adhoc_expression_evaluation() {
    let service = EvalService::start(ServiceConfig::default(), vec![]).unwrap();
    let h = service.handle();
    let mut rng = Rng::new(5);
    let a = Tensor::rand(&[3, 4], -1.0, 1.0, &mut rng);
    let b = Tensor::rand(&[4, 5], -1.0, 1.0, &mut rng);
    let y = h
        .submit_adhoc("ij,jk->ik", vec![a.clone(), b.clone()])
        .unwrap()
        .recv()
        .unwrap()
        .unwrap();
    let want = conv_einsum("ij,jk->ik", &[&a, &b]).unwrap();
    y.assert_close(&want, 1e-5);
    // bad expression routes an error back, not a hang
    let res = h
        .submit_adhoc("ij,jk->iz", vec![a, b])
        .unwrap()
        .recv()
        .unwrap();
    assert!(res.is_err());
    service.shutdown();
}

#[test]
fn train_request_matches_direct_forward_backward() {
    use crate::autodiff::{CkptPolicy, MemoryMeter, PathAutodiff};
    use crate::exec::TrainWorkspace;
    use crate::planner::{plan_with, PlanOptions};

    let service = EvalService::start(ServiceConfig::default(), vec![]).unwrap();
    let h = service.handle();
    let mut rng = Rng::new(9);
    let expr = "ij,jk->ik";
    let a = Tensor::rand(&[3, 4], -1.0, 1.0, &mut rng);
    let b = Tensor::rand(&[4, 5], -1.0, 1.0, &mut rng);
    let dout = Tensor::rand(&[3, 5], -1.0, 1.0, &mut rng);

    let (y, grads) = h
        .train(
            expr,
            vec![a.clone(), b.clone()],
            dout.clone(),
            CkptPolicy::Sqrt,
        )
        .unwrap();

    // Direct training step with the same (training-cost) plan options.
    let spec = crate::einsum::parse(expr).unwrap();
    let sized =
        crate::einsum::SizedSpec::new(spec, vec![vec![3, 4], vec![4, 5]]).unwrap();
    let plan = plan_with(
        &sized,
        &PlanOptions {
            training: true,
            ..Default::default()
        },
    )
    .unwrap();
    let ad = PathAutodiff::new(&plan).unwrap();
    let mut ws = TrainWorkspace::new();
    let meter = MemoryMeter::new();
    let d = dout.clone();
    let (want_y, want_grads) = ad
        .forward_backward(&[&a, &b], |_| d.clone(), CkptPolicy::Sqrt, &mut ws, &meter)
        .unwrap();
    y.assert_close(&want_y, 1e-5);
    assert_eq!(grads.len(), 2);
    for (g, w) in grads.iter().zip(want_grads.iter()) {
        g.assert_close(w, 1e-5);
    }

    // Single-input expressions are rejected with an error, not a hang.
    let res = h.train(
        "ij->j",
        vec![Tensor::zeros(&[2, 3])],
        Tensor::zeros(&[3]),
        CkptPolicy::StoreAll,
    );
    assert!(res.is_err());
    service.shutdown();
}

#[test]
fn train_requests_coalesce_into_batches_with_exact_results() {
    use crate::autodiff::{CkptPolicy, MemoryMeter, PathAutodiff};
    use crate::exec::{compile_expr, TrainWorkspace};
    use crate::planner::PlanOptions;
    use std::time::Duration;

    // One worker, big enough steps that the router outruns the worker: a
    // steady same-expression training stream must coalesce (observable via
    // the batch-size histogram) with results identical to direct engine
    // execution.
    let expr = "bsx,tsx,tu,uv->bvx|x";
    let dims: Vec<Vec<usize>> = vec![vec![4, 8, 32], vec![16, 8, 3], vec![16, 32], vec![32, 8]];
    let opts = PlanOptions {
        training: true,
        ..Default::default()
    };
    let compiled = std::sync::Arc::new(compile_expr(expr, &dims, &opts).unwrap());
    let ad = PathAutodiff::from_compiled(std::sync::Arc::clone(&compiled));

    let service = EvalService::start(
        ServiceConfig {
            workers: 1,
            max_batch: 8,
            batch_timeout: Duration::from_millis(20),
            ..Default::default()
        },
        vec![],
    )
    .unwrap();
    let h = service.handle();
    let mut rng = Rng::new(21);
    let n_req = 16usize;
    let reqs: Vec<(Vec<Tensor>, Tensor)> = (0..n_req)
        .map(|_| {
            let ins: Vec<Tensor> = dims.iter().map(|d| Tensor::rand(d, -1.0, 1.0, &mut rng)).collect();
            let dout = Tensor::rand(compiled.out_shape(), -1.0, 1.0, &mut rng);
            (ins, dout)
        })
        .collect();
    let rxs: Vec<_> = reqs
        .iter()
        .map(|(ins, dout)| {
            h.submit_train(expr, ins.clone(), dout.clone(), CkptPolicy::Sqrt)
                .unwrap()
        })
        .collect();

    let mut ws = TrainWorkspace::new();
    let meter = MemoryMeter::new();
    for ((ins, dout), rx) in reqs.iter().zip(rxs) {
        let (y, grads) = rx.recv().unwrap().unwrap();
        let refs: Vec<&Tensor> = ins.iter().collect();
        let d = dout.clone();
        let (want_y, want_g) = ad
            .forward_backward(&refs, |_| d.clone(), CkptPolicy::Sqrt, &mut ws, &meter)
            .unwrap();
        y.assert_close(&want_y, 1e-6);
        assert_eq!(grads.len(), want_g.len());
        for (g, w) in grads.iter().zip(want_g.iter()) {
            g.assert_close(w, 1e-6);
        }
    }

    let m = h.metrics();
    assert_eq!(m.train_submitted, n_req as u64);
    assert_eq!(m.completed, n_req as u64);
    assert!(
        m.train_batches < n_req as u64,
        "{n_req} streamed train steps must coalesce into fewer batches (got {})",
        m.train_batches
    );
    assert!(m.mean_train_batch_size > 1.0);
    assert!(
        m.batch_sizes[2..].iter().any(|&c| c > 0),
        "batch-size histogram must record a coalesced (size >= 2) batch: {:?}",
        m.batch_sizes
    );
    service.shutdown();
}

#[test]
fn alternating_shapes_batch_independently_without_starvation() {
    // The pre-unification router flushed the whole partial batch whenever an
    // incompatible shape arrived, so an alternating-shape stream never
    // formed batches. Grouped queues must batch each shape independently.
    let mut rng = Rng::new(22);
    let (name, expr, factors, _spec) = cp_layer("cp", &mut rng);
    let service = EvalService::start(
        ServiceConfig {
            workers: 1,
            max_batch: 4,
            batch_timeout: std::time::Duration::from_millis(30),
            ..Default::default()
        },
        vec![(name, expr.clone(), factors.clone())],
    )
    .unwrap();
    let h = service.handle();
    let n_pairs = 8usize;
    let xs: Vec<Tensor> = (0..2 * n_pairs)
        .map(|i| {
            let hw = if i % 2 == 0 { 6 } else { 10 };
            Tensor::rand(&[1, 3, hw, hw], -1.0, 1.0, &mut rng)
        })
        .collect();
    let rxs: Vec<_> = xs.iter().map(|x| h.submit("cp", x.clone()).unwrap()).collect();
    for (x, rx) in xs.iter().zip(rxs) {
        let y = rx.recv().unwrap().unwrap();
        let mut inputs = vec![x];
        inputs.extend(factors.iter());
        let want = conv_einsum(&expr, &inputs).unwrap();
        y.assert_close(&want, 1e-4);
    }
    let m = h.metrics();
    assert_eq!(m.completed, 2 * n_pairs as u64);
    assert!(
        m.batches < 2 * n_pairs as u64,
        "interleaved shapes must still coalesce per shape group (got {} batches for {} requests)",
        m.batches,
        2 * n_pairs
    );
    assert!(m.mean_batch_size > 1.0);
    service.shutdown();
}

#[test]
fn metrics_expose_queue_latency_kind_counters_and_batch_histogram() {
    use crate::autodiff::CkptPolicy;

    let mut rng = Rng::new(23);
    let (name, expr, factors, _spec) = cp_layer("cp", &mut rng);
    let service =
        EvalService::start(ServiceConfig::default(), vec![(name, expr, factors)]).unwrap();
    let h = service.handle();
    for _ in 0..2 {
        let x = Tensor::rand(&[1, 3, 6, 6], -1.0, 1.0, &mut rng);
        h.eval("cp", x).unwrap();
    }
    let a = Tensor::rand(&[3, 4], -1.0, 1.0, &mut rng);
    let b = Tensor::rand(&[4, 5], -1.0, 1.0, &mut rng);
    h.submit_adhoc("ij,jk->ik", vec![a.clone(), b.clone()])
        .unwrap()
        .recv()
        .unwrap()
        .unwrap();
    let dout = Tensor::rand(&[3, 5], -1.0, 1.0, &mut rng);
    h.train("ij,jk->ik", vec![a, b], dout, CkptPolicy::StoreAll)
        .unwrap();

    let m = h.metrics();
    assert_eq!(m.infer_submitted, 3, "two layer evals + one ad-hoc");
    assert_eq!(m.train_submitted, 1);
    assert_eq!(m.submitted, 4);
    assert_eq!(m.completed, 4);
    // Every flushed batch (infer + train) lands in exactly one histogram
    // bucket; ad-hoc requests bypass batching.
    let histo_total: u64 = m.batch_sizes.iter().sum();
    assert_eq!(histo_total, m.batches + m.train_batches);
    assert!(m.batches >= 1 && m.train_batches >= 1);
    // Queue residency was recorded for every batched request.
    assert!(m.queue_p50_us >= 0.0 && m.queue_p99_us >= m.queue_p50_us);
    // The responder races the worker's in-flight decrement by design, so
    // at most the just-answered message may still read as in flight.
    assert!(m.inflight <= 1, "drained service shows no backlog");
    service.shutdown();
}

#[test]
fn mixed_shapes_do_not_cross_batch() {
    let mut rng = Rng::new(6);
    let (name, expr, factors, _spec) = cp_layer("cp", &mut rng);
    let service = EvalService::start(
        ServiceConfig {
            max_batch: 8,
            ..Default::default()
        },
        vec![(name, expr.clone(), factors.clone())],
    )
    .unwrap();
    let h = service.handle();
    let x1 = Tensor::rand(&[1, 3, 6, 6], -1.0, 1.0, &mut rng);
    let x2 = Tensor::rand(&[1, 3, 10, 10], -1.0, 1.0, &mut rng);
    let r1 = h.submit("cp", x1.clone()).unwrap();
    let r2 = h.submit("cp", x2.clone()).unwrap();
    let y1 = r1.recv().unwrap().unwrap();
    let y2 = r2.recv().unwrap().unwrap();
    assert_eq!(y1.shape(), &[1, 4, 6, 6]);
    assert_eq!(y2.shape(), &[1, 4, 10, 10]);
    let mut i1 = vec![&x1];
    i1.extend(factors.iter());
    y1.assert_close(&conv_einsum(&expr, &i1).unwrap(), 1e-4);
    service.shutdown();
}

#[test]
fn plan_cache_hit_on_repeated_shapes() {
    let mut rng = Rng::new(7);
    let (name, expr, factors, _spec) = cp_layer("cp", &mut rng);
    let service = EvalService::start(
        ServiceConfig {
            max_batch: 1, // force one batch per request → same plan key
            ..Default::default()
        },
        vec![(name, expr, factors)],
    )
    .unwrap();
    let h = service.handle();
    for _ in 0..5 {
        let x = Tensor::rand(&[1, 3, 6, 6], -1.0, 1.0, &mut rng);
        h.eval("cp", x).unwrap();
    }
    let m = h.metrics();
    assert_eq!(m.completed, 5);
    assert_eq!(m.plan_misses, 1, "plan should be cached after first use");
    service.shutdown();
}

#[test]
fn layer_plan_cache_evicts_lru_geometry() {
    // Fill a layer's per-geometry plan cache past LAYER_PLAN_CACHE_CAPACITY
    // with distinct spatial shapes: the first geometry must be evicted (its
    // re-submission re-plans), while the most recent stays cached — both
    // observable through the plan-miss metric.
    let mut rng = Rng::new(8);
    let (name, expr, factors, _spec) = cp_layer("cp", &mut rng);
    let service = EvalService::start(
        ServiceConfig {
            max_batch: 1, // one batch per request → one plan key per shape
            ..Default::default()
        },
        vec![(name, expr, factors)],
    )
    .unwrap();
    let h = service.handle();
    let eval_spatial = |hw: usize, rng: &mut Rng| {
        let x = Tensor::rand(&[1, 3, hw, hw], -1.0, 1.0, rng);
        h.eval("cp", x).unwrap();
    };
    // Geometry A, then `capacity` distinct fillers (A becomes LRU and is
    // evicted when the last filler lands).
    eval_spatial(5, &mut rng);
    for hw in 6..6 + LAYER_PLAN_CACHE_CAPACITY {
        eval_spatial(hw, &mut rng);
    }
    let misses_after_fill = h.metrics().plan_misses;
    assert_eq!(misses_after_fill as usize, LAYER_PLAN_CACHE_CAPACITY + 1);
    // The newest filler is still cached: no new miss.
    eval_spatial(5 + LAYER_PLAN_CACHE_CAPACITY, &mut rng);
    assert_eq!(h.metrics().plan_misses, misses_after_fill);
    // Geometry A was evicted: re-submission re-plans.
    eval_spatial(5, &mut rng);
    assert_eq!(h.metrics().plan_misses, misses_after_fill + 1);
    service.shutdown();
}
