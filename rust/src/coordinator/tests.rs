//! Coordinator tests: correctness under batching, concurrency, error
//! routing, metrics accounting and shutdown.

use super::*;
use crate::exec::conv_einsum;
use crate::tnn::{build_layer, Decomp};
use crate::util::rng::Rng;

fn cp_layer(name: &str, rng: &mut Rng) -> (String, String, Vec<Tensor>, crate::tnn::TnnLayerSpec) {
    let spec = build_layer(Decomp::Cp, 1, 4, 3, 3, 3, 1.0).unwrap();
    let factors = spec.init_factors(rng);
    (name.to_string(), spec.expr.clone(), factors, spec)
}

#[test]
fn single_request_matches_direct_execution() {
    let mut rng = Rng::new(1);
    let (name, expr, factors, _spec) = cp_layer("cp", &mut rng);
    let service = EvalService::start(
        ServiceConfig::default(),
        vec![(name.clone(), expr.clone(), factors.clone())],
    )
    .unwrap();
    let h = service.handle();
    let x = Tensor::rand(&[1, 3, 8, 8], -1.0, 1.0, &mut rng);
    let y = h.eval("cp", x.clone()).unwrap();
    // direct evaluation
    let mut inputs = vec![&x];
    inputs.extend(factors.iter());
    let want = conv_einsum(&expr, &inputs).unwrap();
    y.assert_close(&want, 1e-4);
    service.shutdown();
}

#[test]
fn batched_requests_each_get_their_slice() {
    let mut rng = Rng::new(2);
    let (name, expr, factors, _spec) = cp_layer("cp", &mut rng);
    let service = EvalService::start(
        ServiceConfig {
            max_batch: 4,
            batch_timeout: std::time::Duration::from_millis(20),
            ..Default::default()
        },
        vec![(name, expr.clone(), factors.clone())],
    )
    .unwrap();
    let h = service.handle();
    let xs: Vec<Tensor> = (0..6)
        .map(|_| Tensor::rand(&[1, 3, 6, 6], -1.0, 1.0, &mut rng))
        .collect();
    let receivers: Vec<_> = xs
        .iter()
        .map(|x| h.submit("cp", x.clone()).unwrap())
        .collect();
    for (x, rx) in xs.iter().zip(receivers) {
        let y = rx.recv().unwrap().unwrap();
        let mut inputs = vec![x];
        inputs.extend(factors.iter());
        let want = conv_einsum(&expr, &inputs).unwrap();
        y.assert_close(&want, 1e-4);
    }
    let m = h.metrics();
    assert_eq!(m.completed, 6);
    assert!(m.batches >= 1);
    assert!(m.mean_batch_size >= 1.0);
    service.shutdown();
}

#[test]
fn batching_coalesces_under_load() {
    let mut rng = Rng::new(3);
    let (name, expr, factors, _spec) = cp_layer("cp", &mut rng);
    let service = EvalService::start(
        ServiceConfig {
            max_batch: 8,
            workers: 1,
            batch_timeout: std::time::Duration::from_millis(30),
            ..Default::default()
        },
        vec![(name, expr, factors)],
    )
    .unwrap();
    let h = service.handle();
    let receivers: Vec<_> = (0..16)
        .map(|_| {
            let x = Tensor::rand(&[1, 3, 6, 6], -1.0, 1.0, &mut rng);
            h.submit("cp", x).unwrap()
        })
        .collect();
    for rx in receivers {
        rx.recv().unwrap().unwrap();
    }
    let m = h.metrics();
    assert_eq!(m.completed, 16);
    assert!(
        m.batches < 16,
        "16 requests should coalesce into fewer batches (got {})",
        m.batches
    );
    assert!(m.mean_batch_size > 1.0);
    service.shutdown();
}

#[test]
fn concurrent_clients() {
    let mut rng = Rng::new(4);
    let (name, expr, factors, _spec) = cp_layer("cp", &mut rng);
    let service =
        EvalService::start(ServiceConfig::default(), vec![(name, expr.clone(), factors.clone())])
            .unwrap();
    let h = service.handle();
    let threads: Vec<_> = (0..4)
        .map(|tid| {
            let h = h.clone();
            let factors = factors.clone();
            let expr = expr.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(100 + tid);
                for _ in 0..5 {
                    let x = Tensor::rand(&[1, 3, 5, 5], -1.0, 1.0, &mut rng);
                    let y = h.eval("cp", x.clone()).unwrap();
                    let mut inputs = vec![&x];
                    inputs.extend(factors.iter());
                    let want = conv_einsum(&expr, &inputs).unwrap();
                    y.assert_close(&want, 1e-4);
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    assert_eq!(h.metrics().completed, 20);
    service.shutdown();
}

#[test]
fn unknown_layer_errors() {
    let service = EvalService::start(ServiceConfig::default(), vec![]).unwrap();
    let h = service.handle();
    let x = Tensor::zeros(&[1, 3, 4, 4]);
    let res = h.eval("nope", x);
    assert!(res.is_err());
    service.shutdown();
}

#[test]
fn adhoc_expression_evaluation() {
    let service = EvalService::start(ServiceConfig::default(), vec![]).unwrap();
    let h = service.handle();
    let mut rng = Rng::new(5);
    let a = Tensor::rand(&[3, 4], -1.0, 1.0, &mut rng);
    let b = Tensor::rand(&[4, 5], -1.0, 1.0, &mut rng);
    let y = h
        .submit_adhoc("ij,jk->ik", vec![a.clone(), b.clone()])
        .unwrap()
        .recv()
        .unwrap()
        .unwrap();
    let want = conv_einsum("ij,jk->ik", &[&a, &b]).unwrap();
    y.assert_close(&want, 1e-5);
    // bad expression routes an error back, not a hang
    let res = h
        .submit_adhoc("ij,jk->iz", vec![a, b])
        .unwrap()
        .recv()
        .unwrap();
    assert!(res.is_err());
    service.shutdown();
}

#[test]
fn train_request_matches_direct_forward_backward() {
    use crate::autodiff::{CkptPolicy, MemoryMeter, PathAutodiff};
    use crate::exec::TrainWorkspace;
    use crate::planner::{plan_with, PlanOptions};

    let service = EvalService::start(ServiceConfig::default(), vec![]).unwrap();
    let h = service.handle();
    let mut rng = Rng::new(9);
    let expr = "ij,jk->ik";
    let a = Tensor::rand(&[3, 4], -1.0, 1.0, &mut rng);
    let b = Tensor::rand(&[4, 5], -1.0, 1.0, &mut rng);
    let dout = Tensor::rand(&[3, 5], -1.0, 1.0, &mut rng);

    let (y, grads) = h
        .train(
            expr,
            vec![a.clone(), b.clone()],
            dout.clone(),
            CkptPolicy::Sqrt,
        )
        .unwrap();

    // Direct training step with the same (training-cost) plan options.
    let spec = crate::einsum::parse(expr).unwrap();
    let sized =
        crate::einsum::SizedSpec::new(spec, vec![vec![3, 4], vec![4, 5]]).unwrap();
    let plan = plan_with(
        &sized,
        &PlanOptions {
            training: true,
            ..Default::default()
        },
    )
    .unwrap();
    let ad = PathAutodiff::new(&plan).unwrap();
    let mut ws = TrainWorkspace::new();
    let meter = MemoryMeter::new();
    let d = dout.clone();
    let (want_y, want_grads) = ad
        .forward_backward(&[&a, &b], |_| d.clone(), CkptPolicy::Sqrt, &mut ws, &meter)
        .unwrap();
    y.assert_close(&want_y, 1e-5);
    assert_eq!(grads.len(), 2);
    for (g, w) in grads.iter().zip(want_grads.iter()) {
        g.assert_close(w, 1e-5);
    }

    // Single-input expressions are rejected with an error, not a hang.
    let res = h.train(
        "ij->j",
        vec![Tensor::zeros(&[2, 3])],
        Tensor::zeros(&[3]),
        CkptPolicy::StoreAll,
    );
    assert!(res.is_err());
    service.shutdown();
}

#[test]
fn train_requests_coalesce_into_batches_with_exact_results() {
    use crate::autodiff::{CkptPolicy, MemoryMeter, PathAutodiff};
    use crate::exec::{compile_expr, TrainWorkspace};
    use crate::planner::PlanOptions;
    use std::time::Duration;

    // One worker, big enough steps that the router outruns the worker: a
    // steady same-expression training stream must coalesce (observable via
    // the batch-size histogram) with results identical to direct engine
    // execution.
    let expr = "bsx,tsx,tu,uv->bvx|x";
    let dims: Vec<Vec<usize>> = vec![vec![4, 8, 32], vec![16, 8, 3], vec![16, 32], vec![32, 8]];
    let opts = PlanOptions {
        training: true,
        ..Default::default()
    };
    let compiled = std::sync::Arc::new(compile_expr(expr, &dims, &opts).unwrap());
    let ad = PathAutodiff::from_compiled(std::sync::Arc::clone(&compiled));

    let service = EvalService::start(
        ServiceConfig {
            workers: 1,
            max_batch: 8,
            batch_timeout: Duration::from_millis(20),
            ..Default::default()
        },
        vec![],
    )
    .unwrap();
    let h = service.handle();
    let mut rng = Rng::new(21);
    let n_req = 16usize;
    let reqs: Vec<(Vec<Tensor>, Tensor)> = (0..n_req)
        .map(|_| {
            let ins: Vec<Tensor> =
                dims.iter().map(|d| Tensor::rand(d, -1.0, 1.0, &mut rng)).collect();
            let dout = Tensor::rand(compiled.out_shape(), -1.0, 1.0, &mut rng);
            (ins, dout)
        })
        .collect();
    let rxs: Vec<_> = reqs
        .iter()
        .map(|(ins, dout)| {
            h.submit_train(expr, ins.clone(), dout.clone(), CkptPolicy::Sqrt)
                .unwrap()
        })
        .collect();

    let mut ws = TrainWorkspace::new();
    let meter = MemoryMeter::new();
    for ((ins, dout), rx) in reqs.iter().zip(rxs) {
        let (y, grads) = rx.recv().unwrap().unwrap();
        let refs: Vec<&Tensor> = ins.iter().collect();
        let d = dout.clone();
        let (want_y, want_g) = ad
            .forward_backward(&refs, |_| d.clone(), CkptPolicy::Sqrt, &mut ws, &meter)
            .unwrap();
        y.assert_close(&want_y, 1e-6);
        assert_eq!(grads.len(), want_g.len());
        for (g, w) in grads.iter().zip(want_g.iter()) {
            g.assert_close(w, 1e-6);
        }
    }

    let m = h.metrics();
    assert_eq!(m.train_submitted, n_req as u64);
    assert_eq!(m.completed, n_req as u64);
    assert!(
        m.train_batches < n_req as u64,
        "{n_req} streamed train steps must coalesce into fewer batches (got {})",
        m.train_batches
    );
    assert!(m.mean_train_batch_size > 1.0);
    assert!(
        m.batch_sizes[2..].iter().any(|&c| c > 0),
        "batch-size histogram must record a coalesced (size >= 2) batch: {:?}",
        m.batch_sizes
    );
    service.shutdown();
}

#[test]
fn alternating_shapes_batch_independently_without_starvation() {
    // The pre-unification router flushed the whole partial batch whenever an
    // incompatible shape arrived, so an alternating-shape stream never
    // formed batches. Grouped queues must batch each shape independently.
    let mut rng = Rng::new(22);
    let (name, expr, factors, _spec) = cp_layer("cp", &mut rng);
    let service = EvalService::start(
        ServiceConfig {
            workers: 1,
            max_batch: 4,
            batch_timeout: std::time::Duration::from_millis(30),
            ..Default::default()
        },
        vec![(name, expr.clone(), factors.clone())],
    )
    .unwrap();
    let h = service.handle();
    let n_pairs = 8usize;
    let xs: Vec<Tensor> = (0..2 * n_pairs)
        .map(|i| {
            let hw = if i % 2 == 0 { 6 } else { 10 };
            Tensor::rand(&[1, 3, hw, hw], -1.0, 1.0, &mut rng)
        })
        .collect();
    let rxs: Vec<_> = xs.iter().map(|x| h.submit("cp", x.clone()).unwrap()).collect();
    for (x, rx) in xs.iter().zip(rxs) {
        let y = rx.recv().unwrap().unwrap();
        let mut inputs = vec![x];
        inputs.extend(factors.iter());
        let want = conv_einsum(&expr, &inputs).unwrap();
        y.assert_close(&want, 1e-4);
    }
    let m = h.metrics();
    assert_eq!(m.completed, 2 * n_pairs as u64);
    assert!(
        m.batches < 2 * n_pairs as u64,
        "interleaved shapes must still coalesce per shape group (got {} batches for {} requests)",
        m.batches,
        2 * n_pairs
    );
    assert!(m.mean_batch_size > 1.0);
    service.shutdown();
}

#[test]
fn metrics_expose_queue_latency_kind_counters_and_batch_histogram() {
    use crate::autodiff::CkptPolicy;

    let mut rng = Rng::new(23);
    let (name, expr, factors, _spec) = cp_layer("cp", &mut rng);
    let service =
        EvalService::start(ServiceConfig::default(), vec![(name, expr, factors)]).unwrap();
    let h = service.handle();
    for _ in 0..2 {
        let x = Tensor::rand(&[1, 3, 6, 6], -1.0, 1.0, &mut rng);
        h.eval("cp", x).unwrap();
    }
    let a = Tensor::rand(&[3, 4], -1.0, 1.0, &mut rng);
    let b = Tensor::rand(&[4, 5], -1.0, 1.0, &mut rng);
    h.submit_adhoc("ij,jk->ik", vec![a.clone(), b.clone()])
        .unwrap()
        .recv()
        .unwrap()
        .unwrap();
    let dout = Tensor::rand(&[3, 5], -1.0, 1.0, &mut rng);
    h.train("ij,jk->ik", vec![a, b], dout, CkptPolicy::StoreAll)
        .unwrap();

    let m = h.metrics();
    assert_eq!(m.infer_submitted, 3, "two layer evals + one ad-hoc");
    assert_eq!(m.train_submitted, 1);
    assert_eq!(m.submitted, 4);
    assert_eq!(m.completed, 4);
    // Every flushed batch (infer + train) lands in exactly one histogram
    // bucket; ad-hoc requests bypass batching.
    let histo_total: u64 = m.batch_sizes.iter().sum();
    assert_eq!(histo_total, m.batches + m.train_batches);
    assert!(m.batches >= 1 && m.train_batches >= 1);
    // Queue residency was recorded for every batched request.
    assert!(m.queue_p50_us >= 0.0 && m.queue_p99_us >= m.queue_p50_us);
    // The responder races the worker's in-flight decrement by design, so
    // at most the just-answered message may still read as in flight.
    assert!(m.inflight <= 1, "drained service shows no backlog");
    service.shutdown();
}

#[test]
fn mixed_shapes_do_not_cross_batch() {
    let mut rng = Rng::new(6);
    let (name, expr, factors, _spec) = cp_layer("cp", &mut rng);
    let service = EvalService::start(
        ServiceConfig {
            max_batch: 8,
            ..Default::default()
        },
        vec![(name, expr.clone(), factors.clone())],
    )
    .unwrap();
    let h = service.handle();
    let x1 = Tensor::rand(&[1, 3, 6, 6], -1.0, 1.0, &mut rng);
    let x2 = Tensor::rand(&[1, 3, 10, 10], -1.0, 1.0, &mut rng);
    let r1 = h.submit("cp", x1.clone()).unwrap();
    let r2 = h.submit("cp", x2.clone()).unwrap();
    let y1 = r1.recv().unwrap().unwrap();
    let y2 = r2.recv().unwrap().unwrap();
    assert_eq!(y1.shape(), &[1, 4, 6, 6]);
    assert_eq!(y2.shape(), &[1, 4, 10, 10]);
    let mut i1 = vec![&x1];
    i1.extend(factors.iter());
    y1.assert_close(&conv_einsum(&expr, &i1).unwrap(), 1e-4);
    service.shutdown();
}

#[test]
fn plan_cache_hit_on_repeated_shapes() {
    let mut rng = Rng::new(7);
    let (name, expr, factors, _spec) = cp_layer("cp", &mut rng);
    let service = EvalService::start(
        ServiceConfig {
            max_batch: 1, // force one batch per request → same plan key
            ..Default::default()
        },
        vec![(name, expr, factors)],
    )
    .unwrap();
    let h = service.handle();
    for _ in 0..5 {
        let x = Tensor::rand(&[1, 3, 6, 6], -1.0, 1.0, &mut rng);
        h.eval("cp", x).unwrap();
    }
    let m = h.metrics();
    assert_eq!(m.completed, 5);
    assert_eq!(m.plan_misses, 1, "plan should be cached after first use");
    service.shutdown();
}

#[test]
fn layer_plan_cache_evicts_lru_geometry() {
    // Fill a layer's per-geometry plan cache past LAYER_PLAN_CACHE_CAPACITY
    // with distinct spatial shapes: the first geometry must be evicted (its
    // re-submission re-plans), while the most recent stays cached — both
    // observable through the plan-miss metric.
    let mut rng = Rng::new(8);
    let (name, expr, factors, _spec) = cp_layer("cp", &mut rng);
    let service = EvalService::start(
        ServiceConfig {
            max_batch: 1, // one batch per request → one plan key per shape
            ..Default::default()
        },
        vec![(name, expr, factors)],
    )
    .unwrap();
    let h = service.handle();
    let eval_spatial = |hw: usize, rng: &mut Rng| {
        let x = Tensor::rand(&[1, 3, hw, hw], -1.0, 1.0, rng);
        h.eval("cp", x).unwrap();
    };
    // Geometry A, then `capacity` distinct fillers (A becomes LRU and is
    // evicted when the last filler lands).
    eval_spatial(5, &mut rng);
    for hw in 6..6 + LAYER_PLAN_CACHE_CAPACITY {
        eval_spatial(hw, &mut rng);
    }
    let misses_after_fill = h.metrics().plan_misses;
    assert_eq!(misses_after_fill as usize, LAYER_PLAN_CACHE_CAPACITY + 1);
    // The newest filler is still cached: no new miss.
    eval_spatial(5 + LAYER_PLAN_CACHE_CAPACITY, &mut rng);
    assert_eq!(h.metrics().plan_misses, misses_after_fill);
    // Geometry A was evicted: re-submission re-plans.
    eval_spatial(5, &mut rng);
    assert_eq!(h.metrics().plan_misses, misses_after_fill + 1);
    service.shutdown();
}

#[test]
fn shutdown_answers_every_pending_request_and_rejects_new_ones() {
    let mut rng = Rng::new(31);
    let (name, expr, factors, _spec) = cp_layer("cp", &mut rng);
    let service = EvalService::start(
        ServiceConfig {
            workers: 1,
            max_batch: 8,
            batch_timeout: std::time::Duration::from_millis(50),
            ..Default::default()
        },
        vec![(name, expr, factors)],
    )
    .unwrap();
    let h = service.handle();
    let rxs: Vec<_> = (0..12)
        .map(|_| {
            let x = Tensor::rand(&[1, 3, 6, 6], -1.0, 1.0, &mut rng);
            h.submit("cp", x).unwrap()
        })
        .collect();
    service.shutdown();
    // The liveness contract: every receiver yields exactly one terminal
    // outcome across shutdown — flushed-and-served or failed `Shutdown` —
    // and none dangles.
    let mut ok = 0u64;
    let mut errs = 0u64;
    for rx in rxs {
        match rx.recv_timeout(std::time::Duration::from_secs(10)) {
            Ok(Ok(_)) => ok += 1,
            Ok(Err(e)) => {
                assert_eq!(e, ServiceError::Shutdown, "drain failures are structured");
                errs += 1;
            }
            Err(_) => panic!("request left dangling across shutdown"),
        }
    }
    assert_eq!(ok + errs, 12);
    let m = h.metrics();
    assert_eq!(m.completed + m.errors, m.submitted, "unaccounted terminal outcomes");
    // Post-shutdown submissions are rejected outright, not enqueued.
    let post = h.submit("cp", Tensor::zeros(&[1, 3, 6, 6]));
    assert!(matches!(post, Err(ServiceError::Shutdown)));
}

/// Fault-injected failure paths (cargo feature `fault-injection`; see
/// `tests/chaos.rs` for the randomized schedules). These install plans in
/// the process-global fault registry, so they serialize on
/// [`crate::faults::test_serial`] — and the CI chaos job runs the whole
/// binary single-threaded so unrelated tests never trip an installed rule.
#[cfg(feature = "fault-injection")]
mod faulted {
    use super::*;
    use crate::faults::{self, FaultAction, FaultPlan, Schedule};
    use std::time::Duration;

    #[test]
    fn worker_panic_recovers_capacity_with_bounded_retry() {
        let _g = faults::test_serial();
        faults::install(FaultPlan::new(11).rule(
            "worker.eval.pre",
            Schedule::Nth(0),
            FaultAction::Panic,
        ));
        let mut rng = Rng::new(41);
        let (name, expr, factors, _spec) = cp_layer("cp", &mut rng);
        let service = EvalService::start(
            ServiceConfig {
                workers: 2,
                max_retries: 2,
                ..Default::default()
            },
            vec![(name, expr.clone(), factors.clone())],
        )
        .unwrap();
        let h = service.handle();
        // The first dispatch panics its worker; the request is re-queued
        // and the second attempt answers it.
        let x = Tensor::rand(&[1, 3, 6, 6], -1.0, 1.0, &mut rng);
        let y = h.eval("cp", x.clone()).unwrap();
        let mut inputs = vec![&x];
        inputs.extend(factors.iter());
        y.assert_close(&conv_einsum(&expr, &inputs).unwrap(), 1e-4);
        let m = h.metrics();
        assert_eq!(m.worker_restarts, 1, "the panicked incarnation restarted");
        assert_eq!(m.retries, 1, "the in-flight request was re-queued once");
        // No silent capacity loss: the service keeps answering at full
        // strength after the crash.
        for _ in 0..8 {
            let x = Tensor::rand(&[1, 3, 6, 6], -1.0, 1.0, &mut rng);
            h.eval("cp", x).unwrap();
        }
        assert_eq!(h.metrics().completed, 9);
        faults::clear();
        service.shutdown();
    }

    #[test]
    fn injected_error_routes_structured_engine_err() {
        let _g = faults::test_serial();
        faults::install(FaultPlan::new(12).rule(
            "worker.eval.pre",
            Schedule::Nth(0),
            FaultAction::Error,
        ));
        let mut rng = Rng::new(42);
        let (name, expr, factors, _spec) = cp_layer("cp", &mut rng);
        let service =
            EvalService::start(ServiceConfig::default(), vec![(name, expr, factors)]).unwrap();
        let h = service.handle();
        let err = h.eval("cp", Tensor::zeros(&[1, 3, 6, 6])).unwrap_err();
        match err {
            ServiceError::Engine(m) => assert!(m.contains("worker.eval.pre"), "wrong site: {m}"),
            other => panic!("expected an injected engine error, got {other}"),
        }
        faults::clear();
        service.shutdown();
    }

    #[test]
    fn deadline_expiry_is_shed_and_counted() {
        let _g = faults::test_serial();
        // Every batch stalls 50ms at the gate; a 10ms deadline therefore
        // expires deterministically before execution.
        faults::install(FaultPlan::new(13).rule(
            "worker.eval.pre",
            Schedule::Every(1),
            FaultAction::Delay(Duration::from_millis(50)),
        ));
        let mut rng = Rng::new(43);
        let (name, expr, factors, _spec) = cp_layer("cp", &mut rng);
        let service = EvalService::start(
            ServiceConfig {
                workers: 1,
                request_deadline: Some(Duration::from_millis(10)),
                ..Default::default()
            },
            vec![(name, expr, factors)],
        )
        .unwrap();
        let h = service.handle();
        let x = Tensor::rand(&[1, 3, 6, 6], -1.0, 1.0, &mut rng);
        let err = h.eval("cp", x).unwrap_err();
        assert_eq!(err, ServiceError::DeadlineExceeded);
        assert!(h.metrics().deadline_expired >= 1);
        faults::clear();
        service.shutdown();
    }

    #[test]
    fn overload_rejects_with_budget_and_gauges() {
        let _g = faults::test_serial();
        // Pin the lone worker on a slow ad-hoc request so utilization is 1
        // and subsequent evals queue instead of flushing immediately.
        faults::install(FaultPlan::new(14).rule(
            "worker.adhoc.pre",
            Schedule::Nth(0),
            FaultAction::Delay(Duration::from_millis(200)),
        ));
        let mut rng = Rng::new(44);
        let (name, expr, factors, _spec) = cp_layer("cp", &mut rng);
        let service = EvalService::start(
            ServiceConfig {
                workers: 1,
                max_batch: 8,
                batch_timeout: Duration::from_millis(300),
                max_pending: 2,
                backend: crate::exec::Backend::Scalar,
                ..Default::default()
            },
            vec![(name, expr, factors)],
        )
        .unwrap();
        let h = service.handle();
        let a = Tensor::rand(&[3, 4], -1.0, 1.0, &mut rng);
        let b = Tensor::rand(&[4, 5], -1.0, 1.0, &mut rng);
        let busy = h.submit_adhoc("ij,jk->ik", vec![a, b]).unwrap();
        std::thread::sleep(Duration::from_millis(30));
        let rx1 = h.submit("cp", Tensor::rand(&[1, 3, 6, 6], -1.0, 1.0, &mut rng)).unwrap();
        let rx2 = h.submit("cp", Tensor::rand(&[1, 3, 6, 6], -1.0, 1.0, &mut rng)).unwrap();
        // The pending budget (2 requests) is exhausted: the third request
        // is rejected — either at the submit fast path (gauge) or by the
        // router's authoritative budget — never silently queued.
        let third = h.submit("cp", Tensor::rand(&[1, 3, 6, 6], -1.0, 1.0, &mut rng));
        match third {
            Err(ServiceError::Overloaded) => {}
            Ok(rx) => {
                let r = rx
                    .recv_timeout(Duration::from_secs(5))
                    .expect("rejected request still gets a terminal answer");
                let rejected = matches!(r, Err(ServiceError::Overloaded));
                assert!(rejected, "third request must be rejected by admission control");
            }
            Err(e) => panic!("unexpected submit error: {e}"),
        }
        // While the two admitted evals wait, the pending gauges are live.
        let t0 = std::time::Instant::now();
        let mut saw_bytes = false;
        while t0.elapsed() < Duration::from_secs(2) {
            if h.metrics().pending_bytes > 0 {
                saw_bytes = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(saw_bytes, "pending_bytes gauge must reflect queued payloads");
        assert!(h.metrics().overload_rejected >= 1);
        // Admitted work is unaffected by the rejection.
        busy.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        rx1.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        rx2.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        faults::clear();
        service.shutdown();
    }
}
