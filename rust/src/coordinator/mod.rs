//! The L3 coordinator: a multi-threaded evaluation service for tensorial
//! layers — request router, dynamic batcher, worker pool, plan cache,
//! metrics and backpressure (vLLM-router-style, adapted to layer-evaluation
//! traffic).
//!
//! Clients register tensorial layers once (expression + factor weights) and
//! submit single-example evaluations; the router coalesces same-layer
//! requests into one batched conv_einsum execution (the batch mode `b` of
//! the layer string) up to `max_batch` or `batch_timeout`, whichever first.
//! Workers execute along the planner's FLOPs-optimal path on the native
//! engine, or via a PJRT artifact when one is registered for the layer.
//!
//! Layer evaluation is **compile-once, run-many**: every `(layer, batch,
//! spatial)` key is planned and lowered to a [`CompiledPlan`] once and held
//! in a per-layer LRU cache bounded at [`LAYER_PLAN_CACHE_CAPACITY`]
//! geometries (with [`ServiceConfig::backend`] hoisted onto the cached
//! entry, so batch-level and step-level pool arbitration always see one
//! consistent backend per entry), and ad-hoc expressions share a
//! service-wide [`PlanCache`] keyed by `(expr, dims, backend, strategy)`.
//! Each worker thread owns one reusable [`TrainWorkspace`] that survives
//! across requests (the worker threads — like the executor's pool workers
//! — are persistent), so steady-state execution allocates only the output
//! tensors.
//!
//! Besides inference, the service accepts **training-step requests**
//! ([`ServiceHandle::submit_train`]): a forward-with-tape + backward of an
//! ad-hoc expression under a checkpoint policy, returning the output and
//! ∂L/∂input for every input. Training requests run through the same
//! compile-once cache (with the training cost model) and share the same
//! per-worker arena as inference — the tape lives in the worker's
//! [`TrainWorkspace`] for the duration of the request, so a steady stream
//! of train steps allocates only the returned tensors.
//!
//! Workers and the executor's intra-step parallelism share one pool: each
//! compiled plan carries [`ServiceConfig::backend`], and under the default
//! [`Backend::Parallel`]` { threads: 0 }` (= the global persistent
//! [`crate::parallel::Pool`]) the pool's busy-flag arbitration means that
//! when several workers execute batches concurrently, exactly one fans out
//! across the pool while the rest run their steps serially on their own
//! worker thread — batch-level and step-level parallelism compose without
//! oversubscribing the machine. Explicit `Backend::Parallel { threads: k }`
//! counts resolve to the persistent per-size pools
//! ([`crate::parallel::Pool::sized`]), which carry the same busy-flag
//! arbitration — but their workers add to the global pool's, so prefer the
//! default backend outside benchmarking.

mod metrics;

pub use metrics::{MetricsSnapshot, ServiceMetrics};

use crate::autodiff::{CkptPolicy, MemoryMeter, PathAutodiff};
use crate::einsum::{parse, SizedSpec};
use crate::exec::{Backend, CompiledPlan, PlanCache, TrainWorkspace};
use crate::planner::{plan_with, PlanOptions, Strategy};
use crate::tensor::Tensor;
use crate::util::lru::LruCache;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Worker threads executing batches.
    pub workers: usize,
    /// Maximum requests coalesced into one batch.
    pub max_batch: usize,
    /// Maximum time the batcher holds a partial batch.
    pub batch_timeout: Duration,
    /// Router inbox capacity (backpressure: submit blocks when full).
    pub queue_capacity: usize,
    /// Path strategy for plans.
    pub strategy: Strategy,
    /// Execution backend recorded on every plan (see module docs on pool
    /// sharing between workers and intra-step parallelism).
    pub backend: Backend,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            // Sized like the worker pool: available parallelism with the
            // CONV_EINSUM_THREADS override, instead of a fixed constant.
            workers: crate::parallel::default_threads(),
            max_batch: 8,
            batch_timeout: Duration::from_millis(2),
            queue_capacity: 256,
            strategy: Strategy::Optimal,
            backend: Backend::default(),
        }
    }
}

/// Bound on each layer's per-geometry compiled-plan cache: enough for a
/// realistic batch/spatial mix per layer while keeping client-controlled
/// geometry churn from growing resident memory without limit (the shared
/// ad-hoc [`PlanCache`] is bounded separately).
pub const LAYER_PLAN_CACHE_CAPACITY: usize = 16;

/// A registered tensorial layer: expression + weights.
struct LayerEntry {
    expr: String,
    factors: Vec<Tensor>,
    /// Per-(batch, height, width) compiled-plan cache, LRU-bounded at
    /// [`LAYER_PLAN_CACHE_CAPACITY`]; each entry carries its hoisted
    /// `ExecOptions`, so every replay uses one consistent backend.
    plans: LruCache<(usize, usize, usize), Arc<CompiledPlan>>,
}

/// One in-flight request.
struct Pending {
    x: Tensor,
    respond: SyncSender<Result<Tensor>>,
    enqueued: Instant,
}

enum Msg {
    Eval {
        layer: String,
        pending: Pending,
    },
    AdHoc {
        expr: String,
        tensors: Vec<Tensor>,
        respond: SyncSender<Result<Tensor>>,
    },
    Train {
        expr: String,
        tensors: Vec<Tensor>,
        dout: Tensor,
        policy: CkptPolicy,
        respond: SyncSender<Result<(Tensor, Vec<Tensor>)>>,
    },
    Shutdown,
}

/// Handle for submitting work; cheap to clone.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: SyncSender<Msg>,
    metrics: Arc<ServiceMetrics>,
}

impl ServiceHandle {
    /// Evaluate a registered layer on a single example `[1, S, H', W']`
    /// (or `[S, H', W']`, auto-expanded). Blocks if the router is saturated
    /// (backpressure). Returns a receiver for the result.
    pub fn submit(&self, layer: &str, x: Tensor) -> Result<Receiver<Result<Tensor>>> {
        let x = if x.rank() == 3 {
            let mut shape = vec![1];
            shape.extend_from_slice(x.shape());
            let s2 = shape.clone();
            x.reshape(&s2)
        } else {
            x
        };
        let (rtx, rrx) = sync_channel(1);
        self.metrics.note_submit();
        self.tx
            .send(Msg::Eval {
                layer: layer.to_string(),
                pending: Pending {
                    x,
                    respond: rtx,
                    enqueued: Instant::now(),
                },
            })
            .map_err(|_| anyhow!("service stopped"))?;
        Ok(rrx)
    }

    /// Evaluate an ad-hoc conv_einsum expression (unbatched path).
    pub fn submit_adhoc(
        &self,
        expr: &str,
        tensors: Vec<Tensor>,
    ) -> Result<Receiver<Result<Tensor>>> {
        let (rtx, rrx) = sync_channel(1);
        self.metrics.note_submit();
        self.tx
            .send(Msg::AdHoc {
                expr: expr.to_string(),
                tensors,
                respond: rtx,
            })
            .map_err(|_| anyhow!("service stopped"))?;
        Ok(rrx)
    }

    /// Evaluate an ad-hoc **training step**: forward-with-tape + backward
    /// of `expr` at the given inputs under `policy`, seeded with the output
    /// cotangent `dout`. Returns the forward output and ∂L/∂input for
    /// every input. Runs on a worker's training workspace — the same arena
    /// its inference requests use.
    pub fn submit_train(
        &self,
        expr: &str,
        tensors: Vec<Tensor>,
        dout: Tensor,
        policy: CkptPolicy,
    ) -> Result<Receiver<Result<(Tensor, Vec<Tensor>)>>> {
        let (rtx, rrx) = sync_channel(1);
        self.metrics.note_submit();
        self.tx
            .send(Msg::Train {
                expr: expr.to_string(),
                tensors,
                dout,
                policy,
                respond: rtx,
            })
            .map_err(|_| anyhow!("service stopped"))?;
        Ok(rrx)
    }

    /// Convenience: submit a training step and wait.
    pub fn train(
        &self,
        expr: &str,
        tensors: Vec<Tensor>,
        dout: Tensor,
        policy: CkptPolicy,
    ) -> Result<(Tensor, Vec<Tensor>)> {
        self.submit_train(expr, tensors, dout, policy)?
            .recv()
            .map_err(|_| anyhow!("service dropped response"))?
    }

    /// Convenience: submit and wait.
    pub fn eval(&self, layer: &str, x: Tensor) -> Result<Tensor> {
        self.submit(layer, x)?
            .recv()
            .map_err(|_| anyhow!("service dropped response"))?
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }
}

/// The evaluation service: router thread + worker pool.
pub struct EvalService {
    handle: ServiceHandle,
    router: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
}

/// A batch dispatched to workers.
struct WorkItem {
    layer: String,
    plan: Arc<CompiledPlan>,
    factors: Arc<Vec<Tensor>>,
    requests: Vec<Pending>,
}

enum WorkMsg {
    Batch(WorkItem),
    AdHoc {
        expr: String,
        tensors: Vec<Tensor>,
        respond: SyncSender<Result<Tensor>>,
        strategy: Strategy,
        backend: Backend,
    },
    Train {
        expr: String,
        tensors: Vec<Tensor>,
        dout: Tensor,
        policy: CkptPolicy,
        respond: SyncSender<Result<(Tensor, Vec<Tensor>)>>,
        strategy: Strategy,
        backend: Backend,
    },
    Stop,
}

impl EvalService {
    /// Start the service with the given registered layers.
    pub fn start(
        config: ServiceConfig,
        layers: Vec<(String, String, Vec<Tensor>)>, // (name, expr, factors)
    ) -> Result<EvalService> {
        let metrics = Arc::new(ServiceMetrics::default());
        let (tx, rx) = sync_channel::<Msg>(config.queue_capacity);
        let (wtx, wrx) = sync_channel::<WorkMsg>(config.workers * 2);
        let wrx = Arc::new(Mutex::new(wrx));
        let stop = Arc::new(AtomicBool::new(false));
        // Compiled-plan cache shared by all workers (ad-hoc expressions).
        let cache = Arc::new(PlanCache::new());

        let mut registry: HashMap<String, LayerEntry> = HashMap::new();
        for (name, expr, factors) in layers {
            parse(&expr).map_err(|e| anyhow!("layer '{name}': {e}"))?;
            registry.insert(
                name,
                LayerEntry {
                    expr,
                    factors,
                    plans: LruCache::new(LAYER_PLAN_CACHE_CAPACITY),
                },
            );
        }

        // Worker pool.
        let mut workers = Vec::new();
        for wid in 0..config.workers.max(1) {
            let wrx = Arc::clone(&wrx);
            let metrics = Arc::clone(&metrics);
            let cache = Arc::clone(&cache);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("conv-einsum-worker-{wid}"))
                    .spawn(move || worker_loop(wrx, metrics, cache))
                    .expect("spawn worker"),
            );
        }

        // Router thread.
        let router_metrics = Arc::clone(&metrics);
        let cfg = config.clone();
        let router = std::thread::Builder::new()
            .name("conv-einsum-router".to_string())
            .spawn(move || router_loop(rx, wtx, registry, cfg, router_metrics))
            .expect("spawn router");

        Ok(EvalService {
            handle: ServiceHandle { tx, metrics },
            router: Some(router),
            workers,
            stop,
        })
    }

    pub fn handle(&self) -> ServiceHandle {
        self.handle.clone()
    }

    /// Graceful shutdown: drain queues, stop threads.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.handle.tx.send(Msg::Shutdown);
        if let Some(r) = self.router.take() {
            let _ = r.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn router_loop(
    rx: Receiver<Msg>,
    wtx: SyncSender<WorkMsg>,
    mut registry: HashMap<String, LayerEntry>,
    config: ServiceConfig,
    metrics: Arc<ServiceMetrics>,
) {
    // Per-layer pending queues awaiting batch formation.
    let mut queues: HashMap<String, Vec<Pending>> = HashMap::new();
    let mut deadline: Option<Instant> = None;

    let flush = |registry: &mut HashMap<String, LayerEntry>,
                 layer_name: &str,
                 batch: Vec<Pending>,
                 wtx: &SyncSender<WorkMsg>,
                 metrics: &ServiceMetrics,
                 strategy: Strategy,
                 backend: Backend| {
        if batch.is_empty() {
            return;
        }
        let entry = registry.get_mut(layer_name).expect("layer exists");
        // All requests in a bucket share the single-example shape; derive
        // the batched plan for the combined batch size.
        let bshape = batch[0].x.shape().to_vec();
        let total_b: usize = batch.iter().map(|p| p.x.shape()[0]).sum();
        let key = (total_b, bshape[bshape.len() - 2], bshape[bshape.len() - 1]);
        let cached = entry.plans.get(&key).cloned();
        let plan = match cached {
            Some(p) => p,
            None => {
                let planned = plan_layer(entry, total_b, &bshape, strategy, backend);
                match planned {
                    Ok(p) => {
                        let p = Arc::new(p);
                        // LRU-bounded: geometry churn past the capacity
                        // evicts the least-recently-served shape.
                        entry.plans.insert(key, Arc::clone(&p));
                        metrics.note_plan_miss();
                        p
                    }
                    Err(e) => {
                        let msg = format!("planning failed: {e}");
                        for p in batch {
                            let _ = p.respond.send(Err(anyhow!("{msg}")));
                        }
                        return;
                    }
                }
            }
        };
        metrics.note_batch(batch.len());
        let item = WorkItem {
            layer: layer_name.to_string(),
            plan,
            factors: Arc::new(entry.factors.clone()),
            requests: batch,
        };
        let _ = wtx.send(WorkMsg::Batch(item));
    };

    loop {
        let timeout = deadline
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(Msg::Eval { layer, pending }) => {
                if !registry.contains_key(&layer) {
                    let _ = pending.respond.send(Err(anyhow!("unknown layer '{layer}'")));
                    continue;
                }
                // Mixed shapes cannot batch together: flush incompatible.
                let q = queues.entry(layer.clone()).or_default();
                if let Some(first) = q.first() {
                    if first.x.shape() != pending.x.shape() {
                        let old = std::mem::take(q);
                        flush(
                            &mut registry,
                            &layer,
                            old,
                            &wtx,
                            &metrics,
                            config.strategy,
                            config.backend,
                        );
                    }
                }
                let q = queues.entry(layer.clone()).or_default();
                q.push(pending);
                if q.len() >= config.max_batch {
                    let old = std::mem::take(q);
                    flush(
                        &mut registry,
                        &layer,
                        old,
                        &wtx,
                        &metrics,
                        config.strategy,
                        config.backend,
                    );
                } else if deadline.is_none() {
                    deadline = Some(Instant::now() + config.batch_timeout);
                }
            }
            Ok(Msg::AdHoc {
                expr,
                tensors,
                respond,
            }) => {
                let _ = wtx.send(WorkMsg::AdHoc {
                    expr,
                    tensors,
                    respond,
                    strategy: config.strategy,
                    backend: config.backend,
                });
            }
            Ok(Msg::Train {
                expr,
                tensors,
                dout,
                policy,
                respond,
            }) => {
                let _ = wtx.send(WorkMsg::Train {
                    expr,
                    tensors,
                    dout,
                    policy,
                    respond,
                    strategy: config.strategy,
                    backend: config.backend,
                });
            }
            Ok(Msg::Shutdown) => break,
            Err(RecvTimeoutError::Timeout) => {
                // Flush everything pending.
                for (layer, q) in queues.iter_mut() {
                    let old = std::mem::take(q);
                    flush(
                        &mut registry,
                        layer,
                        old,
                        &wtx,
                        &metrics,
                        config.strategy,
                        config.backend,
                    );
                }
                deadline = None;
            }
            Err(RecvTimeoutError::Disconnected) => break,
        }
        metrics.set_queue_depth(queues.values().map(Vec::len).sum());
    }
    // Drain on shutdown.
    for (layer, q) in queues.iter_mut() {
        let old = std::mem::take(q);
        flush(
            &mut registry,
            layer,
            old,
            &wtx,
            &metrics,
            config.strategy,
            config.backend,
        );
    }
    for _ in 0..8 {
        let _ = wtx.send(WorkMsg::Stop);
    }
}

fn plan_layer(
    entry: &LayerEntry,
    batch: usize,
    single_shape: &[usize],
    strategy: Strategy,
    backend: Backend,
) -> Result<CompiledPlan, String> {
    let spec = parse(&entry.expr).map_err(|e| e.to_string())?;
    let mut x_dims = single_shape.to_vec();
    x_dims[0] = batch;
    let mut dims = vec![x_dims];
    dims.extend(entry.factors.iter().map(|f| f.shape().to_vec()));
    let sized = SizedSpec::new(spec, dims)?;
    let plan = plan_with(
        &sized,
        &PlanOptions {
            strategy,
            backend,
            ..Default::default()
        },
    )?;
    CompiledPlan::compile_arc(Arc::new(plan)).map_err(|e| e.to_string())
}

/// Evaluate an ad-hoc expression through the shared compile-once cache
/// (single-input expressions have no pairwise plan and run directly). The
/// expression is parsed exactly once per request — the parsed spec is
/// handed to the cache so a miss does not re-parse.
fn eval_adhoc(
    cache: &PlanCache,
    ws: &mut TrainWorkspace,
    expr: &str,
    tensors: &[Tensor],
    strategy: Strategy,
    backend: Backend,
) -> Result<Tensor> {
    let refs: Vec<&Tensor> = tensors.iter().collect();
    let opts = PlanOptions {
        strategy,
        backend,
        ..Default::default()
    };
    let spec = parse(expr).map_err(|e| anyhow!("{e}"))?;
    let dims: Vec<Vec<usize>> = refs.iter().map(|t| t.shape().to_vec()).collect();
    if spec.n_inputs() < 2 {
        let sized = SizedSpec::new(spec, dims).map_err(|e| anyhow!("{e}"))?;
        return Ok(crate::exec::single_input_eval(&sized, refs[0]));
    }
    let compiled = cache.get_or_compile_parsed(expr, &spec, &dims, &opts)?;
    compiled.run(&refs, ws.base_mut())
}

/// Run an ad-hoc training step on the worker's training workspace: plan +
/// compile (training cost model) through the shared cache, then
/// forward-with-tape + backward under the requested checkpoint policy.
#[allow(clippy::too_many_arguments)]
fn eval_train(
    cache: &PlanCache,
    ws: &mut TrainWorkspace,
    expr: &str,
    tensors: &[Tensor],
    dout: &Tensor,
    policy: CkptPolicy,
    strategy: Strategy,
    backend: Backend,
) -> Result<(Tensor, Vec<Tensor>)> {
    let spec = parse(expr).map_err(|e| anyhow!("{e}"))?;
    if spec.n_inputs() < 2 {
        return Err(anyhow!(
            "training steps need at least 2 inputs (got {})",
            spec.n_inputs()
        ));
    }
    let refs: Vec<&Tensor> = tensors.iter().collect();
    let dims: Vec<Vec<usize>> = refs.iter().map(|t| t.shape().to_vec()).collect();
    let opts = PlanOptions {
        strategy,
        backend,
        training: true,
        ..Default::default()
    };
    let compiled = cache.get_or_compile_parsed(expr, &spec, &dims, &opts)?;
    let ad = PathAutodiff::from_compiled(compiled);
    let meter = MemoryMeter::new();
    let tape = ad.forward_with_tape(&refs, policy, ws, &meter)?;
    let grads = ad.backward(&tape, dout, ws, &meter)?;
    Ok((tape.output, grads))
}

fn worker_loop(
    wrx: Arc<Mutex<Receiver<WorkMsg>>>,
    metrics: Arc<ServiceMetrics>,
    cache: Arc<PlanCache>,
) {
    // One reusable training workspace per worker thread: compiled plans of
    // any shape run against it (training requests tape into the same arena
    // inference uses), and it only ever grows.
    let mut ws = TrainWorkspace::new();
    loop {
        let msg = {
            let rx = wrx.lock().unwrap();
            rx.recv()
        };
        match msg {
            Ok(WorkMsg::Batch(item)) => {
                let t0 = Instant::now();
                // Concatenate the batch along axis 0.
                let bsum: usize = item.requests.iter().map(|p| p.x.shape()[0]).sum();
                let mut shape = item.requests[0].x.shape().to_vec();
                shape[0] = bsum;
                let mut data = Vec::with_capacity(shape.iter().product());
                for p in &item.requests {
                    data.extend_from_slice(p.x.data());
                }
                let x = Tensor::from_vec(&shape, data);
                let mut inputs: Vec<&Tensor> = vec![&x];
                inputs.extend(item.factors.iter());
                let result = item.plan.run(&inputs, ws.base_mut());
                match result {
                    Ok(y) => {
                        // Split along axis 0 back to requesters.
                        let mut offset = 0usize;
                        for p in item.requests {
                            let nb = p.x.shape()[0];
                            let part = y.slice_axis(0, offset, offset + nb);
                            offset += nb;
                            metrics.note_done(p.enqueued.elapsed());
                            let _ = p.respond.send(Ok(part));
                        }
                    }
                    Err(e) => {
                        let msg = format!("layer '{}' failed: {e}", item.layer);
                        for p in item.requests {
                            metrics.note_error();
                            let _ = p.respond.send(Err(anyhow!("{msg}")));
                        }
                    }
                }
                metrics.note_exec_time(t0.elapsed());
            }
            Ok(WorkMsg::AdHoc {
                expr,
                tensors,
                respond,
                strategy,
                backend,
            }) => {
                let t0 = Instant::now();
                let result = eval_adhoc(&cache, &mut ws, &expr, &tensors, strategy, backend);
                match &result {
                    Ok(_) => metrics.note_done(t0.elapsed()),
                    Err(_) => metrics.note_error(),
                }
                let _ = respond.send(result);
                metrics.note_exec_time(t0.elapsed());
            }
            Ok(WorkMsg::Train {
                expr,
                tensors,
                dout,
                policy,
                respond,
                strategy,
                backend,
            }) => {
                let t0 = Instant::now();
                let result = eval_train(
                    &cache, &mut ws, &expr, &tensors, &dout, policy, strategy, backend,
                );
                match &result {
                    Ok(_) => metrics.note_done(t0.elapsed()),
                    Err(_) => metrics.note_error(),
                }
                let _ = respond.send(result);
                metrics.note_exec_time(t0.elapsed());
            }
            Ok(WorkMsg::Stop) | Err(_) => break,
        }
    }
}

#[cfg(test)]
mod tests;
