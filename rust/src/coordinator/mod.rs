//! The L3 coordinator: a multi-threaded evaluation service for tensorial
//! layers — request router, **unified batching scheduler**, worker pool,
//! plan cache, metrics and backpressure (vLLM-router-style, adapted to
//! layer-evaluation traffic).
//!
//! Clients register tensorial layers once (expression + factor weights) and
//! submit single-example evaluations; ad-hoc expressions and ad-hoc
//! **training steps** ride the same pipeline. One scheduler (the `batcher`
//! submodule) owns queueing, shape-compatibility grouping, deadline
//! flushing and plan lookup for *both* request kinds: inference requests of
//! one layer and shape coalesce along the batch mode `b` into a single
//! batched replay, and same-expression training requests coalesce the same
//! way — a flushed training batch replays through one cached
//! [`crate::exec::TrainLayout`] against the worker's [`TrainWorkspace`],
//! one fused [`CompiledPlan::train_step`] per request in submission order
//! (per-request error isolation), with input gradients split along the
//! batch mode and weight gradients accumulated per segment — so batched
//! and individually submitted training steps are **bit-identical**. The
//! gradient contract, and the engine-level batch entry point library
//! callers use directly, is
//! [`crate::autodiff::PathAutodiff::train_step_batch_into`].
//!
//! Batch formation is **pool-aware and adaptive**
//! ([`AdaptiveController`]): the router sizes batches and flush deadlines
//! from live utilization — its own workers' in-flight count and the
//! executor pool's activity ([`crate::parallel::Pool::utilization`]). An
//! idle service flushes lone requests immediately (no added latency); a
//! saturated one holds partial batches up to
//! [`ServiceConfig::batch_timeout`] and coalesces up to
//! [`ServiceConfig::max_batch`] — the config bounds the controller instead
//! of fixing its operating point. Pending queues are keyed per
//! `(layer, shape)` / `(expression, shapes, policy)` group, so interleaved
//! traffic of incompatible shapes batches independently instead of
//! flushing each other out.
//!
//! Layer evaluation is **compile-once, run-many**: every `(layer, batch,
//! spatial)` key is planned and lowered to a [`CompiledPlan`] once and held
//! in a per-layer LRU cache bounded at [`LAYER_PLAN_CACHE_CAPACITY`]
//! geometries (with [`ServiceConfig::backend`] hoisted onto the cached
//! entry), and ad-hoc expressions — inference and training alike — share a
//! service-wide [`PlanCache`] keyed by `(expr, dims, backend, strategy,
//! training, conv kinds)`. Each worker thread owns one reusable
//! [`TrainWorkspace`] plus a reusable batch-staging tensor (inference
//! batches concatenate into it via [`crate::tensor::concat_into`]), so
//! steady-state execution allocates only the returned tensors.
//!
//! Workers and the executor's intra-step parallelism share one pool: each
//! compiled plan carries [`ServiceConfig::backend`], and under the default
//! [`Backend::Parallel`]` { threads: 0 }` (= the global persistent
//! [`crate::parallel::Pool`]) the pool's busy-flag arbitration means that
//! when several workers execute batches concurrently, exactly one fans out
//! across the pool while the rest run their steps serially on their own
//! worker thread — batch-level and step-level parallelism compose without
//! oversubscribing the machine. Explicit `Backend::Parallel { threads: k }`
//! counts resolve to the persistent per-size pools
//! ([`crate::parallel::Pool::sized`]), which carry the same busy-flag
//! arbitration — but their workers add to the global pool's, so prefer the
//! default backend outside benchmarking.
//!
//! Every plan the service caches is **statically verified** before it is
//! shared: [`PlanCache`] runs the [`crate::verify`] plan verifier
//! ([`CompiledPlan::verify`]) on insertion (and debug/test builds verify
//! at compile time), so a schedule with an unsound workspace layout,
//! out-of-bounds gather table or stale kernel accumulation-order version
//! never reaches a worker.

mod batcher;
mod metrics;

pub use batcher::{AdaptiveController, LAYER_PLAN_CACHE_CAPACITY};
pub use metrics::{MetricsSnapshot, ServiceMetrics, BATCH_SIZE_BUCKETS};

use crate::autodiff::CkptPolicy;
use crate::einsum::{parse, SizedSpec};
use crate::exec::{Backend, CompiledPlan, PlanCache, TrainWorkspace};
use crate::parallel::Pool;
use crate::planner::Strategy;
use crate::tensor::{concat_into, Tensor};
use anyhow::{anyhow, Result};
use batcher::{dispatch, Batcher, LayerEntry, Pending, TrainPending};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Service configuration. `max_batch` and `batch_timeout` bound the
/// adaptive batching controller ([`AdaptiveController`]); the actual batch
/// size and flush deadline at any moment are derived from live pool
/// utilization within those bounds.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Worker threads executing batches.
    pub workers: usize,
    /// Upper bound on requests coalesced into one batch.
    pub max_batch: usize,
    /// Upper bound on how long the scheduler holds a partial batch.
    pub batch_timeout: Duration,
    /// Router inbox capacity (backpressure: submit blocks when full).
    pub queue_capacity: usize,
    /// Path strategy for plans.
    pub strategy: Strategy,
    /// Execution backend recorded on every plan (see module docs on pool
    /// sharing between workers and intra-step parallelism).
    pub backend: Backend,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            // Sized like the worker pool: available parallelism with the
            // CONV_EINSUM_THREADS override, instead of a fixed constant.
            workers: crate::parallel::default_threads(),
            max_batch: 8,
            batch_timeout: Duration::from_millis(2),
            queue_capacity: 256,
            strategy: Strategy::Optimal,
            backend: Backend::default(),
        }
    }
}

enum Msg {
    Eval {
        layer: String,
        pending: Pending,
    },
    AdHoc {
        expr: String,
        tensors: Vec<Tensor>,
        respond: SyncSender<Result<Tensor>>,
    },
    Train {
        expr: String,
        pending: TrainPending,
    },
    Shutdown,
}

/// Handle for submitting work; cheap to clone.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: SyncSender<Msg>,
    metrics: Arc<ServiceMetrics>,
}

impl ServiceHandle {
    /// Evaluate a registered layer on a single example `[1, S, H', W']`
    /// (or `[S, H', W']`, auto-expanded). Blocks if the router is saturated
    /// (backpressure). Returns a receiver for the result.
    pub fn submit(&self, layer: &str, x: Tensor) -> Result<Receiver<Result<Tensor>>> {
        let x = if x.rank() == 3 {
            let mut shape = vec![1];
            shape.extend_from_slice(x.shape());
            let s2 = shape.clone();
            x.reshape(&s2)
        } else {
            x
        };
        let (rtx, rrx) = sync_channel(1);
        self.metrics.note_infer_submit();
        self.tx
            .send(Msg::Eval {
                layer: layer.to_string(),
                pending: Pending {
                    x,
                    respond: rtx,
                    enqueued: Instant::now(),
                },
            })
            .map_err(|_| anyhow!("service stopped"))?;
        Ok(rrx)
    }

    /// Evaluate an ad-hoc conv_einsum expression (unbatched path).
    pub fn submit_adhoc(
        &self,
        expr: &str,
        tensors: Vec<Tensor>,
    ) -> Result<Receiver<Result<Tensor>>> {
        let (rtx, rrx) = sync_channel(1);
        self.metrics.note_infer_submit();
        self.tx
            .send(Msg::AdHoc {
                expr: expr.to_string(),
                tensors,
                respond: rtx,
            })
            .map_err(|_| anyhow!("service stopped"))?;
        Ok(rrx)
    }

    /// Evaluate an ad-hoc **training step**: forward-with-tape + backward
    /// of `expr` at the given inputs under `policy`, seeded with the output
    /// cotangent `dout`. Returns the forward output and ∂L/∂input for
    /// every input.
    ///
    /// Training requests flow through the same batching scheduler as
    /// inference: same-expression, same-shape, same-policy steps are
    /// coalesced and replayed through one cached
    /// [`crate::exec::TrainLayout`] on a worker's training workspace, with
    /// results bit-identical to submitting each step alone (see the module
    /// docs).
    pub fn submit_train(
        &self,
        expr: &str,
        tensors: Vec<Tensor>,
        dout: Tensor,
        policy: CkptPolicy,
    ) -> Result<Receiver<Result<(Tensor, Vec<Tensor>)>>> {
        let (rtx, rrx) = sync_channel(1);
        self.metrics.note_train_submit();
        self.tx
            .send(Msg::Train {
                expr: expr.to_string(),
                pending: TrainPending {
                    tensors,
                    dout,
                    policy,
                    respond: rtx,
                    enqueued: Instant::now(),
                },
            })
            .map_err(|_| anyhow!("service stopped"))?;
        Ok(rrx)
    }

    /// Convenience: submit a training step and wait.
    pub fn train(
        &self,
        expr: &str,
        tensors: Vec<Tensor>,
        dout: Tensor,
        policy: CkptPolicy,
    ) -> Result<(Tensor, Vec<Tensor>)> {
        self.submit_train(expr, tensors, dout, policy)?
            .recv()
            .map_err(|_| anyhow!("service dropped response"))?
    }

    /// Convenience: submit and wait.
    pub fn eval(&self, layer: &str, x: Tensor) -> Result<Tensor> {
        self.submit(layer, x)?
            .recv()
            .map_err(|_| anyhow!("service dropped response"))?
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }
}

/// The evaluation service: router thread + worker pool.
pub struct EvalService {
    handle: ServiceHandle,
    router: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
}

/// An inference batch dispatched to workers.
struct WorkItem {
    layer: String,
    plan: Arc<CompiledPlan>,
    factors: Arc<Vec<Tensor>>,
    requests: Vec<Pending>,
}

enum WorkMsg {
    Batch(WorkItem),
    AdHoc {
        expr: String,
        tensors: Vec<Tensor>,
        respond: SyncSender<Result<Tensor>>,
        strategy: Strategy,
        backend: Backend,
    },
    /// A coalesced batch of same-expression training steps: compiled once
    /// through the shared cache, then replayed segment by segment against
    /// the worker's training workspace.
    TrainBatch {
        expr: String,
        policy: CkptPolicy,
        items: Vec<TrainPending>,
        strategy: Strategy,
        backend: Backend,
    },
    Stop,
}

impl EvalService {
    /// Start the service with the given registered layers.
    pub fn start(
        config: ServiceConfig,
        layers: Vec<(String, String, Vec<Tensor>)>, // (name, expr, factors)
    ) -> Result<EvalService> {
        let metrics = Arc::new(ServiceMetrics::default());
        let (tx, rx) = sync_channel::<Msg>(config.queue_capacity);
        let (wtx, wrx) = sync_channel::<WorkMsg>(config.workers * 2);
        let wrx = Arc::new(Mutex::new(wrx));
        let stop = Arc::new(AtomicBool::new(false));
        // Compiled-plan cache shared by all workers (ad-hoc expressions and
        // training steps).
        let cache = Arc::new(PlanCache::new());

        let mut registry: HashMap<String, LayerEntry> = HashMap::new();
        for (name, expr, factors) in layers {
            parse(&expr).map_err(|e| anyhow!("layer '{name}': {e}"))?;
            registry.insert(
                name,
                LayerEntry {
                    expr,
                    factors,
                    plans: crate::util::lru::LruCache::new(LAYER_PLAN_CACHE_CAPACITY),
                },
            );
        }

        // Worker pool.
        let mut workers = Vec::new();
        for wid in 0..config.workers.max(1) {
            let wrx = Arc::clone(&wrx);
            let metrics = Arc::clone(&metrics);
            let cache = Arc::clone(&cache);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("conv-einsum-worker-{wid}"))
                    .spawn(move || worker_loop(wrx, metrics, cache))
                    .expect("spawn worker"),
            );
        }

        // Router thread.
        let router_metrics = Arc::clone(&metrics);
        let cfg = config.clone();
        let router = std::thread::Builder::new()
            .name("conv-einsum-router".to_string())
            .spawn(move || router_loop(rx, wtx, registry, cfg, router_metrics))
            .expect("spawn router");

        Ok(EvalService {
            handle: ServiceHandle { tx, metrics },
            router: Some(router),
            workers,
            stop,
        })
    }

    pub fn handle(&self) -> ServiceHandle {
        self.handle.clone()
    }

    /// Graceful shutdown: drain queues, stop threads.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.handle.tx.send(Msg::Shutdown);
        if let Some(r) = self.router.take() {
            let _ = r.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Router poll cap while no deadlines are pending.
const IDLE_TICK: Duration = Duration::from_millis(50);

/// Live utilization in `[0, 1]`: the larger of the coordinator workers'
/// in-flight fraction and the executor pool's activity. This is the signal
/// the adaptive controller sizes batches from — idle means "nothing gains
/// from waiting, flush now"; saturated means "workers are busy anyway,
/// coalesce".
fn service_utilization(metrics: &ServiceMetrics, config: &ServiceConfig) -> f64 {
    let worker_u = metrics.inflight() as f64 / config.workers.max(1) as f64;
    let pool_u = match config.backend {
        Backend::Scalar => 0.0,
        Backend::Parallel { threads: 0 } => Pool::global().utilization(),
        Backend::Parallel { threads } => Pool::sized(threads).utilization(),
    };
    worker_u.max(pool_u).clamp(0.0, 1.0)
}

fn router_loop(
    rx: Receiver<Msg>,
    wtx: SyncSender<WorkMsg>,
    mut registry: HashMap<String, LayerEntry>,
    config: ServiceConfig,
    metrics: Arc<ServiceMetrics>,
) {
    let mut batcher = Batcher::new(AdaptiveController::new(
        config.max_batch,
        config.batch_timeout,
    ));
    loop {
        let util = service_utilization(&metrics, &config);
        let timeout = batcher
            .next_deadline(util)
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(IDLE_TICK);
        let msg = rx.recv_timeout(timeout);
        let util = service_utilization(&metrics, &config);
        match msg {
            Ok(Msg::Eval { layer, pending }) => {
                if !registry.contains_key(&layer) {
                    let _ = pending
                        .respond
                        .send(Err(anyhow!("unknown layer '{layer}'")));
                } else if let Some(batch) = batcher.push_eval(&layer, pending, util) {
                    dispatch(batch, &mut registry, &wtx, &metrics, &config);
                }
            }
            Ok(Msg::AdHoc {
                expr,
                tensors,
                respond,
            }) => {
                metrics.note_dispatched();
                let _ = wtx.send(WorkMsg::AdHoc {
                    expr,
                    tensors,
                    respond,
                    strategy: config.strategy,
                    backend: config.backend,
                });
            }
            Ok(Msg::Train { expr, pending }) => {
                if let Some(batch) = batcher.push_train(&expr, pending, util) {
                    dispatch(batch, &mut registry, &wtx, &metrics, &config);
                }
            }
            Ok(Msg::Shutdown) => break,
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
        for batch in batcher.due(Instant::now(), util) {
            dispatch(batch, &mut registry, &wtx, &metrics, &config);
        }
        metrics.set_queue_depth(batcher.pending_len());
    }
    // Drain on shutdown.
    for batch in batcher.drain() {
        dispatch(batch, &mut registry, &wtx, &metrics, &config);
    }
    for _ in 0..8 {
        let _ = wtx.send(WorkMsg::Stop);
    }
}

/// Evaluate an ad-hoc expression through the shared compile-once cache
/// (single-input expressions have no pairwise plan and run directly). The
/// expression is parsed exactly once per request — the parsed spec is
/// handed to the cache so a miss does not re-parse.
fn eval_adhoc(
    cache: &PlanCache,
    ws: &mut TrainWorkspace,
    expr: &str,
    tensors: &[Tensor],
    strategy: Strategy,
    backend: Backend,
) -> Result<Tensor> {
    let refs: Vec<&Tensor> = tensors.iter().collect();
    let opts = crate::planner::PlanOptions {
        strategy,
        backend,
        ..Default::default()
    };
    let spec = parse(expr).map_err(|e| anyhow!("{e}"))?;
    let dims: Vec<Vec<usize>> = refs.iter().map(|t| t.shape().to_vec()).collect();
    if spec.n_inputs() < 2 {
        let sized = SizedSpec::new(spec, dims).map_err(|e| anyhow!("{e}"))?;
        return Ok(crate::exec::single_input_eval(&sized, refs[0]));
    }
    let compiled = cache.get_or_compile_parsed(expr, &spec, &dims, &opts)?;
    compiled.run(&refs, ws.base_mut())
}

/// Parse + plan + compile a training batch's expression once through the
/// shared cache (the training cost model), validating that it has a
/// pairwise path at all.
fn prepare_train(
    cache: &PlanCache,
    expr: &str,
    items: &[TrainPending],
    strategy: Strategy,
    backend: Backend,
) -> Result<Arc<CompiledPlan>> {
    let spec = parse(expr).map_err(|e| anyhow!("{e}"))?;
    if spec.n_inputs() < 2 {
        return Err(anyhow!(
            "training steps need at least 2 inputs (got {})",
            spec.n_inputs()
        ));
    }
    let first = items
        .first()
        .ok_or_else(|| anyhow!("empty training batch"))?;
    let dims: Vec<Vec<usize>> = first.tensors.iter().map(|t| t.shape().to_vec()).collect();
    let opts = crate::planner::PlanOptions {
        strategy,
        backend,
        training: true,
        ..Default::default()
    };
    cache.get_or_compile_parsed(expr, &spec, &dims, &opts)
}

fn worker_loop(
    wrx: Arc<Mutex<Receiver<WorkMsg>>>,
    metrics: Arc<ServiceMetrics>,
    cache: Arc<PlanCache>,
) {
    // One reusable training workspace per worker thread: compiled plans of
    // any shape run against it (training batches tape into the same arena
    // inference uses), and it only ever grows. The staging tensor receives
    // each inference batch's concatenated inputs — same-shape steady-state
    // traffic reuses it without allocating.
    let mut ws = TrainWorkspace::new();
    let mut stage: Option<Tensor> = None;
    loop {
        let msg = {
            let rx = wrx.lock().unwrap();
            rx.recv()
        };
        match msg {
            Ok(WorkMsg::Batch(item)) => {
                let t0 = Instant::now();
                // Concatenate the batch along axis 0 into the reusable
                // staging tensor.
                let sizes: Vec<usize> = item.requests.iter().map(|p| p.x.shape()[0]).collect();
                let bsum: usize = sizes.iter().sum();
                let mut shape = item.requests[0].x.shape().to_vec();
                shape[0] = bsum;
                let reuse = matches!(&stage, Some(t) if t.shape() == &shape[..]);
                if !reuse {
                    stage = Some(Tensor::zeros(&shape));
                }
                let x = stage.as_mut().expect("staging tensor present");
                {
                    let parts: Vec<&Tensor> = item.requests.iter().map(|p| &p.x).collect();
                    concat_into(&parts, x);
                }
                let x = stage.as_ref().expect("staging tensor present");
                let mut inputs: Vec<&Tensor> = vec![x];
                inputs.extend(item.factors.iter());
                let result = item.plan.run(&inputs, ws.base_mut());
                match result {
                    Ok(y) => {
                        // Split along axis 0 back to requesters.
                        let parts = y.split_axis0(&sizes);
                        for (p, part) in item.requests.into_iter().zip(parts) {
                            metrics.note_done(p.enqueued.elapsed());
                            let _ = p.respond.send(Ok(part));
                        }
                    }
                    Err(e) => {
                        let msg = format!("layer '{}' failed: {e}", item.layer);
                        for p in item.requests {
                            metrics.note_error();
                            let _ = p.respond.send(Err(anyhow!("{msg}")));
                        }
                    }
                }
                metrics.note_work_done();
                metrics.note_exec_time(t0.elapsed());
            }
            Ok(WorkMsg::AdHoc {
                expr,
                tensors,
                respond,
                strategy,
                backend,
            }) => {
                let t0 = Instant::now();
                let result = eval_adhoc(&cache, &mut ws, &expr, &tensors, strategy, backend);
                match &result {
                    Ok(_) => metrics.note_done(t0.elapsed()),
                    Err(_) => metrics.note_error(),
                }
                let _ = respond.send(result);
                metrics.note_work_done();
                metrics.note_exec_time(t0.elapsed());
            }
            Ok(WorkMsg::TrainBatch {
                expr,
                policy,
                items,
                strategy,
                backend,
            }) => {
                let t0 = Instant::now();
                match prepare_train(&cache, &expr, &items, strategy, backend) {
                    Ok(compiled) => {
                        // One layout, one workspace, one segment per request
                        // in submission order — the batched replay.
                        let layout = compiled.train_layout(policy);
                        for p in items {
                            let refs: Vec<&Tensor> = p.tensors.iter().collect();
                            let mut out = Tensor::zeros(compiled.out_shape());
                            let mut grads: Vec<Tensor> = compiled
                                .in_dims()
                                .iter()
                                .map(|d| Tensor::zeros(d))
                                .collect();
                            let res = compiled
                                .train_step(&layout, &refs, &p.dout, &mut ws, &mut out, &mut grads);
                            match res {
                                Ok(()) => {
                                    metrics.note_done(p.enqueued.elapsed());
                                    let _ = p.respond.send(Ok((out, grads)));
                                }
                                Err(e) => {
                                    metrics.note_error();
                                    let _ = p.respond.send(Err(e));
                                }
                            }
                        }
                    }
                    Err(e) => {
                        let msg = format!("{e}");
                        for p in items {
                            metrics.note_error();
                            let _ = p.respond.send(Err(anyhow!("{msg}")));
                        }
                    }
                }
                metrics.note_work_done();
                metrics.note_exec_time(t0.elapsed());
            }
            Ok(WorkMsg::Stop) | Err(_) => break,
        }
    }
}

#[cfg(test)]
mod tests;
