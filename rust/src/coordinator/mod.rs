//! The L3 coordinator: a multi-threaded evaluation service for tensorial
//! layers — request router, **unified batching scheduler**, worker pool,
//! plan cache, metrics and backpressure (vLLM-router-style, adapted to
//! layer-evaluation traffic).
//!
//! Clients register tensorial layers once (expression + factor weights) and
//! submit single-example evaluations; ad-hoc expressions and ad-hoc
//! **training steps** ride the same pipeline. One scheduler (the `batcher`
//! submodule) owns queueing, shape-compatibility grouping, deadline
//! flushing and plan lookup for *both* request kinds: inference requests of
//! one layer and shape coalesce along the batch mode `b` into a single
//! batched replay, and same-expression training requests coalesce the same
//! way — a flushed training batch replays through one cached
//! [`crate::exec::TrainLayout`] against the worker's [`TrainWorkspace`],
//! one fused [`CompiledPlan::train_step`] per request in submission order
//! (per-request error isolation), with input gradients split along the
//! batch mode and weight gradients accumulated per segment — so batched
//! and individually submitted training steps are **bit-identical**. The
//! gradient contract, and the engine-level batch entry point library
//! callers use directly, is
//! [`crate::autodiff::PathAutodiff::train_step_batch_into`].
//!
//! Batch formation is **pool-aware and adaptive**
//! ([`AdaptiveController`]): the router sizes batches and flush deadlines
//! from live utilization — its own workers' in-flight count and the
//! executor pool's activity ([`crate::parallel::Pool::utilization`]). An
//! idle service flushes lone requests immediately (no added latency); a
//! saturated one holds partial batches up to
//! [`ServiceConfig::batch_timeout`] and coalesces up to
//! [`ServiceConfig::max_batch`] — the config bounds the controller instead
//! of fixing its operating point. Pending queues are keyed per
//! `(layer, shape)` / `(expression, shapes, policy)` group, so interleaved
//! traffic of incompatible shapes batches independently instead of
//! flushing each other out.
//!
//! # Fault tolerance
//!
//! The service guarantees **exactly one terminal outcome per request**: a
//! result tensor or a structured [`ServiceError`] — never a hung receiver,
//! no matter which thread panics or when shutdown lands. The mechanism is
//! the central in-flight table: every submitted request registers a
//! responder under a fresh id before it enters the pipeline, work messages
//! carry only ids, and *removing the table entry is the commit point* —
//! whichever path (worker completion, deadline shed, overload rejection,
//! crash handling, shutdown sweep) removes the entry first delivers the
//! one response, and every later path finds the entry gone and does
//! nothing.
//!
//! * **Worker supervision** — each worker runs its loop under
//!   `catch_unwind`; a panic is contained at the message boundary, its
//!   workspace and staging state are discarded (a fresh incarnation starts
//!   clean), the restart is counted
//!   ([`MetricsSnapshot::worker_restarts`]) and restarts back off
//!   exponentially. Idempotent inference requests in the dying batch are
//!   re-queued for a bounded number of retries
//!   ([`ServiceConfig::max_retries`], with backoff); training steps are
//!   **never silently replayed** — unfinished ones fail fast with
//!   [`ServiceError::WorkerCrashed`].
//! * **Deadlines** — [`ServiceConfig::request_deadline`] stamps every
//!   request with an absolute deadline; the scheduler and the workers shed
//!   expired requests with [`ServiceError::DeadlineExceeded`] instead of
//!   executing them.
//! * **Admission control** — pending work is bounded by
//!   [`ServiceConfig::max_pending`] requests and
//!   [`ServiceConfig::max_pending_bytes`] of payload. At the budget the
//!   router first sheds expired (oldest) work to make room, then rejects
//!   with [`ServiceError::Overloaded`] — explicit, immediate rejection
//!   instead of unbounded queue growth.
//! * **Graceful drain** — [`EvalService::shutdown`] stops admission,
//!   flushes everything pending, bounds the drain by
//!   [`ServiceConfig::drain_timeout`], joins what finished and answers
//!   every remaining request [`ServiceError::Shutdown`].
//!
//! The failure paths are exercised deterministically through the seeded
//! [`crate::faults`] registry (cargo feature `fault-injection`; named
//! sites `worker.eval.pre`, `worker.train.pre`, `worker.adhoc.pre`,
//! `parallel.run_chunks.pre`) — see `tests/chaos.rs`.
//!
//! Layer evaluation is **compile-once, run-many**: every `(layer, batch,
//! spatial)` key is planned and lowered to a [`CompiledPlan`] once and held
//! in a per-layer LRU cache bounded at [`LAYER_PLAN_CACHE_CAPACITY`]
//! geometries (with [`ServiceConfig::backend`] hoisted onto the cached
//! entry), and ad-hoc expressions — inference and training alike — share a
//! service-wide [`PlanCache`] keyed by `(expr, dims, backend, strategy,
//! training, conv kinds)`. Each worker thread owns one reusable
//! [`TrainWorkspace`] plus a reusable batch-staging tensor (inference
//! batches concatenate into it via [`crate::tensor::concat_into`]), so
//! steady-state execution allocates only the returned tensors.
//!
//! Workers and the executor's intra-step parallelism share one pool: each
//! compiled plan carries [`ServiceConfig::backend`], and under the default
//! [`Backend::Parallel`]` { threads: 0 }` (= the global persistent
//! [`crate::parallel::Pool`]) the pool's busy-flag arbitration means that
//! when several workers execute batches concurrently, exactly one fans out
//! across the pool while the rest run their steps serially on their own
//! worker thread — batch-level and step-level parallelism compose without
//! oversubscribing the machine. Explicit `Backend::Parallel { threads: k }`
//! counts resolve to the persistent per-size pools
//! ([`crate::parallel::Pool::sized`]), which carry the same busy-flag
//! arbitration — but their workers add to the global pool's, so prefer the
//! default backend outside benchmarking.
//!
//! Every plan the service caches is **statically verified** before it is
//! shared: [`PlanCache`] runs the [`crate::verify`] plan verifier
//! ([`CompiledPlan::verify`]) on insertion (and debug/test builds verify
//! at compile time), so a schedule with an unsound workspace layout,
//! out-of-bounds gather table or stale kernel accumulation-order version
//! never reaches a worker.

mod batcher;
mod metrics;

pub use batcher::{AdaptiveController, LAYER_PLAN_CACHE_CAPACITY};
pub use metrics::{MetricsSnapshot, ServiceMetrics, BATCH_SIZE_BUCKETS};

use crate::autodiff::CkptPolicy;
use crate::einsum::{parse, SizedSpec};
use crate::exec::{Backend, CompiledPlan, PlanCache, TrainWorkspace};
use crate::parallel::Pool;
use crate::planner::{PlanOptions, Strategy};
use crate::tensor::{concat_into, Tensor};
use crate::tune::{calibrate_expr, CalibrationReport, CalibrationSpec};
use anyhow::{anyhow, Result};
use batcher::{
    dispatch, tensor_bytes, Batcher, LayerEntry, Pending, PendingRequest, PushOutcome, ReadyBatch,
    TrainPending,
};
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Terminal request outcomes the service can report. Every submitted
/// request ends in exactly one `Ok` result or exactly one of these —
/// the liveness contract enforced by the in-flight table (module docs).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServiceError {
    /// The requested layer name was never registered.
    UnknownLayer(String),
    /// The request itself is malformed (e.g. an input of unusable rank).
    BadRequest(String),
    /// The request's absolute deadline passed before it could execute.
    DeadlineExceeded,
    /// Admission control: the pending budget is exhausted.
    Overloaded,
    /// A worker died executing the request and it could not be (or must
    /// not be — training) retried. Carries the panic message.
    WorkerCrashed(String),
    /// The service shut down before the request completed.
    Shutdown,
    /// The engine reported a planning or execution error.
    Engine(String),
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::UnknownLayer(name) => write!(f, "unknown layer '{name}'"),
            ServiceError::BadRequest(m) => write!(f, "bad request: {m}"),
            ServiceError::DeadlineExceeded => {
                write!(f, "deadline exceeded: request expired before execution")
            }
            ServiceError::Overloaded => write!(f, "overloaded: pending budget exhausted"),
            ServiceError::WorkerCrashed(m) => write!(f, "worker crashed: {m}"),
            ServiceError::Shutdown => {
                write!(f, "service shut down before the request completed")
            }
            ServiceError::Engine(m) => write!(f, "execution failed: {m}"),
        }
    }
}

impl std::error::Error for ServiceError {}

/// Terminal outcome of a training-step request: the forward output and
/// per-input gradients, or a [`ServiceError`].
pub type TrainResult = std::result::Result<(Tensor, Vec<Tensor>), ServiceError>;
/// Terminal outcome of an inference request.
pub type InferResult = std::result::Result<Tensor, ServiceError>;

/// The responder half of a registered request, typed by request kind.
enum Responder {
    Infer(SyncSender<InferResult>),
    Train(SyncSender<TrainResult>),
}

/// The in-flight request table: the single source of truth for which
/// requests still owe a response. Work messages carry only request ids;
/// the capacity-1 response channel lives here until some path commits the
/// terminal outcome by removing the entry (see the module docs). All
/// completion accounting (`completed`/`errors`, latency) flows through
/// this table, so `completed + errors == submitted` once drained.
pub(crate) struct Inflight {
    next: AtomicU64,
    table: Mutex<HashMap<u64, Responder>>,
    metrics: Arc<ServiceMetrics>,
}

impl Inflight {
    fn new(metrics: Arc<ServiceMetrics>) -> Inflight {
        Inflight {
            next: AtomicU64::new(0),
            table: Mutex::new(HashMap::new()),
            metrics,
        }
    }

    fn lock(&self) -> MutexGuard<'_, HashMap<u64, Responder>> {
        // A fault-injected panic can unwind through a holder; poisoning
        // must never wedge request completion for everyone else.
        self.table.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn register_infer(&self) -> (u64, Receiver<InferResult>) {
        let id = self.next.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = sync_channel(1);
        self.lock().insert(id, Responder::Infer(tx));
        (id, rx)
    }

    fn register_train(&self) -> (u64, Receiver<TrainResult>) {
        let id = self.next.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = sync_channel(1);
        self.lock().insert(id, Responder::Train(tx));
        (id, rx)
    }

    /// Deliver an inference outcome. Entry removal is the exactly-once
    /// commit point; returns `false` if the request was already answered.
    /// The send can never block (capacity-1 channel, one send per entry)
    /// and a gone receiver is the caller's loss alone.
    pub(crate) fn complete_infer(&self, id: u64, enqueued: Instant, result: InferResult) -> bool {
        let Some(entry) = self.lock().remove(&id) else {
            return false;
        };
        match &result {
            Ok(_) => self.metrics.note_done(enqueued.elapsed()),
            Err(_) => self.metrics.note_error(),
        }
        if let Responder::Infer(tx) = entry {
            let _ = tx.try_send(result);
        }
        true
    }

    /// Deliver a training outcome (same contract as
    /// [`Inflight::complete_infer`]).
    pub(crate) fn complete_train(&self, id: u64, enqueued: Instant, result: TrainResult) -> bool {
        let Some(entry) = self.lock().remove(&id) else {
            return false;
        };
        match &result {
            Ok(_) => self.metrics.note_done(enqueued.elapsed()),
            Err(_) => self.metrics.note_error(),
        }
        if let Responder::Train(tx) = entry {
            let _ = tx.try_send(result);
        }
        true
    }

    /// Terminally fail a request of either kind.
    pub(crate) fn fail(&self, id: u64, err: ServiceError) -> bool {
        let Some(entry) = self.lock().remove(&id) else {
            return false;
        };
        self.metrics.note_error();
        match entry {
            Responder::Infer(tx) => {
                let _ = tx.try_send(Err(err));
            }
            Responder::Train(tx) => {
                let _ = tx.try_send(Err(err));
            }
        }
        true
    }

    /// Fail every still-registered request — the final shutdown sweep that
    /// makes "no request ever ends without a terminal response" hold even
    /// for requests stranded by a wedged worker or a mid-flight submit.
    pub(crate) fn fail_all(&self, err: ServiceError) -> usize {
        let drained: Vec<Responder> = self.lock().drain().map(|(_, r)| r).collect();
        let n = drained.len();
        for r in drained {
            self.metrics.note_error();
            match r {
                Responder::Infer(tx) => {
                    let _ = tx.try_send(Err(err.clone()));
                }
                Responder::Train(tx) => {
                    let _ = tx.try_send(Err(err.clone()));
                }
            }
        }
        n
    }
}

/// Service configuration. `max_batch` and `batch_timeout` bound the
/// adaptive batching controller ([`AdaptiveController`]); the actual batch
/// size and flush deadline at any moment are derived from live pool
/// utilization within those bounds.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Worker threads executing batches.
    pub workers: usize,
    /// Upper bound on requests coalesced into one batch.
    pub max_batch: usize,
    /// Upper bound on how long the scheduler holds a partial batch.
    pub batch_timeout: Duration,
    /// Router inbox capacity (backpressure: submit blocks when full).
    pub queue_capacity: usize,
    /// Path strategy for plans.
    pub strategy: Strategy,
    /// Execution backend recorded on every plan (see module docs on pool
    /// sharing between workers and intra-step parallelism).
    pub backend: Backend,
    /// End-to-end deadline stamped on every request at submit; expired
    /// requests are shed with [`ServiceError::DeadlineExceeded`] instead
    /// of executed. `None` (the default) disables deadlines.
    pub request_deadline: Option<Duration>,
    /// Crash-retry bound for idempotent inference requests whose worker
    /// died mid-batch (training steps are never retried).
    pub max_retries: u32,
    /// Admission budget: maximum requests queued in the scheduler before
    /// new work is rejected with [`ServiceError::Overloaded`].
    pub max_pending: usize,
    /// Admission budget: maximum payload bytes queued in the scheduler.
    pub max_pending_bytes: usize,
    /// Hard bound on the shutdown drain: past it, undelivered work and
    /// unfinished requests are answered [`ServiceError::Shutdown`] and
    /// wedged workers are abandoned rather than joined.
    pub drain_timeout: Duration,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            // Sized like the worker pool: available parallelism with the
            // CONV_EINSUM_THREADS override, instead of a fixed constant.
            workers: crate::parallel::default_threads(),
            max_batch: 8,
            batch_timeout: Duration::from_millis(2),
            queue_capacity: 256,
            strategy: Strategy::Optimal,
            backend: Backend::default(),
            request_deadline: None,
            max_retries: 2,
            max_pending: 4096,
            max_pending_bytes: 1 << 28,
            drain_timeout: Duration::from_secs(10),
        }
    }
}

/// An ad-hoc expression request (unbatched path). Like [`Pending`], it
/// carries only its inflight id — never a responder.
pub(crate) struct AdHocPending {
    pub(crate) tensors: Vec<Tensor>,
    pub(crate) id: u64,
    pub(crate) enqueued: Instant,
    pub(crate) deadline: Option<Instant>,
    pub(crate) retries: u32,
    pub(crate) not_before: Option<Instant>,
}

impl PendingRequest for AdHocPending {
    fn id(&self) -> u64 {
        self.id
    }
    fn deadline(&self) -> Option<Instant> {
        self.deadline
    }
    fn bytes(&self) -> usize {
        self.tensors.iter().map(tensor_bytes).sum()
    }
}

enum Msg {
    Eval {
        layer: String,
        pending: Pending,
    },
    AdHoc {
        expr: String,
        pending: AdHocPending,
    },
    Train {
        expr: String,
        pending: TrainPending,
    },
    Shutdown,
}

/// Handle for submitting work; cheap to clone.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: SyncSender<Msg>,
    metrics: Arc<ServiceMetrics>,
    inflight: Arc<Inflight>,
    stop: Arc<AtomicBool>,
    cfg: Arc<ServiceConfig>,
}

impl ServiceHandle {
    /// Submit-side admission: reject before registering anything when the
    /// service is stopping or the router's published pending gauges are
    /// over budget. The gauge check is a conservative fast path (gauges
    /// update once per router tick); the authoritative budget lives in the
    /// scheduler.
    fn admit(&self) -> std::result::Result<(), ServiceError> {
        if self.stop.load(Ordering::SeqCst) {
            return Err(ServiceError::Shutdown);
        }
        if self.metrics.queue_depth() >= self.cfg.max_pending
            || self.metrics.pending_bytes() > self.cfg.max_pending_bytes
        {
            self.metrics.note_overload_rejected();
            return Err(ServiceError::Overloaded);
        }
        Ok(())
    }

    fn deadline_from(&self, now: Instant) -> Option<Instant> {
        self.cfg.request_deadline.map(|d| now + d)
    }

    /// Evaluate a registered layer on a single example `[1, S, H', W']`
    /// (or `[S, H', W']`, auto-expanded). Blocks if the router is saturated
    /// (backpressure). Returns a receiver that is **guaranteed** to yield
    /// exactly one terminal `Result` (see the module docs).
    pub fn submit(
        &self,
        layer: &str,
        x: Tensor,
    ) -> std::result::Result<Receiver<InferResult>, ServiceError> {
        let x = if x.rank() == 3 {
            let mut shape = vec![1];
            shape.extend_from_slice(x.shape());
            x.reshape(&shape)
        } else {
            x
        };
        self.admit()?;
        let (id, rrx) = self.inflight.register_infer();
        self.metrics.note_infer_submit();
        let now = Instant::now();
        let pending = Pending {
            x,
            id,
            enqueued: now,
            deadline: self.deadline_from(now),
            retries: 0,
            not_before: None,
        };
        match self.tx.send(Msg::Eval {
            layer: layer.to_string(),
            pending,
        }) {
            Ok(()) => Ok(rrx),
            Err(_) => {
                self.inflight.fail(id, ServiceError::Shutdown);
                Err(ServiceError::Shutdown)
            }
        }
    }

    /// Evaluate an ad-hoc conv_einsum expression (unbatched path).
    pub fn submit_adhoc(
        &self,
        expr: &str,
        tensors: Vec<Tensor>,
    ) -> std::result::Result<Receiver<InferResult>, ServiceError> {
        self.admit()?;
        let (id, rrx) = self.inflight.register_infer();
        self.metrics.note_infer_submit();
        let now = Instant::now();
        let pending = AdHocPending {
            tensors,
            id,
            enqueued: now,
            deadline: self.deadline_from(now),
            retries: 0,
            not_before: None,
        };
        match self.tx.send(Msg::AdHoc {
            expr: expr.to_string(),
            pending,
        }) {
            Ok(()) => Ok(rrx),
            Err(_) => {
                self.inflight.fail(id, ServiceError::Shutdown);
                Err(ServiceError::Shutdown)
            }
        }
    }

    /// Evaluate an ad-hoc **training step**: forward-with-tape + backward
    /// of `expr` at the given inputs under `policy`, seeded with the output
    /// cotangent `dout`. Returns the forward output and ∂L/∂input for
    /// every input.
    ///
    /// Training requests flow through the same batching scheduler as
    /// inference: same-expression, same-shape, same-policy steps are
    /// coalesced and replayed through one cached
    /// [`crate::exec::TrainLayout`] on a worker's training workspace, with
    /// results bit-identical to submitting each step alone (see the module
    /// docs). Unlike inference, a training step whose worker crashes is
    /// never replayed — it fails fast with
    /// [`ServiceError::WorkerCrashed`].
    pub fn submit_train(
        &self,
        expr: &str,
        tensors: Vec<Tensor>,
        dout: Tensor,
        policy: CkptPolicy,
    ) -> std::result::Result<Receiver<TrainResult>, ServiceError> {
        self.admit()?;
        let (id, rrx) = self.inflight.register_train();
        self.metrics.note_train_submit();
        let now = Instant::now();
        let pending = TrainPending {
            tensors,
            dout,
            policy,
            id,
            enqueued: now,
            deadline: self.deadline_from(now),
        };
        match self.tx.send(Msg::Train {
            expr: expr.to_string(),
            pending,
        }) {
            Ok(()) => Ok(rrx),
            Err(_) => {
                self.inflight.fail(id, ServiceError::Shutdown);
                Err(ServiceError::Shutdown)
            }
        }
    }

    /// Convenience: submit a training step and wait.
    pub fn train(
        &self,
        expr: &str,
        tensors: Vec<Tensor>,
        dout: Tensor,
        policy: CkptPolicy,
    ) -> TrainResult {
        match self.submit_train(expr, tensors, dout, policy)?.recv() {
            Ok(r) => r,
            // Defensive: the responder is dropped without an answer only if
            // the terminal send itself raced a vanished process state.
            Err(_) => Err(ServiceError::Shutdown),
        }
    }

    /// Convenience: submit and wait.
    pub fn eval(&self, layer: &str, x: Tensor) -> InferResult {
        match self.submit(layer, x)?.recv() {
            Ok(r) => r,
            Err(_) => Err(ServiceError::Shutdown),
        }
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }
}

/// The evaluation service: router thread + supervised worker pool.
pub struct EvalService {
    handle: ServiceHandle,
    router: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    /// `(name, expr, factor shapes)` of every registered layer, kept on
    /// the service side (the registry itself moves into the router) so
    /// [`EvalService::calibrate_registered`] can rebuild calibration
    /// geometries without a router round-trip.
    calib_layers: Vec<(String, String, Vec<Vec<usize>>)>,
}

/// An inference batch dispatched to workers.
pub(crate) struct WorkItem {
    pub(crate) layer: String,
    pub(crate) plan: Arc<CompiledPlan>,
    pub(crate) factors: Arc<Vec<Tensor>>,
    pub(crate) requests: Vec<Pending>,
}

pub(crate) enum WorkMsg {
    Batch(WorkItem),
    AdHoc {
        expr: String,
        pending: AdHocPending,
        strategy: Strategy,
        backend: Backend,
    },
    /// A coalesced batch of same-expression training steps: compiled once
    /// through the shared cache, then replayed segment by segment against
    /// the worker's training workspace.
    TrainBatch {
        expr: String,
        policy: CkptPolicy,
        items: Vec<TrainPending>,
        strategy: Strategy,
        backend: Backend,
    },
    Stop,
}

/// Send a work message to the worker channel. `deadline: None` blocks
/// (normal-path backpressure); `Some(d)` bounds the send during shutdown
/// drain so a wedged worker pool cannot hang the router forever. An
/// undeliverable message terminally answers every request it carries with
/// [`ServiceError::Shutdown`] — work is never silently dropped.
pub(crate) fn send_work(
    wtx: &SyncSender<WorkMsg>,
    msg: WorkMsg,
    deadline: Option<Instant>,
    metrics: &ServiceMetrics,
    inflight: &Inflight,
) {
    let is_stop = matches!(msg, WorkMsg::Stop);
    let failed = match deadline {
        None => wtx.send(msg).err().map(|e| e.0),
        Some(d) => {
            let mut msg = msg;
            loop {
                match wtx.try_send(msg) {
                    Ok(()) => break None,
                    Err(TrySendError::Disconnected(m)) => break Some(m),
                    Err(TrySendError::Full(m)) => {
                        if Instant::now() >= d {
                            break Some(m);
                        }
                        msg = m;
                        std::thread::sleep(Duration::from_millis(1));
                    }
                }
            }
        }
    };
    match failed {
        None => {
            // Stop markers are not work: they must not skew the in-flight
            // utilization gauge.
            if !is_stop {
                metrics.note_dispatched();
            }
        }
        Some(m) => fail_work_msg(m, inflight, ServiceError::Shutdown),
    }
}

/// Terminally answer every request carried by an undeliverable work
/// message.
pub(crate) fn fail_work_msg(msg: WorkMsg, inflight: &Inflight, err: ServiceError) {
    match msg {
        WorkMsg::Batch(item) => {
            for p in item.requests {
                inflight.fail(p.id, err.clone());
            }
        }
        WorkMsg::AdHoc { pending, .. } => {
            inflight.fail(pending.id, err);
        }
        WorkMsg::TrainBatch { items, .. } => {
            for p in items {
                inflight.fail(p.id, err.clone());
            }
        }
        WorkMsg::Stop => {}
    }
}

impl EvalService {
    /// Start the service with the given registered layers.
    pub fn start(
        config: ServiceConfig,
        layers: Vec<(String, String, Vec<Tensor>)>, // (name, expr, factors)
    ) -> Result<EvalService> {
        let metrics = Arc::new(ServiceMetrics::default());
        let inflight = Arc::new(Inflight::new(Arc::clone(&metrics)));
        let (tx, rx) = sync_channel::<Msg>(config.queue_capacity);
        let (wtx, wrx) = sync_channel::<WorkMsg>(config.workers * 2);
        let wrx = Arc::new(Mutex::new(wrx));
        let stop = Arc::new(AtomicBool::new(false));
        // Compiled-plan cache shared by all workers (ad-hoc expressions and
        // training steps).
        let cache = Arc::new(PlanCache::new());

        // Layer geometries survive on the service side for background
        // calibration; the registry itself moves into the router below.
        let calib_layers: Vec<(String, String, Vec<Vec<usize>>)> = layers
            .iter()
            .map(|(name, expr, factors)| {
                (
                    name.clone(),
                    expr.clone(),
                    factors.iter().map(|f| f.shape().to_vec()).collect(),
                )
            })
            .collect();

        let mut registry: HashMap<String, LayerEntry> = HashMap::new();
        for (name, expr, factors) in layers {
            parse(&expr).map_err(|e| anyhow!("layer '{name}': {e}"))?;
            registry.insert(
                name,
                LayerEntry {
                    expr,
                    factors,
                    plans: crate::util::lru::LruCache::new(LAYER_PLAN_CACHE_CAPACITY),
                },
            );
        }

        // Supervised worker pool. Workers hold a feedback sender into the
        // router so a dying incarnation can re-queue idempotent requests.
        let mut workers = Vec::new();
        for wid in 0..config.workers.max(1) {
            let ctx = WorkerCtx {
                wrx: Arc::clone(&wrx),
                metrics: Arc::clone(&metrics),
                cache: Arc::clone(&cache),
                inflight: Arc::clone(&inflight),
                feedback: tx.clone(),
                max_retries: config.max_retries,
            };
            workers.push(
                std::thread::Builder::new()
                    .name(format!("conv-einsum-worker-{wid}"))
                    .spawn(move || worker_thread(ctx))
                    .expect("spawn worker"),
            );
        }

        // Router thread.
        let router_metrics = Arc::clone(&metrics);
        let router_inflight = Arc::clone(&inflight);
        let cfg = config.clone();
        let router = std::thread::Builder::new()
            .name("conv-einsum-router".to_string())
            .spawn(move || router_loop(rx, wtx, registry, cfg, router_metrics, router_inflight))
            .expect("spawn router");

        Ok(EvalService {
            handle: ServiceHandle {
                tx,
                metrics,
                inflight,
                stop: Arc::clone(&stop),
                cfg: Arc::new(config),
            },
            router: Some(router),
            workers,
            stop,
            calib_layers,
        })
    }

    pub fn handle(&self) -> ServiceHandle {
        self.handle.clone()
    }

    /// Opt-in background calibration over the registered layers: for each
    /// `(layer name, example input shape)` pair, run the measured-cost
    /// plan tournament ([`crate::tune::calibrate_expr`]) for that layer's
    /// expression at `[input shape, factor shapes...]` on this service's
    /// configured backend, recording measurements into the global tuning
    /// cache as each layer finishes.
    ///
    /// The pass runs on its own thread — serving traffic continues
    /// untouched, though calibration replays do compete for the shared
    /// worker pool, so schedule it during warm-up or off-peak. Outcomes
    /// stream per layer on the returned channel (an unknown layer name
    /// reports an error rather than being skipped silently); drop the
    /// receiver to let the pass finish unobserved. Once a layer's
    /// measurements land, `Strategy::Measured` compiles for that geometry
    /// rank by wall-clock, and previously compiled measured plans go
    /// stale (their tuning-generation stamp no longer verifies).
    pub fn calibrate_registered(
        &self,
        examples: &[(String, Vec<usize>)],
        spec: CalibrationSpec,
    ) -> Receiver<(String, std::result::Result<CalibrationReport, String>)> {
        let (tx, rx) = channel();
        let jobs: Vec<(String, std::result::Result<(String, Vec<Vec<usize>>), String>)> = examples
            .iter()
            .map(|(name, xshape)| {
                let job = match self.calib_layers.iter().find(|(n, _, _)| n == name) {
                    Some((_, expr, factor_dims)) => {
                        let mut dims = Vec::with_capacity(1 + factor_dims.len());
                        dims.push(xshape.clone());
                        dims.extend(factor_dims.iter().cloned());
                        Ok((expr.clone(), dims))
                    }
                    None => Err(format!("layer '{name}' is not registered")),
                };
                (name.clone(), job)
            })
            .collect();
        let opts = PlanOptions {
            strategy: Strategy::Measured { top_k: spec.top_k },
            backend: self.handle.cfg.backend,
            ..PlanOptions::default()
        };
        std::thread::Builder::new()
            .name("conv-einsum-calibrate".to_string())
            .spawn(move || {
                for (name, job) in jobs {
                    let outcome = match job {
                        Ok((expr, dims)) => calibrate_expr(&expr, &dims, &opts, &spec),
                        Err(e) => Err(e),
                    };
                    // A dropped receiver doesn't stop the pass: the cache
                    // still benefits, reporting just goes unobserved.
                    let _ = tx.send((name, outcome));
                }
            })
            .expect("spawn calibrator");
        rx
    }

    /// Graceful shutdown: stop admitting, flush and answer everything
    /// pending, stop the threads. Bounded by
    /// [`ServiceConfig::drain_timeout`]: a worker wedged past it is
    /// abandoned (its thread dies with the process) and every request
    /// still unfinished is answered [`ServiceError::Shutdown`] — shutdown
    /// never hangs and never strands a receiver.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.handle.tx.send(Msg::Shutdown);
        if let Some(r) = self.router.take() {
            let _ = r.join();
        }
        // The router's drain sent each worker a Stop (bounded send); join
        // with a hard timeout so a wedged worker cannot hang us.
        let deadline = Instant::now() + self.handle.cfg.drain_timeout;
        let mut pending: Vec<JoinHandle<()>> = self.workers.drain(..).collect();
        while !pending.is_empty() && Instant::now() < deadline {
            let mut still = Vec::with_capacity(pending.len());
            for w in pending {
                if w.is_finished() {
                    let _ = w.join();
                } else {
                    still.push(w);
                }
            }
            pending = still;
            if !pending.is_empty() {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        drop(pending);
        // Final sweep: whatever nobody answered — requests stranded in a
        // wedged worker, submits that raced the stop flag — fails here.
        self.handle.inflight.fail_all(ServiceError::Shutdown);
    }
}

/// Router poll cap while no deadlines are pending.
const IDLE_TICK: Duration = Duration::from_millis(50);

/// Live utilization in `[0, 1]`: the larger of the coordinator workers'
/// in-flight fraction and the executor pool's activity. This is the signal
/// the adaptive controller sizes batches from — idle means "nothing gains
/// from waiting, flush now"; saturated means "workers are busy anyway,
/// coalesce".
fn service_utilization(metrics: &ServiceMetrics, config: &ServiceConfig) -> f64 {
    let worker_u = metrics.inflight() as f64 / config.workers.max(1) as f64;
    let pool_u = match config.backend {
        Backend::Scalar => 0.0,
        Backend::Parallel { threads: 0 } => Pool::global().utilization(),
        Backend::Parallel { threads } => Pool::sized(threads).utilization(),
    };
    worker_u.max(pool_u).clamp(0.0, 1.0)
}

/// The router's mutable state, grouped so routing logic can live in
/// methods (single messages, retry releases and the shutdown drain all
/// share one code path).
struct RouterState {
    batcher: Batcher,
    registry: HashMap<String, LayerEntry>,
    /// Crash-retried requests held for their backoff (`not_before`).
    delayed: Vec<(Instant, Msg)>,
    wtx: SyncSender<WorkMsg>,
    config: ServiceConfig,
    metrics: Arc<ServiceMetrics>,
    inflight: Arc<Inflight>,
}

impl RouterState {
    fn dispatch(&mut self, batch: ReadyBatch, deadline: Option<Instant>) {
        dispatch(
            batch,
            &mut self.registry,
            &self.wtx,
            &self.metrics,
            &self.config,
            &self.inflight,
            deadline,
        );
    }

    /// Shed queued expired work, answering each shed request.
    fn shed_expired(&mut self, now: Instant) {
        for id in self.batcher.shed_expired(now) {
            self.metrics.note_deadline_expired();
            self.inflight.fail(id, ServiceError::DeadlineExceeded);
        }
    }

    fn publish_gauges(&self) {
        self.metrics.set_queue_depth(self.batcher.pending_len());
        self.metrics.set_pending_bytes(self.batcher.pending_bytes());
    }

    /// Route one message. Rejected pushes first shed expired work to make
    /// room (oldest-first under overload), then answer `Overloaded`.
    fn route(&mut self, msg: Msg, util: f64) {
        match msg {
            Msg::Eval { layer, pending } => {
                if let Some(t) = pending.not_before {
                    if t > Instant::now() {
                        self.delayed.push((t, Msg::Eval { layer, pending }));
                        return;
                    }
                }
                if !self.registry.contains_key(&layer) {
                    self.inflight
                        .fail(pending.id, ServiceError::UnknownLayer(layer));
                    return;
                }
                match self.batcher.push_eval(&layer, pending, util) {
                    PushOutcome::Ready(b) => self.dispatch(b, None),
                    PushOutcome::Queued => {}
                    PushOutcome::Rejected(p) => {
                        self.shed_expired(Instant::now());
                        match self.batcher.push_eval(&layer, p, util) {
                            PushOutcome::Ready(b) => self.dispatch(b, None),
                            PushOutcome::Queued => {}
                            PushOutcome::Rejected(p) => {
                                self.metrics.note_overload_rejected();
                                self.inflight.fail(p.id, ServiceError::Overloaded);
                            }
                        }
                    }
                }
            }
            Msg::AdHoc { expr, pending } => {
                if let Some(t) = pending.not_before {
                    if t > Instant::now() {
                        self.delayed.push((t, Msg::AdHoc { expr, pending }));
                        return;
                    }
                }
                send_work(
                    &self.wtx,
                    WorkMsg::AdHoc {
                        expr,
                        pending,
                        strategy: self.config.strategy,
                        backend: self.config.backend,
                    },
                    None,
                    &self.metrics,
                    &self.inflight,
                );
            }
            Msg::Train { expr, pending } => match self.batcher.push_train(&expr, pending, util) {
                PushOutcome::Ready(b) => self.dispatch(b, None),
                PushOutcome::Queued => {}
                PushOutcome::Rejected(p) => {
                    self.shed_expired(Instant::now());
                    match self.batcher.push_train(&expr, p, util) {
                        PushOutcome::Ready(b) => self.dispatch(b, None),
                        PushOutcome::Queued => {}
                        PushOutcome::Rejected(p) => {
                            self.metrics.note_overload_rejected();
                            self.inflight.fail(p.id, ServiceError::Overloaded);
                        }
                    }
                }
            },
            Msg::Shutdown => {}
        }
    }

    /// Re-route retry-held requests whose backoff has elapsed.
    fn release_delayed(&mut self, now: Instant, util: f64) {
        let mut i = 0;
        while i < self.delayed.len() {
            if self.delayed[i].0 <= now {
                let (_, mut msg) = self.delayed.swap_remove(i);
                clear_not_before(&mut msg);
                self.route(msg, util);
            } else {
                i += 1;
            }
        }
    }

    /// Shutdown drain: final routing pass for retry-held requests (backoff
    /// waived — a prompt final attempt beats a missed one), flush every
    /// pending group, release the workers. Every send is bounded by the
    /// drain deadline; what cannot be delivered is answered `Shutdown`.
    fn drain(mut self) {
        let deadline = Instant::now() + self.config.drain_timeout;
        let delayed = std::mem::take(&mut self.delayed);
        for (_, mut msg) in delayed {
            clear_not_before(&mut msg);
            self.route(msg, 1.0);
        }
        for batch in self.batcher.drain() {
            self.dispatch(batch, Some(deadline));
        }
        for _ in 0..self.config.workers.max(1) {
            send_work(
                &self.wtx,
                WorkMsg::Stop,
                Some(deadline),
                &self.metrics,
                &self.inflight,
            );
        }
        self.publish_gauges();
    }
}

fn clear_not_before(msg: &mut Msg) {
    match msg {
        Msg::Eval { pending, .. } => pending.not_before = None,
        Msg::AdHoc { pending, .. } => pending.not_before = None,
        Msg::Train { .. } | Msg::Shutdown => {}
    }
}

fn router_loop(
    rx: Receiver<Msg>,
    wtx: SyncSender<WorkMsg>,
    registry: HashMap<String, LayerEntry>,
    config: ServiceConfig,
    metrics: Arc<ServiceMetrics>,
    inflight: Arc<Inflight>,
) {
    let mut st = RouterState {
        batcher: Batcher::new(
            AdaptiveController::new(config.max_batch, config.batch_timeout),
            config.max_pending,
            config.max_pending_bytes,
        ),
        registry,
        delayed: Vec::new(),
        wtx,
        config,
        metrics,
        inflight,
    };
    loop {
        let util = service_utilization(&st.metrics, &st.config);
        let next = [
            st.batcher.next_deadline(util),
            st.delayed.iter().map(|(t, _)| *t).min(),
        ]
        .into_iter()
        .flatten()
        .min();
        let timeout = next
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(IDLE_TICK);
        let msg = rx.recv_timeout(timeout);
        let util = service_utilization(&st.metrics, &st.config);
        let stopping = match msg {
            Ok(Msg::Shutdown) => true,
            Ok(m) => {
                st.route(m, util);
                false
            }
            Err(RecvTimeoutError::Timeout) => false,
            Err(RecvTimeoutError::Disconnected) => true,
        };
        let now = Instant::now();
        st.release_delayed(now, util);
        st.shed_expired(now);
        if stopping {
            break;
        }
        for batch in st.batcher.due(Instant::now(), util) {
            st.dispatch(batch, None);
        }
        st.publish_gauges();
    }
    st.drain();
}

/// Evaluate an ad-hoc expression through the shared compile-once cache
/// (single-input expressions have no pairwise plan and run directly). The
/// expression is parsed exactly once per request — the parsed spec is
/// handed to the cache so a miss does not re-parse.
fn eval_adhoc(
    cache: &PlanCache,
    ws: &mut TrainWorkspace,
    expr: &str,
    tensors: &[Tensor],
    strategy: Strategy,
    backend: Backend,
) -> Result<Tensor> {
    let refs: Vec<&Tensor> = tensors.iter().collect();
    let opts = crate::planner::PlanOptions {
        strategy,
        backend,
        ..Default::default()
    };
    let spec = parse(expr).map_err(|e| anyhow!("{e}"))?;
    let dims: Vec<Vec<usize>> = refs.iter().map(|t| t.shape().to_vec()).collect();
    if spec.n_inputs() < 2 {
        let sized = SizedSpec::new(spec, dims).map_err(|e| anyhow!("{e}"))?;
        return Ok(crate::exec::single_input_eval(&sized, refs[0]));
    }
    let compiled = cache.get_or_compile_parsed(expr, &spec, &dims, &opts)?;
    compiled.run(&refs, ws.base_mut())
}

/// Parse + plan + compile a training batch's expression once through the
/// shared cache (the training cost model), validating that it has a
/// pairwise path at all.
fn prepare_train(
    cache: &PlanCache,
    expr: &str,
    items: &[TrainPending],
    strategy: Strategy,
    backend: Backend,
) -> Result<Arc<CompiledPlan>> {
    let spec = parse(expr).map_err(|e| anyhow!("{e}"))?;
    if spec.n_inputs() < 2 {
        return Err(anyhow!(
            "training steps need at least 2 inputs (got {})",
            spec.n_inputs()
        ));
    }
    let first = items
        .first()
        .ok_or_else(|| anyhow!("empty training batch"))?;
    let dims: Vec<Vec<usize>> = first.tensors.iter().map(|t| t.shape().to_vec()).collect();
    let opts = crate::planner::PlanOptions {
        strategy,
        backend,
        training: true,
        ..Default::default()
    };
    cache.get_or_compile_parsed(expr, &spec, &dims, &opts)
}

/// Everything a worker incarnation needs, bundled for the supervisor.
struct WorkerCtx {
    wrx: Arc<Mutex<Receiver<WorkMsg>>>,
    metrics: Arc<ServiceMetrics>,
    cache: Arc<PlanCache>,
    inflight: Arc<Inflight>,
    /// Back into the router: crash-retried requests re-enter the pipeline
    /// here (`try_send` only — a dying worker never blocks on a full
    /// inbox, it fails the request instead).
    feedback: SyncSender<Msg>,
    max_retries: u32,
}

enum WorkerExit {
    /// Clean stop (Stop marker or closed channel).
    Stop,
    /// A message handler panicked; the supervisor restarts the loop.
    Crashed,
}

/// The worker supervisor: run the loop, and when an incarnation crashes,
/// count the restart, back off exponentially against crash loops
/// (consecutive crashes reset on any successfully handled message), and
/// start a fresh incarnation — with a fresh workspace and staging tensor,
/// so no state a panic may have half-written is ever reused.
fn worker_thread(ctx: WorkerCtx) {
    let mut consecutive: u32 = 0;
    loop {
        match worker_loop(&ctx, &mut consecutive) {
            WorkerExit::Stop => break,
            WorkerExit::Crashed => {
                ctx.metrics.note_worker_restart();
                consecutive += 1;
                std::thread::sleep(Duration::from_millis(1u64 << consecutive.min(6)));
            }
        }
    }
}

/// One supervised incarnation of the worker loop: returns at the first
/// caught panic (or a clean stop), never unwinds.
fn worker_loop(ctx: &WorkerCtx, consecutive: &mut u32) -> WorkerExit {
    // One reusable training workspace per incarnation: compiled plans of
    // any shape run against it (training batches tape into the same arena
    // inference uses), and it only ever grows. The staging tensor receives
    // each inference batch's concatenated inputs — same-shape steady-state
    // traffic reuses it without allocating.
    let mut ws = TrainWorkspace::new();
    let mut stage: Option<Tensor> = None;
    loop {
        let msg = {
            let rx = ctx.wrx.lock().unwrap_or_else(PoisonError::into_inner);
            rx.recv()
        };
        let crashed = match msg {
            Ok(WorkMsg::Batch(item)) => {
                let t0 = Instant::now();
                let crashed = run_eval_batch(ctx, &mut ws, &mut stage, item);
                ctx.metrics.note_work_done();
                ctx.metrics.note_exec_time(t0.elapsed());
                crashed
            }
            Ok(WorkMsg::AdHoc {
                expr,
                pending,
                strategy,
                backend,
            }) => {
                let t0 = Instant::now();
                let crashed = run_adhoc(ctx, &mut ws, expr, pending, strategy, backend);
                ctx.metrics.note_work_done();
                ctx.metrics.note_exec_time(t0.elapsed());
                crashed
            }
            Ok(WorkMsg::TrainBatch {
                expr,
                policy,
                items,
                strategy,
                backend,
            }) => {
                let t0 = Instant::now();
                let crashed = run_train_batch(ctx, &mut ws, expr, policy, items, strategy, backend);
                ctx.metrics.note_work_done();
                ctx.metrics.note_exec_time(t0.elapsed());
                crashed
            }
            Ok(WorkMsg::Stop) | Err(_) => return WorkerExit::Stop,
        };
        if crashed {
            return WorkerExit::Crashed;
        }
        *consecutive = 0;
    }
}

/// A worker died mid-batch: re-queue each idempotent inference request for
/// a bounded, backed-off retry through the router — or answer
/// `WorkerCrashed` when retries are exhausted or the router is unreachable
/// (`try_send`: a crashed worker never blocks).
fn crash_requeue_evals(ctx: &WorkerCtx, layer: &str, requests: Vec<Pending>, panic_msg: &str) {
    let now = Instant::now();
    for mut p in requests {
        if p.retries < ctx.max_retries {
            p.retries += 1;
            p.not_before = Some(now + Duration::from_millis(1u64 << p.retries.min(6)));
            let id = p.id;
            let msg = Msg::Eval {
                layer: layer.to_string(),
                pending: p,
            };
            match ctx.feedback.try_send(msg) {
                Ok(()) => ctx.metrics.note_retry(),
                Err(_) => {
                    ctx.inflight
                        .fail(id, ServiceError::WorkerCrashed(panic_msg.to_string()));
                }
            }
        } else {
            ctx.inflight
                .fail(p.id, ServiceError::WorkerCrashed(panic_msg.to_string()));
        }
    }
}

/// Ad-hoc variant of [`crash_requeue_evals`].
fn crash_requeue_adhoc(ctx: &WorkerCtx, expr: String, mut p: AdHocPending, panic_msg: &str) {
    if p.retries < ctx.max_retries {
        p.retries += 1;
        p.not_before = Some(Instant::now() + Duration::from_millis(1u64 << p.retries.min(6)));
        let id = p.id;
        match ctx.feedback.try_send(Msg::AdHoc { expr, pending: p }) {
            Ok(()) => ctx.metrics.note_retry(),
            Err(_) => {
                ctx.inflight
                    .fail(id, ServiceError::WorkerCrashed(panic_msg.to_string()));
            }
        }
    } else {
        ctx.inflight
            .fail(p.id, ServiceError::WorkerCrashed(panic_msg.to_string()));
    }
}

/// Execute one inference batch. Three phases, each fault-contained:
/// the injection gate (a panic here models a worker dying before touching
/// any state), the deadline shed, and the guarded execution. Returns
/// `true` if the incarnation must be restarted.
fn run_eval_batch(
    ctx: &WorkerCtx,
    ws: &mut TrainWorkspace,
    stage: &mut Option<Tensor>,
    item: WorkItem,
) -> bool {
    let WorkItem {
        layer,
        plan,
        factors,
        mut requests,
    } = item;
    match catch_unwind(|| crate::faults::point("worker.eval.pre")) {
        Ok(false) => {}
        Ok(true) => {
            for p in requests {
                ctx.inflight.fail(
                    p.id,
                    ServiceError::Engine("injected fault at worker.eval.pre".to_string()),
                );
            }
            return false;
        }
        Err(payload) => {
            let msg = crate::parallel::describe_panic(payload.as_ref());
            crash_requeue_evals(ctx, &layer, requests, &msg);
            return true;
        }
    }
    // Requests that expired while queued or in the worker channel are
    // shed, not executed.
    let now = Instant::now();
    requests.retain(|p| {
        if p.expired(now) {
            ctx.metrics.note_deadline_expired();
            ctx.inflight.fail(p.id, ServiceError::DeadlineExceeded);
            false
        } else {
            true
        }
    });
    if requests.is_empty() {
        return false;
    }
    let sizes: Vec<usize> = requests.iter().map(|p| p.x.shape()[0]).collect();
    let run = || -> InferResult {
        // Concatenate the batch along axis 0 into the reusable staging
        // tensor.
        let bsum: usize = sizes.iter().sum();
        let mut shape = requests[0].x.shape().to_vec();
        shape[0] = bsum;
        let reuse = matches!(&*stage, Some(t) if t.shape() == &shape[..]);
        if !reuse {
            *stage = Some(Tensor::zeros(&shape));
        }
        let x = stage.as_mut().expect("staging tensor present");
        {
            let parts: Vec<&Tensor> = requests.iter().map(|p| &p.x).collect();
            concat_into(&parts, x);
        }
        let x = stage.as_ref().expect("staging tensor present");
        let mut inputs: Vec<&Tensor> = vec![x];
        inputs.extend(factors.iter());
        plan.run(&inputs, ws.base_mut())
            .map_err(|e| ServiceError::Engine(format!("layer '{layer}' failed: {e}")))
    };
    match catch_unwind(AssertUnwindSafe(run)) {
        Ok(Ok(y)) => {
            // Split along axis 0 back to requesters.
            let parts = y.split_axis0(&sizes);
            for (p, part) in requests.into_iter().zip(parts) {
                ctx.inflight.complete_infer(p.id, p.enqueued, Ok(part));
            }
            false
        }
        Ok(Err(e)) => {
            for p in requests {
                ctx.inflight.complete_infer(p.id, p.enqueued, Err(e.clone()));
            }
            false
        }
        Err(payload) => {
            let msg = crate::parallel::describe_panic(payload.as_ref());
            crash_requeue_evals(ctx, &layer, requests, &msg);
            true
        }
    }
}

/// Execute one ad-hoc request (same three-phase structure as
/// [`run_eval_batch`]).
fn run_adhoc(
    ctx: &WorkerCtx,
    ws: &mut TrainWorkspace,
    expr: String,
    pending: AdHocPending,
    strategy: Strategy,
    backend: Backend,
) -> bool {
    match catch_unwind(|| crate::faults::point("worker.adhoc.pre")) {
        Ok(false) => {}
        Ok(true) => {
            ctx.inflight.fail(
                pending.id,
                ServiceError::Engine("injected fault at worker.adhoc.pre".to_string()),
            );
            return false;
        }
        Err(payload) => {
            let msg = crate::parallel::describe_panic(payload.as_ref());
            crash_requeue_adhoc(ctx, expr, pending, &msg);
            return true;
        }
    }
    if pending.expired(Instant::now()) {
        ctx.metrics.note_deadline_expired();
        ctx.inflight.fail(pending.id, ServiceError::DeadlineExceeded);
        return false;
    }
    let result = catch_unwind(AssertUnwindSafe(|| {
        eval_adhoc(&ctx.cache, ws, &expr, &pending.tensors, strategy, backend)
            .map_err(|e| ServiceError::Engine(e.to_string()))
    }));
    match result {
        Ok(r) => {
            ctx.inflight.complete_infer(pending.id, pending.enqueued, r);
            false
        }
        Err(payload) => {
            let msg = crate::parallel::describe_panic(payload.as_ref());
            crash_requeue_adhoc(ctx, expr, pending, &msg);
            true
        }
    }
}

/// Execute one training batch. Training steps are never replayed: on a
/// crash, every request not yet answered fails fast with `WorkerCrashed`
/// (already-completed segments keep their delivered results — per-request
/// isolation).
fn run_train_batch(
    ctx: &WorkerCtx,
    ws: &mut TrainWorkspace,
    expr: String,
    policy: CkptPolicy,
    mut items: Vec<TrainPending>,
    strategy: Strategy,
    backend: Backend,
) -> bool {
    match catch_unwind(|| crate::faults::point("worker.train.pre")) {
        Ok(false) => {}
        Ok(true) => {
            for p in items {
                ctx.inflight.fail(
                    p.id,
                    ServiceError::Engine("injected fault at worker.train.pre".to_string()),
                );
            }
            return false;
        }
        Err(payload) => {
            let msg = crate::parallel::describe_panic(payload.as_ref());
            for p in items {
                ctx.inflight
                    .fail(p.id, ServiceError::WorkerCrashed(msg.clone()));
            }
            return true;
        }
    }
    let now = Instant::now();
    items.retain(|p| {
        if p.expired(now) {
            ctx.metrics.note_deadline_expired();
            ctx.inflight.fail(p.id, ServiceError::DeadlineExceeded);
            false
        } else {
            true
        }
    });
    if items.is_empty() {
        return false;
    }
    // `done` tracks delivery progress across the unwind boundary: segments
    // completed before a panic stay delivered, the rest fail.
    let mut done = 0usize;
    let result = catch_unwind(AssertUnwindSafe(
        || -> std::result::Result<(), ServiceError> {
            let compiled = prepare_train(&ctx.cache, &expr, &items, strategy, backend)
                .map_err(|e| ServiceError::Engine(e.to_string()))?;
            // One layout, one workspace, one segment per request in
            // submission order — the batched replay.
            let layout = compiled.train_layout(policy);
            while done < items.len() {
                let p = &items[done];
                let refs: Vec<&Tensor> = p.tensors.iter().collect();
                let mut out = Tensor::zeros(compiled.out_shape());
                let mut grads: Vec<Tensor> = compiled
                    .in_dims()
                    .iter()
                    .map(|d| Tensor::zeros(d))
                    .collect();
                let res = compiled
                    .train_step(&layout, &refs, &p.dout, ws, &mut out, &mut grads)
                    .map_err(|e| ServiceError::Engine(e.to_string()));
                match res {
                    Ok(()) => {
                        ctx.inflight.complete_train(p.id, p.enqueued, Ok((out, grads)));
                    }
                    Err(e) => {
                        ctx.inflight.complete_train(p.id, p.enqueued, Err(e));
                    }
                }
                done += 1;
            }
            Ok(())
        },
    ));
    match result {
        Ok(Ok(())) => false,
        Ok(Err(e)) => {
            // Whole-batch preparation failed before any segment ran.
            for p in &items[done..] {
                ctx.inflight.fail(p.id, e.clone());
            }
            false
        }
        Err(payload) => {
            let msg = crate::parallel::describe_panic(payload.as_ref());
            for p in &items[done..] {
                ctx.inflight
                    .fail(p.id, ServiceError::WorkerCrashed(msg.clone()));
            }
            true
        }
    }
}

#[cfg(test)]
mod tests;
