//! Persistent measured-cost tuning cache backing [`Strategy::Measured`].
//!
//! The analytic tnn-cost model in [`crate::cost`] ranks candidate
//! contraction trees by multiply count, but FLOPs-optimal is not always
//! wall-clock-optimal under the parallel backend and SIMD-dispatched
//! kernels: parallelizable rows and packing-eligible shapes differ per
//! tree. This module closes the loop. Calibration
//! ([`crate::tune::calibrate_expr`]) times the top-k candidate plans of a
//! geometry on the live worker pool and records each plan's wall-clock
//! here, keyed by the full execution context — expression, input dims,
//! backend, resolved pool width, selected kernel variant, and
//! training/inference mode — so a measurement never leaks across a
//! context where it would not reproduce. Planning with
//! [`Strategy::Measured`] then blends the recorded seconds back into plan
//! ranking via [`blend_scores`]: measured candidates score by their
//! median wall-clock, unmeasured siblings extrapolate through the
//! context's median seconds-per-FLOP ratio, and a context with no
//! measurements at all falls back to analytic FLOPs unchanged.
//!
//! # Persistence
//!
//! The cache serializes through [`crate::util::json`] in the same
//! artifact shape as the `BENCH_*.json` files. When the
//! `CONV_EINSUM_TUNING_CACHE` environment variable ([`TUNING_CACHE_ENV`])
//! names a path, the process-global cache ([`global`]) loads it at first
//! access and calibration passes save back to it. A missing, truncated,
//! or otherwise corrupted cache file never fails planning: loading
//! degrades to an empty cache (analytic-FLOPs behavior) and reports the
//! parse error to the caller of [`TuningCache::load_path`] only.
//!
//! # Generations and staleness
//!
//! Every mutation of the *global* cache (recording a measurement, loading
//! a file, installing a GEMM tuning) bumps a process-wide generation
//! counter ([`generation`]). Plans selected by measurement carry the
//! generation they were scored under
//! ([`crate::planner::Plan::tuning_generation`]);
//! `CompiledPlan::verify()` rejects a measured plan whose stamp no longer
//! matches, and the `PlanCache` key includes the generation so stale
//! measured plans age out instead of being served. Local
//! [`TuningCache`] instances (tests, offline analysis) never touch the
//! generation.
//!
//! # Per-geometry GEMM tunings
//!
//! Besides plan timings the cache carries per-geometry GEMM blocking
//! overrides ([`GemmTuning`]): tuned `kc` depth and packed-path
//! engagement threshold for a specific `(m, n, k)` contraction geometry.
//! Loading the global cache installs them into
//! [`crate::kernels::dispatch`], where kernel resolution
//! ([`crate::kernels::dispatch::resolved_gemm`]) consults them per
//! compiled step; static defaults apply everywhere else.
//!
//! [`Strategy::Measured`]: crate::planner::Strategy::Measured

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::kernels::dispatch;
use crate::util::json::{self, Json};

/// Environment variable naming the persistent tuning-cache file consulted
/// by [`global`] at first access and written by calibration passes.
pub const TUNING_CACHE_ENV: &str = "CONV_EINSUM_TUNING_CACHE";

/// Artifact identifier stored in the cache file's `"kind"` field.
pub const CACHE_KIND: &str = "conv_einsum_tuning_cache";

/// Current cache-file format version.
pub const CACHE_VERSION: u64 = 1;

/// Process-wide tuning generation. Starts at 1 so a stamp of 0 can mean
/// "not a measured plan" in `PlanCache` keys.
static GENERATION: AtomicU64 = AtomicU64::new(1);

/// The current tuning generation: bumped whenever the global cache's
/// contents change. Measured plans are stamped with this value and
/// rejected by `CompiledPlan::verify()` once it moves on.
pub fn generation() -> u64 {
    GENERATION.load(Ordering::SeqCst)
}

fn bump_generation() {
    GENERATION.fetch_add(1, Ordering::SeqCst);
}

/// One calibration measurement for a single candidate plan in a single
/// execution context.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Measurement {
    /// Median wall-clock of one forward replay, seconds.
    pub fwd_secs: f64,
    /// Median wall-clock of one fused train step (forward + backward),
    /// seconds; `None` when calibration only timed inference.
    pub train_secs: Option<f64>,
    /// The plan's analytic cost (training-mode multiplies when the plan
    /// was built for training), kept so seconds-per-FLOP extrapolation
    /// has a denominator.
    pub cost: f64,
}

impl Measurement {
    /// The seconds relevant to a plan of the given mode: train-step time
    /// when available and training, forward time otherwise.
    pub fn secs(&self, training: bool) -> f64 {
        match (training, self.train_secs) {
            (true, Some(t)) => t,
            _ => self.fwd_secs,
        }
    }
}

/// The execution context a measurement is valid for. Any change of pool
/// width, backend, kernel variant, or mode lands in a different context,
/// which is how measured plans re-score instead of replaying stale data.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CalibKey {
    /// Canonical rendered expression.
    pub expr: String,
    /// Input dims, in input order.
    pub dims: Vec<Vec<usize>>,
    /// Backend family name (`"scalar"` / `"parallel"`).
    pub backend: String,
    /// Resolved worker count (1 for scalar; `Parallel { threads: 0 }`
    /// resolves through [`crate::parallel::default_threads`]).
    pub threads: usize,
    /// Selected kernel variant name at key-construction time.
    pub variant: String,
    /// Whether the measurements describe train steps.
    pub training: bool,
}

impl CalibKey {
    /// Build the key for the *current* process state: resolves the live
    /// pool width and the selected kernel variant.
    pub fn current(
        expr: &str,
        dims: &[Vec<usize>],
        backend: crate::exec::Backend,
        training: bool,
    ) -> CalibKey {
        let (backend_name, threads) = match backend {
            crate::exec::Backend::Scalar => ("scalar", 1),
            crate::exec::Backend::Parallel { threads: 0 } => {
                ("parallel", crate::parallel::default_threads())
            }
            crate::exec::Backend::Parallel { threads } => ("parallel", threads),
        };
        CalibKey {
            expr: expr.to_string(),
            dims: dims.to_vec(),
            backend: backend_name.to_string(),
            threads,
            variant: dispatch::selected().variant.name().to_string(),
            training,
        }
    }

    /// Stable string id used as the context key in the cache (and in the
    /// JSON artifact).
    pub fn context_id(&self) -> String {
        let dims = self
            .dims
            .iter()
            .map(|d| {
                d.iter()
                    .map(|x| x.to_string())
                    .collect::<Vec<_>>()
                    .join("x")
            })
            .collect::<Vec<_>>()
            .join(";");
        format!(
            "{} | dims={} | backend={} | threads={} | variant={} | train={}",
            self.expr, dims, self.backend, self.threads, self.variant, self.training
        )
    }
}

/// A per-geometry GEMM blocking override: for contractions of logical
/// shape `m × k · k × n`, use cache-block depth `kc` and engage the packed
/// path at `min_flops` multiplies instead of the static defaults.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmTuning {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    /// Tuned cache-block depth (clamped to ≥ 1 on install).
    pub kc: usize,
    /// Tuned packed-path engagement threshold (`m·n·k` multiplies).
    pub min_flops: usize,
}

impl GemmTuning {
    fn to_dispatch(self) -> ((usize, usize, usize), dispatch::TunedGemm) {
        (
            (self.m, self.n, self.k),
            dispatch::TunedGemm {
                kc: self.kc,
                min_flops: self.min_flops,
            },
        )
    }
}

#[derive(Default)]
struct Inner {
    /// context id → (plan signature → measurement).
    contexts: HashMap<String, HashMap<String, Measurement>>,
    /// Per-geometry GEMM blocking overrides.
    gemm: Vec<GemmTuning>,
}

/// Measured-cost cache: plan wall-clock measurements grouped by execution
/// context, plus per-geometry GEMM tunings. The process-global instance
/// ([`global`]) is the one consulted by planning; constructing local
/// instances is cheap and never touches global state.
#[derive(Default)]
pub struct TuningCache {
    inner: Mutex<Inner>,
}

static GLOBAL: OnceLock<TuningCache> = OnceLock::new();
static GLOBAL_LOADED: OnceLock<()> = OnceLock::new();

/// The process-global tuning cache. On first access, loads the file named
/// by [`TUNING_CACHE_ENV`] if set; a missing or corrupted file silently
/// yields an empty cache (planning falls back to analytic FLOPs).
pub fn global() -> &'static TuningCache {
    let cache = GLOBAL.get_or_init(TuningCache::default);
    GLOBAL_LOADED.get_or_init(|| {
        if let Some(path) = env_path() {
            let _ = cache.load_path(&path);
        }
    });
    cache
}

/// The configured persistent cache path, if any.
pub fn env_path() -> Option<String> {
    match std::env::var(TUNING_CACHE_ENV) {
        Ok(p) if !p.trim().is_empty() => Some(p),
        _ => None,
    }
}

impl TuningCache {
    pub fn new() -> TuningCache {
        TuningCache::default()
    }

    /// Whether this is the process-global instance (only the global
    /// instance bumps the tuning generation or installs GEMM tunings
    /// into the kernel dispatcher).
    fn is_global(&self) -> bool {
        GLOBAL.get().is_some_and(|g| std::ptr::eq(g, self))
    }

    /// Record one candidate measurement under a context.
    pub fn record(&self, ctx_id: &str, signature: &str, m: Measurement) {
        {
            let mut inner = self.inner.lock().unwrap();
            inner
                .contexts
                .entry(ctx_id.to_string())
                .or_default()
                .insert(signature.to_string(), m);
        }
        if self.is_global() {
            bump_generation();
        }
    }

    /// All measurements recorded under a context (empty on miss).
    pub fn measurements(&self, ctx_id: &str) -> HashMap<String, Measurement> {
        let inner = self.inner.lock().unwrap();
        inner.contexts.get(ctx_id).cloned().unwrap_or_default()
    }

    /// One measurement, if present.
    pub fn lookup(&self, ctx_id: &str, signature: &str) -> Option<Measurement> {
        let inner = self.inner.lock().unwrap();
        inner.contexts.get(ctx_id)?.get(signature).copied()
    }

    /// Number of contexts with at least one measurement.
    pub fn context_count(&self) -> usize {
        self.inner.lock().unwrap().contexts.len()
    }

    /// Total measurement count across contexts.
    pub fn len(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner.contexts.values().map(|c| c.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0 && self.inner.lock().unwrap().gemm.is_empty()
    }

    /// Install (or replace) a per-geometry GEMM tuning. On the global
    /// cache this also pushes the tuning into the kernel dispatcher and
    /// bumps the generation (plans compiled under the old blocking are
    /// stale: engagement thresholds affect which kernel path runs).
    pub fn set_gemm_tuning(&self, t: GemmTuning) {
        {
            let mut inner = self.inner.lock().unwrap();
            match inner
                .gemm
                .iter_mut()
                .find(|g| (g.m, g.n, g.k) == (t.m, t.n, t.k))
            {
                Some(slot) => *slot = t,
                None => inner.gemm.push(t),
            }
        }
        if self.is_global() {
            dispatch::set_gemm_tunings(&[t.to_dispatch()]);
            bump_generation();
        }
    }

    /// The recorded GEMM tunings.
    pub fn gemm_tunings(&self) -> Vec<GemmTuning> {
        self.inner.lock().unwrap().gemm.clone()
    }

    /// Drop all contents. The global cache also clears the dispatcher's
    /// tuned-geometry registry and bumps the generation.
    pub fn clear(&self) {
        {
            let mut inner = self.inner.lock().unwrap();
            inner.contexts.clear();
            inner.gemm.clear();
        }
        if self.is_global() {
            dispatch::clear_gemm_tunings();
            bump_generation();
        }
    }

    /// Serialize to the `BENCH_*.json`-shaped artifact.
    pub fn to_json(&self) -> Json {
        let inner = self.inner.lock().unwrap();
        let mut contexts = BTreeMap::new();
        for (ctx, sigs) in &inner.contexts {
            let mut entries = BTreeMap::new();
            for (sig, m) in sigs {
                entries.insert(
                    sig.clone(),
                    Json::obj(vec![
                        ("fwd_secs", Json::num(m.fwd_secs)),
                        (
                            "train_secs",
                            m.train_secs.map(Json::num).unwrap_or(Json::Null),
                        ),
                        ("cost", Json::num(m.cost)),
                    ]),
                );
            }
            contexts.insert(ctx.clone(), Json::Obj(entries));
        }
        let gemm: Vec<Json> = inner
            .gemm
            .iter()
            .map(|t| {
                Json::obj(vec![
                    ("m", Json::num(t.m as f64)),
                    ("n", Json::num(t.n as f64)),
                    ("k", Json::num(t.k as f64)),
                    ("kc", Json::num(t.kc as f64)),
                    ("min_flops", Json::num(t.min_flops as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("kind", Json::str(CACHE_KIND)),
            ("version", Json::num(CACHE_VERSION as f64)),
            ("contexts", Json::Obj(contexts)),
            ("gemm", Json::Arr(gemm)),
        ])
    }

    /// Merge a parsed artifact into this cache. Tolerant of partially
    /// malformed entries (they are skipped); errors only on a value that
    /// is not a cache object at all. Returns the number of measurements
    /// merged. On the global cache, installs GEMM tunings into the
    /// dispatcher and bumps the generation once.
    pub fn load_json(&self, v: &Json) -> Result<usize, String> {
        let obj = v.as_obj().ok_or("tuning cache: top level is not an object")?;
        if let Some(kind) = obj.get("kind").and_then(|k| k.as_str()) {
            if kind != CACHE_KIND {
                return Err(format!("tuning cache: unexpected kind '{kind}'"));
            }
        }
        let mut loaded = 0usize;
        let mut tunings: Vec<GemmTuning> = Vec::new();
        {
            let mut inner = self.inner.lock().unwrap();
            if let Some(contexts) = obj.get("contexts").and_then(|c| c.as_obj()) {
                for (ctx, sigs) in contexts {
                    let Some(sigs) = sigs.as_obj() else { continue };
                    for (sig, entry) in sigs {
                        let (Some(fwd), Some(cost)) = (
                            entry.get("fwd_secs").and_then(|x| x.as_f64()),
                            entry.get("cost").and_then(|x| x.as_f64()),
                        ) else {
                            continue; // malformed entry: skip, don't fail
                        };
                        let train_secs = entry.get("train_secs").and_then(|x| x.as_f64());
                        inner.contexts.entry(ctx.clone()).or_default().insert(
                            sig.clone(),
                            Measurement {
                                fwd_secs: fwd,
                                train_secs,
                                cost,
                            },
                        );
                        loaded += 1;
                    }
                }
            }
            if let Some(gemm) = obj.get("gemm").and_then(|g| g.as_arr()) {
                for entry in gemm {
                    let fields = ["m", "n", "k", "kc", "min_flops"]
                        .map(|f| entry.get(f).and_then(|x| x.as_usize()));
                    let [Some(m), Some(n), Some(k), Some(kc), Some(min_flops)] = fields else {
                        continue; // malformed entry: skip
                    };
                    let t = GemmTuning {
                        m,
                        n,
                        k,
                        kc,
                        min_flops,
                    };
                    match inner
                        .gemm
                        .iter_mut()
                        .find(|g| (g.m, g.n, g.k) == (t.m, t.n, t.k))
                    {
                        Some(slot) => *slot = t,
                        None => inner.gemm.push(t),
                    }
                    tunings.push(t);
                }
            }
        }
        if self.is_global() {
            if !tunings.is_empty() {
                let converted: Vec<_> = tunings.iter().map(|t| t.to_dispatch()).collect();
                dispatch::set_gemm_tunings(&converted);
            }
            if loaded > 0 || !tunings.is_empty() {
                bump_generation();
            }
        }
        Ok(loaded)
    }

    /// Load a cache file. A missing or unparseable file returns `Err` and
    /// leaves the cache unchanged — callers fall back to analytic FLOPs.
    pub fn load_path(&self, path: &str) -> Result<usize, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("tuning cache: cannot read {path}: {e}"))?;
        let v = json::parse(&text).map_err(|e| format!("tuning cache: {path}: {e}"))?;
        self.load_json(&v)
    }

    /// Write the cache artifact to a file (pretty-printed, deterministic
    /// key order).
    pub fn save_to(&self, path: &str) -> Result<(), String> {
        std::fs::write(path, self.to_json().encode_pretty())
            .map_err(|e| format!("tuning cache: cannot write {path}: {e}"))
    }
}

/// Blend measured data into candidate ranking.
///
/// `candidates` carries `(signature, analytic cost)` per candidate plan,
/// FLOPs-ascending with the canonical FLOPs-best first. Returns one score
/// per candidate, lower is better:
///
/// * no measurement in this context at all → the analytic costs,
///   unchanged (pure-FLOPs fallback, so ranking is exactly the analytic
///   ranking);
/// * otherwise → seconds: a measured candidate scores its recorded
///   wall-clock, an unmeasured one extrapolates `cost × median
///   seconds-per-FLOP` over the measured siblings.
pub fn blend_scores(
    candidates: &[(String, f64)],
    measured: &HashMap<String, Measurement>,
    training: bool,
) -> Vec<f64> {
    let mut ratios: Vec<f64> = candidates
        .iter()
        .filter_map(|(sig, cost)| measured.get(sig).map(|m| m.secs(training) / cost.max(1.0)))
        .collect();
    if ratios.is_empty() {
        return candidates.iter().map(|(_, c)| *c).collect();
    }
    ratios.sort_by(|a, b| a.total_cmp(b));
    let secs_per_flop = ratios[ratios.len() / 2];
    candidates
        .iter()
        .map(|(sig, cost)| match measured.get(sig) {
            Some(m) => m.secs(training),
            None => cost.max(1.0) * secs_per_flop,
        })
        .collect()
}

/// Index of the best (lowest) score; ties resolve to the earliest
/// candidate, which keeps selection deterministic and biased toward the
/// FLOPs-best tree.
pub fn select_index(scores: &[f64]) -> usize {
    let mut best = 0usize;
    for (i, s) in scores.iter().enumerate().skip(1) {
        if s.total_cmp(&scores[best]) == std::cmp::Ordering::Less {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(fwd: f64, train: Option<f64>, cost: f64) -> Measurement {
        Measurement {
            fwd_secs: fwd,
            train_secs: train,
            cost,
        }
    }

    #[test]
    fn blend_empty_context_is_pure_flops() {
        let cands = vec![("a".to_string(), 100.0), ("b".to_string(), 200.0)];
        let scores = blend_scores(&cands, &HashMap::new(), false);
        assert_eq!(scores, vec![100.0, 200.0]);
        assert_eq!(select_index(&scores), 0);
    }

    #[test]
    fn blend_prefers_measured_wall_clock_over_flops() {
        // FLOPs say `a` (cheaper); measurement says `b` is faster.
        let cands = vec![("a".to_string(), 100.0), ("b".to_string(), 100.0)];
        let mut measured = HashMap::new();
        measured.insert("a".to_string(), m(2e-3, None, 100.0));
        measured.insert("b".to_string(), m(1e-3, None, 100.0));
        let scores = blend_scores(&cands, &measured, false);
        assert_eq!(select_index(&scores), 1);
    }

    #[test]
    fn blend_extrapolates_unmeasured_by_secs_per_flop() {
        let cands = vec![("a".to_string(), 100.0), ("b".to_string(), 400.0)];
        let mut measured = HashMap::new();
        measured.insert("a".to_string(), m(1e-3, None, 100.0)); // 1e-5 s/flop
        let scores = blend_scores(&cands, &measured, false);
        assert!((scores[0] - 1e-3).abs() < 1e-12);
        assert!((scores[1] - 4e-3).abs() < 1e-12);
    }

    #[test]
    fn blend_uses_train_secs_in_training_mode() {
        let cands = vec![("a".to_string(), 100.0), ("b".to_string(), 100.0)];
        let mut measured = HashMap::new();
        // Forward prefers `a`, train step prefers `b`.
        measured.insert("a".to_string(), m(1e-3, Some(9e-3), 100.0));
        measured.insert("b".to_string(), m(2e-3, Some(3e-3), 100.0));
        assert_eq!(select_index(&blend_scores(&cands, &measured, false)), 0);
        assert_eq!(select_index(&blend_scores(&cands, &measured, true)), 1);
    }

    #[test]
    fn select_index_ties_break_to_first() {
        assert_eq!(select_index(&[1.0, 1.0, 0.5, 0.5]), 2);
        assert_eq!(select_index(&[1.0, 1.0]), 0);
    }

    #[test]
    fn calib_key_resolves_scalar_threads_to_one() {
        let k = CalibKey::current(
            "ij,jk->ik",
            &[vec![2, 3], vec![3, 4]],
            crate::exec::Backend::Scalar,
            false,
        );
        assert_eq!(k.threads, 1);
        assert!(k.context_id().contains("backend=scalar"));
        assert!(k.context_id().contains("dims=2x3;3x4"));
    }

    #[test]
    fn local_cache_round_trips_and_never_touches_generation() {
        let g0 = generation();
        let cache = TuningCache::new();
        cache.record("ctx", "sig-a", m(1e-3, Some(3e-3), 42.0));
        cache.record("ctx", "sig-b", m(2e-3, None, 84.0));
        cache.set_gemm_tuning(GemmTuning {
            m: 8,
            n: 512,
            k: 256,
            kc: 128,
            min_flops: 1 << 12,
        });
        let text = cache.to_json().encode_pretty();
        let back = TuningCache::new();
        let n = back.load_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(n, 2);
        assert_eq!(back.lookup("ctx", "sig-a"), cache.lookup("ctx", "sig-a"));
        assert_eq!(back.lookup("ctx", "sig-b"), cache.lookup("ctx", "sig-b"));
        assert_eq!(back.gemm_tunings(), cache.gemm_tunings());
        assert_eq!(generation(), g0, "local caches must not bump the generation");
    }

    #[test]
    fn corrupted_artifacts_are_rejected_without_panicking() {
        let cache = TuningCache::new();
        assert!(cache.load_json(&Json::Num(3.0)).is_err());
        assert!(cache
            .load_json(&Json::obj(vec![("kind", Json::str("other"))]))
            .is_err());
        assert!(cache.load_path("/nonexistent/tuning.json").is_err());
        assert!(cache.is_empty());
        // Malformed sub-entries are skipped, well-formed ones load.
        let mixed = Json::obj(vec![
            ("kind", Json::str(CACHE_KIND)),
            (
                "contexts",
                Json::obj(vec![(
                    "ctx",
                    Json::obj(vec![
                        ("bad", Json::obj(vec![("fwd_secs", Json::str("oops"))])),
                        (
                            "good",
                            Json::obj(vec![
                                ("fwd_secs", Json::num(1e-3)),
                                ("cost", Json::num(10.0)),
                            ]),
                        ),
                    ]),
                )]),
            ),
            ("gemm", Json::arr(vec![Json::str("not-a-tuning")])),
        ]);
        assert_eq!(cache.load_json(&mixed).unwrap(), 1);
        assert!(cache.lookup("ctx", "good").is_some());
        assert!(cache.lookup("ctx", "bad").is_none());
    }
}
