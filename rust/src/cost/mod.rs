//! The tnn-cost model (paper Appendix B).
//!
//! FLOPs (multiplication counts) of the primitive operations, Eq. (5)–(8):
//!
//! * mode-(k,l) contraction / batch product:  (∏ᵖ Iₚ)(∏_{q≠l} J_q)
//! * outer product:                            (∏ᵖ Iₚ)(∏ᑫ J_q)
//! * mode-(k,l) convolution (no FFT):          (∏ᵖ Iₚ)(∏ᑫ J_q)
//!
//! For the generalized pairwise atom with merged groups G (batch), T/N
//! (free), S (contraction) and conv axes (Iₐ, I_b) these collapse to
//!
//! ```text
//!   mults(f)  = G · T · N · S · ∏_c Iₐᶜ · I_bᶜ
//! ```
//!
//! Training mode ("Modification of the cost model for training") adds the
//! two backward computations `g1 = ∂L/∂a`, `g2 = ∂L/∂b`, each a pairwise op
//! against the cotangent whose conv axes pair the *output* size I_oᶜ with
//! the other operand's size:
//!
//! ```text
//!   mults(g1) = G · T · N · S · ∏_c I_oᶜ · I_bᶜ
//!   mults(g2) = G · T · N · S · ∏_c I_oᶜ · Iₐᶜ
//! ```
//!
//! which reproduces the paper's standard-conv2d example
//! (`cost(f)=O(BHWXYTS)`, `cost(g1)=O(BHWX'Y'TS)`, `cost(g2)=O(BXYX'Y'TS)`).
//!
//! # Autotuning
//!
//! Analytic multiply counts are the planner's *default* ranking, not its
//! only one. The [`tuning`] submodule holds the persistent measured-cost
//! cache behind `Strategy::Measured`: calibration times candidate plans
//! on the live pool, records wall-clock per execution context
//! (expression, dims, backend, pool width, kernel variant, mode), and
//! [`tuning::blend_scores`] folds those seconds back into plan ranking —
//! falling back to the analytic FLOPs here whenever a context has no
//! measurements.

use crate::einsum::{ConvKind, SizedSpec};

pub mod tuning;

/// The merged dimension groups of one pairwise operation — everything the
/// cost model needs to price it.
#[derive(Debug, Clone, PartialEq)]
pub struct MergeDims {
    /// Product of batch-product mode sizes (shared, kept).
    pub g: f64,
    /// Product of lhs-only kept mode sizes.
    pub t: f64,
    /// Product of rhs-only kept mode sizes.
    pub n: f64,
    /// Product of contraction mode sizes (shared, dropped).
    pub s: f64,
    /// Product of self-sum mode sizes (summed in preprocessing; prices the
    /// pre-pass, not the atom itself).
    pub presum: f64,
    /// Per shared conv mode: (lhs size, rhs size, output size).
    pub conv: Vec<(f64, f64, f64)>,
}

impl MergeDims {
    /// Multiplications of the forward pairwise op, Eq. (5)–(8).
    pub fn fwd_mults(&self) -> f64 {
        let conv: f64 = self.conv.iter().map(|&(ia, ib, _)| ia * ib).product();
        self.g * self.t * self.n * self.s * conv
    }

    /// Multiplications of `g1 = ∂L/∂lhs`.
    pub fn g1_mults(&self) -> f64 {
        let conv: f64 = self.conv.iter().map(|&(_, ib, io)| io * ib).product();
        self.g * self.t * self.n * self.s * conv
    }

    /// Multiplications of `g2 = ∂L/∂rhs`.
    pub fn g2_mults(&self) -> f64 {
        let conv: f64 = self.conv.iter().map(|&(ia, _, io)| io * ia).product();
        self.g * self.t * self.n * self.s * conv
    }

    /// Total training-mode cost: `cost(f) + cost(g1) + cost(g2)`.
    pub fn training_mults(&self) -> f64 {
        self.fwd_mults() + self.g1_mults() + self.g2_mults()
    }

    /// Cost under the given mode (forward-only vs training).
    pub fn mults(&self, training: bool) -> f64 {
        if training {
            self.training_mults()
        } else {
            self.fwd_mults()
        }
    }

    /// Elements of the pairwise output.
    pub fn out_elems(&self) -> f64 {
        let conv: f64 = self.conv.iter().map(|&(_, _, io)| io).product();
        self.g * self.t * self.n * conv
    }
}

/// Output size of a pairwise convolution along one mode.
///
/// `modulus` is the circular wrap length (the feature size of the *whole*
/// expression for multi-way convolutions); `None` defaults to `max(ia, ib)`.
pub fn conv_out_size(kind: ConvKind, ia: usize, ib: usize, modulus: Option<usize>) -> usize {
    match kind {
        ConvKind::Circular => {
            let p = modulus.unwrap_or(ia.max(ib));
            (ia + ib - 1).min(p)
        }
        _ => kind.out_dim(ia, ib),
    }
}

/// Analyze a 2-input sized spec into its [`MergeDims`] (shape-only twin of
/// `exec::atom::canonicalize` — no triple tables, cheap enough for the
/// planner's inner loop).
pub fn analyze_pairwise(sized: &SizedSpec, moduli: &[Option<usize>]) -> MergeDims {
    assert_eq!(sized.spec.n_inputs(), 2);
    let spec = &sized.spec;
    let ma = &spec.inputs[0];
    let mb = &spec.inputs[1];
    let size_a = |m| sized.dims[0][ma.iter().position(|&x| x == m).unwrap()];
    let size_b = |m| sized.dims[1][mb.iter().position(|&x| x == m).unwrap()];

    let mut dims = MergeDims {
        g: 1.0,
        t: 1.0,
        n: 1.0,
        s: 1.0,
        presum: 1.0,
        conv: Vec::new(),
    };
    let mut seen = std::collections::HashSet::new();
    for &m in ma.iter().chain(mb.iter()) {
        if !seen.insert(m) {
            continue;
        }
        let in_a = ma.contains(&m);
        let in_b = mb.contains(&m);
        let in_out = spec.output.contains(&m);
        if spec.is_conv(m) && in_a && in_b {
            let pipe = spec.conv.iter().position(|&x| x == m).unwrap();
            let kind = sized.conv_kinds[pipe];
            let modulus = moduli.get(pipe).copied().flatten();
            let (ia, ib) = (size_a(m), size_b(m));
            let io = conv_out_size(kind, ia, ib, modulus);
            dims.conv.push((ia as f64, ib as f64, io as f64));
        } else {
            match (in_a, in_b, in_out) {
                (true, true, true) => dims.g *= size_a(m) as f64,
                (true, true, false) => dims.s *= size_a(m) as f64,
                (true, false, true) => dims.t *= size_a(m) as f64,
                (false, true, true) => dims.n *= size_b(m) as f64,
                (true, false, false) => dims.presum *= size_a(m) as f64,
                (false, true, false) => dims.presum *= size_b(m) as f64,
                (false, false, _) => unreachable!(),
            }
        }
    }
    dims
}

/// The "flat" cost of evaluating an N-input expression in a single nested
/// loop (what opt-einsum reports as the *naive FLOP count*): the product of
/// every distinct index range, counting each conv mode once per occurrence,
/// times one multiplication per input.
pub fn flat_cost(sized: &SizedSpec) -> f64 {
    let spec = &sized.spec;
    let mut loops = 1.0f64;
    for m in spec.all_modes() {
        if spec.is_conv(m) {
            for sz in sized.occurrence_sizes(m) {
                loops *= sz as f64;
            }
        } else {
            loops *= sized.mode_size(m) as f64;
        }
    }
    loops * (spec.n_inputs().max(2) - 1) as f64
}

/// Bytes of one f32 tensor of `elems` elements.
pub fn elems_to_bytes(elems: f64) -> f64 {
    elems * 4.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::parse;

    fn sized(expr: &str, dims: Vec<Vec<usize>>) -> SizedSpec {
        SizedSpec::new(parse(expr).unwrap(), dims).unwrap()
    }

    #[test]
    fn contraction_cost_matches_eq5() {
        // mode-(k,l) contraction of A∈R^{2×3×4}, B∈R^{4×5}:
        // cost = (2·3·4)·(5) = 120
        let s = sized("abc,cd->abd", vec![vec![2, 3, 4], vec![4, 5]]);
        let d = analyze_pairwise(&s, &[]);
        assert_eq!(d.fwd_mults(), 120.0);
        assert_eq!(d.out_elems(), 30.0);
    }

    #[test]
    fn outer_product_cost_matches_eq7() {
        let s = sized("ab,cd->abcd", vec![vec![2, 3], vec![4, 5]]);
        let d = analyze_pairwise(&s, &[]);
        assert_eq!(d.fwd_mults(), 120.0); // ∏I · ∏J
        assert_eq!(d.out_elems(), 120.0);
    }

    #[test]
    fn batch_product_cost_matches_eq6() {
        // batch over shared mode a (kept): cost = ∏I · ∏J / |a| = 2·3·4·5
        let s = sized("ab,acd->abcd", vec![vec![2, 3], vec![2, 4, 5]]);
        let d = analyze_pairwise(&s, &[]);
        assert_eq!(d.fwd_mults(), 120.0);
        assert_eq!(d.g, 2.0);
    }

    #[test]
    fn convolution_cost_matches_eq8() {
        // conv between X (len 10) and L (len 4): all dims of both multiply.
        let s = sized("xbc,xde->xbcde|x", vec![vec![10, 2, 3], vec![4, 5, 6]]);
        let d = analyze_pairwise(&s, &[]);
        assert_eq!(d.fwd_mults(), (10.0 * 2.0 * 3.0) * (4.0 * 5.0 * 6.0));
        assert_eq!(d.conv.len(), 1);
    }

    #[test]
    fn standard_conv2d_training_cost_matches_paper_example() {
        // f: input (B,S,X,Y) ⊛ weight (T,S,H,W) → (B,T,X',Y'), Same pad.
        // The paper writes the layer as "bshw,tshw->bthw|hw": the conv
        // letters are shared between feature (X,Y) and filter (H,W) sizes.
        let (b, s, x, y, t, h, w) = (2, 3, 16, 16, 4, 3, 3);
        let sz = sized(
            "bsxy,tsxy->btxy|xy",
            vec![vec![b, s, x, y], vec![t, s, h, w]],
        );
        let d = analyze_pairwise(&sz, &[]);
        let bf = (b * s * t) as f64;
        assert_eq!(d.fwd_mults(), bf * (x * y * h * w) as f64); // O(BHWXYTS)
        // Same padding ⇒ X' = X, Y' = Y.
        assert_eq!(d.g1_mults(), bf * (x * y * h * w) as f64); // O(BHWX'Y'TS)
        assert_eq!(d.g2_mults(), bf * (x * y * x * y) as f64); // O(BXYX'Y'TS)
        assert_eq!(d.training_mults(), d.fwd_mults() + d.g1_mults() + d.g2_mults());
    }

    #[test]
    fn conv_out_sizes() {
        assert_eq!(conv_out_size(ConvKind::Circular, 8, 3, None), 8);
        assert_eq!(conv_out_size(ConvKind::Circular, 3, 4, Some(32)), 6);
        assert_eq!(conv_out_size(ConvKind::Circular, 30, 4, Some(32)), 32);
        assert_eq!(conv_out_size(ConvKind::Full, 8, 3, None), 10);
        assert_eq!(conv_out_size(ConvKind::Valid, 8, 3, None), 6);
        assert_eq!(conv_out_size(ConvKind::Same, 8, 3, None), 8);
    }

    #[test]
    fn selfsum_tracked_separately() {
        let s = sized("ak,ab->b", vec![vec![2, 5], vec![2, 3]]);
        let d = analyze_pairwise(&s, &[]);
        assert_eq!(d.presum, 5.0);
        assert_eq!(d.s, 2.0); // a contracted
        assert_eq!(d.n, 3.0);
        assert_eq!(d.fwd_mults(), 6.0);
    }

    #[test]
    fn flat_cost_counts_all_loops() {
        // "ij,jk->ik" with i=2,j=3,k=4: 2·3·4 · (2-1) = 24
        let s = sized("ij,jk->ik", vec![vec![2, 3], vec![3, 4]]);
        assert_eq!(flat_cost(&s), 24.0);
        // conv modes count once per occurrence
        let c = sized("xa,xb->xab|x", vec![vec![8, 2], vec![3, 4]]);
        assert_eq!(flat_cost(&c), (8 * 2 * 3 * 4) as f64);
    }
}
