//! Calibration driver for the measured-cost planner.
//!
//! [`calibrate_expr`] runs the plan tournament that backs
//! [`Strategy::Measured`](crate::planner::Strategy::Measured): it
//! enumerates the planner's candidate contraction trees
//! ([`crate::planner::candidate_plans`]), compiles each one, times
//! forward — and, for training contexts, fused train-step — replays on
//! the live backend via [`crate::util::timing`], and records the
//! wall-clock measurements in the global
//! [`crate::cost::tuning::TuningCache`]. Subsequent
//! `Strategy::Measured` compiles for the same execution context
//! (expression, shapes, backend, pool width, kernel variant, training
//! mode) rank candidates by these measurements instead of analytic
//! FLOPs.
//!
//! Recording happens *after* every candidate has been timed, so the
//! tuning generation bumps once per calibrated context-batch rather
//! than mid-tournament; the candidates compiled here carry no
//! generation stamp ([`crate::planner::Plan::tuning_generation`] is `None` for
//! non-measured planning) and stay valid throughout.
//!
//! The driver lives outside the replay hot path: calibration allocates
//! freely (workspaces, probe tensors, report strings) and is expected
//! to run at service warm-up or from an explicit tuning pass — see
//! `EvalService::calibrate_registered` for the coordinator entry point.

use std::sync::Arc;

use crate::autodiff::CkptPolicy;
use crate::cost::tuning::{self, CalibKey, GemmTuning, Measurement};
use crate::einsum::{parse, SizedSpec};
use crate::exec::{CompiledPlan, TrainWorkspace, Workspace};
use crate::kernels::dispatch::{self, TunedGemm};
use crate::planner::{candidate_plans, PlanOptions, DEFAULT_MEASURED_TOP_K};
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::timing;

/// Knobs for one calibration pass. `Default` is sized for service
/// warm-up: a handful of iterations per candidate, persisted to the
/// `CONV_EINSUM_TUNING_CACHE` path when one is configured.
#[derive(Debug, Clone)]
pub struct CalibrationSpec {
    /// How many FLOPs-ranked trees to enumerate (each bit-compatible
    /// orientation mirror rides along, so up to `2 * top_k` candidates
    /// are timed).
    pub top_k: usize,
    /// Warm-up replays per candidate (excluded from the measurement;
    /// grows workspaces so the timed replays are steady-state).
    pub warmup: usize,
    /// Timed replays per candidate (the median is recorded).
    pub iters: usize,
    /// Persist the global cache to the `CONV_EINSUM_TUNING_CACHE` path
    /// after recording (no-op when the variable is unset). Leave off
    /// for probe runs that must not overwrite a pinned artifact.
    pub persist: bool,
    /// Seed for the deterministic probe tensors.
    pub seed: u64,
}

impl Default for CalibrationSpec {
    fn default() -> Self {
        CalibrationSpec {
            top_k: DEFAULT_MEASURED_TOP_K,
            warmup: 2,
            iters: 7,
            persist: true,
            seed: 0x5EED_CA11,
        }
    }
}

/// Timing record for one tournament candidate.
#[derive(Debug, Clone)]
pub struct CandidateTiming {
    /// Structural signature ([`crate::planner::Plan::signature`]) — the
    /// measurement key.
    pub signature: String,
    /// Analytic cost (FLOPs) of the candidate.
    pub cost: f64,
    /// Median forward replay wall-clock, seconds.
    pub fwd_secs: f64,
    /// Median fused train-step wall-clock, seconds (training contexts).
    pub train_secs: Option<f64>,
}

/// Outcome of one [`calibrate_expr`] pass.
#[derive(Debug, Clone)]
pub struct CalibrationReport {
    /// The execution context the measurements were recorded under.
    pub context_id: String,
    /// Per-candidate timings, in tournament order (FLOPs-ascending,
    /// canonical tree before its mirror) — index 0 is the plan the
    /// analytic ranking would pick.
    pub candidates: Vec<CandidateTiming>,
    /// Index of the wall-clock winner in `candidates`.
    pub best: usize,
    /// Cache path the measurements were persisted to, when any.
    pub saved: Option<String>,
}

impl CalibrationReport {
    /// Seconds the measured winner saves per replay over the analytic
    /// (FLOPs-best) choice; `0.0` when the analytic choice wins.
    pub fn secs_saved(&self) -> f64 {
        let secs = |c: &CandidateTiming| c.train_secs.unwrap_or(c.fwd_secs);
        (secs(&self.candidates[0]) - secs(&self.candidates[self.best])).max(0.0)
    }

    /// The report as a JSON object (the `BENCH_planner.json` row shape).
    pub fn to_json(&self) -> Json {
        let candidates = self.candidates.iter().map(|c| {
            let mut fields = vec![
                ("signature", Json::str(&c.signature)),
                ("cost", Json::num(c.cost)),
                ("fwd_secs", Json::num(c.fwd_secs)),
            ];
            if let Some(t) = c.train_secs {
                fields.push(("train_secs", Json::num(t)));
            }
            Json::obj(fields)
        });
        let mut fields = vec![
            ("context", Json::str(&self.context_id)),
            ("candidates", Json::arr(candidates)),
            ("best", Json::num(self.best as f64)),
            ("secs_saved", Json::num(self.secs_saved())),
        ];
        if let Some(p) = &self.saved {
            fields.push(("saved", Json::str(p)));
        }
        Json::obj(fields)
    }
}

/// Time one compiled candidate: median forward replay seconds, plus
/// median fused train-step seconds when `training`.
fn time_candidate(
    compiled: &CompiledPlan,
    inputs: &[&Tensor],
    training: bool,
    spec: &CalibrationSpec,
) -> Result<(f64, Option<f64>), String> {
    let mut ws = Workspace::new();
    let mut out = Tensor::zeros(compiled.out_shape());
    // Validate once outside the timer so replay errors surface as errors
    // rather than poisoning the measurement.
    compiled
        .run_into(inputs, &mut ws, &mut out)
        .map_err(|e| format!("calibration forward failed: {e}"))?;
    let mut failed = false;
    let fwd = timing::bench("calib-fwd", spec.warmup, spec.iters.max(1), || {
        failed |= compiled.run_into(inputs, &mut ws, &mut out).is_err();
    });
    if failed {
        return Err("calibration forward failed during timing".to_string());
    }
    let fwd_secs = fwd.median_secs();

    if !training {
        return Ok((fwd_secs, None));
    }
    let layout = compiled.train_layout(CkptPolicy::StoreAll);
    let mut tws = TrainWorkspace::new();
    let dout = Tensor::zeros(compiled.out_shape());
    let mut grads: Vec<Tensor> = compiled
        .in_dims()
        .iter()
        .map(|d| Tensor::zeros(d))
        .collect();
    compiled
        .train_step(&layout, inputs, &dout, &mut tws, &mut out, &mut grads)
        .map_err(|e| format!("calibration train step failed: {e}"))?;
    let mut failed = false;
    let train = timing::bench("calib-train", spec.warmup, spec.iters.max(1), || {
        failed |= compiled
            .train_step(&layout, inputs, &dout, &mut tws, &mut out, &mut grads)
            .is_err();
    });
    if failed {
        return Err("calibration train step failed during timing".to_string());
    }
    Ok((fwd_secs, Some(train.median_secs())))
}

/// Run the plan tournament for `expr` at these shapes and record the
/// measurements in the global tuning cache.
///
/// Every candidate of [`candidate_plans`] (the exact set a later
/// `Strategy::Measured` compile will rank) is compiled and timed on the
/// backend in `opts`; `opts.training` selects whether fused train-step
/// replays are timed alongside forwards, and is baked into the context
/// key, so calibrate with the same `training` flag the serving path
/// will plan with. Returns the per-candidate report; measurements are
/// visible to planners as soon as this returns (the tuning generation
/// has bumped, so previously compiled *measured* plans re-verify as
/// stale and recompile via their `PlanCache`).
pub fn calibrate_expr(
    expr: &str,
    dims: &[Vec<usize>],
    opts: &PlanOptions,
    spec: &CalibrationSpec,
) -> Result<CalibrationReport, String> {
    let parsed = parse(expr).map_err(|e| e.to_string())?;
    let sized = SizedSpec::new(parsed, dims.to_vec())?;
    let plans = candidate_plans(&sized, opts, spec.top_k)?;

    let mut compiled: Vec<CompiledPlan> = Vec::with_capacity(plans.len());
    for plan in &plans {
        compiled.push(
            CompiledPlan::compile_arc(Arc::new(plan.clone()))
                .map_err(|e| format!("calibration compile failed: {e}"))?,
        );
    }

    let mut rng = Rng::new(spec.seed);
    let probes: Vec<Tensor> = dims
        .iter()
        .map(|d| Tensor::rand(d, -1.0, 1.0, &mut rng))
        .collect();
    let inputs: Vec<&Tensor> = probes.iter().collect();

    let mut candidates = Vec::with_capacity(plans.len());
    for (plan, cp) in plans.iter().zip(&compiled) {
        let (fwd_secs, train_secs) = time_candidate(cp, &inputs, opts.training, spec)?;
        candidates.push(CandidateTiming {
            signature: plan.signature(),
            cost: plan.cost,
            fwd_secs,
            train_secs,
        });
    }

    // Record everything at once: the generation bumps per measurement,
    // but no measured plan was compiled mid-tournament to invalidate.
    let key = CalibKey::current(&plans[0].expr, dims, opts.backend, opts.training);
    let ctx_id = key.context_id();
    for c in &candidates {
        tuning::global().record(
            &ctx_id,
            &c.signature,
            Measurement {
                fwd_secs: c.fwd_secs,
                train_secs: c.train_secs,
                cost: c.cost,
            },
        );
    }

    let secs: Vec<f64> = candidates
        .iter()
        .map(|c| {
            if opts.training {
                c.train_secs.unwrap_or(c.fwd_secs)
            } else {
                c.fwd_secs
            }
        })
        .collect();
    let best = tuning::select_index(&secs);

    let mut saved = None;
    if spec.persist {
        if let Some(path) = tuning::env_path() {
            tuning::global().save_to(&path)?;
            saved = Some(path);
        }
    }

    Ok(CalibrationReport {
        context_id: ctx_id,
        candidates,
        best,
        saved,
    })
}

/// Cache-block depths swept per geometry by [`calibrate_gemm_blocking`]
/// (each clamped to the contraction depth; duplicates collapse).
pub const GEMM_KC_CANDIDATES: [usize; 4] = [64, 128, 256, 512];

/// Measured sweep for one GEMM geometry: the per-`kc` packed timings, the
/// unpacked baseline, and the blocking the sweep learned from them.
#[derive(Debug, Clone)]
pub struct GemmBlockingTiming {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    /// Winning cache-block depth (best median packed replay).
    pub kc: usize,
    /// Learned engagement threshold: at or below the static floor when
    /// packing wins on this geometry, just above `m·n·k` when it loses.
    pub min_flops: usize,
    /// Median replay seconds at the winning `kc`.
    pub packed_secs: f64,
    /// Median replay seconds with packing disengaged.
    pub unpacked_secs: f64,
    /// The full `(kc, median seconds)` sweep, in candidate order.
    pub kc_secs: Vec<(usize, f64)>,
}

impl GemmBlockingTiming {
    /// Whether the learned tuning engages the packed path here.
    pub fn packs(&self) -> bool {
        self.min_flops <= self.m * self.n * self.k
    }

    /// The sweep as a JSON object (the `BENCH_kernels.json` row shape).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("m", Json::num(self.m as f64)),
            ("n", Json::num(self.n as f64)),
            ("k", Json::num(self.k as f64)),
            ("kc", Json::num(self.kc as f64)),
            ("min_flops", Json::num(self.min_flops as f64)),
            ("packs", Json::Bool(self.packs())),
            ("packed_secs", Json::num(self.packed_secs)),
            ("unpacked_secs", Json::num(self.unpacked_secs)),
            (
                "kc_secs",
                Json::arr(self.kc_secs.iter().map(|&(kc, s)| {
                    Json::obj(vec![
                        ("kc", Json::num(kc as f64)),
                        ("secs", Json::num(s)),
                    ])
                })),
            ),
        ])
    }
}

/// Time one `m×k · k×n` contraction replay under the dispatcher tuning
/// currently installed for that geometry (the plan must be compiled
/// *after* the tuning is set — resolved GEMM parameters are captured at
/// compile time).
fn time_gemm_geometry(
    m: usize,
    n: usize,
    k: usize,
    spec: &CalibrationSpec,
) -> Result<f64, String> {
    let dims = vec![vec![m, k], vec![n, k]];
    let parsed = parse("ts,ns->tn").map_err(|e| e.to_string())?;
    let sized = SizedSpec::new(parsed, dims.clone())?;
    let plans = candidate_plans(&sized, &PlanOptions::default(), 1)?;
    let compiled = CompiledPlan::compile_arc(Arc::new(plans[0].clone()))
        .map_err(|e| format!("blocking-sweep compile failed: {e}"))?;
    let mut rng = Rng::new(spec.seed);
    let probes: Vec<Tensor> = dims
        .iter()
        .map(|d| Tensor::rand(d, -1.0, 1.0, &mut rng))
        .collect();
    let inputs: Vec<&Tensor> = probes.iter().collect();
    let mut ws = Workspace::new();
    let mut out = Tensor::zeros(compiled.out_shape());
    compiled
        .run_into(&inputs, &mut ws, &mut out)
        .map_err(|e| format!("blocking-sweep forward failed: {e}"))?;
    let mut failed = false;
    let t = timing::bench("calib-gemm", spec.warmup, spec.iters.max(1), || {
        failed |= compiled.run_into(&inputs, &mut ws, &mut out).is_err();
    });
    if failed {
        return Err("blocking-sweep forward failed during timing".to_string());
    }
    Ok(t.median_secs())
}

/// Learn per-geometry GEMM blocking from measured sweeps (the
/// self-learning arm of the kernel dispatcher).
///
/// For each `(m, n, k)` geometry this times the contraction replay at
/// every [`GEMM_KC_CANDIDATES`] cache-block depth (engagement forced on)
/// plus an unpacked baseline (engagement forced off), installs temporary
/// tunings directly into the dispatcher so each compile resolves the
/// candidate blocking, then records the winner in the global
/// [`tuning::TuningCache`] via [`TuningCache::set_gemm_tuning`] — which
/// re-installs it in the dispatcher, bumps the tuning generation (stale
/// measured plans re-verify and recompile), and makes it eligible for
/// persistence. When the unpacked baseline wins, the learned threshold
/// parks engagement just above `m·n·k` so the geometry short-circuits to
/// the unblocked loops. With `spec.persist`, the cache is saved to the
/// `CONV_EINSUM_TUNING_CACHE` path when one is configured.
///
/// [`TuningCache::set_gemm_tuning`]: tuning::TuningCache::set_gemm_tuning
// alloc-ok(fn): calibration driver; runs at warm-up, never on the replay
// hot path.
pub fn calibrate_gemm_blocking(
    geometries: &[(usize, usize, usize)],
    spec: &CalibrationSpec,
) -> Result<Vec<GemmBlockingTiming>, String> {
    let mut reports = Vec::with_capacity(geometries.len());
    for &(m, n, k) in geometries {
        let flops = m
            .checked_mul(n)
            .and_then(|v| v.checked_mul(k))
            .ok_or_else(|| format!("geometry {m}x{n}x{k} overflows the FLOP estimate"))?;
        // Packed sweep: force engagement at each candidate depth.
        let mut kc_secs: Vec<(usize, f64)> = Vec::new();
        for kc in GEMM_KC_CANDIDATES {
            let kc = kc.min(k).max(1);
            if kc_secs.iter().any(|&(c, _)| c == kc) {
                continue;
            }
            dispatch::set_gemm_tunings(&[((m, n, k), TunedGemm { kc, min_flops: 0 })]);
            kc_secs.push((kc, time_gemm_geometry(m, n, k, spec)?));
        }
        // Unpacked baseline: park the threshold above this geometry.
        dispatch::set_gemm_tunings(&[(
            (m, n, k),
            TunedGemm {
                kc: k.max(1),
                min_flops: usize::MAX,
            },
        )]);
        let unpacked_secs = time_gemm_geometry(m, n, k, spec)?;

        let secs: Vec<f64> = kc_secs.iter().map(|&(_, s)| s).collect();
        let best = tuning::select_index(&secs);
        let (kc, packed_secs) = kc_secs[best];
        let min_flops = if packed_secs <= unpacked_secs {
            // Packing wins here: keep the static floor, but never above
            // this geometry's own volume (so it always engages).
            dispatch::PACK_MIN_FLOPS.min(flops)
        } else {
            flops.saturating_add(1)
        };
        // The permanent record: cache + dispatcher + generation bump.
        tuning::global().set_gemm_tuning(GemmTuning {
            m,
            n,
            k,
            kc,
            min_flops,
        });
        reports.push(GemmBlockingTiming {
            m,
            n,
            k,
            kc,
            min_flops,
            packed_secs,
            unpacked_secs,
            kc_secs,
        });
    }

    if spec.persist {
        if let Some(path) = tuning::env_path() {
            tuning::global().save_to(&path)?;
        }
    }
    Ok(reports)
}
