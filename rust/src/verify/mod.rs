//! Static invariant verifier for compiled plans and the pool protocol.
//!
//! The engine's load-bearing guarantees — liveness-packed arena layouts,
//! in-bounds gather tables, the normative accumulation order that keeps
//! scalar and parallel backends bit-identical, FLOP totals that match the
//! planner's chosen tree — are enforced dynamically by parity tests and the
//! counting allocator in `bench_hotpath`. This module proves them
//! *statically*, before any data flows:
//!
//! * [`CompiledPlan::verify`] walks a freshly lowered plan and each of its
//!   [`TrainLayout`]s (all three [`CkptPolicy`]s) and checks, without
//!   executing a single kernel, that
//!   - every permutation table (`out_perm`, `inv_out_perm`, `final_perm`,
//!     `inv_final_perm`) is an in-bounds permutation and inverses actually
//!     invert ([`VerifyError::BadPermutation`]);
//!   - every [`GradGather`] stride table stays inside the canonical operand
//!     buffer it gathers from, with all offset arithmetic `checked_mul`
//!     ([`VerifyError::GatherOutOfBounds`], [`VerifyError::OffsetOverflow`]);
//!   - every step's kernel holder carries the current
//!     [`crate::kernels::ACCUM_ORDER_VERSION`], the kernel family the atom
//!     would select, and the microkernel variant the process selected
//!     ([`VerifyError::KernelOrderVersion`],
//!     [`VerifyError::KernelVariantMismatch`]);
//!   - the step sequence's recomputed FLOP total matches the planner's
//!     per-step and whole-plan cost estimates
//!     ([`VerifyError::FlopMismatch`]);
//!   - a dataflow simulation of the inference schedule and of every
//!     training schedule (stored forward, checkpoint-segment recomputes,
//!     backward with cotangent accumulation) proves that each read sees a
//!     range written earlier and still live, and that no fresh write
//!     clobbers a range a later event still reads
//!     ([`VerifyError::ReadBeforeWrite`],
//!     [`VerifyError::OverlappingLiveSlots`],
//!     [`VerifyError::SlotOutOfBounds`]).
//!
//! Debug/test builds run the verifier automatically after every
//! `CompiledPlan::compile_arc`; release builds verify on [`PlanCache`]
//! insertion (cached entries amortize the cost) or on demand.
//!
//! [`pool_model`] is the companion checker for the runtime side: an
//! exhaustive-interleaving model of the [`crate::parallel::Pool`]
//! epoch/claim/notify protocol.
//!
//! [`PlanCache`]: crate::exec::PlanCache

pub mod pool_model;

use crate::autodiff::CkptPolicy;
use crate::exec::compiled::{Operand, TrainLayout};
use crate::exec::CompiledPlan;
use crate::kernels::ACCUM_ORDER_VERSION;
use std::fmt;
use std::ops::Range;

/// Which schedule a dataflow-simulation error was found in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimContext {
    /// The inference schedule ([`CompiledPlan::run`]).
    Inference,
    /// The training schedule for this checkpoint policy.
    Train(CkptPolicy),
}

impl fmt::Display for SimContext {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SimContext::Inference => write!(f, "inference schedule"),
            SimContext::Train(p) => write!(f, "training schedule ({p:?})"),
        }
    }
}

/// A statically detected violation of a compiled-plan invariant.
#[derive(Debug, Clone, PartialEq)]
pub enum VerifyError {
    /// A fresh write clobbers an arena range that a later event still reads.
    OverlappingLiveSlots {
        context: SimContext,
        /// DAG node being written (grad nodes offset by `n + ksteps`).
        writer: usize,
        /// Node whose live range the write overlaps.
        clobbered: usize,
    },
    /// An event reads a node whose value is not resident at that range
    /// (never written, already clobbered, or written somewhere else) — this
    /// covers read-after-free and step reordering.
    ReadBeforeWrite { context: SimContext, node: usize },
    /// An arena range extends past the arena (or is inverted).
    SlotOutOfBounds { context: SimContext, node: usize },
    /// A permutation table is not a permutation, or an inverse table does
    /// not invert its forward table.
    BadPermutation {
        step: Option<usize>,
        what: &'static str,
    },
    /// A gather stride table can address past its canonical source buffer.
    GatherOutOfBounds { step: usize, operand: char },
    /// Offset/extent arithmetic overflows `usize`.
    OffsetOverflow {
        step: Option<usize>,
        what: &'static str,
    },
    /// Recomputed FLOPs disagree with the planner's cost estimate
    /// (`step: None` is the whole-plan total).
    FlopMismatch {
        step: Option<usize>,
        expected: f64,
        found: f64,
    },
    /// A step's kernel holder carries a stale accumulation-order version.
    KernelOrderVersion {
        step: usize,
        found: u32,
        expected: u32,
    },
    /// A step's kernel holder is pinned to a different microkernel variant
    /// than the one currently selected for the process — replaying it would
    /// mix accumulation orders across steps.
    KernelVariantMismatch {
        step: usize,
        found: &'static str,
        selected: &'static str,
    },
    /// A measured plan carries a tuning-generation stamp older than the
    /// process's current tuning-cache generation — its candidate ranking
    /// was decided against measurements that have since changed, so the
    /// plan must be re-planned (a fresh `PlanCache` lookup misses and
    /// recompiles; see `PlanKey::tuning_generation`).
    TuningGenerationMismatch { plan: u64, current: u64 },
    /// Structural inconsistency not covered by a more specific variant.
    Malformed { what: String },
}

impl fmt::Display for VerifyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            VerifyError::OverlappingLiveSlots {
                context,
                writer,
                clobbered,
            } => write!(
                f,
                "{context}: write of node {writer} clobbers the live arena range of \
                 node {clobbered}"
            ),
            VerifyError::ReadBeforeWrite { context, node } => write!(
                f,
                "{context}: node {node} is read at a range where its value is not \
                 resident (unproduced, reordered, or already clobbered)"
            ),
            VerifyError::SlotOutOfBounds { context, node } => {
                write!(f, "{context}: arena range of node {node} is out of bounds")
            }
            VerifyError::BadPermutation { step, what } => match step {
                Some(k) => write!(f, "step {k}: {what} is not a valid permutation/inverse"),
                None => write!(f, "{what} is not a valid permutation/inverse"),
            },
            VerifyError::GatherOutOfBounds { step, operand } => write!(
                f,
                "step {step}: grad gather for operand {operand} can address past its \
                 canonical buffer"
            ),
            VerifyError::OffsetOverflow { step, what } => match step {
                Some(k) => write!(f, "step {k}: {what} overflows usize"),
                None => write!(f, "{what} overflows usize"),
            },
            VerifyError::FlopMismatch {
                step,
                expected,
                found,
            } => match step {
                Some(k) => write!(
                    f,
                    "step {k}: planner cost {found} != recomputed FLOPs {expected}"
                ),
                None => write!(f, "plan cost {found} != recomputed FLOP total {expected}"),
            },
            VerifyError::KernelOrderVersion {
                step,
                found,
                expected,
            } => write!(
                f,
                "step {step}: kernel accumulation-order version {found} != current \
                 version {expected} (stale compiled artifact?)"
            ),
            VerifyError::KernelVariantMismatch {
                step,
                found,
                selected,
            } => write!(
                f,
                "step {step}: kernel pinned to variant '{found}' but the process \
                 selected '{selected}' (plan compiled under a different kernel \
                 selection?)"
            ),
            VerifyError::TuningGenerationMismatch { plan, current } => write!(
                f,
                "plan ranked under tuning-cache generation {plan} but the process is \
                 at generation {current} (stale measured plan; re-plan to pick up the \
                 new calibration data)"
            ),
            VerifyError::Malformed { what } => write!(f, "malformed compiled plan: {what}"),
        }
    }
}

impl std::error::Error for VerifyError {}

fn checked_product(dims: &[usize]) -> Option<usize> {
    dims.iter().try_fold(1usize, |acc, &d| acc.checked_mul(d))
}

fn is_permutation(perm: &[usize]) -> bool {
    let mut seen = vec![false; perm.len()];
    for &p in perm {
        if p >= perm.len() || seen[p] {
            return false;
        }
        seen[p] = true;
    }
    true
}

fn inverts(perm: &[usize], inv: &[usize]) -> bool {
    inv.len() == perm.len() && perm.iter().enumerate().all(|(i, &p)| inv[p] == i)
}

// ---------------------------------------------------------------------------
// Dataflow simulation
// ---------------------------------------------------------------------------

/// One arena access of a schedule, in program order. `node` is a DAG node id
/// (inputs `0..n`, step `k` output `n + k`) or, in training schedules, a
/// cotangent id `n + ksteps + node`.
#[derive(Debug, Clone)]
enum Ev {
    Read {
        node: usize,
        range: Range<usize>,
    },
    Write {
        node: usize,
        range: Range<usize>,
        /// `true` overwrites (evicting whatever lived there); `false`
        /// accumulates onto a resident value (read-modify-write).
        fresh: bool,
    },
}

fn overlaps(a: &Range<usize>, b: &Range<usize>) -> bool {
    a.start < b.end && b.start < a.end
}

/// Does any event at `events[from..]` read node `x` before its next fresh
/// write? (An accumulating write counts as a read.)
fn read_before_next_fresh_write(events: &[Ev], from: usize, x: usize) -> bool {
    for ev in &events[from..] {
        match ev {
            Ev::Read { node, .. } if *node == x => return true,
            Ev::Write { node, fresh, .. } if *node == x => return !fresh,
            _ => {}
        }
    }
    false
}

/// Replay a schedule's arena accesses symbolically, proving every read sees
/// a live value and no write clobbers one. `elems(node)` gives the expected
/// flat length of each node's value.
fn simulate(
    context: SimContext,
    events: &[Ev],
    n_nodes: usize,
    arena_len: usize,
    elems: impl Fn(usize) -> Result<usize, VerifyError>,
) -> Result<(), VerifyError> {
    let mut resident: Vec<Option<Range<usize>>> = vec![None; n_nodes];
    for (i, ev) in events.iter().enumerate() {
        let (node, range) = match ev {
            Ev::Read { node, range } | Ev::Write { node, range, .. } => (*node, range),
        };
        if range.start > range.end || range.end > arena_len {
            return Err(VerifyError::SlotOutOfBounds { context, node });
        }
        if range.end - range.start != elems(node)? {
            return Err(VerifyError::Malformed {
                what: format!(
                    "{context}: node {node} accessed with range length {} but its value \
                     has {} elements",
                    range.end - range.start,
                    elems(node)?
                ),
            });
        }
        match ev {
            Ev::Read { .. } => {
                if resident[node] != Some(range.clone()) {
                    return Err(VerifyError::ReadBeforeWrite { context, node });
                }
            }
            Ev::Write { fresh: false, .. } => {
                // Accumulation is a read-modify-write of a resident value.
                if resident[node] != Some(range.clone()) {
                    return Err(VerifyError::ReadBeforeWrite { context, node });
                }
            }
            Ev::Write { fresh: true, .. } => {
                for x in 0..n_nodes {
                    if x == node {
                        continue;
                    }
                    if let Some(rx) = &resident[x] {
                        if overlaps(rx, range) {
                            if read_before_next_fresh_write(events, i + 1, x) {
                                return Err(VerifyError::OverlappingLiveSlots {
                                    context,
                                    writer: node,
                                    clobbered: x,
                                });
                            }
                            resident[x] = None;
                        }
                    }
                }
                resident[node] = Some(range.clone());
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Plan walking
// ---------------------------------------------------------------------------

impl CompiledPlan {
    /// Flat element count of a DAG node's value, checked.
    fn verify_node_elems(&self, node: usize) -> Result<usize, VerifyError> {
        let n = self.plan.n_inputs;
        let dims: &[usize] = if node < n {
            &self.in_dims[node]
        } else {
            &self.steps[node - n].atom.out_shape
        };
        checked_product(dims).ok_or(VerifyError::OffsetOverflow {
            step: None,
            what: "node element count",
        })
    }

    /// Per-step structural checks: permutations, gather tables, kernel
    /// selection and accumulation-order version.
    fn verify_steps(&self) -> Result<(), VerifyError> {
        let n = self.plan.n_inputs;
        let ksteps = self.steps.len();
        for (k, step) in self.steps.iter().enumerate() {
            let atom = &step.atom;
            // Permutations.
            if !is_permutation(&atom.out_perm) {
                return Err(VerifyError::BadPermutation {
                    step: Some(k),
                    what: "atom.out_perm",
                });
            }
            if !inverts(&atom.out_perm, &step.inv_out_perm) {
                return Err(VerifyError::BadPermutation {
                    step: Some(k),
                    what: "inv_out_perm",
                });
            }
            if atom.out_shape.len() != atom.out_perm.len()
                || atom
                    .out_perm
                    .iter()
                    .enumerate()
                    .any(|(i, &p)| atom.out_shape[i] != atom.raw_out_dims[p])
            {
                return Err(VerifyError::Malformed {
                    what: format!("step {k}: out_shape does not match permuted raw_out_dims"),
                });
            }
            // Operand bookkeeping: sources must agree with the DAG node ids.
            for (node, src, name) in [
                (step.lhs_node, &step.lhs_src, "lhs"),
                (step.rhs_node, &step.rhs_src, "rhs"),
            ] {
                if node >= n + ksteps {
                    return Err(VerifyError::Malformed {
                        what: format!("step {k}: {name} node id {node} out of range"),
                    });
                }
                match src {
                    Operand::Input(i) => {
                        if *i != node || node >= n {
                            return Err(VerifyError::Malformed {
                                what: format!(
                                    "step {k}: {name} input operand disagrees with node id"
                                ),
                            });
                        }
                    }
                    Operand::Value(_) => {
                        if node < n {
                            return Err(VerifyError::Malformed {
                                what: format!(
                                    "step {k}: {name} value operand names an input node"
                                ),
                            });
                        }
                    }
                }
            }
            // Kernel family + accumulation-order version.
            if step.kernel.step() != atom.select_kernel() {
                return Err(VerifyError::Malformed {
                    what: format!("step {k}: kernel family differs from the atom's selection"),
                });
            }
            if step.kernel.order_version != ACCUM_ORDER_VERSION {
                return Err(VerifyError::KernelOrderVersion {
                    step: k,
                    found: step.kernel.order_version,
                    expected: ACCUM_ORDER_VERSION,
                });
            }
            let selected = crate::kernels::dispatch::selected();
            if step.kernel.variant() != selected.variant {
                return Err(VerifyError::KernelVariantMismatch {
                    step: k,
                    found: step.kernel.variant().name(),
                    selected: selected.variant.name(),
                });
            }
            // Gather tables: the backward gathers operand cotangents out of
            // the canonical scratch buffers; every addressable offset must
            // stay inside them.
            let dims = &self.plan.steps[k].sized.dims;
            let pa = checked_product(&atom.conv.iter().map(|c| c.ia).collect::<Vec<_>>());
            let pb = checked_product(&atom.conv.iter().map(|c| c.ib).collect::<Vec<_>>());
            let canon_len = |free: usize, pconv: Option<usize>| {
                pconv
                    .and_then(|p| checked_product(&[atom.g, free, atom.s, p]))
                    .ok_or(VerifyError::OffsetOverflow {
                        step: Some(k),
                        what: "canonical buffer length",
                    })
            };
            let a_len = canon_len(atom.t, pa)?;
            let b_len = canon_len(atom.n, pb)?;
            for (grad, natural, len, name) in [
                (&step.grad_a, &dims[0], a_len, 'a'),
                (&step.grad_b, &dims[1], b_len, 'b'),
            ] {
                if grad.out_shape != *natural || grad.strides.len() != grad.out_shape.len() {
                    return Err(VerifyError::Malformed {
                        what: format!(
                            "step {k}: grad gather for operand {name} has shape {:?}, \
                             operand has {:?}",
                            grad.out_shape, natural
                        ),
                    });
                }
                // Max addressable offset: Σ (d − 1) · stride, checked.
                let mut max_off: usize = 0;
                for (&d, &stride) in grad.out_shape.iter().zip(&grad.strides) {
                    if d == 0 {
                        continue;
                    }
                    let overflow = || VerifyError::OffsetOverflow {
                        step: Some(k),
                        what: "grad gather offset",
                    };
                    let term = (d - 1).checked_mul(stride).ok_or_else(overflow)?;
                    max_off = max_off.checked_add(term).ok_or_else(overflow)?;
                }
                let empty = grad.out_shape.iter().any(|&d| d == 0);
                if !empty && max_off >= len {
                    return Err(VerifyError::GatherOutOfBounds { step: k, operand: name });
                }
            }
        }
        // Final permutation.
        match (&self.plan.final_perm, &self.inv_final_perm) {
            (None, None) => {}
            (Some(p), Some(inv)) => {
                if !is_permutation(p) || !inverts(p, inv) {
                    return Err(VerifyError::BadPermutation {
                        step: None,
                        what: "final_perm/inv_final_perm",
                    });
                }
            }
            _ => {
                return Err(VerifyError::Malformed {
                    what: "final_perm and inv_final_perm presence disagree".to_string(),
                });
            }
        }
        Ok(())
    }

    /// Dataflow simulation of the inference schedule: per step, operand
    /// reads then the output write; finally the root copy-out.
    fn verify_inference_dataflow(&self) -> Result<(), VerifyError> {
        let n = self.plan.n_inputs;
        let ksteps = self.steps.len();
        let mut events: Vec<Ev> = Vec::with_capacity(3 * ksteps + 1);
        for (k, step) in self.steps.iter().enumerate() {
            for (node, src) in [(step.lhs_node, &step.lhs_src), (step.rhs_node, &step.rhs_src)] {
                if let Operand::Value(r) = src {
                    events.push(Ev::Read {
                        node,
                        range: r.clone(),
                    });
                }
            }
            events.push(Ev::Write {
                node: n + k,
                range: step.out.clone(),
                fresh: true,
            });
        }
        let root_node = n + ksteps - 1;
        events.push(Ev::Read {
            node: root_node,
            range: self.root.clone(),
        });
        simulate(
            SimContext::Inference,
            &events,
            n + ksteps,
            self.values_len,
            |node| self.verify_node_elems(node),
        )
    }

    /// Recompute every step's FLOPs from its compiled atom (independently of
    /// the planner's cost analysis) and compare against the recorded
    /// per-step and whole-plan costs.
    fn verify_flops(&self) -> Result<(), VerifyError> {
        let training = self.plan.training;
        let mut total = 0.0f64;
        for (k, step) in self.steps.iter().enumerate() {
            let atom = &step.atom;
            let base = atom.g as f64 * atom.t as f64 * atom.n as f64 * atom.s as f64;
            let fwd: f64 = atom
                .conv
                .iter()
                .map(|c| c.ia as f64 * c.ib as f64)
                .product::<f64>()
                * base;
            let expected = if training {
                let g1: f64 = atom
                    .conv
                    .iter()
                    .map(|c| c.out as f64 * c.ib as f64)
                    .product::<f64>()
                    * base;
                let g2: f64 = atom
                    .conv
                    .iter()
                    .map(|c| c.out as f64 * c.ia as f64)
                    .product::<f64>()
                    * base;
                fwd + g1 + g2
            } else {
                fwd
            };
            let found = self.plan.steps[k].cost;
            if (expected - found).abs() > 1e-6 * expected.abs().max(1.0) {
                return Err(VerifyError::FlopMismatch {
                    step: Some(k),
                    expected,
                    found,
                });
            }
            total += expected;
        }
        let found = self.plan.cost;
        if (total - found).abs() > 1e-6 * total.abs().max(1.0) {
            return Err(VerifyError::FlopMismatch {
                step: None,
                expected: total,
                found,
            });
        }
        Ok(())
    }

    /// Dataflow simulation of one training layout: input copies, stored
    /// forward, root copy-out, cotangent seed, backward (with recompute
    /// segments and cotangent accumulation), input-gradient copy-out.
    ///
    /// Public so tests can verify (or refute) mutated clones of a layout
    /// directly — the cached layouts on a compiled plan are immutable.
    pub fn verify_train_layout(&self, layout: &TrainLayout) -> Result<(), VerifyError> {
        let n = self.plan.n_inputs;
        let ksteps = self.steps.len();
        let context = SimContext::Train(layout.policy());
        // Grad node of DAG node `x` is `n + ksteps + x`.
        let gid = |x: usize| n + ksteps + x;
        let malformed = |what: String| VerifyError::Malformed { what };
        if layout.fwd.len() != ksteps {
            return Err(malformed(format!(
                "{context}: forward schedule has {} steps, plan has {ksteps}",
                layout.fwd.len()
            )));
        }
        if layout.input_ranges.len() != n || layout.input_grads.len() != n {
            return Err(malformed(format!(
                "{context}: input range tables do not cover all {n} inputs"
            )));
        }
        let step_nodes = |k: usize| -> Result<(usize, usize), VerifyError> {
            if k >= ksteps {
                return Err(VerifyError::Malformed {
                    what: format!("{context}: schedule names step {k}, plan has {ksteps}"),
                });
            }
            Ok((self.steps[k].lhs_node, self.steps[k].rhs_node))
        };

        let mut events: Vec<Ev> = Vec::new();
        for (i, r) in layout.input_ranges.iter().enumerate() {
            events.push(Ev::Write {
                node: i,
                range: r.clone(),
                fresh: true,
            });
        }
        for loc in &layout.fwd {
            let (l, r) = step_nodes(loc.k)?;
            events.push(Ev::Read {
                node: l,
                range: loc.a.clone(),
            });
            events.push(Ev::Read {
                node: r,
                range: loc.b.clone(),
            });
            events.push(Ev::Write {
                node: n + loc.k,
                range: loc.out.clone(),
                fresh: true,
            });
        }
        let root_node = n + ksteps - 1;
        events.push(Ev::Read {
            node: root_node,
            range: layout.root.clone(),
        });
        events.push(Ev::Write {
            node: gid(root_node),
            range: layout.droot.clone(),
            fresh: true,
        });
        for bstep in &layout.bwd {
            for rloc in &bstep.recompute {
                let (l, r) = step_nodes(rloc.k)?;
                events.push(Ev::Read {
                    node: l,
                    range: rloc.a.clone(),
                });
                events.push(Ev::Read {
                    node: r,
                    range: rloc.b.clone(),
                });
                events.push(Ev::Write {
                    node: n + rloc.k,
                    range: rloc.out.clone(),
                    fresh: true,
                });
            }
            let (l, r) = step_nodes(bstep.k)?;
            events.push(Ev::Read {
                node: l,
                range: bstep.a.clone(),
            });
            events.push(Ev::Read {
                node: r,
                range: bstep.b.clone(),
            });
            events.push(Ev::Read {
                node: gid(n + bstep.k),
                range: bstep.dnode.clone(),
            });
            events.push(Ev::Write {
                node: gid(l),
                range: bstep.da.range.clone(),
                fresh: bstep.da.fresh,
            });
            events.push(Ev::Write {
                node: gid(r),
                range: bstep.db.range.clone(),
                fresh: bstep.db.fresh,
            });
        }
        for (i, r) in layout.input_grads.iter().enumerate() {
            events.push(Ev::Read {
                node: gid(i),
                range: r.clone(),
            });
        }
        let n_nodes = 2 * (n + ksteps);
        simulate(context, &events, n_nodes, layout.arena_len, |node| {
            let value_node = if node >= n + ksteps {
                node - (n + ksteps)
            } else {
                node
            };
            self.verify_node_elems(value_node)
        })
    }

    /// Statically verify every invariant of this compiled plan: per-step
    /// structure (permutations, gather bounds, kernel order versions), the
    /// inference dataflow, the FLOP accounting, and the training dataflow
    /// under all three checkpoint policies. See the module docs for the full
    /// catalogue; `INVARIANTS.md` maps each invariant to its check.
    ///
    /// Runs automatically after every compile in debug/test builds and on
    /// [`crate::exec::PlanCache`] insertion in release builds.
    pub fn verify(&self) -> Result<(), VerifyError> {
        if let Some(plan) = self.plan.tuning_generation {
            let current = crate::cost::tuning::generation();
            if plan != current {
                return Err(VerifyError::TuningGenerationMismatch { plan, current });
            }
        }
        self.verify_steps()?;
        self.verify_inference_dataflow()?;
        self.verify_flops()?;
        for policy in CkptPolicy::ALL {
            let layout = self.train_layout(policy);
            self.verify_train_layout(&layout)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests;
