//! Exhaustive-interleaving model checker for the [`crate::parallel::Pool`]
//! epoch/claim/notify protocol (std-only, loom-style but hand-rolled).
//!
//! The pool's dispatch protocol is the one piece of the engine whose
//! correctness argument is genuinely concurrent: a caller publishes a job
//! under the slot mutex, wakes a bounded number of workers *without* the
//! lock, and then caller + workers race on a lock-free atomic chunk cursor
//! until an atomic `remaining` counter hits zero. Unit tests exercise a few
//! schedules per run; this module instead **enumerates every schedule** of a
//! faithful finite-state model of the protocol for small configurations
//! (1–3 workers × 1–4 chunks × 1–3 back-to-back jobs) and proves, for each:
//!
//! * **no double-claimed chunk** — no chunk index is ever executed twice
//!   within a job (the claim cursor hands each index to exactly one thread);
//! * **no lost chunk** — when the publisher's completion wait returns, every
//!   chunk of the job has executed exactly once;
//! * **no stale execution** — a worker never runs a chunk while the slot
//!   holds a different epoch than the one it joined (the raw job pointer is
//!   only ever dereferenced while its publishing stack frame is pinned);
//! * **no lost wakeup / deadlock** — from every reachable state some thread
//!   can move, and every terminal state has the publisher finished, all
//!   jobs' chunks drained, and all workers shut down. The protocol's
//!   unlocked `work_cv` notifies *can* be lost — the model shows this is
//!   benign (the publisher participates and drains) — while the `done_cv`
//!   notifies are lock-paired so the publisher's sleep is never stranded.
//!
//! # Modeling fidelity
//!
//! Each transition is one atomic action of the real protocol. The two
//! subtleties that make condvar protocols wrong in practice are modeled
//! explicitly:
//!
//! * a condvar wait is **two** transitions — evaluate the predicate while
//!   holding the mutex, then atomically (enqueue on the wait set + release
//!   the mutex). Atomic operations by other threads (e.g. the `remaining`
//!   decrement) can interleave between them, exactly as on real hardware;
//!   a notify performed *without* the mutex can therefore fire in that
//!   window and be lost, while a notify performed *with* the mutex held
//!   cannot — which is precisely the discipline the real code follows for
//!   `done_cv`.
//! * `notify_one` nondeterministically wakes **any** parked waiter (the
//!   checker branches over all choices), and a notify with no waiters is a
//!   no-op, not a credit.
//!
//! Known, deliberate simplifications: spurious wakeups are not injected
//! (every wait sits in a while-loop re-check, so they can only add benign
//! schedules, not remove any modeled here); chunk-closure panics are not
//! modeled (the panic path only adds a lock-protected payload hand-off);
//! memory ordering is sequentially consistent (all cross-thread data in the
//! model is either mutex-protected or a single atomic cell).
//!
//! # Bug injection
//!
//! [`Bug`] variants re-introduce classic mistakes — splitting the atomic
//! claim `fetch_add` into a load + store, or dropping the participant-exit
//! notify — and the tests assert the checker catches each one, which is the
//! evidence that the passing runs are meaningful.

use std::collections::HashSet;

/// Upper bounds of the finite model (publisher + up to 3 workers, ≤ 4
/// chunks). Configurations beyond these are rejected, not truncated.
const MAX_WORKERS: usize = 3;
const MAX_CHUNKS: usize = 4;
const MAX_THREADS: usize = MAX_WORKERS + 1;

/// Cap on explored states; hitting it is reported, never silently ignored.
const MAX_STATES: usize = 20_000_000;

/// A model configuration: how many workers, chunks per job, cursor claim
/// batch size, and back-to-back jobs (sequential jobs exercise the
/// epoch-staleness protocol).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Config {
    pub workers: usize,
    pub chunks: usize,
    pub claim: usize,
    pub jobs: usize,
    pub bug: Option<Bug>,
}

impl Config {
    /// A correct-protocol configuration (no injected bug).
    pub fn new(workers: usize, chunks: usize, claim: usize, jobs: usize) -> Config {
        Config {
            workers,
            chunks,
            claim,
            jobs,
            bug: None,
        }
    }
}

/// Deliberately injected protocol mistakes, used to prove the checker has
/// teeth.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bug {
    /// Replace the atomic claim `fetch_add` with a separate load and store,
    /// re-creating the lost-update race the atomic exists to prevent.
    SplitClaimFetch,
    /// Drop the `done_cv` notify a leaving worker issues when
    /// `participants` reaches zero, re-creating a stranded publisher.
    NoLeaveNotify,
}

/// A property violation found on some schedule.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Violation {
    /// A chunk index was executed more than once within one job.
    DoubleClaim { chunk: usize },
    /// The publisher's completion wait returned with a chunk unexecuted.
    UnclaimedChunk { chunk: usize },
    /// A worker executed a chunk under an epoch other than the one it
    /// joined (the raw job pointer would be dangling or retargeted).
    StaleExecution { worker: usize },
    /// A reachable state where no thread can move but the run is not
    /// complete — a lost wakeup or other deadlock.
    Deadlock,
    /// The configuration exceeded the model's state budget (not a protocol
    /// violation; shrink the configuration).
    StateSpaceExceeded,
    /// The configuration exceeds the model's hard bounds.
    BadConfig(&'static str),
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Violation::DoubleClaim { chunk } => {
                write!(f, "chunk {chunk} executed more than once in a single job")
            }
            Violation::UnclaimedChunk { chunk } => {
                write!(f, "publisher completed with chunk {chunk} never executed")
            }
            Violation::StaleExecution { worker } => {
                write!(f, "worker {worker} executed a chunk of a stale epoch")
            }
            Violation::Deadlock => {
                write!(f, "reachable state with no enabled transition before completion")
            }
            Violation::StateSpaceExceeded => {
                write!(f, "state budget of {MAX_STATES} exceeded")
            }
            Violation::BadConfig(what) => write!(f, "unsupported configuration: {what}"),
        }
    }
}

impl std::error::Error for Violation {}

/// Statistics from an exhaustive run that found no violation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelStats {
    /// Distinct states reached (the whole reachable graph was explored).
    pub states: usize,
}

/// Program counter of one modeled thread. Thread 0 is the publisher
/// (`run_chunks`); threads 1.. are pool workers (`worker_loop`). The
/// `Fetch`/`Exec`/… claim-loop states are shared by both roles
/// (`execute_chunks` in the real code); the thread index decides where the
/// loop exits to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum Pc {
    // Publisher: publish one job under the mutex, then wake workers.
    PLockPublish,
    PPublish,
    PNotifyWork,
    // Publisher: completion wait (predicate eval and enqueue are separate
    // transitions — see module docs).
    PWaitLock,
    PWaitEval,
    PWaitEnqueue,
    PParked,
    PReacquire,
    PFinish,
    // Publisher: pool drop — set shutdown under the mutex, notify unlocked.
    PShutdownLock,
    PShutdownSet,
    PShutdownNotify,
    PDone,
    // Worker: park/join loop.
    WLock,
    WEval,
    WEnqueue,
    WParked,
    WReacquire,
    WLeaveLock,
    WLeave,
    WDone,
    // Shared claim loop (`execute_chunks`).
    Fetch,
    FetchStore,
    Exec,
    DecRemaining,
    DoneLock,
    DoneNotify,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct Thread {
    pc: Pc,
    /// Worker: last epoch joined/drained (`seen` in the real code).
    seen: u8,
    /// Claimed batch `[start, end)` of the current fetch.
    start: u8,
    end: u8,
    /// Publisher: `work_cv` notifies still to send for this job.
    notifies: u8,
    /// `Bug::SplitClaimFetch` only: cursor value loaded but not yet stored.
    pending: u8,
}

/// One global state of the model. `n_chunks`/`claim` live in [`Config`]
/// (they are re-published identically every job), so the state holds only
/// what varies.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct State {
    /// Which thread holds the slot mutex.
    mutex: Option<u8>,
    epoch: u8,
    published: bool,
    shutdown: bool,
    participants: u8,
    /// The lock-free claim cursor (saturating; overshoot is part of the
    /// real protocol).
    cursor: u8,
    remaining: u8,
    /// Bitmask of workers parked on `work_cv`.
    work_waiters: u8,
    /// Publisher parked on `done_cv`.
    done_wait: bool,
    /// Jobs fully published-and-finished so far.
    job_idx: u8,
    /// Execution count per chunk of the current job.
    exec: [u8; MAX_CHUNKS],
    threads: [Thread; MAX_THREADS],
}

const IDLE: Thread = Thread {
    pc: Pc::WDone,
    seen: 0,
    start: 0,
    end: 0,
    notifies: 0,
    pending: 0,
};

/// Where a thread's claim loop exits to once the cursor is exhausted.
fn exit_pc(t: usize) -> Pc {
    if t == 0 {
        Pc::PWaitLock
    } else {
        Pc::WLeaveLock
    }
}

/// Wake the publisher if it is parked on `done_cv` (a `notify_all`; the
/// publisher is the only `done_cv` waiter).
fn notify_done(s: &mut State) {
    if s.done_wait {
        s.done_wait = false;
        s.threads[0].pc = Pc::PReacquire;
    }
}

/// Compute the successor states of letting thread `t` take its next atomic
/// action. `Ok(None)` means the thread is currently blocked (mutex held
/// elsewhere, parked, or finished); `Ok(Some(succs))` enumerates every
/// nondeterministic outcome; `Err` reports a property violation.
fn step(cfg: &Config, s: &State, t: usize) -> Result<Option<Vec<State>>, Violation> {
    let th = s.threads[t];
    let chunks = cfg.chunks as u8;
    let claim = cfg.claim as u8;
    match th.pc {
        // ---- blocked-forever / externally-woken states ----
        Pc::PParked | Pc::WParked | Pc::PDone | Pc::WDone => Ok(None),

        // ---- mutex acquisition ----
        Pc::PLockPublish
        | Pc::PWaitLock
        | Pc::PReacquire
        | Pc::PShutdownLock
        | Pc::WLock
        | Pc::WReacquire
        | Pc::WLeaveLock
        | Pc::DoneLock => {
            if s.mutex.is_some() {
                return Ok(None);
            }
            let mut n = s.clone();
            n.mutex = Some(t as u8);
            n.threads[t].pc = match th.pc {
                Pc::PLockPublish => Pc::PPublish,
                // A condvar wait returns holding the mutex; re-evaluate the
                // predicate (while-loop in the real code).
                Pc::PWaitLock | Pc::PReacquire => Pc::PWaitEval,
                Pc::PShutdownLock => Pc::PShutdownSet,
                Pc::WLock | Pc::WReacquire => Pc::WEval,
                Pc::WLeaveLock => Pc::WLeave,
                Pc::DoneLock => Pc::DoneNotify,
                _ => unreachable!(),
            };
            Ok(Some(vec![n]))
        }

        // ---- publisher: publish one job ----
        Pc::PPublish => {
            // Everything under the slot mutex, exactly as `run_chunks`:
            // bump the epoch, publish the job, reset cursor and remaining.
            // No other thread can observe the cursor mid-publish: claim
            // loops require participation, and the previous job's wait
            // ensured participants == 0.
            let mut n = s.clone();
            n.epoch = n.epoch.wrapping_add(1);
            n.published = true;
            n.cursor = 0;
            n.remaining = chunks;
            n.exec = [0; MAX_CHUNKS];
            n.threads[t].notifies = (cfg.chunks - 1).min(cfg.workers) as u8;
            n.threads[t].pc = Pc::PNotifyWork;
            n.mutex = None;
            Ok(Some(vec![n]))
        }
        Pc::PNotifyWork => {
            // `wake` unlocked notify_one calls. Each wakes an arbitrary
            // parked worker (branch over all), or is lost if none is
            // parked — the real protocol tolerates that because the
            // publisher participates.
            if th.notifies == 0 {
                let mut n = s.clone();
                n.threads[t].pc = Pc::Fetch;
                return Ok(Some(vec![n]));
            }
            let mut out = Vec::new();
            if s.work_waiters == 0 {
                let mut n = s.clone();
                n.threads[t].notifies -= 1;
                out.push(n);
            } else {
                for w in 1..=cfg.workers {
                    if s.work_waiters & (1 << w) != 0 {
                        let mut n = s.clone();
                        n.threads[t].notifies -= 1;
                        n.work_waiters &= !(1 << w);
                        n.threads[w].pc = Pc::WReacquire;
                        out.push(n);
                    }
                }
            }
            Ok(Some(out))
        }

        // ---- shared claim loop ----
        Pc::Fetch => {
            let mut n = s.clone();
            if cfg.bug == Some(Bug::SplitClaimFetch) {
                // Injected race: load the cursor now, store it back later.
                n.threads[t].pending = s.cursor;
                n.threads[t].pc = Pc::FetchStore;
                return Ok(Some(vec![n]));
            }
            // The real `fetch_add(claim, AcqRel)`: one atomic action.
            let start = s.cursor;
            n.cursor = s.cursor.saturating_add(claim);
            if start >= chunks {
                n.threads[t].pc = exit_pc(t);
            } else {
                n.threads[t].start = start;
                n.threads[t].end = (start + claim).min(chunks);
                n.threads[t].pc = Pc::Exec;
            }
            Ok(Some(vec![n]))
        }
        Pc::FetchStore => {
            // Second half of the injected split fetch: blind store of
            // load + claim, losing any concurrent increment.
            let mut n = s.clone();
            let start = th.pending;
            n.cursor = th.pending.saturating_add(claim);
            if start >= chunks {
                n.threads[t].pc = exit_pc(t);
            } else {
                n.threads[t].start = start;
                n.threads[t].end = (start + claim).min(chunks);
                n.threads[t].pc = Pc::Exec;
            }
            Ok(Some(vec![n]))
        }
        Pc::Exec => {
            // Executing the claimed batch. A worker must still be inside
            // the epoch it joined — otherwise the real code would be
            // dereferencing a dangling or retargeted job pointer.
            if t != 0 && (th.seen != s.epoch || !s.published) {
                return Err(Violation::StaleExecution { worker: t });
            }
            let mut n = s.clone();
            for i in th.start..th.end {
                n.exec[i as usize] += 1;
                if n.exec[i as usize] > 1 {
                    return Err(Violation::DoubleClaim { chunk: i as usize });
                }
            }
            n.threads[t].pc = Pc::DecRemaining;
            Ok(Some(vec![n]))
        }
        Pc::DecRemaining => {
            // `remaining.fetch_sub(done, AcqRel) == done` → last finisher.
            let done = th.end - th.start;
            let rem = match s.remaining.checked_sub(done) {
                Some(r) => r,
                // Underflow means some chunk was decremented twice.
                None => return Err(Violation::DoubleClaim { chunk: th.start as usize }),
            };
            let mut n = s.clone();
            n.remaining = rem;
            n.threads[t].pc = if rem == 0 { Pc::DoneLock } else { Pc::Fetch };
            Ok(Some(vec![n]))
        }
        Pc::DoneNotify => {
            // Last finisher: notify_all(done_cv) while HOLDING the slot
            // mutex. Because the publisher's predicate-eval and enqueue
            // also hold the mutex, this notify serializes against them and
            // can never land in the eval→enqueue window — the lost-wakeup
            // freedom the checker proves.
            let mut n = s.clone();
            notify_done(&mut n);
            n.mutex = None;
            n.threads[t].pc = Pc::Fetch;
            Ok(Some(vec![n]))
        }

        // ---- publisher: completion wait ----
        Pc::PWaitEval => {
            let mut n = s.clone();
            n.threads[t].pc = if s.remaining > 0 || s.participants > 0 {
                Pc::PWaitEnqueue
            } else {
                Pc::PFinish
            };
            Ok(Some(vec![n]))
        }
        Pc::PWaitEnqueue => {
            // Atomically enqueue on done_cv and release the mutex.
            let mut n = s.clone();
            n.done_wait = true;
            n.mutex = None;
            n.threads[t].pc = Pc::PParked;
            Ok(Some(vec![n]))
        }
        Pc::PFinish => {
            // `run_chunks` returns here: remaining == 0 and participants
            // == 0 under the mutex. THE core property: every chunk of the
            // job ran exactly once.
            for i in 0..cfg.chunks {
                if s.exec[i] != 1 {
                    return Err(if s.exec[i] == 0 {
                        Violation::UnclaimedChunk { chunk: i }
                    } else {
                        Violation::DoubleClaim { chunk: i }
                    });
                }
            }
            let mut n = s.clone();
            n.published = false; // slot.job = None
            n.mutex = None;
            n.job_idx += 1;
            n.threads[t].pc = if (n.job_idx as usize) < cfg.jobs {
                Pc::PLockPublish
            } else {
                Pc::PShutdownLock
            };
            Ok(Some(vec![n]))
        }

        // ---- publisher: pool drop ----
        Pc::PShutdownSet => {
            let mut n = s.clone();
            n.shutdown = true;
            n.mutex = None;
            n.threads[t].pc = Pc::PShutdownNotify;
            Ok(Some(vec![n]))
        }
        Pc::PShutdownNotify => {
            // Unlocked notify_all(work_cv). Safe despite being unlocked:
            // `shutdown` was set under the mutex, so a worker that is not
            // yet parked will observe it at its next locked re-check.
            let mut n = s.clone();
            for w in 1..=cfg.workers {
                if n.work_waiters & (1 << w) != 0 {
                    n.threads[w].pc = Pc::WReacquire;
                }
            }
            n.work_waiters = 0;
            n.threads[t].pc = Pc::PDone;
            Ok(Some(vec![n]))
        }

        // ---- worker: park/join loop ----
        Pc::WEval => {
            let mut n = s.clone();
            if s.shutdown {
                n.mutex = None;
                n.threads[t].pc = Pc::WDone;
            } else if s.published && s.epoch != th.seen && s.cursor < chunks {
                // Join the job under the mutex: this is what pins the raw
                // job pointer for this worker's whole claim loop.
                n.participants += 1;
                n.threads[t].seen = s.epoch;
                n.mutex = None;
                n.threads[t].pc = Pc::Fetch;
            } else {
                n.threads[t].pc = Pc::WEnqueue;
            }
            Ok(Some(vec![n]))
        }
        Pc::WEnqueue => {
            let mut n = s.clone();
            n.work_waiters |= 1 << t;
            n.mutex = None;
            n.threads[t].pc = Pc::WParked;
            Ok(Some(vec![n]))
        }
        Pc::WLeave => {
            let mut n = s.clone();
            n.participants -= 1;
            if n.participants == 0 && cfg.bug != Some(Bug::NoLeaveNotify) {
                // notify_all(done_cv) under the mutex: the publisher's
                // participants-drained wakeup.
                notify_done(&mut n);
            }
            n.mutex = None;
            n.threads[t].pc = Pc::WLock;
            Ok(Some(vec![n]))
        }
    }
}

/// Exhaustively explore every schedule of `cfg` and check all protocol
/// properties. Returns statistics if no reachable state violates them.
pub fn check_pool_protocol(cfg: &Config) -> Result<ModelStats, Violation> {
    if cfg.workers > MAX_WORKERS {
        return Err(Violation::BadConfig("workers > 3"));
    }
    if cfg.chunks == 0 || cfg.chunks > MAX_CHUNKS {
        return Err(Violation::BadConfig("chunks must be in 1..=4"));
    }
    if cfg.claim == 0 || cfg.claim > MAX_CHUNKS {
        return Err(Violation::BadConfig("claim must be in 1..=4"));
    }
    if cfg.jobs == 0 || cfg.jobs > 3 {
        return Err(Violation::BadConfig("jobs must be in 1..=3"));
    }

    let mut threads = [IDLE; MAX_THREADS];
    threads[0] = Thread {
        pc: Pc::PLockPublish,
        ..IDLE
    };
    for w in 1..=cfg.workers {
        threads[w] = Thread {
            pc: Pc::WLock,
            ..IDLE
        };
    }
    let init = State {
        mutex: None,
        epoch: 0,
        published: false,
        shutdown: false,
        participants: 0,
        cursor: 0,
        remaining: 0,
        work_waiters: 0,
        done_wait: false,
        job_idx: 0,
        exec: [0; MAX_CHUNKS],
        threads,
    };

    let mut visited: HashSet<State> = HashSet::new();
    let mut stack: Vec<State> = Vec::new();
    visited.insert(init.clone());
    stack.push(init);

    while let Some(s) = stack.pop() {
        let mut any_enabled = false;
        for t in 0..=cfg.workers {
            if let Some(succs) = step(cfg, &s, t)? {
                any_enabled = true;
                for n in succs {
                    if visited.insert(n.clone()) {
                        if visited.len() > MAX_STATES {
                            return Err(Violation::StateSpaceExceeded);
                        }
                        stack.push(n);
                    }
                }
            }
        }
        if !any_enabled {
            // Terminal state: the only acceptable one is "everything done".
            let complete = s.threads[0].pc == Pc::PDone
                && (1..=cfg.workers).all(|w| s.threads[w].pc == Pc::WDone);
            if !complete {
                return Err(Violation::Deadlock);
            }
            debug_assert_eq!(s.job_idx as usize, cfg.jobs);
        }
    }
    Ok(ModelStats {
        states: visited.len(),
    })
}

/// The standard verification sweep run in CI: every correct-protocol
/// configuration the model supports at claim 1, plus a batched-claim
/// configuration. Returns total states explored across all configurations.
pub fn check_standard_configs() -> Result<ModelStats, Violation> {
    let mut states = 0;
    for workers in 1..=2 {
        for chunks in 1..=3 {
            for jobs in 1..=2 {
                states += check_pool_protocol(&Config::new(workers, chunks, 1, jobs))?.states;
            }
        }
    }
    // Batched claims: each fetch grabs 2 indices, tail batch is short.
    states += check_pool_protocol(&Config::new(2, 3, 2, 1))?.states;
    // Three sequential jobs: the seen-epoch staleness protocol.
    states += check_pool_protocol(&Config::new(1, 2, 1, 3))?.states;
    Ok(ModelStats { states })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn correct_protocol_is_exhaustively_safe() {
        let stats = check_standard_configs().expect("pool protocol must verify");
        // The sweep must be a real exploration, not a degenerate one.
        assert!(
            stats.states > 10_000,
            "suspiciously small state space: {}",
            stats.states
        );
    }

    #[test]
    fn three_workers_one_job_verifies() {
        let stats = check_pool_protocol(&Config::new(3, 3, 1, 1)).expect("must verify");
        assert!(stats.states > 1_000);
    }

    #[test]
    fn split_claim_fetch_is_caught() {
        // Breaking the claim fetch_add into load + store must surface as a
        // double-claimed (or, downstream, lost) chunk.
        let cfg = Config {
            bug: Some(Bug::SplitClaimFetch),
            ..Config::new(2, 2, 1, 1)
        };
        match check_pool_protocol(&cfg) {
            Err(Violation::DoubleClaim { .. }) | Err(Violation::UnclaimedChunk { .. }) => {}
            other => panic!("expected a claim violation, got {other:?}"),
        }
    }

    #[test]
    fn dropped_leave_notify_is_caught_as_deadlock() {
        // Without the participants-drained notify the publisher can park
        // forever: worker decrements participants to zero silently after
        // the publisher re-enqueued.
        let cfg = Config {
            bug: Some(Bug::NoLeaveNotify),
            ..Config::new(1, 2, 1, 1)
        };
        assert_eq!(check_pool_protocol(&cfg), Err(Violation::Deadlock));
    }

    #[test]
    fn oversized_configs_are_rejected_not_truncated() {
        assert!(matches!(
            check_pool_protocol(&Config::new(9, 2, 1, 1)),
            Err(Violation::BadConfig(_))
        ));
        assert!(matches!(
            check_pool_protocol(&Config::new(1, 0, 1, 1)),
            Err(Violation::BadConfig(_))
        ));
    }
}
