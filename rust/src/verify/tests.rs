//! Mutation tests for the static plan verifier: every valid compiled plan
//! must verify clean, and each class of corruption must be rejected with its
//! specific error variant. The corruptions are the verifier's "bug
//! injections" — evidence that a passing [`CompiledPlan::verify`] means
//! something.

use super::*;
use crate::einsum::ConvKind;
use crate::exec::compile_expr;
use crate::planner::{PlanOptions, Strategy};
use std::sync::Arc;

/// A 2-input convolutional plan over the given variety pair.
fn conv_plan(kind: ConvKind) -> CompiledPlan {
    let opts = PlanOptions {
        conv_kinds: Some(vec![kind, kind]),
        ..PlanOptions::default()
    };
    compile_expr(
        "bsxy,tsxy->btxy|xy",
        &[vec![2, 3, 6, 5], vec![4, 3, 3, 3]],
        &opts,
    )
    .expect("conv plan must compile")
}

/// A 3-step matmul chain with equal-size intermediates (so a step reorder
/// is caught by the dataflow simulation, not by a shape mismatch).
fn chain_plan() -> CompiledPlan {
    let opts = PlanOptions {
        strategy: Strategy::LeftToRight,
        ..PlanOptions::default()
    };
    compile_expr(
        "ij,jk,kl,lm->im",
        &[vec![2, 3], vec![3, 4], vec![4, 4], vec![4, 5]],
        &opts,
    )
    .expect("chain plan must compile")
}

// ---------------------------------------------------------------------------
// Valid plans pass
// ---------------------------------------------------------------------------

#[test]
fn valid_plans_verify_across_all_conv_kinds() {
    for kind in [
        ConvKind::Circular,
        ConvKind::Same,
        ConvKind::Valid,
        ConvKind::Full,
    ] {
        let cp = conv_plan(kind);
        cp.verify()
            .unwrap_or_else(|e| panic!("{kind:?} plan must verify: {e}"));
    }
}

#[test]
fn valid_plans_verify_across_strategies_and_training() {
    for strategy in [Strategy::Optimal, Strategy::Greedy, Strategy::LeftToRight] {
        for training in [false, true] {
            let opts = PlanOptions {
                strategy,
                training,
                ..PlanOptions::default()
            };
            let cp = compile_expr(
                "ij,jk,kl->il",
                &[vec![3, 4], vec![4, 5], vec![5, 2]],
                &opts,
            )
            .expect("must compile");
            cp.verify().unwrap_or_else(|e| {
                panic!("{strategy:?} training={training} plan must verify: {e}")
            });
        }
    }
}

#[test]
fn multiway_circular_plan_verifies() {
    let cp = compile_expr(
        "isx,stx,tjx->ijx|x",
        &[vec![2, 3, 5], vec![3, 4, 5], vec![4, 2, 5]],
        &PlanOptions::default(),
    )
    .expect("multi-way circular plan must compile");
    cp.verify().expect("must verify");
}

// ---------------------------------------------------------------------------
// Mutations are rejected with the right variant
// ---------------------------------------------------------------------------

#[test]
fn mutation_plan_cost_inflation_is_flop_mismatch() {
    let mut cp = conv_plan(ConvKind::Same);
    Arc::make_mut(&mut cp.plan).cost += 1.0e12;
    assert!(matches!(
        cp.verify(),
        Err(VerifyError::FlopMismatch { step: None, .. })
    ));
}

#[test]
fn mutation_step_cost_inflation_is_per_step_flop_mismatch() {
    let mut cp = conv_plan(ConvKind::Same);
    Arc::make_mut(&mut cp.plan).steps[0].cost += 1.0e9;
    assert!(matches!(
        cp.verify(),
        Err(VerifyError::FlopMismatch { step: Some(0), .. })
    ));
}

#[test]
fn mutation_stale_kernel_version_is_rejected() {
    let mut cp = conv_plan(ConvKind::Circular);
    cp.steps[0].kernel.order_version = ACCUM_ORDER_VERSION + 999;
    match cp.verify() {
        Err(VerifyError::KernelOrderVersion {
            step: 0,
            found,
            expected,
        }) => {
            assert_eq!(found, ACCUM_ORDER_VERSION + 999);
            assert_eq!(expected, ACCUM_ORDER_VERSION);
        }
        other => panic!("expected KernelOrderVersion, got {other:?}"),
    }
}

#[test]
fn mutation_truncated_inverse_permutation_is_rejected() {
    let mut cp = conv_plan(ConvKind::Full);
    cp.steps[0].inv_out_perm.pop();
    assert_eq!(
        cp.verify(),
        Err(VerifyError::BadPermutation {
            step: Some(0),
            what: "inv_out_perm",
        })
    );
}

#[test]
fn mutation_wild_gather_stride_is_out_of_bounds() {
    let mut cp = conv_plan(ConvKind::Same);
    // Point some axis of extent ≥ 2 at a stride of a full canonical-buffer
    // length: the last addressable element lands past the buffer.
    let grad = &mut cp.steps[0].grad_a;
    let ax = grad
        .out_shape
        .iter()
        .position(|&d| d >= 2)
        .expect("operand has a non-trivial axis");
    grad.strides[ax] = usize::MAX / 4;
    match cp.verify() {
        Err(VerifyError::GatherOutOfBounds { step: 0, operand }) => assert_eq!(operand, 'a'),
        other => panic!("expected GatherOutOfBounds, got {other:?}"),
    }
}

#[test]
fn mutation_overflowing_gather_stride_is_offset_overflow() {
    let mut cp = conv_plan(ConvKind::Same);
    let grad = &mut cp.steps[0].grad_b;
    let ax = grad
        .out_shape
        .iter()
        .position(|&d| d >= 2)
        .expect("operand has a non-trivial axis");
    // (d − 1) · MAX overflows the checked multiply before any bound check.
    grad.strides[ax] = usize::MAX;
    assert_eq!(
        cp.verify(),
        Err(VerifyError::OffsetOverflow {
            step: Some(0),
            what: "grad gather offset",
        })
    );
}

#[test]
fn mutation_reordered_steps_are_read_before_write() {
    let mut cp = chain_plan();
    assert!(cp.steps.len() >= 3, "left-to-right chain has 3 steps");
    // Swap the first two steps in both the compiled program and the plan it
    // mirrors (so every per-step structural and cost check still matches and
    // the *schedule* is the only corruption). Step 1 consumes step 0's
    // intermediate, so the swapped schedule reads it before it exists. The
    // chain's dims make both intermediates the same size — a pure
    // use-before-def, not a shape mismatch.
    cp.steps.swap(0, 1);
    Arc::make_mut(&mut cp.plan).steps.swap(0, 1);
    assert!(matches!(
        cp.verify(),
        Err(VerifyError::ReadBeforeWrite {
            context: SimContext::Inference,
            ..
        })
    ));
}

#[test]
fn mutation_overlapping_training_slots_are_rejected() {
    let cp = conv_plan(ConvKind::Same);
    let mut layout = (*cp.train_layout(CkptPolicy::StoreAll)).clone();
    // Relocate the first forward output onto input 0's live slot (same
    // length, so only the liveness invariant is violated). Input 0 is read
    // again by the backward, so the clobber must be fatal.
    let out_len = layout.fwd[0].out.len();
    let start = layout.input_ranges[0].start;
    layout.fwd[0].out = start..start + out_len;
    assert!(matches!(
        cp.verify_train_layout(&layout),
        Err(VerifyError::OverlappingLiveSlots {
            context: SimContext::Train(CkptPolicy::StoreAll),
            ..
        })
    ));
    // The unmutated layout still verifies (the clone was independent).
    for policy in CkptPolicy::ALL {
        cp.verify_train_layout(&cp.train_layout(policy))
            .expect("unmutated layout must verify");
    }
}

#[test]
fn offline_arena_packing_never_peaks_above_best_fit() {
    // The shipped training layout (offline interval packing when it wins,
    // online best-fit otherwise) must never peak above the plain best-fit
    // pass — for every checkpoint policy, on both a recompute-heavy chain
    // and a conv plan — and must still satisfy the liveness verifier.
    for cp in [chain_plan(), conv_plan(ConvKind::Same)] {
        for policy in CkptPolicy::ALL {
            let layout = cp.train_layout(policy);
            let bestfit = cp.train_layout_bestfit_elems(policy);
            assert!(
                layout.arena_elems() <= bestfit,
                "{policy:?}: packed peak {} exceeds best-fit peak {bestfit}",
                layout.arena_elems()
            );
            cp.verify_train_layout(&layout)
                .expect("packed layout must verify");
        }
    }
}

#[test]
fn mutation_truncated_final_permutation_is_rejected() {
    // A plan whose output order forces a final permutation.
    let mut cp = compile_expr(
        "ij,jk->ki",
        &[vec![3, 4], vec![4, 5]],
        &PlanOptions::default(),
    )
    .expect("must compile");
    assert!(
        cp.inv_final_perm.is_some(),
        "transposed output must carry a final permutation"
    );
    cp.inv_final_perm = None;
    assert!(matches!(
        cp.verify(),
        Err(VerifyError::Malformed { .. }) | Err(VerifyError::BadPermutation { .. })
    ));
}

// ---------------------------------------------------------------------------
// Error formatting is stable enough to grep in CI logs
// ---------------------------------------------------------------------------

#[test]
fn verify_errors_display_their_context() {
    let e = VerifyError::OverlappingLiveSlots {
        context: SimContext::Train(CkptPolicy::Sqrt),
        writer: 7,
        clobbered: 2,
    };
    let msg = e.to_string();
    assert!(msg.contains("training schedule"), "{msg}");
    assert!(msg.contains("node 7"), "{msg}");
    let e = VerifyError::KernelOrderVersion {
        step: 3,
        found: 0,
        expected: ACCUM_ORDER_VERSION,
    };
    assert!(e.to_string().contains("accumulation-order version"));
}
