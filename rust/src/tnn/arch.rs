//! Architecture shape tables for the paper's evaluation workloads:
//! ResNet-34/ResNet-101 convolution inventories (He et al. 2016, Table 1),
//! the Conformer convolution module (Gulati et al. 2020) and the two-stream
//! action-recognition network (Simonyan & Zisserman 2014).
//!
//! Only the *convolution layer shapes* matter for reproducing the paper's
//! FLOPs/runtime/memory results — FLOPs are "purely a function of the
//! tensor dimensions" (paper §5) — so these tables carry exactly that.

/// One convolutional layer site: kernel `T×S×H×W` applied to a `H'×W'`
/// feature map, with a repetition count for identical layers in a stage.
#[derive(Debug, Clone, PartialEq)]
pub struct ConvSite {
    /// Paper/He-et-al. stage name, e.g. "conv3_x".
    pub stage: &'static str,
    /// Output channels.
    pub t: usize,
    /// Input channels.
    pub s: usize,
    /// Kernel height/width.
    pub h: usize,
    pub w: usize,
    /// Feature-map size the kernel runs over.
    pub hp: usize,
    pub wp: usize,
    /// Number of identical layers at this site.
    pub count: usize,
}

impl ConvSite {
    pub fn kernel_params(&self) -> usize {
        self.t * self.s * self.h * self.w
    }
}

/// ResNet-34 convolution inventory on 224×224 inputs (He et al. Table 1).
/// Stage rows aggregate the 3×3 convolutions of their basic blocks; the
/// first conv of each of conv3–conv5 downsamples (stride 2) and maps
/// S(prev)→T channels — represented as a separate site.
pub fn resnet34_imagenet() -> Vec<ConvSite> {
    vec![
        ConvSite { stage: "conv1", t: 64, s: 3, h: 7, w: 7, hp: 112, wp: 112, count: 1 },
        // conv2_x: 3 basic blocks × 2 convs, 64→64 on 56×56
        ConvSite { stage: "conv2_x", t: 64, s: 64, h: 3, w: 3, hp: 56, wp: 56, count: 6 },
        // conv3_x: 4 blocks × 2 convs at 28×28; first conv is 64→128
        ConvSite { stage: "conv3_x", t: 128, s: 64, h: 3, w: 3, hp: 28, wp: 28, count: 1 },
        ConvSite { stage: "conv3_x", t: 128, s: 128, h: 3, w: 3, hp: 28, wp: 28, count: 7 },
        // conv4_x: 6 blocks × 2 convs at 14×14; first is 128→256
        ConvSite { stage: "conv4_x", t: 256, s: 128, h: 3, w: 3, hp: 14, wp: 14, count: 1 },
        ConvSite { stage: "conv4_x", t: 256, s: 256, h: 3, w: 3, hp: 14, wp: 14, count: 11 },
        // conv5_x: 3 blocks × 2 convs at 7×7; first is 256→512
        ConvSite { stage: "conv5_x", t: 512, s: 256, h: 3, w: 3, hp: 7, wp: 7, count: 1 },
        ConvSite { stage: "conv5_x", t: 512, s: 512, h: 3, w: 3, hp: 7, wp: 7, count: 5 },
    ]
}

/// ResNet-34 scaled to CIFAR-10's 32×32 inputs (conv1 is 3×3 and no initial
/// downsampling, the common CIFAR adaptation).
pub fn resnet34_cifar10() -> Vec<ConvSite> {
    vec![
        ConvSite { stage: "conv1", t: 64, s: 3, h: 3, w: 3, hp: 32, wp: 32, count: 1 },
        ConvSite { stage: "conv2_x", t: 64, s: 64, h: 3, w: 3, hp: 32, wp: 32, count: 6 },
        ConvSite { stage: "conv3_x", t: 128, s: 64, h: 3, w: 3, hp: 16, wp: 16, count: 1 },
        ConvSite { stage: "conv3_x", t: 128, s: 128, h: 3, w: 3, hp: 16, wp: 16, count: 7 },
        ConvSite { stage: "conv4_x", t: 256, s: 128, h: 3, w: 3, hp: 8, wp: 8, count: 1 },
        ConvSite { stage: "conv4_x", t: 256, s: 256, h: 3, w: 3, hp: 8, wp: 8, count: 11 },
        ConvSite { stage: "conv5_x", t: 512, s: 256, h: 3, w: 3, hp: 4, wp: 4, count: 1 },
        ConvSite { stage: "conv5_x", t: 512, s: 512, h: 3, w: 3, hp: 4, wp: 4, count: 5 },
    ]
}

/// The 3×3-conv inventory of ResNet-101 bottleneck stages (for the
/// two-stream video classification streams). Only the 3×3 convs are
/// tensorized in the paper's VC experiments.
pub fn resnet101_imagenet() -> Vec<ConvSite> {
    vec![
        ConvSite { stage: "conv1", t: 64, s: 3, h: 7, w: 7, hp: 112, wp: 112, count: 1 },
        ConvSite { stage: "conv2_x", t: 64, s: 64, h: 3, w: 3, hp: 56, wp: 56, count: 3 },
        ConvSite { stage: "conv3_x", t: 128, s: 128, h: 3, w: 3, hp: 28, wp: 28, count: 4 },
        ConvSite { stage: "conv4_x", t: 256, s: 256, h: 3, w: 3, hp: 14, wp: 14, count: 23 },
        ConvSite { stage: "conv5_x", t: 512, s: 512, h: 3, w: 3, hp: 7, wp: 7, count: 3 },
    ]
}

/// Conformer convolution module sites (ASR): depthwise + pointwise convs
/// over time on `d_model` channels and ~T=256-frame features. The paper's
/// CP-TNN tensorizes the pointwise/depthwise kernels. 1-D convolution is
/// represented with W'=1, W=1.
pub fn conformer_conv_modules(d_model: usize, frames: usize, n_blocks: usize) -> Vec<ConvSite> {
    let mut sites = Vec::new();
    for _ in 0..n_blocks {
        // pointwise expansion 1×1 (2× expansion, GLU halves it back)
        sites.push(ConvSite {
            stage: "pw_expand",
            t: 2 * d_model,
            s: d_model,
            h: 1,
            w: 1,
            hp: frames,
            wp: 1,
            count: 1,
        });
        // depthwise temporal conv, kernel 31 (represented densely as the
        // grouped kernel it factorizes from)
        sites.push(ConvSite {
            stage: "dw_conv",
            t: d_model,
            s: d_model,
            h: 31,
            w: 1,
            hp: frames,
            wp: 1,
            count: 1,
        });
        // pointwise projection
        sites.push(ConvSite {
            stage: "pw_proj",
            t: d_model,
            s: d_model,
            h: 1,
            w: 1,
            hp: frames,
            wp: 1,
            count: 1,
        });
    }
    sites
}

/// Spatial stream of the two-stream network: ResNet-101 over RGB frames.
pub fn two_stream_spatial() -> Vec<ConvSite> {
    resnet101_imagenet()
}

/// Temporal stream: ResNet-101 whose conv1 ingests stacked optical flow
/// (2 channels × 10 frames = 20 input channels).
pub fn two_stream_temporal() -> Vec<ConvSite> {
    let mut sites = resnet101_imagenet();
    sites[0].s = 20;
    sites
}

/// Scale a site inventory down by `spatial` (feature map + channel divisor)
/// for laptop-scale reproduction runs. Kernel sizes are preserved; channels
/// and feature maps shrink, keeping every site's *structure*.
pub fn scaled(sites: &[ConvSite], channel_div: usize, spatial_div: usize) -> Vec<ConvSite> {
    sites
        .iter()
        .map(|s| ConvSite {
            stage: s.stage,
            t: (s.t / channel_div).max(4),
            s: if s.s <= 3 { s.s } else { (s.s / channel_div).max(4) },
            h: s.h,
            w: s.w,
            hp: (s.hp / spatial_div).max(s.h),
            wp: (s.wp / spatial_div).max(s.w),
            count: s.count,
        })
        .collect()
}

/// The distinct stage names of an inventory, in order.
pub fn stages(sites: &[ConvSite]) -> Vec<&'static str> {
    let mut out = Vec::new();
    for s in sites {
        if !out.contains(&s.stage) {
            out.push(s.stage);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet34_layer_counts() {
        // 1 + 6 + 8 + 12 + 6 = 33 convs (+ the fc makes 34 weight layers).
        let total: usize = resnet34_imagenet().iter().map(|s| s.count).sum();
        assert_eq!(total, 33);
        let total: usize = resnet34_cifar10().iter().map(|s| s.count).sum();
        assert_eq!(total, 33);
    }

    #[test]
    fn resnet34_channel_progression() {
        let sites = resnet34_imagenet();
        assert_eq!(sites[0].s, 3);
        assert_eq!(sites.last().unwrap().t, 512);
        // feature maps shrink monotonically along stages
        for w in sites.windows(2) {
            assert!(w[0].hp >= w[1].hp);
        }
    }

    #[test]
    fn conformer_sites_shape() {
        let sites = conformer_conv_modules(144, 256, 4);
        assert_eq!(sites.len(), 12);
        assert!(sites.iter().all(|s| s.wp == 1 && s.w == 1));
        assert_eq!(sites[0].t, 288);
        assert_eq!(sites[1].h, 31);
    }

    #[test]
    fn temporal_stream_ingests_flow_stack() {
        assert_eq!(two_stream_temporal()[0].s, 20);
        assert_eq!(two_stream_spatial()[0].s, 3);
    }

    #[test]
    fn scaled_preserves_structure() {
        let sites = scaled(&resnet34_imagenet(), 8, 4);
        assert_eq!(sites.len(), resnet34_imagenet().len());
        assert!(sites.iter().all(|s| s.h == 3 || s.h == 7));
        assert!(sites.iter().all(|s| s.hp >= s.h));
        assert_eq!(stages(&sites).len(), 5);
    }
}
