//! The TNN layer zoo (paper §2.3, Appendix A.3): tensorial convolutional
//! layers built from CP / Tucker / Tensor-Train / Tensor-Ring / Block-Term /
//! Hierarchical-Tucker factorizations of a `T×S×H×W` convolution kernel,
//! plus their *reshaped* variants (channel modes split into M factors), and
//! the compression-rate mechanism that trims ranks until the layer holds
//! ≤ CR·(original parameters).
//!
//! Every layer is just a conv_einsum string over its factor tensors —
//! [`TnnLayerSpec::expr`] — so it plugs straight into the planner and the
//! path executor/autodiff.

pub mod arch;

mod factorize;

pub use factorize::{balanced_factors, solve_ranks};

use crate::einsum::{parse, SizedSpec};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Supported tensor decompositions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Decomp {
    Cp,
    Tucker,
    TensorTrain,
    TensorRing,
    BlockTerm,
    HierarchicalTucker,
}

impl Decomp {
    pub fn name(&self) -> &'static str {
        match self {
            Decomp::Cp => "CP",
            Decomp::Tucker => "TK",
            Decomp::TensorTrain => "TT",
            Decomp::TensorRing => "TR",
            Decomp::BlockTerm => "BT",
            Decomp::HierarchicalTucker => "HT",
        }
    }

    /// All decompositions with both flat and reshaped layer constructions.
    pub fn all() -> [Decomp; 6] {
        [
            Decomp::Cp,
            Decomp::Tucker,
            Decomp::TensorTrain,
            Decomp::TensorRing,
            Decomp::BlockTerm,
            Decomp::HierarchicalTucker,
        ]
    }
}

/// A fully-specified tensorial convolutional layer.
#[derive(Debug, Clone)]
pub struct TnnLayerSpec {
    pub decomp: Decomp,
    /// Channel reshape order; 1 = unreshaped ("flat") variant.
    pub m: usize,
    /// Original kernel dims.
    pub t: usize,
    pub s: usize,
    pub h: usize,
    pub w: usize,
    /// Channel factorizations (length m; products equal t and s).
    pub t_factors: Vec<usize>,
    pub s_factors: Vec<usize>,
    /// Solved rank values (interpretation depends on the decomposition).
    pub ranks: Vec<usize>,
    /// The layer's forward conv_einsum string (batch mode `b` included);
    /// the input carries the *reshaped* channel modes.
    pub expr: String,
    /// Kernel-reconstruction einsum (factors → reshaped kernel, no conv).
    pub kernel_expr: String,
    /// Shapes of the factor tensors, in the order they appear in `expr`
    /// after the input.
    pub factor_shapes: Vec<Vec<usize>>,
    /// Total learnable parameters across factors.
    pub params: usize,
}

impl TnnLayerSpec {
    /// Parameters of the original dense kernel this layer replaces.
    pub fn original_params(&self) -> usize {
        self.t * self.s * self.h * self.w
    }

    /// Achieved compression rate (params / original).
    pub fn achieved_cr(&self) -> f64 {
        self.params as f64 / self.original_params() as f64
    }

    /// Shape the layer expects for the (reshaped) input given batch and
    /// spatial sizes: `[B, S1..SM, H', W']`.
    pub fn input_shape(&self, batch: usize, hp: usize, wp: usize) -> Vec<usize> {
        let mut shape = vec![batch];
        shape.extend(&self.s_factors);
        shape.push(hp);
        shape.push(wp);
        shape
    }

    /// Output shape `[B, T1..TM, H', W']` (Same padding).
    pub fn output_shape(&self, batch: usize, hp: usize, wp: usize) -> Vec<usize> {
        let mut shape = vec![batch];
        shape.extend(&self.t_factors);
        shape.push(hp);
        shape.push(wp);
        shape
    }

    /// The dims list for the layer's expression at a given batch/spatial
    /// size: input dims followed by factor dims.
    pub fn expr_dims(&self, batch: usize, hp: usize, wp: usize) -> Vec<Vec<usize>> {
        let mut dims = vec![self.input_shape(batch, hp, wp)];
        dims.extend(self.factor_shapes.iter().cloned());
        dims
    }

    /// Initialize factor tensors. Each factor uses fan-in-scaled normals so
    /// the reconstructed kernel has roughly He-init variance.
    pub fn init_factors(&self, rng: &mut Rng) -> Vec<Tensor> {
        let n_factors = self.factor_shapes.len() as f64;
        // Aim for per-element kernel std ≈ sqrt(2 / (S·H·W)); each factor
        // contributes multiplicatively, so take the 1/n-th root heuristic.
        let kernel_std = (2.0 / (self.s * self.h * self.w) as f64).sqrt();
        // The reconstruction sums over rank components; normalize by the
        // total rank product to keep variance bounded.
        let rank_prod: f64 = self.ranks.iter().map(|&r| r as f64).product::<f64>().max(1.0);
        let per_factor = (kernel_std / rank_prod.sqrt()).powf(1.0 / n_factors);
        self.factor_shapes
            .iter()
            .map(|shape| Tensor::randn(shape, 0.0, per_factor as f32, rng))
            .collect()
    }

    /// Reconstruct the full (reshaped) kernel from factors, then reshape to
    /// the dense `[T, S, H, W]` kernel. Ground truth for equivalence tests.
    pub fn reconstruct_kernel(&self, factors: &[Tensor]) -> Tensor {
        let refs: Vec<&Tensor> = factors.iter().collect();
        let k = crate::exec::conv_einsum(&self.kernel_expr, &refs)
            .expect("kernel reconstruction must evaluate");
        // kernel_expr output modes: (t1..tM)(s1..sM)hw
        k.reshape(&[self.t, self.s, self.h, self.w])
    }
}

/// Build a tensorial layer for kernel `T×S×H×W` under `decomp`, reshape
/// order `m` (1 = flat), targeting compression rate `cr` ∈ (0, 1].
pub fn build_layer(
    decomp: Decomp,
    m: usize,
    t: usize,
    s: usize,
    h: usize,
    w: usize,
    cr: f64,
) -> Result<TnnLayerSpec, String> {
    if m == 0 {
        return Err("reshape order m must be ≥ 1".into());
    }
    if decomp == Decomp::HierarchicalTucker && m < 2 {
        return Err("hierarchical Tucker requires a reshaped kernel (m ≥ 2)".into());
    }
    if !(0.0..=1.0).contains(&cr) || cr == 0.0 {
        return Err(format!("compression rate {} outside (0,1]", cr));
    }
    let t_factors = balanced_factors(t, m);
    let s_factors = balanced_factors(s, m);
    let target = (cr * (t * s * h * w) as f64).ceil().max(1.0);

    let builder = LayerBuilder {
        decomp,
        m,
        t,
        s,
        h,
        w,
        t_factors: t_factors.clone(),
        s_factors: s_factors.clone(),
    };
    let ranks = solve_ranks(&builder, target)?;
    let (expr, kernel_expr, factor_shapes) = builder.strings_and_shapes(&ranks);
    let params = factor_shapes.iter().map(|s| s.iter().product::<usize>()).sum();

    // Sanity: the expression must parse and size correctly.
    let spec = parse(&expr).map_err(|e| e.to_string())?;
    let dims = {
        let mut d = vec![{
            let mut v = vec![2];
            v.extend(&s_factors);
            v.push(h.max(2) * 2);
            v.push(w.max(2) * 2);
            v
        }];
        d.extend(factor_shapes.iter().cloned());
        d
    };
    SizedSpec::new(spec, dims)?;

    Ok(TnnLayerSpec {
        decomp,
        m,
        t,
        s,
        h,
        w,
        t_factors,
        s_factors,
        ranks,
        expr,
        kernel_expr,
        factor_shapes,
        params,
    })
}

/// Internal: generates strings + shapes per decomposition given ranks.
pub(crate) struct LayerBuilder {
    pub decomp: Decomp,
    pub m: usize,
    pub t: usize,
    pub s: usize,
    pub h: usize,
    pub w: usize,
    pub t_factors: Vec<usize>,
    pub s_factors: Vec<usize>,
}

impl LayerBuilder {
    /// Number of independent rank variables for this decomposition/reshape.
    pub fn n_ranks(&self) -> usize {
        let m = self.m;
        match (self.decomp, m) {
            (Decomp::Cp, _) => 1,
            (Decomp::Tucker, 1) => 2,        // (r1)t, (r2)s, core
            (Decomp::Tucker, _) => m + 1,    // r0..rm
            (Decomp::TensorTrain, 1) => 3,   // r1,r2,r3
            (Decomp::TensorTrain, _) => m,   // r1..rm (rM feeds W0)
            (Decomp::TensorRing, 1) => 4,    // r0..r3
            (Decomp::TensorRing, _) => m + 1, // r0..rm
            (Decomp::BlockTerm, _) => m + 2, // r, r0..rm
            (Decomp::HierarchicalTucker, _) => {
                // leaf ranks r0..rm plus internal ranks: a binary tree over
                // (m+1) leaves has m-1 internal edges (root excluded).
                (m + 1) + (m - 1).max(1)
            }
        }
    }

    /// Max sensible value per rank position (used by the solver as an upper
    /// bound; CP-style ranks can exceed min dims so give them headroom).
    pub fn rank_cap(&self) -> usize {
        let full = self.t * self.s * self.h * self.w;
        full.min(4096)
    }

    /// Parameter count for a rank assignment.
    pub fn params(&self, ranks: &[usize]) -> usize {
        let (_, _, shapes) = self.strings_and_shapes(ranks);
        shapes.iter().map(|s| s.iter().product::<usize>()).sum()
    }

    /// (layer expr, kernel reconstruction expr, factor shapes).
    pub fn strings_and_shapes(&self, ranks: &[usize]) -> (String, String, Vec<Vec<usize>>) {
        let m = self.m;
        let (t, s, h, w) = (self.t, self.s, self.h, self.w);
        let tf = &self.t_factors;
        let sf = &self.s_factors;

        // Subscript fragments for channel modes.
        let smodes: String = (1..=m).map(|i| format!("(s{i})")).collect();
        let tmodes: String = (1..=m).map(|i| format!("(t{i})")).collect();
        let x_sub = format!("b{smodes}hw");
        let out_sub = format!("b{tmodes}hw");

        match (self.decomp, m) {
            // ---- CP ------------------------------------------------------
            (Decomp::Cp, 1) => {
                let r = ranks[0];
                (
                    "bshw,rt,rs,rh,rw->bthw|hw".into(),
                    "rt,rs,rh,rw->tshw".into(),
                    vec![vec![r, t], vec![r, s], vec![r, h], vec![r, w]],
                )
            }
            (Decomp::Cp, _) => {
                let r = ranks[0];
                let mut lhs = vec![x_sub.clone()];
                let mut klhs = Vec::new();
                let mut shapes = Vec::new();
                for i in 1..=m {
                    lhs.push(format!("r(t{i})(s{i})"));
                    klhs.push(format!("r(t{i})(s{i})"));
                    shapes.push(vec![r, tf[i - 1], sf[i - 1]]);
                }
                lhs.push("rhw".into());
                klhs.push("rhw".into());
                shapes.push(vec![r, h, w]);
                (
                    format!("{}->{}|hw", lhs.join(","), out_sub),
                    format!("{}->{}{}hw", klhs.join(","), tmodes, smodes),
                    shapes,
                )
            }
            // ---- Tucker --------------------------------------------------
            (Decomp::Tucker, 1) => {
                let (r1, r2) = (ranks[0], ranks[1]);
                (
                    "bshw,(r1)t,(r2)s,(r1)(r2)hw->bthw|hw".into(),
                    "(r1)t,(r2)s,(r1)(r2)hw->tshw".into(),
                    vec![vec![r1, t], vec![r2, s], vec![r1, r2, h, w]],
                )
            }
            (Decomp::Tucker, _) => {
                // ranks = [r0, r1..rm]; core C ∈ R^{r0×r1×…×rm}, W0 ∈ R^{r0×h×w}
                let r0 = ranks[0];
                let mut lhs = vec![x_sub.clone()];
                let mut klhs = Vec::new();
                let mut shapes = Vec::new();
                for i in 1..=m {
                    lhs.push(format!("(r{i})(t{i})(s{i})"));
                    klhs.push(format!("(r{i})(t{i})(s{i})"));
                    shapes.push(vec![ranks[i], tf[i - 1], sf[i - 1]]);
                }
                lhs.push("(r0)hw".into());
                klhs.push("(r0)hw".into());
                shapes.push(vec![r0, h, w]);
                let core_modes: String = (0..=m).map(|i| format!("(r{i})")).collect();
                lhs.push(core_modes.clone());
                klhs.push(core_modes);
                shapes.push(ranks.to_vec());
                (
                    format!("{}->{}|hw", lhs.join(","), out_sub),
                    format!("{}->{}{}hw", klhs.join(","), tmodes, smodes),
                    shapes,
                )
            }
            // ---- Tensor-Train ---------------------------------------------
            (Decomp::TensorTrain, 1) => {
                let (r1, r2, r3) = (ranks[0], ranks[1], ranks[2]);
                (
                    "bshw,(r1)t,(r1)(r2)h,(r2)(r3)w,(r3)s->bthw|hw".into(),
                    "(r1)t,(r1)(r2)h,(r2)(r3)w,(r3)s->tshw".into(),
                    vec![
                        vec![r1, t],
                        vec![r1, r2, h],
                        vec![r2, r3, w],
                        vec![r3, s],
                    ],
                )
            }
            (Decomp::TensorTrain, _) => {
                // ranks = [r1..rm]; cores: (r1 t1 s1), (r(i-1) r(i) t(i) s(i)), (rm h w)
                let mut lhs = vec![x_sub.clone()];
                let mut klhs = Vec::new();
                let mut shapes = Vec::new();
                lhs.push("(r1)(t1)(s1)".into());
                klhs.push("(r1)(t1)(s1)".into());
                shapes.push(vec![ranks[0], tf[0], sf[0]]);
                for i in 2..=m {
                    lhs.push(format!("(r{})(r{})(t{})(s{})", i - 1, i, i, i));
                    klhs.push(format!("(r{})(r{})(t{})(s{})", i - 1, i, i, i));
                    shapes.push(vec![ranks[i - 2], ranks[i - 1], tf[i - 1], sf[i - 1]]);
                }
                lhs.push(format!("(r{m})hw"));
                klhs.push(format!("(r{m})hw"));
                shapes.push(vec![ranks[m - 1], h, w]);
                (
                    format!("{}->{}|hw", lhs.join(","), out_sub),
                    format!("{}->{}{}hw", klhs.join(","), tmodes, smodes),
                    shapes,
                )
            }
            // ---- Tensor-Ring ---------------------------------------------
            (Decomp::TensorRing, 1) => {
                let (r0, r1, r2, r3) = (ranks[0], ranks[1], ranks[2], ranks[3]);
                (
                    "bshw,(r0)(r1)t,(r1)(r2)h,(r2)(r3)w,(r3)(r0)s->bthw|hw".into(),
                    "(r0)(r1)t,(r1)(r2)h,(r2)(r3)w,(r3)(r0)s->tshw".into(),
                    vec![
                        vec![r0, r1, t],
                        vec![r1, r2, h],
                        vec![r2, r3, w],
                        vec![r3, r0, s],
                    ],
                )
            }
            (Decomp::TensorRing, _) => {
                // ranks = [r0..rm]; cores (r(i-1) r(i) t(i) s(i)), W0 (rm r0 h w)
                let mut lhs = vec![x_sub.clone()];
                let mut klhs = Vec::new();
                let mut shapes = Vec::new();
                for i in 1..=m {
                    lhs.push(format!("(r{})(r{})(t{})(s{})", i - 1, i, i, i));
                    klhs.push(format!("(r{})(r{})(t{})(s{})", i - 1, i, i, i));
                    shapes.push(vec![ranks[i - 1], ranks[i], tf[i - 1], sf[i - 1]]);
                }
                lhs.push(format!("(r{m})(r0)hw"));
                klhs.push(format!("(r{m})(r0)hw"));
                shapes.push(vec![ranks[m], ranks[0], h, w]);
                (
                    format!("{}->{}|hw", lhs.join(","), out_sub),
                    format!("{}->{}{}hw", klhs.join(","), tmodes, smodes),
                    shapes,
                )
            }
            // ---- Block-Term -----------------------------------------------
            (Decomp::BlockTerm, _) => {
                // ranks = [r, r0, r1..rm]
                let r = ranks[0];
                let r0 = ranks[1];
                let mut lhs = vec![x_sub.clone()];
                let mut klhs = Vec::new();
                let mut shapes = Vec::new();
                for i in 1..=m {
                    lhs.push(format!("r(r{i})(t{i})(s{i})"));
                    klhs.push(format!("r(r{i})(t{i})(s{i})"));
                    shapes.push(vec![r, ranks[i + 1], tf[i - 1], sf[i - 1]]);
                }
                lhs.push("r(r0)hw".into());
                klhs.push("r(r0)hw".into());
                shapes.push(vec![r, r0, h, w]);
                let core: String =
                    format!("r{}(r0)", (1..=m).map(|i| format!("(r{i})")).collect::<String>());
                lhs.push(core.clone());
                klhs.push(core);
                {
                    let mut c = vec![r];
                    c.extend(&ranks[2..]);
                    c.push(r0);
                    shapes.push(c);
                }
                (
                    format!("{}->{}|hw", lhs.join(","), out_sub),
                    format!("{}->{}{}hw", klhs.join(","), tmodes, smodes),
                    shapes,
                )
            }
            // ---- Hierarchical Tucker (paper's M=3 topology, generalized
            //      as a caterpillar tree for other M) --------------------------
            (Decomp::HierarchicalTucker, _) => {
                // ranks = [r0, r1..rm, internal ranks i1..i(m-1)]
                let r0 = ranks[0];
                let mut lhs = vec![x_sub.clone()];
                let mut klhs = Vec::new();
                let mut shapes = Vec::new();
                for i in 1..=m {
                    lhs.push(format!("(r{i})(t{i})(s{i})"));
                    klhs.push(format!("(r{i})(t{i})(s{i})"));
                    shapes.push(vec![ranks[i], tf[i - 1], sf[i - 1]]);
                }
                lhs.push("(r0)hw".into());
                klhs.push("(r0)hw".into());
                shapes.push(vec![r0, h, w]);
                // Internal nodes: pair (r1,r2)→u1, (u_{k},r_{k+2})→u_{k+1},
                // last internal pairs with r0 at the root matrix.
                let n_internal = (m - 1).max(1);
                let int_ranks = &ranks[m + 1..];
                // C1 couples r1,r2 → u1
                lhs.push("(r1)(r2)(u1)".into());
                klhs.push("(r1)(r2)(u1)".into());
                shapes.push(vec![ranks[1], ranks[2], int_ranks[0]]);
                for k in 2..n_internal {
                    lhs.push(format!("(u{})(r{})(u{})", k - 1, k + 1, k));
                    klhs.push(format!("(u{})(r{})(u{})", k - 1, k + 1, k));
                    shapes.push(vec![int_ranks[k - 2], ranks[k + 1], int_ranks[k - 1]]);
                }
                // Root couples the last internal with the remaining leaf(s):
                if m >= 3 {
                    // C2: (r3)(r0)(u2)-style: couple leaf m and r0
                    lhs.push(format!("(r{m})(r0)(u{})", n_internal));
                    klhs.push(format!("(r{m})(r0)(u{})", n_internal));
                    shapes.push(vec![ranks[m], r0, int_ranks[n_internal - 1]]);
                    // C3: root matrix over the two internal edges
                    lhs.push(format!("(u1)(u{})", n_internal));
                    klhs.push(format!("(u1)(u{})", n_internal));
                    shapes.push(vec![int_ranks[0], int_ranks[n_internal - 1]]);
                } else {
                    // m == 2: root couples u1 with r0 directly.
                    lhs.push("(u1)(r0)".into());
                    klhs.push("(u1)(r0)".into());
                    shapes.push(vec![int_ranks[0], r0]);
                }
                (
                    format!("{}->{}|hw", lhs.join(","), out_sub),
                    format!("{}->{}{}hw", klhs.join(","), tmodes, smodes),
                    shapes,
                )
            }
        }
    }
}

#[cfg(test)]
mod tests;
