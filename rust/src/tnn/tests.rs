//! TNN zoo tests. The central invariant: **a tensorial layer computes
//! exactly the standard convolution with its reconstructed kernel** —
//! `layer(X, factors...) == conv2d(X, reconstruct(factors))` — for every
//! decomposition, flat and reshaped. Plus rank/CR accounting and planning
//! sanity for every layer string.

use super::*;
use crate::exec::{conv_einsum, conv_einsum_ltr};
use crate::planner::{contract_path, PlanOptions};
use crate::util::prop;
use crate::util::rng::Rng;

/// Run the layer via its conv_einsum string and via dense reconstruction;
/// they must agree.
fn check_equivalence(layer: &TnnLayerSpec, batch: usize, hp: usize, wp: usize, seed: u64) {
    let mut rng = Rng::new(seed);
    let factors = layer.init_factors(&mut rng);
    let x = Tensor::rand(&layer.input_shape(batch, hp, wp), -1.0, 1.0, &mut rng);

    let mut inputs: Vec<&Tensor> = vec![&x];
    inputs.extend(factors.iter());
    let y = conv_einsum(&layer.expr, &inputs).expect("layer must evaluate");
    assert_eq!(y.shape(), &layer.output_shape(batch, hp, wp)[..]);

    // Dense path: reconstruct kernel, flatten channels, standard conv.
    let kernel = layer.reconstruct_kernel(&factors);
    let x_flat = x.clone().reshape(&[batch, layer.s, hp, wp]);
    let y_dense = conv_einsum("bshw,tshw->bthw|hw", &[&x_flat, &kernel]).unwrap();
    let y_flat = y.clone().reshape(&[batch, layer.t, hp, wp]);
    let scale = y_dense.data().iter().map(|v| v.abs()).fold(0.0f32, f32::max);
    assert!(
        y_flat.max_abs_diff(&y_dense) <= 1e-3 * (1.0 + scale),
        "{:?} m={} layer != dense conv (Δ={})",
        layer.decomp,
        layer.m,
        y_flat.max_abs_diff(&y_dense)
    );
}

#[test]
fn flat_layers_equal_dense_conv() {
    for decomp in [Decomp::Cp, Decomp::Tucker, Decomp::TensorTrain, Decomp::TensorRing] {
        let layer = build_layer(decomp, 1, 6, 4, 3, 3, 1.0).unwrap();
        check_equivalence(&layer, 2, 8, 8, 42);
    }
}

#[test]
fn reshaped_layers_equal_dense_conv() {
    for decomp in Decomp::all() {
        let layer = build_layer(decomp, 2, 6, 4, 3, 3, 1.0).unwrap();
        check_equivalence(&layer, 2, 7, 7, 43);
    }
}

#[test]
fn reshaped_m3_layers_equal_dense_conv() {
    for decomp in Decomp::all() {
        let layer = build_layer(decomp, 3, 8, 8, 3, 3, 1.0).unwrap();
        check_equivalence(&layer, 1, 6, 6, 44);
    }
}

#[test]
fn layer_strings_match_paper_forms() {
    // §2.3 (1): CP convolutional layer.
    let cp = build_layer(Decomp::Cp, 1, 16, 8, 3, 3, 0.5).unwrap();
    assert_eq!(cp.expr, "bshw,rt,rs,rh,rw->bthw|hw");
    assert_eq!(cp.kernel_expr, "rt,rs,rh,rw->tshw");
    // §2.3 (2): reshaped CP, M=3.
    let rcp = build_layer(Decomp::Cp, 3, 64, 64, 3, 3, 0.5).unwrap();
    assert_eq!(
        rcp.expr,
        "b(s1)(s2)(s3)hw,r(t1)(s1),r(t2)(s2),r(t3)(s3),rhw->b(t1)(t2)(t3)hw|hw"
    );
    // Appendix A.3 (2a): Tucker layer.
    let tk = build_layer(Decomp::Tucker, 1, 16, 8, 3, 3, 0.5).unwrap();
    assert_eq!(tk.expr, "bshw,(r1)t,(r2)s,(r1)(r2)hw->bthw|hw");
    // Appendix A.3 (3a): TT layer.
    let tt = build_layer(Decomp::TensorTrain, 1, 16, 8, 3, 3, 0.5).unwrap();
    assert_eq!(tt.expr, "bshw,(r1)t,(r1)(r2)h,(r2)(r3)w,(r3)s->bthw|hw");
    // Appendix A.3 (4a): TR layer.
    let tr = build_layer(Decomp::TensorRing, 1, 16, 8, 3, 3, 0.5).unwrap();
    assert_eq!(
        tr.expr,
        "bshw,(r0)(r1)t,(r1)(r2)h,(r2)(r3)w,(r3)(r0)s->bthw|hw"
    );
    // Appendix A.3 HT (M=3) has the C1/C2/C3 coupling structure.
    let ht = build_layer(Decomp::HierarchicalTucker, 3, 8, 8, 3, 3, 1.0).unwrap();
    assert!(ht.expr.contains("(r1)(r2)(u1)"));
    assert!(ht.expr.contains("(r3)(r0)(u2)"));
    assert!(ht.expr.contains("(u1)(u2)"));
}

#[test]
fn compression_rate_respected() {
    for decomp in Decomp::all() {
        for cr in [0.05, 0.1, 0.2, 0.5, 1.0] {
            let layer = build_layer(decomp, 3, 64, 64, 3, 3, cr).unwrap();
            let achieved = layer.achieved_cr();
            // Rank-1 floors can exceed tiny budgets; otherwise must fit.
            if layer.ranks.iter().any(|&r| r > 1) {
                assert!(
                    achieved <= cr * 1.001,
                    "{} m=3 cr={}: achieved {} params {}",
                    decomp.name(),
                    cr,
                    achieved,
                    layer.params
                );
            }
        }
    }
}

#[test]
fn higher_cr_gives_more_params() {
    for decomp in Decomp::all() {
        let small = build_layer(decomp, 3, 64, 64, 3, 3, 0.05).unwrap();
        let large = build_layer(decomp, 3, 64, 64, 3, 3, 0.8).unwrap();
        assert!(
            large.params >= small.params,
            "{}: {} < {}",
            decomp.name(),
            large.params,
            small.params
        );
    }
}

#[test]
fn rank_solver_uses_budget() {
    // At CR=1.0 a CP layer should reach a healthy fraction of the budget.
    let layer = build_layer(Decomp::Cp, 3, 64, 64, 3, 3, 1.0).unwrap();
    assert!(layer.achieved_cr() > 0.8, "only used {}", layer.achieved_cr());
}

#[test]
fn layer_exprs_plan_and_beat_naive() {
    // Every zoo member must plan, and (at paper-like shapes with H'≫H)
    // the optimal path must be at least as cheap as naive — strictly
    // cheaper for the CP/Tucker families (Theorems 1–2).
    for decomp in Decomp::all() {
        let layer = build_layer(decomp, 3, 32, 32, 3, 3, 0.5).unwrap();
        let dims = layer.expr_dims(8, 32, 32);
        let plan = contract_path(&layer.expr, &dims, &PlanOptions::default()).unwrap();
        assert!(
            plan.cost <= plan.naive_cost,
            "{}: opt {} > naive {}",
            decomp.name(),
            plan.cost,
            plan.naive_cost
        );
    }
    for decomp in [Decomp::Cp, Decomp::Tucker] {
        let layer = build_layer(decomp, 3, 32, 32, 3, 3, 0.5).unwrap();
        let dims = layer.expr_dims(8, 32, 32);
        let plan = contract_path(&layer.expr, &dims, &PlanOptions::default()).unwrap();
        assert!(
            plan.cost < plan.naive_cost,
            "{}: no strict improvement",
            decomp.name()
        );
    }
}

#[test]
fn optimal_and_ltr_agree_numerically_on_layers() {
    for decomp in [Decomp::Cp, Decomp::Tucker, Decomp::TensorTrain] {
        let layer = build_layer(decomp, 2, 4, 4, 3, 3, 1.0).unwrap();
        let mut rng = Rng::new(7);
        let factors = layer.init_factors(&mut rng);
        let x = Tensor::rand(&layer.input_shape(1, 6, 6), -1.0, 1.0, &mut rng);
        let mut inputs: Vec<&Tensor> = vec![&x];
        inputs.extend(factors.iter());
        let a = conv_einsum(&layer.expr, &inputs).unwrap();
        let b = conv_einsum_ltr(&layer.expr, &inputs).unwrap();
        a.assert_close(&b, 1e-3);
    }
}

#[test]
fn ht_requires_reshaping() {
    assert!(build_layer(Decomp::HierarchicalTucker, 1, 8, 8, 3, 3, 0.5).is_err());
}

#[test]
fn invalid_args_rejected() {
    assert!(build_layer(Decomp::Cp, 0, 8, 8, 3, 3, 0.5).is_err());
    assert!(build_layer(Decomp::Cp, 1, 8, 8, 3, 3, 0.0).is_err());
    assert!(build_layer(Decomp::Cp, 1, 8, 8, 3, 3, 1.5).is_err());
}

#[test]
fn property_zoo_equivalence_random_shapes() {
    prop::check("tnn-zoo-equivalence", 12, |g| {
        let decomp = *g.pick(&[
            Decomp::Cp,
            Decomp::Tucker,
            Decomp::TensorTrain,
            Decomp::TensorRing,
            Decomp::BlockTerm,
        ]);
        let m = g.usize_in(1, 2);
        let m = if decomp == Decomp::HierarchicalTucker { 2 } else { m };
        let t = 2 * g.usize_in(1, 3);
        let s = 2 * g.usize_in(1, 3);
        let k = 2 * g.usize_in(0, 1) + 1; // 1 or 3
        let layer = build_layer(decomp, m, t, s, k, k, 1.0).unwrap();
        check_equivalence(&layer, 1, 5, 5, 0xfeed ^ (t * 31 + s) as u64);
    });
}
