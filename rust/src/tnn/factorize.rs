//! Channel-mode factorization and compression-rate-driven rank solving.

use super::LayerBuilder;

/// Split `n` into `m` integer factors whose product is exactly `n`, as
/// balanced as possible: prime factors are assigned largest-first to the
/// currently-smallest bucket. `balanced_factors(64, 3) = [4, 4, 4]`.
pub fn balanced_factors(n: usize, m: usize) -> Vec<usize> {
    assert!(n > 0 && m > 0);
    if m == 1 {
        return vec![n];
    }
    let mut primes = Vec::new();
    let mut x = n;
    let mut d = 2;
    while d * d <= x {
        while x % d == 0 {
            primes.push(d);
            x /= d;
        }
        d += 1;
    }
    if x > 1 {
        primes.push(x);
    }
    primes.sort_unstable_by(|a, b| b.cmp(a));
    let mut buckets = vec![1usize; m];
    for p in primes {
        let idx = buckets
            .iter()
            .enumerate()
            .min_by_key(|(_, &v)| v)
            .map(|(i, _)| i)
            .unwrap();
        buckets[idx] *= p;
    }
    buckets.sort_unstable_by(|a, b| b.cmp(a));
    buckets
}

/// Solve for the largest rank assignment whose parameter count stays at or
/// below `target` (the paper's CR mechanism: "trim off the least
/// significant components, i.e. reduce the rank, until it contains ≤ x% of
/// the original parameters"). All ranks start equal and the residual budget
/// is spent greedily one rank at a time.
pub fn solve_ranks(builder: &LayerBuilder, target: f64) -> Result<Vec<usize>, String> {
    let n = builder.n_ranks();
    let cap = builder.rank_cap();
    let fits = |ranks: &[usize]| (builder.params(ranks) as f64) <= target;

    // Largest equal value by doubling + binary search.
    let mut lo = 1usize;
    if !fits(&vec![1; n]) {
        // Even the minimal layer exceeds the budget — the paper's trimming
        // bottoms out at rank 1; accept it (CR is then slightly exceeded).
        return Ok(vec![1; n]);
    }
    let mut hi = 2usize;
    while hi <= cap && fits(&vec![hi; n]) {
        lo = hi;
        hi *= 2;
    }
    hi = hi.min(cap + 1);
    while lo + 1 < hi {
        let mid = (lo + hi) / 2;
        if fits(&vec![mid; n]) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let mut ranks = vec![lo; n];

    // Greedy refinement: bump individual ranks while budget remains.
    let mut improved = true;
    while improved {
        improved = false;
        for i in 0..n {
            if ranks[i] >= cap {
                continue;
            }
            ranks[i] += 1;
            if fits(&ranks) {
                improved = true;
            } else {
                ranks[i] -= 1;
            }
        }
    }
    Ok(ranks)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_factors_exact_products() {
        for (n, m) in [(64, 3), (128, 3), (12, 2), (7, 2), (1, 3), (360, 4)] {
            let f = balanced_factors(n, m);
            assert_eq!(f.len(), m);
            assert_eq!(f.iter().product::<usize>(), n, "n={n} m={m} f={f:?}");
        }
        assert_eq!(balanced_factors(64, 3), vec![4, 4, 4]);
        assert_eq!(balanced_factors(512, 3), vec![8, 8, 8]);
        assert_eq!(balanced_factors(7, 2), vec![7, 1]);
    }
}
