//! Autodiff over pairwise evaluation paths, with gradient checkpointing
//! (paper §3.3) and peak-memory metering (the mechanism behind Table 3).
//!
//! Evaluating an N-input conv_einsum pairwise produces N−1 intermediates.
//! An autograd-style backward needs each step's operands, so the default
//! ([`CkptPolicy::StoreAll`]) tape keeps every intermediate live — high
//! memory. [`CkptPolicy::Sqrt`] keeps only √K segment boundaries and
//! recomputes inside each segment during the backward pass, trading FLOPs
//! for memory exactly as Chen et al. [21] describe; [`CkptPolicy::None`]
//! stores nothing and recomputes each segment from the inputs.
//!
//! A [`PathAutodiff`] is built over a [`CompiledPlan`]: every step's atom
//! canonicalization and kernel tables are resolved once at construction
//! (or shared from a layer/coordinator cache via
//! [`PathAutodiff::from_compiled`]), so both the taped forward and the VJP
//! replay without re-canonicalizing. Each step replays with the compiled
//! plan's hoisted execution options, so under a parallel backend both the
//! forward tape and the backward VJP fan out over the **persistent worker
//! pool** ([`crate::parallel::Pool`]) — training steps pay a condvar
//! wake-up per region, never a thread spawn — and both backends run the
//! same SIMD microkernels ([`crate::kernels`]), keeping gradients
//! bit-identical to the scalar backend's.

use crate::exec::CompiledPlan;
use crate::planner::Plan;
use crate::tensor::Tensor;
use anyhow::{anyhow, Result};
use std::cell::RefCell;
use std::sync::Arc;

/// Checkpointing policy for the backward pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CkptPolicy {
    /// Keep every intermediate (PyTorch autograd default; "naive w/o ckpt").
    StoreAll,
    /// √K segment checkpointing (paper's "w/ ckpt" mode).
    Sqrt,
    /// Keep nothing; recompute every segment from the inputs.
    None,
}

/// Tracks live tensor bytes during an evaluation, recording the peak.
/// This is the quantity Table 3 bounds with GPU memory.
#[derive(Debug, Default)]
pub struct MemoryMeter {
    live: RefCell<usize>,
    peak: RefCell<usize>,
}

impl MemoryMeter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn alloc(&self, bytes: usize) {
        let mut live = self.live.borrow_mut();
        *live += bytes;
        let mut peak = self.peak.borrow_mut();
        if *live > *peak {
            *peak = *live;
        }
    }

    pub fn free(&self, bytes: usize) {
        let mut live = self.live.borrow_mut();
        *live = live.saturating_sub(bytes);
    }

    pub fn peak_bytes(&self) -> usize {
        *self.peak.borrow()
    }

    pub fn live_bytes(&self) -> usize {
        *self.live.borrow()
    }

    pub fn reset(&self) {
        *self.live.borrow_mut() = 0;
        *self.peak.borrow_mut() = 0;
    }
}

/// DAG node id: inputs are 0..n, step k produces node n+k.
type NodeId = usize;

/// A differentiation tape: node values retained by the forward pass (per
/// checkpoint policy) plus the forward output.
pub struct Tape {
    vals: Vec<Option<Tensor>>,
    pub output: Tensor,
}

/// Forward + backward executor over a compiled plan, with checkpointing.
pub struct PathAutodiff {
    compiled: Arc<CompiledPlan>,
    /// node ids consumed/produced per step: (lhs, rhs, out).
    step_nodes: Vec<(NodeId, NodeId, NodeId)>,
    root: NodeId,
}

impl PathAutodiff {
    /// Compile `plan` and build the executor. Callers that evaluate the
    /// same plan repeatedly should compile once and use
    /// [`PathAutodiff::from_compiled`] instead.
    pub fn new(plan: &Plan) -> Result<Self> {
        let compiled = CompiledPlan::compile(plan).map_err(|e| anyhow!("{e}"))?;
        Ok(Self::from_compiled(Arc::new(compiled)))
    }

    /// Build the executor over an already-compiled (typically cached) plan.
    /// Construction is O(steps) bookkeeping — no re-canonicalization.
    pub fn from_compiled(compiled: Arc<CompiledPlan>) -> PathAutodiff {
        let n = compiled.n_inputs();
        let step_nodes: Vec<(NodeId, NodeId, NodeId)> = (0..compiled.n_steps())
            .map(|k| {
                let (l, r) = compiled.step(k).nodes();
                (l, r, n + k)
            })
            .collect();
        // The last step always produces the root (compile validated that
        // the plan reduces to a single output).
        let root = n + compiled.n_steps() - 1;
        PathAutodiff {
            compiled,
            step_nodes,
            root,
        }
    }

    /// The compiled plan this executor replays.
    pub fn compiled(&self) -> &Arc<CompiledPlan> {
        &self.compiled
    }

    fn n(&self) -> usize {
        self.compiled.n_inputs()
    }

    /// Execute one step given node values, metering the allocation.
    fn run_step(&self, k: usize, vals: &mut [Option<Tensor>], meter: &MemoryMeter) {
        let (l, r, o) = self.step_nodes[k];
        let st = self.compiled.step(k);
        let a = vals[l].as_ref().expect("lhs value live");
        let b = vals[r].as_ref().expect("rhs value live");
        let out = st
            .atom()
            .execute_with_kernel(st.kernel_tables(), a, b, self.compiled.exec_options());
        meter.alloc(out.bytes());
        vals[o] = Some(out);
    }

    /// Drop a node value, metering the free.
    fn drop_val(&self, vals: &mut [Option<Tensor>], node: NodeId, meter: &MemoryMeter) {
        if let Some(t) = vals[node].take() {
            meter.free(t.bytes());
        }
    }

    /// Is `node` still needed by any step ≥ `after` (as an operand)?
    fn needed_after(&self, node: NodeId, after: usize) -> bool {
        self.step_nodes[after..]
            .iter()
            .any(|&(l, r, _)| l == node || r == node)
    }

    /// Forward pass returning the output (final permutation applied).
    /// Intermediates are freed as soon as no later step consumes them —
    /// this is the inference-mode memory profile.
    pub fn forward(&self, inputs: &[&Tensor], meter: &MemoryMeter) -> Result<Tensor> {
        let n = self.n();
        if inputs.len() != n {
            return Err(anyhow!("expected {} inputs, got {}", n, inputs.len()));
        }
        let mut vals: Vec<Option<Tensor>> = vec![None; n + self.step_nodes.len()];
        for (i, t) in inputs.iter().enumerate() {
            meter.alloc(t.bytes());
            vals[i] = Some((*t).clone());
        }
        for k in 0..self.step_nodes.len() {
            self.run_step(k, &mut vals, meter);
            let (l, r, _) = self.step_nodes[k];
            for node in [l, r] {
                if node != self.root && !self.needed_after(node, k + 1) {
                    self.drop_val(&mut vals, node, meter);
                }
            }
        }
        let root = vals[self.root].take().expect("root value");
        let out = match &self.compiled.plan().final_perm {
            Some(p) => {
                let o = root.permute(p);
                meter.alloc(o.bytes());
                meter.free(root.bytes());
                o
            }
            None => root,
        };
        Ok(out)
    }

    /// Forward + backward under a checkpoint policy. Returns the output
    /// and ∂L/∂input for every input, given the output cotangent computed
    /// by `dout_fn(output) -> dout`.
    pub fn forward_backward(
        &self,
        inputs: &[&Tensor],
        dout_fn: impl FnOnce(&Tensor) -> Tensor,
        policy: CkptPolicy,
        meter: &MemoryMeter,
    ) -> Result<(Tensor, Vec<Tensor>)> {
        let mut tape = self.forward_with_tape(inputs, policy, meter)?;
        let dout = dout_fn(&tape.output);
        let grads = self.backward(&mut tape, &dout, meter)?;
        Ok((tape.output, grads))
    }

    /// Forward pass retaining a differentiation tape per the checkpoint
    /// policy. Use with [`PathAutodiff::backward`]; this is the layer-level
    /// API of the training substrate.
    pub fn forward_with_tape(
        &self,
        inputs: &[&Tensor],
        policy: CkptPolicy,
        meter: &MemoryMeter,
    ) -> Result<Tape> {
        let n = self.n();
        let ksteps = self.step_nodes.len();
        if inputs.len() != n {
            return Err(anyhow!("expected {} inputs, got {}", n, inputs.len()));
        }

        // Which step outputs to retain during the stored forward:
        let keep: Vec<bool> = match policy {
            CkptPolicy::StoreAll => vec![true; ksteps],
            CkptPolicy::None => vec![false; ksteps],
            CkptPolicy::Sqrt => {
                let seg = (ksteps as f64).sqrt().ceil() as usize;
                (0..ksteps).map(|k| seg != 0 && k % seg == seg - 1).collect()
            }
        };

        let mut vals: Vec<Option<Tensor>> = vec![None; n + ksteps];
        for (i, t) in inputs.iter().enumerate() {
            meter.alloc(t.bytes());
            vals[i] = Some((*t).clone());
        }
        // Stored forward: keep checkpointed nodes; free the rest when no
        // longer needed *within the remaining forward*.
        for k in 0..ksteps {
            self.run_step(k, &mut vals, meter);
            let (l, r, _) = self.step_nodes[k];
            for node in [l, r] {
                let is_input = node < n;
                let is_kept = !is_input && keep[node - n];
                if !is_input && !is_kept && !self.needed_after(node, k + 1) {
                    self.drop_val(&mut vals, node, meter);
                }
            }
        }
        // Under None/Sqrt, non-checkpointed values that were still live at
        // the end of the forward (e.g. the root's direct operands) stay, but
        // drop anything not marked kept except the root.
        for k in 0..ksteps {
            let node = n + k;
            if node != self.root && !keep[k] && vals[node].is_some() {
                self.drop_val(&mut vals, node, meter);
            }
        }

        let root_val = vals[self.root].clone().expect("root");
        let output = match &self.compiled.plan().final_perm {
            Some(p) => {
                let o = root_val.permute(p);
                meter.alloc(o.bytes());
                o
            }
            None => root_val.clone(),
        };
        Ok(Tape { vals, output })
    }

    /// Backward pass from a tape: returns ∂L/∂input for every input given
    /// the output cotangent. Consumes the tape's stored values (recomputing
    /// checkpointed segments as needed).
    pub fn backward(
        &self,
        tape: &mut Tape,
        dout: &Tensor,
        meter: &MemoryMeter,
    ) -> Result<Vec<Tensor>> {
        let n = self.n();
        let ksteps = self.step_nodes.len();
        let vals = &mut tape.vals;
        meter.alloc(dout.bytes());
        let droot = match &self.compiled.plan().final_perm {
            Some(p) => {
                let inv = invert(p);
                let d = dout.permute(&inv);
                meter.alloc(d.bytes());
                meter.free(dout.bytes());
                d
            }
            None => dout.clone(),
        };

        // Backward, recomputing missing operand values per step (checkpoint
        // segment replay).
        let mut grads: Vec<Option<Tensor>> = vec![None; n + ksteps];
        grads[self.root] = Some(droot);
        for k in (0..ksteps).rev() {
            let (l, r, o) = self.step_nodes[k];
            for node in [l, r] {
                if vals[node].is_none() {
                    self.recompute(node, vals, meter);
                }
            }
            let st = self.compiled.step(k);
            let dnode = grads[o].take().expect("cotangent for step output");
            let a = vals[l].as_ref().unwrap();
            let b = vals[r].as_ref().unwrap();
            let (da, db) = st.atom().vjp_with_kernel(
                st.kernel_tables(),
                a,
                b,
                &dnode,
                self.compiled.exec_options(),
            );
            meter.free(dnode.bytes());
            meter.alloc(da.bytes());
            meter.alloc(db.bytes());
            accumulate(&mut grads, l, da, meter);
            accumulate(&mut grads, r, db, meter);
            // The step output value is no longer needed going backward.
            if o >= n {
                self.drop_val(vals, o, meter);
            }
        }

        let input_grads: Vec<Tensor> = (0..n)
            .map(|i| {
                grads[i].take().unwrap_or_else(|| {
                    Tensor::zeros(vals[i].as_ref().expect("input value live").shape())
                })
            })
            .collect();
        Ok(input_grads)
    }

    /// Recompute the value of `node` (a step output) from the nearest
    /// materialized ancestors, re-running intermediate steps.
    fn recompute(&self, node: NodeId, vals: &mut Vec<Option<Tensor>>, meter: &MemoryMeter) {
        let n = self.n();
        debug_assert!(node >= n, "input values are always live");
        let k = node - n;
        let (l, r, _) = self.step_nodes[k];
        for dep in [l, r] {
            if vals[dep].is_none() {
                self.recompute(dep, vals, meter);
            }
        }
        self.run_step(k, vals, meter);
    }
}

fn invert(perm: &[usize]) -> Vec<usize> {
    let mut inv = vec![0usize; perm.len()];
    for (i, &p) in perm.iter().enumerate() {
        inv[p] = i;
    }
    inv
}

fn accumulate(grads: &mut [Option<Tensor>], node: NodeId, g: Tensor, meter: &MemoryMeter) {
    match &mut grads[node] {
        Some(existing) => {
            existing.add_assign(&g);
            meter.free(g.bytes());
        }
        slot @ None => *slot = Some(g),
    }
}

#[cfg(test)]
mod tests;
