//! Autodiff over pairwise evaluation paths, with gradient checkpointing
//! (paper §3.3) and peak-memory metering (the mechanism behind Table 3).
//!
//! Evaluating an N-input conv_einsum pairwise produces N−1 intermediates.
//! An autograd-style backward needs each step's operands, so the default
//! ([`CkptPolicy::StoreAll`]) tape keeps every intermediate live — high
//! memory. [`CkptPolicy::Sqrt`] keeps only √K segment boundaries and
//! recomputes inside each segment during the backward pass, trading FLOPs
//! for memory exactly as Chen et al. [21] describe; [`CkptPolicy::None`]
//! stores nothing and recomputes each segment from the inputs.
//!
//! A [`PathAutodiff`] is built over a [`CompiledPlan`]: every step's atom
//! canonicalization and kernel tables are resolved once at construction
//! (or shared from a layer/coordinator cache via
//! [`PathAutodiff::from_compiled`]), so both the taped forward and the VJP
//! replay without re-canonicalizing.
//!
//! # Workspace tape
//!
//! The tape itself lives in a caller-held arena: the compiled plan carries
//! a per-policy [`crate::exec::TrainLayout`] assigning an arena slot to
//! every input copy, retained intermediate, recompute-segment transient and
//! cotangent, and [`PathAutodiff::forward_with_tape`] /
//! [`PathAutodiff::backward`] replay that schedule against a
//! [`TrainWorkspace`] through the same `*_into` workspace kernels the
//! inference engine uses. After workspace warm-up a full
//! forward-with-tape + backward step performs **zero heap allocations** on
//! both backends (use the `_into` variants with caller-held output/gradient
//! tensors; `bench_hotpath` asserts this), and gradients are bit-identical
//! to the heap tape this replaced (`tests/train_parity.rs` replays the old
//! algorithm step by step and compares bit patterns).
//!
//! A [`Tape`] is a token onto the workspace state: running another taped
//! forward (or touching the workspace's inference half) bumps the
//! workspace epoch and invalidates outstanding tapes — their backward
//! fails with a clear error instead of reading clobbered arena ranges.
//!
//! # Batched training steps
//!
//! The coordinator coalesces same-expression training requests the way it
//! coalesces inference requests; [`PathAutodiff::train_step_batch_into`]
//! is the engine entry point: a batch of [`TrainSegment`]s replays through
//! one cached [`crate::exec::TrainLayout`] against one workspace, segment
//! by segment in slice order (each segment's tape is consumed before the
//! next is laid, so batch epochs advance per segment and stale tokens are
//! rejected). Gradients are **bit-identical** to individually submitted
//! steps — input gradients split along the batch mode, weight gradients
//! accumulated per segment, never across segments.
//!
//! Each step replays with the compiled plan's hoisted execution options,
//! so under a parallel backend both the taped forward and the backward VJP
//! fan out over the **persistent worker pool** ([`crate::parallel::Pool`])
//! — training steps pay a condvar wake-up per region, never a thread spawn
//! — and both backends run the same SIMD microkernels
//! ([`crate::kernels`]), keeping gradients bit-identical to the scalar
//! backend's.
//!
//! # Metering
//!
//! [`MemoryMeter`] reports the arena **high-water mark** of the layout a
//! step ran under (the peak tape footprint, Table 3's bounded quantity)
//! rather than per-allocation traffic: both the taped forward and the
//! backward record the layout's peak as a balanced `alloc`/`free` pair, so
//! `peak_bytes` captures the step's footprint while `live_bytes` always
//! returns to its prior level — regardless of policy, final permutation,
//! or whether a tape is ever consumed (abandoned tapes cannot leak
//! accounting).

use crate::exec::{CompiledPlan, TrainWorkspace};
use crate::planner::Plan;
use crate::tensor::Tensor;
use anyhow::{anyhow, Result};
use std::cell::RefCell;
use std::sync::Arc;

/// Checkpointing policy for the backward pass. (`Hash` so the coordinator's
/// batching scheduler can group pending training requests by policy.)
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CkptPolicy {
    /// Keep every intermediate (PyTorch autograd default; "naive w/o ckpt").
    StoreAll,
    /// √K segment checkpointing (paper's "w/ ckpt" mode).
    Sqrt,
    /// Keep nothing; recompute every segment from the inputs.
    None,
}

impl CkptPolicy {
    /// All policies, in the order [`crate::exec::CompiledPlan`] caches
    /// their training layouts. [`crate::exec::CompiledPlan::verify`]
    /// iterates this to statically check every layout.
    pub const ALL: [CkptPolicy; 3] = [CkptPolicy::StoreAll, CkptPolicy::Sqrt, CkptPolicy::None];
}

/// Tracks live tensor bytes during an evaluation, recording the peak.
/// This is the quantity Table 3 bounds with GPU memory.
#[derive(Debug, Default)]
pub struct MemoryMeter {
    live: RefCell<usize>,
    peak: RefCell<usize>,
}

impl MemoryMeter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn alloc(&self, bytes: usize) {
        let mut live = self.live.borrow_mut();
        *live += bytes;
        let mut peak = self.peak.borrow_mut();
        if *live > *peak {
            *peak = *live;
        }
    }

    pub fn free(&self, bytes: usize) {
        let mut live = self.live.borrow_mut();
        *live = live.saturating_sub(bytes);
    }

    pub fn peak_bytes(&self) -> usize {
        *self.peak.borrow()
    }

    pub fn live_bytes(&self) -> usize {
        *self.live.borrow()
    }

    pub fn reset(&self) {
        *self.live.borrow_mut() = 0;
        *self.peak.borrow_mut() = 0;
    }
}

/// DAG node id: inputs are 0..n, step k produces node n+k.
type NodeId = usize;

/// Handle onto a taped forward resident in a [`TrainWorkspace`]: the
/// checkpoint policy it ran under, the identity and epoch of the workspace
/// whose arena holds it, and the compiled plan it belongs to.
/// [`PathAutodiff::backward_into`] validates all of them, so a stale tape
/// (another taped forward ran, the workspace's inference half was used) or
/// a backward against the wrong workspace errors instead of producing
/// garbage gradients.
pub struct TapeToken {
    policy: CkptPolicy,
    ws_id: u64,
    epoch: u64,
    plan: Arc<CompiledPlan>,
}

impl TapeToken {
    /// The compiled plan this tape was produced by. Drive the backward
    /// from this (e.g. [`PathAutodiff::from_compiled`]) rather than
    /// re-fetching the plan from a cache: a cache may have evicted and
    /// recompiled a structurally identical entry, which this token would
    /// rightly reject as a different plan.
    pub fn plan(&self) -> &Arc<CompiledPlan> {
        &self.plan
    }
}

/// A differentiation tape: the forward output plus the [`TapeToken`]
/// identifying the arena-resident state the backward will consume.
pub struct Tape {
    pub output: Tensor,
    token: TapeToken,
}

impl Tape {
    /// The workspace-tape token (for [`PathAutodiff::backward_into`]).
    pub fn token(&self) -> &TapeToken {
        &self.token
    }
}

/// Forward + backward executor over a compiled plan, with checkpointing.
pub struct PathAutodiff {
    compiled: Arc<CompiledPlan>,
    /// node ids consumed/produced per step: (lhs, rhs, out).
    step_nodes: Vec<(NodeId, NodeId, NodeId)>,
    root: NodeId,
}

impl PathAutodiff {
    /// Compile `plan` and build the executor. Callers that evaluate the
    /// same plan repeatedly should compile once and use
    /// [`PathAutodiff::from_compiled`] instead.
    pub fn new(plan: &Plan) -> Result<Self> {
        let compiled = CompiledPlan::compile(plan).map_err(|e| anyhow!("{e}"))?;
        Ok(Self::from_compiled(Arc::new(compiled)))
    }

    /// Build the executor over an already-compiled (typically cached) plan.
    /// Construction is O(steps) bookkeeping — no re-canonicalization.
    pub fn from_compiled(compiled: Arc<CompiledPlan>) -> PathAutodiff {
        let n = compiled.n_inputs();
        let step_nodes: Vec<(NodeId, NodeId, NodeId)> = (0..compiled.n_steps())
            .map(|k| {
                let (l, r) = compiled.step(k).nodes();
                (l, r, n + k)
            })
            .collect();
        // The last step always produces the root (compile validated that
        // the plan reduces to a single output).
        let root = n + compiled.n_steps() - 1;
        PathAutodiff {
            compiled,
            step_nodes,
            root,
        }
    }

    /// The compiled plan this executor replays.
    pub fn compiled(&self) -> &Arc<CompiledPlan> {
        &self.compiled
    }

    fn n(&self) -> usize {
        self.compiled.n_inputs()
    }

    /// Execute one step given node values, metering the allocation
    /// (inference-mode forward only; the training path runs through the
    /// compiled plan's arena schedule instead).
    fn run_step(&self, k: usize, vals: &mut [Option<Tensor>], meter: &MemoryMeter) {
        let (l, r, o) = self.step_nodes[k];
        let st = self.compiled.step(k);
        let a = vals[l].as_ref().expect("lhs value live");
        let b = vals[r].as_ref().expect("rhs value live");
        let out = st
            .atom()
            .execute_with_kernel(st.kernel_tables(), a, b, self.compiled.exec_options());
        meter.alloc(out.bytes());
        vals[o] = Some(out);
    }

    /// Drop a node value, metering the free.
    fn drop_val(&self, vals: &mut [Option<Tensor>], node: NodeId, meter: &MemoryMeter) {
        if let Some(t) = vals[node].take() {
            meter.free(t.bytes());
        }
    }

    /// Is `node` still needed by any step ≥ `after` (as an operand)?
    fn needed_after(&self, node: NodeId, after: usize) -> bool {
        self.step_nodes[after..]
            .iter()
            .any(|&(l, r, _)| l == node || r == node)
    }

    /// Forward pass returning the output (final permutation applied).
    /// Intermediates are freed as soon as no later step consumes them —
    /// this is the inference-mode memory profile. One-shot callers only;
    /// steady-state inference should replay [`CompiledPlan::run_into`]
    /// against a held workspace.
    pub fn forward(&self, inputs: &[&Tensor], meter: &MemoryMeter) -> Result<Tensor> {
        let n = self.n();
        if inputs.len() != n {
            return Err(anyhow!("expected {} inputs, got {}", n, inputs.len()));
        }
        let mut vals: Vec<Option<Tensor>> = vec![None; n + self.step_nodes.len()];
        for (i, t) in inputs.iter().enumerate() {
            meter.alloc(t.bytes());
            vals[i] = Some((*t).clone());
        }
        for k in 0..self.step_nodes.len() {
            self.run_step(k, &mut vals, meter);
            let (l, r, _) = self.step_nodes[k];
            for node in [l, r] {
                if node != self.root && !self.needed_after(node, k + 1) {
                    self.drop_val(&mut vals, node, meter);
                }
            }
        }
        let root = vals[self.root].take().expect("root value");
        let out = match &self.compiled.plan().final_perm {
            Some(p) => {
                let o = root.permute(p);
                meter.alloc(o.bytes());
                meter.free(root.bytes());
                o
            }
            None => root,
        };
        Ok(out)
    }

    /// Forward + backward under a checkpoint policy. Returns the output
    /// and ∂L/∂input for every input, given the output cotangent computed
    /// by `dout_fn(output) -> dout`.
    pub fn forward_backward(
        &self,
        inputs: &[&Tensor],
        dout_fn: impl FnOnce(&Tensor) -> Tensor,
        policy: CkptPolicy,
        ws: &mut TrainWorkspace,
        meter: &MemoryMeter,
    ) -> Result<(Tensor, Vec<Tensor>)> {
        let tape = self.forward_with_tape(inputs, policy, ws, meter)?;
        let dout = dout_fn(&tape.output);
        let grads = self.backward(&tape, &dout, ws, meter)?;
        Ok((tape.output, grads))
    }

    /// Forward pass retaining a differentiation tape (in the workspace
    /// arena) per the checkpoint policy. Use with
    /// [`PathAutodiff::backward`]; this is the layer-level API of the
    /// training substrate. Allocates only the output tensor — use
    /// [`PathAutodiff::forward_with_tape_into`] for the fully
    /// allocation-free loop.
    pub fn forward_with_tape(
        &self,
        inputs: &[&Tensor],
        policy: CkptPolicy,
        ws: &mut TrainWorkspace,
        meter: &MemoryMeter,
    ) -> Result<Tape> {
        let mut output = Tensor::zeros(self.compiled.out_shape());
        let token = self.forward_with_tape_into(inputs, policy, ws, &mut output, meter)?;
        Ok(Tape { output, token })
    }

    /// As [`PathAutodiff::forward_with_tape`], writing the output into a
    /// caller-held tensor of shape [`CompiledPlan::out_shape`]: the
    /// allocation-free steady-state entry point (zero heap allocations
    /// after workspace warm-up, both backends).
    pub fn forward_with_tape_into(
        &self,
        inputs: &[&Tensor],
        policy: CkptPolicy,
        ws: &mut TrainWorkspace,
        out: &mut Tensor,
        meter: &MemoryMeter,
    ) -> Result<TapeToken> {
        let layout = self.compiled.train_layout(policy);
        let epoch = self.compiled.train_forward(&layout, inputs, ws, out)?;
        // Meter the layout's arena high-water mark — the peak tape bytes a
        // step under this policy holds — as a balanced alloc/free pair:
        // the peak is recorded, the meter returns to its prior live level,
        // and an abandoned or invalidated tape cannot leak accounting.
        meter.alloc(layout.arena_bytes());
        meter.free(layout.arena_bytes());
        Ok(TapeToken {
            policy,
            ws_id: ws.id(),
            epoch,
            plan: Arc::clone(&self.compiled),
        })
    }

    /// Backward pass from a tape: returns ∂L/∂input for every input given
    /// the output cotangent. Consumes the arena-resident tape (recomputing
    /// checkpointed segments as scheduled by the layout). Allocates only
    /// the gradient tensors — use [`PathAutodiff::backward_into`] for the
    /// allocation-free loop.
    pub fn backward(
        &self,
        tape: &Tape,
        dout: &Tensor,
        ws: &mut TrainWorkspace,
        meter: &MemoryMeter,
    ) -> Result<Vec<Tensor>> {
        let mut grads: Vec<Tensor> = self
            .compiled
            .in_dims()
            .iter()
            .map(|d| Tensor::zeros(d))
            .collect();
        self.backward_into(&tape.token, dout, ws, &mut grads, meter)?;
        Ok(grads)
    }

    /// As [`PathAutodiff::backward`], accumulating into caller-held
    /// gradient tensors (one per input, natural shapes; contents are
    /// overwritten). Zero heap allocations after workspace warm-up as long
    /// as the gradient tensors are unshared.
    pub fn backward_into(
        &self,
        tape: &TapeToken,
        dout: &Tensor,
        ws: &mut TrainWorkspace,
        grads: &mut [Tensor],
        meter: &MemoryMeter,
    ) -> Result<()> {
        if !Arc::ptr_eq(&tape.plan, &self.compiled) {
            return Err(anyhow!(
                "tape was produced by a different compiled plan; forward and \
                 backward must replay the same compiled entry"
            ));
        }
        if tape.ws_id != ws.id() {
            return Err(anyhow!(
                "tape belongs to a different workspace: the backward must run \
                 against the TrainWorkspace whose arena holds the tape"
            ));
        }
        if tape.epoch != ws.epoch() {
            return Err(anyhow!(
                "tape invalidated: the workspace ran a later taped forward (or \
                 its inference half was used) since this tape was produced"
            ));
        }
        let layout = self.compiled.train_layout(tape.policy);
        self.compiled.train_backward(&layout, dout, ws, grads)?;
        // The tape is consumed: a second backward over the same arena state
        // would re-accumulate garbage, so invalidate it.
        ws.invalidate();
        // Balanced peak recording, mirroring the forward (the backward
        // replays the same arena; its recompute peaks are part of the
        // layout's high-water mark).
        meter.alloc(layout.arena_bytes());
        meter.free(layout.arena_bytes());
        Ok(())
    }

    /// Run a **coalesced batch** of training steps — one per
    /// [`TrainSegment`] — through this plan's single cached
    /// [`crate::exec::TrainLayout`] against one workspace. This is the
    /// engine half of the coordinator's unified batching scheduler: a batch
    /// of same-expression, same-shape training requests (conceptually one
    /// request concatenated along the batch mode) replays segment by
    /// segment in slice order, each segment's tape living in — and being
    /// consumed from — the shared arena before the next is laid.
    ///
    /// # Gradient contract (segment accumulation order)
    ///
    /// Segments are executed strictly in slice order, and every segment's
    /// gradients — the batch-mode slice of ∂L/∂x *and* its own weight
    /// gradients — are accumulated entirely within that segment's replay,
    /// never summed across segments. Batched and individually submitted
    /// requests therefore produce **bit-identical** outputs, input
    /// gradients and per-segment weight gradients
    /// (`tests/batch_train_parity.rs` asserts this across ConvKinds ×
    /// backends × batch sizes), and the steady state performs **zero heap
    /// allocations** on both backends (`bench_hotpath` asserts it).
    ///
    /// Every segment bumps the workspace epoch (forward) and consumes its
    /// tape (backward), so any [`TapeToken`] issued before the batch — or
    /// captured mid-batch — is invalid afterwards: a stale backward errors
    /// instead of reading a later segment's arena state.
    pub fn train_step_batch_into(
        &self,
        segments: &mut [TrainSegment<'_>],
        policy: CkptPolicy,
        ws: &mut TrainWorkspace,
        meter: &MemoryMeter,
    ) -> Result<()> {
        let layout = self.compiled.train_layout(policy);
        for seg in segments.iter_mut() {
            self.compiled
                .train_step(&layout, seg.inputs, seg.dout, ws, seg.out, seg.grads)?;
            // One balanced peak record per segment: the batch's peak equals
            // a single step's (segments share the arena serially).
            meter.alloc(layout.arena_bytes());
            meter.free(layout.arena_bytes());
        }
        Ok(())
    }
}

/// One request of a coalesced training batch
/// ([`PathAutodiff::train_step_batch_into`]): the segment's inputs and
/// output cotangent, plus caller-held destinations for its forward output
/// and per-input gradients (all in natural shapes; contents overwritten).
/// Holding the destinations across calls keeps the repeated batched step
/// allocation-free.
pub struct TrainSegment<'a> {
    /// Inputs of this segment, matching the compiled plan's shapes.
    pub inputs: &'a [&'a Tensor],
    /// Output cotangent seeding this segment's backward.
    pub dout: &'a Tensor,
    /// Receives the forward output (shape [`CompiledPlan::out_shape`]).
    pub out: &'a mut Tensor,
    /// Receives ∂L/∂input for every input of this segment.
    pub grads: &'a mut [Tensor],
}

#[cfg(test)]
mod tests;
