//! Tests for path autodiff and checkpointing: gradient correctness against
//! finite differences and against the single-op VJP; memory-policy
//! invariants (StoreAll ≥ Sqrt ≥ forward-only peak; identical gradients
//! under every policy).

use super::*;
use crate::einsum::{parse, SizedSpec};
use crate::exec::{pairwise, TrainWorkspace};
use crate::planner::{plan_with, PlanOptions, Strategy};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

fn make_plan(expr: &str, dims: Vec<Vec<usize>>, strategy: Strategy) -> crate::planner::Plan {
    let spec = parse(expr).unwrap();
    let sized = SizedSpec::new(spec, dims).unwrap();
    plan_with(
        &sized,
        &PlanOptions {
            strategy,
            ..Default::default()
        },
    )
    .unwrap()
}

fn rand_inputs(dims: &[Vec<usize>], rng: &mut Rng) -> Vec<Tensor> {
    dims.iter().map(|d| Tensor::rand(d, -1.0, 1.0, rng)).collect()
}

/// Sum-loss cotangent: L = Σ out ⊙ dout for fixed random dout.
fn fixed_dout(shape: &[usize], rng: &mut Rng) -> Tensor {
    Tensor::rand(shape, -1.0, 1.0, rng)
}

#[test]
fn two_input_grads_match_pairwise_vjp() {
    let expr = "ij,jk->ik";
    let dims = vec![vec![3, 4], vec![4, 5]];
    let plan = make_plan(expr, dims.clone(), Strategy::Optimal);
    let mut rng = Rng::new(1);
    let ins = rand_inputs(&dims, &mut rng);
    let ad = PathAutodiff::new(&plan).unwrap();
    let meter = MemoryMeter::new();
    let mut ws = TrainWorkspace::new();
    let dout = fixed_dout(&[3, 5], &mut rng);
    let d2 = dout.clone();
    let (_out, grads) = ad
        .forward_backward(
            &[&ins[0], &ins[1]],
            |_| d2.clone(),
            CkptPolicy::StoreAll,
            &mut ws,
            &meter,
        )
        .unwrap();
    let sized = SizedSpec::new(parse(expr).unwrap(), dims).unwrap();
    let (da, db) = crate::exec::pairwise_vjp(&sized, &ins[0], &ins[1], &dout);
    grads[0].assert_close(&da, 1e-4);
    grads[1].assert_close(&db, 1e-4);
}

#[test]
fn multi_input_grads_match_finite_differences() {
    // CP layer in 1D with optimal path (shared intermediates exercise grad
    // accumulation through the DAG).
    let expr = "bsh,rt,rs,rh->bth|h";
    let dims = vec![vec![2, 2, 6], vec![3, 2], vec![3, 2], vec![3, 3]];
    let plan = make_plan(expr, dims.clone(), Strategy::Optimal);
    let mut rng = Rng::new(2);
    let ins = rand_inputs(&dims, &mut rng);
    let refs: Vec<&Tensor> = ins.iter().collect();
    let ad = PathAutodiff::new(&plan).unwrap();
    let meter = MemoryMeter::new();
    let mut ws = TrainWorkspace::new();
    let out = ad.forward(&refs, &meter).unwrap();
    let dout = fixed_dout(out.shape(), &mut rng);
    let d2 = dout.clone();
    let (_o, grads) = ad
        .forward_backward(&refs, |_| d2.clone(), CkptPolicy::StoreAll, &mut ws, &meter)
        .unwrap();

    let loss = |ins: &[Tensor]| -> f32 {
        let refs: Vec<&Tensor> = ins.iter().collect();
        let o = crate::exec::execute_path(&plan, &refs).unwrap();
        o.data().iter().zip(dout.data()).map(|(a, b)| a * b).sum()
    };
    let eps = 1e-2f32;
    for input_idx in 0..ins.len() {
        for k in [0usize, ins[input_idx].len() / 2, ins[input_idx].len() - 1] {
            let mut p = ins.clone();
            p[input_idx].data_mut()[k] += eps;
            let mut m = ins.clone();
            m[input_idx].data_mut()[k] -= eps;
            let fd = (loss(&p) - loss(&m)) / (2.0 * eps);
            let an = grads[input_idx].data()[k];
            assert!(
                (fd - an).abs() < 3e-2 * (1.0 + an.abs()),
                "input {input_idx} coord {k}: fd={fd} analytic={an}"
            );
        }
    }
}

#[test]
fn gradients_identical_across_ckpt_policies() {
    let expr = "bshw,rt,rs,rh,rw->bthw|hw";
    let dims = vec![vec![2, 2, 5, 5], vec![3, 2], vec![3, 2], vec![3, 3], vec![3, 3]];
    let plan = make_plan(expr, dims.clone(), Strategy::Optimal);
    let mut rng = Rng::new(3);
    let ins = rand_inputs(&dims, &mut rng);
    let refs: Vec<&Tensor> = ins.iter().collect();
    let ad = PathAutodiff::new(&plan).unwrap();
    let meter = MemoryMeter::new();
    let mut ws = TrainWorkspace::new();
    let out = ad.forward(&refs, &meter).unwrap();
    let dout = fixed_dout(out.shape(), &mut rng);

    let mut all = Vec::new();
    for policy in [CkptPolicy::StoreAll, CkptPolicy::Sqrt, CkptPolicy::None] {
        let meter = MemoryMeter::new();
        let d = dout.clone();
        let (o, grads) = ad
            .forward_backward(&refs, |_| d.clone(), policy, &mut ws, &meter)
            .unwrap();
        o.assert_close(&out, 1e-4);
        all.push(grads);
    }
    for i in 0..ins.len() {
        all[1][i].assert_close(&all[0][i], 1e-4);
        all[2][i].assert_close(&all[0][i], 1e-4);
    }
}

#[test]
fn checkpointing_reduces_peak_memory() {
    // A batch chain "za,ab,...,gh->zh" with a large batch mode z: every
    // intermediate is z×2, so StoreAll holds 7 large intermediates at once
    // while Sqrt holds only √K boundaries (+1 transient recompute).
    let n = 8;
    let letters: Vec<char> = "abcdefghi".chars().collect();
    let mut parts = vec!["za".to_string()];
    for i in 0..n - 1 {
        parts.push(format!("{}{}", letters[i], letters[i + 1]));
    }
    let expr = format!("{}->z{}", parts.join(","), letters[n - 1]);
    let mut dims: Vec<Vec<usize>> = vec![vec![4096, 2]];
    dims.extend((0..n - 1).map(|_| vec![2, 2]));
    // Left-to-right keeps the chain order so intermediates stay 24×24.
    let plan = make_plan(&expr, dims.clone(), Strategy::LeftToRight);
    let mut rng = Rng::new(4);
    let ins = rand_inputs(&dims, &mut rng);
    let refs: Vec<&Tensor> = ins.iter().collect();
    let ad = PathAutodiff::new(&plan).unwrap();

    let mut ws = TrainWorkspace::new();
    let mut peaks = Vec::new();
    for policy in [CkptPolicy::StoreAll, CkptPolicy::Sqrt, CkptPolicy::None] {
        let meter = MemoryMeter::new();
        let (_o, _g) = ad
            .forward_backward(
                &refs,
                |o| Tensor::full(o.shape(), 1.0),
                policy,
                &mut ws,
                &meter,
            )
            .unwrap();
        peaks.push(meter.peak_bytes());
    }
    assert!(
        peaks[0] > peaks[1],
        "StoreAll peak {} should exceed Sqrt peak {}",
        peaks[0],
        peaks[1]
    );
    // CkptPolicy::None recomputes the whole prefix at the first backward
    // step and keeps it live for the remaining steps, so its *peak* matches
    // StoreAll — which is exactly why the paper uses segment checkpointing
    // rather than full recomputation. Sqrt must beat both.
    assert!(
        peaks[2] >= peaks[1],
        "None peak {} should be ≥ Sqrt peak {}",
        peaks[2],
        peaks[1]
    );
}

#[test]
fn forward_only_frees_dead_intermediates() {
    let expr = "ij,jk,kl,lm->im";
    let dims = vec![vec![16, 16]; 4];
    let plan = make_plan(expr, dims.clone(), Strategy::LeftToRight);
    let mut rng = Rng::new(5);
    let ins = rand_inputs(&dims, &mut rng);
    let refs: Vec<&Tensor> = ins.iter().collect();
    let ad = PathAutodiff::new(&plan).unwrap();
    let meter = MemoryMeter::new();
    let out = ad.forward(&refs, &meter).unwrap();
    assert_eq!(out.shape(), &[16, 16]);
    // Peak should be well under "inputs + all intermediates":
    let all = 4 * 16 * 16 * 4 + 3 * 16 * 16 * 4;
    assert!(meter.peak_bytes() < all);
    // Live at the end: inputs (cloned) + output only.
    assert!(meter.live_bytes() <= 5 * 16 * 16 * 4 + 16 * 16 * 4);
}

#[test]
fn meter_tracks_alloc_free() {
    let m = MemoryMeter::new();
    m.alloc(100);
    m.alloc(50);
    assert_eq!(m.live_bytes(), 150);
    assert_eq!(m.peak_bytes(), 150);
    m.free(100);
    assert_eq!(m.live_bytes(), 50);
    assert_eq!(m.peak_bytes(), 150);
    m.alloc(60);
    assert_eq!(m.peak_bytes(), 150);
    m.reset();
    assert_eq!(m.peak_bytes(), 0);
}

#[test]
fn conv_path_grads_policy_invariant() {
    // Gradient equality across policies for a *convolutional* TNN path.
    let expr = "bsh,(r1)t,(r1)(r2)h,(r2)s->bth|h";
    let dims = vec![vec![2, 3, 6], vec![2, 4], vec![2, 2, 3], vec![2, 3]];
    let plan = make_plan(expr, dims.clone(), Strategy::Optimal);
    let mut rng = Rng::new(6);
    let ins = rand_inputs(&dims, &mut rng);
    let refs: Vec<&Tensor> = ins.iter().collect();
    let ad = PathAutodiff::new(&plan).unwrap();
    let meter = MemoryMeter::new();
    let mut ws = TrainWorkspace::new();
    let out = ad.forward(&refs, &meter).unwrap();
    let dout = fixed_dout(out.shape(), &mut rng);
    let d1 = dout.clone();
    let d2 = dout.clone();
    let (_o1, g1) = ad
        .forward_backward(&refs, |_| d1.clone(), CkptPolicy::StoreAll, &mut ws, &meter)
        .unwrap();
    let (_o2, g2) = ad
        .forward_backward(&refs, |_| d2.clone(), CkptPolicy::Sqrt, &mut ws, &meter)
        .unwrap();
    for i in 0..ins.len() {
        g2[i].assert_close(&g1[i], 1e-4);
    }
}

#[test]
fn meter_balances_to_zero_across_policies_and_final_perm() {
    // The meter must return to zero live bytes after every completed
    // forward+backward step — including on plans with a final output
    // permutation, where the old heap tape metered the permuted output as
    // an alloc with no matching free.
    let mut rng = Rng::new(21);
    for expr in ["ij,jk->ik", "ij,jk->ki"] {
        let dims = vec![vec![4, 5], vec![5, 6]];
        let plan = make_plan(expr, dims.clone(), Strategy::Optimal);
        let ins = rand_inputs(&dims, &mut rng);
        let refs: Vec<&Tensor> = ins.iter().collect();
        let ad = PathAutodiff::new(&plan).unwrap();
        let mut ws = TrainWorkspace::new();
        for policy in [CkptPolicy::StoreAll, CkptPolicy::Sqrt, CkptPolicy::None] {
            let meter = MemoryMeter::new();
            let (_o, _g) = ad
                .forward_backward(
                    &refs,
                    |o| Tensor::full(o.shape(), 1.0),
                    policy,
                    &mut ws,
                    &meter,
                )
                .unwrap();
            assert_eq!(
                meter.live_bytes(),
                0,
                "{expr} {policy:?}: meter must balance after forward+backward"
            );
            assert!(meter.peak_bytes() > 0, "{expr} {policy:?}: peak recorded");
        }
    }
    // The second expression really does exercise the final permutation.
    let plan = make_plan("ij,jk->ki", vec![vec![4, 5], vec![5, 6]], Strategy::Optimal);
    assert!(plan.final_perm.is_some(), "ki output must need a final perm");
}

#[test]
fn stale_or_consumed_tapes_are_rejected() {
    let expr = "ij,jk->ik";
    let dims = vec![vec![3, 4], vec![4, 5]];
    let plan = make_plan(expr, dims.clone(), Strategy::Optimal);
    let mut rng = Rng::new(22);
    let ins = rand_inputs(&dims, &mut rng);
    let refs: Vec<&Tensor> = ins.iter().collect();
    let ad = PathAutodiff::new(&plan).unwrap();
    let meter = MemoryMeter::new();
    let mut ws = TrainWorkspace::new();
    let dout = Tensor::full(&[3, 5], 1.0);

    // A later taped forward on the same workspace invalidates the tape.
    let stale = ad
        .forward_with_tape(&refs, CkptPolicy::StoreAll, &mut ws, &meter)
        .unwrap();
    let live = ad
        .forward_with_tape(&refs, CkptPolicy::StoreAll, &mut ws, &meter)
        .unwrap();
    assert!(
        ad.backward(&stale, &dout, &mut ws, &meter).is_err(),
        "stale tape must be rejected"
    );
    // The most recent tape still works — once.
    let grads = ad.backward(&live, &dout, &mut ws, &meter).unwrap();
    assert_eq!(grads.len(), 2);
    assert!(
        ad.backward(&live, &dout, &mut ws, &meter).is_err(),
        "a consumed tape must be rejected"
    );

    // A tape is bound to the workspace whose arena holds it: a backward
    // against a different workspace must be rejected even when that
    // workspace has a tape of its own (same plan, same-looking epoch).
    let mut other = TrainWorkspace::new();
    let mine = ad
        .forward_with_tape(&refs, CkptPolicy::StoreAll, &mut ws, &meter)
        .unwrap();
    let _theirs = ad
        .forward_with_tape(&refs, CkptPolicy::StoreAll, &mut other, &meter)
        .unwrap();
    assert!(
        ad.backward(&mine, &dout, &mut other, &meter).is_err(),
        "a tape from another workspace must be rejected"
    );
}

#[test]
fn pairwise_and_path_agree_on_two_inputs() {
    let expr = "bshw,tshw->bthw|hw";
    let dims = vec![vec![1, 2, 5, 5], vec![3, 2, 3, 3]];
    let plan = make_plan(expr, dims.clone(), Strategy::Optimal);
    let mut rng = Rng::new(7);
    let ins = rand_inputs(&dims, &mut rng);
    let ad = PathAutodiff::new(&plan).unwrap();
    let meter = MemoryMeter::new();
    let got = ad.forward(&[&ins[0], &ins[1]], &meter).unwrap();
    let sized = SizedSpec::new(parse(expr).unwrap(), dims).unwrap();
    let want = pairwise(&sized, &ins[0], &ins[1]);
    got.assert_close(&want, 1e-4);
}
