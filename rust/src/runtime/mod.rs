//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO **text** — see DESIGN.md: jax ≥ 0.5 emits protos with 64-bit ids
//! that xla_extension 0.5.1 rejects, so text is the interchange format) and
//! executes them from the rust hot path via the `xla` crate's PJRT CPU
//! client. Python never runs at request time.
//!
//! The whole XLA-backed implementation is gated behind the off-by-default
//! `pjrt` cargo feature so the default build carries no external native
//! dependencies. With the feature disabled this module exposes an
//! API-compatible stub whose [`ArtifactRegistry::open`] fails with a clear
//! error; callers that probe for artifacts (`runtime_aot` tests,
//! `serve_layers`, `conv-einsum artifacts`) degrade gracefully.

/// Metadata for one compiled artifact, mirrored from `manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    /// HLO text file, relative to the manifest's directory.
    pub file: String,
    pub input_shapes: Vec<Vec<usize>>,
    pub output_shape: Vec<usize>,
    /// Free-form description (layer type, path strategy, etc).
    pub description: String,
}

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{literal_to_tensor, tensor_to_literal, ArtifactRegistry};

#[cfg(not(feature = "pjrt"))]
mod stub {
    use super::ArtifactMeta;
    use crate::tensor::Tensor;
    use anyhow::{anyhow, Result};
    use std::path::Path;

    const DISABLED: &str = "PJRT runtime disabled: this build was compiled without the \
         `pjrt` cargo feature. Rebuild with `--features pjrt` (and add the \
         `xla` crate dependency noted in Cargo.toml) to execute AOT artifacts.";

    /// Stub registry compiled when the `pjrt` feature is off. `open` always
    /// fails with a clear diagnostic; the accessor methods exist only to
    /// keep the API surface identical to the real registry.
    pub struct ArtifactRegistry {}

    impl ArtifactRegistry {
        /// Always fails: the PJRT runtime is not compiled in.
        pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
            Err(anyhow!(
                "{DISABLED} (requested artifact dir: {})",
                dir.as_ref().display()
            ))
        }

        pub fn names(&self) -> Vec<&str> {
            Vec::new()
        }

        pub fn meta(&self, _name: &str) -> Option<&ArtifactMeta> {
            None
        }

        pub fn platform(&self) -> String {
            "disabled".to_string()
        }

        pub fn execute(&mut self, _name: &str, _inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
            Err(anyhow!("{DISABLED}"))
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn stub_open_reports_disabled_feature() {
            let err = ArtifactRegistry::open("artifacts").err().expect("must fail");
            let msg = format!("{err}");
            assert!(msg.contains("pjrt"), "diagnostic should name the feature: {msg}");
            assert!(msg.contains("artifacts"), "diagnostic should name the dir: {msg}");
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::ArtifactRegistry;
