//! The real PJRT runtime (compiled only with `--features pjrt`): loads AOT
//! HLO-text artifacts and executes them via the `xla` crate's PJRT CPU
//! client.
//!
//! Enabling the `pjrt` feature requires adding the external `xla` crate
//! (0.5.1) to Cargo.toml yourself — it cannot be vendored into the offline
//! build (see the feature's comment in Cargo.toml).

use super::ArtifactMeta;
use crate::tensor::Tensor;
use crate::util::json::{parse as json_parse, Json};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Registry of AOT artifacts: lazy-compiles HLO text on first use and
/// caches the loaded executable.
pub struct ArtifactRegistry {
    dir: PathBuf,
    metas: HashMap<String, ArtifactMeta>,
    client: xla::PjRtClient,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl ArtifactRegistry {
    /// Open `artifacts/` via its `manifest.json`.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let json = json_parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
        let mut metas = HashMap::new();
        for entry in json
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'artifacts' array"))?
        {
            let meta = ArtifactMeta {
                name: entry
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("artifact missing name"))?
                    .to_string(),
                file: entry
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("artifact missing file"))?
                    .to_string(),
                input_shapes: shapes_from(entry.get("input_shapes"))?,
                output_shape: shape_from(entry.get("output_shape"))?,
                description: entry
                    .get("description")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string(),
            };
            metas.insert(meta.name.clone(), meta);
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(ArtifactRegistry {
            dir,
            metas,
            client,
            compiled: HashMap::new(),
        })
    }

    pub fn names(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self.metas.keys().map(String::as_str).collect();
        names.sort_unstable();
        names
    }

    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.metas.get(name)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) and return a handle for execution.
    fn ensure_compiled(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.compiled.contains_key(name) {
            let meta = self
                .metas
                .get(name)
                .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
            let path = self.dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling '{name}': {e:?}"))?;
            self.compiled.insert(name.to_string(), exe);
        }
        Ok(&self.compiled[name])
    }

    /// Execute an artifact on native tensors. Outputs are returned as
    /// native tensors (the artifacts are lowered with `return_tuple=True`,
    /// so the single result literal is a tuple).
    pub fn execute(&mut self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let meta = self
            .metas
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?
            .clone();
        if inputs.len() != meta.input_shapes.len() {
            return Err(anyhow!(
                "'{name}' expects {} inputs, got {}",
                meta.input_shapes.len(),
                inputs.len()
            ));
        }
        for (i, (t, want)) in inputs.iter().zip(meta.input_shapes.iter()).enumerate() {
            if t.shape() != &want[..] {
                return Err(anyhow!(
                    "'{name}' input {i}: shape {:?} != expected {:?}",
                    t.shape(),
                    want
                ));
            }
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| tensor_to_literal(t))
            .collect::<Result<_>>()?;
        let exe = self.ensure_compiled(name)?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing '{name}': {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of '{name}': {e:?}"))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("untupling result of '{name}': {e:?}"))?;
        parts.iter().map(literal_to_tensor).collect()
    }
}

fn shapes_from(v: Option<&Json>) -> Result<Vec<Vec<usize>>> {
    v.and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("bad input_shapes"))?
        .iter()
        .map(|s| shape_from(Some(s)))
        .collect()
}

fn shape_from(v: Option<&Json>) -> Result<Vec<usize>> {
    v.and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("bad shape"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect()
}

/// Native tensor → PJRT literal (f32).
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(t.data())
        .reshape(&dims)
        .map_err(|e| anyhow!("literal reshape: {e:?}"))
}

/// PJRT literal → native tensor (f32).
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.shape().map_err(|e| anyhow!("literal shape: {e:?}"))?;
    let dims: Vec<usize> = match &shape {
        xla::Shape::Array(a) => a.dims().iter().map(|&d| d as usize).collect(),
        _ => return Err(anyhow!("expected array literal")),
    };
    let data = lit
        .to_vec::<f32>()
        .map_err(|e| anyhow!("literal to_vec: {e:?}"))?;
    Ok(Tensor::from_vec(&dims, data))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    // Literal round-trips exercise the PJRT bridge without artifacts.
    #[test]
    fn literal_roundtrip() {
        let mut rng = Rng::new(1);
        let t = Tensor::rand(&[2, 3, 4], -1.0, 1.0, &mut rng);
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit).unwrap();
        back.assert_close(&t, 0.0);
    }

    #[test]
    fn registry_missing_dir_errors() {
        assert!(ArtifactRegistry::open("/nonexistent/artifacts").is_err());
    }

    #[test]
    fn registry_rejects_bad_manifest() {
        let dir = std::env::temp_dir().join("conv_einsum_badmanifest");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
        assert!(ArtifactRegistry::open(&dir).is_err());
        std::fs::write(dir.join("manifest.json"), "{\"artifacts\": [{}]}").unwrap();
        assert!(ArtifactRegistry::open(&dir).is_err());
    }

    // Full load-and-execute integration lives in rust/tests/runtime_aot.rs
    // (requires `make artifacts`).
}
