//! Training loop with per-epoch wall-clock timing, loss/accuracy logging
//! and peak-tape-memory tracking — the measurement harness behind the
//! paper's runtime tables/figures.

use super::data::Dataset;
use super::loss::softmax_cross_entropy;
use super::model::Sequential;
use super::optim::Sgd;
use std::time::{Duration, Instant};

/// Trainer configuration.
#[derive(Debug, Clone)]
pub struct TrainerConfig {
    pub batch_size: usize,
    pub epochs: usize,
    /// Steps per epoch = ceil(dataset len / batch).
    pub log_every: usize,
    pub lr_decay_every: usize,
    pub lr_decay_factor: f32,
    pub verbose: bool,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            batch_size: 16,
            epochs: 1,
            log_every: 0,
            lr_decay_every: 30,
            lr_decay_factor: 0.5,
            verbose: false,
        }
    }
}

/// Per-epoch statistics.
#[derive(Debug, Clone)]
pub struct EpochStats {
    pub epoch: usize,
    pub train_loss: f32,
    pub train_acc: f32,
    pub train_time: Duration,
    pub eval_loss: f32,
    pub eval_acc: f32,
    pub eval_time: Duration,
    pub peak_tape_bytes: usize,
}

/// Orchestrates train/eval epochs over a model + dataset.
pub struct Trainer {
    pub config: TrainerConfig,
    pub optimizer: Sgd,
}

impl Trainer {
    pub fn new(config: TrainerConfig, optimizer: Sgd) -> Self {
        Trainer { config, optimizer }
    }

    fn n_batches(&self, ds: &dyn Dataset) -> usize {
        ds.len().div_ceil(self.config.batch_size).max(1)
    }

    /// One training epoch; returns (mean loss, mean acc, wall time, peak tape bytes).
    pub fn train_epoch(
        &mut self,
        model: &mut Sequential,
        ds: &dyn Dataset,
        epoch: usize,
    ) -> (f32, f32, Duration, usize) {
        self.optimizer.decay_lr(
            epoch,
            self.config.lr_decay_every,
            self.config.lr_decay_factor,
        );
        model.reset_peaks();
        let nb = self.n_batches(ds);
        let t0 = Instant::now();
        let mut loss_sum = 0.0;
        let mut acc_sum = 0.0;
        for bi in 0..nb {
            let (x, labels) = ds.batch(bi + epoch * nb, self.config.batch_size);
            let logits = model.forward(&x, true);
            let out = softmax_cross_entropy(&logits, &labels);
            model.backward(&out.dlogits);
            let mut params = model.params_mut();
            self.optimizer.step(&mut params);
            loss_sum += out.loss;
            acc_sum += out.accuracy;
            if self.config.verbose
                && self.config.log_every > 0
                && bi % self.config.log_every == 0
            {
                println!(
                    "  epoch {epoch} step {bi}/{nb}: loss {:.4} acc {:.3}",
                    out.loss, out.accuracy
                );
            }
        }
        (
            loss_sum / nb as f32,
            acc_sum / nb as f32,
            t0.elapsed(),
            model.peak_tape_bytes(),
        )
    }

    /// One evaluation epoch (no grads): (mean loss, mean acc, wall time).
    pub fn eval_epoch(
        &self,
        model: &mut Sequential,
        ds: &dyn Dataset,
    ) -> (f32, f32, Duration) {
        let nb = self.n_batches(ds);
        let t0 = Instant::now();
        let mut loss_sum = 0.0;
        let mut acc_sum = 0.0;
        for bi in 0..nb {
            let (x, labels) = ds.batch(1_000_000 + bi, self.config.batch_size);
            let logits = model.forward(&x, false);
            let out = softmax_cross_entropy(&logits, &labels);
            loss_sum += out.loss;
            acc_sum += out.accuracy;
        }
        (loss_sum / nb as f32, acc_sum / nb as f32, t0.elapsed())
    }

    /// Full run: `epochs` train+eval rounds.
    pub fn fit(
        &mut self,
        model: &mut Sequential,
        train: &dyn Dataset,
        eval: &dyn Dataset,
    ) -> Vec<EpochStats> {
        let mut stats = Vec::new();
        for epoch in 0..self.config.epochs {
            let (train_loss, train_acc, train_time, peak) =
                self.train_epoch(model, train, epoch);
            let (eval_loss, eval_acc, eval_time) = self.eval_epoch(model, eval);
            if self.config.verbose {
                println!(
                    "epoch {epoch}: train loss {train_loss:.4} acc {train_acc:.3} ({train_time:?}) | eval loss {eval_loss:.4} acc {eval_acc:.3} ({eval_time:?})"
                );
            }
            stats.push(EpochStats {
                epoch,
                train_loss,
                train_acc,
                train_time,
                eval_loss,
                eval_acc,
                eval_time,
                peak_tape_bytes: peak,
            });
        }
        stats
    }
}
