//! Synthetic datasets shaped like the paper's tasks (DESIGN.md §5
//! substitutions): runtime/memory results depend only on tensor shapes and
//! batch sizes, and the accuracy *trend* (Table 7) is reproduced on a
//! learnable class-conditional task.
//!
//! Images: each class has a deterministic frequency/orientation signature
//! (2-D sinusoid bank) plus pixel noise — linearly separable enough to
//! learn quickly, hard enough that capacity (rank/CR) matters.

use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// A labelled-batch provider.
pub trait Dataset {
    /// Total examples per epoch.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    fn n_classes(&self) -> usize;
    /// Sample a batch; deterministic in (seed at construction, batch index).
    fn batch(&self, index: usize, batch_size: usize) -> (Tensor, Vec<usize>);
}

/// CIFAR-like class-conditional synthetic image dataset `[B, C, H, W]`.
pub struct SyntheticImages {
    pub channels: usize,
    pub height: usize,
    pub width: usize,
    pub classes: usize,
    pub epoch_size: usize,
    pub noise: f32,
    seed: u64,
}

impl SyntheticImages {
    pub fn cifar_like(epoch_size: usize, seed: u64) -> Self {
        SyntheticImages {
            channels: 3,
            height: 32,
            width: 32,
            classes: 10,
            epoch_size,
            noise: 0.3,
            seed,
        }
    }

    pub fn sized(
        channels: usize,
        height: usize,
        width: usize,
        classes: usize,
        epoch_size: usize,
        seed: u64,
    ) -> Self {
        SyntheticImages {
            channels,
            height,
            width,
            classes,
            epoch_size,
            noise: 0.3,
            seed,
        }
    }

    fn render(&self, class: usize, rng: &mut Rng) -> Vec<f32> {
        let (c, h, w) = (self.channels, self.height, self.width);
        let mut out = vec![0.0f32; c * h * w];
        // class signature: orientation + frequency + channel phase
        let theta = class as f32 * std::f32::consts::PI / self.classes as f32;
        let freq = 1.0 + (class % 4) as f32;
        let (ct, st) = (theta.cos(), theta.sin());
        for ci in 0..c {
            let phase = ci as f32 * 0.7 + class as f32 * 0.21;
            for i in 0..h {
                for j in 0..w {
                    let u = i as f32 / h as f32;
                    let v = j as f32 / w as f32;
                    let proj = u * ct + v * st;
                    let val = (2.0 * std::f32::consts::PI * freq * proj + phase).sin();
                    out[(ci * h + i) * w + j] =
                        val + self.noise * rng.normal() as f32;
                }
            }
        }
        out
    }
}

impl Dataset for SyntheticImages {
    fn len(&self) -> usize {
        self.epoch_size
    }

    fn n_classes(&self) -> usize {
        self.classes
    }

    fn batch(&self, index: usize, batch_size: usize) -> (Tensor, Vec<usize>) {
        let mut rng = Rng::new(self.seed ^ (index as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let mut data = Vec::with_capacity(batch_size * self.channels * self.height * self.width);
        let mut labels = Vec::with_capacity(batch_size);
        for _ in 0..batch_size {
            let class = rng.below(self.classes);
            labels.push(class);
            data.extend(self.render(class, &mut rng));
        }
        (
            Tensor::from_vec(
                &[batch_size, self.channels, self.height, self.width],
                data,
            ),
            labels,
        )
    }
}

/// ASR-like synthetic sequences `[B, C, T, 1]` (log-mel-ish feature maps
/// over time; W′=1 matches the 1-D convolution sites of the Conformer
/// module). Classes differ by temporal modulation frequency.
pub struct SyntheticSequences {
    pub channels: usize,
    pub frames: usize,
    pub classes: usize,
    pub epoch_size: usize,
    seed: u64,
}

impl SyntheticSequences {
    pub fn librispeech_like(channels: usize, frames: usize, epoch_size: usize, seed: u64) -> Self {
        SyntheticSequences {
            channels,
            frames,
            classes: 10,
            epoch_size,
            seed,
        }
    }
}

impl Dataset for SyntheticSequences {
    fn len(&self) -> usize {
        self.epoch_size
    }

    fn n_classes(&self) -> usize {
        self.classes
    }

    fn batch(&self, index: usize, batch_size: usize) -> (Tensor, Vec<usize>) {
        let mut rng = Rng::new(self.seed ^ (index as u64).wrapping_mul(0xD1B54A32D192ED03));
        let (c, t) = (self.channels, self.frames);
        let mut data = Vec::with_capacity(batch_size * c * t);
        let mut labels = Vec::with_capacity(batch_size);
        for _ in 0..batch_size {
            let class = rng.below(self.classes);
            labels.push(class);
            let freq = 1.0 + class as f32 * 0.5;
            for ci in 0..c {
                let phase = ci as f32 * 0.3;
                for ti in 0..t {
                    let x = ti as f32 / t as f32;
                    data.push(
                        (2.0 * std::f32::consts::PI * freq * x + phase).sin()
                            + 0.3 * rng.normal() as f32,
                    );
                }
            }
        }
        (Tensor::from_vec(&[batch_size, c, t, 1], data), labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_batches_deterministic() {
        let ds = SyntheticImages::cifar_like(100, 7);
        let (a, la) = ds.batch(3, 4);
        let (b, lb) = ds.batch(3, 4);
        assert_eq!(a, b);
        assert_eq!(la, lb);
        let (c, _) = ds.batch(4, 4);
        assert!(a != c, "different batch indices must differ");
    }

    #[test]
    fn image_shapes() {
        let ds = SyntheticImages::sized(3, 16, 16, 5, 50, 1);
        let (x, labels) = ds.batch(0, 8);
        assert_eq!(x.shape(), &[8, 3, 16, 16]);
        assert_eq!(labels.len(), 8);
        assert!(labels.iter().all(|&l| l < 5));
    }

    #[test]
    fn classes_are_distinguishable() {
        // Same-class examples should correlate more than cross-class ones.
        let ds = SyntheticImages::sized(1, 16, 16, 4, 50, 2);
        let mut rng = Rng::new(3);
        let a0 = ds.render(0, &mut rng);
        let a0b = ds.render(0, &mut rng);
        let a2 = ds.render(2, &mut rng);
        let corr = |x: &[f32], y: &[f32]| -> f32 {
            let n = x.len() as f32;
            let mx = x.iter().sum::<f32>() / n;
            let my = y.iter().sum::<f32>() / n;
            let cov: f32 = x.iter().zip(y).map(|(a, b)| (a - mx) * (b - my)).sum();
            let vx: f32 = x.iter().map(|a| (a - mx) * (a - mx)).sum();
            let vy: f32 = y.iter().map(|b| (b - my) * (b - my)).sum();
            cov / (vx.sqrt() * vy.sqrt() + 1e-9)
        };
        assert!(corr(&a0, &a0b) > corr(&a0, &a2) + 0.2);
    }

    #[test]
    fn sequence_shapes() {
        let ds = SyntheticSequences::librispeech_like(8, 32, 100, 5);
        let (x, labels) = ds.batch(1, 6);
        assert_eq!(x.shape(), &[6, 8, 32, 1]);
        assert_eq!(labels.len(), 6);
    }
}
