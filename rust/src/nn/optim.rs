//! SGD with momentum and weight decay — the paper's §5 training setup
//! (weight decay 5e-4, momentum 0.9, lr 0.05 with step decay 0.5 / 30
//! epochs).

use crate::tensor::Tensor;

/// SGD with classical momentum and decoupled L2 weight decay.
pub struct Sgd {
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    /// Per-parameter velocity buffers, lazily initialized.
    velocity: Vec<Tensor>,
}

impl Sgd {
    /// The paper's hyperparameters.
    pub fn paper_defaults() -> Self {
        Sgd::new(0.05, 0.9, 5e-4)
    }

    pub fn new(lr: f32, momentum: f32, weight_decay: f32) -> Self {
        Sgd {
            lr,
            momentum,
            weight_decay,
            velocity: Vec::new(),
        }
    }

    /// Step-decay schedule: ×0.5 every `every` epochs (paper: 30).
    pub fn decay_lr(&mut self, epoch: usize, every: usize, factor: f32) {
        if every > 0 && epoch > 0 && epoch % every == 0 {
            self.lr *= factor;
        }
    }

    /// Apply one update to `(param, grad)` pairs; grads are zeroed after.
    pub fn step(&mut self, params: &mut [(&mut Tensor, &mut Tensor)]) {
        if self.velocity.len() != params.len() {
            self.velocity = params
                .iter()
                .map(|(p, _)| Tensor::zeros(p.shape()))
                .collect();
        }
        for (i, (param, grad)) in params.iter_mut().enumerate() {
            let v = &mut self.velocity[i];
            assert_eq!(v.shape(), param.shape(), "optimizer state shape drift");
            let (vd, pd, gd) = (v.data_mut(), param.data_mut(), grad.data_mut());
            let (lr, mu, wd) = (self.lr, self.momentum, self.weight_decay);
            for k in 0..pd.len() {
                let g = gd[k] + wd * pd[k];
                vd[k] = mu * vd[k] + g;
                pd[k] -= lr * vd[k];
                gd[k] = 0.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gradient_descent_reduces_quadratic() {
        // minimize f(x) = ||x||² from x=1: x ← x(1 − 2lr)…
        let mut x = Tensor::full(&[4], 1.0);
        let mut g = Tensor::zeros(&[4]);
        let mut opt = Sgd::new(0.1, 0.0, 0.0);
        for _ in 0..50 {
            for k in 0..4 {
                g.data_mut()[k] = 2.0 * x.data()[k];
            }
            opt.step(&mut [(&mut x, &mut g)]);
        }
        assert!(x.data().iter().all(|v| v.abs() < 1e-3));
    }

    #[test]
    fn momentum_accelerates() {
        let run = |mu: f32| {
            let mut x = Tensor::full(&[1], 1.0);
            let mut g = Tensor::zeros(&[1]);
            let mut opt = Sgd::new(0.02, mu, 0.0);
            for _ in 0..30 {
                g.data_mut()[0] = 2.0 * x.data()[0];
                opt.step(&mut [(&mut x, &mut g)]);
            }
            x.data()[0].abs()
        };
        assert!(run(0.9) < run(0.0));
    }

    #[test]
    fn weight_decay_shrinks_params_without_grad() {
        let mut x = Tensor::full(&[1], 1.0);
        let mut g = Tensor::zeros(&[1]);
        let mut opt = Sgd::new(0.1, 0.0, 0.5);
        opt.step(&mut [(&mut x, &mut g)]);
        assert!(x.data()[0] < 1.0);
    }

    #[test]
    fn grads_zeroed_after_step() {
        let mut x = Tensor::full(&[2], 1.0);
        let mut g = Tensor::full(&[2], 3.0);
        let mut opt = Sgd::new(0.1, 0.9, 0.0);
        opt.step(&mut [(&mut x, &mut g)]);
        assert_eq!(g.data(), &[0.0, 0.0]);
    }

    #[test]
    fn lr_decay_schedule() {
        let mut opt = Sgd::new(0.05, 0.9, 0.0);
        opt.decay_lr(30, 30, 0.5);
        assert!((opt.lr - 0.025).abs() < 1e-9);
        opt.decay_lr(31, 30, 0.5);
        assert!((opt.lr - 0.025).abs() < 1e-9);
    }
}
