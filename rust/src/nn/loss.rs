//! Softmax cross-entropy loss with gradient and accuracy.

use crate::tensor::Tensor;

/// Result of a loss evaluation on a batch.
pub struct SoftmaxCeLoss {
    /// Mean cross-entropy over the batch.
    pub loss: f32,
    /// ∂loss/∂logits (already averaged over the batch).
    pub dlogits: Tensor,
    /// Top-1 accuracy on the batch.
    pub accuracy: f32,
}

/// Compute softmax cross-entropy for `[B, C]` logits and integer labels.
pub fn softmax_cross_entropy(logits: &Tensor, labels: &[usize]) -> SoftmaxCeLoss {
    let (b, c) = (logits.shape()[0], logits.shape()[1]);
    assert_eq!(labels.len(), b);
    let mut dlogits = Tensor::zeros(&[b, c]);
    let mut loss = 0.0f32;
    let mut correct = 0usize;
    let inv_b = 1.0 / b as f32;
    for bi in 0..b {
        let row = &logits.data()[bi * c..(bi + 1) * c];
        let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f32> = row.iter().map(|&v| (v - maxv).exp()).collect();
        let z: f32 = exps.iter().sum();
        let label = labels[bi];
        assert!(label < c, "label out of range");
        let p_label = exps[label] / z;
        loss += -(p_label.max(1e-12)).ln();
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        if argmax == label {
            correct += 1;
        }
        let drow = &mut dlogits.data_mut()[bi * c..(bi + 1) * c];
        for k in 0..c {
            let p = exps[k] / z;
            drow[k] = (p - if k == label { 1.0 } else { 0.0 }) * inv_b;
        }
    }
    SoftmaxCeLoss {
        loss: loss * inv_b,
        dlogits,
        accuracy: correct as f32 * inv_b,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_logits_give_log_c() {
        let logits = Tensor::zeros(&[4, 10]);
        let labels = vec![0, 3, 5, 9];
        let out = softmax_cross_entropy(&logits, &labels);
        assert!((out.loss - (10.0f32).ln()).abs() < 1e-5);
    }

    #[test]
    fn confident_correct_prediction_low_loss() {
        let mut logits = Tensor::zeros(&[1, 3]);
        logits.set(&[0, 1], 10.0);
        let out = softmax_cross_entropy(&logits, &[1]);
        assert!(out.loss < 1e-3);
        assert_eq!(out.accuracy, 1.0);
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let mut logits = Tensor::from_vec(&[2, 3], vec![0.2, -0.4, 0.6, 1.0, 0.0, -1.0]);
        let labels = vec![2, 0];
        let out = softmax_cross_entropy(&logits, &labels);
        let eps = 1e-3f32;
        for k in 0..6 {
            let orig = logits.data()[k];
            logits.data_mut()[k] = orig + eps;
            let lp = softmax_cross_entropy(&logits, &labels).loss;
            logits.data_mut()[k] = orig - eps;
            let lm = softmax_cross_entropy(&logits, &labels).loss;
            logits.data_mut()[k] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            let an = out.dlogits.data()[k];
            assert!((fd - an).abs() < 1e-3, "coord {k}: fd={fd} an={an}");
        }
    }

    #[test]
    fn gradient_rows_sum_to_zero() {
        let logits = Tensor::from_vec(&[1, 4], vec![1.0, 2.0, 3.0, 4.0]);
        let out = softmax_cross_entropy(&logits, &[2]);
        let s: f32 = out.dlogits.data().iter().sum();
        assert!(s.abs() < 1e-6);
    }
}
