//! Layers. The tensorial convolution layer is the paper's object of study:
//! its forward/backward run along a planner-chosen pairwise path
//! (optimal / left-to-right) under a checkpoint policy — exactly the three
//! execution modes compared throughout the paper's §5.

use crate::autodiff::{CkptPolicy, MemoryMeter, PathAutodiff, Tape};
use crate::einsum::parse;
use crate::einsum::SizedSpec;
use crate::exec::{CompiledPlan, TrainWorkspace, Workspace};
use crate::planner::{plan_with, PlanOptions, Strategy};
use crate::tensor::Tensor;
use crate::tnn::TnnLayerSpec;
use crate::util::lru::LruCache;
use crate::util::rng::Rng;
use std::sync::Arc;

/// Bound on a [`TensorialConv2d`]'s per-geometry compiled-plan cache:
/// alternating batch sizes / spatial shapes (train vs eval, ragged last
/// batches) stay compiled, while unbounded geometry churn evicts LRU-first.
pub const GEOMETRY_PLAN_CACHE_CAPACITY: usize = 8;

/// How tensorial layers evaluate: the paper's experimental axes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EvalConfig {
    /// Path selection: Optimal = conv_einsum, LeftToRight = naive baseline.
    pub strategy: Strategy,
    /// Checkpoint policy for the backward tape.
    pub ckpt: CkptPolicy,
    /// Price the plan with the training cost model (f + g1 + g2).
    pub training_cost_model: bool,
}

impl Default for EvalConfig {
    fn default() -> Self {
        EvalConfig {
            strategy: Strategy::Optimal,
            ckpt: CkptPolicy::Sqrt,
            training_cost_model: true,
        }
    }
}

impl EvalConfig {
    /// The paper's "conv_einsum" mode.
    pub fn conv_einsum() -> Self {
        Self::default()
    }

    /// The paper's "naive w/ ckpt" baseline.
    pub fn naive_ckpt() -> Self {
        EvalConfig {
            strategy: Strategy::LeftToRight,
            ckpt: CkptPolicy::Sqrt,
            training_cost_model: false,
        }
    }

    /// The paper's "naive w/o ckpt" baseline.
    pub fn naive_no_ckpt() -> Self {
        EvalConfig {
            strategy: Strategy::LeftToRight,
            ckpt: CkptPolicy::StoreAll,
            training_cost_model: false,
        }
    }

    pub fn label(&self) -> &'static str {
        match (self.strategy, self.ckpt) {
            (Strategy::LeftToRight, CkptPolicy::StoreAll) => "naive w/o ckpt",
            (Strategy::LeftToRight, _) => "naive w/ ckpt",
            _ => "conv_einsum",
        }
    }
}

/// A trainable layer.
pub trait Layer {
    /// Forward; caches whatever backward needs when `train` is set.
    fn forward(&mut self, x: &Tensor, train: bool) -> Tensor;
    /// Backward from ∂L/∂y, accumulating parameter grads; returns ∂L/∂x.
    fn backward(&mut self, dy: &Tensor) -> Tensor;
    /// (param, grad) pairs for the optimizer.
    fn params_mut(&mut self) -> Vec<(&mut Tensor, &mut Tensor)> {
        Vec::new()
    }
    fn param_count(&self) -> usize {
        0
    }
    fn name(&self) -> String;
    /// Peak tape memory observed (tensorial layers only).
    fn peak_tape_bytes(&self) -> usize {
        0
    }
    fn reset_peak(&self) {}
}

/// The tensorial convolutional layer (paper §2.3): factors + a planned
/// pairwise path. Input/output are dense `[B, S, H', W']` / `[B, T, H', W']`;
/// channel reshaping to the factorized modes happens inside.
pub struct TensorialConv2d {
    pub spec: TnnLayerSpec,
    pub factors: Vec<Tensor>,
    pub grads: Vec<Tensor>,
    pub eval: EvalConfig,
    /// Compiled-plan cache keyed by (batch, hp, wp): the expression is
    /// planned + lowered once per input geometry and replayed on every
    /// forward/backward. LRU-bounded at [`GEOMETRY_PLAN_CACHE_CAPACITY`],
    /// so alternating geometries (e.g. train batch vs eval batch) keep
    /// their plans instead of thrashing, while arbitrary-shape churn stays
    /// memory-bounded.
    compiled: LruCache<(usize, usize, usize), Arc<CompiledPlan>>,
    /// Reusable training workspace owned by the layer: the tape of a train
    /// forward lives in its arena until `backward` consumes it.
    /// Recompile-on-shape-change reuses it unchanged: the workspace is
    /// plan-agnostic and only ever grows.
    tws: TrainWorkspace,
    /// Separate inference workspace, so an eval forward between a train
    /// forward and its backward (e.g. a mid-epoch validation pass) cannot
    /// clobber the pending tape's arena.
    ws: Workspace,
    tape: Option<Tape>,
    cached_x_shape: Vec<usize>,
    pub meter: MemoryMeter,
}

impl TensorialConv2d {
    pub fn new(spec: TnnLayerSpec, eval: EvalConfig, rng: &mut Rng) -> Self {
        let factors = spec.init_factors(rng);
        let grads = spec
            .factor_shapes
            .iter()
            .map(|s| Tensor::zeros(s))
            .collect();
        TensorialConv2d {
            spec,
            factors,
            grads,
            eval,
            compiled: LruCache::new(GEOMETRY_PLAN_CACHE_CAPACITY),
            tws: TrainWorkspace::new(),
            ws: Workspace::new(),
            tape: None,
            cached_x_shape: Vec::new(),
            meter: MemoryMeter::new(),
        }
    }

    fn compiled_for(&mut self, b: usize, hp: usize, wp: usize) -> Arc<CompiledPlan> {
        let key = (b, hp, wp);
        if let Some(p) = self.compiled.get(&key) {
            return Arc::clone(p);
        }
        let spec = parse(&self.spec.expr).expect("layer expr parses");
        let dims = self.spec.expr_dims(b, hp, wp);
        let sized = SizedSpec::new(spec, dims).expect("layer expr sizes");
        let plan = plan_with(
            &sized,
            &PlanOptions {
                strategy: self.eval.strategy,
                training: self.eval.training_cost_model,
                ..Default::default()
            },
        )
        .expect("layer expr plans");
        let compiled =
            Arc::new(CompiledPlan::compile_arc(Arc::new(plan)).expect("layer expr compiles"));
        self.compiled.insert(key, Arc::clone(&compiled));
        compiled
    }

    /// Number of geometries currently holding a compiled plan (bounded by
    /// [`GEOMETRY_PLAN_CACHE_CAPACITY`]).
    pub fn plan_cache_len(&self) -> usize {
        self.compiled.len()
    }

    /// Planned FLOPs (multiplications) for one forward at this input shape.
    pub fn planned_cost(&mut self, b: usize, hp: usize, wp: usize) -> f64 {
        self.compiled_for(b, hp, wp).plan().cost
    }
}

impl Layer for TensorialConv2d {
    fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        let (b, hp, wp) = (x.shape()[0], x.shape()[2], x.shape()[3]);
        assert_eq!(x.shape()[1], self.spec.s, "input channels mismatch");
        self.cached_x_shape = x.shape().to_vec();
        let x_reshaped = x.clone().reshape(&self.spec.input_shape(b, hp, wp));
        let ckpt = self.eval.ckpt;
        let compiled = self.compiled_for(b, hp, wp);
        let mut inputs: Vec<&Tensor> = vec![&x_reshaped];
        inputs.extend(self.factors.iter());
        if train {
            // Taped forward out of the layer-held training arena: the tape
            // lives in `tws` until backward consumes it, and the step
            // allocates only the output tensor.
            let ad = PathAutodiff::from_compiled(Arc::clone(&compiled));
            let tape = ad
                .forward_with_tape(&inputs, ckpt, &mut self.tws, &self.meter)
                .expect("forward");
            let out = tape.output.clone();
            self.tape = Some(tape);
            out.reshape(&[b, self.spec.t, hp, wp])
        } else {
            // Steady-state inference: replay the compiled plan against the
            // layer-held inference workspace (kept separate from the
            // training arena so a pending tape survives eval forwards) —
            // no planning, no canonicalization analysis, no
            // per-intermediate allocation. Meter the footprint this call
            // actually needs (inputs + the plan's workspace requirement +
            // output), not the workspace's lifetime-grown capacity, so
            // peak_bytes() stays comparable across geometries.
            let input_bytes: usize = inputs.iter().map(|t| t.bytes()).sum();
            let out = compiled.run(&inputs, &mut self.ws).expect("forward");
            let transient = input_bytes + compiled.workspace_bytes() + out.bytes();
            self.meter.alloc(transient);
            self.meter.free(transient);
            out.reshape(&[b, self.spec.t, hp, wp])
        }
    }

    fn backward(&mut self, dy: &Tensor) -> Tensor {
        let (b, hp, wp) = (
            self.cached_x_shape[0],
            self.cached_x_shape[2],
            self.cached_x_shape[3],
        );
        let tape = self.tape.take().expect("backward without forward");
        // Replay the exact compiled plan the tape was produced by (held in
        // the tape token) — re-fetching from the LRU could recompile a
        // structurally identical but distinct entry if enough other
        // geometries ran since the forward, which the tape would reject.
        let ad = PathAutodiff::from_compiled(Arc::clone(tape.token().plan()));
        let dy_shaped = dy.clone().reshape(&self.spec.output_shape(b, hp, wp));
        let grads = ad
            .backward(&tape, &dy_shaped, &mut self.tws, &self.meter)
            .expect("backward");
        // grads[0] is ∂L/∂x (reshaped); the rest are factor grads.
        for (g, acc) in grads[1..].iter().zip(self.grads.iter_mut()) {
            acc.add_assign(g);
        }
        grads[0].clone().reshape(&self.cached_x_shape)
    }

    fn params_mut(&mut self) -> Vec<(&mut Tensor, &mut Tensor)> {
        self.factors.iter_mut().zip(self.grads.iter_mut()).collect()
    }

    fn param_count(&self) -> usize {
        self.spec.params
    }

    fn name(&self) -> String {
        format!(
            "TensorialConv2d[{} m={} {}x{}x{}x{} cr={:.3}]",
            self.spec.decomp.name(),
            self.spec.m,
            self.spec.t,
            self.spec.s,
            self.spec.h,
            self.spec.w,
            self.spec.achieved_cr()
        )
    }

    fn peak_tape_bytes(&self) -> usize {
        self.meter.peak_bytes()
    }

    fn reset_peak(&self) {
        self.meter.reset();
    }
}

/// ReLU.
#[derive(Default)]
pub struct ReLU {
    mask: Option<Vec<bool>>,
}

impl ReLU {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Layer for ReLU {
    fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        if train {
            self.mask = Some(x.data().iter().map(|&v| v > 0.0).collect());
        }
        x.map(|v| v.max(0.0))
    }

    fn backward(&mut self, dy: &Tensor) -> Tensor {
        let mask = self.mask.as_ref().expect("backward without forward");
        let data = dy
            .data()
            .iter()
            .zip(mask.iter())
            .map(|(&d, &m)| if m { d } else { 0.0 })
            .collect();
        Tensor::from_vec(dy.shape(), data)
    }

    fn name(&self) -> String {
        "ReLU".into()
    }
}

/// 2×2 max pooling with stride 2 over the last two axes of `[B,C,H,W]`.
#[derive(Default)]
pub struct MaxPool2 {
    argmax: Option<Vec<usize>>,
    in_shape: Vec<usize>,
}

impl MaxPool2 {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Layer for MaxPool2 {
    fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        let (b, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
        let (oh, ow) = (h / 2, w / 2);
        let mut out = Tensor::zeros(&[b, c, oh, ow]);
        let mut arg = vec![0usize; b * c * oh * ow];
        let xd = x.data();
        let od = out.data_mut();
        for bi in 0..b {
            for ci in 0..c {
                let base = (bi * c + ci) * h * w;
                for i in 0..oh {
                    for j in 0..ow {
                        let mut best = f32::NEG_INFINITY;
                        let mut besti = 0;
                        for di in 0..2 {
                            for dj in 0..2 {
                                let idx = base + (2 * i + di) * w + (2 * j + dj);
                                if xd[idx] > best {
                                    best = xd[idx];
                                    besti = idx;
                                }
                            }
                        }
                        let o = ((bi * c + ci) * oh + i) * ow + j;
                        od[o] = best;
                        arg[o] = besti;
                    }
                }
            }
        }
        if train {
            self.argmax = Some(arg);
            self.in_shape = x.shape().to_vec();
        }
        out
    }

    fn backward(&mut self, dy: &Tensor) -> Tensor {
        let arg = self.argmax.as_ref().expect("backward without forward");
        let mut dx = Tensor::zeros(&self.in_shape);
        let dxd = dx.data_mut();
        for (o, &src) in arg.iter().enumerate() {
            dxd[src] += dy.data()[o];
        }
        dx
    }

    fn name(&self) -> String {
        "MaxPool2".into()
    }
}

/// Global average pooling `[B,C,H,W] -> [B,C]`.
#[derive(Default)]
pub struct GlobalAvgPool {
    in_shape: Vec<usize>,
}

impl GlobalAvgPool {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Layer for GlobalAvgPool {
    fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        let (b, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
        if train {
            self.in_shape = x.shape().to_vec();
        }
        let mut out = Tensor::zeros(&[b, c]);
        let inv = 1.0 / (h * w) as f32;
        let od = out.data_mut();
        for bi in 0..b {
            for ci in 0..c {
                let base = (bi * c + ci) * h * w;
                let s: f32 = x.data()[base..base + h * w].iter().sum();
                od[bi * c + ci] = s * inv;
            }
        }
        out
    }

    fn backward(&mut self, dy: &Tensor) -> Tensor {
        let (b, c, h, w) = (
            self.in_shape[0],
            self.in_shape[1],
            self.in_shape[2],
            self.in_shape[3],
        );
        let inv = 1.0 / (h * w) as f32;
        let mut dx = Tensor::zeros(&self.in_shape);
        let dxd = dx.data_mut();
        for bi in 0..b {
            for ci in 0..c {
                let g = dy.data()[bi * c + ci] * inv;
                let base = (bi * c + ci) * h * w;
                for k in 0..h * w {
                    dxd[base + k] = g;
                }
            }
        }
        dx
    }

    fn name(&self) -> String {
        "GlobalAvgPool".into()
    }
}

/// Fully-connected layer `[B, in] -> [B, out]` with bias.
pub struct Linear {
    pub weight: Tensor, // [out, in]
    pub bias: Tensor,   // [out]
    pub dweight: Tensor,
    pub dbias: Tensor,
    cached_x: Option<Tensor>,
}

impl Linear {
    pub fn new(in_dim: usize, out_dim: usize, rng: &mut Rng) -> Self {
        let std = (2.0 / in_dim as f64).sqrt() as f32;
        Linear {
            weight: Tensor::randn(&[out_dim, in_dim], 0.0, std, rng),
            bias: Tensor::zeros(&[out_dim]),
            dweight: Tensor::zeros(&[out_dim, in_dim]),
            dbias: Tensor::zeros(&[out_dim]),
            cached_x: None,
        }
    }
}

impl Layer for Linear {
    fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        let (b, d) = (x.shape()[0], x.shape()[1]);
        let o = self.weight.shape()[0];
        let mut out = Tensor::zeros(&[b, o]);
        let od = out.data_mut();
        for bi in 0..b {
            let xrow = &x.data()[bi * d..(bi + 1) * d];
            for oi in 0..o {
                let wrow = &self.weight.data()[oi * d..(oi + 1) * d];
                let mut acc = self.bias.data()[oi];
                for k in 0..d {
                    acc += xrow[k] * wrow[k];
                }
                od[bi * o + oi] = acc;
            }
        }
        if train {
            self.cached_x = Some(x.clone());
        }
        out
    }

    fn backward(&mut self, dy: &Tensor) -> Tensor {
        let x = self.cached_x.take().expect("backward without forward");
        let (b, d) = (x.shape()[0], x.shape()[1]);
        let o = self.weight.shape()[0];
        let mut dx = Tensor::zeros(&[b, d]);
        for bi in 0..b {
            let dyrow = &dy.data()[bi * o..(bi + 1) * o];
            let xrow = &x.data()[bi * d..(bi + 1) * d];
            for oi in 0..o {
                let g = dyrow[oi];
                self.dbias.data_mut()[oi] += g;
                let wrow_base = oi * d;
                for k in 0..d {
                    self.dweight.data_mut()[wrow_base + k] += g * xrow[k];
                    dx.data_mut()[bi * d + k] += g * self.weight.data()[wrow_base + k];
                }
            }
        }
        dx
    }

    fn params_mut(&mut self) -> Vec<(&mut Tensor, &mut Tensor)> {
        vec![
            (&mut self.weight, &mut self.dweight),
            (&mut self.bias, &mut self.dbias),
        ]
    }

    fn param_count(&self) -> usize {
        self.weight.len() + self.bias.len()
    }

    fn name(&self) -> String {
        format!(
            "Linear[{}x{}]",
            self.weight.shape()[0],
            self.weight.shape()[1]
        )
    }
}
