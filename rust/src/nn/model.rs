//! Model containers: a [`Sequential`] stack and the TNN network builder
//! that turns an architecture inventory ([`crate::tnn::arch::ConvSite`])
//! into a stack of tensorial conv blocks — the model family all §5
//! experiments run on.

use super::layers::{
    EvalConfig, GlobalAvgPool, Layer, Linear, MaxPool2, ReLU, TensorialConv2d,
};
use crate::tensor::Tensor;
use crate::tnn::arch::ConvSite;
use crate::tnn::{build_layer, Decomp};
use crate::util::rng::Rng;

/// A sequential stack of layers.
pub struct Sequential {
    pub layers: Vec<Box<dyn Layer>>,
}

impl Sequential {
    pub fn new(layers: Vec<Box<dyn Layer>>) -> Self {
        Sequential { layers }
    }

    pub fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        let mut cur = x.clone();
        for layer in self.layers.iter_mut() {
            cur = layer.forward(&cur, train);
        }
        cur
    }

    pub fn backward(&mut self, dy: &Tensor) -> Tensor {
        let mut cur = dy.clone();
        for layer in self.layers.iter_mut().rev() {
            cur = layer.backward(&cur);
        }
        cur
    }

    pub fn params_mut(&mut self) -> Vec<(&mut Tensor, &mut Tensor)> {
        self.layers
            .iter_mut()
            .flat_map(|l| l.params_mut())
            .collect()
    }

    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|l| l.param_count()).sum()
    }

    /// Peak tape bytes across tensorial layers (Table 3's bounded quantity).
    pub fn peak_tape_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.peak_tape_bytes()).sum()
    }

    pub fn reset_peaks(&self) {
        for l in &self.layers {
            l.reset_peak();
        }
    }

    pub fn describe(&self) -> String {
        self.layers
            .iter()
            .map(|l| l.name())
            .collect::<Vec<_>>()
            .join("\n")
    }
}

/// Configuration for building a tensorial conv-net from an arch inventory.
#[derive(Debug, Clone)]
pub struct TnnNetConfig {
    pub decomp: Decomp,
    /// Reshape order (paper experiments: M=3 for RCP/RTK/RTT/RTR).
    pub m: usize,
    /// Compression rate ∈ (0, 1].
    pub cr: f64,
    pub eval: EvalConfig,
    pub n_classes: usize,
    /// Downsample (MaxPool2) between stages, mirroring ResNet's strides.
    pub pool_between_stages: bool,
}

impl TnnNetConfig {
    pub fn build(&self, sites: &[ConvSite], rng: &mut Rng) -> Result<Sequential, String> {
        let mut layers: Vec<Box<dyn Layer>> = Vec::new();
        let mut prev_stage = None;
        let mut last_t = 0usize;
        for site in sites {
            if self.pool_between_stages {
                if let Some(prev) = prev_stage {
                    if prev != site.stage {
                        layers.push(Box::new(MaxPool2::new()));
                    }
                }
            }
            prev_stage = Some(site.stage);
            for _ in 0..site.count {
                // First layer of the net ingests the raw input channels;
                // inner repeats keep T→T.
                let s_in = if last_t == 0 { site.s } else { last_t };
                let spec = build_layer(self.decomp, self.m, site.t, s_in, site.h, site.w, self.cr)?;
                layers.push(Box::new(TensorialConv2d::new(spec, self.eval, rng)));
                layers.push(Box::new(ReLU::new()));
                last_t = site.t;
            }
        }
        layers.push(Box::new(GlobalAvgPool::new()));
        layers.push(Box::new(Linear::new(last_t, self.n_classes, rng)));
        Ok(Sequential::new(layers))
    }
}

/// A compact tensorial CNN for fast tests/benches: `depth` tensorial conv
/// blocks on `channels`, then GAP + linear head.
pub fn small_tnn_cnn(
    decomp: Decomp,
    m: usize,
    cr: f64,
    in_channels: usize,
    channels: usize,
    depth: usize,
    kernel: usize,
    n_classes: usize,
    eval: EvalConfig,
    rng: &mut Rng,
) -> Result<Sequential, String> {
    small_tnn_cnn_hw(decomp, m, cr, in_channels, channels, depth, kernel, kernel, n_classes, eval, rng)
}

/// As [`small_tnn_cnn`] with a non-square kernel (e.g. temporal-only
/// convolutions for the ASR workload, kw = 1).
#[allow(clippy::too_many_arguments)]
pub fn small_tnn_cnn_hw(
    decomp: Decomp,
    m: usize,
    cr: f64,
    in_channels: usize,
    channels: usize,
    depth: usize,
    kh: usize,
    kw: usize,
    n_classes: usize,
    eval: EvalConfig,
    rng: &mut Rng,
) -> Result<Sequential, String> {
    let mut layers: Vec<Box<dyn Layer>> = Vec::new();
    let mut s = in_channels;
    for _ in 0..depth {
        let spec = build_layer(decomp, m, channels, s, kh, kw, cr)?;
        layers.push(Box::new(TensorialConv2d::new(spec, eval, rng)));
        layers.push(Box::new(ReLU::new()));
        s = channels;
    }
    layers.push(Box::new(GlobalAvgPool::new()));
    layers.push(Box::new(Linear::new(channels, n_classes, rng)));
    Ok(Sequential::new(layers))
}
