//! Integration tests of the training substrate: layer gradient checks,
//! execution-mode equivalence (conv_einsum vs naive paths give identical
//! losses), and actual learning on the synthetic task.

use super::*;
use crate::autodiff::CkptPolicy;
use crate::nn::model::small_tnn_cnn;
use crate::planner::Strategy;
use crate::tensor::Tensor;
use crate::tnn::{build_layer, Decomp};
use crate::util::rng::Rng;

#[test]
fn tensorial_conv_forward_shapes() {
    let mut rng = Rng::new(1);
    let spec = build_layer(Decomp::Cp, 1, 8, 4, 3, 3, 1.0).unwrap();
    let mut layer = TensorialConv2d::new(spec, EvalConfig::conv_einsum(), &mut rng);
    let x = Tensor::rand(&[2, 4, 10, 10], -1.0, 1.0, &mut rng);
    let y = layer.forward(&x, false);
    assert_eq!(y.shape(), &[2, 8, 10, 10]);
}

#[test]
fn tensorial_conv_gradcheck() {
    let mut rng = Rng::new(2);
    let spec = build_layer(Decomp::Cp, 1, 4, 3, 3, 3, 1.0).unwrap();
    let mut layer = TensorialConv2d::new(spec, EvalConfig::conv_einsum(), &mut rng);
    let x = Tensor::rand(&[1, 3, 6, 6], -1.0, 1.0, &mut rng);
    let y = layer.forward(&x, true);
    let dy = Tensor::rand(y.shape(), -1.0, 1.0, &mut rng);
    let dx = layer.backward(&dy);
    assert_eq!(dx.shape(), x.shape());

    // finite differences on a few x coordinates
    let loss = |layer: &mut TensorialConv2d, x: &Tensor| -> f32 {
        let y = layer.forward(x, false);
        y.data().iter().zip(dy.data()).map(|(a, b)| a * b).sum()
    };
    let eps = 1e-2f32;
    for k in [0usize, 31, 77] {
        let mut xp = x.clone();
        xp.data_mut()[k] += eps;
        let mut xm = x.clone();
        xm.data_mut()[k] -= eps;
        let fd = (loss(&mut layer, &xp) - loss(&mut layer, &xm)) / (2.0 * eps);
        let an = dx.data()[k];
        assert!(
            (fd - an).abs() < 3e-2 * (1.0 + an.abs()),
            "dx[{k}]: fd={fd} an={an}"
        );
    }
    // factor gradient check (first factor, a few coords)
    let g0 = layer.grads[0].clone();
    for k in [0usize, 3] {
        let orig = layer.factors[0].data()[k];
        layer.factors[0].data_mut()[k] = orig + eps;
        let lp = loss(&mut layer, &x);
        layer.factors[0].data_mut()[k] = orig - eps;
        let lm = loss(&mut layer, &x);
        layer.factors[0].data_mut()[k] = orig;
        let fd = (lp - lm) / (2.0 * eps);
        let an = g0.data()[k];
        assert!(
            (fd - an).abs() < 3e-2 * (1.0 + an.abs()),
            "dW0[{k}]: fd={fd} an={an}"
        );
    }
}

#[test]
fn eval_modes_compute_identical_functions() {
    // The same factors evaluated under conv_einsum vs naive paths must give
    // identical outputs (the paper's modes differ only in speed/memory).
    let mut rng = Rng::new(3);
    let spec = build_layer(Decomp::Cp, 2, 4, 4, 3, 3, 0.8).unwrap();
    let mut a = TensorialConv2d::new(spec.clone(), EvalConfig::conv_einsum(), &mut rng);
    let mut b = TensorialConv2d::new(spec.clone(), EvalConfig::naive_ckpt(), &mut rng);
    let mut c = TensorialConv2d::new(spec, EvalConfig::naive_no_ckpt(), &mut rng);
    // share weights
    b.factors = a.factors.clone();
    c.factors = a.factors.clone();
    let x = Tensor::rand(&[2, 4, 8, 8], -1.0, 1.0, &mut rng);
    let ya = a.forward(&x, true);
    let yb = b.forward(&x, true);
    let yc = c.forward(&x, true);
    yb.assert_close(&ya, 1e-3);
    yc.assert_close(&ya, 1e-3);
    // and identical gradients
    let dy = Tensor::rand(ya.shape(), -1.0, 1.0, &mut rng);
    let dxa = a.backward(&dy);
    let dxb = b.backward(&dy);
    let dxc = c.backward(&dy);
    dxb.assert_close(&dxa, 1e-3);
    dxc.assert_close(&dxa, 1e-3);
    for i in 0..a.grads.len() {
        b.grads[i].assert_close(&a.grads[i], 1e-3);
        c.grads[i].assert_close(&a.grads[i], 1e-3);
    }
}

#[test]
fn geometry_plan_cache_is_lru_bounded() {
    // More distinct input geometries than the cache capacity: the layer
    // must keep working, never exceed the bound, and retain the most
    // recently used geometries (alternating two geometries at the end must
    // not recompile — observable by the cache length staying fixed).
    let mut rng = Rng::new(11);
    let spec = build_layer(Decomp::Cp, 1, 4, 3, 3, 3, 1.0).unwrap();
    let mut layer = TensorialConv2d::new(spec, EvalConfig::conv_einsum(), &mut rng);
    for b in 1..=GEOMETRY_PLAN_CACHE_CAPACITY + 2 {
        let x = Tensor::rand(&[b, 3, 6, 6], -1.0, 1.0, &mut rng);
        let y = layer.forward(&x, false);
        assert_eq!(y.shape(), &[b, 4, 6, 6]);
        assert!(
            layer.plan_cache_len() <= GEOMETRY_PLAN_CACHE_CAPACITY,
            "cache exceeded its bound: {}",
            layer.plan_cache_len()
        );
    }
    assert_eq!(layer.plan_cache_len(), GEOMETRY_PLAN_CACHE_CAPACITY);
    // Alternating two resident geometries stays within the bound and keeps
    // producing correct shapes (train-batch vs eval-batch pattern).
    for _ in 0..3 {
        for b in [GEOMETRY_PLAN_CACHE_CAPACITY + 1, GEOMETRY_PLAN_CACHE_CAPACITY + 2] {
            let x = Tensor::rand(&[b, 3, 6, 6], -1.0, 1.0, &mut rng);
            let y = layer.forward(&x, false);
            assert_eq!(y.shape(), &[b, 4, 6, 6]);
        }
    }
    assert_eq!(layer.plan_cache_len(), GEOMETRY_PLAN_CACHE_CAPACITY);
}

#[test]
fn eval_config_labels() {
    assert_eq!(EvalConfig::conv_einsum().label(), "conv_einsum");
    assert_eq!(EvalConfig::naive_ckpt().label(), "naive w/ ckpt");
    assert_eq!(EvalConfig::naive_no_ckpt().label(), "naive w/o ckpt");
    assert_eq!(EvalConfig::naive_no_ckpt().ckpt, CkptPolicy::StoreAll);
    assert_eq!(EvalConfig::conv_einsum().strategy, Strategy::Optimal);
}

#[test]
fn maxpool_gradcheck() {
    let mut rng = Rng::new(4);
    let mut pool = MaxPool2::new();
    let x = Tensor::rand(&[1, 2, 4, 4], -1.0, 1.0, &mut rng);
    let y = pool.forward(&x, true);
    assert_eq!(y.shape(), &[1, 2, 2, 2]);
    let dy = Tensor::full(y.shape(), 1.0);
    let dx = pool.backward(&dy);
    // gradient is 1 at each argmax location, 0 elsewhere; sums match
    assert_eq!(dx.sum(), dy.sum());
    assert!(dx.data().iter().all(|&v| v == 0.0 || v == 1.0));
}

#[test]
fn gap_and_linear_gradcheck() {
    let mut rng = Rng::new(5);
    let mut gap = GlobalAvgPool::new();
    let mut lin = Linear::new(3, 2, &mut rng);
    let x = Tensor::rand(&[2, 3, 4, 4], -1.0, 1.0, &mut rng);
    let h = gap.forward(&x, true);
    let y = lin.forward(&h, true);
    assert_eq!(y.shape(), &[2, 2]);
    let dy = Tensor::rand(&[2, 2], -1.0, 1.0, &mut rng);
    let dh = lin.backward(&dy);
    let dx = gap.backward(&dh);

    let loss = |x: &Tensor, lin: &mut Linear, gap: &mut GlobalAvgPool| -> f32 {
        let h = gap.forward(x, false);
        let y = lin.forward(&h, false);
        y.data().iter().zip(dy.data()).map(|(a, b)| a * b).sum()
    };
    let eps = 1e-2f32;
    for k in [0usize, 20, 90] {
        let mut xp = x.clone();
        xp.data_mut()[k] += eps;
        let mut xm = x.clone();
        xm.data_mut()[k] -= eps;
        let fd = (loss(&xp, &mut lin, &mut gap) - loss(&xm, &mut lin, &mut gap)) / (2.0 * eps);
        let an = dx.data()[k];
        assert!((fd - an).abs() < 1e-2, "dx[{k}]: fd={fd} an={an}");
    }
}

#[test]
fn small_tnn_learns_synthetic_task() {
    // End-to-end: a tiny RCP net must beat chance on the synthetic images
    // within a few epochs — the learning-works smoke test.
    let mut rng = Rng::new(6);
    let mut model = small_tnn_cnn(
        Decomp::Cp,
        1,
        1.0,
        1,
        8,
        2,
        3,
        4,
        EvalConfig::conv_einsum(),
        &mut rng,
    )
    .unwrap();
    let train = SyntheticImages::sized(1, 12, 12, 4, 64, 11);
    let eval = SyntheticImages::sized(1, 12, 12, 4, 32, 12);
    let mut trainer = Trainer::new(
        TrainerConfig {
            batch_size: 16,
            epochs: 6,
            ..Default::default()
        },
        Sgd::new(0.05, 0.9, 5e-4),
    );
    let stats = trainer.fit(&mut model, &train, &eval);
    let first = &stats[0];
    let last = stats.last().unwrap();
    assert!(
        last.eval_acc > 0.45,
        "should beat 25% chance clearly: got {}",
        last.eval_acc
    );
    assert!(
        last.train_loss < first.train_loss,
        "loss should decrease: {} -> {}",
        first.train_loss,
        last.train_loss
    );
}

#[test]
fn training_identical_across_eval_modes() {
    // Training curves must be *identical* between conv_einsum and naive
    // modes — only time/memory differ. (Fixed seeds end to end.)
    let run = |eval: EvalConfig| -> Vec<f32> {
        let mut rng = Rng::new(7);
        let mut model =
            small_tnn_cnn(Decomp::Cp, 1, 1.0, 1, 6, 1, 3, 3, eval, &mut rng).unwrap();
        let train = SyntheticImages::sized(1, 10, 10, 3, 32, 21);
        let mut trainer = Trainer::new(
            TrainerConfig {
                batch_size: 8,
                epochs: 2,
                ..Default::default()
            },
            Sgd::new(0.05, 0.9, 5e-4),
        );
        trainer
            .fit(&mut model, &train, &train)
            .iter()
            .map(|s| s.train_loss)
            .collect()
    };
    let a = run(EvalConfig::conv_einsum());
    let b = run(EvalConfig::naive_ckpt());
    for (x, y) in a.iter().zip(b.iter()) {
        assert!((x - y).abs() < 1e-3, "loss curves diverged: {x} vs {y}");
    }
}

#[test]
fn model_peak_memory_reported() {
    let mut rng = Rng::new(8);
    let mut model = small_tnn_cnn(
        Decomp::Cp,
        2,
        0.5,
        2,
        4,
        2,
        3,
        3,
        EvalConfig::naive_no_ckpt(),
        &mut rng,
    )
    .unwrap();
    let x = Tensor::rand(&[2, 2, 8, 8], -1.0, 1.0, &mut rng);
    let y = model.forward(&x, true);
    assert!(model.peak_tape_bytes() > 0);
    assert_eq!(y.shape(), &[2, 3]);
    model.reset_peaks();
    assert_eq!(model.peak_tape_bytes(), 0);
}
