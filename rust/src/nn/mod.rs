//! Training substrate: the minimal neural-network stack needed to run the
//! paper's workloads end-to-end on the native engine — tensorial conv
//! layers driven by the planner/autodiff, elementwise layers, SGD with
//! momentum + weight decay (the paper's §5 hyperparameters), synthetic
//! datasets shaped like the paper's tasks, and a trainer with per-epoch
//! timing and peak-memory metering.

pub mod data;
pub mod layers;
pub mod loss;
pub mod model;
pub mod optim;
pub mod trainer;

pub use data::{Dataset, SyntheticImages, SyntheticSequences};
pub use layers::{
    EvalConfig, GlobalAvgPool, Layer, Linear, MaxPool2, ReLU, TensorialConv2d,
    GEOMETRY_PLAN_CACHE_CAPACITY,
};
pub use loss::{softmax_cross_entropy, SoftmaxCeLoss};
pub use model::{small_tnn_cnn, small_tnn_cnn_hw, Sequential, TnnNetConfig};
pub use optim::Sgd;
pub use trainer::{EpochStats, Trainer, TrainerConfig};

#[cfg(test)]
mod tests;
