//! conv-einsum launcher: plan inspection, FLOPs tables, training runs, the
//! evaluation service demo, and AOT-artifact smoke execution.
//!
//! ```text
//! conv-einsum plan "<expr>" --dims "4,7,9;10,5;5,4,2" [--json] [--strategy S]
//!                            [--training] [--cap FLOPS]
//! conv-einsum flops-table [--batch 128]          # paper Table 2
//! conv-einsum train [--decomp CP] [--m 1] [--cr 0.5] [--epochs 2] [--mode conv_einsum]
//! conv-einsum serve [--requests 64] [--max-batch 8]
//! conv-einsum artifacts [--dir artifacts]
//! ```

use anyhow::{anyhow, Result};
use conv_einsum::nn::{Sgd, SyntheticImages, Trainer, TrainerConfig};
use conv_einsum::planner::{contract_path, PlanOptions, Strategy};
use conv_einsum::tensor::Tensor;
use conv_einsum::tnn::{build_layer, Decomp};
use conv_einsum::util::rng::Rng;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let result = match args.first().map(String::as_str) {
        Some("plan") => cmd_plan(&args[1..]),
        Some("flops-table") => cmd_flops_table(&args[1..]),
        Some("train") => cmd_train(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("artifacts") => cmd_artifacts(&args[1..]),
        Some("--help") | Some("-h") | None => {
            print_usage();
            Ok(())
        }
        Some(other) => Err(anyhow!("unknown subcommand '{other}' (try --help)")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_usage() {
    println!(
        "conv-einsum — representation and fast evaluation of multilinear \
         operations in convolutional TNNs\n\n\
         subcommands:\n  \
         plan <expr> --dims \"d,d;d,d\" [--json] [--strategy optimal|greedy|ltr|measured[:K]] [--training] [--cap N]\n  \
         flops-table [--batch N]     reproduce paper Table 2 (FLOPs per CP layer of ResNet-34)\n  \
         train [--decomp CP|TK|TT|TR|BT|HT] [--m M] [--cr CR] [--epochs N] [--mode conv_einsum|naive_ckpt|naive_no_ckpt]\n  \
         serve [--requests N] [--max-batch N]\n  \
         artifacts [--dir DIR]       list + smoke-run AOT artifacts"
    );
}

fn flag_value<'a>(args: &'a [String], flag: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
}

fn has_flag(args: &[String], flag: &str) -> bool {
    args.iter().any(|a| a == flag)
}

fn parse_dims(text: &str) -> Result<Vec<Vec<usize>>> {
    text.split(';')
        .map(|group| {
            group
                .split(',')
                .map(|d| {
                    d.trim()
                        .parse::<usize>()
                        .map_err(|_| anyhow!("bad dimension '{d}'"))
                })
                .collect()
        })
        .collect()
}

fn cmd_plan(args: &[String]) -> Result<()> {
    let expr = args
        .first()
        .filter(|a| !a.starts_with("--"))
        .ok_or_else(|| anyhow!("usage: plan <expr> --dims \"...\""))?;
    let dims = parse_dims(
        flag_value(args, "--dims").ok_or_else(|| anyhow!("--dims required"))?,
    )?;
    let strategy: Strategy = flag_value(args, "--strategy")
        .unwrap_or("optimal")
        .parse()
        .map_err(|e| anyhow!("{e}"))?;
    let opts = PlanOptions {
        strategy,
        training: has_flag(args, "--training"),
        cost_cap: flag_value(args, "--cap").and_then(|c| c.parse().ok()),
        ..Default::default()
    };
    let plan = contract_path(expr, &dims, &opts).map_err(|e| anyhow!("{e}"))?;
    if has_flag(args, "--json") {
        println!("{}", plan.to_json().encode_pretty());
    } else {
        println!("{}", plan.report());
    }
    Ok(())
}

/// Paper Table 2: analytic FLOPs per CP convolutional layer of ResNet-34,
/// left-to-right vs conv_einsum, CR = 100%, batch 128.
fn cmd_flops_table(args: &[String]) -> Result<()> {
    let batch: usize = flag_value(args, "--batch").unwrap_or("128").parse()?;
    println!("{}", conv_einsum::experiments::table2::run(batch).render());
    Ok(())
}

fn cmd_train(args: &[String]) -> Result<()> {
    use conv_einsum::nn::*;
    let decomp = match flag_value(args, "--decomp").unwrap_or("CP") {
        "CP" => Decomp::Cp,
        "TK" => Decomp::Tucker,
        "TT" => Decomp::TensorTrain,
        "TR" => Decomp::TensorRing,
        "BT" => Decomp::BlockTerm,
        "HT" => Decomp::HierarchicalTucker,
        other => return Err(anyhow!("unknown decomposition '{other}'")),
    };
    let m: usize = flag_value(args, "--m").unwrap_or("1").parse()?;
    let cr: f64 = flag_value(args, "--cr").unwrap_or("0.5").parse()?;
    let epochs: usize = flag_value(args, "--epochs").unwrap_or("2").parse()?;
    let eval = match flag_value(args, "--mode").unwrap_or("conv_einsum") {
        "conv_einsum" => EvalConfig::conv_einsum(),
        "naive_ckpt" => EvalConfig::naive_ckpt(),
        "naive_no_ckpt" => EvalConfig::naive_no_ckpt(),
        other => return Err(anyhow!("unknown mode '{other}'")),
    };
    let mut rng = Rng::new(42);
    let spec = build_layer(decomp, m, 16, 3, 3, 3, cr).map_err(|e| anyhow!("{e}"))?;
    println!(
        "layer: {} ({} params, CR {:.3})",
        spec.expr,
        spec.params,
        spec.achieved_cr()
    );
    let spec2 = build_layer(decomp, m, 16, 16, 3, 3, cr).map_err(|e| anyhow!("{e}"))?;
    let mut model = Sequential::new(vec![
        Box::new(TensorialConv2d::new(spec, eval, &mut rng)),
        Box::new(ReLU::new()),
        Box::new(TensorialConv2d::new(spec2, eval, &mut rng)),
        Box::new(ReLU::new()),
        Box::new(GlobalAvgPool::new()),
        Box::new(Linear::new(16, 10, &mut rng)),
    ]);
    let train = SyntheticImages::sized(3, 16, 16, 10, 128, 7);
    let evalds = SyntheticImages::sized(3, 16, 16, 10, 64, 8);
    let mut trainer = Trainer::new(
        TrainerConfig {
            batch_size: 16,
            epochs,
            verbose: true,
            ..Default::default()
        },
        Sgd::paper_defaults(),
    );
    let stats = trainer.fit(&mut model, &train, &evalds);
    let last = stats.last().unwrap();
    println!(
        "done [{}]: eval acc {:.3}, peak tape {}",
        eval.label(),
        last.eval_acc,
        conv_einsum::util::human_bytes(last.peak_tape_bytes)
    );
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    use conv_einsum::coordinator::{EvalService, ServiceConfig};
    let n_requests: usize = flag_value(args, "--requests").unwrap_or("64").parse()?;
    let max_batch: usize = flag_value(args, "--max-batch").unwrap_or("8").parse()?;
    let mut rng = Rng::new(1);
    let spec = build_layer(Decomp::Cp, 1, 16, 8, 3, 3, 0.5).map_err(|e| anyhow!("{e}"))?;
    let factors = spec.init_factors(&mut rng);
    let service = EvalService::start(
        ServiceConfig {
            max_batch,
            ..Default::default()
        },
        vec![("cp16".to_string(), spec.expr.clone(), factors)],
    )?;
    let h = service.handle();
    let t0 = std::time::Instant::now();
    let receivers: Vec<_> = (0..n_requests)
        .map(|_| {
            let x = Tensor::rand(&[1, 8, 16, 16], -1.0, 1.0, &mut rng);
            h.submit("cp16", x).unwrap()
        })
        .collect();
    for rx in receivers {
        rx.recv().unwrap()?;
    }
    let dt = t0.elapsed();
    println!(
        "{n_requests} requests in {dt:?} ({:.1} req/s)",
        n_requests as f64 / dt.as_secs_f64()
    );
    println!("{}", h.metrics().report());
    service.shutdown();
    Ok(())
}

fn cmd_artifacts(args: &[String]) -> Result<()> {
    use conv_einsum::runtime::ArtifactRegistry;
    let dir = flag_value(args, "--dir").unwrap_or("artifacts");
    let mut registry = ArtifactRegistry::open(dir)?;
    println!("platform: {}", registry.platform());
    let names: Vec<String> = registry.names().iter().map(|s| s.to_string()).collect();
    for name in names {
        let meta = registry.meta(&name).unwrap().clone();
        let mut rng = Rng::new(9);
        let inputs: Vec<Tensor> = meta
            .input_shapes
            .iter()
            .map(|s| Tensor::rand(s, -0.5, 0.5, &mut rng))
            .collect();
        let refs: Vec<&Tensor> = inputs.iter().collect();
        let t0 = std::time::Instant::now();
        let out = registry.execute(&name, &refs)?;
        println!(
            "  {name}: {} inputs -> {} outputs (first shape {:?}) in {:?}   [{}]",
            meta.input_shapes.len(),
            out.len(),
            out[0].shape(),
            t0.elapsed(),
            meta.description,
        );
    }
    Ok(())
}
